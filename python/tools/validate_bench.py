#!/usr/bin/env python3
"""Validate a BENCH_*.json perf-trajectory report (schema holon-bench/v1).

Usage: python python/tools/validate_bench.py BENCH_PR3.json

Exit code 0 when the document is schema-valid, 1 otherwise (errors on
stderr). Stdlib-only so the CI bench-smoke job needs no extra deps.
"""

from __future__ import annotations

import json
import sys

SCHEMA = "holon-bench/v1"

# field -> allowed JSON types per scenario entry
SCENARIO_FIELDS = {
    "name": (str,),
    "system": (str,),
    "workload": (str,),
    "events_per_sec_peak": (int, float),
    "events_per_sec_mean": (int, float),
    "events_produced": (int,),
    "events_consumed": (int,),
    "outputs": (int,),
    "latency_mean_ms": (int, float),
    "latency_p50_ms": (int,),
    "latency_p99_ms": (int,),
    "gossip_msgs": (int,),
    "gossip_bytes_encoded": (int,),
    "gossip_bytes_wire": (int,),
    "gossip_bytes_per_sec": (int, float),
    "payload_clones": (int,),
    "records_read": (int,),
    "payload_clones_per_event": (int, float),
    "dedup_duplicates": (int,),
    "seq_gaps": (int,),
    "stalled": (bool,),
}

SYSTEMS = {"holon", "flink", "flink_spare"}


def validate(doc: object) -> list[str]:
    """Return a list of schema violations (empty == valid)."""
    errors: list[str] = []
    if not isinstance(doc, dict):
        return ["document root must be a JSON object"]
    if doc.get("schema") != SCHEMA:
        errors.append(f"schema must be {SCHEMA!r}, got {doc.get('schema')!r}")
    if not isinstance(doc.get("pr"), str) or not doc.get("pr"):
        errors.append("pr must be a non-empty string")
    if not isinstance(doc.get("quick"), bool):
        errors.append("quick must be a boolean")
    scenarios = doc.get("scenarios")
    if not isinstance(scenarios, list) or not scenarios:
        return errors + ["scenarios must be a non-empty array"]
    names = set()
    for i, sc in enumerate(scenarios):
        where = f"scenarios[{i}]"
        if not isinstance(sc, dict):
            errors.append(f"{where} must be an object")
            continue
        for field, types in SCENARIO_FIELDS.items():
            if field not in sc:
                errors.append(f"{where} missing field {field!r}")
            elif not isinstance(sc[field], types) or (
                # bool is an int subclass in python; reject it for int fields
                isinstance(sc[field], bool) and bool not in types
            ):
                errors.append(
                    f"{where}.{field} has type {type(sc[field]).__name__}, "
                    f"want one of {[t.__name__ for t in types]}"
                )
        extra = set(sc) - set(SCENARIO_FIELDS)
        if extra:
            errors.append(f"{where} has unknown fields {sorted(extra)}")
        name = sc.get("name")
        if isinstance(name, str):
            if name in names:
                errors.append(f"{where} duplicate scenario name {name!r}")
            names.add(name)
        if isinstance(sc.get("system"), str) and sc["system"] not in SYSTEMS:
            errors.append(f"{where}.system {sc['system']!r} not in {sorted(SYSTEMS)}")
        # negative counters are always a bug
        for field in SCENARIO_FIELDS:
            v = sc.get(field)
            if isinstance(v, (int, float)) and not isinstance(v, bool) and v < 0:
                errors.append(f"{where}.{field} is negative ({v})")
    return errors


def main(argv: list[str]) -> int:
    if len(argv) != 2:
        print(__doc__, file=sys.stderr)
        return 2
    try:
        with open(argv[1], encoding="utf-8") as f:
            doc = json.load(f)
    except (OSError, json.JSONDecodeError) as e:
        print(f"error reading {argv[1]}: {e}", file=sys.stderr)
        return 1
    errors = validate(doc)
    if errors:
        for e in errors:
            print(f"schema violation: {e}", file=sys.stderr)
        return 1
    n = len(doc["scenarios"])
    print(f"{argv[1]}: valid {SCHEMA} report with {n} scenario(s)")
    return 0


if __name__ == "__main__":
    sys.exit(main(sys.argv))
