#!/usr/bin/env python3
"""Validate a BENCH_*.json perf-trajectory report (schema holon-bench/v1).

Usage:
    python python/tools/validate_bench.py BENCH_PR9.json
    python python/tools/validate_bench.py BENCH_PR9.json --baseline BENCH_BASELINE.json

Exit code 0 when the document is schema-valid (and, with --baseline, no
scenario regressed), 1 otherwise (errors on stderr). Stdlib-only so the
CI bench-smoke job needs no extra deps.

The --baseline gate compares `events_per_sec_peak` per scenario name
against a previously recorded report (the trajectory row checked in as
BENCH_BASELINE.json) and fails when any shared scenario's peak drops by
more than --max-regress percent (default 10).
"""

from __future__ import annotations

import json
import sys

SCHEMA = "holon-bench/v1"

# field -> allowed JSON types per scenario entry; `list` means an array
# of non-negative ints (the per-shard counters)
SCENARIO_FIELDS = {
    "name": (str,),
    "system": (str,),
    "workload": (str,),
    "events_per_sec_peak": (int, float),
    "events_per_sec_mean": (int, float),
    "events_produced": (int,),
    "events_consumed": (int,),
    "outputs": (int,),
    "latency_mean_ms": (int, float),
    "latency_p50_ms": (int,),
    "latency_p99_ms": (int,),
    "gossip_msgs": (int,),
    "gossip_bytes_encoded": (int,),
    "gossip_bytes_wire": (int,),
    "gossip_bytes_per_sec": (int, float),
    "payload_clones": (int,),
    "records_read": (int,),
    "payload_clones_per_event": (int, float),
    "dedup_duplicates": (int,),
    "seq_gaps": (int,),
    "merge_changed": (int,),
    "merge_noop": (int,),
    "redundant_gossip_bytes": (int,),
    "gossip_skipped": (int,),
    "shard_count": (int,),
    "shard_gossip_bytes": (list,),
    "shard_parallel_merges": (int,),
    "shard_serial_merges": (int,),
    "queries_served": (int,),
    "query_index_hits": (int,),
    "query_index_misses": (int,),
    "query_scan_rows_avoided": (int,),
    "changefeed_lag": (int,),
    "outbound_queue_depth_max": (int,),
    "credits_stalled_rounds": (int,),
    "inbox_depth_max": (int,),
    "output_arena_bytes": (int,),
    "output_frames": (int,),
    "window_ring_spills": (int,),
    "stage_latency_ingest_p50_ms": (int,),
    "stage_latency_ingest_p99_ms": (int,),
    "stage_latency_fire_p50_ms": (int,),
    "stage_latency_fire_p99_ms": (int,),
    "stage_latency_converge_p50_ms": (int,),
    "stage_latency_converge_p99_ms": (int,),
    "stage_latency_emit_p50_ms": (int,),
    "stage_latency_emit_p99_ms": (int,),
    "trace_dropped_events": (int,),
    "stalled": (bool,),
}

# each stage's p50 may not exceed its p99 (histogram percentiles are
# monotone; a violation means the emitter wired the fields wrong)
STAGE_PAIRS = [
    ("stage_latency_ingest_p50_ms", "stage_latency_ingest_p99_ms"),
    ("stage_latency_fire_p50_ms", "stage_latency_fire_p99_ms"),
    ("stage_latency_converge_p50_ms", "stage_latency_converge_p99_ms"),
    ("stage_latency_emit_p50_ms", "stage_latency_emit_p99_ms"),
    ("latency_p50_ms", "latency_p99_ms"),
]

SYSTEMS = {"holon", "flink", "flink_spare"}

# peak ev/s may drop at most this fraction vs the recorded baseline row
DEFAULT_MAX_REGRESS_PCT = 10.0


def _check_int_array(where: str, field: str, v: object) -> list[str]:
    errors = []
    for i, x in enumerate(v):
        if isinstance(x, bool) or not isinstance(x, int):
            errors.append(f"{where}.{field}[{i}] must be an int, got {type(x).__name__}")
        elif x < 0:
            errors.append(f"{where}.{field}[{i}] is negative ({x})")
    return errors


def validate(doc: object) -> list[str]:
    """Return a list of schema violations (empty == valid)."""
    errors: list[str] = []
    if not isinstance(doc, dict):
        return ["document root must be a JSON object"]
    if doc.get("schema") != SCHEMA:
        errors.append(f"schema must be {SCHEMA!r}, got {doc.get('schema')!r}")
    if not isinstance(doc.get("pr"), str) or not doc.get("pr"):
        errors.append("pr must be a non-empty string")
    if not isinstance(doc.get("quick"), bool):
        errors.append("quick must be a boolean")
    scenarios = doc.get("scenarios")
    if not isinstance(scenarios, list) or not scenarios:
        return errors + ["scenarios must be a non-empty array"]
    names = set()
    for i, sc in enumerate(scenarios):
        where = f"scenarios[{i}]"
        if not isinstance(sc, dict):
            errors.append(f"{where} must be an object")
            continue
        for field, types in SCENARIO_FIELDS.items():
            if field not in sc:
                errors.append(f"{where} missing field {field!r}")
            elif not isinstance(sc[field], types) or (
                # bool is an int subclass in python; reject it for int fields
                isinstance(sc[field], bool) and bool not in types
            ):
                errors.append(
                    f"{where}.{field} has type {type(sc[field]).__name__}, "
                    f"want one of {[t.__name__ for t in types]}"
                )
            elif list in types:
                errors.extend(_check_int_array(where, field, sc[field]))
        extra = set(sc) - set(SCENARIO_FIELDS)
        if extra:
            errors.append(f"{where} has unknown fields {sorted(extra)}")
        name = sc.get("name")
        if isinstance(name, str):
            if name in names:
                errors.append(f"{where} duplicate scenario name {name!r}")
            names.add(name)
        if isinstance(sc.get("system"), str) and sc["system"] not in SYSTEMS:
            errors.append(f"{where}.system {sc['system']!r} not in {sorted(SYSTEMS)}")
        # negative counters are always a bug
        for field in SCENARIO_FIELDS:
            v = sc.get(field)
            if isinstance(v, (int, float)) and not isinstance(v, bool) and v < 0:
                errors.append(f"{where}.{field} is negative ({v})")
        # shard_count must agree with the per-shard array
        if isinstance(sc.get("shard_count"), int) and isinstance(
            sc.get("shard_gossip_bytes"), list
        ):
            if sc["shard_count"] != len(sc["shard_gossip_bytes"]):
                errors.append(
                    f"{where}.shard_count ({sc['shard_count']}) != "
                    f"len(shard_gossip_bytes) ({len(sc['shard_gossip_bytes'])})"
                )
        # percentile ordering within each stage histogram
        for lo, hi in STAGE_PAIRS:
            a, b = sc.get(lo), sc.get(hi)
            if (
                isinstance(a, int)
                and isinstance(b, int)
                and not isinstance(a, bool)
                and not isinstance(b, bool)
                and a > b
            ):
                errors.append(f"{where}.{lo} ({a}) exceeds {hi} ({b})")
    return errors


def check_baseline(doc: dict, baseline: dict, max_regress_pct: float) -> list[str]:
    """Regressions of `events_per_sec_peak` vs a recorded baseline report.

    Scenarios are matched by name; names present on only one side are
    ignored (new scenarios are allowed to appear, retired ones to go).
    Returns a list of violations (empty == within budget).
    """
    errors: list[str] = []
    current = {
        sc["name"]: sc
        for sc in doc.get("scenarios", [])
        if isinstance(sc, dict) and isinstance(sc.get("name"), str)
    }
    recorded = {
        sc["name"]: sc
        for sc in baseline.get("scenarios", [])
        if isinstance(sc, dict) and isinstance(sc.get("name"), str)
    }
    floor_frac = 1.0 - max_regress_pct / 100.0
    for name in sorted(set(current) & set(recorded)):
        base = recorded[name].get("events_per_sec_peak")
        now = current[name].get("events_per_sec_peak")
        if not isinstance(base, (int, float)) or not isinstance(now, (int, float)):
            # a non-numeric peak on either side must fail loudly — a
            # silently skipped comparison would leave CI green on an
            # arbitrary regression
            errors.append(f"{name}: events_per_sec_peak is non-numeric on one side")
            continue
        if base > 0 and now < base * floor_frac:
            errors.append(
                f"{name}: events_per_sec_peak regressed {now:.0f} < "
                f"{floor_frac:.2f} x baseline {base:.0f} "
                f"(allowed drop {max_regress_pct:.0f}%)"
            )
    if not set(current) & set(recorded):
        errors.append("no scenario names shared with the baseline report")
    return errors


def main(argv: list[str]) -> int:
    args = argv[1:]
    baseline_path: str | None = None
    max_regress = DEFAULT_MAX_REGRESS_PCT
    paths: list[str] = []
    i = 0
    while i < len(args):
        a = args[i]
        if a == "--baseline":
            if i + 1 >= len(args):
                print("--baseline needs a file argument", file=sys.stderr)
                return 2
            baseline_path = args[i + 1]
            i += 2
        elif a.startswith("--baseline="):
            baseline_path = a.split("=", 1)[1]
            i += 1
        elif a.startswith("--max-regress="):
            try:
                max_regress = float(a.split("=", 1)[1])
            except ValueError:
                print(f"bad --max-regress value: {a}", file=sys.stderr)
                return 2
            i += 1
        else:
            paths.append(a)
            i += 1
    if len(paths) != 1:
        print(__doc__, file=sys.stderr)
        return 2

    def load(path: str) -> object | None:
        try:
            with open(path, encoding="utf-8") as f:
                return json.load(f)
        except (OSError, json.JSONDecodeError) as e:
            print(f"error reading {path}: {e}", file=sys.stderr)
            return None

    doc = load(paths[0])
    if doc is None:
        return 1
    errors = validate(doc)
    if errors:
        for e in errors:
            print(f"schema violation: {e}", file=sys.stderr)
        return 1
    n = len(doc["scenarios"])
    print(f"{paths[0]}: valid {SCHEMA} report with {n} scenario(s)")

    if baseline_path is not None:
        baseline = load(baseline_path)
        if baseline is None:
            return 1
        # A malformed baseline must not neutralize the gate — but only
        # the shape the gate actually reads is enforced (object with a
        # non-empty scenarios array; per-scenario peaks are checked
        # loudly inside check_baseline). Full schema validation here
        # would turn every future schema evolution into a spurious CI
        # failure against the older recorded baseline.
        if (
            not isinstance(baseline, dict)
            or not isinstance(baseline.get("scenarios"), list)
            or not baseline.get("scenarios")
        ):
            print(
                f"baseline {baseline_path}: must be an object with a "
                "non-empty scenarios array",
                file=sys.stderr,
            )
            return 1
        regressions = check_baseline(doc, baseline, max_regress)
        if regressions:
            for e in regressions:
                print(f"perf regression: {e}", file=sys.stderr)
            return 1
        print(f"{paths[0]}: within {max_regress:.0f}% of baseline {baseline_path}")
    return 0


if __name__ == "__main__":
    sys.exit(main(sys.argv))
