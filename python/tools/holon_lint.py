#!/usr/bin/env python3
"""holon-lint — determinism & exactly-once static analysis for the Rust tree.

Every guarantee this repo reproduces from the paper (deterministic
replay, byte-identical cross-replica outputs, exactly-once under
failure) rests on *source-level* disciplines that the runtime
differential suites assume but cannot themselves enforce:

  hash-on-wire      (D1)  no ``HashMap``/``HashSet`` in modules whose
                          iteration order can reach the wire (gossip /
                          checkpoint / emit encode paths). Unordered
                          iteration is the classic nondeterminism leak in
                          stream processors that *intend* to be
                          deterministic — ``BTreeMap`` / ``WindowRing`` /
                          sort-before-emit only.
  wall-clock        (D2)  no ``SystemTime`` / ``Instant`` / ambient RNG
                          outside the allowlisted wall-clock modules
                          (clock.rs, benchkit.rs, trace/). All data-plane
                          time flows through ``SimClock``; all randomness
                          through seeded ``util::XorShift64``.
  discarded-merge   (D3)  no ``let _ = …merge/join/take_delta…``: the
                          trait-v3 contract is that every join reports
                          its effect (``MergeOutcome``); silently
                          discarding it hides divergence and breaks the
                          dirty-marking discipline delta gossip relies on.
  float-crdt-field  (D4)  no raw ``f32``/``f64`` fields in CRDT state
                          structs — float addition is not associative, so
                          merge order would leak into converged values.
                          Use ``util::OrdF64`` (total order, join = max)
                          or a documented prefix discipline (waived).
  zero-alloc        (A1)  functions annotated ``// lint: zero-alloc``
                          (arena emit path, WindowRing in-horizon touch,
                          TraceHandle::record, gossip encode) must not
                          contain allocating constructs (``Vec::new``,
                          ``vec!``, ``format!``, ``to_vec``, ``Box::new``,
                          …) — the static twin of the counting
                          ``#[global_allocator]`` in micro_hotpath.
  lock-unwrap       (S1)  no bare ``.lock().unwrap()`` in data-plane
                          modules: a poisoned mutex cascades the panic
                          across every in-process node, turning one
                          partition's bug into a cluster-wide abort the
                          exactly-once recovery machinery never gets to
                          handle. Use ``util::LockExt::plane_lock()``.

Waivers
-------
Findings are suppressed by an inline comment carrying a mandatory
reason, one of::

    x.lock().unwrap();           // lint:allow(lock-unwrap): <reason>
    // lint:allow(lock-unwrap): <reason>   (applies to the next code line)

or, at file granularity (place anywhere in the file, conventionally at
the top)::

    // lint:allow-file(<rule>): <reason>    whole file
    // lint:allow-tests(<rule>): <reason>   #[cfg(test)] regions only

A waiver that no longer suppresses anything is *stale* and fails the run
under ``--strict`` — the waiver set can only shrink. A waiver without a
reason is always an error.

Usage
-----
    python3 python/tools/holon_lint.py [--root DIR] [--strict] [--json]
    python3 python/tools/holon_lint.py --list-rules

Exit codes: 0 clean; 1 findings / directive errors (plus stale waivers
under ``--strict``); 2 usage error. Stdlib-only by design: this is the
one correctness gate that executes even in containers without a cargo
toolchain (see EXPERIMENTS.md, "Static analysis").
"""

from __future__ import annotations

import argparse
import dataclasses
import json
import pathlib
import re
import sys
import time

# ---------------------------------------------------------------------------
# Rule registry
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class Rule:
    id: str
    paper_tag: str
    summary: str
    hint: str


RULES = {
    r.id: r
    for r in [
        Rule(
            "hash-on-wire",
            "D1",
            "HashMap/HashSet in an encode-path module (iteration order can "
            "reach the wire)",
            "use BTreeMap/BTreeSet or WindowRing; if every order-dependent "
            "read is sorted before leaving the function, waive with that "
            "proof as the reason",
        ),
        Rule(
            "wall-clock",
            "D2",
            "wall-clock or ambient randomness outside the allowlisted clock "
            "modules",
            "route time through SimClock and randomness through a seeded "
            "util::XorShift64 (seeds derive from the sim plan)",
        ),
        Rule(
            "discarded-merge",
            "D3",
            "MergeOutcome discarded via `let _ = ...`",
            "consume the outcome (ClusterMetrics::note_join where a metrics "
            "handle is in scope) or waive with the reason the outcome is "
            "irrelevant at this site",
        ),
        Rule(
            "float-crdt-field",
            "D4",
            "raw f32/f64 field in CRDT state (float merges are not "
            "associative)",
            "store util::OrdF64 (total order) or integer-scaled values, or "
            "waive with the algebraic argument (e.g. prefix discipline: "
            "join never adds floats)",
        ),
        Rule(
            "zero-alloc",
            "A1",
            "allocating construct inside a `// lint: zero-alloc` function",
            "hoist the allocation to a setup/recycle path (arena, ring, "
            "pre-sized buffer); the counting allocator in micro_hotpath is "
            "the runtime ground truth for transitive callees",
        ),
        Rule(
            "lock-unwrap",
            "S1",
            "bare .lock().unwrap() in a data-plane module (poison-abort "
            "cascade hazard)",
            "use util::LockExt::plane_lock() — recovers the poisoned guard; "
            "CRDT state is monotone, so a torn update is re-converged by "
            "the next merge instead of aborting every in-process node",
        ),
    ]
}

DEFAULT_ROOTS = ("rust/src", "rust/tests", "rust/benches")

# Module classification (paths are repo-relative, posix separators).
D1_PREFIXES = (
    "rust/src/crdt/",
    "rust/src/wcrdt/",
    "rust/src/shard/",
    "rust/src/net/",
    "rust/src/api/",
    "rust/src/engine/",
    "rust/src/storage/",
)
D1_FILES = ("rust/src/codec.rs", "rust/src/arena.rs", "rust/src/query/index.rs")

D2_EXEMPT_FILES = ("rust/src/clock.rs", "rust/src/benchkit.rs")
D2_EXEMPT_PREFIXES = ("rust/src/trace/",)

S1_PREFIXES = (
    "rust/src/engine/",
    "rust/src/net/",
    "rust/src/query/",
    "rust/src/trace/",
    "rust/src/log/",
    "rust/src/storage/",
    "rust/src/metrics/",
    "rust/src/crdt/",
    "rust/src/wcrdt/",
    "rust/src/shard/",
    "rust/src/api/",
    "rust/src/runtime/",
)
S1_FILES = ("rust/src/arena.rs", "rust/src/codec.rs")

D4_PREFIXES = ("rust/src/crdt/", "rust/src/wcrdt/", "rust/src/shard/")

# ---------------------------------------------------------------------------
# Rust source scrubbing (lightweight tokenizer)
# ---------------------------------------------------------------------------

_RAW_STR_RE = re.compile(r'b?r(#*)"')
_CHAR_LIT_RE = re.compile(
    r"'(?:\\(?:x[0-9a-fA-F]{2}|u\{[0-9a-fA-F_]{1,6}\}|.)|[^\\'\n])'"
)
_IDENT_CHARS = set("abcdefghijklmnopqrstuvwxyzABCDEFGHIJKLMNOPQRSTUVWXYZ0123456789_")


def scrub(text: str):
    """Blank comments, strings and char literals out of Rust source.

    Returns ``(code, comments)`` where ``code`` is the same length as
    ``text`` (newlines preserved, everything non-code replaced by
    spaces) so offsets map 1:1, and ``comments`` is a list of
    ``(line0, comment_text)`` for every ``//`` comment (text excludes
    the slashes). Handles nested block comments, escaped quotes, raw
    strings (``r"…"``/``r#"…"#``/``br"…"``) and the char-literal vs
    lifetime ambiguity.
    """
    n = len(text)
    out = list(text)
    comments = []
    i = 0
    line = 0

    def blank(a, b):
        for k in range(a, b):
            if out[k] != "\n":
                out[k] = " "

    while i < n:
        c = text[i]
        if c == "\n":
            line += 1
            i += 1
            continue
        if c == "/" and text.startswith("//", i):
            j = text.find("\n", i)
            if j == -1:
                j = n
            comments.append((line, text[i + 2 : j]))
            blank(i, j)
            i = j
            continue
        if c == "/" and text.startswith("/*", i):
            depth = 1
            j = i + 2
            while j < n and depth:
                if text.startswith("/*", j):
                    depth += 1
                    j += 2
                elif text.startswith("*/", j):
                    depth -= 1
                    j += 2
                else:
                    j += 1
            blank(i, j)
            line += text.count("\n", i, j)
            i = j
            continue
        if c in "rb":
            prev = text[i - 1] if i > 0 else " "
            if prev not in _IDENT_CHARS:
                m = _RAW_STR_RE.match(text, i)
                if m:
                    closer = '"' + "#" * len(m.group(1))
                    j = text.find(closer, m.end())
                    j = n if j == -1 else j + len(closer)
                    blank(i, j)
                    line += text.count("\n", i, j)
                    i = j
                    continue
        if c == '"':
            prev = text[i - 1] if i > 0 else " "
            # b"..." byte strings: the 'b' is blanked as part of the code
            # being a prefix is fine — we only start here at the quote.
            j = i + 1
            while j < n:
                if text[j] == "\\":
                    j += 2
                    continue
                if text[j] == '"':
                    j += 1
                    break
                j += 1
            blank(i, j)
            line += text.count("\n", i, j)
            i = j
            continue
        if c == "'":
            m = _CHAR_LIT_RE.match(text, i)
            if m:
                blank(i, m.end())
                i = m.end()
                continue
            # lifetime tick — leave in place, it is inert for every rule
            i += 1
            continue
        i += 1
    return "".join(out), comments


def match_brace(code: str, open_idx: int) -> int:
    """Offset one past the ``}`` matching the ``{`` at ``open_idx``
    (``len(code)`` when unbalanced)."""
    depth = 0
    for j in range(open_idx, len(code)):
        ch = code[j]
        if ch == "{":
            depth += 1
        elif ch == "}":
            depth -= 1
            if depth == 0:
                return j + 1
    return len(code)


# ---------------------------------------------------------------------------
# Findings, waivers, directives
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class Finding:
    rule: str
    rel: str
    line: int  # 1-based
    message: str
    waived: bool = False

    def as_dict(self):
        return dataclasses.asdict(self)


@dataclasses.dataclass
class Waiver:
    rel: str
    line: int  # 1-based, where the directive sits
    scope: str  # "inline" | "file" | "tests"
    rule: str
    reason: str
    target_line: int  # inline only: the code line it covers
    used: bool = False


@dataclasses.dataclass
class Problem:
    """Engine-level error: malformed/stale directives."""

    rel: str
    line: int
    kind: str  # stale-waiver | waiver-missing-reason | unknown-rule |
    #            unknown-directive | dangling-zero-alloc
    message: str

    def as_dict(self):
        return dataclasses.asdict(self)


_DIRECTIVE_RE = re.compile(r"^\s*lint\s*:\s*(.+?)\s*$")
_ALLOW_RE = re.compile(
    r"^allow(-file|-tests)?\s*\(\s*([A-Za-z0-9_-]+)\s*\)\s*(?::\s*(.*))?$"
)


class SourceFile:
    def __init__(self, root: pathlib.Path, path: pathlib.Path):
        self.path = path
        self.rel = path.relative_to(root).as_posix()
        self.raw = path.read_text(encoding="utf-8", errors="replace")
        self.code, self.comments = scrub(self.raw)
        # offset -> line lookup
        self.line_starts = [0]
        for m in re.finditer(r"\n", self.raw):
            self.line_starts.append(m.end())
        self.is_test_file = self.rel.startswith(("rust/tests/", "rust/benches/"))
        self.test_regions = self._find_test_regions()
        self.code_lines = self.code.split("\n")

    def line_of(self, offset: int) -> int:
        """1-based line number of a byte offset."""
        import bisect

        return bisect.bisect_right(self.line_starts, offset)

    def _find_test_regions(self):
        regions = []
        for m in re.finditer(r"#\s*\[\s*cfg\s*\(\s*test\s*\)\s*\]", self.code):
            open_idx = self.code.find("{", m.end())
            if open_idx == -1:
                continue
            end = match_brace(self.code, open_idx)
            regions.append((self.line_of(m.start()), self.line_of(end - 1)))
        return regions

    def in_test(self, line: int) -> bool:
        if self.is_test_file:
            return True
        return any(a <= line <= b for a, b in self.test_regions)

    def line_has_code(self, line: int) -> bool:
        idx = line - 1
        if idx < 0 or idx >= len(self.code_lines):
            return False
        return bool(self.code_lines[idx].strip())

    def next_code_line(self, line: int) -> int:
        """First line >= `line` with code on it (for standalone waivers)."""
        j = line
        while j <= len(self.code_lines) and not self.line_has_code(j):
            j += 1
        return j


def parse_directives(sf: SourceFile, problems: list):
    """Extract waivers and zero-alloc annotations from `//` comments."""
    waivers = []
    zero_alloc_lines = []  # 1-based directive lines
    for line0, text in sf.comments:
        dm = _DIRECTIVE_RE.match(text)
        if not dm:
            continue
        body = dm.group(1)
        line = line0 + 1
        if body == "zero-alloc":
            zero_alloc_lines.append(line)
            continue
        am = _ALLOW_RE.match(body)
        if not am:
            problems.append(
                Problem(
                    sf.rel,
                    line,
                    "unknown-directive",
                    f"unrecognized lint directive `lint: {body}`",
                )
            )
            continue
        scope = {None: "inline", "-file": "file", "-tests": "tests"}[am.group(1)]
        rule = am.group(2)
        reason = (am.group(3) or "").strip()
        if rule not in RULES:
            problems.append(
                Problem(
                    sf.rel,
                    line,
                    "unknown-rule",
                    f"waiver names unknown rule `{rule}` "
                    f"(known: {', '.join(sorted(RULES))})",
                )
            )
            continue
        if not reason:
            problems.append(
                Problem(
                    sf.rel,
                    line,
                    "waiver-missing-reason",
                    f"waiver for `{rule}` carries no reason — the reason is "
                    "mandatory",
                )
            )
            continue
        target = line if sf.line_has_code(line) else sf.next_code_line(line + 1)
        waivers.append(Waiver(sf.rel, line, scope, rule, reason, target))
    return waivers, zero_alloc_lines


# ---------------------------------------------------------------------------
# Rule checks
# ---------------------------------------------------------------------------

_HASH_RE = re.compile(r"\bHash(?:Map|Set)\b")
_WALLCLOCK_RE = re.compile(
    r"\b(SystemTime|Instant|thread_rng|from_entropy)\b|\brand\s*::\s*random\b"
)
_LET_DISCARD_RE = re.compile(r"\blet\s+_\s*=\s*")
_MERGE_CALLEE_RE = re.compile(
    r"\b(merge_report|merge_entry|join_delta_into|take_delta|ingest|merge|join)"
    r"\s*\("
)
_LOCK_UNWRAP_RE = re.compile(r"\.\s*lock\s*\(\s*\)\s*\.\s*unwrap\s*\(\s*\)")
_FLOAT_RE = re.compile(r"\b(f32|f64)\b")
_STRUCT_RE = re.compile(r"\bstruct\s+(\w+)")
_IMPL_CRDT_RE = re.compile(r"\bimpl\s*(?:<[^>]*>)?\s+(?:[\w:]+\s*::\s*)?Crdt\s+for\s+(\w+)")
_FN_RE = re.compile(r"\bfn\s+(\w+)")
_ALLOC_BANNED = [
    (re.compile(r"\bVec\s*::\s*new\b"), "Vec::new"),
    (re.compile(r"\bvec!\s*"), "vec!"),
    (re.compile(r"\bformat!\s*"), "format!"),
    (re.compile(r"\.\s*to_vec\s*\("), ".to_vec()"),
    (re.compile(r"\bString\s*::\s*from\b"), "String::from"),
    (re.compile(r"\bString\s*::\s*new\b"), "String::new"),
    (re.compile(r"\bBox\s*::\s*new\b"), "Box::new"),
    (re.compile(r"\.\s*to_string\s*\("), ".to_string()"),
    (re.compile(r"\.\s*to_owned\s*\("), ".to_owned()"),
]


def _statement_end(code: str, start: int) -> int:
    """Offset of the `;` ending the statement starting at `start`
    (depth-aware for parens/brackets/braces in the expression)."""
    depth = 0
    for j in range(start, min(len(code), start + 4000)):
        ch = code[j]
        if ch in "([{":
            depth += 1
        elif ch in ")]}":
            depth -= 1
        elif ch == ";" and depth <= 0:
            return j
    return min(len(code), start + 4000)


def check_hash_on_wire(sf: SourceFile, findings):
    if not (sf.rel.startswith(D1_PREFIXES) or sf.rel in D1_FILES):
        return
    for m in _HASH_RE.finditer(sf.code):
        line = sf.line_of(m.start())
        if sf.in_test(line):
            continue
        findings.append(
            Finding(
                "hash-on-wire",
                sf.rel,
                line,
                f"`{m.group(0)}` in encode-path module `{sf.rel}` — unordered "
                "iteration here can reach the wire",
            )
        )


def check_wall_clock(sf: SourceFile, findings):
    if sf.rel in D2_EXEMPT_FILES or sf.rel.startswith(D2_EXEMPT_PREFIXES):
        return
    for m in _WALLCLOCK_RE.finditer(sf.code):
        line = sf.line_of(m.start())
        tok = m.group(0)
        findings.append(
            Finding(
                "wall-clock",
                sf.rel,
                line,
                f"`{tok}` outside the wall-clock allowlist — data-plane time "
                "must flow through SimClock, randomness through seeded RNGs",
            )
        )


def check_discarded_merge(sf: SourceFile, findings):
    for m in _LET_DISCARD_RE.finditer(sf.code):
        end = _statement_end(sf.code, m.end())
        expr = sf.code[m.end() : end]
        hit = None
        for cm in _MERGE_CALLEE_RE.finditer(expr):
            name = cm.group(1)
            if name == "join":
                # `handle.join()` (zero args) is a thread join, not a
                # lattice join — only flag calls that pass an argument.
                rest = expr[cm.end() :].lstrip()
                if rest.startswith(")"):
                    continue
            hit = name
            break
        if hit is None:
            continue
        line = sf.line_of(m.start())
        findings.append(
            Finding(
                "discarded-merge",
                sf.rel,
                line,
                f"MergeOutcome of `{hit}` discarded by `let _ = …`",
            )
        )


def check_lock_unwrap(sf: SourceFile, findings):
    if not (sf.rel.startswith(S1_PREFIXES) or sf.rel in S1_FILES):
        return
    for m in _LOCK_UNWRAP_RE.finditer(sf.code):
        line = sf.line_of(m.start())
        if sf.in_test(line):
            continue
        findings.append(
            Finding(
                "lock-unwrap",
                sf.rel,
                line,
                "bare `.lock().unwrap()` in a data-plane module — a poisoned "
                "mutex cascades the panic across in-process nodes",
            )
        )


def collect_crdt_impls(files) -> set:
    types = set()
    for sf in files:
        for m in _IMPL_CRDT_RE.finditer(sf.code):
            types.add(m.group(1))
    return types


def check_float_fields(sf: SourceFile, crdt_types: set, findings):
    in_crdt_module = sf.rel.startswith(D4_PREFIXES)
    if not sf.rel.startswith("rust/src/"):
        return
    for m in _STRUCT_RE.finditer(sf.code):
        name = m.group(1)
        line = sf.line_of(m.start())
        if sf.in_test(line):
            continue
        if not in_crdt_module and name not in crdt_types:
            continue
        # find the struct body: first '{' before any ';' terminator
        semi = sf.code.find(";", m.end())
        brace = sf.code.find("{", m.end())
        if brace == -1 or (semi != -1 and semi < brace):
            # tuple/unit struct: scan the `(...)` payload if any
            paren = sf.code.find("(", m.end())
            if paren != -1 and (semi == -1 or paren < semi):
                span = sf.code[paren : semi if semi != -1 else paren + 400]
                fm = _FLOAT_RE.search(span)
                if fm:
                    findings.append(
                        Finding(
                            "float-crdt-field",
                            sf.rel,
                            sf.line_of(paren + fm.start()),
                            f"raw `{fm.group(0)}` field in CRDT state struct "
                            f"`{name}` — float merges are not associative",
                        )
                    )
            continue
        end = match_brace(sf.code, brace)
        for fm in _FLOAT_RE.finditer(sf.code, brace, end):
            findings.append(
                Finding(
                    "float-crdt-field",
                    sf.rel,
                    sf.line_of(fm.start()),
                    f"raw `{fm.group(0)}` field in CRDT state struct `{name}` "
                    "— float merges are not associative",
                )
            )


def check_zero_alloc(sf: SourceFile, zero_alloc_lines, findings, problems):
    for dline in zero_alloc_lines:
        # the annotated fn starts on the first fn-bearing code line below
        # the directive (attributes / doc comments may intervene)
        start_off = sf.line_starts[dline - 1] if dline - 1 < len(sf.line_starts) else 0
        fm = _FN_RE.search(sf.code, start_off)
        if not fm or sf.line_of(fm.start()) > dline + 12:
            problems.append(
                Problem(
                    sf.rel,
                    dline,
                    "dangling-zero-alloc",
                    "`lint: zero-alloc` annotation is not followed by a "
                    "function definition",
                )
            )
            continue
        brace = sf.code.find("{", fm.end())
        if brace == -1:
            continue
        end = match_brace(sf.code, brace)
        fn_name = fm.group(1)
        for rx, label in _ALLOC_BANNED:
            for am in rx.finditer(sf.code, brace, end):
                findings.append(
                    Finding(
                        "zero-alloc",
                        sf.rel,
                        sf.line_of(am.start()),
                        f"`{label}` inside `// lint: zero-alloc` fn "
                        f"`{fn_name}`",
                    )
                )


# ---------------------------------------------------------------------------
# Engine
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class Report:
    findings: list
    waivers: list
    problems: list
    files_scanned: int
    elapsed_ms: float

    @property
    def unwaived(self):
        return [f for f in self.findings if not f.waived]

    @property
    def stale_waivers(self):
        return [w for w in self.waivers if not w.used]


def apply_waivers(findings, waivers, files_by_rel):
    """Match findings against waivers; inline beats tests beats file."""
    by_key = {}
    for w in waivers:
        by_key.setdefault((w.rel, w.rule, w.scope), []).append(w)
    for f in findings:
        sf = files_by_rel[f.rel]
        for w in by_key.get((f.rel, f.rule, "inline"), []):
            if w.target_line == f.line:
                f.waived = True
                w.used = True
                break
        if f.waived:
            continue
        if sf.in_test(f.line):
            for w in by_key.get((f.rel, f.rule, "tests"), []):
                f.waived = True
                w.used = True
                break
        if f.waived:
            continue
        for w in by_key.get((f.rel, f.rule, "file"), []):
            f.waived = True
            w.used = True
            break


def run_lint(root: pathlib.Path, roots=DEFAULT_ROOTS) -> Report:
    t0 = time.monotonic()
    paths = []
    for r in roots:
        base = root / r
        if base.is_dir():
            paths.extend(sorted(base.rglob("*.rs")))
    files = [SourceFile(root, p) for p in paths]
    files_by_rel = {sf.rel: sf for sf in files}

    findings, waivers, problems = [], [], []
    crdt_types = collect_crdt_impls(files)
    for sf in files:
        ws, za = parse_directives(sf, problems)
        waivers.extend(ws)
        check_hash_on_wire(sf, findings)
        check_wall_clock(sf, findings)
        check_discarded_merge(sf, findings)
        check_lock_unwrap(sf, findings)
        check_float_fields(sf, crdt_types, findings)
        check_zero_alloc(sf, za, findings, problems)

    apply_waivers(findings, waivers, files_by_rel)
    elapsed = (time.monotonic() - t0) * 1000.0
    return Report(findings, waivers, problems, len(files), elapsed)


# ---------------------------------------------------------------------------
# CLI
# ---------------------------------------------------------------------------


def _print_report(rep: Report, strict: bool):
    for f in rep.unwaived:
        r = RULES[f.rule]
        print(f"{f.rel}:{f.line}: [{f.rule}] {f.message}")
        print(f"    hint: {r.hint}")
    for p in rep.problems:
        print(f"{p.rel}:{p.line}: [{p.kind}] {p.message}")
    for w in rep.stale_waivers:
        sev = "error" if strict else "warning"
        print(
            f"{w.rel}:{w.line}: [stale-waiver] {sev}: waiver for "
            f"`{w.rule}` suppresses nothing — remove it (the waiver set "
            "only shrinks)"
        )
    waived = sum(1 for f in rep.findings if f.waived)
    print(
        f"holon-lint: {len(rep.findings)} finding(s) "
        f"({waived} waived, {len(rep.unwaived)} unwaived), "
        f"{len(rep.stale_waivers)} stale waiver(s), "
        f"{len(rep.problems)} directive error(s) — "
        f"{rep.files_scanned} files in {rep.elapsed_ms:.0f} ms"
    )


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="holon-lint",
        description="determinism & exactly-once static analysis over the "
        "Rust tree (stdlib-only; see module docstring for the rule set)",
    )
    ap.add_argument(
        "--root",
        default=None,
        help="repo root (default: two levels above this script)",
    )
    ap.add_argument(
        "--strict",
        action="store_true",
        help="also fail on stale waivers (CI gate mode)",
    )
    ap.add_argument("--json", action="store_true", help="machine-readable output")
    ap.add_argument(
        "--list-rules", action="store_true", help="print the rule registry and exit"
    )
    args = ap.parse_args(argv)

    if args.list_rules:
        for r in RULES.values():
            print(f"{r.id:18s} ({r.paper_tag})  {r.summary}")
            print(f"{'':18s}        fix: {r.hint}")
        return 0

    root = (
        pathlib.Path(args.root).resolve()
        if args.root
        else pathlib.Path(__file__).resolve().parents[2]
    )
    if not (root / "rust" / "src").is_dir():
        print(f"holon-lint: no rust/src under {root}", file=sys.stderr)
        return 2

    rep = run_lint(root)
    if args.json:
        print(
            json.dumps(
                {
                    "findings": [f.as_dict() for f in rep.findings],
                    "stale_waivers": [
                        dataclasses.asdict(w) for w in rep.stale_waivers
                    ],
                    "problems": [p.as_dict() for p in rep.problems],
                    "files_scanned": rep.files_scanned,
                    "elapsed_ms": rep.elapsed_ms,
                },
                indent=2,
            )
        )
    else:
        _print_report(rep, args.strict)

    failed = bool(rep.unwaived or rep.problems)
    if args.strict and rep.stale_waivers:
        failed = True
    return 1 if failed else 0


if __name__ == "__main__":
    sys.exit(main())
