"""Layer-2 JAX compute graphs (build-time only; AOT-lowered by aot.py).

Two graphs are exported for the rust hot path:

* ``window_batch`` — the per-batch aggregation step of the node executor:
  calls the Pallas ``window_aggregate`` kernel and derives per-window
  averages (guarded division) in the same fused module.  One executable
  invocation folds a whole event batch into per-window partial aggregates
  (sum, count, max, avg) that rust then joins into WCRDT lattice state.

* ``merge_batch`` — the gossip-path lattice join: calls the Pallas
  ``crdt_merge`` kernel on stacked replica state matrices.

Both are pure functions of their inputs — no trainable state — so forward
lowering is all the paper's system needs (there is no bwd pass in a
stream-aggregation workload).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from compile.kernels.window_agg import window_aggregate, BATCH, WINDOWS
from compile.kernels.crdt_merge import crdt_merge, ROWS, COLS


def window_batch(values, window_ids):
    """Aggregate one event batch.

    Args:
      values:     f32[BATCH]  event values (padded entries arbitrary).
      window_ids: i32[BATCH]  window index in [0, WINDOWS); negative = pad.

    Returns:
      (sums, counts, maxes, avgs): four f32[WINDOWS] vectors.
    """
    sums, counts, maxes = window_aggregate(values, window_ids, windows=WINDOWS)
    avgs = jnp.where(counts > 0, sums / jnp.maximum(counts, 1.0), 0.0)
    return sums, counts, maxes, avgs


def merge_batch(a, b):
    """Join two stacked replica state matrices (f32[ROWS, COLS])."""
    return (crdt_merge(a, b),)


def window_batch_specs():
    return (
        jax.ShapeDtypeStruct((BATCH,), jnp.float32),
        jax.ShapeDtypeStruct((BATCH,), jnp.int32),
    )


def merge_batch_specs():
    spec = jax.ShapeDtypeStruct((ROWS, COLS), jnp.float32)
    return (spec, spec)
