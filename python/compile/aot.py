"""AOT lowering: JAX graphs -> HLO *text* artifacts for the rust runtime.

HLO text (NOT ``lowered.compile()`` / serialized HloModuleProto) is the
interchange format: jax >= 0.5 emits protos with 64-bit instruction ids
which the xla crate's xla_extension 0.5.1 rejects (``proto.id() <=
INT_MAX``).  The HLO text parser on the rust side reassigns ids, so text
round-trips cleanly.  See /opt/xla-example/gen_hlo.py.

Usage:  cd python && python -m compile.aot --out-dir ../artifacts
"""

from __future__ import annotations

import argparse
import os

import jax
from jax._src.lib import xla_client as xc

from compile import model


def to_hlo_text(lowered) -> str:
    """stablehlo MLIR -> XlaComputation -> HLO text, return_tuple=True."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


EXPORTS = {
    "window_agg": (model.window_batch, model.window_batch_specs),
    "crdt_merge": (model.merge_batch, model.merge_batch_specs),
}


def main() -> None:
    parser = argparse.ArgumentParser(description=__doc__)
    parser.add_argument("--out-dir", default="../artifacts")
    parser.add_argument("--only", choices=sorted(EXPORTS), default=None)
    args = parser.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)

    names = [args.only] if args.only else sorted(EXPORTS)
    for name in names:
        fn, specs = EXPORTS[name]
        lowered = jax.jit(fn).lower(*specs())
        text = to_hlo_text(lowered)
        path = os.path.join(args.out_dir, f"{name}.hlo.txt")
        with open(path, "w") as f:
            f.write(text)
        print(f"wrote {len(text)} chars to {path}")


if __name__ == "__main__":
    main()
