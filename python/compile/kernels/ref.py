"""Pure-jnp oracles for the Pallas kernels (the correctness ground truth).

No pallas imports here: these are straight-line jax.numpy implementations
that pytest/hypothesis compare the kernels against.
"""

from __future__ import annotations

import jax.numpy as jnp

NEG_INF = float("-inf")


def window_aggregate_ref(values, window_ids, *, windows):
    """Reference segment reduce: per-window (sums, counts, maxes)."""
    wids = window_ids[None, :] == jnp.arange(windows, dtype=jnp.int32)[:, None]
    vals = jnp.broadcast_to(values[None, :], wids.shape)
    sums = jnp.sum(jnp.where(wids, vals, 0.0), axis=1)
    counts = jnp.sum(wids.astype(jnp.float32), axis=1)
    maxes = jnp.max(jnp.where(wids, vals, NEG_INF), axis=1)
    return sums, counts, maxes


def crdt_merge_ref(a, b):
    """Reference lattice join: element-wise max."""
    return jnp.maximum(a, b)


def averages_ref(sums, counts):
    """Guarded per-window average: 0 where the window is empty."""
    return jnp.where(counts > 0, sums / jnp.maximum(counts, 1.0), 0.0)
