"""Layer-1 Pallas kernel: CRDT lattice merge (element-wise join).

State-based CRDT synchronization merges two replicas by a join-semilattice
`merge`.  For the numeric CRDTs Holon Streaming gossips at high rate —
GCounter per-node contribution vectors, MaxRegister/TopK score tables —
the join is an element-wise max over equally-shaped matrices:

    merged[i, j] = max(a[i, j], b[i, j])

For PNCounter-style state the increment and decrement planes are stored as
separate rows, so a single element-wise max still implements the join.

Pure VPU workload: tiled element-wise max with (8, 128)-aligned blocks —
no MXU involvement, no cross-lane traffic.  interpret=True for the CPU
PJRT path (see window_agg.py).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Default AOT merge-tile shape: 64 replicas/rows x 128 lanes.
ROWS = 64
COLS = 128
ROW_TILE = 8


def _merge_kernel(a_ref, b_ref, out_ref):
    out_ref[...] = jnp.maximum(a_ref[...], b_ref[...])


@jax.jit
def crdt_merge(a, b):
    """Element-wise lattice join of two f32[R, C] state matrices."""
    rows, cols = a.shape
    assert a.shape == b.shape
    assert rows % ROW_TILE == 0
    grid = (rows // ROW_TILE,)
    spec = pl.BlockSpec((ROW_TILE, cols), lambda i: (i, 0))
    return pl.pallas_call(
        _merge_kernel,
        grid=grid,
        in_specs=[spec, spec],
        out_specs=spec,
        out_shape=jax.ShapeDtypeStruct((rows, cols), jnp.float32),
        interpret=True,
    )(a, b)
