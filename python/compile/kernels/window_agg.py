"""Layer-1 Pallas kernel: batched windowed aggregation (segment reduce).

The numeric hot-spot of every Holon Streaming workload is folding a batch
of events into per-window partial aggregates before they are merged into
the Windowed-CRDT lattice state.  Given

    values     : f32[B]   event values (e.g. bid prices)
    window_ids : i32[B]   window index per event, in [0, W) (or <0 = pad)

the kernel produces, per window w:

    sums[w]   = sum  of values where window_ids == w
    counts[w] = count of events where window_ids == w
    maxes[w]  = max  of values where window_ids == w  (NEG_INF if empty)

TPU-shaped formulation (see DESIGN.md §Hardware-Adaptation): instead of a
scatter-add (atomics / shared-memory on GPU — hostile to the VPU/MXU), we
grid over *window tiles*; each grid step holds a (WT,)-tile of windows and
the full value batch in VMEM and performs masked broadcast reductions —
one pass produces sum, count and max simultaneously.

The kernel is lowered with interpret=True: the CPU PJRT plugin cannot run
Mosaic custom-calls, and correctness (vs ref.py) is what the CPU path
verifies.  Real-TPU characteristics are estimated in DESIGN.md §Perf.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = float("-inf")

# Default AOT shapes (rust pads batches to these; see rust/src/runtime).
BATCH = 1024
WINDOWS = 32
WINDOW_TILE = 8  # windows per grid step


def _window_agg_kernel(values_ref, window_ids_ref, sums_ref, counts_ref, maxes_ref):
    """One grid step: reduce the full batch into a WINDOW_TILE-slice."""
    w0 = pl.program_id(0) * WINDOW_TILE
    values = values_ref[...]          # f32[B]
    wids = window_ids_ref[...]        # i32[B]

    # (WT, B) mask: mask[t, b] = (wids[b] == w0 + t).  Padded events carry a
    # negative window id and therefore never match.
    tile_ids = w0 + jax.lax.broadcasted_iota(jnp.int32, (WINDOW_TILE, 1), 0)
    mask = wids[None, :] == tile_ids  # bool[WT, B]

    vals_b = jnp.broadcast_to(values[None, :], (WINDOW_TILE, values.shape[0]))
    sums_ref[...] = jnp.sum(jnp.where(mask, vals_b, 0.0), axis=1)
    counts_ref[...] = jnp.sum(mask.astype(jnp.float32), axis=1)
    maxes_ref[...] = jnp.max(jnp.where(mask, vals_b, NEG_INF), axis=1)


@functools.partial(jax.jit, static_argnames=("windows",))
def window_aggregate(values, window_ids, *, windows=WINDOWS):
    """Segment-reduce values by window id. Returns (sums, counts, maxes)."""
    batch = values.shape[0]
    assert windows % WINDOW_TILE == 0, "windows must be a multiple of WINDOW_TILE"
    grid = (windows // WINDOW_TILE,)
    out_shape = [
        jax.ShapeDtypeStruct((windows,), jnp.float32),  # sums
        jax.ShapeDtypeStruct((windows,), jnp.float32),  # counts
        jax.ShapeDtypeStruct((windows,), jnp.float32),  # maxes
    ]
    # Each grid step sees the whole batch (VMEM-resident: B*4*2 bytes ≈ 8 KiB
    # at B=1024) and writes one WINDOW_TILE slice of each output.
    in_specs = [
        pl.BlockSpec((batch,), lambda i: (0,)),
        pl.BlockSpec((batch,), lambda i: (0,)),
    ]
    out_specs = [
        pl.BlockSpec((WINDOW_TILE,), lambda i: (i,)),
        pl.BlockSpec((WINDOW_TILE,), lambda i: (i,)),
        pl.BlockSpec((WINDOW_TILE,), lambda i: (i,)),
    ]
    return pl.pallas_call(
        _window_agg_kernel,
        grid=grid,
        in_specs=in_specs,
        out_specs=out_specs,
        out_shape=out_shape,
        interpret=True,
    )(values, window_ids)
