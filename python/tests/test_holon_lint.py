"""Tests for holon-lint, the determinism/exactly-once static analyzer.

Two layers:

* fixture repos built under ``tmp_path`` exercising each rule's
  positive/negative space, the scrubber, and the waiver machinery;
* a meta-test asserting the *real* tree lints clean under ``--strict``
  — the same invocation the CI ``lint-smoke`` job runs.
"""

import importlib.util
import json
import pathlib
import sys
import textwrap

_spec = importlib.util.spec_from_file_location(
    "holon_lint",
    pathlib.Path(__file__).resolve().parents[1] / "tools" / "holon_lint.py",
)
hl = importlib.util.module_from_spec(_spec)
# dataclass field resolution needs the module visible in sys.modules
# while the body executes (PEP 563 deferred annotations)
sys.modules["holon_lint"] = hl
_spec.loader.exec_module(hl)

REPO_ROOT = pathlib.Path(__file__).resolve().parents[2]


# ---------------------------------------------------------------------------
# fixture helpers
# ---------------------------------------------------------------------------


def repo(tmp_path, files):
    """Build a throwaway repo: {relpath: source} -> root path."""
    for rel, src in files.items():
        p = tmp_path / rel
        p.parent.mkdir(parents=True, exist_ok=True)
        p.write_text(textwrap.dedent(src))
    return tmp_path


def lint(tmp_path, files):
    return hl.run_lint(repo(tmp_path, files))


def rules_of(report):
    return sorted(f.rule for f in report.unwaived)


# ---------------------------------------------------------------------------
# scrubber
# ---------------------------------------------------------------------------


class TestScrub:
    def test_offsets_are_preserved(self):
        src = 'let a = "x";\nlet b = 1; // trailing\n'
        code, _ = hl.scrub(src)
        assert len(code) == len(src)
        assert code.count("\n") == src.count("\n")

    def test_line_comment_collected_and_blanked(self):
        code, comments = hl.scrub("let a = 1; // HashMap here\nlet b = 2;\n")
        assert "HashMap" not in code
        assert comments == [(0, " HashMap here")]

    def test_nested_block_comments(self):
        code, _ = hl.scrub("/* outer /* inner */ still comment */ fn f() {}")
        assert "inner" not in code
        assert "still" not in code
        assert "fn f()" in code

    def test_escaped_quote_in_string(self):
        code, _ = hl.scrub(r'let s = "a\"HashMap\""; let t = 1;')
        assert "HashMap" not in code
        assert "let t = 1;" in code

    def test_raw_string_with_hashes(self):
        code, _ = hl.scrub('let s = r#"Instant "quoted" inside"#; let t = 1;')
        assert "Instant" not in code
        assert "let t = 1;" in code

    def test_char_literal_vs_lifetime(self):
        src = "fn f<'a>(x: &'a str) { let c = '\"'; let d = 1; }"
        code, _ = hl.scrub(src)
        # the lifetime survives, the char literal is blanked, and the
        # quote inside it did not open a string that eats the rest
        assert "'a str" in code
        assert "let d = 1;" in code

    def test_trigger_tokens_in_strings_do_not_flag(self, tmp_path):
        rep = lint(
            tmp_path,
            {
                "rust/src/crdt/x.rs": '''
                pub fn f() -> &'static str {
                    "HashMap and Instant and .lock().unwrap()"
                }
                '''
            },
        )
        assert rep.unwaived == []

    def test_match_brace(self):
        code = "fn f() { if x { y } else { z } } fn g() {}"
        end = hl.match_brace(code, code.index("{"))
        assert code[:end].endswith("{ z } }")


# ---------------------------------------------------------------------------
# D1 hash-on-wire
# ---------------------------------------------------------------------------


class TestHashOnWire:
    def test_flags_in_encode_path_module(self, tmp_path):
        rep = lint(
            tmp_path,
            {"rust/src/crdt/c.rs": "use std::collections::HashMap;\n"},
        )
        assert rules_of(rep) == ["hash-on-wire"]
        assert rep.unwaived[0].line == 1

    def test_silent_outside_classified_modules(self, tmp_path):
        rep = lint(
            tmp_path,
            {"rust/src/util/mod.rs": "use std::collections::HashMap;\n"},
        )
        assert rep.unwaived == []

    def test_classified_single_files(self, tmp_path):
        rep = lint(
            tmp_path,
            {
                "rust/src/codec.rs": "use std::collections::HashSet;\n",
                "rust/src/query/index.rs": "use std::collections::HashMap;\n",
            },
        )
        assert rules_of(rep) == ["hash-on-wire", "hash-on-wire"]

    def test_test_region_is_exempt(self, tmp_path):
        rep = lint(
            tmp_path,
            {
                "rust/src/crdt/c.rs": """
                pub fn f() {}
                #[cfg(test)]
                mod tests {
                    use std::collections::HashMap;
                }
                """
            },
        )
        assert rep.unwaived == []


# ---------------------------------------------------------------------------
# D2 wall-clock
# ---------------------------------------------------------------------------


class TestWallClock:
    def test_flags_instant_and_thread_rng(self, tmp_path):
        rep = lint(
            tmp_path,
            {
                "rust/src/engine/mod.rs": """
                use std::time::Instant;
                pub fn f() { let _r = thread_rng(); }
                """
            },
        )
        assert rules_of(rep) == ["wall-clock", "wall-clock"]

    def test_clock_and_benchkit_and_trace_exempt(self, tmp_path):
        rep = lint(
            tmp_path,
            {
                "rust/src/clock.rs": "use std::time::Instant;\n",
                "rust/src/benchkit.rs": "use std::time::Instant;\n",
                "rust/src/trace/mod.rs": "use std::time::SystemTime;\n",
            },
        )
        assert rep.unwaived == []

    def test_flags_even_in_tests(self, tmp_path):
        # wall time in a test is still a determinism leak (seeded replay)
        rep = lint(
            tmp_path,
            {
                "rust/src/net/mod.rs": """
                #[cfg(test)]
                mod tests {
                    use std::time::SystemTime;
                }
                """
            },
        )
        assert rules_of(rep) == ["wall-clock"]


# ---------------------------------------------------------------------------
# D3 discarded-merge
# ---------------------------------------------------------------------------


class TestDiscardedMerge:
    def test_flags_discarded_merge(self, tmp_path):
        rep = lint(
            tmp_path,
            {"rust/src/util/x.rs": "fn f() { let _ = a.merge(&b); }\n"},
        )
        assert rules_of(rep) == ["discarded-merge"]

    def test_flags_multiline_statement(self, tmp_path):
        rep = lint(
            tmp_path,
            {
                "rust/src/util/x.rs": """
                fn f() {
                    let _ = shared
                        .join_delta_into(&mut other);
                }
                """
            },
        )
        assert rules_of(rep) == ["discarded-merge"]

    def test_thread_join_is_not_a_lattice_join(self, tmp_path):
        rep = lint(
            tmp_path,
            {"rust/src/util/x.rs": "fn f() { let _ = handle.join(); }\n"},
        )
        assert rep.unwaived == []

    def test_bound_outcome_is_fine(self, tmp_path):
        rep = lint(
            tmp_path,
            {"rust/src/util/x.rs": "fn f() { let out = a.merge(&b); use_(out); }\n"},
        )
        assert rep.unwaived == []

    def test_take_delta_and_ingest_count(self, tmp_path):
        rep = lint(
            tmp_path,
            {
                "rust/src/util/x.rs": """
                fn f() {
                    let _ = s.take_delta();
                    let _ = q.ingest(&wm);
                }
                """
            },
        )
        assert rules_of(rep) == ["discarded-merge", "discarded-merge"]


# ---------------------------------------------------------------------------
# D4 float-crdt-field
# ---------------------------------------------------------------------------


class TestFloatCrdtField:
    def test_flags_float_field_in_crdt_module(self, tmp_path):
        rep = lint(
            tmp_path,
            {"rust/src/crdt/c.rs": "pub struct S { pub v: f64 }\n"},
        )
        assert rules_of(rep) == ["float-crdt-field"]

    def test_impl_crdt_elsewhere_is_tracked(self, tmp_path):
        # a Crdt impl outside crdt/ pulls its struct into scope for D4
        rep = lint(
            tmp_path,
            {
                "rust/src/query/agg.rs": """
                pub struct QAgg { pub v: f32 }
                impl Crdt for QAgg {}
                """
            },
        )
        assert rules_of(rep) == ["float-crdt-field"]

    def test_non_crdt_struct_outside_modules_ignored(self, tmp_path):
        rep = lint(
            tmp_path,
            {"rust/src/metrics/mod.rs": "pub struct Gauge { pub v: f64 }\n"},
        )
        assert rep.unwaived == []

    def test_tuple_struct_payload(self, tmp_path):
        rep = lint(
            tmp_path,
            {"rust/src/wcrdt/c.rs": "pub struct W(pub f64);\n"},
        )
        assert rules_of(rep) == ["float-crdt-field"]


# ---------------------------------------------------------------------------
# A1 zero-alloc
# ---------------------------------------------------------------------------


class TestZeroAlloc:
    def test_flags_allocations_in_annotated_fn(self, tmp_path):
        rep = lint(
            tmp_path,
            {
                "rust/src/util/x.rs": """
                // lint: zero-alloc
                fn hot() {
                    let v = vec![1, 2];
                    let s = format!("{v:?}");
                }
                fn cold() { let _v = Vec::<u8>::new(); }
                """
            },
        )
        # only the annotated fn is policed; `cold` allocates freely
        assert rules_of(rep) == ["zero-alloc", "zero-alloc"]
        labels = sorted(f.message.split("`")[1] for f in rep.unwaived)
        assert labels == ["format!", "vec!"]

    def test_clean_annotated_fn(self, tmp_path):
        rep = lint(
            tmp_path,
            {
                "rust/src/util/x.rs": """
                // lint: zero-alloc
                #[inline]
                fn hot(buf: &mut [u8]) { buf[0] = 1; }
                """
            },
        )
        assert rep.unwaived == []
        assert rep.problems == []

    def test_dangling_annotation_is_a_problem(self, tmp_path):
        rep = lint(
            tmp_path,
            {"rust/src/util/x.rs": "// lint: zero-alloc\nconst X: u8 = 1;\n"},
        )
        assert [p.kind for p in rep.problems] == ["dangling-zero-alloc"]


# ---------------------------------------------------------------------------
# S1 lock-unwrap
# ---------------------------------------------------------------------------


class TestLockUnwrap:
    def test_flags_in_data_plane_module(self, tmp_path):
        rep = lint(
            tmp_path,
            {"rust/src/engine/mod.rs": "fn f() { m.lock().unwrap(); }\n"},
        )
        assert rules_of(rep) == ["lock-unwrap"]

    def test_flags_formatted_multiline_chain(self, tmp_path):
        rep = lint(
            tmp_path,
            {
                "rust/src/net/mod.rs": """
                fn f() {
                    let g = m
                        .lock()
                        .unwrap();
                }
                """
            },
        )
        assert rules_of(rep) == ["lock-unwrap"]

    def test_test_region_exempt_and_util_unclassified(self, tmp_path):
        rep = lint(
            tmp_path,
            {
                "rust/src/util/mod.rs": "fn f() { m.lock().unwrap(); }\n",
                "rust/src/engine/node.rs": """
                #[cfg(test)]
                mod tests {
                    fn f() { m.lock().unwrap(); }
                }
                """,
            },
        )
        assert rep.unwaived == []


# ---------------------------------------------------------------------------
# waivers
# ---------------------------------------------------------------------------


class TestWaivers:
    def test_trailing_inline_waiver(self, tmp_path):
        rep = lint(
            tmp_path,
            {
                "rust/src/crdt/c.rs": (
                    "use std::collections::HashMap; "
                    "// lint:allow(hash-on-wire): sorted before emit\n"
                )
            },
        )
        assert rep.unwaived == []
        assert rep.stale_waivers == []
        assert [f.waived for f in rep.findings] == [True]

    def test_standalone_waiver_binds_next_code_line(self, tmp_path):
        rep = lint(
            tmp_path,
            {
                "rust/src/crdt/c.rs": """
                // lint:allow(discarded-merge): fold from bottom

                fn f() { let _ = a.merge(&b); }
                """
            },
        )
        assert rep.unwaived == []
        assert rep.stale_waivers == []

    def test_waiver_does_not_leak_to_other_lines(self, tmp_path):
        rep = lint(
            tmp_path,
            {
                "rust/src/crdt/c.rs": """
                fn f() {
                    // lint:allow(discarded-merge): only this one
                    let _ = a.merge(&b);
                    let _ = c.merge(&d);
                }
                """
            },
        )
        assert rules_of(rep) == ["discarded-merge"]

    def test_missing_reason_is_an_error(self, tmp_path):
        rep = lint(
            tmp_path,
            {
                "rust/src/crdt/c.rs": (
                    "use std::collections::HashMap; // lint:allow(hash-on-wire)\n"
                )
            },
        )
        assert [p.kind for p in rep.problems] == ["waiver-missing-reason"]
        # the un-suppressed finding is still reported
        assert rules_of(rep) == ["hash-on-wire"]

    def test_unknown_rule_and_unknown_directive(self, tmp_path):
        rep = lint(
            tmp_path,
            {
                "rust/src/crdt/c.rs": """
                // lint:allow(no-such-rule): reason
                // lint: frobnicate
                pub fn f() {}
                """
            },
        )
        assert sorted(p.kind for p in rep.problems) == [
            "unknown-directive",
            "unknown-rule",
        ]

    def test_doc_comments_cannot_carry_directives(self, tmp_path):
        # `//! lint:allow...` starts with `!`, not whitespace, so the
        # directive regex must not fire — doc text stays inert
        rep = lint(
            tmp_path,
            {
                "rust/src/crdt/c.rs": """
                //! lint:allow(hash-on-wire): doc text, not a directive
                use std::collections::HashMap;
                """
            },
        )
        assert rules_of(rep) == ["hash-on-wire"]

    def test_allow_tests_scope(self, tmp_path):
        rep = lint(
            tmp_path,
            {
                "rust/src/crdt/c.rs": """
                fn prod() { let _ = a.merge(&b); }
                // lint:allow-tests(discarded-merge): asserted on state
                #[cfg(test)]
                mod tests {
                    fn t() { let _ = a.merge(&b); }
                }
                """
            },
        )
        # the production discard is NOT covered by the tests-scope waiver
        assert rules_of(rep) == ["discarded-merge"]
        assert rep.stale_waivers == []

    def test_allow_file_scope(self, tmp_path):
        rep = lint(
            tmp_path,
            {
                "rust/tests/props.rs": """
                // lint:allow-file(discarded-merge): bytes are the oracle
                fn a() { let _ = x.merge(&y); }
                fn b() { let _ = y.merge(&x); }
                """
            },
        )
        assert rep.unwaived == []
        assert rep.stale_waivers == []

    def test_integration_tests_dir_is_all_test_scope(self, tmp_path):
        # rust/tests/* files are test code wholesale: allow-tests covers them
        rep = lint(
            tmp_path,
            {
                "rust/tests/props.rs": """
                // lint:allow-tests(discarded-merge): law checks
                fn a() { let _ = x.merge(&y); }
                """
            },
        )
        assert rep.unwaived == []

    def test_stale_waiver_detected(self, tmp_path):
        rep = lint(
            tmp_path,
            {
                "rust/src/crdt/c.rs": (
                    "pub fn f() {} // lint:allow(hash-on-wire): nothing here\n"
                )
            },
        )
        assert len(rep.stale_waivers) == 1
        assert rep.stale_waivers[0].rule == "hash-on-wire"


# ---------------------------------------------------------------------------
# CLI
# ---------------------------------------------------------------------------


class TestCli:
    def test_exit_codes(self, tmp_path, capsys):
        root = repo(
            tmp_path,
            {"rust/src/crdt/c.rs": "use std::collections::HashMap;\n"},
        )
        assert hl.main(["--root", str(root)]) == 1
        out = capsys.readouterr().out
        assert "[hash-on-wire]" in out
        assert "hint:" in out

    def test_clean_tree_exits_zero(self, tmp_path):
        root = repo(tmp_path, {"rust/src/crdt/c.rs": "pub fn f() {}\n"})
        assert hl.main(["--root", str(root)]) == 0

    def test_stale_waiver_fails_only_under_strict(self, tmp_path, capsys):
        root = repo(
            tmp_path,
            {
                "rust/src/crdt/c.rs": (
                    "pub fn f() {} // lint:allow(hash-on-wire): stale\n"
                )
            },
        )
        assert hl.main(["--root", str(root)]) == 0
        assert hl.main(["--root", str(root), "--strict"]) == 1
        assert "stale-waiver" in capsys.readouterr().out

    def test_json_output(self, tmp_path, capsys):
        root = repo(
            tmp_path,
            {"rust/src/crdt/c.rs": "use std::collections::HashMap;\n"},
        )
        assert hl.main(["--root", str(root), "--json"]) == 1
        doc = json.loads(capsys.readouterr().out)
        assert doc["files_scanned"] == 1
        assert [f["rule"] for f in doc["findings"]] == ["hash-on-wire"]

    def test_missing_tree_is_usage_error(self, tmp_path):
        assert hl.main(["--root", str(tmp_path)]) == 2

    def test_list_rules(self, capsys):
        assert hl.main(["--list-rules"]) == 0
        out = capsys.readouterr().out
        for rid in hl.RULES:
            assert rid in out


# ---------------------------------------------------------------------------
# the real tree
# ---------------------------------------------------------------------------


class TestRealTree:
    def test_repo_lints_clean_under_strict(self):
        rep = hl.run_lint(REPO_ROOT)
        assert rep.problems == [], [p.message for p in rep.problems]
        assert rep.unwaived == [], [
            f"{f.rel}:{f.line} [{f.rule}]" for f in rep.unwaived
        ]
        assert rep.stale_waivers == [], [
            f"{w.rel}:{w.line} [{w.rule}]" for w in rep.stale_waivers
        ]

    def test_scans_the_whole_tree_quickly(self):
        rep = hl.run_lint(REPO_ROOT)
        assert rep.files_scanned > 50
        assert rep.elapsed_ms < 2000

    def test_known_waivers_are_live(self):
        # spot-check the paper-motivated waivers stay attached to code
        rep = hl.run_lint(REPO_ROOT)
        used = {(w.rel, w.rule) for w in rep.waivers if w.used}
        assert ("rust/src/crdt/agg.rs", "float-crdt-field") in used
        assert ("rust/src/api/mod.rs", "hash-on-wire") in used
        assert ("rust/src/crdt/mod.rs", "discarded-merge") in used
