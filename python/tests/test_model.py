"""L2 model graphs: shapes, averaging, and AOT lowering round-trip."""

import jax
import jax.numpy as jnp
import numpy as np

from compile import model
from compile.aot import EXPORTS, to_hlo_text
from compile.kernels import ref
from compile.kernels.window_agg import BATCH, WINDOWS


def test_window_batch_shapes():
    vals = jnp.ones((BATCH,), jnp.float32)
    wids = jnp.zeros((BATCH,), jnp.int32)
    sums, counts, maxes, avgs = model.window_batch(vals, wids)
    for out in (sums, counts, maxes, avgs):
        assert out.shape == (WINDOWS,)
        assert out.dtype == jnp.float32


def test_window_batch_avg_guarded():
    vals = jnp.asarray(np.full(BATCH, 4.0, np.float32))
    wids = jnp.asarray(np.full(BATCH, -1, np.int32))  # no valid events
    _, counts, _, avgs = model.window_batch(vals, wids)
    assert float(counts.sum()) == 0.0
    assert float(jnp.abs(avgs).sum()) == 0.0  # no NaN/inf from 0/0


def test_window_batch_avg_matches_ref():
    rng = np.random.default_rng(11)
    vals = jnp.asarray(rng.normal(size=BATCH), jnp.float32)
    wids = jnp.asarray(rng.integers(0, WINDOWS, BATCH), jnp.int32)
    sums, counts, _, avgs = model.window_batch(vals, wids)
    np.testing.assert_allclose(
        np.asarray(avgs), np.asarray(ref.averages_ref(sums, counts)), rtol=1e-6
    )


def test_merge_batch_is_join():
    a, b = model.merge_batch_specs()
    x = jnp.zeros(a.shape, a.dtype) + 1.0
    y = jnp.zeros(b.shape, b.dtype) + 2.0
    (m,) = model.merge_batch(x, y)
    assert float(m.min()) == 2.0


def test_all_exports_lower_to_hlo_text():
    """Every artifact aot.py exports must lower and contain an ENTRY."""
    for name, (fn, specs) in EXPORTS.items():
        lowered = jax.jit(fn).lower(*specs())
        text = to_hlo_text(lowered)
        assert "ENTRY" in text, name
        assert len(text) > 200, name
