"""Pallas crdt_merge kernel vs oracle + lattice laws."""

import jax.numpy as jnp
import numpy as np
from hypcompat import given, settings, st

from compile.kernels import ref
from compile.kernels.crdt_merge import COLS, ROW_TILE, ROWS, crdt_merge


def rand(seed, rows=ROWS, cols=COLS):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.normal(scale=50.0, size=(rows, cols)), jnp.float32)


def test_matches_ref():
    a, b = rand(1), rand(2)
    np.testing.assert_array_equal(
        np.asarray(crdt_merge(a, b)), np.asarray(ref.crdt_merge_ref(a, b))
    )


def test_idempotent():
    a = rand(3)
    np.testing.assert_array_equal(np.asarray(crdt_merge(a, a)), np.asarray(a))


def test_commutative():
    a, b = rand(4), rand(5)
    np.testing.assert_array_equal(
        np.asarray(crdt_merge(a, b)), np.asarray(crdt_merge(b, a))
    )


def test_associative():
    a, b, c = rand(6), rand(7), rand(8)
    left = crdt_merge(crdt_merge(a, b), c)
    right = crdt_merge(a, crdt_merge(b, c))
    np.testing.assert_array_equal(np.asarray(left), np.asarray(right))


def test_monotone_wrt_inputs():
    a, b = rand(9), rand(10)
    m = np.asarray(crdt_merge(a, b))
    assert (m >= np.asarray(a)).all() and (m >= np.asarray(b)).all()


@given(
    seed=st.integers(0, 2**32 - 1),
    rows=st.sampled_from([ROW_TILE, 16, ROWS]),
    cols=st.sampled_from([8, COLS, 256]),
)
@settings(max_examples=30, deadline=None)
def test_hypothesis_shapes(seed, rows, cols):
    a = rand(seed, rows, cols)
    b = rand(seed + 1, rows, cols)
    np.testing.assert_array_equal(
        np.asarray(crdt_merge(a, b)), np.maximum(np.asarray(a), np.asarray(b))
    )
