"""Use hypothesis when installed; degrade to a deterministic grid otherwise.

CI installs hypothesis and gets real property testing. The bare
container (no network, no ``pip install``) instead runs each ``@given``
test over a small fixed sample grid drawn from the declared strategies —
the properties still execute, just without random exploration.

Only the strategy surface these tests use is mirrored:
``st.integers(min, max)`` and ``st.sampled_from(choices)``.
"""

try:
    import hypothesis  # noqa: F401
    import hypothesis.strategies as st
    from hypothesis import given, settings

    HAVE_HYPOTHESIS = True
except ImportError:  # container without hypothesis: deterministic fallback
    import itertools

    hypothesis = None
    HAVE_HYPOTHESIS = False

    _MAX_COMBOS = 8

    class _Strategy:
        def __init__(self, samples):
            # dedupe, keep declaration order
            self.samples = list(dict.fromkeys(samples))

    class _Strategies:
        @staticmethod
        def integers(min_value, max_value):
            return _Strategy([min_value, (min_value + max_value) // 2, max_value])

        @staticmethod
        def sampled_from(choices):
            return _Strategy(list(choices)[:3])

    st = _Strategies()

    def given(*arg_strategies, **kw_strategies):
        def deco(fn):
            # No functools.wraps: pytest must see a zero-arg signature,
            # not the original one (it would mistake params for fixtures).
            def wrapper():
                if arg_strategies:
                    combos = itertools.product(*(s.samples for s in arg_strategies))
                    for combo in itertools.islice(combos, _MAX_COMBOS):
                        fn(*combo)
                else:
                    keys = list(kw_strategies)
                    combos = itertools.product(*(kw_strategies[k].samples for k in keys))
                    for combo in itertools.islice(combos, _MAX_COMBOS):
                        fn(**dict(zip(keys, combo)))

            wrapper.__name__ = fn.__name__
            wrapper.__doc__ = fn.__doc__
            return wrapper

        return deco

    def settings(*_args, **_kwargs):
        def deco(fn):
            return fn

        return deco
