"""Pallas window_aggregate kernel vs pure-jnp oracle (the core L1 signal)."""

import jax.numpy as jnp
import numpy as np
import pytest
from hypcompat import HAVE_HYPOTHESIS, given, hypothesis, settings, st

from compile.kernels import ref
from compile.kernels.window_agg import (
    BATCH,
    NEG_INF,
    WINDOW_TILE,
    WINDOWS,
    window_aggregate,
)

if HAVE_HYPOTHESIS:
    hypothesis.settings.register_profile("ci", deadline=None, max_examples=50)
    hypothesis.settings.load_profile("ci")


def run_both(values, window_ids, windows=WINDOWS):
    values = jnp.asarray(values, jnp.float32)
    window_ids = jnp.asarray(window_ids, jnp.int32)
    got = window_aggregate(values, window_ids, windows=windows)
    want = ref.window_aggregate_ref(values, window_ids, windows=windows)
    return got, want


def assert_matches(got, want):
    for g, w, name in zip(got, want, ["sums", "counts", "maxes"]):
        np.testing.assert_allclose(
            np.asarray(g), np.asarray(w), rtol=1e-6, atol=1e-5, err_msg=name
        )


def test_single_window():
    got, want = run_both(np.ones(BATCH), np.zeros(BATCH))
    assert_matches(got, want)
    assert float(got[0][0]) == BATCH  # all values land in window 0
    assert float(got[1][0]) == BATCH
    assert float(got[2][0]) == 1.0


def test_round_robin_windows():
    wids = np.arange(BATCH) % WINDOWS
    vals = np.arange(BATCH, dtype=np.float32)
    got, want = run_both(vals, wids)
    assert_matches(got, want)


def test_padding_is_ignored():
    vals = np.full(BATCH, 7.0, np.float32)
    wids = np.full(BATCH, -1, np.int32)  # everything is padding
    wids[:3] = 5
    got, _ = run_both(vals, wids)
    sums, counts, maxes = got
    assert float(sums[5]) == 21.0
    assert float(counts[5]) == 3.0
    assert float(counts.sum()) == 3.0


def test_empty_window_max_is_neg_inf():
    vals = np.ones(BATCH, np.float32)
    wids = np.zeros(BATCH, np.int32)
    got, _ = run_both(vals, wids)
    assert float(got[2][1]) == NEG_INF


def test_negative_values():
    rng = np.random.default_rng(0)
    vals = rng.normal(size=BATCH).astype(np.float32) - 10.0
    wids = rng.integers(0, WINDOWS, BATCH).astype(np.int32)
    got, want = run_both(vals, wids)
    assert_matches(got, want)


def test_out_of_range_ids_are_dropped():
    vals = np.ones(BATCH, np.float32)
    wids = np.full(BATCH, WINDOWS + 3, np.int32)  # beyond the window range
    got, _ = run_both(vals, wids)
    assert float(got[1].sum()) == 0.0


@given(
    seed=st.integers(0, 2**32 - 1),
    windows=st.sampled_from([WINDOW_TILE, 16, WINDOWS, 64]),
    batch=st.sampled_from([8, 64, 256, BATCH]),
)
@settings(max_examples=40, deadline=None)
def test_hypothesis_matches_ref(seed, windows, batch):
    rng = np.random.default_rng(seed)
    vals = rng.normal(scale=100.0, size=batch).astype(np.float32)
    # include padding (-1) and out-of-range ids in the sweep
    wids = rng.integers(-1, windows + 1, batch).astype(np.int32)
    got, want = run_both(vals, wids, windows=windows)
    assert_matches(got, want)


@given(st.integers(0, 2**32 - 1))
@settings(max_examples=20, deadline=None)
def test_sum_of_counts_equals_valid_events(seed):
    rng = np.random.default_rng(seed)
    vals = rng.normal(size=BATCH).astype(np.float32)
    wids = rng.integers(-1, WINDOWS, BATCH).astype(np.int32)
    got, _ = run_both(vals, wids)
    valid = int((wids >= 0).sum())
    assert int(np.asarray(got[1]).sum()) == valid


def test_batch_must_match_grid_assert():
    with pytest.raises(AssertionError):
        window_aggregate(
            jnp.ones((8,), jnp.float32), jnp.zeros((8,), jnp.int32), windows=12
        )  # 12 is not a multiple of WINDOW_TILE
