"""Tests for the BENCH_*.json schema validator used by CI bench-smoke."""

import copy
import importlib.util
import pathlib

_spec = importlib.util.spec_from_file_location(
    "validate_bench",
    pathlib.Path(__file__).resolve().parents[1] / "tools" / "validate_bench.py",
)
validate_bench = importlib.util.module_from_spec(_spec)
_spec.loader.exec_module(validate_bench)
validate = validate_bench.validate


def scenario(**overrides):
    base = {
        "name": "throughput_max_q7_holon",
        "system": "holon",
        "workload": "q7",
        "events_per_sec_peak": 120000.0,
        "events_per_sec_mean": 80000.0,
        "events_produced": 800000,
        "events_consumed": 790000,
        "outputs": 120,
        "latency_mean_ms": 350.5,
        "latency_p50_ms": 300,
        "latency_p99_ms": 900,
        "gossip_msgs": 4200,
        "gossip_bytes_encoded": 262144,
        "gossip_bytes_wire": 1048576,
        "gossip_bytes_per_sec": 52428.8,
        "payload_clones": 0,
        "records_read": 912000,
        "payload_clones_per_event": 0.0,
        "dedup_duplicates": 3,
        "seq_gaps": 0,
        "stalled": False,
    }
    base.update(overrides)
    return base


def doc(**overrides):
    d = {
        "schema": "holon-bench/v1",
        "pr": "PR3",
        "quick": True,
        "scenarios": [scenario()],
    }
    d.update(overrides)
    return d


def test_valid_document_passes():
    assert validate(doc()) == []


def test_wrong_schema_tag_fails():
    assert any("schema" in e for e in validate(doc(schema="nope/v0")))


def test_missing_field_fails():
    d = doc()
    del d["scenarios"][0]["payload_clones"]
    assert any("payload_clones" in e for e in validate(d))


def test_unknown_field_fails():
    d = doc()
    d["scenarios"][0]["surprise"] = 1
    assert any("unknown fields" in e for e in validate(d))


def test_wrong_type_fails():
    d = doc()
    d["scenarios"][0]["outputs"] = "many"
    assert any("outputs" in e for e in validate(d))


def test_bool_is_not_an_int():
    d = doc()
    d["scenarios"][0]["seq_gaps"] = True
    assert any("seq_gaps" in e for e in validate(d))


def test_empty_scenarios_fail():
    assert any("non-empty" in e for e in validate(doc(scenarios=[])))


def test_duplicate_scenario_names_fail():
    d = doc()
    d["scenarios"].append(copy.deepcopy(d["scenarios"][0]))
    assert any("duplicate" in e for e in validate(d))


def test_negative_counter_fails():
    d = doc()
    d["scenarios"][0]["gossip_msgs"] = -1
    assert any("negative" in e for e in validate(d))


def test_unknown_system_fails():
    d = doc()
    d["scenarios"][0]["system"] = "spark"
    assert any("system" in e for e in validate(d))
