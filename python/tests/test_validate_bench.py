"""Tests for the BENCH_*.json schema validator used by CI bench-smoke."""

import copy
import importlib.util
import pathlib

_spec = importlib.util.spec_from_file_location(
    "validate_bench",
    pathlib.Path(__file__).resolve().parents[1] / "tools" / "validate_bench.py",
)
validate_bench = importlib.util.module_from_spec(_spec)
_spec.loader.exec_module(validate_bench)
validate = validate_bench.validate
check_baseline = validate_bench.check_baseline


def scenario(**overrides):
    base = {
        "name": "throughput_max_q7_holon",
        "system": "holon",
        "workload": "q7",
        "events_per_sec_peak": 120000.0,
        "events_per_sec_mean": 80000.0,
        "events_produced": 800000,
        "events_consumed": 790000,
        "outputs": 120,
        "latency_mean_ms": 350.5,
        "latency_p50_ms": 300,
        "latency_p99_ms": 900,
        "gossip_msgs": 4200,
        "gossip_bytes_encoded": 262144,
        "gossip_bytes_wire": 1048576,
        "gossip_bytes_per_sec": 52428.8,
        "payload_clones": 0,
        "records_read": 912000,
        "payload_clones_per_event": 0.0,
        "dedup_duplicates": 3,
        "seq_gaps": 0,
        "merge_changed": 4100,
        "merge_noop": 100,
        "redundant_gossip_bytes": 2048,
        "gossip_skipped": 0,
        "shard_count": 0,
        "shard_gossip_bytes": [],
        "shard_parallel_merges": 0,
        "shard_serial_merges": 0,
        "queries_served": 0,
        "query_index_hits": 0,
        "query_index_misses": 0,
        "query_scan_rows_avoided": 0,
        "changefeed_lag": 0,
        "outbound_queue_depth_max": 0,
        "credits_stalled_rounds": 0,
        "inbox_depth_max": 0,
        "output_arena_bytes": 0,
        "output_frames": 0,
        "window_ring_spills": 0,
        "stage_latency_ingest_p50_ms": 40,
        "stage_latency_ingest_p99_ms": 120,
        "stage_latency_fire_p50_ms": 80,
        "stage_latency_fire_p99_ms": 400,
        "stage_latency_converge_p50_ms": 300,
        "stage_latency_converge_p99_ms": 900,
        "stage_latency_emit_p50_ms": 310,
        "stage_latency_emit_p99_ms": 950,
        "trace_dropped_events": 0,
        "stalled": False,
    }
    base.update(overrides)
    return base


def doc(**overrides):
    d = {
        "schema": "holon-bench/v1",
        "pr": "PR3",
        "quick": True,
        "scenarios": [scenario()],
    }
    d.update(overrides)
    return d


def test_valid_document_passes():
    assert validate(doc()) == []


def test_wrong_schema_tag_fails():
    assert any("schema" in e for e in validate(doc(schema="nope/v0")))


def test_missing_field_fails():
    d = doc()
    del d["scenarios"][0]["payload_clones"]
    assert any("payload_clones" in e for e in validate(d))


def test_unknown_field_fails():
    d = doc()
    d["scenarios"][0]["surprise"] = 1
    assert any("unknown fields" in e for e in validate(d))


def test_wrong_type_fails():
    d = doc()
    d["scenarios"][0]["outputs"] = "many"
    assert any("outputs" in e for e in validate(d))


def test_bool_is_not_an_int():
    d = doc()
    d["scenarios"][0]["seq_gaps"] = True
    assert any("seq_gaps" in e for e in validate(d))


def test_empty_scenarios_fail():
    assert any("non-empty" in e for e in validate(doc(scenarios=[])))


def test_duplicate_scenario_names_fail():
    d = doc()
    d["scenarios"].append(copy.deepcopy(d["scenarios"][0]))
    assert any("duplicate" in e for e in validate(d))


def test_negative_counter_fails():
    d = doc()
    d["scenarios"][0]["gossip_msgs"] = -1
    assert any("negative" in e for e in validate(d))


def test_unknown_system_fails():
    d = doc()
    d["scenarios"][0]["system"] = "spark"
    assert any("system" in e for e in validate(d))


def test_sharded_scenario_passes():
    d = doc(
        scenarios=[
            scenario(
                name="q4_keyed_sharded",
                workload="q4",
                shard_count=4,
                shard_gossip_bytes=[1024, 0, 2048, 512],
                shard_parallel_merges=7,
                shard_serial_merges=100,
            )
        ]
    )
    assert validate(d) == []


def test_shard_bytes_must_be_nonneg_ints():
    d = doc()
    d["scenarios"][0]["shard_count"] = 2
    d["scenarios"][0]["shard_gossip_bytes"] = [10, -1]
    assert any("shard_gossip_bytes[1]" in e for e in validate(d))
    d["scenarios"][0]["shard_gossip_bytes"] = [10, "x"]
    assert any("shard_gossip_bytes[1]" in e for e in validate(d))
    d["scenarios"][0]["shard_gossip_bytes"] = "not a list"
    assert any("shard_gossip_bytes" in e for e in validate(d))


def test_merge_outcome_fields_are_required():
    # the trait-v3 counters are part of the schema: a report missing any
    # of them (an old binary) must fail validation
    for field in ("merge_changed", "merge_noop", "redundant_gossip_bytes", "gossip_skipped"):
        d = doc()
        del d["scenarios"][0][field]
        assert any(field in e for e in validate(d)), field


def test_merge_outcome_fields_are_typed_counters():
    d = doc()
    d["scenarios"][0]["redundant_gossip_bytes"] = -5
    assert any("redundant_gossip_bytes" in e for e in validate(d))
    d = doc()
    d["scenarios"][0]["merge_noop"] = 1.5
    assert any("merge_noop" in e for e in validate(d))
    d = doc()
    d["scenarios"][0]["gossip_skipped"] = True
    assert any("gossip_skipped" in e for e in validate(d))


def test_read_path_fields_are_required():
    # PR6 read-path counters are part of the schema: a report missing
    # any of them (an old binary) must fail validation
    for field in (
        "queries_served",
        "query_index_hits",
        "query_index_misses",
        "query_scan_rows_avoided",
        "changefeed_lag",
    ):
        d = doc()
        del d["scenarios"][0][field]
        assert any(field in e for e in validate(d)), field


def test_read_path_fields_are_typed_counters():
    d = doc()
    d["scenarios"][0]["queries_served"] = -2
    assert any("queries_served" in e for e in validate(d))
    d = doc()
    d["scenarios"][0]["query_index_hits"] = 0.5
    assert any("query_index_hits" in e for e in validate(d))
    d = doc()
    d["scenarios"][0]["changefeed_lag"] = True
    assert any("changefeed_lag" in e for e in validate(d))


def test_read_heavy_scenario_passes():
    d = doc(
        scenarios=[
            scenario(
                name="mixed_rw_q4_point",
                workload="q4",
                shard_count=8,
                shard_gossip_bytes=[1, 2, 3, 4, 5, 6, 7, 8],
                queries_served=1200,
                query_index_hits=700,
                query_index_misses=500,
                query_scan_rows_avoided=34000,
                changefeed_lag=3,
            )
        ]
    )
    assert validate(d) == []


def test_backpressure_fields_are_required():
    # PR7 async-data-plane counters are part of the schema: a report
    # missing any of them (an old binary) must fail validation
    for field in (
        "outbound_queue_depth_max",
        "credits_stalled_rounds",
        "inbox_depth_max",
    ):
        d = doc()
        del d["scenarios"][0][field]
        assert any(field in e for e in validate(d)), field


def test_backpressure_fields_are_typed_counters():
    d = doc()
    d["scenarios"][0]["outbound_queue_depth_max"] = -1
    assert any("outbound_queue_depth_max" in e for e in validate(d))
    d = doc()
    d["scenarios"][0]["credits_stalled_rounds"] = 2.5
    assert any("credits_stalled_rounds" in e for e in validate(d))
    d = doc()
    d["scenarios"][0]["inbox_depth_max"] = True
    assert any("inbox_depth_max" in e for e in validate(d))


def test_arena_fields_are_required():
    # PR8 arena/ring memory-layout counters are part of the schema: a
    # report missing any of them (an old binary) must fail validation
    for field in ("output_arena_bytes", "output_frames", "window_ring_spills"):
        d = doc()
        del d["scenarios"][0][field]
        assert any(field in e for e in validate(d)), field


def test_arena_fields_are_typed_counters():
    d = doc()
    d["scenarios"][0]["output_arena_bytes"] = -1
    assert any("output_arena_bytes" in e for e in validate(d))
    d = doc()
    d["scenarios"][0]["output_frames"] = 0.5
    assert any("output_frames" in e for e in validate(d))
    d = doc()
    d["scenarios"][0]["window_ring_spills"] = True
    assert any("window_ring_spills" in e for e in validate(d))


def test_arena_heavy_scenario_passes():
    d = doc(
        scenarios=[
            scenario(
                name="throughput_max_q7_arena",
                output_arena_bytes=52428800,
                output_frames=120000,
                window_ring_spills=0,
            )
        ]
    )
    assert validate(d) == []


def test_overloaded_scenario_passes():
    d = doc(
        scenarios=[
            scenario(
                name="overload_q7_slow_receiver",
                outbound_queue_depth_max=64,
                credits_stalled_rounds=12,
                inbox_depth_max=32,
            )
        ]
    )
    assert validate(d) == []


def test_stage_latency_fields_are_required():
    # PR9 flight-recorder stage-latency fields are part of the schema: a
    # report missing any of them (an old binary) must fail validation
    for stage in ("ingest", "fire", "converge", "emit"):
        for pct in ("p50", "p99"):
            field = f"stage_latency_{stage}_{pct}_ms"
            d = doc()
            del d["scenarios"][0][field]
            assert any(field in e for e in validate(d)), field
    d = doc()
    del d["scenarios"][0]["trace_dropped_events"]
    assert any("trace_dropped_events" in e for e in validate(d))


def test_stage_latency_fields_are_typed_counters():
    d = doc()
    d["scenarios"][0]["stage_latency_fire_p99_ms"] = -1
    assert any("stage_latency_fire_p99_ms" in e for e in validate(d))
    d = doc()
    d["scenarios"][0]["stage_latency_emit_p50_ms"] = 1.5
    assert any("stage_latency_emit_p50_ms" in e for e in validate(d))
    d = doc()
    d["scenarios"][0]["trace_dropped_events"] = True
    assert any("trace_dropped_events" in e for e in validate(d))


def test_stage_p50_above_p99_fails():
    # percentiles off one histogram are monotone; an inversion means the
    # emitter wired the fields to the wrong histograms
    for stage in ("ingest", "fire", "converge", "emit"):
        d = doc()
        d["scenarios"][0][f"stage_latency_{stage}_p50_ms"] = 500
        d["scenarios"][0][f"stage_latency_{stage}_p99_ms"] = 100
        assert any("exceeds" in e for e in validate(d)), stage
    # the end-to-end latency pair is gated by the same rule
    d = doc()
    d["scenarios"][0]["latency_p50_ms"] = 901
    assert any("exceeds" in e for e in validate(d))


def test_stage_p50_equal_to_p99_passes():
    d = doc()
    for stage in ("ingest", "fire", "converge", "emit"):
        d["scenarios"][0][f"stage_latency_{stage}_p50_ms"] = 77
        d["scenarios"][0][f"stage_latency_{stage}_p99_ms"] = 77
    assert validate(d) == []


def test_shard_count_must_match_array_length():
    d = doc()
    d["scenarios"][0]["shard_count"] = 3
    d["scenarios"][0]["shard_gossip_bytes"] = [1, 2]
    assert any("shard_count" in e for e in validate(d))


# ---- the --baseline regression gate -----------------------------------


def test_baseline_within_budget_passes():
    base = doc(scenarios=[scenario(events_per_sec_peak=100000.0)])
    now = doc(scenarios=[scenario(events_per_sec_peak=95000.0)])
    assert check_baseline(now, base, 10.0) == []


def test_baseline_regression_fails():
    base = doc(scenarios=[scenario(events_per_sec_peak=100000.0)])
    now = doc(scenarios=[scenario(events_per_sec_peak=85000.0)])
    errs = check_baseline(now, base, 10.0)
    assert any("regressed" in e for e in errs)


def test_baseline_improvement_passes():
    base = doc(scenarios=[scenario(events_per_sec_peak=100000.0)])
    now = doc(scenarios=[scenario(events_per_sec_peak=200000.0)])
    assert check_baseline(now, base, 10.0) == []


def test_baseline_ignores_unshared_scenarios():
    base = doc(
        scenarios=[
            scenario(events_per_sec_peak=100000.0),
            scenario(name="retired_scenario", events_per_sec_peak=999999.0),
        ]
    )
    now = doc(
        scenarios=[
            scenario(events_per_sec_peak=99000.0),
            scenario(name="q4_keyed_sharded", events_per_sec_peak=1.0),
        ]
    )
    assert check_baseline(now, base, 10.0) == []


def test_baseline_with_no_shared_names_fails():
    base = doc(scenarios=[scenario(name="old_only")])
    now = doc(scenarios=[scenario(name="new_only")])
    errs = check_baseline(now, base, 10.0)
    assert any("no scenario names shared" in e for e in errs)


def test_baseline_custom_budget():
    base = doc(scenarios=[scenario(events_per_sec_peak=100000.0)])
    now = doc(scenarios=[scenario(events_per_sec_peak=75000.0)])
    assert check_baseline(now, base, 30.0) == []
    assert check_baseline(now, base, 10.0) != []


def test_baseline_nonnumeric_peak_fails_loudly():
    # a hand-edited/corrupted baseline must not neutralize the gate
    base = doc(scenarios=[scenario(events_per_sec_peak="100000")])
    now = doc(scenarios=[scenario(events_per_sec_peak=1.0)])
    errs = check_baseline(now, base, 10.0)
    assert any("non-numeric" in e for e in errs)


def test_baseline_cli_rejects_malformed_baseline(tmp_path):
    import json
    import subprocess
    import sys as _sys

    tool = pathlib.Path(__file__).resolve().parents[1] / "tools" / "validate_bench.py"
    good = tmp_path / "report.json"
    good.write_text(json.dumps(doc()))

    def run_against(baseline_doc):
        bad_base = tmp_path / "base.json"
        bad_base.write_text(json.dumps(baseline_doc))
        return subprocess.run(
            [_sys.executable, str(tool), str(good), "--baseline", str(bad_base)],
            capture_output=True,
            text=True,
        )

    # structurally broken baseline: shape check fails the run
    proc = run_against({"schema": "holon-bench/v1", "scenarios": []})
    assert proc.returncode == 1
    assert "baseline" in proc.stderr

    # baseline with a missing peak: the per-scenario loud failure fires
    broken = doc()
    del broken["scenarios"][0]["events_per_sec_peak"]
    proc = run_against(broken)
    assert proc.returncode == 1
    assert "non-numeric" in proc.stderr

    # a baseline from an older schema (extra/missing unrelated fields)
    # still gates fine — only the fields the gate reads matter
    old_schema = doc()
    del old_schema["scenarios"][0]["shard_count"]
    old_schema["scenarios"][0]["a_retired_field"] = 1
    proc = run_against(old_schema)
    assert proc.returncode == 0, proc.stderr
