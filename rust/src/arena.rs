//! Per-batch output arena: the write-side half of the zero-copy data
//! plane.
//!
//! Before this module, every output record was an owned `Vec<u8>`
//! (header + payload copy) allocated in the emit stage, wrapped in a
//! fresh `Arc` by `Topic::append_batch` — two allocations per record on
//! the hottest path the engine has. The arena replaces that with a
//! *framed append-only buffer*: emit stages write each record directly
//! into one shared backing buffer through the ordinary [`Writer`]
//! surface (so every existing `Encode` impl works unchanged), and the
//! batch drain ships the whole buffer as **one** `Arc<Vec<u8>>` whose
//! frames the [`crate::log::Topic`] records reference by `(offset, len)`
//! — the read-side `read_slice`/`payload_clones` discipline of the data
//! plane, extended to the write side.
//!
//! Frame wire layout (byte-identical to the old per-record
//! `encode_output`): `u64 seq | u64 ref_ts | u32 len | inner bytes`.
//! The sequence number is not known at emit time (the engine assigns it
//! at drain, after dedup bookkeeping), so [`OutputArena::frame`] writes
//! a placeholder and [`OutputArena::finish`] backpatches it.
//!
//! Allocation budget per batch: one backing-buffer allocation (the
//! buffer is handed off to the log as the shared `Arc` backing, so the
//! next batch starts from an empty, pre-reserved buffer) plus the `Arc`
//! cell itself. [`OutputArena::batch_allocs`] counts backing growth so
//! `micro_hotpath` can assert the ≤1-allocation contract, and the
//! lifetime counters feed `ClusterMetrics::{output_arena_bytes,
//! output_frames}`.

use std::sync::Arc;

use crate::codec::Writer;
use crate::util::SimTime;

/// Bytes of frame header preceding the inner payload:
/// `u64 seq + u64 ref_ts + u32 inner-len`.
pub const FRAME_HEADER_BYTES: usize = 8 + 8 + 4;

/// One output record within the batch backing buffer.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Frame {
    /// Reference timestamp of the output (window end / input insert_ts).
    pub ref_ts: SimTime,
    /// Byte offset of the frame (its seq header) in the backing buffer.
    pub start: u32,
    /// Total frame length including the header.
    pub len: u32,
}

impl Frame {
    /// `(start, len)` of the inner payload, header stripped.
    pub fn inner_range(&self) -> (usize, usize) {
        (
            self.start as usize + FRAME_HEADER_BYTES,
            self.len as usize - FRAME_HEADER_BYTES,
        )
    }
}

/// A finished batch: the shared backing plus its frame table. Hand the
/// backing to [`crate::log::Topic::append_frames`]; every record of the
/// batch then shares it without a single payload copy.
#[derive(Debug)]
pub struct FinishedBatch {
    pub backing: Arc<Vec<u8>>,
    pub frames: Vec<Frame>,
}

/// Framed append-only output buffer, reused across batches.
#[derive(Debug, Default)]
pub struct OutputArena {
    w: Writer,
    frames: Vec<Frame>,
    /// High-water byte mark over past batches — the pre-reserve hint
    /// that keeps steady-state emit loops growth-free.
    high_water: usize,
    /// Backing-buffer growth events in the current batch.
    grew: u64,
    /// Lifetime bytes shipped through finished batches.
    total_bytes: u64,
    /// Lifetime frames shipped through finished batches.
    total_frames: u64,
}

impl OutputArena {
    pub fn new() -> Self {
        Self::default()
    }

    /// Number of frames emitted into the current batch.
    pub fn len(&self) -> usize {
        self.frames.len()
    }

    pub fn is_empty(&self) -> bool {
        self.frames.is_empty()
    }

    /// Backing-buffer growth events in the current batch — the
    /// "≤1 arena allocation per batch" acceptance counter.
    pub fn batch_allocs(&self) -> u64 {
        self.grew
    }

    /// Lifetime `(bytes, frames)` shipped through [`finish`](Self::finish)
    /// — drained into `ClusterMetrics::{output_arena_bytes, output_frames}`.
    pub fn take_totals(&mut self) -> (u64, u64) {
        (
            std::mem::take(&mut self.total_bytes),
            std::mem::take(&mut self.total_frames),
        )
    }

    /// Pre-reserve the batch backing to the high-water mark of past
    /// batches: the single up-front allocation that keeps the per-record
    /// emit path growth-free in steady state.
    pub fn begin_batch(&mut self) {
        self.grew = 0;
        if self.w.capacity() < self.high_water {
            self.w.reserve(self.high_water - self.w.len());
            self.grew += 1;
        }
    }

    /// Write one output frame through `f`. The closure receives the
    /// backing [`Writer`] positioned inside the frame's inner-payload
    /// slot (after the seq/ref_ts/len header, which this method writes
    /// and backpatches). Returning `false` cancels the frame: the buffer
    /// is rolled back and nothing is recorded.
    // lint: zero-alloc
    pub fn frame(&mut self, ref_ts: SimTime, f: impl FnOnce(&mut Writer) -> bool) -> bool {
        let start = self.w.len();
        let cap = self.w.capacity();
        self.w.put_u64(0); // seq placeholder, patched in finish()
        self.w.put_u64(ref_ts);
        let inner_slot = self.w.len();
        self.w.put_u32(0); // inner length, backpatched below
        if !f(&mut self.w) {
            self.w.truncate(start);
            return false;
        }
        let inner_len = (self.w.len() - inner_slot - 4) as u32;
        self.w.patch_u32(inner_slot, inner_len);
        if self.w.capacity() != cap {
            self.grew += 1;
        }
        self.frames.push(Frame {
            ref_ts,
            start: start as u32,
            len: (self.w.len() - start) as u32,
        });
        true
    }

    /// Finish the batch: backpatch each frame's sequence number
    /// (`seq0 + frame index`), hand the backing off as one shared `Arc`,
    /// and reset for the next batch (frame table capacity retained,
    /// backing re-reserved lazily by [`begin_batch`](Self::begin_batch)).
    /// Returns `None` when nothing was emitted.
    pub fn finish(&mut self, seq0: u64) -> Option<FinishedBatch> {
        if self.frames.is_empty() {
            return None;
        }
        for (i, fr) in self.frames.iter().enumerate() {
            self.w.patch_u64(fr.start as usize, seq0 + i as u64);
        }
        self.high_water = self.high_water.max(self.w.len());
        self.total_bytes += self.w.len() as u64;
        self.total_frames += self.frames.len() as u64;
        let backing = Arc::new(self.w.take_bytes());
        let frames = std::mem::take(&mut self.frames);
        Some(FinishedBatch { backing, frames })
    }

    /// Return a shipped batch's frame table for reuse. The backing is
    /// owned by the log records now and stays out; reclaiming the frame
    /// table is what keeps steady-state batches at ≤1 allocation (the
    /// backing pre-reserve) instead of re-growing a fresh `Vec<Frame>`
    /// every batch.
    pub fn recycle(&mut self, batch: FinishedBatch) {
        let mut frames = batch.frames;
        frames.clear();
        if frames.capacity() > self.frames.capacity() {
            self.frames = frames;
        }
    }

    /// Materialize the current batch as owned `(ref_ts, inner payload)`
    /// outputs and reset — the test/oracle surface (unit tests assert on
    /// payload bytes; the engine never calls this).
    pub fn take_outputs(&mut self) -> Vec<crate::api::Output> {
        let outs = self
            .frames
            .iter()
            .map(|fr| {
                let (start, len) = fr.inner_range();
                crate::api::Output::new(fr.ref_ts, self.w.as_slice()[start..start + len].to_vec())
            })
            .collect();
        self.w.clear();
        self.frames.clear();
        outs
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::node::{decode_output, encode_output};

    #[test]
    fn frames_are_byte_identical_to_encode_output() {
        let mut a = OutputArena::new();
        a.begin_batch();
        assert!(a.frame(500, |w| {
            w.put_u64(7);
            w.put_f64(3.5);
            true
        }));
        assert!(a.frame(1000, |w| {
            w.put_bytes(b"xyz");
            true
        }));
        let b = a.finish(42).unwrap();
        // old path: encode each record separately
        let mut inner0 = Writer::new();
        inner0.put_u64(7);
        inner0.put_f64(3.5);
        let mut inner1 = Writer::new();
        inner1.put_bytes(b"xyz");
        let old0 = encode_output(42, 500, inner0.as_slice());
        let old1 = encode_output(43, 1000, inner1.as_slice());
        let f0 = b.frames[0];
        let f1 = b.frames[1];
        assert_eq!(
            &b.backing[f0.start as usize..(f0.start + f0.len) as usize],
            &old0[..]
        );
        assert_eq!(
            &b.backing[f1.start as usize..(f1.start + f1.len) as usize],
            &old1[..]
        );
        // and the sink-side decoder reads them back
        let (seq, ts, inner) =
            decode_output(&b.backing[f1.start as usize..(f1.start + f1.len) as usize]).unwrap();
        assert_eq!((seq, ts), (43, 1000));
        assert_eq!(inner, &old1[20..]);
    }

    #[test]
    fn cancelled_frame_leaves_no_trace() {
        let mut a = OutputArena::new();
        a.begin_batch();
        assert!(!a.frame(5, |w| {
            w.put_u64(99); // partially written, then withdrawn
            false
        }));
        assert!(a.is_empty());
        assert!(a.finish(0).is_none());
        assert!(a.frame(5, |w| {
            w.put_u8(1);
            true
        }));
        let b = a.finish(0).unwrap();
        assert_eq!(b.frames.len(), 1);
        // the cancelled bytes must not have shifted the surviving frame
        assert_eq!(b.frames[0].start, 0);
        let (seq, ts, inner) = decode_output(&b.backing).unwrap();
        assert_eq!((seq, ts, inner), (0, 5, &[1u8][..]));
    }

    #[test]
    fn steady_state_batches_grow_at_most_once() {
        let mut a = OutputArena::new();
        // warmup establishes the high-water mark
        a.begin_batch();
        for i in 0..256 {
            a.frame(i, |w| {
                w.put_u64(i);
                true
            });
        }
        a.finish(0).unwrap();
        // steady state: one pre-reserve, zero growth during emits
        for round in 0..3 {
            a.begin_batch();
            let after_reserve = a.batch_allocs();
            assert!(after_reserve <= 1, "round {round}: {after_reserve}");
            for i in 0..256 {
                a.frame(i, |w| {
                    w.put_u64(i);
                    true
                });
            }
            assert_eq!(
                a.batch_allocs(),
                after_reserve,
                "round {round}: emit loop grew the backing"
            );
            a.finish(0).unwrap();
        }
    }

    #[test]
    fn totals_accumulate_and_drain() {
        let mut a = OutputArena::new();
        a.begin_batch();
        a.frame(1, |w| {
            w.put_u8(1);
            true
        });
        let b = a.finish(0).unwrap();
        let (bytes, frames) = a.take_totals();
        assert_eq!(bytes, b.backing.len() as u64);
        assert_eq!(frames, 1);
        assert_eq!(a.take_totals(), (0, 0));
    }
}
