//! Checkpoint storage — the shared, always-available state store.
//!
//! Algorithm 2 periodically `storage.put(p, partitions[p])`s partition
//! state and recovers with `storage.get(partitionId)`. The paper notes
//! that "the partition state itself forms a CRDT": the lattice merge of
//! two checkpoints of the same partition keeps the one with the largest
//! `nxt_idx` (input offset). We enforce that rule *inside* the store so
//! a slow node can never regress a checkpoint written by a faster one —
//! puts are monotone.
//!
//! Both an in-memory store and a file-backed store (persistence across
//! process restarts, used by the durable examples) are provided.

use std::collections::BTreeMap;
use std::io::Write;
use std::path::PathBuf;
use std::sync::{Arc, Mutex};

use crate::codec::{Decode, DecodeResult, Encode, Reader, Writer};
use crate::util::{LockExt, PartitionId};

/// A checkpoint of one partition: offsets + opaque processor state.
#[derive(Debug, Clone, PartialEq)]
pub struct PartitionCheckpoint {
    /// Next input offset to read (the paper's `nxtIdx`).
    pub nxt_idx: u64,
    /// Next output sequence number (the paper's `odx`).
    pub nxt_odx: u64,
    /// Encoded processor state (Local/WLocal values + WCRDT slices).
    pub state: Vec<u8>,
}

impl PartitionCheckpoint {
    /// Lattice order: larger input offset = later state (deterministic
    /// execution makes checkpoints of a partition totally ordered).
    fn dominates(&self, other: &Self) -> bool {
        self.nxt_idx >= other.nxt_idx
    }
}

impl Encode for PartitionCheckpoint {
    fn encode(&self, w: &mut Writer) {
        w.put_u64(self.nxt_idx);
        w.put_u64(self.nxt_odx);
        w.put_bytes(&self.state);
    }
}

impl Decode for PartitionCheckpoint {
    fn decode(r: &mut Reader) -> DecodeResult<Self> {
        Ok(Self {
            nxt_idx: r.get_u64()?,
            nxt_odx: r.get_u64()?,
            state: r.get_bytes()?.to_vec(),
        })
    }
}

/// Shared checkpoint store (in-memory, thread-safe).
#[derive(Debug, Clone, Default)]
pub struct CheckpointStore {
    inner: Arc<Mutex<StoreInner>>,
}

#[derive(Debug, Default)]
struct StoreInner {
    map: BTreeMap<PartitionId, PartitionCheckpoint>,
    puts: u64,
    stale_puts: u64,
}

impl CheckpointStore {
    pub fn new() -> Self {
        Self::default()
    }

    /// Monotone put: ignored if an equal-or-newer checkpoint exists.
    /// Returns whether the checkpoint was accepted.
    pub fn put(&self, p: PartitionId, cp: PartitionCheckpoint) -> bool {
        let mut s = self.inner.plane_lock();
        s.puts += 1;
        match s.map.get(&p) {
            Some(cur) if cur.dominates(&cp) && cur.nxt_idx != cp.nxt_idx => {
                s.stale_puts += 1;
                false
            }
            Some(cur) if cur.nxt_idx == cp.nxt_idx => {
                // Same prefix — determinism says identical; keep current.
                s.stale_puts += 1;
                false
            }
            _ => {
                s.map.insert(p, cp);
                true
            }
        }
    }

    /// Fetch the latest checkpoint of a partition.
    pub fn get(&self, p: PartitionId) -> Option<PartitionCheckpoint> {
        self.inner.plane_lock().map.get(&p).cloned()
    }

    /// All partition ids with a checkpoint.
    pub fn partitions(&self) -> Vec<PartitionId> {
        self.inner.plane_lock().map.keys().copied().collect()
    }

    /// (total puts, rejected stale puts) — observability for tests.
    pub fn stats(&self) -> (u64, u64) {
        let s = self.inner.plane_lock();
        (s.puts, s.stale_puts)
    }

    /// Persist the whole store to a file (length-prefixed entries).
    pub fn save_to(&self, path: &PathBuf) -> std::io::Result<()> {
        let s = self.inner.plane_lock();
        let mut w = Writer::new();
        w.put_u32(s.map.len() as u32);
        for (&p, cp) in &s.map {
            w.put_u32(p);
            cp.encode(&mut w);
        }
        let mut f = std::fs::File::create(path)?;
        f.write_all(&w.into_bytes())?;
        f.sync_all()
    }

    /// Load a store persisted with [`save_to`](Self::save_to).
    pub fn load_from(path: &PathBuf) -> std::io::Result<Self> {
        let bytes = std::fs::read(path)?;
        let mut r = Reader::new(&bytes);
        let store = Self::new();
        let n = r
            .get_u32()
            .map_err(|e| std::io::Error::new(std::io::ErrorKind::InvalidData, e))? as usize;
        for _ in 0..n {
            let p = r
                .get_u32()
                .map_err(|e| std::io::Error::new(std::io::ErrorKind::InvalidData, e))?;
            let cp = PartitionCheckpoint::decode(&mut r)
                .map_err(|e| std::io::Error::new(std::io::ErrorKind::InvalidData, e))?;
            store.put(p, cp);
        }
        Ok(store)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cp(nxt_idx: u64) -> PartitionCheckpoint {
        PartitionCheckpoint {
            nxt_idx,
            nxt_odx: nxt_idx * 2,
            state: vec![nxt_idx as u8],
        }
    }

    #[test]
    fn put_get_roundtrip() {
        let s = CheckpointStore::new();
        assert!(s.get(0).is_none());
        assert!(s.put(0, cp(5)));
        assert_eq!(s.get(0).unwrap().nxt_idx, 5);
    }

    #[test]
    fn stale_puts_rejected() {
        // The CRDT rule: largest nxt_idx wins (paper §4.3).
        let s = CheckpointStore::new();
        s.put(0, cp(10));
        assert!(!s.put(0, cp(5)));
        assert_eq!(s.get(0).unwrap().nxt_idx, 10);
        assert!(s.put(0, cp(12)));
        assert_eq!(s.get(0).unwrap().nxt_idx, 12);
        assert_eq!(s.stats(), (3, 1));
    }

    #[test]
    fn equal_offset_put_is_noop() {
        let s = CheckpointStore::new();
        s.put(0, cp(5));
        assert!(!s.put(0, cp(5)));
    }

    #[test]
    fn partitions_lists_keys() {
        let s = CheckpointStore::new();
        s.put(3, cp(1));
        s.put(1, cp(1));
        assert_eq!(s.partitions(), vec![1, 3]);
    }

    #[test]
    fn concurrent_puts_converge_to_max() {
        let s = CheckpointStore::new();
        let mut handles = vec![];
        for i in 0..8u64 {
            let s = s.clone();
            handles.push(std::thread::spawn(move || {
                s.put(0, cp(i));
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(s.get(0).unwrap().nxt_idx, 7);
    }

    #[test]
    fn file_roundtrip() {
        let dir = std::env::temp_dir().join(format!("holon-store-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("ckpt.bin");
        let s = CheckpointStore::new();
        s.put(0, cp(5));
        s.put(7, cp(9));
        s.save_to(&path).unwrap();
        let loaded = CheckpointStore::load_from(&path).unwrap();
        assert_eq!(loaded.get(0), s.get(0));
        assert_eq!(loaded.get(7), s.get(7));
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn checkpoint_codec_roundtrip() {
        use crate::codec::{Decode, Encode};
        let c = cp(42);
        assert_eq!(
            PartitionCheckpoint::from_bytes(&c.to_bytes()).unwrap(),
            c
        );
    }
}
