//! Watermark generation strategies (paper §3.2: ordered streams advance
//! the watermark to the last event; out-of-order streams "calculate the
//! watermark in a different way" — the standard bounded-disorder
//! generator of Akidau et al. / Begoli et al.).

use crate::codec::{Decode, DecodeError, DecodeResult, Encode, Reader, Writer};
use crate::util::SimTime;

/// How a partition derives its local watermark from observed event times.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum WatermarkGen {
    /// Events arrive in timestamp order per partition (the paper's
    /// implementation assumption): watermark = last event time.
    Ascending,
    /// Events may arrive up to `max_delay_ms` late: watermark trails the
    /// maximum observed event time by that bound. Events later than the
    /// bound are *late* and are dropped by the windowed insert guard
    /// (their window may already be complete globally).
    BoundedOutOfOrder { max_delay_ms: SimTime },
}

impl WatermarkGen {
    /// The watermark after observing an event at `ts`, given the maximum
    /// event time seen so far (including `ts`).
    pub fn watermark(&self, max_seen_ts: SimTime) -> SimTime {
        match self {
            WatermarkGen::Ascending => max_seen_ts,
            WatermarkGen::BoundedOutOfOrder { max_delay_ms } => {
                max_seen_ts.saturating_sub(*max_delay_ms)
            }
        }
    }

    /// Whether an event at `ts` is too late to be inserted when the
    /// maximum observed event time is `max_seen_ts`.
    pub fn is_late(&self, ts: SimTime, max_seen_ts: SimTime) -> bool {
        ts < self.watermark(max_seen_ts)
    }
}

impl Encode for WatermarkGen {
    fn encode(&self, w: &mut Writer) {
        match self {
            WatermarkGen::Ascending => w.put_u8(0),
            WatermarkGen::BoundedOutOfOrder { max_delay_ms } => {
                w.put_u8(1);
                w.put_u64(*max_delay_ms);
            }
        }
    }
}

impl Decode for WatermarkGen {
    fn decode(r: &mut Reader) -> DecodeResult<Self> {
        match r.get_u8()? {
            0 => Ok(WatermarkGen::Ascending),
            1 => Ok(WatermarkGen::BoundedOutOfOrder {
                max_delay_ms: r.get_u64()?,
            }),
            _ => Err(DecodeError("invalid watermark gen tag")),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ascending_tracks_max() {
        let g = WatermarkGen::Ascending;
        assert_eq!(g.watermark(500), 500);
        assert!(!g.is_late(500, 500));
        assert!(g.is_late(499, 500));
    }

    #[test]
    fn bounded_trails_by_delay() {
        let g = WatermarkGen::BoundedOutOfOrder { max_delay_ms: 200 };
        assert_eq!(g.watermark(1000), 800);
        assert!(!g.is_late(800, 1000)); // within the bound
        assert!(g.is_late(799, 1000)); // beyond the bound
        assert_eq!(g.watermark(100), 0); // saturating near zero
    }

    #[test]
    fn boundary_is_exact_not_fuzzy() {
        // An event exactly `max_delay_ms` behind the max is the last
        // acceptable one; one millisecond more is late. Off-by-ones here
        // silently drop (or double-count) boundary events.
        let g = WatermarkGen::BoundedOutOfOrder { max_delay_ms: 200 };
        let max_seen = 10_000;
        assert!(!g.is_late(max_seen - 200, max_seen));
        assert!(g.is_late(max_seen - 201, max_seen));
        // zero-lateness degenerates to Ascending behavior
        let g0 = WatermarkGen::BoundedOutOfOrder { max_delay_ms: 0 };
        assert_eq!(g0.watermark(500), WatermarkGen::Ascending.watermark(500));
        assert!(g0.is_late(499, 500));
        assert!(!g0.is_late(500, 500));
    }

    #[test]
    fn watermark_never_regresses_as_max_advances() {
        // The generator is fed a monotone max; the derived watermark
        // must be monotone too (for both strategies).
        for g in [
            WatermarkGen::Ascending,
            WatermarkGen::BoundedOutOfOrder { max_delay_ms: 137 },
        ] {
            let mut last = 0;
            for max_seen in [0, 1, 137, 138, 500, 500, 9999] {
                let wm = g.watermark(max_seen);
                assert!(wm >= last, "{g:?}: watermark regressed {last} -> {wm}");
                last = wm;
            }
        }
    }

    #[test]
    fn saturation_below_delay_never_marks_late() {
        // While max_seen < max_delay the watermark pins to 0 — nothing
        // can be late yet, even ts = 0.
        let g = WatermarkGen::BoundedOutOfOrder { max_delay_ms: 1000 };
        assert_eq!(g.watermark(999), 0);
        assert!(!g.is_late(0, 999));
    }

    #[test]
    fn codec_roundtrip() {
        for g in [
            WatermarkGen::Ascending,
            WatermarkGen::BoundedOutOfOrder { max_delay_ms: 42 },
        ] {
            assert_eq!(WatermarkGen::from_bytes(&g.to_bytes()).unwrap(), g);
        }
    }
}
