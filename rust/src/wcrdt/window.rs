//! Window assignment: tumbling (the paper's implementation) plus a
//! sliding extension (paper §7 future work — window generalization).

use crate::codec::{Decode, DecodeError, DecodeResult, Encode, Reader, Writer};
use crate::util::SimTime;

/// Dense window index (window 0 covers `[0, size)` for tumbling).
pub type WindowId = u64;

/// Assigns timestamps to windows.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum WindowAssigner {
    /// Fixed-size, non-overlapping windows of `size` sim-ms.
    Tumbling { size: SimTime },
    /// Overlapping windows: length `size`, advanced every `slide`.
    /// `window_of` returns the *last* window containing the timestamp;
    /// `windows_of` enumerates all of them.
    Sliding { size: SimTime, slide: SimTime },
}

impl WindowAssigner {
    pub fn tumbling(size: SimTime) -> Self {
        assert!(size > 0);
        WindowAssigner::Tumbling { size }
    }

    pub fn sliding(size: SimTime, slide: SimTime) -> Self {
        assert!(size > 0 && slide > 0 && slide <= size);
        WindowAssigner::Sliding { size, slide }
    }

    /// The tumbling size, or the slide for sliding windows (the pace at
    /// which new windows open).
    pub fn size(&self) -> SimTime {
        match self {
            WindowAssigner::Tumbling { size } => *size,
            WindowAssigner::Sliding { slide, .. } => *slide,
        }
    }

    /// Primary window of a timestamp.
    pub fn window_of(&self, ts: SimTime) -> WindowId {
        match self {
            WindowAssigner::Tumbling { size } => ts / size,
            WindowAssigner::Sliding { slide, .. } => ts / slide,
        }
    }

    /// All windows containing a timestamp (1 for tumbling).
    pub fn windows_of(&self, ts: SimTime) -> Vec<WindowId> {
        match self {
            WindowAssigner::Tumbling { size } => vec![ts / size],
            WindowAssigner::Sliding { size, slide } => {
                let last = ts / slide;
                let span = (size + slide - 1) / slide; // windows covering ts
                let first = last.saturating_sub(span - 1);
                // window w covers [w*slide, w*slide + size)
                (first..=last)
                    .filter(|w| w * slide <= ts && ts < w * slide + size)
                    .collect()
            }
        }
    }

    /// Exclusive end timestamp of a window.
    pub fn window_end(&self, wid: WindowId) -> SimTime {
        match self {
            WindowAssigner::Tumbling { size } => (wid + 1) * size,
            WindowAssigner::Sliding { size, slide } => wid * slide + size,
        }
    }

    /// Inclusive start timestamp of a window.
    pub fn window_start(&self, wid: WindowId) -> SimTime {
        match self {
            WindowAssigner::Tumbling { size } => wid * size,
            WindowAssigner::Sliding { slide, .. } => wid * slide,
        }
    }
}

impl Encode for WindowAssigner {
    fn encode(&self, w: &mut Writer) {
        match self {
            WindowAssigner::Tumbling { size } => {
                w.put_u8(0);
                w.put_u64(*size);
            }
            WindowAssigner::Sliding { size, slide } => {
                w.put_u8(1);
                w.put_u64(*size);
                w.put_u64(*slide);
            }
        }
    }
}

impl Decode for WindowAssigner {
    fn decode(r: &mut Reader) -> DecodeResult<Self> {
        match r.get_u8()? {
            0 => Ok(WindowAssigner::Tumbling {
                size: r.get_u64()?,
            }),
            1 => Ok(WindowAssigner::Sliding {
                size: r.get_u64()?,
                slide: r.get_u64()?,
            }),
            _ => Err(DecodeError("invalid window assigner tag")),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tumbling_assignment() {
        let a = WindowAssigner::tumbling(1000);
        assert_eq!(a.window_of(0), 0);
        assert_eq!(a.window_of(999), 0);
        assert_eq!(a.window_of(1000), 1);
        assert_eq!(a.window_end(0), 1000);
        assert_eq!(a.window_start(3), 3000);
        assert_eq!(a.windows_of(1500), vec![1]);
    }

    #[test]
    fn sliding_assignment_covers() {
        // size 1000, slide 500 => each ts is in 2 windows.
        let a = WindowAssigner::sliding(1000, 500);
        assert_eq!(a.windows_of(0), vec![0]); // window -1 doesn't exist
        assert_eq!(a.windows_of(700), vec![0, 1]);
        assert_eq!(a.windows_of(1200), vec![1, 2]);
        for &w in &a.windows_of(1200) {
            assert!(a.window_start(w) <= 1200 && 1200 < a.window_end(w));
        }
    }

    #[test]
    fn sliding_window_bounds() {
        let a = WindowAssigner::sliding(1000, 500);
        assert_eq!(a.window_start(2), 1000);
        assert_eq!(a.window_end(2), 2000);
    }

    #[test]
    fn codec_roundtrip() {
        use crate::codec::{Decode, Encode};
        for a in [WindowAssigner::tumbling(250), WindowAssigner::sliding(1000, 100)] {
            let b = a.to_bytes();
            assert_eq!(WindowAssigner::from_bytes(&b).unwrap(), a);
        }
    }

    #[test]
    #[should_panic]
    fn zero_size_rejected() {
        WindowAssigner::tumbling(0);
    }
}
