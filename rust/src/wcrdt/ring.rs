//! `WindowRing<T>` — a ring buffer over the live window horizon.
//!
//! Every windowed store in the system (`WindowedCrdt`, `WLocal`, the
//! query `SignatureIndex`, per-partition emit counters) used to be a
//! `BTreeMap<WindowId, T>`, paying a log-n probe and a node allocation
//! per window touch on the hottest path the engine has. But compaction
//! already bounds the live span to a handful of windows, so the map is
//! really a dense array in disguise: this type indexes `window_id −
//! base` into a contiguous slot ring for O(1), allocation-free access
//! inside the horizon, spilling to a small `BTreeMap` only for
//! out-of-horizon windows (late stragglers below the ring base after
//! compaction, or far-future windows beyond [`MAX_DENSE_SPAN`]).
//!
//! The ring is a drop-in *logical* map replacement:
//!
//! * iteration is always in ascending `WindowId` order (dense range
//!   merged with both spill ranges), so [`Encode`] produces bytes
//!   **identical** to the `BTreeMap<WindowId, T>` layout it replaces —
//!   `u32 count` followed by sorted `(u64 key, value)` pairs. Gossip
//!   payloads, checkpoints and golden outputs do not move by a byte.
//! * `PartialEq` is logical (same key/value pairs), independent of how
//!   entries are split between dense slots and spill.
//!
//! Invariant: the spill map never holds a key inside the dense range
//! `[base, base+len)` — extending the dense range migrates any spilled
//! keys it swallows, which is what keeps single-pass ordered iteration
//! correct. Spill insertions are counted in a thread-local drained by
//! the engine into `ClusterMetrics::window_ring_spills`: in a healthy
//! deployment the counter stays ~0, so a nonzero rate is a direct
//! signal that lateness/compaction tuning is off.

use std::cell::Cell;
use std::collections::{BTreeMap, VecDeque};

use crate::codec::{Decode, DecodeResult, Encode, Reader, Writer};

use super::WindowId;

/// Hard cap on the dense slot span. Far above any real live horizon
/// (compaction holds ~16 windows); a workload that somehow touches a
/// wider spread degrades to the spill map instead of allocating an
/// unbounded slot array.
pub const MAX_DENSE_SPAN: usize = 1024;

thread_local! {
    static RING_SPILLS: Cell<u64> = const { Cell::new(0) };
}

/// Drain this thread's count of out-of-horizon spill insertions
/// (accumulated across every [`WindowRing`] the thread touched).
pub fn take_ring_spills() -> u64 {
    RING_SPILLS.with(|c| c.replace(0))
}

fn note_spill() {
    RING_SPILLS.with(|c| c.set(c.get() + 1));
}

/// Ring-over-horizon window store. See the module docs.
#[derive(Debug, Clone)]
pub struct WindowRing<T> {
    /// WindowId of `slots[0]`. Meaningless while `slots` is empty.
    base: WindowId,
    /// Dense coverage `[base, base + slots.len())`; `None` = absent.
    slots: VecDeque<Option<T>>,
    /// Occupied dense slots.
    live: usize,
    /// Out-of-horizon entries; never overlaps the dense range.
    spill: BTreeMap<WindowId, T>,
}

impl<T> Default for WindowRing<T> {
    fn default() -> Self {
        Self {
            base: 0,
            slots: VecDeque::new(),
            live: 0,
            spill: BTreeMap::new(),
        }
    }
}

impl<T> WindowRing<T> {
    pub fn new() -> Self {
        Self::default()
    }

    /// Number of occupied windows.
    pub fn len(&self) -> usize {
        self.live + self.spill.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Entries currently held by the spill map (observability/tests).
    pub fn spilled(&self) -> usize {
        self.spill.len()
    }

    fn dense_idx(&self, w: WindowId) -> Option<usize> {
        if !self.slots.is_empty() && w >= self.base {
            let idx = (w - self.base) as usize;
            if idx < self.slots.len() {
                return Some(idx);
            }
        }
        None
    }

    pub fn get(&self, w: &WindowId) -> Option<&T> {
        match self.dense_idx(*w) {
            Some(i) => self.slots[i].as_ref(),
            None => self.spill.get(w),
        }
    }

    pub fn get_mut(&mut self, w: &WindowId) -> Option<&mut T> {
        match self.dense_idx(*w) {
            Some(i) => self.slots[i].as_mut(),
            None => self.spill.get_mut(w),
        }
    }

    pub fn contains_key(&self, w: &WindowId) -> bool {
        self.get(w).is_some()
    }

    /// First (lowest) occupied WindowId.
    pub fn first_key(&self) -> Option<WindowId> {
        self.iter().next().map(|(w, _)| w)
    }

    /// Get-or-insert in the slot for `w`, placing new out-of-horizon
    /// entries in the spill map (counted). The hot path — a window
    /// inside the dense range — is an index probe, no allocation.
    // lint: zero-alloc
    pub fn entry_or_insert_with(&mut self, w: WindowId, f: impl FnOnce() -> T) -> &mut T {
        // existing spill entry wins: the dense range must not shadow it
        if self.spill.contains_key(&w) {
            return self.spill.get_mut(&w).unwrap();
        }
        if self.slots.is_empty() {
            // anchor the ring on the first touched window
            self.base = w;
            self.slots.push_back(Some(f()));
            self.live += 1;
            return self.slots[0].as_mut().unwrap();
        }
        if w >= self.base {
            let idx = (w - self.base) as usize;
            if idx < self.slots.len() {
                let slot = &mut self.slots[idx];
                if slot.is_none() {
                    *slot = Some(f());
                    self.live += 1;
                }
                return slot.as_mut().unwrap();
            }
            // extend the dense range upward when it stays within span
            if idx < MAX_DENSE_SPAN {
                let old_end = self.base + self.slots.len() as u64;
                while self.slots.len() <= idx {
                    self.slots.push_back(None);
                }
                self.migrate_spill_range(old_end, self.base + self.slots.len() as u64);
                let slot = &mut self.slots[idx];
                if slot.is_none() {
                    *slot = Some(f());
                    self.live += 1;
                }
                return slot.as_mut().unwrap();
            }
        } else {
            // below base: extend downward when the total span allows
            let grow = (self.base - w) as usize;
            if grow + self.slots.len() <= MAX_DENSE_SPAN {
                let old_base = self.base;
                for _ in 0..grow {
                    self.slots.push_front(None);
                }
                self.base = w;
                self.migrate_spill_range(w, old_base);
                let slot = &mut self.slots[0];
                if slot.is_none() {
                    *slot = Some(f());
                    self.live += 1;
                }
                return slot.as_mut().unwrap();
            }
        }
        // out of horizon in either direction: spill
        note_spill();
        self.spill.entry(w).or_insert_with(f)
    }

    /// Move spill entries inside `[lo, hi)` into their (newly covering)
    /// dense slots, preserving the no-overlap invariant.
    fn migrate_spill_range(&mut self, lo: WindowId, hi: WindowId) {
        if self.spill.is_empty() {
            return;
        }
        let keys: Vec<WindowId> = self.spill.range(lo..hi).map(|(k, _)| *k).collect();
        for k in keys {
            let v = self.spill.remove(&k).unwrap();
            let idx = (k - self.base) as usize;
            debug_assert!(self.slots[idx].is_none());
            self.slots[idx] = Some(v);
            self.live += 1;
        }
    }

    pub fn remove(&mut self, w: &WindowId) -> Option<T> {
        match self.dense_idx(*w) {
            Some(i) => {
                let v = self.slots[i].take();
                if v.is_some() {
                    self.live -= 1;
                }
                // keep the deque from pinning dead low slots forever
                self.trim_front();
                v
            }
            None => self.spill.remove(w),
        }
    }

    /// Drop leading empty slots, advancing `base` (cheap, keeps the
    /// dense span anchored near the live horizon).
    fn trim_front(&mut self) {
        while matches!(self.slots.front(), Some(None)) {
            self.slots.pop_front();
            self.base += 1;
        }
        if self.slots.is_empty() {
            // fully drained: next insert re-anchors
            self.base = 0;
        }
    }

    /// Remove all windows strictly below `w` (compaction). The ring
    /// base advances with the floor, which is what keeps the dense
    /// span bounded by the live horizon between compactions.
    pub fn compact_below(&mut self, w: WindowId) {
        while !self.slots.is_empty() && self.base < w {
            if self.slots.pop_front().unwrap().is_some() {
                self.live -= 1;
            }
            self.base += 1;
        }
        self.trim_front();
        // split_off keeps >= w
        self.spill = self.spill.split_off(&w);
    }

    /// Iterate `(WindowId, &T)` in ascending WindowId order across the
    /// spill-below / dense / spill-above segments.
    pub fn iter(&self) -> impl Iterator<Item = (WindowId, &T)> {
        let base = self.base;
        let end = base + self.slots.len() as u64;
        let below = self.spill.range(..base).map(|(k, v)| (*k, v));
        let dense = self
            .slots
            .iter()
            .enumerate()
            .filter_map(move |(i, s)| s.as_ref().map(|v| (base + i as u64, v)));
        let above = self.spill.range(end..).map(|(k, v)| (*k, v));
        below.chain(dense).chain(above)
    }

    /// Occupied WindowIds in ascending order.
    pub fn keys(&self) -> impl Iterator<Item = WindowId> + '_ {
        self.iter().map(|(w, _)| w)
    }

    /// Insert, returning the previous value (BTreeMap semantics).
    pub fn insert(&mut self, w: WindowId, v: T) -> Option<T> {
        let mut fresh = Some(v);
        let slot = self.entry_or_insert_with(w, || fresh.take().unwrap());
        fresh.take().map(|v| std::mem::replace(slot, v))
    }
}

impl<T: PartialEq> PartialEq for WindowRing<T> {
    fn eq(&self, other: &Self) -> bool {
        self.len() == other.len()
            && self
                .iter()
                .zip(other.iter())
                .all(|((wa, va), (wb, vb))| wa == wb && va == vb)
    }
}

impl<T> FromIterator<(WindowId, T)> for WindowRing<T> {
    fn from_iter<I: IntoIterator<Item = (WindowId, T)>>(it: I) -> Self {
        let mut r = Self::new();
        for (w, v) in it {
            r.insert(w, v);
        }
        r
    }
}

impl<T: Encode> Encode for WindowRing<T> {
    fn encode(&self, w: &mut Writer) {
        // byte-identical to BTreeMap<WindowId, T>: count + sorted pairs
        w.put_u32(self.len() as u32);
        for (wid, v) in self.iter() {
            w.put_u64(wid);
            v.encode(w);
        }
    }
}

impl<T: Decode> Decode for WindowRing<T> {
    fn decode(r: &mut Reader) -> DecodeResult<Self> {
        let n = r.get_u32()? as usize;
        let mut ring = Self::new();
        for _ in 0..n {
            let w = r.get_u64()?;
            let v = T::decode(r)?;
            ring.insert(w, v);
        }
        Ok(ring)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn keys<T>(r: &WindowRing<T>) -> Vec<WindowId> {
        r.keys().collect()
    }

    #[test]
    fn dense_insert_get_remove() {
        let mut r = WindowRing::new();
        *r.entry_or_insert_with(5, || 0u64) += 10;
        *r.entry_or_insert_with(7, || 0) += 20;
        *r.entry_or_insert_with(5, || 0) += 1;
        assert_eq!(r.get(&5), Some(&11));
        assert_eq!(r.get(&6), None);
        assert_eq!(r.get(&7), Some(&20));
        assert_eq!(r.len(), 2);
        assert_eq!(r.spilled(), 0);
        assert_eq!(r.remove(&5), Some(11));
        assert_eq!(r.len(), 1);
        assert_eq!(keys(&r), vec![7]);
    }

    #[test]
    fn iterates_in_window_order_across_segments() {
        let _ = take_ring_spills();
        let mut r = WindowRing::new();
        r.entry_or_insert_with(1000, || 'a');
        // far below: spills (span would exceed MAX_DENSE_SPAN)
        r.entry_or_insert_with(3, || 'b');
        // far above: spills
        r.entry_or_insert_with(1000 + MAX_DENSE_SPAN as u64 + 5, || 'c');
        r.entry_or_insert_with(1001, || 'd');
        assert_eq!(keys(&r), vec![3, 1000, 1001, 1000 + MAX_DENSE_SPAN as u64 + 5]);
        assert_eq!(r.spilled(), 2);
        assert_eq!(take_ring_spills(), 2);
    }

    #[test]
    fn compact_below_drops_all_segments_and_advances_base() {
        let mut r = WindowRing::new();
        for w in [100u64, 101, 103, 5, 2000] {
            r.entry_or_insert_with(w, || w);
        }
        r.compact_below(102);
        assert_eq!(keys(&r), vec![103, 2000]);
        // post-compaction inserts above the floor stay dense
        r.entry_or_insert_with(104, || 104);
        assert_eq!(r.get(&104), Some(&104));
        assert_eq!(keys(&r), vec![103, 104, 2000]);
    }

    #[test]
    fn late_insert_at_exact_floor_minus_one_spills_or_extends_safely() {
        // Regression shape for the wid − base underflow class: after
        // compaction to floor f, an insert at exactly f − 1 must land
        // correctly (never index-underflow into the dense ring).
        let mut r = WindowRing::new();
        for w in 10u64..20 {
            r.entry_or_insert_with(w, || w);
        }
        r.compact_below(15);
        let _ = take_ring_spills();
        *r.entry_or_insert_with(14, || 140) = 140;
        assert_eq!(r.get(&14), Some(&140));
        assert_eq!(keys(&r), vec![14, 15, 16, 17, 18, 19]);
        // iteration order and logical equality survive a re-encode
        let enc = {
            let mut w = Writer::new();
            r.encode(&mut w);
            w.into_bytes()
        };
        let back = WindowRing::<u64>::from_bytes(&enc).unwrap();
        assert_eq!(back, r);
        // and when the gap really is out of horizon, it spills instead
        let mut far = WindowRing::new();
        far.entry_or_insert_with(MAX_DENSE_SPAN as u64 + 50, || 1u64);
        far.compact_below(MAX_DENSE_SPAN as u64 + 50);
        let _ = take_ring_spills();
        far.entry_or_insert_with(10, || 2);
        assert_eq!(far.spilled(), 1);
        assert_eq!(take_ring_spills(), 1);
        assert_eq!(keys(&far), vec![10, MAX_DENSE_SPAN as u64 + 50]);
    }

    #[test]
    fn encode_is_byte_identical_to_btreemap() {
        let mut m: BTreeMap<WindowId, u64> = BTreeMap::new();
        let mut r: WindowRing<u64> = WindowRing::new();
        for (w, v) in [(7u64, 70u64), (3, 30), (4000, 9), (5, 50)] {
            m.insert(w, v);
            r.entry_or_insert_with(w, || v);
        }
        let mut wm = Writer::new();
        m.encode(&mut wm);
        let mut wr = Writer::new();
        r.encode(&mut wr);
        assert_eq!(wm.as_slice(), wr.as_slice());
        // decode round-trips logically
        let back = WindowRing::<u64>::from_bytes(wr.as_slice()).unwrap();
        assert_eq!(back, r);
    }

    #[test]
    fn dense_extension_migrates_spilled_keys() {
        let mut r = WindowRing::new();
        r.entry_or_insert_with(100, || 1u64);
        // beyond span: spills
        let far = 100 + MAX_DENSE_SPAN as u64 + 10;
        r.entry_or_insert_with(far, || 2);
        assert_eq!(r.spilled(), 1);
        // compaction moves the base past the gap; the next insert near
        // `far` extends the dense range over it — the spilled entry must
        // migrate, not be shadowed by an empty dense slot
        r.compact_below(far - 5);
        r.entry_or_insert_with(far + 1, || 3);
        assert_eq!(r.spilled(), 0);
        assert_eq!(r.get(&far), Some(&2));
        assert_eq!(keys(&r), vec![far, far + 1]);
    }

    #[test]
    fn downward_extension_covers_nearby_late_windows() {
        let mut r = WindowRing::new();
        r.entry_or_insert_with(50, || 'x');
        let _ = take_ring_spills();
        r.entry_or_insert_with(47, || 'y'); // fits: extends down
        assert_eq!(take_ring_spills(), 0);
        assert_eq!(keys(&r), vec![47, 50]);
        assert_eq!(r.get(&47), Some(&'y'));
    }

    #[test]
    fn logical_eq_ignores_physical_layout() {
        // same logical content, different insertion orders → different
        // dense/spill splits, but equal
        let mut a = WindowRing::new();
        a.entry_or_insert_with(10, || 1u64);
        a.entry_or_insert_with(11, || 2);
        let mut b = WindowRing::new();
        b.entry_or_insert_with(11, || 2u64);
        b.entry_or_insert_with(10, || 1);
        assert_eq!(a, b);
        b.entry_or_insert_with(12, || 3);
        assert_ne!(a, b);
    }

    #[test]
    fn remove_then_reinsert_keeps_ring_consistent() {
        let mut r = WindowRing::new();
        for w in 0u64..8 {
            r.entry_or_insert_with(w, || w);
        }
        for w in 0u64..8 {
            assert_eq!(r.remove(&w), Some(w));
        }
        assert!(r.is_empty());
        // fully drained ring re-anchors wherever the next insert lands
        r.entry_or_insert_with(1_000_000, || 42);
        assert_eq!(r.get(&1_000_000), Some(&42));
        assert_eq!(r.len(), 1);
        assert_eq!(r.spilled(), 0);
    }

    #[test]
    fn from_iterator_builds_sorted_or_not() {
        let r: WindowRing<u64> = [(9u64, 90u64), (2, 20), (5, 50)].into_iter().collect();
        assert_eq!(keys(&r), vec![2, 5, 9]);
        assert_eq!(r.get(&5), Some(&50));
    }
}
