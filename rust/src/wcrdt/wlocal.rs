//! Partition-local state: `WLocal` (windowed local values) and `Local`
//! (plain local values) — Table 1's non-replicated state types.
//!
//! Unlike [`WindowedCrdt`](super::WindowedCrdt), these are visible only
//! to the owning partition; the runtime checkpoints and recovers them
//! with the partition state, so they share the exactly-once guarantee.

use super::ring::WindowRing;
use super::window::{WindowAssigner, WindowId};
use crate::codec::{Decode, DecodeResult, Encode, Reader, Writer};
use crate::util::SimTime;

/// A windowed, partition-local value folded with a user `fold` function
/// applied via [`WLocal::update`]. Completion tracks the partition's own
/// watermark only (no global coordination — it is local state). The
/// window store is the same O(1) [`WindowRing`] as [`WindowedCrdt`]'s
/// (byte-identical `Encode` layout to the old `BTreeMap`).
#[derive(Debug, Clone, PartialEq)]
pub struct WLocal<T: Clone> {
    assigner: WindowAssigner,
    windows: WindowRing<T>,
    watermark: SimTime,
    zero: T,
}

impl<T: Clone> WLocal<T> {
    pub fn new(assigner: WindowAssigner, zero: T) -> Self {
        Self {
            assigner,
            windows: WindowRing::new(),
            watermark: 0,
            zero,
        }
    }

    /// Fold an event at `ts` into its window.
    pub fn update(&mut self, ts: SimTime, f: impl FnOnce(&mut T)) {
        let wid = self.assigner.window_of(ts);
        let zero = &self.zero;
        f(self.windows.entry_or_insert_with(wid, || zero.clone()));
    }

    pub fn increment_watermark(&mut self, ts: SimTime) {
        self.watermark = self.watermark.max(ts);
    }

    pub fn watermark(&self) -> SimTime {
        self.watermark
    }

    /// The window value once the local watermark passed its end.
    pub fn window_value(&self, wid: WindowId) -> Option<T> {
        if self.assigner.window_end(wid) > self.watermark {
            return None;
        }
        Some(
            self.windows
                .get(&wid)
                .cloned()
                .unwrap_or_else(|| self.zero.clone()),
        )
    }

    pub fn compact_below(&mut self, wid: WindowId) {
        self.windows.compact_below(wid);
    }

    pub fn live_windows(&self) -> usize {
        self.windows.len()
    }
}

impl<T: Clone + Encode> Encode for WLocal<T> {
    fn encode(&self, w: &mut Writer) {
        self.assigner.encode(w);
        self.windows.encode(w);
        w.put_u64(self.watermark);
        self.zero.encode(w);
    }
}

impl<T: Clone + Decode> Decode for WLocal<T> {
    fn decode(r: &mut Reader) -> DecodeResult<Self> {
        Ok(Self {
            assigner: WindowAssigner::decode(r)?,
            windows: WindowRing::decode(r)?,
            watermark: r.get_u64()?,
            zero: T::decode(r)?,
        })
    }
}

/// A plain partition-local value (Table 1 `Local`), checkpointed with
/// the partition. A thin newtype so query code reads like the paper's
/// listings.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct Local<T>(pub T);

impl<T> Local<T> {
    pub fn new(v: T) -> Self {
        Local(v)
    }

    pub fn get(&self) -> &T {
        &self.0
    }

    pub fn set(&mut self, v: T) {
        self.0 = v;
    }
}

impl<T: Encode> Encode for Local<T> {
    fn encode(&self, w: &mut Writer) {
        self.0.encode(w);
    }
}

impl<T: Decode> Decode for Local<T> {
    fn decode(r: &mut Reader) -> DecodeResult<Self> {
        Ok(Local(T::decode(r)?))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn wlocal_folds_per_window() {
        let mut w = WLocal::new(WindowAssigner::tumbling(100), 0u64);
        w.update(10, |v| *v += 1);
        w.update(50, |v| *v += 1);
        w.update(150, |v| *v += 1);
        assert_eq!(w.window_value(0), None); // watermark still 0
        w.increment_watermark(100);
        assert_eq!(w.window_value(0), Some(2));
        assert_eq!(w.window_value(1), None);
        w.increment_watermark(200);
        assert_eq!(w.window_value(1), Some(1));
    }

    #[test]
    fn wlocal_empty_window_is_zero() {
        let mut w = WLocal::new(WindowAssigner::tumbling(100), 7u64);
        w.increment_watermark(300);
        assert_eq!(w.window_value(1), Some(7));
    }

    #[test]
    fn wlocal_compaction() {
        let mut w = WLocal::new(WindowAssigner::tumbling(100), 0u64);
        w.update(10, |v| *v += 1);
        w.update(110, |v| *v += 1);
        w.compact_below(1);
        assert_eq!(w.live_windows(), 1);
    }

    #[test]
    fn wlocal_codec() {
        use crate::codec::{Decode, Encode};
        let mut w = WLocal::new(WindowAssigner::tumbling(100), 0u64);
        w.update(10, |v| *v += 3);
        w.increment_watermark(42);
        let back = WLocal::<u64>::from_bytes(&w.to_bytes()).unwrap();
        assert_eq!(back, w);
    }

    #[test]
    fn local_roundtrip() {
        use crate::codec::{Decode, Encode};
        let l = Local::new(123u64);
        assert_eq!(Local::<u64>::from_bytes(&l.to_bytes()).unwrap(), l);
    }
}
