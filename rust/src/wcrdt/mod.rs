//! Windowed CRDTs — Algorithm 1, the paper's core abstraction.
//!
//! A [`WindowedCrdt`] wraps any state-based [`Crdt`] and slices an
//! infinite stream into an infinite sequence of finite windows. State:
//!
//! * `windows: Map<WindowId, C>` — one CRDT per window;
//! * `progress: Map<PartitionId, Timestamp>` — each participant's local
//!   watermark (the lowest timestamp it may still process).
//!
//! Reading a window value succeeds only once the *global watermark*
//! (min over all participants' progress) has passed the window end: at
//! that point no participant can still insert into the window, every
//! insert has been merged (reads happen on the reader's replica, which
//! by then has received all contributions), and the value is final —
//! **every replica returns the same value for the same window**. This is
//! the "global determinism" guarantee of §3.3/§4.2, and what a plain
//! CRDT cannot give on an infinite stream.
//!
//! The `progress` map is keyed by *partition* (the unit of ownership and
//! work stealing); a node's watermark is the min over the partitions it
//! executes, which is what Algorithm 1 tracks per "node".

pub mod ring;
mod watermark;
mod window;
mod wlocal;

pub use ring::WindowRing;
pub use watermark::WatermarkGen;
pub use window::{WindowAssigner, WindowId};
pub use wlocal::{Local, WLocal};

use std::cell::Cell;
use std::collections::BTreeMap;

use crate::codec::{Decode, DecodeResult, Encode, Reader, Writer};
use crate::crdt::{Crdt, MergeOutcome};
use crate::util::{PartitionId, SimTime};

thread_local! {
    /// `(count, oldest wid, newest wid)` of windows newly materialised
    /// by *local* inserts on this thread since the last drain. Windows
    /// learned through merge/gossip are not "opened" here — the peer
    /// that first saw data for them already recorded the open.
    static WINDOW_OPENS: Cell<(u64, WindowId, WindowId)> =
        const { Cell::new((0, WindowId::MAX, 0)) };
}

fn note_window_open(wid: WindowId) {
    WINDOW_OPENS.with(|c| {
        let (n, lo, hi) = c.get();
        c.set((n + 1, lo.min(wid), hi.max(wid)));
    });
}

/// Drain this thread's window-open record (accumulated across every
/// [`WindowedCrdt`] the thread touched): `(count, oldest wid, newest
/// wid)`, with `count == 0` meaning nothing opened. The node loop
/// drains this once per iteration into a single `window_opened`
/// flight-recorder event — the same thread-local-drain idiom as
/// [`ring::take_ring_spills`].
pub fn take_window_opens() -> (u64, WindowId, WindowId) {
    WINDOW_OPENS.with(|c| c.replace((0, WindowId::MAX, 0)))
}

/// Errors from WCRDT operations.
#[derive(Debug, thiserror::Error, PartialEq, Eq)]
pub enum WcrdtError {
    /// Insert below the inserting participant's own watermark
    /// (Algorithm 1 line 5: `if ts < progress[self] then error`).
    #[error("insert at ts={ts} below own watermark {watermark}")]
    LateInsert { ts: SimTime, watermark: SimTime },
}

/// What a [`WindowedCrdt::merge`] actually did — the windowed face of
/// the trait-v3 change-reporting contract. The engine's receive path
/// reads this to dirty-mark only the windows that genuinely inflated;
/// a received full-sync payload the replica already subsumes reports
/// an empty set, killing the post-anti-entropy delta amplification.
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct MergeReport {
    /// Windows whose state actually inflated (ascending window id).
    pub changed_windows: Vec<WindowId>,
    /// Some progress (watermark) entry was raised or added.
    pub progress_changed: bool,
    /// `compacted_below` advanced.
    pub compaction_advanced: bool,
}

impl MergeReport {
    /// Collapse to the scalar outcome: did the target change at all?
    pub fn outcome(&self) -> MergeOutcome {
        MergeOutcome::changed_if(
            !self.changed_windows.is_empty() || self.progress_changed || self.compaction_advanced,
        )
    }
}

/// A windowed, replicated, convergent aggregate (Algorithm 1).
///
/// The window store is a [`WindowRing`]: compaction bounds the live
/// horizon, so window access is an O(1) slot probe instead of the old
/// `BTreeMap` log-n walk — with a spill map for out-of-horizon
/// stragglers and a byte-identical `Encode` layout (see the ring docs).
#[derive(Debug, Clone)]
pub struct WindowedCrdt<C: Crdt> {
    assigner: WindowAssigner,
    windows: WindowRing<C>,
    progress: BTreeMap<PartitionId, SimTime>,
    /// Windows at or below this id have been compacted away; their
    /// values were final (and identical on every replica) when dropped.
    compacted_below: WindowId,
    /// Windows touched since the last [`take_delta`](Self::take_delta)
    /// — local metadata (not serialized, not part of equality) backing
    /// delta-based synchronization (paper §7 future work).
    dirty: std::collections::BTreeSet<WindowId>,
    /// Whether any progress entry was raised since the last
    /// [`take_delta`](Self::take_delta) / [`mark_clean`](Self::mark_clean)
    /// — sync metadata like `dirty`. Deltas always carry the (small)
    /// full progress map, so a replica whose only news is watermark
    /// movement still has a (tiny) delta to ship; a replica with neither
    /// dirty windows nor progress movement has nothing to gossip at all
    /// ([`has_delta`](Self::has_delta)), which is what lets the engine
    /// skip encoding/broadcasting empty delta rounds entirely.
    progress_dirty: bool,
}

impl<C: Crdt + PartialEq> PartialEq for WindowedCrdt<C> {
    fn eq(&self, other: &Self) -> bool {
        // dirty is sync metadata, not state
        self.assigner == other.assigner
            && self.windows == other.windows
            && self.progress == other.progress
            && self.compacted_below == other.compacted_below
    }
}

impl<C: Crdt> WindowedCrdt<C> {
    /// Create a replica. `participants` must be the full partition set —
    /// the global watermark is the min over *all* of them, so a replica
    /// must know who participates (the paper's deployment fixes the
    /// partition count up front; reconfiguration moves partitions, it
    /// does not add them).
    pub fn new(assigner: WindowAssigner, participants: impl IntoIterator<Item = PartitionId>) -> Self {
        let progress = participants.into_iter().map(|p| (p, 0)).collect();
        Self {
            assigner,
            windows: WindowRing::new(),
            progress,
            compacted_below: 0,
            dirty: std::collections::BTreeSet::new(),
            progress_dirty: false,
        }
    }

    pub fn assigner(&self) -> WindowAssigner {
        self.assigner
    }

    /// Algorithm 1 `INSERT`: fold an update into the window of `ts` on
    /// behalf of partition `myself`.
    pub fn insert_with(
        &mut self,
        myself: PartitionId,
        ts: SimTime,
        f: impl FnOnce(&mut C),
    ) -> Result<(), WcrdtError> {
        let own = self.progress.get(&myself).copied().unwrap_or(0);
        if ts < own {
            return Err(WcrdtError::LateInsert { ts, watermark: own });
        }
        let wid = self.assigner.window_of(ts);
        debug_assert!(wid >= self.compacted_below, "insert into compacted window");
        let before = self.windows.len();
        f(self.windows.entry_or_insert_with(wid, C::default));
        if self.windows.len() > before {
            note_window_open(wid);
        }
        self.dirty.insert(wid);
        Ok(())
    }

    /// Batch-path insert directly into window `wid` (the XLA hot path
    /// inserts one pre-aggregated contribution per window per batch
    /// instead of one per event). Returns `false` (skips) for windows
    /// already compacted or strictly below the inserter's own progress
    /// window — which only happens on stale replays whose contributions
    /// are already reflected.
    pub fn insert_window_with(
        &mut self,
        myself: PartitionId,
        wid: WindowId,
        f: impl FnOnce(&mut C),
    ) -> bool {
        if wid < self.compacted_below {
            return false;
        }
        let own = self.progress.get(&myself).copied().unwrap_or(0);
        if wid < self.assigner.window_of(own) {
            return false;
        }
        let before = self.windows.len();
        f(self.windows.entry_or_insert_with(wid, C::default));
        if self.windows.len() > before {
            note_window_open(wid);
        }
        self.dirty.insert(wid);
        true
    }

    /// Algorithm 1 `INCREMENTWATERMARK`: raise `myself`'s local watermark.
    pub fn increment_watermark(&mut self, myself: PartitionId, ts: SimTime) {
        let e = self.progress.entry(myself).or_insert(0);
        if *e < ts {
            *e = ts;
            self.progress_dirty = true;
        }
    }

    /// Algorithm 1 `GLOBALWATERMARK`: min over all participants.
    pub fn global_watermark(&self) -> SimTime {
        self.progress.values().copied().min().unwrap_or(0)
    }

    /// Algorithm 1 `WINDOWVALUE` (the *unsafe mode* read): `None` until
    /// the global watermark passes the window end, then the final value.
    pub fn window_value(&self, wid: WindowId) -> Option<C> {
        if wid < self.compacted_below || !self.is_complete(wid) {
            // Compacted windows are gone: their (final, deterministic)
            // values were emitted before compaction. Returning None makes
            // a stale reader stall visibly rather than read bottom.
            return None;
        }
        Some(self.windows.get(&wid).cloned().unwrap_or_default())
    }

    /// First window id that has not been compacted away. Readers whose
    /// cursor fell behind a compaction (extremely stale restart) skip
    /// forward to this id.
    pub fn first_available(&self) -> WindowId {
        self.compacted_below
    }

    /// Whether `wid` is completed (no more updates can arrive anywhere).
    pub fn is_complete(&self, wid: WindowId) -> bool {
        self.assigner.window_end(wid) <= self.global_watermark()
    }

    /// Highest window id that is complete, if any.
    pub fn completed_up_to(&self) -> Option<WindowId> {
        let gw = self.global_watermark();
        // Window w is complete iff window_end(w) <= gw; scan down from
        // the watermark's own window (ends are monotone in w).
        let mut w = self.assigner.window_of(gw);
        loop {
            if self.assigner.window_end(w) <= gw {
                return Some(w);
            }
            if w == 0 {
                return None;
            }
            w -= 1;
        }
    }

    /// Algorithm 1 `MERGE`: join windows pointwise and progress by max,
    /// reporting exactly the windows that inflated (trait v3). Only
    /// *changed* windows are marked dirty — genuinely new information
    /// still propagates transitively through sampled gossip, while a
    /// no-op join (a full-sync payload this replica already subsumes)
    /// marks nothing and therefore costs nothing on the next delta
    /// round. Windows whose join would leave them at bottom are not
    /// materialized at all.
    #[must_use = "the report drives receive-path dirty-marking; discard with `let _ =` if unneeded"]
    pub fn merge(&mut self, other: &Self) -> MergeReport {
        let mut report = MergeReport::default();
        for (w, win) in other.windows.iter() {
            if w < self.compacted_below {
                continue; // already finalized and dropped here
            }
            let changed = match self.windows.get_mut(&w) {
                Some(mine) => mine.merge(win).is_changed(),
                None => {
                    let mut fresh = C::default();
                    let inflated = fresh.merge(win).is_changed();
                    if inflated {
                        self.windows.insert(w, fresh);
                    }
                    inflated
                }
            };
            if changed {
                self.dirty.insert(w);
                report.changed_windows.push(w);
            }
        }
        for (&p, &ts) in &other.progress {
            match self.progress.get_mut(&p) {
                Some(e) => {
                    if *e < ts {
                        *e = ts;
                        report.progress_changed = true;
                    }
                }
                None => {
                    self.progress.insert(p, ts);
                    report.progress_changed = true;
                }
            }
        }
        if report.progress_changed {
            self.progress_dirty = true;
        }
        if other.compacted_below > self.compacted_below {
            self.compacted_below = other.compacted_below;
            report.compaction_advanced = true;
        }
        report
    }

    /// Drop windows strictly below `wid` (metadata compaction). Callers
    /// only compact windows they have already emitted. Also advances the
    /// ring base, which is what keeps the dense span anchored to the
    /// live horizon.
    pub fn compact_below(&mut self, wid: WindowId) {
        self.compacted_below = self.compacted_below.max(wid);
        self.windows.compact_below(wid);
    }

    /// Delta-based synchronization (paper §7): a partial replica
    /// carrying only the windows touched since the previous call, plus
    /// the (small) full progress map. Joining a delta is sound because
    /// any sub-state of a CRDT is a valid state — deltas just converge
    /// with less traffic. Clears the dirty set, and drills into each
    /// touched window via [`Crdt::take_delta`] so inner CRDTs with their
    /// own dirty tracking (sharded keyed state) ship only the changed
    /// sub-state.
    pub fn take_delta(&mut self) -> Self {
        let dirty = std::mem::take(&mut self.dirty);
        self.progress_dirty = false;
        let mut windows = WindowRing::new();
        for w in &dirty {
            if let Some(c) = self.windows.get_mut(w) {
                windows.insert(*w, c.take_delta());
            }
        }
        Self {
            assigner: self.assigner,
            windows,
            progress: self.progress.clone(),
            compacted_below: self.compacted_below,
            dirty: Default::default(),
            progress_dirty: false,
        }
    }

    /// Number of windows currently marked dirty (observability, and the
    /// engine's skip-checkpoint-re-encode gate).
    pub fn dirty_windows(&self) -> usize {
        self.dirty.len()
    }

    /// Whether a delta round would ship anything: some window is dirty
    /// or some progress entry was raised since the last drain. The
    /// engine skips encoding/broadcasting the gossip payload entirely
    /// when this is false (and the round is not a full sync).
    pub fn has_delta(&self) -> bool {
        !self.dirty.is_empty() || self.progress_dirty
    }

    /// Drain this replica's delta into `dst` by reference — equivalent
    /// to `dst.merge(&self.take_delta())` with no window clones and no
    /// progress-map clone — reporting whether `dst` inflated. The engine
    /// joins each partition's own contribution accumulator into the node
    /// replica after every batch through this: only the windows the
    /// batch touched are walked (and within them, via
    /// [`Crdt::join_delta_into`], only the changed sub-state), and `dst`
    /// marks exactly the windows that inflated dirty so the next gossip
    /// delta ships them.
    pub fn join_delta_into(&mut self, dst: &mut Self) -> MergeOutcome {
        let mut changed = false;
        for w in std::mem::take(&mut self.dirty) {
            if w < dst.compacted_below {
                continue; // already finalized and dropped there
            }
            if let Some(c) = self.windows.get_mut(&w) {
                let inflated = match dst.windows.get_mut(&w) {
                    Some(d) => c.join_delta_into(d).is_changed(),
                    None => {
                        let mut fresh = C::default();
                        let inflated = c.join_delta_into(&mut fresh).is_changed();
                        if inflated {
                            dst.windows.insert(w, fresh);
                        }
                        inflated
                    }
                };
                if inflated {
                    dst.dirty.insert(w);
                    changed = true;
                }
            }
        }
        let mut progress_changed = false;
        for (&p, &ts) in &self.progress {
            match dst.progress.get_mut(&p) {
                Some(e) => {
                    if *e < ts {
                        *e = ts;
                        progress_changed = true;
                    }
                }
                None => {
                    dst.progress.insert(p, ts);
                    progress_changed = true;
                }
            }
        }
        if progress_changed {
            dst.progress_dirty = true;
            changed = true;
        }
        self.progress_dirty = false;
        if self.compacted_below > dst.compacted_below {
            dst.compacted_below = self.compacted_below;
            changed = true;
        }
        MergeOutcome::changed_if(changed)
    }

    /// Discard the dirty markers without building a delta — used after a
    /// consumer has observed the full state (a full-sync gossip round, a
    /// checkpoint encode). Without this, a replica that never calls
    /// [`take_delta`](Self::take_delta) accumulates dirty ids forever.
    /// Inner dirty markers ([`Crdt::mark_clean`]) are dropped with the
    /// window ids; only dirty windows can hold them (inserts and merges
    /// mark both levels together).
    pub fn mark_clean(&mut self) {
        for w in std::mem::take(&mut self.dirty) {
            if let Some(c) = self.windows.get_mut(&w) {
                c.mark_clean();
            }
        }
        self.progress_dirty = false;
    }

    /// Checkpoint slice: this partition's contributions + its progress
    /// entry (see DESIGN.md — partition state forms a CRDT).
    pub fn project_with(&self, myself: PartitionId, f: impl Fn(&C) -> C) -> Self {
        let windows = self.windows.iter().map(|(w, c)| (w, f(c))).collect();
        let mut progress: BTreeMap<PartitionId, SimTime> =
            self.progress.keys().map(|&p| (p, 0)).collect();
        if let Some(&ts) = self.progress.get(&myself) {
            progress.insert(myself, ts);
        }
        Self {
            assigner: self.assigner,
            windows,
            progress,
            compacted_below: self.compacted_below,
            dirty: Default::default(),
            progress_dirty: false,
        }
    }

    /// Number of live (uncompacted) windows held.
    pub fn live_windows(&self) -> usize {
        self.windows.len()
    }

    /// Ids of the live (uncompacted) windows, ascending. The read path
    /// uses this to seed its signature index from an existing replica.
    pub fn window_ids(&self) -> impl Iterator<Item = WindowId> + '_ {
        self.windows.keys()
    }

    /// Direct read access for tests/benches.
    pub fn raw_window(&self, wid: WindowId) -> Option<&C> {
        self.windows.get(&wid)
    }

    pub fn progress_of(&self, p: PartitionId) -> SimTime {
        self.progress.get(&p).copied().unwrap_or(0)
    }
}

impl<C: Crdt> Encode for WindowedCrdt<C> {
    fn encode(&self, w: &mut Writer) {
        self.assigner.encode(w);
        self.windows.encode(w);
        self.progress.encode(w);
        w.put_u64(self.compacted_below);
    }
}

impl<C: Crdt> Decode for WindowedCrdt<C> {
    fn decode(r: &mut Reader) -> DecodeResult<Self> {
        Ok(Self {
            assigner: WindowAssigner::decode(r)?,
            windows: WindowRing::decode(r)?,
            progress: BTreeMap::decode(r)?,
            compacted_below: r.get_u64()?,
            dirty: Default::default(),
            progress_dirty: false,
        })
    }
}

// lint:allow-tests(discarded-merge): tests join replicas for effect and assert on the resulting window state/bytes
#[cfg(test)]
mod tests {
    use super::*;
    use crate::crdt::GCounter;

    fn wcrdt(parts: &[PartitionId]) -> WindowedCrdt<GCounter> {
        WindowedCrdt::new(WindowAssigner::tumbling(1000), parts.iter().copied())
    }

    /// Only *first local contributions* count as window opens — repeat
    /// inserts into a live window and windows learned via merge do not
    /// — and the thread-local drain resets.
    #[test]
    fn window_opens_drain_counts_first_local_contributions() {
        let _ = take_window_opens(); // isolate from other tests on this thread
        let mut w = wcrdt(&[0, 1]);
        w.insert_with(0, 100, |c| c.add(0, 1)).unwrap(); // opens wid 0
        w.insert_with(0, 200, |c| c.add(0, 1)).unwrap(); // same window: no open
        w.insert_with(0, 2500, |c| c.add(0, 1)).unwrap(); // opens wid 2
        assert_eq!(take_window_opens(), (2, 0, 2));
        assert_eq!(take_window_opens(), (0, WindowId::MAX, 0), "drain resets");
        // windows arriving through merge are the peer's opens, not ours
        let mut other = wcrdt(&[0, 1]);
        other.insert_with(1, 5500, |c| c.add(1, 1)).unwrap();
        let _ = take_window_opens();
        let _ = w.merge(&other);
        assert_eq!(take_window_opens().0, 0);
    }

    #[test]
    fn window_not_readable_until_global_watermark() {
        let mut w = wcrdt(&[0, 1]);
        w.insert_with(0, 100, |c| c.add(0, 1)).unwrap();
        w.increment_watermark(0, 2000);
        // partition 1 still at 0 => window 0 incomplete
        assert_eq!(w.window_value(0), None);
        w.increment_watermark(1, 1000);
        // now global watermark = 1000 = end of window 0
        let v = w.window_value(0).unwrap();
        assert_eq!(v.value(), 1);
    }

    #[test]
    fn late_insert_rejected() {
        let mut w = wcrdt(&[0]);
        w.increment_watermark(0, 500);
        let err = w.insert_with(0, 100, |c| c.add(0, 1)).unwrap_err();
        assert_eq!(
            err,
            WcrdtError::LateInsert {
                ts: 100,
                watermark: 500
            }
        );
    }

    #[test]
    fn empty_completed_window_reads_bottom() {
        let mut w = wcrdt(&[0, 1]);
        w.increment_watermark(0, 3000);
        w.increment_watermark(1, 3000);
        assert_eq!(w.window_value(1).unwrap().value(), 0);
    }

    #[test]
    fn merge_converges_replicas() {
        let mut a = wcrdt(&[0, 1]);
        let mut b = wcrdt(&[0, 1]);
        a.insert_with(0, 10, |c| c.add(0, 5)).unwrap();
        a.increment_watermark(0, 1000);
        b.insert_with(1, 20, |c| c.add(1, 7)).unwrap();
        b.increment_watermark(1, 1000);

        // exchange state both ways — in any order
        let a0 = a.clone();
        let _ = a.merge(&b);
        let _ = b.merge(&a0);
        assert_eq!(a, b);
        assert_eq!(a.window_value(0).unwrap().value(), 12);
    }

    #[test]
    fn merge_is_idempotent_and_commutative() {
        let mut a = wcrdt(&[0, 1]);
        a.insert_with(0, 1, |c| c.add(0, 3)).unwrap();
        let mut b = wcrdt(&[0, 1]);
        b.insert_with(1, 1, |c| c.add(1, 4)).unwrap();

        let mut ab = a.clone();
        let _ = ab.merge(&b);
        let mut ba = b.clone();
        let _ = ba.merge(&a);
        assert_eq!(ab, ba);

        let mut aa = a.clone();
        let report = aa.merge(&a.clone());
        assert_eq!(aa, a);
        // idempotent self-merge reports no change at all
        assert_eq!(report, MergeReport::default());
        assert_eq!(report.outcome(), MergeOutcome::Unchanged);
    }

    #[test]
    fn deterministic_reads_across_replicas() {
        // Two replicas receive contributions in different orders; once
        // the global watermark passes, both read identical values.
        let mut a = wcrdt(&[0, 1, 2]);
        let mut b = wcrdt(&[0, 1, 2]);
        let mut updates = vec![];
        for p in 0..3u32 {
            let mut u = wcrdt(&[0, 1, 2]);
            u.insert_with(p, 50 + p as u64, |c| c.add(p as u64, (p + 1) as u64))
                .unwrap();
            u.increment_watermark(p, 1000);
            updates.push(u);
        }
        // a merges 0,1,2; b merges 2,0,1
        for i in [0, 1, 2] {
            let _ = a.merge(&updates[i]);
        }
        for i in [2, 0, 1] {
            let _ = b.merge(&updates[i]);
        }
        assert_eq!(a.window_value(0), b.window_value(0));
        assert_eq!(a.window_value(0).unwrap().value(), 6);
    }

    #[test]
    fn compaction_drops_old_windows_only() {
        let mut w = wcrdt(&[0]);
        w.insert_with(0, 100, |c| c.add(0, 1)).unwrap();
        w.insert_with(0, 1100, |c| c.add(0, 2)).unwrap();
        w.increment_watermark(0, 5000);
        w.compact_below(1);
        assert_eq!(w.live_windows(), 1);
        assert_eq!(w.window_value(1).unwrap().value(), 2);
        // merging an old replica cannot resurrect window 0
        let mut old = wcrdt(&[0]);
        old.insert_with(0, 100, |c| c.add(0, 9)).unwrap();
        let report = w.merge(&old);
        assert!(report.changed_windows.is_empty());
        assert_eq!(w.live_windows(), 1);
    }

    #[test]
    fn project_keeps_own_progress_only() {
        let mut w = wcrdt(&[0, 1]);
        w.increment_watermark(0, 500);
        w.increment_watermark(1, 700);
        let p = w.project_with(0, |c| c.clone());
        assert_eq!(p.progress_of(0), 500);
        assert_eq!(p.progress_of(1), 0);
    }

    #[test]
    fn codec_roundtrip() {
        use crate::codec::{Decode, Encode};
        let mut w = wcrdt(&[0, 1]);
        w.insert_with(0, 10, |c| c.add(0, 2)).unwrap();
        w.increment_watermark(0, 99);
        let b = w.to_bytes();
        let back = WindowedCrdt::<GCounter>::from_bytes(&b).unwrap();
        assert_eq!(back, w);
    }

    #[test]
    fn take_delta_carries_only_touched_windows() {
        let mut w = wcrdt(&[0, 1]);
        w.insert_with(0, 100, |c| c.add(0, 1)).unwrap();
        w.insert_with(0, 1100, |c| c.add(0, 2)).unwrap();
        let _ = w.take_delta(); // drain
        w.insert_with(0, 1200, |c| c.add(0, 3)).unwrap();
        w.increment_watermark(0, 1200);
        let d = w.take_delta();
        assert_eq!(d.live_windows(), 1); // only window 1 was touched
        assert_eq!(d.progress_of(0), 1200); // progress always included
        assert_eq!(w.dirty_windows(), 0);
    }

    #[test]
    fn join_delta_into_equals_merge_of_take_delta() {
        // the engine's per-batch own→replica drain must land dst in the
        // same state (value AND dirty markers) as merging a take_delta
        let build_src = || {
            let mut s = wcrdt(&[0, 1]);
            s.insert_with(0, 100, |c| c.add(0, 5)).unwrap();
            s.insert_with(0, 1200, |c| c.add(0, 2)).unwrap();
            s.increment_watermark(0, 1500);
            s
        };
        let mut src_a = build_src();
        let mut src_b = build_src();
        let mut dst_a = wcrdt(&[0, 1]);
        dst_a.insert_with(1, 150, |c| c.add(1, 7)).unwrap();
        dst_a.increment_watermark(1, 1500);
        let mut dst_b = dst_a.clone(); // clone() carries the dirty set too

        let oc_a = src_a.join_delta_into(&mut dst_a);
        let oc_b = dst_b.merge(&src_b.take_delta()).outcome();
        assert_eq!(dst_a, dst_b);
        assert_eq!(oc_a, oc_b, "both drain shapes report the same outcome");
        assert_eq!(dst_a.dirty, dst_b.dirty, "drain must mark the same windows");
        assert_eq!(src_a.dirty_windows(), 0, "drain clears the source markers");
        assert_eq!(dst_a.window_value(0).unwrap().value(), 12);
        assert_eq!(dst_a.progress_of(0), 1500);
    }

    #[test]
    fn noop_full_sync_merge_leaves_the_delta_empty() {
        // The amplification fix: merging a received full-sync payload
        // the replica already subsumes must not re-mark windows dirty —
        // pre-v3, every received window was marked and the next delta
        // round re-shipped ~full state.
        let build = || {
            let mut w = wcrdt(&[0, 1]);
            w.insert_with(0, 100, |c| c.add(0, 5)).unwrap();
            w.insert_with(0, 1200, |c| c.add(0, 2)).unwrap();
            w.increment_watermark(0, 1500);
            w
        };
        let mut replica = build();
        let _ = replica.take_delta(); // markers drained (delta shipped)
        assert!(!replica.has_delta());
        let report = replica.merge(&build()); // identical remote full state
        assert_eq!(report, MergeReport::default(), "no-op join: {report:?}");
        assert_eq!(replica.dirty_windows(), 0);
        assert!(!replica.has_delta(), "nothing to gossip after a no-op join");
        assert_eq!(replica.take_delta().live_windows(), 0);
        // a genuinely new contribution still propagates transitively
        let mut remote = build();
        remote.insert_with(1, 300, |c| c.add(1, 7)).unwrap();
        let report = replica.merge(&remote);
        assert_eq!(report.changed_windows, vec![0]);
        assert!(replica.has_delta());
    }

    #[test]
    fn watermark_movement_alone_still_has_a_delta() {
        // Progress must keep flowing through delta rounds even when no
        // window was touched (a filter-heavy batch advances watermarks
        // without inserting): has_delta reflects progress movement, and
        // merging newer progress marks the receiver's own progress
        // dirty so watermarks also propagate transitively.
        let mut w = wcrdt(&[0, 1]);
        let _ = w.take_delta();
        assert!(!w.has_delta());
        w.increment_watermark(0, 500);
        assert!(w.has_delta(), "raised watermark is gossip-worthy");
        let d = w.take_delta();
        assert_eq!(d.live_windows(), 0);
        assert_eq!(d.progress_of(0), 500);
        assert!(!w.has_delta());
        // receiving newer progress re-arms the receiver's delta
        let mut peer = wcrdt(&[0, 1]);
        let _ = peer.take_delta();
        let report = peer.merge(&d);
        assert!(report.progress_changed);
        assert!(peer.has_delta());
        // receiving the same progress again does not
        let mut settled = peer.clone();
        let _ = settled.take_delta();
        let report = settled.merge(&d);
        assert!(!report.progress_changed);
        assert!(!settled.has_delta());
    }

    #[test]
    fn merge_report_lists_exactly_the_inflated_windows() {
        let mut a = wcrdt(&[0, 1]);
        a.insert_with(0, 100, |c| c.add(0, 5)).unwrap(); // window 0
        a.insert_with(0, 2500, |c| c.add(0, 1)).unwrap(); // window 2
        let mut b = a.clone();
        b.insert_with(1, 2600, |c| c.add(1, 9)).unwrap(); // window 2 only
        let report = a.merge(&b);
        assert_eq!(report.changed_windows, vec![2]);
        assert!(!report.progress_changed);
        assert_eq!(report.outcome(), MergeOutcome::Changed);
    }

    #[test]
    fn mark_clean_resets_dirty_without_losing_state() {
        let mut w = wcrdt(&[0]);
        w.insert_with(0, 100, |c| c.add(0, 1)).unwrap();
        assert_eq!(w.dirty_windows(), 1);
        let before = w.clone();
        w.mark_clean();
        assert_eq!(w.dirty_windows(), 0);
        assert_eq!(w, before); // dirty is metadata, not state
        // the next delta after mark_clean is empty-windowed
        assert_eq!(w.take_delta().live_windows(), 0);
    }

    #[test]
    fn delta_sync_converges_like_full_sync() {
        let mut a = wcrdt(&[0, 1]);
        let mut b = wcrdt(&[0, 1]);
        a.insert_with(0, 100, |c| c.add(0, 5)).unwrap();
        a.increment_watermark(0, 1500);
        b.insert_with(1, 200, |c| c.add(1, 7)).unwrap();
        b.increment_watermark(1, 1500);
        // exchange deltas instead of full state
        let da = a.take_delta();
        let db = b.take_delta();
        let _ = a.merge(&db);
        let _ = b.merge(&da);
        assert_eq!(a, b);
        assert_eq!(a.window_value(0).unwrap().value(), 12);
        // merging a delta marks windows dirty => transitive propagation
        assert!(a.dirty_windows() > 0);
    }

    #[test]
    fn increment_watermark_rejects_regression() {
        // A stale replay may call increment with an older timestamp;
        // progress must be monotone (max-merge, never assignment).
        let mut w = wcrdt(&[0]);
        w.increment_watermark(0, 700);
        w.increment_watermark(0, 300);
        assert_eq!(w.progress_of(0), 700);
        // merging an older replica cannot regress progress either
        let mut old = wcrdt(&[0]);
        old.increment_watermark(0, 100);
        let report = w.merge(&old);
        assert!(!report.progress_changed);
        assert_eq!(w.progress_of(0), 700);
        assert_eq!(w.global_watermark(), 700);
    }

    #[test]
    fn window_closes_exactly_at_boundary_watermark() {
        // Window 0 covers [0, 1000); it completes exactly when the
        // global watermark *equals* 1000 — not at 999, and an event at
        // ts=1000 belongs to window 1, never to the just-closed window.
        let mut w = wcrdt(&[0, 1]);
        w.insert_with(0, 999, |c| c.add(0, 1)).unwrap();
        w.increment_watermark(0, 999);
        w.increment_watermark(1, 999);
        assert!(!w.is_complete(0));
        assert_eq!(w.window_value(0), None);

        w.insert_with(0, 1000, |c| c.add(0, 5)).unwrap(); // next window
        w.increment_watermark(0, 1000);
        w.increment_watermark(1, 1000);
        assert!(w.is_complete(0));
        assert_eq!(w.window_value(0).unwrap().value(), 1); // 1000-event excluded
        assert!(!w.is_complete(1));
        assert_eq!(w.completed_up_to(), Some(0));
    }

    #[test]
    fn fire_order_is_sequential_at_shared_boundaries() {
        // When one watermark jump completes several windows at once
        // (restart catch-up), the drain fires them strictly in order
        // with no skips — including empty windows in the middle.
        use crate::api::drain_completed;
        let mut w = wcrdt(&[0]);
        w.insert_with(0, 100, |c| c.add(0, 1)).unwrap();
        w.insert_with(0, 2100, |c| c.add(0, 3)).unwrap(); // window 1 empty
        w.increment_watermark(0, 3000); // completes windows 0,1,2 at once
        let mut cursor = 0;
        let mut fired = Vec::new();
        drain_completed(&w, &mut cursor, |wid, c: GCounter| fired.push((wid, c.value())));
        assert_eq!(fired, vec![(0, 1), (1, 0), (2, 3)]);
        assert_eq!(cursor, 3);
        // watermark exactly on the next boundary: window 3 now complete
        w.increment_watermark(0, 4000);
        drain_completed(&w, &mut cursor, |wid, c: GCounter| fired.push((wid, c.value())));
        assert_eq!(fired.last(), Some(&(3, 0)));
    }

    #[test]
    fn late_insert_boundary_is_exact() {
        // Inserting exactly *at* the own watermark is allowed (Algorithm
        // 1 rejects strictly-below only); one tick below errors.
        let mut w = wcrdt(&[0]);
        w.increment_watermark(0, 500);
        assert!(w.insert_with(0, 500, |c| c.add(0, 1)).is_ok());
        assert_eq!(
            w.insert_with(0, 499, |c| c.add(0, 1)),
            Err(WcrdtError::LateInsert {
                ts: 499,
                watermark: 500
            })
        );
    }

    #[test]
    fn global_watermark_is_min() {
        let mut w = wcrdt(&[0, 1, 2]);
        w.increment_watermark(0, 100);
        w.increment_watermark(1, 50);
        w.increment_watermark(2, 200);
        assert_eq!(w.global_watermark(), 50);
    }
}
