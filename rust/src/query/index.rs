//! Signature-index pre-filter for the read path.
//!
//! One [`WindowSig`] per live window: a 64-bit two-probe Bloom filter
//! over key fingerprints plus a 64-bit shard-occupancy bitset. Both
//! halves are monotone under bit-or — exactly like the CRDT state they
//! summarize — so a signature maintained across merges never un-learns
//! a key. The index can answer "definitely absent" (prune the lookup or
//! a whole shard) or "maybe present" (validate against state); it can
//! never drop a matching key. Zero false negatives is property-tested
//! in `tests/query_read_path.rs`.
//!
//! Signatures are maintained incrementally by
//! [`QueryEngine::ingest`](crate::query::QueryEngine::ingest): after a
//! merge, only the windows named in the
//! [`MergeReport`](crate::wcrdt::MergeReport) changed-set are re-signed
//! from the replica's own post-merge state. Signing our own state (not
//! the incoming payload) keeps the shard bitset correct even when a
//! payload arrives with a different shard layout and the merge rehashes
//! its keys.

use crate::codec::Encode;
use crate::wcrdt::{WindowId, WindowRing};

/// 64-bit fingerprint of an encodable key: FNV-1a over the key's
/// encoded bytes, with a final avalanche mix so the Bloom probes (low
/// bit slices) differ even for short sequential keys.
pub fn fingerprint<K: Encode>(key: &K) -> u64 {
    fingerprint_bytes(&key.to_bytes())
}

/// [`fingerprint`] over pre-encoded key bytes.
pub fn fingerprint_bytes(bytes: &[u8]) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for &b in bytes {
        h ^= u64::from(b);
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    // avalanche (splitmix-style): FNV alone leaves short keys clustered
    // in the low bits, which is where the Bloom probes look
    h ^= h >> 33;
    h = h.wrapping_mul(0xff51_afd7_ed55_8ccd);
    h ^= h >> 33;
    h
}

/// Compact signature of one window's keyed state.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct WindowSig {
    /// Two-probe Bloom filter over key fingerprints.
    keys: u64,
    /// Occupancy bitset over shard indices (`shard % 64`); bit 0 doubles
    /// as the "has any data" bit for flat (unsharded) state.
    shards: u64,
}

impl WindowSig {
    /// The two Bloom probe positions of a fingerprint.
    fn key_mask(fp: u64) -> u64 {
        (1u64 << (fp & 63)) | (1u64 << ((fp >> 6) & 63))
    }

    /// Record a key fingerprint.
    pub fn note_key(&mut self, fp: u64) {
        self.keys |= Self::key_mask(fp);
    }

    /// Whether a key with this fingerprint may be present. `false` is
    /// definitive (prune); `true` requires validation against state.
    pub fn may_contain_key(&self, fp: u64) -> bool {
        let m = Self::key_mask(fp);
        self.keys & m == m
    }

    /// Record an occupied shard index.
    pub fn note_shard(&mut self, shard: usize) {
        self.shards |= 1u64 << (shard & 63);
    }

    /// Whether the shard may hold data for this window. With ≤ 64 shards
    /// the bitset is exact; beyond that it aliases (still no false
    /// negatives).
    pub fn may_contain_shard(&self, shard: usize) -> bool {
        self.shards & (1u64 << (shard & 63)) != 0
    }

    /// Nothing was ever signed into this window.
    pub fn is_empty(&self) -> bool {
        self.keys == 0 && self.shards == 0
    }

    /// Fold another signature in (bit-or; monotone like the state).
    pub fn merge(&mut self, other: &WindowSig) {
        self.keys |= other.keys;
        self.shards |= other.shards;
    }

    /// Bloom occupancy (set bits out of 64) — a saturation diagnostic:
    /// at 64 the filter prunes nothing.
    pub fn key_bits(&self) -> u32 {
        self.keys.count_ones()
    }
}

/// Per-window signatures of a replica's keyed state. Window-indexed
/// like the state it summarizes, so it uses the same O(1)
/// [`WindowRing`] store (signatures live exactly over the compaction
/// horizon).
#[derive(Debug, Clone, Default)]
pub struct SignatureIndex {
    windows: WindowRing<WindowSig>,
}

impl SignatureIndex {
    pub fn new() -> Self {
        Self::default()
    }

    /// The signature of a window, if anything was ever signed into it.
    /// `None` means the window verifiably holds no data (prune).
    pub fn sig(&self, wid: WindowId) -> Option<&WindowSig> {
        self.windows.get(&wid)
    }

    /// The signature of a window, created empty on first touch.
    pub fn sig_mut(&mut self, wid: WindowId) -> &mut WindowSig {
        self.windows.entry_or_insert_with(wid, WindowSig::default)
    }

    /// Whether `wid` may contain a key with fingerprint `fp`.
    pub fn may_contain(&self, wid: WindowId, fp: u64) -> bool {
        self.windows.get(&wid).is_some_and(|s| s.may_contain_key(fp))
    }

    /// Drop signatures below `first` (mirrors window compaction — a
    /// compacted window must not look "verifiably empty but queryable").
    pub fn retain_from(&mut self, first: WindowId) {
        self.windows.compact_below(first);
    }

    /// Number of signed windows.
    pub fn len(&self) -> usize {
        self.windows.len()
    }

    pub fn is_empty(&self) -> bool {
        self.windows.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn noted_keys_are_always_contained() {
        let mut sig = WindowSig::default();
        for k in 0u64..1000 {
            let fp = fingerprint(&k);
            sig.note_key(fp);
            assert!(sig.may_contain_key(fp), "false negative for key {k}");
        }
        // after 1000 keys a 64-bit Bloom is saturated — still no false
        // negatives, just no pruning power
        for k in 0u64..1000 {
            assert!(sig.may_contain_key(fingerprint(&k)));
        }
    }

    #[test]
    fn sparse_signature_prunes_absent_keys() {
        let mut sig = WindowSig::default();
        for k in 0u64..4 {
            sig.note_key(fingerprint(&k));
        }
        // with 4 keys (≤ 8 set bits of 64) most absent keys must be
        // pruned — quantifies the filter actually filters
        let pruned = (1000u64..2000)
            .filter(|k| !sig.may_contain_key(fingerprint(k)))
            .count();
        assert!(pruned > 800, "only {pruned}/1000 absent keys pruned");
    }

    #[test]
    fn fingerprints_of_sequential_keys_spread() {
        // the avalanche mix must keep low-bit slices distinct for the
        // sequential integer keys real workloads use
        let mut seen = std::collections::BTreeSet::new();
        for k in 0u64..64 {
            seen.insert(fingerprint(&k) & 63);
        }
        assert!(seen.len() > 32, "low probe bits collapsed: {}", seen.len());
    }

    #[test]
    fn shard_bits_are_exact_up_to_64() {
        let mut sig = WindowSig::default();
        sig.note_shard(0);
        sig.note_shard(7);
        assert!(sig.may_contain_shard(0));
        assert!(sig.may_contain_shard(7));
        assert!(!sig.may_contain_shard(1));
        // beyond 64 the bitset aliases — never a false negative
        sig.note_shard(65);
        assert!(sig.may_contain_shard(65));
        assert!(sig.may_contain_shard(1), "aliased bit must stay conservative");
    }

    #[test]
    fn merge_is_monotone() {
        let mut a = WindowSig::default();
        let mut b = WindowSig::default();
        a.note_key(fingerprint(&1u64));
        b.note_key(fingerprint(&2u64));
        b.note_shard(3);
        a.merge(&b);
        assert!(a.may_contain_key(fingerprint(&1u64)));
        assert!(a.may_contain_key(fingerprint(&2u64)));
        assert!(a.may_contain_shard(3));
    }

    #[test]
    fn index_retain_from_mirrors_compaction() {
        let mut idx = SignatureIndex::new();
        for w in 0..8u64 {
            idx.sig_mut(w).note_key(fingerprint(&w));
        }
        idx.retain_from(5);
        assert_eq!(idx.len(), 3);
        assert!(idx.sig(4).is_none());
        assert!(idx.sig(5).is_some());
        assert!(!idx.may_contain(4, fingerprint(&4u64)));
    }

    #[test]
    fn absent_window_is_definitively_empty() {
        let idx = SignatureIndex::new();
        assert!(!idx.may_contain(0, fingerprint(&0u64)));
        assert!(idx.sig(0).is_none());
    }
}
