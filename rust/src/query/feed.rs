//! Changefeed: subscription surface over the delta stream a node
//! already produces for gossip.
//!
//! The write path publishes every outbound state payload (full-state on
//! full-sync rounds, `take_delta()` encodes otherwise) into a
//! [`ReadHandle`] — the same `Arc<Vec<u8>>` handed to the bus, so
//! serving subscribers costs one Arc clone per item, not a re-encode.
//! Each item gets a monotonically increasing cursor. Subscribers pull
//! with [`Subscription::poll`]; delivery is exactly-once per cursor per
//! subscription, and a dropped subscriber resumes from its saved cursor
//! via [`ReadHandle::subscribe_at`].
//!
//! Retention is bounded (a ring of the last N items). A subscriber that
//! falls behind the ring gets [`FeedGap`] — the feed analogue of window
//! compaction's `first_available()` — and must re-bootstrap from
//! [`ReadHandle::snapshot`], which carries the cursor to resume from.

use std::collections::VecDeque;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex, Weak};

use crate::util::{LockExt, SimTime};

/// Default ring retention (items) — the *floor*: deployments size the
/// ring from the gossip config via
/// `engine::effective_changefeed_retention` (override:
/// `changefeed_retention` config key), which derives
/// `FULL_SYNC_EVERY × fanout` rounds of slack with headroom and never
/// goes below this value. A subscriber polling at gossip cadence never
/// gaps, even when a batched flush delivers a burst of rounds at once.
pub const DEFAULT_RETENTION: usize = 256;

/// One published state payload.
#[derive(Debug, Clone)]
pub struct FeedItem {
    /// Position in the feed; consecutive, starting at 0.
    pub cursor: u64,
    /// Publisher's watermark floor when the payload was produced.
    pub watermark: SimTime,
    /// `true` when `payload` is a full state encode (safe bootstrap
    /// point), `false` for a delta.
    pub full: bool,
    /// Encoded `WindowedCrdt` state or delta — shared with the gossip
    /// path, never copied per subscriber.
    pub payload: Arc<Vec<u8>>,
}

/// Bootstrap snapshot: the most recent full-state payload plus the
/// cursor a fresh subscriber should resume the delta stream from.
#[derive(Debug, Clone)]
pub struct StateSnapshot {
    pub bytes: Arc<Vec<u8>>,
    /// First cursor NOT covered by `bytes` — pass to `subscribe_at`.
    pub cursor: u64,
    pub watermark: SimTime,
}

/// A subscriber fell behind retention: `requested` is its cursor,
/// `oldest_available` the oldest still in the ring. Re-bootstrap from
/// the snapshot.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct FeedGap {
    pub requested: u64,
    pub oldest_available: u64,
}

struct HandleInner {
    snapshot: Option<StateSnapshot>,
    /// Cursor the next published item will receive.
    next_cursor: u64,
    ring: VecDeque<FeedItem>,
    retention: usize,
    /// Live subscriber cursors, for lag accounting only.
    subscribers: Vec<Weak<AtomicU64>>,
}

impl HandleInner {
    fn oldest_retained(&self) -> u64 {
        self.next_cursor - self.ring.len() as u64
    }

    fn push(&mut self, item: FeedItem) {
        self.ring.push_back(item);
        while self.ring.len() > self.retention {
            self.ring.pop_front();
        }
        self.next_cursor += 1;
    }
}

/// Per-node publication point for the changefeed. Cloned into the node
/// loop (publisher) and held by the cluster (readers); cheap to clone.
#[derive(Clone)]
pub struct ReadHandle {
    inner: Arc<Mutex<HandleInner>>,
}

impl Default for ReadHandle {
    fn default() -> Self {
        Self::new()
    }
}

impl ReadHandle {
    pub fn new() -> Self {
        Self::with_retention(DEFAULT_RETENTION)
    }

    pub fn with_retention(retention: usize) -> Self {
        assert!(retention > 0);
        ReadHandle {
            inner: Arc::new(Mutex::new(HandleInner {
                snapshot: None,
                next_cursor: 0,
                ring: VecDeque::new(),
                retention,
                subscribers: Vec::new(),
            })),
        }
    }

    /// Publish a full-state payload: appended to the feed AND installed
    /// as the bootstrap snapshot. Returns the item's cursor.
    pub fn publish_full(&self, payload: Arc<Vec<u8>>, watermark: SimTime) -> u64 {
        let mut inner = self.inner.plane_lock();
        let cursor = inner.next_cursor;
        inner.push(FeedItem {
            cursor,
            watermark,
            full: true,
            payload: Arc::clone(&payload),
        });
        inner.snapshot = Some(StateSnapshot {
            bytes: payload,
            cursor: cursor + 1,
            watermark,
        });
        cursor
    }

    /// Publish a delta payload. Returns the item's cursor.
    pub fn publish_delta(&self, payload: Arc<Vec<u8>>, watermark: SimTime) -> u64 {
        let mut inner = self.inner.plane_lock();
        let cursor = inner.next_cursor;
        inner.push(FeedItem {
            cursor,
            watermark,
            full: false,
            payload,
        });
        cursor
    }

    /// Latest bootstrap snapshot, if any full state was published yet.
    pub fn snapshot(&self) -> Option<StateSnapshot> {
        self.inner.plane_lock().snapshot.clone()
    }

    /// Subscribe from the live tail (items published after this call).
    pub fn subscribe(&self) -> Subscription {
        let at = self.inner.plane_lock().next_cursor;
        self.subscribe_at(at)
    }

    /// Subscribe from an explicit cursor (resume). If the cursor has
    /// fallen out of retention the first `poll` reports [`FeedGap`].
    pub fn subscribe_at(&self, cursor: u64) -> Subscription {
        let cur = Arc::new(AtomicU64::new(cursor));
        let mut inner = self.inner.plane_lock();
        inner.subscribers.push(Arc::downgrade(&cur));
        Subscription {
            inner: Arc::clone(&self.inner),
            cursor: cur,
        }
    }

    /// Cursor the next published item will receive.
    pub fn latest_cursor(&self) -> u64 {
        self.inner.plane_lock().next_cursor
    }

    /// Oldest cursor still retained in the ring.
    pub fn oldest_retained(&self) -> u64 {
        self.inner.plane_lock().oldest_retained()
    }

    /// Items the slowest live subscriber is behind the head (0 when no
    /// subscribers). Dead subscriptions are pruned here.
    pub fn max_lag(&self) -> u64 {
        let mut inner = self.inner.plane_lock();
        let head = inner.next_cursor;
        let mut lag = 0u64;
        inner.subscribers.retain(|w| match w.upgrade() {
            Some(cur) => {
                lag = lag.max(head.saturating_sub(cur.load(Ordering::Relaxed)));
                true
            }
            None => false,
        });
        lag
    }
}

/// A pull-model changefeed subscription. Not `Clone`: the cursor is the
/// delivery state, and sharing it would break exactly-once.
pub struct Subscription {
    inner: Arc<Mutex<HandleInner>>,
    cursor: Arc<AtomicU64>,
}

impl Subscription {
    /// Next cursor this subscription will read — save it to resume
    /// later via [`ReadHandle::subscribe_at`].
    pub fn cursor(&self) -> u64 {
        self.cursor.load(Ordering::Relaxed)
    }

    /// Pull up to `max` items past the cursor. Advances the cursor by
    /// the number returned — each cursor is delivered exactly once per
    /// subscription. Returns [`FeedGap`] if the cursor fell behind
    /// retention (cursor is NOT advanced; re-bootstrap via snapshot).
    pub fn poll(&mut self, max: usize) -> Result<Vec<FeedItem>, FeedGap> {
        let inner = self.inner.plane_lock();
        let want = self.cursor.load(Ordering::Relaxed);
        let oldest = inner.oldest_retained();
        if want < oldest {
            return Err(FeedGap {
                requested: want,
                oldest_available: oldest,
            });
        }
        let skip = (want - oldest) as usize;
        let items: Vec<FeedItem> = inner.ring.iter().skip(skip).take(max).cloned().collect();
        self.cursor
            .store(want + items.len() as u64, Ordering::Relaxed);
        Ok(items)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn payload(n: u8) -> Arc<Vec<u8>> {
        Arc::new(vec![n; 4])
    }

    #[test]
    fn poll_is_exactly_once_and_in_order() {
        let h = ReadHandle::new();
        let mut sub = h.subscribe();
        h.publish_full(payload(0), 0);
        h.publish_delta(payload(1), 100);
        h.publish_delta(payload(2), 200);
        let items = sub.poll(10).unwrap();
        assert_eq!(items.iter().map(|i| i.cursor).collect::<Vec<_>>(), [0, 1, 2]);
        assert!(items[0].full && !items[1].full);
        // nothing new: empty, not a re-delivery
        assert!(sub.poll(10).unwrap().is_empty());
        h.publish_delta(payload(3), 300);
        assert_eq!(sub.poll(10).unwrap()[0].cursor, 3);
    }

    #[test]
    fn poll_respects_max() {
        let h = ReadHandle::new();
        let mut sub = h.subscribe();
        for i in 0..5 {
            h.publish_delta(payload(i), 0);
        }
        assert_eq!(sub.poll(2).unwrap().len(), 2);
        assert_eq!(sub.poll(2).unwrap()[0].cursor, 2);
        assert_eq!(sub.poll(10).unwrap().len(), 1);
    }

    #[test]
    fn cursor_resume_continues_where_dropped() {
        let h = ReadHandle::new();
        let mut sub = h.subscribe();
        h.publish_delta(payload(0), 0);
        h.publish_delta(payload(1), 0);
        sub.poll(1).unwrap();
        let saved = sub.cursor();
        drop(sub);
        let mut resumed = h.subscribe_at(saved);
        let items = resumed.poll(10).unwrap();
        assert_eq!(items.iter().map(|i| i.cursor).collect::<Vec<_>>(), [1]);
    }

    #[test]
    fn laggard_behind_retention_gets_gap_then_rebootstraps() {
        let h = ReadHandle::with_retention(4);
        let mut sub = h.subscribe();
        for i in 0..10u8 {
            h.publish_full(payload(i), u64::from(i) * 100);
        }
        let gap = sub.poll(10).unwrap_err();
        assert_eq!(gap, FeedGap { requested: 0, oldest_available: 6 });
        // the documented recovery: snapshot + subscribe_at(snapshot.cursor)
        let snap = h.snapshot().unwrap();
        assert_eq!(snap.cursor, 10);
        assert_eq!(snap.bytes.as_slice(), &[9; 4]);
        let mut fresh = h.subscribe_at(snap.cursor);
        assert!(fresh.poll(10).unwrap().is_empty());
        h.publish_delta(payload(42), 1000);
        assert_eq!(fresh.poll(10).unwrap()[0].cursor, 10);
    }

    /// Regression (changefeed gap storms): a batched flush can publish a
    /// burst of up to retention items between two polls of a live
    /// subscriber. That must be the boundary case that still succeeds —
    /// the subscriber's cursor lands exactly on `oldest_retained`, so it
    /// reads every item with zero loss. One more item and it gaps; the
    /// retention derivation exists to keep real bursts at or under the
    /// ring size.
    #[test]
    fn burst_of_exactly_retention_items_does_not_gap_a_live_poller() {
        let h = ReadHandle::with_retention(4);
        h.publish_full(payload(9), 0);
        let mut sub = h.subscribe();
        assert!(sub.poll(10).unwrap().is_empty()); // live at the tail
        // the burst: exactly `retention` items while the poller is away
        for i in 0..4u8 {
            h.publish_delta(payload(i), u64::from(i));
        }
        let items = sub.poll(10).expect("exactly-retention burst must not gap");
        assert_eq!(
            items.iter().map(|i| i.cursor).collect::<Vec<_>>(),
            [1, 2, 3, 4],
            "every burst item delivered, none lost"
        );
        // retention + 1 is the first burst size that gaps
        let mut lag = h.subscribe();
        for i in 0..5u8 {
            h.publish_delta(payload(i), 0);
        }
        let gap = lag.poll(10).unwrap_err();
        assert_eq!(gap.oldest_available, gap.requested + 1);
    }

    #[test]
    fn snapshot_cursor_skips_the_snapshot_item() {
        let h = ReadHandle::new();
        h.publish_delta(payload(0), 0);
        h.publish_full(payload(1), 100);
        let snap = h.snapshot().unwrap();
        // snapshot covers cursor 1; resume stream at 2
        assert_eq!(snap.cursor, 2);
        let mut sub = h.subscribe_at(snap.cursor);
        h.publish_delta(payload(2), 200);
        assert_eq!(sub.poll(10).unwrap()[0].cursor, 2);
    }

    #[test]
    fn max_lag_tracks_slowest_live_subscriber() {
        let h = ReadHandle::new();
        assert_eq!(h.max_lag(), 0);
        let mut fast = h.subscribe();
        let slow = h.subscribe();
        for i in 0..6 {
            h.publish_delta(payload(i), 0);
        }
        fast.poll(10).unwrap();
        assert_eq!(h.max_lag(), 6); // slow hasn't polled
        drop(slow);
        assert_eq!(h.max_lag(), 0); // dead subscriber pruned
        let _keep = fast;
    }

    #[test]
    fn no_snapshot_before_first_full_publish() {
        let h = ReadHandle::new();
        h.publish_delta(payload(0), 0);
        assert!(h.snapshot().is_none());
    }
}
