//! Queryable replicated state — the read path.
//!
//! The write path converges replicas; this module serves reads off any
//! of them without coordination. A [`QueryEngine`] wraps a
//! [`WindowedCrdt`] replica and answers point lookups, inclusive range
//! scans, and top-k scans over keyed windows (flat [`MapCrdt`] or
//! [`ShardedMapCrdt`]), each under a caller-declared **staleness
//! bound**: the query succeeds only if the window's end is within
//! `staleness_ms` of the replica's global watermark. `staleness == 0`
//! demands a *final* value — exactly [`WindowedCrdt::is_complete`],
//! with the same exact-boundary semantics as the allowed-lateness check
//! in `wcrdt/watermark.rs`: a watermark that just reached the window
//! end satisfies the bound, one ms short does not.
//!
//! Reads are pre-filtered through a [`SignatureIndex`](index): per
//! window, a Bloom filter over key fingerprints plus a shard-occupancy
//! bitset, maintained incrementally from the
//! [`MergeReport`](crate::wcrdt::MergeReport) changed-window sets the
//! merge path already computes. The index yields candidate shards/keys
//! for cheap validation; it can prune ("definitely absent") but never
//! lie ("maybe present" is always validated), so query results are
//! identical with and without it — only the scanned-row count differs.
//!
//! State flows in through the changefeed ([`feed`]): the engine
//! bootstraps from a [`StateSnapshot`] and then applies the same
//! full/delta payloads the node gossips, tracked by cursor so restarts
//! resume without loss or double-apply.

pub mod feed;
pub mod index;

pub use feed::{FeedGap, FeedItem, ReadHandle, StateSnapshot, Subscription};
pub use index::{fingerprint, SignatureIndex, WindowSig};

use crate::codec::{Decode, DecodeResult, Encode};
use crate::crdt::{Crdt, GCounter, MapCrdt, PrefixAgg};
use crate::shard::ShardedMapCrdt;
use crate::util::SimTime;
use crate::wcrdt::{MergeReport, WindowId, WindowedCrdt};

/// Keyed per-window state the query scanner understands. Implemented by
/// the flat [`MapCrdt`] and the [`ShardedMapCrdt`]; both scan
/// allocation-free (asserted in `benches/micro_hotpath.rs`).
pub trait KeyedWindowState {
    type Key: Ord + Clone + Encode;
    type Value: Clone;

    /// Point lookup within this window's state.
    fn get_value(&self, key: &Self::Key) -> Option<&Self::Value>;

    /// Total rows (keys) in this window's state.
    fn key_count(&self) -> usize;

    /// Visit every `(key, value)` row. Order is unspecified.
    fn for_each(&self, f: impl FnMut(&Self::Key, &Self::Value));

    /// Record this state's keys and shard occupancy into a signature.
    fn sign_into(&self, sig: &mut WindowSig);

    /// Visit rows, skipping whole shards the signature proves empty.
    /// Returns the number of rows skipped (the pre-filter's win).
    fn for_each_filtered(&self, sig: &WindowSig, f: impl FnMut(&Self::Key, &Self::Value)) -> u64;

    /// The shard `key` routes to, when sharded and materialized.
    fn shard_of_key(&self, key: &Self::Key) -> Option<usize>;
}

impl<K, C> KeyedWindowState for MapCrdt<K, C>
where
    K: Ord + Clone + Encode,
    C: Crdt,
{
    type Key = K;
    type Value = C;

    fn get_value(&self, key: &K) -> Option<&C> {
        self.get(key)
    }

    fn key_count(&self) -> usize {
        self.len()
    }

    fn for_each(&self, mut f: impl FnMut(&K, &C)) {
        for (k, v) in self.iter() {
            f(k, v);
        }
    }

    fn sign_into(&self, sig: &mut WindowSig) {
        // flat state is "shard 0": the bitset's bit 0 is its has-data bit
        if !self.is_empty() {
            sig.note_shard(0);
        }
        for (k, _) in self.iter() {
            sig.note_key(fingerprint(k));
        }
    }

    fn for_each_filtered(&self, sig: &WindowSig, f: impl FnMut(&K, &C)) -> u64 {
        if !sig.may_contain_shard(0) {
            return self.len() as u64;
        }
        self.for_each(f);
        0
    }

    fn shard_of_key(&self, _key: &K) -> Option<usize> {
        None
    }
}

impl<K, C> KeyedWindowState for ShardedMapCrdt<K, C>
where
    K: Ord + Clone + Encode,
    C: Crdt,
{
    type Key = K;
    type Value = C;

    fn get_value(&self, key: &K) -> Option<&C> {
        self.get(key)
    }

    fn key_count(&self) -> usize {
        self.len()
    }

    fn for_each(&self, mut f: impl FnMut(&K, &C)) {
        for (k, v) in self.entries() {
            f(k, v);
        }
    }

    fn sign_into(&self, sig: &mut WindowSig) {
        for (si, shard) in self.shards().iter().enumerate() {
            if shard.is_empty() {
                continue;
            }
            sig.note_shard(si);
            for (k, _) in shard.iter() {
                sig.note_key(fingerprint(k));
            }
        }
    }

    fn for_each_filtered(&self, sig: &WindowSig, mut f: impl FnMut(&K, &C)) -> u64 {
        let mut avoided = 0u64;
        for (si, shard) in self.shards().iter().enumerate() {
            if !sig.may_contain_shard(si) {
                avoided += shard.len() as u64;
                continue;
            }
            for (k, v) in shard.iter() {
                f(k, v);
            }
        }
        avoided
    }

    fn shard_of_key(&self, key: &K) -> Option<usize> {
        self.shard_index(key)
    }
}

/// Ranking for top-k scans — "bigger is hotter".
pub trait Rank {
    fn rank(&self) -> f64;
}

impl Rank for GCounter {
    fn rank(&self) -> f64 {
        self.value() as f64
    }
}

impl Rank for PrefixAgg {
    fn rank(&self) -> f64 {
        self.sum()
    }
}

/// Read-path counters, folded into
/// [`ClusterMetrics`](crate::engine::ClusterMetrics) by the harnesses.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct QueryStats {
    /// Queries answered (Ok results; staleness rejections don't count).
    pub served: u64,
    /// Queries where the pre-filter pruned work (a point lookup proved
    /// absent, or a scan skipped at least one shard).
    pub index_hits: u64,
    /// Queries the pre-filter could not narrow.
    pub index_misses: u64,
    /// State rows the pre-filter excluded from consideration.
    pub scan_rows_avoided: u64,
}

impl QueryStats {
    /// Fold another counter sample in (readers that re-bootstrap across
    /// engines accumulate stats across all of them).
    pub fn absorb(&mut self, o: &QueryStats) {
        self.served += o.served;
        self.index_hits += o.index_hits;
        self.index_misses += o.index_misses;
        self.scan_rows_avoided += o.scan_rows_avoided;
    }
}

/// Why a query could not be answered at the declared bound.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum QueryError {
    /// The window was compacted away; its final value was emitted before
    /// compaction. `first_available` is the oldest queryable window.
    Compacted { first_available: WindowId },
    /// The replica's watermark is `lag_ms` short of the window end, and
    /// the caller only tolerates `bound_ms`.
    TooStale { lag_ms: SimTime, bound_ms: SimTime },
}

impl std::fmt::Display for QueryError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            QueryError::Compacted { first_available } => {
                write!(f, "window compacted; first available is {first_available}")
            }
            QueryError::TooStale { lag_ms, bound_ms } => {
                write!(f, "replica lags window end by {lag_ms}ms (bound {bound_ms}ms)")
            }
        }
    }
}

/// A successful read, stamped with how stale it was.
#[derive(Debug, Clone, PartialEq)]
pub struct QueryResult<T> {
    pub window: WindowId,
    /// How far the replica's watermark was from the window end.
    pub lag_ms: SimTime,
    /// `lag_ms == 0`: the window is complete and this value is the one
    /// every replica returns (the §3.3 determinism guarantee).
    pub is_final: bool,
    pub value: T,
}

/// Query API over one replica's windowed keyed state.
pub struct QueryEngine<M: Crdt + KeyedWindowState> {
    state: WindowedCrdt<M>,
    index: SignatureIndex,
    stats: QueryStats,
    /// Next changefeed cursor this engine expects (see
    /// [`apply_feed`](Self::apply_feed)).
    cursor: u64,
}

impl<M: Crdt + KeyedWindowState> QueryEngine<M> {
    /// Wrap an existing replica, signing all of its live windows.
    pub fn new(state: WindowedCrdt<M>) -> Self {
        let mut index = SignatureIndex::new();
        for wid in state.window_ids() {
            if let Some(c) = state.raw_window(wid) {
                c.sign_into(index.sig_mut(wid));
            }
        }
        Self {
            state,
            index,
            stats: QueryStats::default(),
            cursor: 0,
        }
    }

    /// Bootstrap from a changefeed snapshot; the engine's cursor is set
    /// so [`apply_feed`](Self::apply_feed) continues where the snapshot
    /// left off.
    pub fn from_snapshot(snap: &StateSnapshot) -> DecodeResult<Self> {
        let state = WindowedCrdt::<M>::from_bytes(&snap.bytes)?;
        let mut engine = Self::new(state);
        engine.cursor = snap.cursor;
        Ok(engine)
    }

    /// Merge a state or delta payload in, keeping the index current:
    /// every window the merge changed is re-signed from the *merged*
    /// state (not the update — immune to shard-layout rehashes), and
    /// compaction advances drop the corresponding signatures.
    pub fn ingest(&mut self, update: &WindowedCrdt<M>) -> MergeReport {
        let report = self.state.merge(update);
        if report.compaction_advanced {
            self.index.retain_from(self.state.first_available());
        }
        for &wid in &report.changed_windows {
            if let Some(c) = self.state.raw_window(wid) {
                c.sign_into(self.index.sig_mut(wid));
            }
        }
        report
    }

    /// [`ingest`](Self::ingest) an encoded payload.
    pub fn ingest_bytes(&mut self, bytes: &[u8]) -> DecodeResult<MergeReport> {
        let update = WindowedCrdt::<M>::from_bytes(bytes)?;
        Ok(self.ingest(&update))
    }

    /// Apply one changefeed item. Items below the engine's cursor are
    /// skipped (already reflected — e.g. the snapshot covered them);
    /// applying is idempotent anyway, but skipping keeps the cursor
    /// accounting exact. Returns whether the item was applied.
    pub fn apply_feed(&mut self, item: &FeedItem) -> DecodeResult<bool> {
        if item.cursor < self.cursor {
            return Ok(false);
        }
        self.ingest_bytes(&item.payload)?;
        self.cursor = item.cursor + 1;
        Ok(true)
    }

    /// How far the replica's watermark is from `wid`'s end (0 when the
    /// window is complete).
    pub fn freshness(&self, wid: WindowId) -> SimTime {
        self.state
            .assigner()
            .window_end(wid)
            .saturating_sub(self.state.global_watermark())
    }

    /// Staleness gate. The bound is inclusive: `lag <= staleness_ms`
    /// passes, so `staleness == 0` accepts a watermark that just
    /// reached the window end — the same exact-boundary rule as
    /// `wcrdt/watermark.rs` (`boundary_is_exact_not_fuzzy`). A strict
    /// `<` here would wrongly reject the post-fire state.
    fn check(&self, wid: WindowId, staleness_ms: SimTime) -> Result<SimTime, QueryError> {
        if wid < self.state.first_available() {
            return Err(QueryError::Compacted {
                first_available: self.state.first_available(),
            });
        }
        let lag = self.freshness(wid);
        if lag > staleness_ms {
            return Err(QueryError::TooStale {
                lag_ms: lag,
                bound_ms: staleness_ms,
            });
        }
        Ok(lag)
    }

    fn result<T>(&self, wid: WindowId, lag: SimTime, value: T) -> QueryResult<T> {
        QueryResult {
            window: wid,
            lag_ms: lag,
            is_final: lag == 0,
            value,
        }
    }

    /// Point lookup: the value of `key` in window `wid`, within
    /// `staleness_ms` of final. `Ok` with `value: None` means the key is
    /// (verifiably, at this staleness) absent.
    pub fn point(
        &mut self,
        wid: WindowId,
        key: &M::Key,
        staleness_ms: SimTime,
    ) -> Result<QueryResult<Option<M::Value>>, QueryError> {
        let lag = self.check(wid, staleness_ms)?;
        self.stats.served += 1;
        let win = self.state.raw_window(wid);
        let pruned = match (self.index.sig(wid), &win) {
            (None, _) | (_, None) => true, // window verifiably holds nothing
            (Some(sig), Some(w)) => {
                if !sig.may_contain_key(fingerprint(key)) {
                    // the validation the filter saved: the target shard
                    // (sharded) or the whole map (flat)
                    true
                } else if let Some(si) = w.shard_of_key(key) {
                    !sig.may_contain_shard(si)
                } else {
                    false
                }
            }
        };
        if pruned {
            self.stats.index_hits += 1;
            self.stats.scan_rows_avoided +=
                win.map(|w| w.key_count() as u64).unwrap_or(0);
            return Ok(self.result(wid, lag, None));
        }
        self.stats.index_misses += 1;
        let value = win.and_then(|w| w.get_value(key)).cloned();
        Ok(self.result(wid, lag, value))
    }

    /// Inclusive range scan: all `(key, value)` rows with
    /// `lo <= key <= hi` in window `wid`, ascending by key.
    pub fn range(
        &mut self,
        wid: WindowId,
        lo: &M::Key,
        hi: &M::Key,
        staleness_ms: SimTime,
    ) -> Result<QueryResult<Vec<(M::Key, M::Value)>>, QueryError> {
        let lag = self.check(wid, staleness_ms)?;
        self.stats.served += 1;
        let mut rows = Vec::new();
        let avoided = self.scan(wid, |k, v| {
            if k >= lo && k <= hi {
                rows.push((k.clone(), v.clone()));
            }
        });
        rows.sort_by(|a, b| a.0.cmp(&b.0));
        self.note_scan(avoided);
        Ok(self.result(wid, lag, rows))
    }

    /// Top-k scan: the `k` hottest rows of window `wid` by
    /// [`Rank`], descending (ties broken by ascending key).
    pub fn top_k(
        &mut self,
        wid: WindowId,
        k: usize,
        staleness_ms: SimTime,
    ) -> Result<QueryResult<Vec<(M::Key, M::Value)>>, QueryError>
    where
        M::Value: Rank,
    {
        let lag = self.check(wid, staleness_ms)?;
        self.stats.served += 1;
        let mut rows: Vec<(M::Key, M::Value)> = Vec::new();
        let avoided = self.scan(wid, |key, v| {
            rows.push((key.clone(), v.clone()));
        });
        rows.sort_by(|a, b| {
            b.1.rank()
                .total_cmp(&a.1.rank())
                .then_with(|| a.0.cmp(&b.0))
        });
        rows.truncate(k);
        self.note_scan(avoided);
        Ok(self.result(wid, lag, rows))
    }

    /// Filtered scan over one window; returns rows avoided. A window
    /// with no signature (or no materialized state) scans nothing.
    fn scan(&self, wid: WindowId, f: impl FnMut(&M::Key, &M::Value)) -> u64 {
        match (self.state.raw_window(wid), self.index.sig(wid)) {
            (Some(w), Some(sig)) => w.for_each_filtered(sig, f),
            (Some(w), None) => w.key_count() as u64,
            _ => 0,
        }
    }

    fn note_scan(&mut self, avoided: u64) {
        if avoided > 0 {
            self.stats.index_hits += 1;
            self.stats.scan_rows_avoided += avoided;
        } else {
            self.stats.index_misses += 1;
        }
    }

    /// Counters since construction (or the last
    /// [`take_stats`](Self::take_stats)).
    pub fn stats(&self) -> QueryStats {
        self.stats
    }

    /// Drain the counters (harnesses fold them into cluster metrics).
    pub fn take_stats(&mut self) -> QueryStats {
        std::mem::take(&mut self.stats)
    }

    /// The wrapped replica.
    pub fn state(&self) -> &WindowedCrdt<M> {
        &self.state
    }

    /// The signature index (diagnostics and property tests).
    pub fn index(&self) -> &SignatureIndex {
        &self.index
    }

    /// Next changefeed cursor this engine expects.
    pub fn cursor(&self) -> u64 {
        self.cursor
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::PartitionId;
    use crate::wcrdt::WindowAssigner;

    type FlatShared = WindowedCrdt<MapCrdt<u64, GCounter>>;
    type ShardedShared = WindowedCrdt<ShardedMapCrdt<u64, GCounter>>;

    fn flat(parts: &[PartitionId]) -> FlatShared {
        WindowedCrdt::new(WindowAssigner::tumbling(1000), parts.iter().copied())
    }

    fn sharded(parts: &[PartitionId]) -> ShardedShared {
        WindowedCrdt::new(WindowAssigner::tumbling(1000), parts.iter().copied())
    }

    #[test]
    fn staleness_zero_sees_post_fire_state_at_exact_boundary() {
        // The satellite bugfix pin: window 0 covers [0, 1000); when the
        // global watermark reaches *exactly* 1000 the window just fired,
        // and a staleness-0 query must see the post-fire (final) state —
        // mirroring wcrdt/watermark.rs `boundary_is_exact_not_fuzzy`.
        // A strict `lag < staleness` gate fails this at lag == 0... and
        // in the off-by-one form (`lag >= staleness` rejection) it
        // rejects exactly the boundary case below.
        let mut w = flat(&[0, 1]);
        w.insert_with(0, 500, |m| m.entry(7).add(0, 3)).unwrap();
        w.increment_watermark(0, 999);
        w.increment_watermark(1, 999);
        let mut q = QueryEngine::new(w.clone());
        // one ms short of the boundary: lag is exactly 1, staleness 0 rejects
        assert_eq!(
            q.point(0, &7, 0).unwrap_err(),
            QueryError::TooStale { lag_ms: 1, bound_ms: 0 }
        );
        // ...but a bound of 1 admits it as a non-final read
        let near = q.point(0, &7, 1).unwrap();
        assert_eq!(near.lag_ms, 1);
        assert!(!near.is_final);
        assert_eq!(near.value.unwrap().value(), 3);

        // watermark lands exactly on the window end: staleness 0 must pass
        w.increment_watermark(0, 1000);
        w.increment_watermark(1, 1000);
        let mut q = QueryEngine::new(w);
        let fired = q.point(0, &7, 0).unwrap();
        assert_eq!(fired.lag_ms, 0);
        assert!(fired.is_final);
        assert_eq!(fired.value.unwrap().value(), 3);
    }

    #[test]
    fn point_prunes_absent_keys_through_the_index() {
        let mut w = flat(&[0]);
        for k in 0..4u64 {
            w.insert_with(0, 100, |m| m.entry(k).add(0, k + 1)).unwrap();
        }
        w.increment_watermark(0, 1000);
        let mut q = QueryEngine::new(w);
        assert_eq!(q.point(0, &2, 0).unwrap().value.unwrap().value(), 3);
        // absent keys: Bloom-pruned lookups count hits and rows avoided
        let mut pruned = 0;
        for k in 1_000_000..1_000_100u64 {
            let r = q.point(0, &k, 0).unwrap();
            assert!(r.value.is_none());
            pruned += 1;
        }
        let s = q.stats();
        assert_eq!(s.served, 1 + pruned);
        assert!(s.index_hits > 90, "only {} of {pruned} absent keys pruned", s.index_hits);
        assert!(s.scan_rows_avoided >= s.index_hits * 4);
    }

    #[test]
    fn range_and_top_k_over_sharded_state() {
        let mut w = sharded(&[0]);
        w.insert_with(0, 100, |m| {
            m.ensure_shards(8);
            for k in 0..10u64 {
                m.entry(k).add(0, (k % 3) * 10 + 1);
            }
        })
        .unwrap();
        w.increment_watermark(0, 1000);
        let mut q = QueryEngine::new(w);
        let r = q.range(0, &3, &6, 0).unwrap();
        assert!(r.is_final);
        assert_eq!(
            r.value.iter().map(|(k, _)| *k).collect::<Vec<_>>(),
            [3, 4, 5, 6],
            "range rows ascending by key"
        );
        let t = q.top_k(0, 3, 0).unwrap();
        // rank = (k % 3)*10 + 1: keys 2,5,8 rank 21, tie broken by key
        assert_eq!(t.value.iter().map(|(k, _)| *k).collect::<Vec<_>>(), [2, 5, 8]);
    }

    #[test]
    fn scans_skip_shards_the_signature_proves_empty() {
        let mut w = sharded(&[0]);
        w.insert_with(0, 100, |m| {
            m.ensure_shards(8);
            m.entry(1).add(0, 5);
        })
        .unwrap();
        // window 2 has many keys; scanning window 0 must not pay for them
        w.insert_with(0, 2100, |m| {
            for k in 0..64u64 {
                m.entry(k).add(0, 1);
            }
        })
        .unwrap();
        w.increment_watermark(0, 3000);
        let mut q = QueryEngine::new(w);
        let r = q.range(0, &0, &100, 0).unwrap();
        assert_eq!(r.value.len(), 1);
        // 7 of 8 shards in window 0 are empty — pruned, not visited;
        // their avoided-row count is 0 though, so the measurable win
        // shows on window 2 lookups with pruned shards instead:
        let r2 = q.point(2, &1_000_000, 0).unwrap();
        assert!(r2.value.is_none());
        let s = q.stats();
        assert!(s.index_hits >= 1, "stats: {s:?}");
    }

    #[test]
    fn compacted_window_reports_first_available() {
        let mut w = flat(&[0]);
        w.insert_with(0, 100, |m| m.entry(1).add(0, 1)).unwrap();
        w.insert_with(0, 2100, |m| m.entry(1).add(0, 1)).unwrap();
        w.increment_watermark(0, 5000);
        w.compact_below(2);
        let mut q = QueryEngine::new(w);
        assert_eq!(
            q.point(0, &1, 1_000_000).unwrap_err(),
            QueryError::Compacted { first_available: 2 }
        );
        assert!(q.point(2, &1, 0).unwrap().value.is_some());
        // rejections don't count as served
        assert_eq!(q.stats().served, 1);
    }

    #[test]
    fn ingest_keeps_index_consistent_across_merges_and_compaction() {
        let mut a = flat(&[0, 1]);
        a.insert_with(0, 100, |m| m.entry(1).add(0, 1)).unwrap();
        let mut q = QueryEngine::new(a);
        let mut update = flat(&[0, 1]);
        update.insert_with(1, 150, |m| m.entry(9).add(1, 4)).unwrap();
        update.increment_watermark(0, 2000);
        update.increment_watermark(1, 2000);
        let report = q.ingest(&update);
        assert_eq!(report.changed_windows, vec![0]);
        // the merged-in key is immediately visible and indexed
        assert!(q.index().may_contain(0, fingerprint(&9u64)));
        assert_eq!(q.point(0, &9, 0).unwrap().value.unwrap().value(), 4);
        // compaction in an update drops the window AND its signature
        let mut compacted = flat(&[0, 1]);
        compacted.compact_below(1);
        let report = q.ingest(&compacted);
        assert!(report.compaction_advanced);
        assert!(q.index().sig(0).is_none());
    }

    #[test]
    fn apply_feed_is_cursor_exact() {
        use std::sync::Arc;
        let mut w = flat(&[0]);
        w.insert_with(0, 100, |m| m.entry(1).add(0, 1)).unwrap();
        let h = ReadHandle::new();
        h.publish_full(Arc::new(w.to_bytes()), 0);
        let snap = h.snapshot().unwrap();
        let mut q = QueryEngine::<MapCrdt<u64, GCounter>>::from_snapshot(&snap).unwrap();
        assert_eq!(q.cursor(), 1);
        // a replayed item below the cursor is skipped, not re-applied
        let stale = FeedItem {
            cursor: 0,
            watermark: 0,
            full: true,
            payload: Arc::new(w.to_bytes()),
        };
        assert!(!q.apply_feed(&stale).unwrap());
        // the next delta applies and advances the cursor
        w.insert_with(0, 150, |m| m.entry(2).add(0, 7)).unwrap();
        let delta = w.take_delta();
        let item = FeedItem {
            cursor: 1,
            watermark: 0,
            full: false,
            payload: Arc::new(delta.to_bytes()),
        };
        assert!(q.apply_feed(&item).unwrap());
        assert_eq!(q.cursor(), 2);
        let mut final_wm = flat(&[0]);
        final_wm.increment_watermark(0, 1000);
        // lint:allow(discarded-merge): watermark-only ingest to close the window — the point query on the next line asserts the resulting state
        let _ = q.ingest(&final_wm);
        assert_eq!(q.point(0, &2, 0).unwrap().value.unwrap().value(), 7);
    }
}
