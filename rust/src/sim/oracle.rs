//! Post-run oracles — the properties every fault schedule must
//! preserve (paper §3.3/§4.2/§5.2):
//!
//! 1. **Exactly-once delivery**: after `(partition, seq)` dedup the
//!    output stream is duplicate-free and gap-free, and every physical
//!    duplicate is byte-identical to its first delivery (idempotent
//!    replay).
//! 2. **Determinism**: the deduplicated outputs are byte-identical to a
//!    fault-free golden run over the same input (prefix-compared, since
//!    a faulty run may complete fewer windows before the stop).
//! 3. **Convergence**: once the network heals, every surviving
//!    replica reads the same value for every globally-completed window.
//!
//! Plus a liveness guard: a run that emitted almost nothing cannot
//! vacuously pass, so a minimum number of compared windows is enforced.

use crate::codec::Decode;
use crate::crdt::GCounter;
use crate::util::{NodeId, PartitionId};
use crate::wcrdt::WindowedCrdt;

use super::runner::RunArtifacts;

/// Minimum windows that must be compared per partition for a run to
/// count (liveness guard against vacuous passes).
pub const MIN_WINDOWS: usize = 3;

/// A falsified oracle.
#[derive(Debug, Clone, PartialEq)]
pub enum OracleFailure {
    /// Post-dedup stream delivered a sequence number twice.
    DuplicateDelivery { partition: PartitionId, seq: u64 },
    /// Post-dedup stream is missing a sequence number.
    SequenceGap { partition: PartitionId, missing: u64 },
    /// A physical replay differed from the first delivery of its seq.
    DivergentReplay { partition: PartitionId, seq: u64 },
    /// Output differs from the fault-free golden run.
    DeterminismViolation { partition: PartitionId, seq: u64 },
    /// Two surviving replicas disagree on a completed window.
    ConvergenceViolation { window: u64, a: NodeId, b: NodeId },
    /// A survivor's published replica failed to decode.
    CorruptReplica { node: NodeId },
    /// The run made too little progress for the oracles to mean much.
    InsufficientProgress { compared_windows: usize },
}

impl std::fmt::Display for OracleFailure {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            OracleFailure::DuplicateDelivery { partition, seq } => {
                write!(f, "duplicate delivery: partition {partition} seq {seq}")
            }
            OracleFailure::SequenceGap { partition, missing } => {
                write!(f, "sequence gap: partition {partition} missing seq {missing}")
            }
            OracleFailure::DivergentReplay { partition, seq } => {
                write!(f, "replayed output differs: partition {partition} seq {seq}")
            }
            OracleFailure::DeterminismViolation { partition, seq } => {
                write!(f, "output differs from golden run: partition {partition} seq {seq}")
            }
            OracleFailure::ConvergenceViolation { window, a, b } => {
                write!(f, "replicas {a} and {b} disagree on completed window {window}")
            }
            OracleFailure::CorruptReplica { node } => {
                write!(f, "replica of node {node} failed to decode")
            }
            OracleFailure::InsufficientProgress { compared_windows } => {
                write!(f, "only {compared_windows} windows compared (liveness)")
            }
        }
    }
}

/// Run the full oracle suite on a faulty run against its golden run.
pub fn check_run(
    faulty: &RunArtifacts,
    golden: &RunArtifacts,
    min_windows: usize,
) -> Result<(), OracleFailure> {
    check_exactly_once(faulty)?;
    check_determinism(faulty, golden, min_windows)?;
    check_convergence(faulty)?;
    Ok(())
}

/// Oracle 1: dedup'd stream is duplicate-free and gap-free, physical
/// duplicates byte-identical.
pub fn check_exactly_once(run: &RunArtifacts) -> Result<(), OracleFailure> {
    for p in 0..run.partitions {
        let deduped = &run.deduped[p as usize];
        for (i, (seq, _)) in deduped.iter().enumerate() {
            let expected = i as u64;
            if *seq < expected {
                return Err(OracleFailure::DuplicateDelivery { partition: p, seq: *seq });
            }
            if *seq > expected {
                return Err(OracleFailure::SequenceGap { partition: p, missing: expected });
            }
        }
        // every physical delivery of a seq must match its first delivery
        for (seq, payload) in &run.raw[p as usize] {
            match deduped.get(*seq as usize) {
                Some((s, first)) if s == seq => {
                    if first != payload {
                        return Err(OracleFailure::DivergentReplay { partition: p, seq: *seq });
                    }
                }
                // seq outside the deduped range: the dedup stream is
                // corrupt in a way the loop above already rejects, or
                // the artifact was mutated — flag as a gap.
                _ => return Err(OracleFailure::SequenceGap { partition: p, missing: *seq }),
            }
        }
    }
    Ok(())
}

/// Oracle 2: byte-identical to the golden run on the common prefix,
/// with at least `min_windows` outputs compared per partition.
pub fn check_determinism(
    faulty: &RunArtifacts,
    golden: &RunArtifacts,
    min_windows: usize,
) -> Result<(), OracleFailure> {
    let mut least = usize::MAX;
    for p in 0..faulty.partitions {
        let a = &faulty.deduped[p as usize];
        let b = &golden.deduped[p as usize];
        let common = a.len().min(b.len());
        least = least.min(common);
        for i in 0..common {
            if a[i].1 != b[i].1 {
                return Err(OracleFailure::DeterminismViolation {
                    partition: p,
                    seq: i as u64,
                });
            }
        }
    }
    if least < min_windows {
        return Err(OracleFailure::InsufficientProgress {
            compared_windows: if least == usize::MAX { 0 } else { least },
        });
    }
    Ok(())
}

/// Oracle 3: surviving replicas agree on every globally-completed
/// window. Completion is judged by the *most conservative* survivor
/// (min global watermark), so every compared window is final on every
/// replica — the paper's global-determinism read guarantee.
pub fn check_convergence(run: &RunArtifacts) -> Result<(), OracleFailure> {
    let mut replicas: Vec<(NodeId, WindowedCrdt<GCounter>)> = Vec::new();
    for (&node, bytes) in &run.replicas {
        match WindowedCrdt::<GCounter>::from_bytes(bytes) {
            Ok(w) => replicas.push((node, w)),
            Err(_) => return Err(OracleFailure::CorruptReplica { node }),
        }
    }
    if replicas.len() < 2 {
        return Ok(()); // nothing to cross-check
    }
    let gw = replicas
        .iter()
        .map(|(_, w)| w.global_watermark())
        .min()
        .unwrap_or(0);
    let first = replicas
        .iter()
        .map(|(_, w)| w.first_available())
        .max()
        .unwrap_or(0);
    let assigner = replicas[0].1.assigner();
    let (ref_node, ref_w) = &replicas[0];
    let mut wid = first;
    while assigner.window_end(wid) <= gw {
        let expected = ref_w.window_value(wid);
        for (node, w) in &replicas[1..] {
            if w.window_value(wid) != expected {
                return Err(OracleFailure::ConvergenceViolation {
                    window: wid,
                    a: *ref_node,
                    b: *node,
                });
            }
        }
        wid += 1;
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::codec::Encode;
    use crate::wcrdt::WindowAssigner;
    use std::collections::BTreeMap;

    fn artifacts(parts: u32, outputs_per_part: usize) -> RunArtifacts {
        let mut raw = Vec::new();
        let mut deduped = Vec::new();
        for p in 0..parts {
            let outs: Vec<(u64, Vec<u8>)> = (0..outputs_per_part as u64)
                .map(|s| (s, vec![p as u8, s as u8]))
                .collect();
            // raw replays the first two outputs (byte-identical)
            let mut all = outs.clone();
            all.extend(outs.iter().take(2).cloned());
            raw.push(all);
            deduped.push(outs);
        }
        RunArtifacts {
            partitions: parts,
            raw,
            deduped,
            replicas: BTreeMap::new(),
            steals: 0,
            trace_json: None,
        }
    }

    #[test]
    fn clean_artifacts_pass() {
        let a = artifacts(4, 5);
        assert_eq!(check_run(&a, &a.clone(), 3), Ok(()));
    }

    #[test]
    fn duplicate_in_dedup_stream_is_caught() {
        let mut a = artifacts(2, 5);
        let dup = a.deduped[1][2].clone();
        a.deduped[1].insert(2, dup);
        assert!(matches!(
            check_exactly_once(&a),
            Err(OracleFailure::DuplicateDelivery { partition: 1, .. })
        ));
    }

    #[test]
    fn gap_is_caught() {
        let mut a = artifacts(2, 5);
        a.deduped[0].remove(2);
        // remove matching raw entries so the gap check fires first
        a.raw[0].retain(|(s, _)| *s != 2);
        assert!(matches!(
            check_exactly_once(&a),
            Err(OracleFailure::SequenceGap { partition: 0, missing: 2 })
        ));
    }

    #[test]
    fn divergent_replay_is_caught() {
        let mut a = artifacts(1, 4);
        a.raw[0].push((1, vec![0xDE, 0xAD]));
        assert!(matches!(
            check_exactly_once(&a),
            Err(OracleFailure::DivergentReplay { partition: 0, seq: 1 })
        ));
    }

    #[test]
    fn golden_mismatch_is_caught() {
        let golden = artifacts(2, 5);
        let mut faulty = golden.clone();
        faulty.deduped[1][3].1 = vec![9, 9, 9];
        assert!(matches!(
            check_determinism(&faulty, &golden, 3),
            Err(OracleFailure::DeterminismViolation { partition: 1, seq: 3 })
        ));
    }

    #[test]
    fn short_run_fails_liveness() {
        let golden = artifacts(2, 5);
        let faulty = artifacts(2, 2);
        assert!(matches!(
            check_determinism(&faulty, &golden, 3),
            Err(OracleFailure::InsufficientProgress { compared_windows: 2 })
        ));
    }

    fn replica(parts: &[u32], adds: &[(u32, u64, u64)], wm: u64) -> Vec<u8> {
        let mut w: WindowedCrdt<GCounter> =
            WindowedCrdt::new(WindowAssigner::tumbling(1000), parts.iter().copied());
        for &(p, ts, n) in adds {
            w.insert_with(p, ts, |c| c.add(p as u64, n)).unwrap();
        }
        for &p in parts {
            w.increment_watermark(p, wm);
        }
        w.to_bytes()
    }

    #[test]
    fn convergent_replicas_pass() {
        let mut a = artifacts(2, 5);
        let r = replica(&[0, 1], &[(0, 100, 3), (1, 1200, 4)], 3000);
        a.replicas.insert(0, r.clone());
        a.replicas.insert(1, r);
        assert_eq!(check_convergence(&a), Ok(()));
    }

    #[test]
    fn divergent_replicas_are_caught() {
        let mut a = artifacts(2, 5);
        a.replicas
            .insert(0, replica(&[0, 1], &[(0, 100, 3)], 3000));
        a.replicas
            .insert(1, replica(&[0, 1], &[(0, 100, 7)], 3000));
        assert!(matches!(
            check_convergence(&a),
            Err(OracleFailure::ConvergenceViolation { window: 0, .. })
        ));
    }

    #[test]
    fn corrupt_replica_is_caught() {
        let mut a = artifacts(1, 5);
        a.replicas.insert(3, vec![0xFF]);
        a.replicas.insert(4, replica(&[0], &[], 1000));
        assert!(matches!(
            check_convergence(&a),
            Err(OracleFailure::CorruptReplica { node: 3 })
        ));
    }
}
