//! Fault plans: seeded, randomly generated failure schedules.
//!
//! A [`FaultPlan`] is the *entire* adversarial input of a simulation
//! run: every kill, restart, network partition, delay/loss burst and
//! reconfiguration, each pinned to a sim-time. Plans are generated
//! deterministically from a seed (same seed → same plan), serialize to
//! a compact one-line string for `HOLON_SIM_PLAN=…` replay, and shrink
//! structurally (see [`crate::sim::shrink`]).

use std::collections::BTreeSet;

use crate::util::{NodeId, SimTime, XorShift64};

/// One fault injected at a point in sim-time.
#[derive(Debug, Clone, PartialEq)]
pub enum FaultAction {
    /// Kill a node abruptly (no final checkpoint, inbox dropped).
    Kill(NodeId),
    /// Restart a previously killed node with the same id, fresh state.
    Restart(NodeId),
    /// Network partition: the listed nodes form one group, everyone
    /// else the other. Replaces any partition currently in effect.
    Partition(Vec<NodeId>),
    /// Heal all network partitions.
    Heal,
    /// Message-loss burst: extra drop probability (percent) for the
    /// given duration.
    Loss { pct: u8, duration_ms: SimTime },
    /// Delay burst: extra per-message one-way delay for the duration.
    Delay { extra_ms: SimTime, duration_ms: SimTime },
    /// Reconfiguration: add a brand-new node to the running cluster.
    AddNode(NodeId),
}

/// A [`FaultAction`] scheduled at `at_ms` sim-time.
#[derive(Debug, Clone, PartialEq)]
pub struct FaultEvent {
    pub at_ms: SimTime,
    pub action: FaultAction,
}

/// A complete fault schedule, sorted by time.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct FaultPlan {
    pub events: Vec<FaultEvent>,
}

impl FaultPlan {
    /// The fault-free plan (golden runs).
    pub fn empty() -> Self {
        Self::default()
    }

    pub fn is_empty(&self) -> bool {
        self.events.is_empty()
    }

    /// Generate a random-but-valid schedule from `seed`: kills are only
    /// issued while more than `min_alive` nodes run, restarts pair with
    /// kills, partitions always schedule their own heal, and bursts are
    /// bounded — so generated plans never wedge the cluster, they only
    /// stress it. Fault times fall inside `window` (sim-ms).
    pub fn generate(seed: u64, nodes: u32, window: (SimTime, SimTime)) -> Self {
        const MIN_ALIVE: usize = 2;
        let (lo, hi) = window;
        let mut rng = XorShift64::new(seed ^ 0x51A7_7ED5);
        let mut alive: BTreeSet<NodeId> = (0..nodes).collect();
        let mut pending_restarts: Vec<(SimTime, NodeId)> = Vec::new();
        let mut next_new_id = nodes;
        let mut added = 0u32;
        let mut events: Vec<FaultEvent> = Vec::new();

        let n_events = 3 + rng.next_below(5); // 3..=7 primary faults
        let span = hi.saturating_sub(lo).max(1);
        let mut t = lo;
        for _ in 0..n_events {
            t += 1 + rng.next_below(span / (n_events + 1) + 1);
            if t >= hi {
                break;
            }
            // nodes whose scheduled restart has passed are alive again
            pending_restarts.retain(|&(rt, n)| {
                if rt <= t {
                    alive.insert(n);
                    false
                } else {
                    true
                }
            });
            match rng.next_below(100) {
                0..=39 => {
                    // kill, usually with a scheduled restart
                    if alive.len() > MIN_ALIVE {
                        let victims: Vec<NodeId> = alive.iter().copied().collect();
                        let victim = *rng.pick(&victims);
                        alive.remove(&victim);
                        events.push(FaultEvent {
                            at_ms: t,
                            action: FaultAction::Kill(victim),
                        });
                        if rng.chance(0.75) {
                            let rt = t + rng.range(300, 1500);
                            events.push(FaultEvent {
                                at_ms: rt,
                                action: FaultAction::Restart(victim),
                            });
                            pending_restarts.push((rt, victim));
                        }
                    }
                }
                40..=54 => {
                    // partition the alive set in two, heal shortly after
                    if alive.len() >= 2 {
                        let all: Vec<NodeId> = alive.iter().copied().collect();
                        let cut = 1 + rng.next_below(all.len() as u64 - 1) as usize;
                        let group: Vec<NodeId> = all[..cut].to_vec();
                        events.push(FaultEvent {
                            at_ms: t,
                            action: FaultAction::Partition(group),
                        });
                        events.push(FaultEvent {
                            at_ms: t + rng.range(300, 1200),
                            action: FaultAction::Heal,
                        });
                    }
                }
                55..=69 => {
                    events.push(FaultEvent {
                        at_ms: t,
                        action: FaultAction::Loss {
                            pct: (20 + rng.next_below(60)) as u8,
                            duration_ms: rng.range(200, 1000),
                        },
                    });
                }
                70..=84 => {
                    events.push(FaultEvent {
                        at_ms: t,
                        action: FaultAction::Delay {
                            extra_ms: rng.range(20, 200),
                            duration_ms: rng.range(200, 1000),
                        },
                    });
                }
                _ => {
                    // reconfiguration: scale out by one node (at most 2)
                    if added < 2 {
                        events.push(FaultEvent {
                            at_ms: t,
                            action: FaultAction::AddNode(next_new_id),
                        });
                        alive.insert(next_new_id);
                        next_new_id += 1;
                        added += 1;
                    }
                }
            }
        }
        events.sort_by_key(|e| e.at_ms);
        FaultPlan { events }
    }

    /// Compact one-line encoding, shell-safe modulo quoting:
    /// `500:k1;800:p0.2;1400:h;1700:r1;2000:l30x400;2600:d80x600;3000:a4`.
    pub fn to_plan_string(&self) -> String {
        self.events
            .iter()
            .map(|e| {
                let a = match &e.action {
                    FaultAction::Kill(n) => format!("k{n}"),
                    FaultAction::Restart(n) => format!("r{n}"),
                    FaultAction::Partition(g) => format!(
                        "p{}",
                        g.iter()
                            .map(|n| n.to_string())
                            .collect::<Vec<_>>()
                            .join(".")
                    ),
                    FaultAction::Heal => "h".to_string(),
                    FaultAction::Loss { pct, duration_ms } => format!("l{pct}x{duration_ms}"),
                    FaultAction::Delay {
                        extra_ms,
                        duration_ms,
                    } => format!("d{extra_ms}x{duration_ms}"),
                    FaultAction::AddNode(n) => format!("a{n}"),
                };
                format!("{}:{}", e.at_ms, a)
            })
            .collect::<Vec<_>>()
            .join(";")
    }

    /// Parse the [`to_plan_string`](Self::to_plan_string) encoding. The
    /// empty string is the empty plan.
    pub fn parse(s: &str) -> Result<Self, String> {
        let mut events = Vec::new();
        for part in s.split(';') {
            let part = part.trim();
            if part.is_empty() {
                continue;
            }
            let (at, act) = part
                .split_once(':')
                .ok_or_else(|| format!("missing ':' in event `{part}`"))?;
            let at_ms: SimTime = at
                .parse()
                .map_err(|_| format!("bad time in event `{part}`"))?;
            let mut act_chars = act.chars();
            let Some(tag) = act_chars.next() else {
                return Err(format!("missing action in event `{part}`"));
            };
            let rest = act_chars.as_str();
            let parse_node = |r: &str| -> Result<NodeId, String> {
                r.parse().map_err(|_| format!("bad node in `{part}`"))
            };
            let parse_pair = |r: &str| -> Result<(u64, u64), String> {
                let (a, b) = r
                    .split_once('x')
                    .ok_or_else(|| format!("missing 'x' in `{part}`"))?;
                Ok((
                    a.parse().map_err(|_| format!("bad value in `{part}`"))?,
                    b.parse().map_err(|_| format!("bad value in `{part}`"))?,
                ))
            };
            let action = match tag {
                'k' => FaultAction::Kill(parse_node(rest)?),
                'r' => FaultAction::Restart(parse_node(rest)?),
                'a' => FaultAction::AddNode(parse_node(rest)?),
                'h' if rest.is_empty() => FaultAction::Heal,
                'p' => {
                    let group = rest
                        .split('.')
                        .filter(|x| !x.is_empty())
                        .map(|x| x.parse().map_err(|_| format!("bad group in `{part}`")))
                        .collect::<Result<Vec<NodeId>, String>>()?;
                    if group.is_empty() {
                        return Err(format!("empty partition group in `{part}`"));
                    }
                    FaultAction::Partition(group)
                }
                'l' => {
                    let (pct, dur) = parse_pair(rest)?;
                    FaultAction::Loss {
                        pct: pct.min(100) as u8,
                        duration_ms: dur,
                    }
                }
                'd' => {
                    let (extra, dur) = parse_pair(rest)?;
                    FaultAction::Delay {
                        extra_ms: extra,
                        duration_ms: dur,
                    }
                }
                _ => return Err(format!("unknown action tag in `{part}`")),
            };
            events.push(FaultEvent { at_ms, action });
        }
        events.sort_by_key(|e| e.at_ms);
        Ok(FaultPlan { events })
    }
}

impl std::fmt::Display for FaultPlan {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        if self.events.is_empty() {
            write!(f, "(no faults)")
        } else {
            write!(f, "{}", self.to_plan_string())
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn generation_is_deterministic() {
        let a = FaultPlan::generate(99, 4, (300, 3000));
        let b = FaultPlan::generate(99, 4, (300, 3000));
        assert_eq!(a, b);
        assert!(!a.is_empty());
    }

    #[test]
    fn different_seeds_give_different_plans() {
        let plans: Vec<FaultPlan> = (0..16)
            .map(|s| FaultPlan::generate(s, 4, (300, 3000)))
            .collect();
        let distinct = plans
            .iter()
            .map(|p| p.to_plan_string())
            .collect::<std::collections::BTreeSet<_>>();
        assert!(distinct.len() > 8, "only {} distinct plans", distinct.len());
    }

    #[test]
    fn generated_plans_keep_two_nodes_alive() {
        for seed in 0..200 {
            let plan = FaultPlan::generate(seed, 4, (300, 3000));
            let mut alive: BTreeSet<NodeId> = (0..4).collect();
            for e in &plan.events {
                match &e.action {
                    FaultAction::Kill(n) => {
                        alive.remove(n);
                    }
                    FaultAction::Restart(n) | FaultAction::AddNode(n) => {
                        alive.insert(*n);
                    }
                    _ => {}
                }
                assert!(alive.len() >= 2, "seed {seed}: plan {plan} drains cluster");
            }
        }
    }

    #[test]
    fn generated_events_are_sorted() {
        for seed in 0..50 {
            let plan = FaultPlan::generate(seed, 5, (300, 3000));
            for w in plan.events.windows(2) {
                assert!(w[0].at_ms <= w[1].at_ms);
            }
        }
    }

    #[test]
    fn plan_string_roundtrips() {
        for seed in 0..100 {
            let plan = FaultPlan::generate(seed, 4, (300, 3000));
            let s = plan.to_plan_string();
            let back = FaultPlan::parse(&s).unwrap();
            assert_eq!(back, plan, "roundtrip failed for `{s}`");
        }
    }

    #[test]
    fn empty_plan_roundtrips() {
        assert_eq!(FaultPlan::parse("").unwrap(), FaultPlan::empty());
        assert_eq!(FaultPlan::empty().to_plan_string(), "");
    }

    #[test]
    fn parse_rejects_garbage() {
        assert!(FaultPlan::parse("nope").is_err());
        assert!(FaultPlan::parse("100:z9").is_err());
        assert!(FaultPlan::parse("100:l5").is_err()); // missing duration
        assert!(FaultPlan::parse("x:k1").is_err());
        assert!(FaultPlan::parse("100:p").is_err()); // empty group
        assert!(FaultPlan::parse("100:").is_err()); // missing action
        assert!(FaultPlan::parse("100:к1").is_err()); // multi-byte tag, no panic
    }

    #[test]
    fn parse_handcrafted_plan() {
        let p = FaultPlan::parse("500:k1;800:p0.2;1400:h;1700:r1;2000:l30x400").unwrap();
        assert_eq!(p.events.len(), 5);
        assert_eq!(p.events[0].action, FaultAction::Kill(1));
        assert_eq!(p.events[1].action, FaultAction::Partition(vec![0, 2]));
        assert_eq!(p.events[2].action, FaultAction::Heal);
        assert_eq!(p.events[3].action, FaultAction::Restart(1));
        assert_eq!(
            p.events[4].action,
            FaultAction::Loss {
                pct: 30,
                duration_ms: 400
            }
        );
    }
}
