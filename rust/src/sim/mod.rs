//! Deterministic simulation harness — seeded fault-schedule exploration
//! with shrinking and convergence/exactly-once oracles.
//!
//! The paper's headline guarantees (determinism, convergence,
//! exactly-once effects, recovery without global restarts) are exactly
//! the properties hand-written scenario tests sample at a few points.
//! This module explores them adversarially, FoundationDB-style:
//!
//! 1. [`FaultPlan::generate`] draws a random-but-valid fault schedule
//!    from a seed — node kills/restarts, crashes without restart,
//!    network partitions and heals, message delay/loss bursts, and
//!    scale-out reconfigurations, each pinned to a sim-time.
//! 2. [`run_plan`] executes the schedule against a live
//!    [`HolonCluster`](crate::engine::HolonCluster) over a pre-seeded,
//!    byte-identical input log, then harvests outputs and every
//!    surviving node's final replica.
//! 3. [`check_run`] applies the oracle suite: duplicate-free and
//!    gap-free delivery after sink dedup, byte-equality with a
//!    fault-free golden run of the same seed (determinism /
//!    exactly-once), and replica convergence on all completed windows.
//! 4. On falsification, [`shrink_plan`] minimizes the schedule and the
//!    harness prints a one-line replayable repro:
//!    `HOLON_SIM_SEED=… HOLON_SIM_PLAN=…`.
//!
//! Entry points: `cargo test --test simulation` (CI smoke over a fixed
//! seed set) and `holon sim --seeds=N` (overnight soaks).

pub mod oracle;
pub mod plan;
pub mod runner;
pub mod shrink;

pub use oracle::{
    check_convergence, check_determinism, check_exactly_once, check_run, OracleFailure,
    MIN_WINDOWS,
};
pub use plan::{FaultAction, FaultEvent, FaultPlan};
pub use runner::{
    collect_outputs, repro_line, run_plan, run_plan_with, Mutation, RunArtifacts, SimSpec,
};
pub use shrink::shrink_plan;

/// A falsified seed: the original and shrunk plans plus the repro line.
#[derive(Debug, Clone)]
pub struct SimFailure {
    pub seed: u64,
    pub failure: String,
    pub original_plan: FaultPlan,
    pub shrunk_plan: FaultPlan,
    pub repro: String,
    /// Path of the flight-recorder dump written from a traced re-run of
    /// the shrunk schedule (Chrome `trace_event` JSON; open in
    /// Perfetto). `None` only when the dump could not be written.
    pub trace_dump: Option<String>,
}

impl std::fmt::Display for SimFailure {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        writeln!(f, "simulation falsified: {}", self.failure)?;
        writeln!(f, "  seed:     {}", self.seed)?;
        writeln!(f, "  plan:     {}", self.original_plan)?;
        writeln!(f, "  shrunk:   {}", self.shrunk_plan)?;
        write!(f, "  repro:    {}", self.repro)?;
        if let Some(path) = &self.trace_dump {
            write!(f, "\n  trace:    {path}")?;
        }
        Ok(())
    }
}

/// Probe budget for shrinking (each probe is a full cluster run).
const SHRINK_BUDGET: usize = 48;

/// Run one explicit plan (with optional artifact mutation for oracle
/// self-checks) against its golden run; shrink on falsification.
pub fn run_seed_with(
    spec: &SimSpec,
    plan: &FaultPlan,
    mutation: Option<Mutation>,
) -> Result<(), SimFailure> {
    let golden = run_plan(spec, &FaultPlan::empty(), None);
    let faulty = run_plan(spec, plan, mutation);
    match check_run(&faulty, &golden, MIN_WINDOWS) {
        Ok(()) => Ok(()),
        Err(first_failure) => {
            let shrunk = shrink_plan(
                plan,
                |cand| {
                    let arts = run_plan(spec, cand, mutation);
                    check_run(&arts, &golden, MIN_WINDOWS).is_err()
                },
                SHRINK_BUDGET,
            );
            // Flight-recorder dump: re-run the shrunk schedule with
            // tracing on so the failure ships with a Perfetto-ready
            // timeline of the window lifecycle / gossip / recovery
            // leading up to it.
            let trace_dump = {
                let mut tspec = spec.clone();
                tspec.trace = true;
                let traced = run_plan(&tspec, &shrunk, mutation);
                traced.trace_json.and_then(|json| {
                    let path = format!("holon-trace-dump-seed{}.json", spec.seed);
                    match std::fs::write(&path, json.as_bytes()) {
                        Ok(()) => Some(path),
                        Err(e) => {
                            eprintln!("warning: could not write trace dump {path}: {e}");
                            None
                        }
                    }
                })
            };
            Err(SimFailure {
                seed: spec.seed,
                failure: first_failure.to_string(),
                original_plan: plan.clone(),
                shrunk_plan: shrunk.clone(),
                repro: repro_line(spec.seed, &shrunk),
                trace_dump,
            })
        }
    }
}

/// Explore one seed end-to-end: generate its fault plan, run it, check
/// the oracles, shrink on failure. The CI smoke test and the `holon
/// sim` soak both call this per seed.
pub fn check_seed(seed: u64) -> Result<(), SimFailure> {
    let spec = SimSpec {
        seed,
        ..SimSpec::default()
    };
    let plan = FaultPlan::generate(seed, spec.nodes, spec.fault_window());
    run_seed_with(&spec, &plan, None)
}
