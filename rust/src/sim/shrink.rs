//! Fault-plan shrinking: given a plan that falsifies an oracle, find a
//! (locally) minimal plan that still falsifies one, so the repro the
//! harness prints is short enough to reason about.
//!
//! Built on [`crate::proptest_lite::shrink_to_minimal`]. Candidates are
//! ordered cheapest-win-first: bisection (drop half the events), then
//! single-event removal, then weakening (halve burst magnitudes and
//! durations). Every probe is a full cluster run, so the probe budget
//! is explicit.

use crate::proptest_lite::shrink_to_minimal;

use super::plan::{FaultAction, FaultPlan};

/// Smaller variants of `plan`, most aggressive first.
pub fn candidates(plan: &FaultPlan) -> Vec<FaultPlan> {
    let n = plan.events.len();
    let mut out = Vec::new();
    // bisect
    if n >= 2 {
        out.push(FaultPlan {
            events: plan.events[..n / 2].to_vec(),
        });
        out.push(FaultPlan {
            events: plan.events[n / 2..].to_vec(),
        });
    }
    // drop one event at a time
    for i in 0..n {
        let mut events = plan.events.clone();
        events.remove(i);
        out.push(FaultPlan { events });
    }
    // weaken bursts in place
    for (i, e) in plan.events.iter().enumerate() {
        let weakened = match &e.action {
            FaultAction::Loss { pct, duration_ms } if *pct > 10 || *duration_ms > 100 => {
                Some(FaultAction::Loss {
                    pct: (*pct / 2).max(5),
                    duration_ms: (*duration_ms / 2).max(50),
                })
            }
            FaultAction::Delay {
                extra_ms,
                duration_ms,
            } if *extra_ms > 10 || *duration_ms > 100 => Some(FaultAction::Delay {
                extra_ms: (*extra_ms / 2).max(5),
                duration_ms: (*duration_ms / 2).max(50),
            }),
            _ => None,
        };
        if let Some(action) = weakened {
            let mut events = plan.events.clone();
            events[i].action = action;
            out.push(FaultPlan { events });
        }
    }
    out
}

/// Minimize a falsifying plan. `still_fails` must re-run the candidate
/// end-to-end and report whether *any* oracle still falsifies; at most
/// `budget` probes are spent.
pub fn shrink_plan(
    plan: &FaultPlan,
    still_fails: impl FnMut(&FaultPlan) -> bool,
    budget: usize,
) -> FaultPlan {
    shrink_to_minimal(plan.clone(), candidates, still_fails, budget)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::plan::FaultEvent;

    fn plan_of(s: &str) -> FaultPlan {
        FaultPlan::parse(s).unwrap()
    }

    #[test]
    fn shrinks_to_the_single_triggering_event() {
        // Pretend the failure needs exactly the kill of node 2.
        let plan = plan_of("400:k1;700:k2;900:r1;1200:r2;1500:l40x600;2000:d90x400");
        let fails = |p: &FaultPlan| {
            p.events
                .iter()
                .any(|e| matches!(e.action, FaultAction::Kill(2)))
        };
        let min = shrink_plan(&plan, fails, 500);
        assert_eq!(min.events.len(), 1);
        assert!(matches!(min.events[0].action, FaultAction::Kill(2)));
    }

    #[test]
    fn shrinks_burst_magnitude_when_events_cannot_be_dropped() {
        // Failure triggered by the presence of any Loss burst.
        let plan = plan_of("1500:l80x800");
        let fails = |p: &FaultPlan| {
            p.events
                .iter()
                .any(|e| matches!(e.action, FaultAction::Loss { .. }))
        };
        let min = shrink_plan(&plan, fails, 500);
        assert_eq!(min.events.len(), 1);
        match min.events[0].action {
            FaultAction::Loss { pct, duration_ms } => {
                assert!(pct <= 10, "pct {pct} not weakened");
                assert!(duration_ms <= 100, "duration {duration_ms} not weakened");
            }
            ref other => panic!("unexpected action {other:?}"),
        }
    }

    #[test]
    fn plan_independent_failure_shrinks_to_empty() {
        let plan = plan_of("400:k1;900:r1;1500:l40x600");
        let min = shrink_plan(&plan, |_| true, 500);
        assert!(min.is_empty());
    }

    #[test]
    fn candidates_never_grow_the_plan() {
        let plan = FaultPlan {
            events: vec![
                FaultEvent {
                    at_ms: 100,
                    action: FaultAction::Kill(0),
                },
                FaultEvent {
                    at_ms: 300,
                    action: FaultAction::Loss {
                        pct: 50,
                        duration_ms: 400,
                    },
                },
            ],
        };
        for c in candidates(&plan) {
            assert!(c.events.len() <= plan.events.len());
        }
    }
}
