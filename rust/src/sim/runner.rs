//! Execute a [`FaultPlan`] against a live [`HolonCluster`] and collect
//! everything the oracles need.
//!
//! The run is FoundationDB-style: the *input* is pre-seeded into the
//! log (byte-identical across runs of the same seed, fault-free or
//! not), the fault schedule executes at planned sim-times against the
//! shared [`SimClock`], and afterwards the harness force-heals the
//! network, drains, stops the cluster gracefully, and harvests the raw
//! output log, the deduplicated output stream, and every surviving
//! node's final replica.

use std::collections::{BTreeMap, BTreeSet};

use crate::clock::SimClock;
use crate::codec::{Decode, Encode};
use crate::config::HolonConfig;
use crate::crdt::GCounter;
use crate::engine::node::decode_output;
use crate::engine::HolonCluster;
use crate::log::Topic;
use crate::net::FaultOverlay;
use crate::nexmark::queries::Query1;
use crate::nexmark::NexmarkGen;
use crate::util::{NodeId, SimTime};
use crate::wcrdt::WindowedCrdt;

use super::plan::{FaultAction, FaultPlan};

/// Shape of a simulation run. Tuned so one run takes well under a
/// wall-second while still exercising kills mid-processing: the modeled
/// per-event cost is inflated (vs. the calibrated 4.9 µs) so consuming
/// the pre-seeded log spans a few sim-seconds instead of finishing
/// before the first fault lands.
#[derive(Debug, Clone)]
pub struct SimSpec {
    pub seed: u64,
    pub nodes: u32,
    pub partitions: u32,
    pub events_per_sec_per_partition: u64,
    pub duration_ms: SimTime,
    pub window_ms: u64,
    pub wall_ms_per_sim_sec: f64,
    /// Post-plan settling time before the graceful stop (heal + gossip
    /// convergence + emission of the remaining completed windows).
    pub drain_ms: SimTime,
    /// Run with the flight recorder on and harvest the Chrome
    /// `trace_event` dump into [`RunArtifacts::trace_json`]. Off for
    /// exploration runs; the harness flips it on for the post-shrink
    /// repro run so every oracle failure ships with a timeline.
    pub trace: bool,
}

impl Default for SimSpec {
    fn default() -> Self {
        Self {
            seed: 1,
            nodes: 4,
            partitions: 8,
            events_per_sec_per_partition: 1000,
            duration_ms: 6000,
            window_ms: 1000,
            wall_ms_per_sim_sec: 50.0,
            drain_ms: 4000,
            trace: false,
        }
    }
}

impl SimSpec {
    /// The engine configuration of a run.
    pub fn config(&self) -> HolonConfig {
        HolonConfig {
            nodes: self.nodes,
            partitions: self.partitions,
            events_per_sec_per_partition: self.events_per_sec_per_partition,
            seed: self.seed,
            wall_ms_per_sim_sec: self.wall_ms_per_sim_sec,
            duration_ms: self.duration_ms,
            window_ms: self.window_ms,
            batch_size: 256,
            gossip_interval_ms: 50,
            checkpoint_interval_ms: 400,
            heartbeat_interval_ms: 150,
            failure_timeout_ms: 600,
            // ~5 events per sim-ms per node: the 48k-event input takes a
            // few sim-seconds to consume, so faults land mid-processing.
            holon_event_cost_us: 200.0,
            trace: self.trace,
            ..HolonConfig::default()
        }
    }

    /// The sim-time window fault events are generated inside.
    pub fn fault_window(&self) -> (SimTime, SimTime) {
        (300, self.duration_ms / 2)
    }
}

/// A deliberately injected defect, used to *verify the oracles* (the
/// mutation check of the harness itself): each variant corrupts the
/// collected artifacts the way a real engine/sink bug would, and the
/// corresponding oracle must catch it.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Mutation {
    /// A replayed output leaks past dedup (broken sink dedup).
    DuplicateDelivery,
    /// One output is lost (gap in the per-partition sequence).
    DropDelivery,
    /// One output payload is corrupted (broken determinism).
    CorruptPayload,
    /// One surviving replica diverges (broken convergence).
    SkewReplica,
}

/// Everything harvested from one run.
#[derive(Debug, Clone)]
pub struct RunArtifacts {
    pub partitions: u32,
    /// Per partition: every physical output record `(seq, inner)`, in
    /// append order — duplicates included.
    pub raw: Vec<Vec<(u64, Vec<u8>)>>,
    /// Per partition: first delivery per sequence number, seq-ordered.
    pub deduped: Vec<Vec<(u64, Vec<u8>)>>,
    /// Encoded final shared replicas of gracefully stopped nodes.
    pub replicas: BTreeMap<NodeId, Vec<u8>>,
    /// Work-stealing count (plan effectiveness signal, not an oracle).
    pub steals: u64,
    /// Chrome `trace_event` dump of the run — present only when
    /// [`SimSpec::trace`] was set.
    pub trace_json: Option<String>,
}

/// Pre-seed a byte-identical input log: event timestamps are a pure
/// function of the index, so every run of the same seed — fault-free or
/// faulty — processes the exact same stream.
fn seed_input(input: &Topic, cfg: &HolonConfig) {
    for p in 0..cfg.partitions {
        let mut gen = NexmarkGen::new(cfg.seed, p);
        let n = cfg.events_per_sec_per_partition * cfg.duration_ms / 1000;
        let batch: Vec<(u64, Vec<u8>)> = (0..n)
            .map(|i| {
                let ts = i * 1000 / cfg.events_per_sec_per_partition;
                (ts, gen.next_event().to_bytes())
            })
            .collect();
        input.append_batch(p, batch);
    }
}

/// Run `plan` against a fresh cluster; optionally corrupt the artifacts
/// with `mutation` before returning (oracle self-checks only). Runs the
/// harness's canonical workload (Query1); [`run_plan_with`] executes
/// the same seeded schedule against any other processor.
pub fn run_plan(spec: &SimSpec, plan: &FaultPlan, mutation: Option<Mutation>) -> RunArtifacts {
    run_plan_with(spec, plan, mutation, Query1::new(spec.window_ms))
}

/// As [`run_plan`], generic over the query: the differential tests in
/// `tests/determinism.rs` drive sharded and unsharded keyed pipelines
/// through the *same* seeded fault schedule and compare outputs byte
/// for byte.
///
/// Caveat: of the oracle suite, only the output-side checks
/// ([`super::oracle::check_exactly_once`] /
/// [`super::oracle::check_determinism`]) are processor-generic.
/// [`super::oracle::check_convergence`] decodes the harvested replicas
/// as Query1's `WindowedCrdt<GCounter>` and will report
/// `CorruptReplica` for any other shared-state type — don't feed
/// non-Query1 artifacts through `check_run`.
pub fn run_plan_with<P: crate::api::Processor>(
    spec: &SimSpec,
    plan: &FaultPlan,
    mutation: Option<Mutation>,
    processor: P,
) -> RunArtifacts {
    let cfg = spec.config();
    let clock = SimClock::scaled(cfg.wall_ms_per_sim_sec);
    let cluster = HolonCluster::start_with_clock(cfg.clone(), processor, clock.clone());
    seed_input(&cluster.input, &cfg);

    // Expand bursts into primitive (time, step) pairs. Bursts carry an
    // id so overlapping bursts compose instead of stomping each other.
    enum Step {
        Kill(NodeId),
        Start(NodeId),
        Partition(Vec<NodeId>),
        Heal,
        BurstStart(usize, FaultOverlay),
        BurstEnd(usize),
    }
    let mut steps: Vec<(SimTime, Step)> = Vec::new();
    let mut burst_id = 0usize;
    let mut burst = |steps: &mut Vec<(SimTime, Step)>, at: SimTime, dur: SimTime, o: FaultOverlay| {
        steps.push((at, Step::BurstStart(burst_id, o)));
        steps.push((at + dur, Step::BurstEnd(burst_id)));
        burst_id += 1;
    };
    for e in &plan.events {
        match &e.action {
            FaultAction::Kill(n) => steps.push((e.at_ms, Step::Kill(*n))),
            FaultAction::Restart(n) | FaultAction::AddNode(n) => {
                steps.push((e.at_ms, Step::Start(*n)))
            }
            FaultAction::Partition(g) => steps.push((e.at_ms, Step::Partition(g.clone()))),
            FaultAction::Heal => steps.push((e.at_ms, Step::Heal)),
            FaultAction::Loss { pct, duration_ms } => burst(
                &mut steps,
                e.at_ms,
                *duration_ms,
                FaultOverlay {
                    extra_delay_ms: 0,
                    extra_drop_prob: f64::from(*pct) / 100.0,
                },
            ),
            FaultAction::Delay {
                extra_ms,
                duration_ms,
            } => burst(
                &mut steps,
                e.at_ms,
                *duration_ms,
                FaultOverlay {
                    extra_delay_ms: *extra_ms,
                    extra_drop_prob: 0.0,
                },
            ),
        }
    }
    steps.sort_by_key(|(t, _)| *t);

    // Active bursts compose: delays add, losses combine independently.
    let compose = |active: &Vec<(usize, FaultOverlay)>| -> FaultOverlay {
        let mut delay = 0;
        let mut keep = 1.0;
        for (_, o) in active {
            delay += o.extra_delay_ms;
            keep *= 1.0 - o.extra_drop_prob;
        }
        FaultOverlay {
            extra_delay_ms: delay,
            extra_drop_prob: 1.0 - keep,
        }
    };
    // The in-effect cut (the plan's listed group), re-applied whenever
    // membership changes so nodes restarted/added during the cut join
    // the "everyone else" side instead of landing in no group at all.
    let apply_cut = |cut: &Option<Vec<NodeId>>, alive: &BTreeSet<NodeId>| match cut {
        None => cluster.bus.heal_partition(),
        Some(group) => {
            let a: Vec<NodeId> = group.iter().copied().filter(|n| alive.contains(n)).collect();
            let b: Vec<NodeId> = alive.iter().copied().filter(|n| !a.contains(n)).collect();
            if a.is_empty() || b.is_empty() {
                // one side is gone: no cross-cut left to enforce
                cluster.bus.heal_partition();
            } else {
                cluster.bus.set_partition(&[a.as_slice(), b.as_slice()]);
            }
        }
    };

    // Execute. The alive set mirrors the cluster so shrunk or
    // hand-written plans (e.g. a Restart whose Kill was dropped) stay
    // executable: impossible steps are skipped, not fatal.
    let mut alive: BTreeSet<NodeId> = (0..cfg.nodes).collect();
    let mut cut: Option<Vec<NodeId>> = None;
    let mut bursts: Vec<(usize, FaultOverlay)> = Vec::new();
    let mut last_t = 0;
    for (t, step) in steps {
        clock.sleep_until(t);
        last_t = last_t.max(t);
        match step {
            Step::Kill(n) => {
                if alive.len() > 1 && alive.remove(&n) {
                    cluster.fail_node(n);
                    if cut.is_some() {
                        apply_cut(&cut, &alive);
                    }
                }
            }
            Step::Start(n) => {
                if alive.insert(n) {
                    if n >= cfg.nodes {
                        cluster.add_node(n); // reconfiguration: fresh id
                    } else {
                        cluster.restart_node(n);
                    }
                    if cut.is_some() {
                        apply_cut(&cut, &alive);
                    }
                }
            }
            Step::Partition(group) => {
                cut = Some(group);
                apply_cut(&cut, &alive);
            }
            Step::Heal => {
                cut = None;
                cluster.bus.heal_partition();
            }
            Step::BurstStart(id, o) => {
                bursts.push((id, o));
                cluster.bus.set_fault_overlay(compose(&bursts));
            }
            Step::BurstEnd(id) => {
                bursts.retain(|(i, _)| *i != id);
                cluster.bus.set_fault_overlay(compose(&bursts));
            }
        }
    }

    // End of schedule: restore the network, drain, stop gracefully.
    clock.sleep_until(spec.duration_ms.max(last_t));
    cluster.bus.heal_partition();
    cluster.bus.clear_fault_overlay();
    clock.sleep_until(spec.duration_ms.max(last_t) + spec.drain_ms);
    cluster.stop();

    // Harvest.
    let (raw, deduped) = collect_outputs(&cluster.output, cfg.partitions);
    let mut artifacts = RunArtifacts {
        partitions: cfg.partitions,
        raw,
        deduped,
        replicas: cluster.final_replicas(),
        steals: cluster
            .metrics
            .steals
            .load(std::sync::atomic::Ordering::Acquire),
        trace_json: cluster
            .tracer
            .is_enabled()
            .then(|| cluster.tracer.chrome_trace_json(&cluster.metrics.counter_snapshot())),
    };
    if let Some(m) = mutation {
        apply_mutation(&mut artifacts, m);
    }
    artifacts
}

/// Harvest an output topic into per-partition `(seq, inner)` streams:
/// every physical record in append order, and the first delivery per
/// sequence number in seq order. Shared by [`run_plan`] and scenario
/// tests that assert [`super::oracle::check_exactly_once`] on a
/// hand-driven cluster.
#[allow(clippy::type_complexity)]
pub fn collect_outputs(
    output: &Topic,
    partitions: u32,
) -> (Vec<Vec<(u64, Vec<u8>)>>, Vec<Vec<(u64, Vec<u8>)>>) {
    let mut raw = Vec::with_capacity(partitions as usize);
    let mut deduped = Vec::with_capacity(partitions as usize);
    for p in 0..partitions {
        let (recs, _) = output.read(p, 0, usize::MAX >> 1);
        let mut all = Vec::with_capacity(recs.len());
        let mut first: BTreeMap<u64, Vec<u8>> = BTreeMap::new();
        for rec in recs {
            if let Some((seq, _ts, inner)) = decode_output(&rec.payload) {
                first.entry(seq).or_insert_with(|| inner.to_vec());
                all.push((seq, inner.to_vec()));
            }
        }
        raw.push(all);
        deduped.push(first.into_iter().collect::<Vec<_>>());
    }
    (raw, deduped)
}

/// Corrupt the artifacts the way the named defect would (dev-only).
fn apply_mutation(a: &mut RunArtifacts, m: Mutation) {
    match m {
        Mutation::DuplicateDelivery => {
            // a replayed output slips past dedup on the busiest partition
            if let Some(part) = a.deduped.iter_mut().max_by_key(|p| p.len()) {
                if let Some(mid) = part.get(part.len() / 2).cloned() {
                    part.insert(part.len() / 2, mid);
                }
            }
        }
        Mutation::DropDelivery => {
            if let Some(part) = a.deduped.iter_mut().max_by_key(|p| p.len()) {
                if part.len() > 1 {
                    part.remove(part.len() / 2);
                }
            }
        }
        Mutation::CorruptPayload => {
            if let Some(part) = a.deduped.iter_mut().max_by_key(|p| p.len()) {
                if let Some((_, payload)) = part.last_mut() {
                    if let Some(b) = payload.last_mut() {
                        *b ^= 0xFF;
                    } else {
                        payload.push(0xFF);
                    }
                }
            }
        }
        Mutation::SkewReplica => {
            // Decodable-but-divergent: graft a phantom contribution into
            // the replica's oldest live window *without* touching its
            // progress map, so the convergence oracle's window-value
            // comparison (not just the decode guard) must catch it.
            if let Some(bytes) = a.replicas.values_mut().next() {
                match WindowedCrdt::<GCounter>::from_bytes(bytes) {
                    Ok(mut w) => {
                        let assigner = w.assigner();
                        let ts = assigner.window_start(w.first_available());
                        let mut skew: WindowedCrdt<GCounter> =
                            WindowedCrdt::new(assigner, std::iter::empty());
                        let _ = skew.insert_with(0, ts, |c| c.add(u64::MAX, 1));
                        // lint:allow(discarded-merge): deliberate divergence injection — the mutation test asserts the convergence oracle catches the graft, the outcome is noise
                        let _ = w.merge(&skew);
                        *bytes = w.to_bytes();
                    }
                    Err(_) => {
                        bytes.clear();
                        bytes.push(0xFF);
                    }
                }
            }
        }
    }
}

/// The one-line replayable repro printed on oracle failure.
pub fn repro_line(seed: u64, plan: &FaultPlan) -> String {
    format!(
        "HOLON_SIM_SEED={seed} HOLON_SIM_PLAN='{}' cargo test --release --test simulation replay_from_env -- --nocapture",
        plan.to_plan_string()
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn spec_config_is_consistent() {
        let spec = SimSpec::default();
        let cfg = spec.config();
        assert_eq!(cfg.nodes, spec.nodes);
        assert_eq!(cfg.partitions, spec.partitions);
        assert!(cfg.holon_event_cost_us > 0.0);
        let (lo, hi) = spec.fault_window();
        assert!(lo < hi && hi <= spec.duration_ms);
    }

    #[test]
    fn repro_line_mentions_seed_and_plan() {
        let plan = FaultPlan::parse("500:k1;900:r1").unwrap();
        let line = repro_line(42, &plan);
        assert!(line.contains("HOLON_SIM_SEED=42"));
        assert!(line.contains("500:k1;900:r1"));
    }
}
