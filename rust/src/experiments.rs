//! Experiment drivers shared by the benches, the examples and the CLI:
//! run one system (Holon or the Flink-model baseline) on one workload
//! with a failure schedule, and return the measured series — the raw
//! material for every table and figure in the paper's §5.

use std::sync::atomic::Ordering;

use crate::baseline::{FlinkCluster, FlinkJob};
use crate::clock::SimClock;
use crate::config::HolonConfig;
use crate::engine::{ClusterMetrics, HolonCluster};
use crate::metrics::sensitivity;
use crate::nexmark::producer::{self, Producers};
use crate::nexmark::queries::{Query1, Q0, Q4, Q7};
use crate::util::{NodeId, SimTime};

/// The workloads of §5.1.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Workload {
    Q0,
    Q4,
    Q7,
    Query1,
}

/// The compared systems (Table 2 rows).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SystemKind {
    Holon,
    Flink,
    FlinkSpareSlots,
}

/// One failure-injection action at a sim-time offset.
#[derive(Debug, Clone)]
pub enum Action {
    Fail(NodeId),
    Restart(NodeId),
    NetSplit(Vec<Vec<NodeId>>),
    NetHeal,
}

/// A scheduled action.
#[derive(Debug, Clone)]
pub struct FailureEvent {
    pub at_ms: SimTime,
    pub action: Action,
}

impl FailureEvent {
    pub fn fail(at_ms: SimTime, node: NodeId) -> Self {
        Self {
            at_ms,
            action: Action::Fail(node),
        }
    }

    pub fn restart(at_ms: SimTime, node: NodeId) -> Self {
        Self {
            at_ms,
            action: Action::Restart(node),
        }
    }
}

/// The §5.2 failure scenarios.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Scenario {
    /// no failures
    Baseline,
    /// two nodes failed at the same time, restarted 10 s later
    ConcurrentFailures,
    /// two nodes failed 5 s apart, each restarted 10 s after failing
    SubsequentFailures,
    /// two nodes failed and never restarted
    CrashFailures,
}

impl Scenario {
    /// The paper's injection schedule, starting at `t0` sim-ms.
    pub fn schedule(self, t0: SimTime) -> Vec<FailureEvent> {
        match self {
            Scenario::Baseline => vec![],
            Scenario::ConcurrentFailures => vec![
                FailureEvent::fail(t0, 1),
                FailureEvent::fail(t0, 2),
                FailureEvent::restart(t0 + 10_000, 1),
                FailureEvent::restart(t0 + 10_000, 2),
            ],
            Scenario::SubsequentFailures => vec![
                FailureEvent::fail(t0, 1),
                FailureEvent::fail(t0 + 5_000, 2),
                FailureEvent::restart(t0 + 10_000, 1),
                FailureEvent::restart(t0 + 15_000, 2),
            ],
            Scenario::CrashFailures => vec![
                FailureEvent::fail(t0, 1),
                FailureEvent::fail(t0, 2),
            ],
        }
    }

    pub fn all() -> [Scenario; 4] {
        [
            Scenario::Baseline,
            Scenario::ConcurrentFailures,
            Scenario::SubsequentFailures,
            Scenario::CrashFailures,
        ]
    }

    pub fn name(self) -> &'static str {
        match self {
            Scenario::Baseline => "Baseline",
            Scenario::ConcurrentFailures => "Concurrent Failures",
            Scenario::SubsequentFailures => "Subsequent Failures",
            Scenario::CrashFailures => "Crash Failures",
        }
    }
}

/// Data-plane counters sampled from the substrate after a run — the
/// machine-readable core of the `holon bench` perf trajectory. Fields a
/// substrate lacks (the baseline has no gossip bus) read zero.
#[derive(Debug, Clone, Default)]
pub struct DataPlaneStats {
    /// Gossip rounds sent across all nodes.
    pub gossip_msgs: u64,
    /// Encoded gossip payload bytes (one encode per round); the ratio
    /// `gossip_bytes_wire / gossip_bytes_encoded` is the fan-out the
    /// shared-`Arc` encode amortizes over.
    pub gossip_bytes_encoded: u64,
    /// Logical wire bytes enqueued on the bus (per-recipient volume).
    pub gossip_bytes_wire: u64,
    /// Records materialized by the copying `Topic::read` path — the
    /// allocations-per-event proxy. Pre-overhaul this equaled
    /// `records_read`; the zero-copy hot path keeps it at ~0.
    pub payload_clones: u64,
    /// Records visited by any read path (the clone-counter denominator).
    pub records_read: u64,
    /// Output sequence numbers skipped by the sink (lost outputs — must
    /// be zero in a correct run).
    pub gaps: u64,
    /// Physical duplicates dropped by the sink.
    pub duplicates: u64,
    /// Gossip payloads whose join inflated the receiving replica
    /// (change-reporting merges, Crdt trait v3).
    pub merge_changed: u64,
    /// Gossip payloads whose join was a complete no-op on the receiver.
    pub merge_noop: u64,
    /// Bytes of received payloads whose join was a complete no-op
    /// (whole-payload granularity — a partially-redundant payload
    /// counts zero). The redundancy the anti-entropy duty cycle pays on
    /// purpose; once nothing diverges, full-sync payloads land here and
    /// delta rounds contribute ~nothing.
    pub redundant_gossip_bytes: u64,
    /// Delta rounds skipped entirely (nothing dirty, no watermark
    /// movement): no encode, no broadcast.
    pub gossip_skipped: u64,
    /// Encoded gossip bytes per shard (index = shard id) for sharded
    /// keyed state; empty for unsharded queries. Deltas skip clean
    /// shards, so the distribution shows how much of the map each
    /// round actually re-shipped.
    pub shard_gossip_bytes: Vec<u64>,
    /// Sharded-state merges that ran on the parallel shard pool.
    pub shard_parallel_merges: u64,
    /// Sharded-state merges that ran inline.
    pub shard_serial_merges: u64,
    /// Read-path: queries answered (point + range + top-k) by query
    /// engines attached to this run; zero for write-only scenarios.
    pub queries_served: u64,
    /// Read-path: queries where the signature pre-filter pruned work.
    pub query_index_hits: u64,
    /// Read-path: queries the pre-filter could not narrow.
    pub query_index_misses: u64,
    /// Read-path: state rows the pre-filter excluded from scans — the
    /// index's measurable win (acceptance counter).
    pub query_scan_rows_avoided: u64,
    /// Read-path high-water mark: most feed items any live changefeed
    /// subscriber was observed behind its node's publish head.
    pub changefeed_lag: u64,
    /// Backpressure: high-water mark of any sender's per-peer outbound
    /// queue depth (parked + unflushed messages).
    pub outbound_queue_depth_max: u64,
    /// Backpressure: node-loop iterations that shrank the event budget
    /// because a peer advertised zero credits or the last flush parked
    /// traffic. Zero when `inbox_capacity` is unset.
    pub credits_stalled_rounds: u64,
    /// Backpressure: high-water mark of any receiver's inbox depth —
    /// bounded by `inbox_capacity` when the cap is set.
    pub inbox_depth_max: u64,
    /// Output-path: bytes shipped through per-batch output arenas (one
    /// shared backing `Arc` per batch — the zero-alloc write side).
    pub output_arena_bytes: u64,
    /// Output-path: frames (output records) written into arenas.
    pub output_frames: u64,
    /// Window-store inserts that fell outside the dense ring horizon
    /// into the spill map; ~0 in a healthy run.
    pub window_ring_spills: u64,
    /// Stage latency, ingest: sim-ms a batch's oldest record sat queued
    /// in the input log before pickup (p50).
    pub stage_latency_ingest_p50_ms: u64,
    /// Stage latency, ingest p99.
    pub stage_latency_ingest_p99_ms: u64,
    /// Stage latency, fire: sim-ms between a window's event-time end
    /// and the global watermark floor passing it (p50).
    pub stage_latency_fire_p50_ms: u64,
    /// Stage latency, fire p99.
    pub stage_latency_fire_p99_ms: u64,
    /// Stage latency, converge: sim-ms from window end to the output's
    /// append in the output log (p50) — the paper's end-to-end latency.
    pub stage_latency_converge_p50_ms: u64,
    /// Stage latency, converge p99.
    pub stage_latency_converge_p99_ms: u64,
    /// Stage latency, emit: sim-ms from output-log append to sink
    /// pickup (p50) — consumer-side queueing only.
    pub stage_latency_emit_p50_ms: u64,
    /// Stage latency, emit p99.
    pub stage_latency_emit_p99_ms: u64,
    /// Flight-recorder events overwritten before export (ring
    /// wraparound); zero when tracing is off.
    pub trace_dropped_events: u64,
}

/// Measurements of one run.
#[derive(Debug, Clone)]
pub struct RunResult {
    pub system: SystemKind,
    pub workload: Workload,
    /// mean end-to-end latency over deduplicated outputs, sim-ms
    pub latency_mean_ms: f64,
    /// median end-to-end latency, sim-ms
    pub latency_p50_ms: u64,
    /// p99 end-to-end latency, sim-ms
    pub latency_p99_ms: u64,
    /// per-bucket mean latency (bucket = 500 sim-ms), for Figs 6/7
    pub latency_series: Vec<Option<f64>>,
    /// per-bucket consumed events/s, for Fig 6
    pub throughput_series: Vec<f64>,
    /// deduplicated outputs delivered
    pub outputs: u64,
    /// events produced into the input topic
    pub produced: u64,
    /// total events consumed by the system
    pub consumed: u64,
    /// peak per-bucket consumption rate (events/s) — §5.3 max throughput
    pub peak_throughput: f64,
    /// work-stealing count (Holon only)
    pub steals: u64,
    /// true when the system stopped delivering outputs well before the
    /// end of the run (Table 2's "–": a crashed baseline with no spare
    /// slots stalls permanently).
    pub stalled: bool,
    /// hot-path substrate counters (gossip volume, payload clones, …)
    pub data_plane: DataPlaneStats,
}

/// Buckets excluded from sensitivity comparisons (startup transient:
/// membership convergence + first windows; failures are injected well
/// after this warmup).
const SENSITIVITY_WARMUP_BUCKETS: usize = 20; // 10 sim-seconds

impl RunResult {
    /// Sensitivity vs a baseline run (paper Figs 7/8): the area between
    /// the latency curves after the warmup transient, in seconds².
    pub fn sensitivity_vs(&self, baseline: &RunResult) -> f64 {
        let skip = SENSITIVITY_WARMUP_BUCKETS.min(self.latency_series.len());
        let skip_b = SENSITIVITY_WARMUP_BUCKETS.min(baseline.latency_series.len());
        sensitivity(
            &self.latency_series[skip..],
            &baseline.latency_series[skip_b..],
            500,
        )
    }
}

/// Sample the data-plane counters shared by both engines; `bus` is
/// `None` for the baseline (no gossip bus).
fn data_plane_stats(
    metrics: &ClusterMetrics,
    input: &crate::log::Topic,
    output: &crate::log::Topic,
    bus: Option<&crate::net::Bus>,
) -> DataPlaneStats {
    let (in_clones, in_read) = input.read_stats();
    let (out_clones, out_read) = output.read_stats();
    DataPlaneStats {
        gossip_msgs: metrics.gossip_sent.load(Ordering::Acquire),
        gossip_bytes_encoded: metrics.gossip_payload_bytes.load(Ordering::Acquire),
        gossip_bytes_wire: bus.map_or(0, |b| b.bytes_sent()),
        payload_clones: in_clones + out_clones,
        records_read: in_read + out_read,
        gaps: metrics.gaps.load(Ordering::Acquire),
        duplicates: metrics.duplicates.load(Ordering::Acquire),
        merge_changed: metrics.merge_changed.load(Ordering::Acquire),
        merge_noop: metrics.merge_noop.load(Ordering::Acquire),
        redundant_gossip_bytes: metrics.redundant_gossip_bytes.load(Ordering::Acquire),
        gossip_skipped: metrics.gossip_skipped.load(Ordering::Acquire),
        shard_gossip_bytes: metrics.shard_gossip_bytes.lock().unwrap().clone(),
        shard_parallel_merges: metrics.shard_parallel_merges.load(Ordering::Acquire),
        shard_serial_merges: metrics.shard_serial_merges.load(Ordering::Acquire),
        queries_served: metrics.queries_served.load(Ordering::Acquire),
        query_index_hits: metrics.query_index_hits.load(Ordering::Acquire),
        query_index_misses: metrics.query_index_misses.load(Ordering::Acquire),
        query_scan_rows_avoided: metrics.query_scan_rows_avoided.load(Ordering::Acquire),
        changefeed_lag: metrics.changefeed_lag.load(Ordering::Acquire),
        outbound_queue_depth_max: bus.map_or(0, |b| b.outbound_depth_max()),
        credits_stalled_rounds: metrics.credits_stalled_rounds.load(Ordering::Acquire),
        inbox_depth_max: bus.map_or(0, |b| b.inbox_depth_max()),
        output_arena_bytes: metrics.output_arena_bytes.load(Ordering::Acquire),
        output_frames: metrics.output_frames.load(Ordering::Acquire),
        window_ring_spills: metrics.window_ring_spills.load(Ordering::Acquire),
        stage_latency_ingest_p50_ms: metrics.stage_ingest.p50(),
        stage_latency_ingest_p99_ms: metrics.stage_ingest.p99(),
        stage_latency_fire_p50_ms: metrics.stage_fire.p50(),
        stage_latency_fire_p99_ms: metrics.stage_fire.p99(),
        stage_latency_converge_p50_ms: metrics.stage_converge.p50(),
        stage_latency_converge_p99_ms: metrics.stage_converge.p99(),
        stage_latency_emit_p50_ms: metrics.stage_emit.p50(),
        stage_latency_emit_p99_ms: metrics.stage_emit.p99(),
        trace_dropped_events: metrics.trace_dropped_events.load(Ordering::Acquire),
    }
}

fn collect(
    system: SystemKind,
    workload: Workload,
    metrics: &ClusterMetrics,
    produced: u64,
    duration_ms: SimTime,
    data_plane: DataPlaneStats,
) -> RunResult {
    // pad both series to the full run duration so a stalled system's
    // silent tail is visible (bucket width = 500 sim-ms)
    let buckets = (duration_ms / 500) as usize;
    let mut lat = metrics.latency_series.means();
    if lat.len() < buckets {
        lat.resize(buckets, None);
    }
    let mut throughput_series = metrics.processed.rates_per_sec();
    if throughput_series.len() < buckets {
        throughput_series.resize(buckets, 0.0);
    }
    // stalled: no outputs at all in the last third of the run
    let tail_start = lat.len().saturating_sub(lat.len() / 3);
    let stalled = !lat.is_empty() && lat[tail_start..].iter().all(|v| v.is_none());
    // ignore first + last buckets when finding the peak (partial buckets)
    let peak = throughput_series
        .iter()
        .copied()
        .take(throughput_series.len().saturating_sub(1))
        .skip(1)
        .fold(0.0, f64::max);
    RunResult {
        system,
        workload,
        latency_mean_ms: metrics.latency.mean(),
        latency_p50_ms: metrics.latency.p50(),
        latency_p99_ms: metrics.latency.p99(),
        latency_series: lat,
        throughput_series: throughput_series.clone(),
        outputs: metrics.outputs.load(Ordering::Acquire),
        produced,
        consumed: metrics.processed.counts().iter().sum(),
        peak_throughput: peak,
        steals: metrics.steals.load(Ordering::Acquire),
        stalled,
        data_plane,
    }
}

/// Drive a failure schedule against callbacks while the workload runs.
fn drive(
    clock: &SimClock,
    duration_ms: SimTime,
    drain_ms: SimTime,
    mut schedule: Vec<FailureEvent>,
    mut apply: impl FnMut(&Action),
) {
    schedule.sort_by_key(|e| e.at_ms);
    let start = clock.now();
    for ev in schedule {
        let target = start + ev.at_ms;
        let now = clock.now();
        if target > now {
            std::thread::sleep(clock.wall_for(target - now));
        }
        apply(&ev.action);
    }
    let end = start + duration_ms + drain_ms;
    let now = clock.now();
    if end > now {
        std::thread::sleep(clock.wall_for(end - now));
    }
}

/// Run a Holon cluster on `workload` with a failure schedule.
pub fn run_holon(
    cfg: &HolonConfig,
    workload: Workload,
    schedule: Vec<FailureEvent>,
) -> RunResult {
    let cfg = cfg.clone();
    match workload {
        Workload::Q0 => run_holon_with(cfg, workload, Q0, schedule),
        Workload::Q4 => {
            if cfg.shard_count > 0 {
                // `--shard-count=N`: the same keyed query over sharded
                // state (byte-identical outputs; see determinism tests)
                let q = crate::nexmark::queries::dataflow_q4_sharded(
                    cfg.window_ms,
                    cfg.shard_count,
                );
                run_holon_with(cfg, workload, q, schedule)
            } else {
                let q = Q4::new(cfg.window_ms);
                run_holon_with(cfg, workload, q, schedule)
            }
        }
        Workload::Q7 => {
            let q = Q7::new(cfg.window_ms);
            run_holon_with(cfg, workload, q, schedule)
        }
        Workload::Query1 => {
            let q = Query1::new(cfg.window_ms);
            run_holon_with(cfg, workload, q, schedule)
        }
    }
}

fn run_holon_with<P: crate::api::Processor>(
    cfg: HolonConfig,
    workload: Workload,
    processor: P,
    schedule: Vec<FailureEvent>,
) -> RunResult {
    let clock = SimClock::scaled(cfg.wall_ms_per_sim_sec);
    let cluster = HolonCluster::start_with_clock(cfg.clone(), processor, clock.clone());
    let prod = spawn_producer(&cfg, &cluster.input, &clock);
    let c2 = cluster.clone();
    drive(
        &clock,
        cfg.duration_ms,
        drain_ms(&cfg),
        schedule,
        move |action| match action {
            Action::Fail(n) => c2.fail_node(*n),
            Action::Restart(n) => c2.restart_node(*n),
            Action::NetSplit(groups) => {
                let refs: Vec<&[NodeId]> = groups.iter().map(|g| g.as_slice()).collect();
                c2.bus.set_partition(&refs);
            }
            Action::NetHeal => c2.bus.heal_partition(),
        },
    );
    let produced = prod.stop();
    cluster.stop();
    // Flight-recorder export: a traced run with a destination writes
    // the Chrome trace_event dump next to the metrics it explains
    // (open in Perfetto / chrome://tracing).
    if cfg.trace && !cfg.trace_out.is_empty() {
        let json = cluster.tracer.chrome_trace_json(&cluster.metrics.counter_snapshot());
        match std::fs::write(&cfg.trace_out, json.as_bytes()) {
            Ok(()) => println!("trace dump written to {}", cfg.trace_out),
            Err(e) => eprintln!("warning: could not write trace dump {}: {e}", cfg.trace_out),
        }
    }
    let dp = data_plane_stats(&cluster.metrics, &cluster.input, &cluster.output, Some(&cluster.bus));
    collect(SystemKind::Holon, workload, &cluster.metrics, produced, cfg.duration_ms, dp)
}

/// Run the Flink-model baseline on `workload` with a failure schedule.
pub fn run_flink(
    cfg: &HolonConfig,
    workload: Workload,
    spare_slots: bool,
    schedule: Vec<FailureEvent>,
) -> RunResult {
    let mut cfg = cfg.clone();
    cfg.flink_spare_slots = spare_slots;
    let job = match workload {
        Workload::Q0 => FlinkJob::PassThrough,
        Workload::Q4 => FlinkJob::AvgByCategory,
        Workload::Q7 => FlinkJob::MaxBid,
        Workload::Query1 => {
            panic!("Query1 is the paper's running example for the Holon model only")
        }
    };
    let clock = SimClock::scaled(cfg.wall_ms_per_sim_sec);
    let cluster = FlinkCluster::start_with_clock(cfg.clone(), job, clock.clone());
    let prod = spawn_producer(&cfg, &cluster.input, &clock);
    let c2 = cluster.clone();
    drive(
        &clock,
        cfg.duration_ms,
        drain_ms(&cfg),
        schedule,
        move |action| match action {
            Action::Fail(n) => c2.fail_node(*n),
            Action::Restart(n) => c2.restart_node(*n),
            // the baseline model has no gossip bus; a network split is
            // equivalent to failing the minority side's TMs
            Action::NetSplit(groups) => {
                if let Some(minority) = groups.iter().min_by_key(|g| g.len()) {
                    for &n in minority {
                        c2.fail_node(n);
                    }
                }
            }
            Action::NetHeal => {}
        },
    );
    let produced = prod.stop();
    cluster.stop();
    let kind = if spare_slots {
        SystemKind::FlinkSpareSlots
    } else {
        SystemKind::Flink
    };
    let dp = data_plane_stats(&cluster.metrics, &cluster.input, &cluster.output, None);
    collect(kind, workload, &cluster.metrics, produced, cfg.duration_ms, dp)
}

fn spawn_producer(
    cfg: &HolonConfig,
    input: &std::sync::Arc<crate::log::Topic>,
    clock: &SimClock,
) -> Producers {
    producer::spawn(
        input.clone(),
        clock.clone(),
        cfg.seed,
        cfg.events_per_sec_per_partition,
        cfg.duration_ms,
    )
}

/// Post-experiment drain time: enough for final windows + recovery tails.
fn drain_ms(cfg: &HolonConfig) -> SimTime {
    (cfg.window_ms * 4).max(4000)
}

/// The §5.3 exponential ingestion ramp — the ONE rate curve every
/// compared system sees (doubles every 2 sim-seconds, capped at 2^8 =
/// 256× so total volume stays bounded). Holon/baseline and
/// sharded/unsharded rows are only comparable because they share this.
fn throughput_ramp(base_events_per_sec: u64) -> impl Fn(SimTime) -> u64 {
    let base = base_events_per_sec.max(1);
    move |t: SimTime| base.saturating_mul(1 << (t / 2000).min(8))
}

/// The §5.3 max-throughput experiment: ramp the ingestion rate
/// exponentially and report the peak sustained consumption rate.
pub fn run_max_throughput(
    cfg: &HolonConfig,
    workload: Workload,
    holon: bool,
) -> RunResult {
    if holon {
        match workload {
            Workload::Q7 => run_max_throughput_with(cfg, workload, Q7::new(cfg.window_ms)),
            Workload::Q4 => run_max_throughput_with(cfg, workload, Q4::new(cfg.window_ms)),
            _ => panic!("max-throughput experiment uses Q4/Q7"),
        }
    } else {
        let cfg = cfg.clone();
        let job = match workload {
            Workload::Q4 => FlinkJob::AvgByCategory,
            Workload::Q7 => FlinkJob::MaxBid,
            _ => panic!("max-throughput experiment uses Q4/Q7"),
        };
        let clock = SimClock::scaled(cfg.wall_ms_per_sim_sec);
        let cluster = FlinkCluster::start_with_clock(cfg.clone(), job, clock.clone());
        let prod = producer::spawn_ramped_pooled(
            cluster.input.clone(),
            clock.clone(),
            cfg.seed,
            throughput_ramp(cfg.events_per_sec_per_partition),
            cfg.duration_ms,
            65_536,
        );
        std::thread::sleep(clock.wall_for(cfg.duration_ms + drain_ms(&cfg)));
        let produced = prod.stop();
        cluster.stop();
        let dp = data_plane_stats(&cluster.metrics, &cluster.input, &cluster.output, None);
        collect(SystemKind::Flink, workload, &cluster.metrics, produced, cfg.duration_ms, dp)
    }
}

/// The Holon side of the §5.3 ramp over an arbitrary processor — how
/// the bench suite compares sharded and unsharded variants of the same
/// keyed workload (`workload` only labels the report row).
pub fn run_max_throughput_with<P: crate::api::Processor>(
    cfg: &HolonConfig,
    workload: Workload,
    processor: P,
) -> RunResult {
    let cfg = cfg.clone();
    let clock = SimClock::scaled(cfg.wall_ms_per_sim_sec);
    let rate = throughput_ramp(cfg.events_per_sec_per_partition);
    let cluster = HolonCluster::start_with_clock(cfg.clone(), processor, clock.clone());
    let prod = producer::spawn_ramped_pooled(
        cluster.input.clone(),
        clock.clone(),
        cfg.seed,
        rate,
        cfg.duration_ms,
        65_536,
    );
    std::thread::sleep(clock.wall_for(cfg.duration_ms + drain_ms(&cfg)));
    let produced = prod.stop();
    cluster.stop();
    let dp = data_plane_stats(&cluster.metrics, &cluster.input, &cluster.output, Some(&cluster.bus));
    collect(SystemKind::Holon, workload, &cluster.metrics, produced, cfg.duration_ms, dp)
}

/// Access pattern of the mixed read/write bench reader.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ReadMode {
    /// Point lookups: every live category plus a spread of absent keys
    /// (the absent keys exercise the signature pre-filter's pruning).
    Point,
    /// Range + top-k scans over the category space.
    Scan,
}

/// Mixed read/write run: the Q4 keyed workload writes while a reader
/// thread serves queries off node 0's replica through the changefeed —
/// bootstrap from snapshot, apply deltas by cursor, query the newest
/// completed window each round with `staleness = window_ms`. The
/// reader's [`crate::query::QueryStats`] and the changefeed lag land in
/// the run's [`DataPlaneStats`] read-path counters.
pub fn run_mixed_read_write(cfg: &HolonConfig, mode: ReadMode) -> RunResult {
    use crate::crdt::PrefixAgg;
    use crate::nexmark::CATEGORIES;
    use crate::query::QueryEngine;
    use crate::shard::ShardedMapCrdt;
    use std::sync::atomic::AtomicBool;
    use std::sync::Arc;

    let mut cfg = cfg.clone();
    cfg.gossip_delta = true; // the changefeed's delta stream is the point
    let shards = if cfg.shard_count > 0 { cfg.shard_count } else { 8 };
    let processor = crate::nexmark::queries::dataflow_q4_sharded(cfg.window_ms, shards);
    let clock = SimClock::scaled(cfg.wall_ms_per_sim_sec);
    let cluster = HolonCluster::start_with_clock(cfg.clone(), processor, clock.clone());
    let prod = spawn_producer(&cfg, &cluster.input, &clock);

    let stop = Arc::new(AtomicBool::new(false));
    let handle = cluster.read_handle(0).expect("node 0 was spawned");
    let reader = {
        let stop = stop.clone();
        let poll_every = clock.wall_for(cfg.gossip_interval_ms.max(1));
        let window_ms = cfg.window_ms;
        std::thread::Builder::new()
            .name("holon-reader".into())
            .spawn(move || {
                type Q4Shared = ShardedMapCrdt<u64, PrefixAgg>;
                let mut engine: Option<QueryEngine<Q4Shared>> = None;
                let mut sub = None;
                let mut folded = crate::query::QueryStats::default();
                let mut lag_hwm = 0u64;
                while !stop.load(Ordering::Acquire) {
                    std::thread::sleep(poll_every);
                    let Some(e) = engine.as_mut() else {
                        // bootstrap once the node's first full-sync round
                        // (or shutdown snapshot) lands
                        if let Some(snap) = handle.snapshot() {
                            if let Ok(fresh) = QueryEngine::from_snapshot(&snap) {
                                sub = Some(handle.subscribe_at(snap.cursor));
                                engine = Some(fresh);
                            }
                        }
                        continue;
                    };
                    let s = sub.as_mut().expect("subscription exists with engine");
                    match s.poll(64) {
                        Ok(items) => {
                            for item in &items {
                                let _ = e.apply_feed(item);
                            }
                        }
                        Err(_gap) => {
                            // fell behind retention: re-bootstrap from the
                            // snapshot, carrying the accumulated stats
                            folded.absorb(&e.take_stats());
                            if let Some(snap) = handle.snapshot() {
                                if let Ok(fresh) = QueryEngine::from_snapshot(&snap) {
                                    sub = Some(handle.subscribe_at(snap.cursor));
                                    engine = Some(fresh);
                                }
                            }
                            continue;
                        }
                    }
                    lag_hwm = lag_hwm.max(handle.max_lag());
                    let Some(wid) = e.state().completed_up_to() else {
                        continue;
                    };
                    if wid < e.state().first_available() {
                        continue; // already compacted past
                    }
                    match mode {
                        ReadMode::Point => {
                            for cat in 0..CATEGORIES {
                                let _ = e.point(wid, &cat, window_ms);
                            }
                            // absent keys: the Bloom prunes these without
                            // consulting state (drives scan_rows_avoided)
                            for i in 0..CATEGORIES {
                                let _ = e.point(wid, &(1_000_000 + i), window_ms);
                            }
                        }
                        ReadMode::Scan => {
                            let _ = e.range(wid, &0, &(CATEGORIES - 1), window_ms);
                            let _ = e.top_k(wid, 3, window_ms);
                        }
                    }
                }
                if let Some(mut e) = engine {
                    folded.absorb(&e.take_stats());
                }
                (folded, lag_hwm)
            })
            .expect("spawn reader")
    };

    drive(&clock, cfg.duration_ms, drain_ms(&cfg), vec![], |_| {});
    let produced = prod.stop();
    stop.store(true, Ordering::Release);
    let (stats, lag_hwm) = reader.join().expect("reader thread");
    cluster.stop();
    cluster.metrics.add_query_stats(&stats);
    cluster
        .metrics
        .changefeed_lag
        .fetch_max(lag_hwm, Ordering::Relaxed);
    let dp = data_plane_stats(&cluster.metrics, &cluster.input, &cluster.output, Some(&cluster.bus));
    collect(SystemKind::Holon, Workload::Q4, &cluster.metrics, produced, cfg.duration_ms, dp)
}

/// Overload run: the Q7 workload with `inbox_capacity` set (32 unless
/// the caller configured one — small enough that a 10×-slowed drain
/// cadence genuinely accumulates past it), optionally with a
/// deliberately slowed
/// receiver attached. The slow receiver is a *phantom* bus endpoint: it
/// registers an inbox (so every broadcast targets it) but never
/// heartbeats (so it owns no partitions), and drains its inbox at 10×
/// the gossip interval — an order of magnitude slower than the cadence
/// that fills it. The backpressure acceptance criterion rides the
/// `uniform` vs `slow_receiver` pair: the slowed receiver's inbox stays
/// bounded at `inbox_capacity` (overflow parks on the senders' outbound
/// queues, the parked tail sheds oldest-first) while writer throughput
/// stays within 20% of the uniform run — the senders' loop never blocks
/// on the stalled peer.
pub fn run_overload(cfg: &HolonConfig, slow_receiver: bool) -> RunResult {
    use std::sync::atomic::AtomicBool;
    use std::sync::Arc;

    let mut cfg = cfg.clone();
    if cfg.inbox_capacity == 0 {
        cfg.inbox_capacity = 32;
    }
    let clock = SimClock::scaled(cfg.wall_ms_per_sim_sec);
    let cluster =
        HolonCluster::start_with_clock(cfg.clone(), Q7::new(cfg.window_ms), clock.clone());
    let prod = spawn_producer(&cfg, &cluster.input, &clock);
    let stop = Arc::new(AtomicBool::new(false));
    let drainer = if slow_receiver {
        let phantom: NodeId = cfg.nodes + 1000;
        cluster.bus.register(phantom);
        let bus = cluster.bus.clone();
        let stop = stop.clone();
        let poll_every = clock.wall_for(cfg.gossip_interval_ms.max(1) * 10);
        Some(
            std::thread::Builder::new()
                .name("holon-slow-receiver".into())
                .spawn(move || {
                    while !stop.load(Ordering::Acquire) {
                        std::thread::sleep(poll_every);
                        let _ = bus.recv(phantom);
                    }
                })
                .expect("spawn slow receiver"),
        )
    } else {
        None
    };
    drive(&clock, cfg.duration_ms, drain_ms(&cfg), vec![], |_| {});
    let produced = prod.stop();
    stop.store(true, Ordering::Release);
    if let Some(d) = drainer {
        let _ = d.join();
    }
    cluster.stop();
    let dp = data_plane_stats(&cluster.metrics, &cluster.input, &cluster.output, Some(&cluster.bus));
    collect(SystemKind::Holon, Workload::Q7, &cluster.metrics, produced, cfg.duration_ms, dp)
}

// ---- the `holon bench` perf trajectory ---------------------------------

/// One named scenario of the `holon bench` suite.
pub struct BenchScenario {
    pub name: String,
    pub result: RunResult,
}

/// Run the perf-trajectory scenario suite headlessly: the §5.3
/// max-throughput ramp (Holon + baseline, the paper's 2× claim), the
/// keyed-throughput ramp over flat vs sharded keyed state
/// (`q4_keyed_unsharded` / `q4_keyed_sharded`, delta gossip on — the
/// shard subsystem's scaling rows), and the Table 2 latency rows
/// (failure-free + concurrent failures, the 5× claim). `quick` shrinks
/// durations/partition counts for the CI smoke job; the measured
/// *ratios* still carry.
pub fn bench_scenarios(cfg: &HolonConfig, quick: bool) -> Vec<BenchScenario> {
    let mut out = Vec::new();

    // §5.3 max throughput: exponentially ramped ingestion, report the
    // peak sustained consumption rate.
    let mut tcfg = cfg.clone();
    tcfg.nodes = 5;
    tcfg.partitions = if quick { 10 } else { 25 };
    tcfg.events_per_sec_per_partition = 400;
    tcfg.wall_ms_per_sim_sec = if quick { 50.0 } else { 200.0 };
    tcfg.duration_ms = if quick { 8_000 } else { 20_000 };
    tcfg.batch_size = 2048;
    for (name, holon) in [("throughput_max_q7_holon", true), ("throughput_max_q7_flink", false)] {
        out.push(BenchScenario {
            name: name.to_string(),
            result: run_max_throughput(&tcfg, Workload::Q7, holon),
        });
    }

    // Keyed-throughput ramp: Q4 over flat vs sharded keyed state — the
    // shard subsystem's scaling claim. Same workload, same ramp; the
    // sharded row additionally carries per-shard gossip-byte counters
    // and the parallel-merge counts.
    let mut kcfg = tcfg.clone();
    kcfg.gossip_delta = true; // per-shard deltas are the point
    let shards = if cfg.shard_count > 0 { cfg.shard_count } else { 8 };
    out.push(BenchScenario {
        name: "q4_keyed_unsharded".to_string(),
        // same dataflow pipeline as the sharded row, flat MapCrdt state:
        // the delta between the two rows isolates the sharding layer
        result: run_max_throughput_with(
            &kcfg,
            Workload::Q4,
            crate::nexmark::queries::dataflow_q4(kcfg.window_ms),
        ),
    });
    out.push(BenchScenario {
        name: "q4_keyed_sharded".to_string(),
        result: run_max_throughput_with(
            &kcfg,
            Workload::Q4,
            crate::nexmark::queries::dataflow_q4_sharded(kcfg.window_ms, shards),
        ),
    });

    // Mixed read/write: the Q4 keyed workload under concurrent readers
    // served off live replica state through the changefeed — the row
    // family that measures the read path (queries_served, index
    // hits/misses, scan rows avoided, changefeed lag).
    let mut rcfg = kcfg.clone();
    rcfg.shard_count = shards;
    for (name, mode) in [
        ("mixed_rw_q4_point", ReadMode::Point),
        ("mixed_rw_q4_scan", ReadMode::Scan),
    ] {
        out.push(BenchScenario {
            name: name.to_string(),
            result: run_mixed_read_write(&rcfg, mode),
        });
    }

    // Overload pair: same workload/rate with backpressure armed, with
    // and without a 10×-slowed receiver attached. The slow row's writer
    // throughput must stay within 20% of the uniform row's while
    // `inbox_depth_max` stays ≤ `inbox_capacity` — one stalled peer
    // degrades to bounded lag, never a writer stall.
    let mut ocfg = tcfg.clone();
    ocfg.inbox_capacity = 32;
    for (name, slow) in [
        ("overload_q7_uniform", false),
        ("overload_q7_slow_receiver", true),
    ] {
        out.push(BenchScenario {
            name: name.to_string(),
            result: run_overload(&ocfg, slow),
        });
    }

    // Table 2 latency rows under the paper's failure scenarios.
    let mut lcfg = cfg.clone();
    lcfg.nodes = 5;
    lcfg.partitions = 10;
    lcfg.wall_ms_per_sim_sec = if quick { 10.0 } else { 20.0 };
    lcfg.duration_ms = if quick { 20_000 } else { 60_000 };
    let t0 = lcfg.duration_ms / 3;
    for (tag, sc) in [
        ("baseline", Scenario::Baseline),
        ("concurrent", Scenario::ConcurrentFailures),
    ] {
        out.push(BenchScenario {
            name: format!("table2_latency_q7_{tag}"),
            result: run_holon(&lcfg, Workload::Q7, sc.schedule(t0)),
        });
    }
    out
}

/// Render the scenario suite as the machine-readable `BENCH_*.json`
/// document (schema `holon-bench/v1`, documented in EXPERIMENTS.md).
/// `payload_clones` vs `records_read` is the before/after comparison
/// baked into every data point: the pre-overhaul data plane cloned every
/// record it read, so `records_read` is the clone count the same run
/// would have produced before the zero-copy paths landed.
pub fn bench_report_json(pr: &str, quick: bool, scenarios: &[BenchScenario]) -> String {
    let mut j = crate::benchkit::JsonWriter::new();
    j.obj()
        .str_field("schema", "holon-bench/v1")
        .str_field("pr", pr)
        .bool_field("quick", quick)
        .arr_field("scenarios");
    for s in scenarios {
        let r = &s.result;
        // both series are padded to the full run duration (500 ms buckets)
        let dur_s = r.throughput_series.len() as f64 * 0.5;
        let per = |n: u64| if r.consumed == 0 { 0.0 } else { n as f64 / r.consumed as f64 };
        j.obj()
            .str_field("name", &s.name)
            .str_field(
                "system",
                match r.system {
                    SystemKind::Holon => "holon",
                    SystemKind::Flink => "flink",
                    SystemKind::FlinkSpareSlots => "flink_spare",
                },
            )
            .str_field("workload", &format!("{:?}", r.workload).to_lowercase())
            .f64_field("events_per_sec_peak", r.peak_throughput)
            .f64_field(
                "events_per_sec_mean",
                if dur_s > 0.0 { r.consumed as f64 / dur_s } else { 0.0 },
            )
            .u64_field("events_produced", r.produced)
            .u64_field("events_consumed", r.consumed)
            .u64_field("outputs", r.outputs)
            .f64_field("latency_mean_ms", r.latency_mean_ms)
            .u64_field("latency_p50_ms", r.latency_p50_ms)
            .u64_field("latency_p99_ms", r.latency_p99_ms)
            .u64_field("gossip_msgs", r.data_plane.gossip_msgs)
            .u64_field("gossip_bytes_encoded", r.data_plane.gossip_bytes_encoded)
            .u64_field("gossip_bytes_wire", r.data_plane.gossip_bytes_wire)
            .f64_field(
                "gossip_bytes_per_sec",
                if dur_s > 0.0 { r.data_plane.gossip_bytes_wire as f64 / dur_s } else { 0.0 },
            )
            .u64_field("payload_clones", r.data_plane.payload_clones)
            .u64_field("records_read", r.data_plane.records_read)
            .f64_field("payload_clones_per_event", per(r.data_plane.payload_clones))
            .u64_field("dedup_duplicates", r.data_plane.duplicates)
            .u64_field("seq_gaps", r.data_plane.gaps)
            .u64_field("merge_changed", r.data_plane.merge_changed)
            .u64_field("merge_noop", r.data_plane.merge_noop)
            .u64_field("redundant_gossip_bytes", r.data_plane.redundant_gossip_bytes)
            .u64_field("gossip_skipped", r.data_plane.gossip_skipped)
            .u64_field("shard_count", r.data_plane.shard_gossip_bytes.len() as u64)
            .arr_field("shard_gossip_bytes");
        for b in &r.data_plane.shard_gossip_bytes {
            j.u64_elem(*b);
        }
        j.end_arr()
            .u64_field("shard_parallel_merges", r.data_plane.shard_parallel_merges)
            .u64_field("shard_serial_merges", r.data_plane.shard_serial_merges)
            .u64_field("queries_served", r.data_plane.queries_served)
            .u64_field("query_index_hits", r.data_plane.query_index_hits)
            .u64_field("query_index_misses", r.data_plane.query_index_misses)
            .u64_field("query_scan_rows_avoided", r.data_plane.query_scan_rows_avoided)
            .u64_field("changefeed_lag", r.data_plane.changefeed_lag)
            .u64_field("outbound_queue_depth_max", r.data_plane.outbound_queue_depth_max)
            .u64_field("credits_stalled_rounds", r.data_plane.credits_stalled_rounds)
            .u64_field("inbox_depth_max", r.data_plane.inbox_depth_max)
            .u64_field("output_arena_bytes", r.data_plane.output_arena_bytes)
            .u64_field("output_frames", r.data_plane.output_frames)
            .u64_field("window_ring_spills", r.data_plane.window_ring_spills)
            .u64_field("stage_latency_ingest_p50_ms", r.data_plane.stage_latency_ingest_p50_ms)
            .u64_field("stage_latency_ingest_p99_ms", r.data_plane.stage_latency_ingest_p99_ms)
            .u64_field("stage_latency_fire_p50_ms", r.data_plane.stage_latency_fire_p50_ms)
            .u64_field("stage_latency_fire_p99_ms", r.data_plane.stage_latency_fire_p99_ms)
            .u64_field("stage_latency_converge_p50_ms", r.data_plane.stage_latency_converge_p50_ms)
            .u64_field("stage_latency_converge_p99_ms", r.data_plane.stage_latency_converge_p99_ms)
            .u64_field("stage_latency_emit_p50_ms", r.data_plane.stage_latency_emit_p50_ms)
            .u64_field("stage_latency_emit_p99_ms", r.data_plane.stage_latency_emit_p99_ms)
            .u64_field("trace_dropped_events", r.data_plane.trace_dropped_events)
            .bool_field("stalled", r.stalled)
            .end_obj();
    }
    j.end_arr().end_obj();
    j.finish()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn small_cfg() -> HolonConfig {
        let mut cfg = HolonConfig::default();
        cfg.nodes = 3;
        cfg.partitions = 6;
        cfg.events_per_sec_per_partition = 500;
        cfg.wall_ms_per_sim_sec = 10.0;
        cfg.duration_ms = 4000;
        cfg
    }

    #[test]
    fn holon_q7_run_produces_metrics() {
        let r = run_holon(&small_cfg(), Workload::Q7, vec![]);
        assert!(r.outputs > 0);
        assert!(r.latency_mean_ms > 0.0);
        assert!(r.latency_p50_ms <= r.latency_p99_ms);
        assert!(r.consumed > 0);
        assert!(r.produced > 0);
        // delivery audit: no output sequence was skipped
        assert_eq!(r.data_plane.gaps, 0);
        // the hot path (RUN_BATCH + sink) is zero-copy: every record is
        // visited, none is cloned
        assert_eq!(r.data_plane.payload_clones, 0);
        assert!(r.data_plane.records_read >= r.consumed);
        // outputs ship through the arena: one frame per output record,
        // and the backing bytes cover at least the frame headers
        assert!(r.data_plane.output_frames >= r.outputs);
        assert!(
            r.data_plane.output_arena_bytes
                >= r.data_plane.output_frames * crate::arena::FRAME_HEADER_BYTES as u64
        );
        // in-order Nexmark input never leaves the ring horizon
        assert_eq!(r.data_plane.window_ring_spills, 0);
        assert!(r.data_plane.gossip_msgs > 0);
        assert!(r.data_plane.gossip_bytes_encoded > 0);
        // every received gossip payload was classified by its join
        // outcome (change-reporting merges)
        assert!(r.data_plane.merge_changed + r.data_plane.merge_noop > 0);
        // broadcast fan-out: wire volume is the encoded volume times the
        // recipients each shared-Arc payload reached
        assert!(r.data_plane.gossip_bytes_wire >= r.data_plane.gossip_bytes_encoded);
        // stage-latency breakdown: each stage histogram saw samples and
        // is internally ordered (the validator enforces the same)
        let d = &r.data_plane;
        for (p50, p99) in [
            (d.stage_latency_ingest_p50_ms, d.stage_latency_ingest_p99_ms),
            (d.stage_latency_fire_p50_ms, d.stage_latency_fire_p99_ms),
            (d.stage_latency_converge_p50_ms, d.stage_latency_converge_p99_ms),
            (d.stage_latency_emit_p50_ms, d.stage_latency_emit_p99_ms),
        ] {
            assert!(p50 <= p99, "stage p50 {p50} must not exceed p99 {p99}");
        }
        // converge is the paper's end-to-end latency: same histogram
        // feed as the top-level percentiles
        assert_eq!(d.stage_latency_converge_p50_ms, r.latency_p50_ms);
        assert_eq!(d.stage_latency_converge_p99_ms, r.latency_p99_ms);
        // tracing is off in bench runs: nothing may be dropped
        assert_eq!(d.trace_dropped_events, 0);
    }

    #[test]
    fn flink_q7_run_produces_metrics() {
        let r = run_flink(&small_cfg(), Workload::Q7, false, vec![]);
        assert!(r.outputs > 0);
        assert!(r.latency_mean_ms > 0.0);
    }

    #[test]
    fn scenario_schedules_match_paper() {
        let s = Scenario::ConcurrentFailures.schedule(30_000);
        assert_eq!(s.len(), 4);
        assert_eq!(s[0].at_ms, 30_000);
        assert_eq!(s[2].at_ms, 40_000); // restarted 10 s later
        let s = Scenario::SubsequentFailures.schedule(0);
        assert_eq!(s[1].at_ms, 5000); // second failure 5 s later
        assert!(Scenario::CrashFailures
            .schedule(0)
            .iter()
            .all(|e| matches!(e.action, Action::Fail(_))));
    }

    #[test]
    fn sensitivity_vs_self_is_zero() {
        let r = run_holon(&small_cfg(), Workload::Q7, vec![]);
        assert_eq!(r.sensitivity_vs(&r), 0.0);
    }

    #[test]
    fn bench_report_json_carries_the_schema() {
        // a real (tiny) run through the JSON emitter: every field of the
        // holon-bench/v1 schema must be present exactly once per scenario
        let r = run_holon(&small_cfg(), Workload::Q7, vec![]);
        let scenarios = vec![BenchScenario {
            name: "unit_q7".to_string(),
            result: r,
        }];
        let s = bench_report_json("PR3", true, &scenarios);
        assert!(s.starts_with("{\"schema\":\"holon-bench/v1\""), "{s}");
        for key in [
            "\"pr\":\"PR3\"",
            "\"quick\":true",
            "\"scenarios\":[",
            "\"name\":\"unit_q7\"",
            "\"system\":\"holon\"",
            "\"workload\":\"q7\"",
        ] {
            assert!(s.contains(key), "missing {key} in {s}");
        }
        for key in [
            "events_per_sec_peak",
            "events_per_sec_mean",
            "events_produced",
            "events_consumed",
            "outputs",
            "latency_mean_ms",
            "latency_p50_ms",
            "latency_p99_ms",
            "gossip_msgs",
            "gossip_bytes_encoded",
            "gossip_bytes_wire",
            "gossip_bytes_per_sec",
            "payload_clones",
            "records_read",
            "payload_clones_per_event",
            "dedup_duplicates",
            "seq_gaps",
            "merge_changed",
            "merge_noop",
            "redundant_gossip_bytes",
            "gossip_skipped",
            "shard_count",
            "shard_gossip_bytes",
            "shard_parallel_merges",
            "shard_serial_merges",
            "queries_served",
            "query_index_hits",
            "query_index_misses",
            "query_scan_rows_avoided",
            "changefeed_lag",
            "outbound_queue_depth_max",
            "credits_stalled_rounds",
            "inbox_depth_max",
            "output_arena_bytes",
            "output_frames",
            "window_ring_spills",
            "stage_latency_ingest_p50_ms",
            "stage_latency_ingest_p99_ms",
            "stage_latency_fire_p50_ms",
            "stage_latency_fire_p99_ms",
            "stage_latency_converge_p50_ms",
            "stage_latency_converge_p99_ms",
            "stage_latency_emit_p50_ms",
            "stage_latency_emit_p99_ms",
            "trace_dropped_events",
            "stalled",
        ] {
            assert_eq!(
                s.matches(&format!("\"{key}\":")).count(),
                1,
                "field {key} must appear exactly once: {s}"
            );
        }
        // the zero-copy data plane: clones stay 0 while records flow
        assert!(s.contains("\"payload_clones\":0,"), "{s}");
        // unsharded Q7: the shard counters are present and empty/zero
        assert!(s.contains("\"shard_count\":0,"), "{s}");
        assert!(s.contains("\"shard_gossip_bytes\":[],"), "{s}");
    }

    #[test]
    fn mixed_read_write_run_serves_queries_with_index_wins() {
        let mut cfg = small_cfg();
        cfg.gossip_delta = true;
        cfg.shard_count = 8;
        // enough run time for several completed windows under the reader
        cfg.duration_ms = 6000;
        let r = run_mixed_read_write(&cfg, ReadMode::Point);
        assert!(r.outputs > 0, "writes must still flow under readers");
        assert_eq!(r.data_plane.gaps, 0);
        let dp = &r.data_plane;
        assert!(dp.queries_served > 0, "reader served no queries: {dp:?}");
        // every served query was classified by the pre-filter
        assert_eq!(
            dp.query_index_hits + dp.query_index_misses,
            dp.queries_served,
            "{dp:?}"
        );
        // the acceptance counter: absent-key points are Bloom-pruned, so
        // the index measurably reduced scanned rows
        assert!(dp.query_scan_rows_avoided > 0, "{dp:?}");
        // and the JSON row carries the read-path fields with real values
        let s = bench_report_json("PR6", true, &[BenchScenario {
            name: "mixed_rw_q4_point".to_string(),
            result: r,
        }]);
        assert!(s.contains("\"name\":\"mixed_rw_q4_point\""), "{s}");
        assert!(!s.contains("\"queries_served\":0,"), "{s}");

        // scans exercise range + top-k through the same counters
        let r = run_mixed_read_write(&cfg, ReadMode::Scan);
        assert!(r.data_plane.queries_served > 0, "{:?}", r.data_plane);
    }

    #[test]
    fn sharded_q4_run_reports_shard_counters() {
        let mut cfg = small_cfg();
        cfg.shard_count = 8;
        cfg.gossip_delta = true;
        let r = run_holon(&cfg, Workload::Q4, vec![]);
        assert!(r.outputs > 0, "sharded keyed run must deliver outputs");
        assert_eq!(r.data_plane.gaps, 0);
        // per-shard gossip bytes were attributed, one slot per
        // configured shard (encode sizes the counters to the layout, so
        // shard_count in the report is stable across runs)
        let per = &r.data_plane.shard_gossip_bytes;
        assert_eq!(per.len(), 8, "per-shard counters: {per:?}");
        assert!(per.iter().sum::<u64>() > 0);
        // replica joins over sharded state were counted (inline or
        // parallel depending on host parallelism and state size)
        assert!(r.data_plane.shard_parallel_merges + r.data_plane.shard_serial_merges > 0);
        // and the JSON row carries them
        let s = bench_report_json("PR4", true, &[BenchScenario {
            name: "q4_keyed_sharded".to_string(),
            result: r,
        }]);
        assert!(s.contains("\"name\":\"q4_keyed_sharded\""), "{s}");
        assert!(!s.contains("\"shard_gossip_bytes\":[],"), "{s}");
    }
}
