//! The JobManager: the centralized coordinator the paper contrasts
//! Holon Streaming against (§2.3). It owns:
//!
//! * **checkpoint rounds** — injects a barrier id every
//!   `flink_checkpoint_interval_ms`; completion happens at the root
//!   (aligned) and lands in the shared checkpoint slot;
//! * **failure detection** — declares a TM dead after
//!   `flink_heartbeat_timeout_ms` without a heartbeat;
//! * **global restart** — on any failure the *whole job* is cancelled
//!   (epoch bump → all TM work threads exit), then: wait for slots
//!   (the failed container must come back unless spare slots exist),
//!   pay the restore cost, redeploy from the last completed checkpoint
//!   and replay. One failed node stops everyone — exactly the
//!   centralized-coordination cost the paper's Figure 6 shows.

use std::sync::atomic::Ordering;
use std::sync::Arc;
use std::thread::JoinHandle;

use crate::util::SimTime;

use super::{FlinkCluster, JobState};

pub fn spawn(cluster: &Arc<FlinkCluster>) -> JoinHandle<()> {
    let c = cluster.clone();
    std::thread::Builder::new()
        .name("flink-jobmanager".to_string())
        .spawn(move || jm_main(c))
        .expect("spawn jm")
}

fn jm_main(c: Arc<FlinkCluster>) {
    let mut last_ckpt: SimTime = 0;
    let mut restore_until: SimTime = 0;
    let mut waiting_since: SimTime = 0;
    // let TMs announce themselves before watching heartbeats
    c.clock.sleep(c.cfg.flink_heartbeat_interval_ms.min(500));
    loop {
        if c.shutdown_requested() {
            return;
        }
        let now = c.clock.now();
        let state = c.job_state();
        match state {
            JobState::Running => {
                // --- failure detection over heartbeats ------------------
                let run = c.run_handle().lock().unwrap().clone();
                let Some(run) = run else {
                    c.clock.sleep(10);
                    continue;
                };
                let dead = run.active_tms.iter().any(|&tm| {
                    let hb = c.heartbeats()[tm as usize].load(Ordering::Acquire);
                    now.saturating_sub(hb) > c.cfg.flink_heartbeat_timeout_ms
                });
                if dead {
                    // cancel the whole job: centralized recovery
                    c.epoch().fetch_add(1, Ordering::AcqRel);
                    *c.run_handle().lock().unwrap() = None;
                    *c.state_handle().write().unwrap() = JobState::WaitingForSlots;
                    waiting_since = now;
                    continue;
                }
                // --- checkpoint rounds ----------------------------------
                if now.saturating_sub(last_ckpt) >= c.cfg.flink_checkpoint_interval_ms {
                    let next = c.barrier_handle().load(Ordering::Acquire) + 1;
                    let mut pending = run.pending_ckpt.lock().unwrap();
                    if pending.is_none() {
                        *pending = Some((next, super::BaselineCheckpoint::default()));
                        drop(pending);
                        c.barrier_handle().store(next, Ordering::Release);
                        last_ckpt = now;
                    }
                }
            }
            JobState::WaitingForSlots => {
                let slots_ok = c.cfg.flink_spare_slots || c.all_alive();
                if slots_ok {
                    *c.state_handle().write().unwrap() = JobState::Restoring;
                    restore_until = now + c.cfg.flink_restore_cost_ms;
                } else if now.saturating_sub(waiting_since) > c.cfg.flink_restart_delay_ms {
                    // no slots forthcoming: the job is stuck (Table 2 "–").
                    *c.state_handle().write().unwrap() = JobState::Stalled;
                }
            }
            JobState::Stalled => {
                // a returning container un-stalls the job
                if c.cfg.flink_spare_slots || c.all_alive() {
                    *c.state_handle().write().unwrap() = JobState::Restoring;
                    restore_until = now + c.cfg.flink_restore_cost_ms;
                }
            }
            JobState::Restoring => {
                if now >= restore_until {
                    // TMs re-register on deploy: refresh their heartbeat
                    // baselines so detection doesn't re-trip instantly.
                    for hb in c.heartbeats().iter() {
                        hb.store(now, Ordering::Release);
                    }
                    let epoch = c.epoch().load(Ordering::Acquire);
                    c.deploy(epoch);
                    *c.state_handle().write().unwrap() = JobState::Running;
                    last_ckpt = now;
                }
            }
        }
        c.clock.sleep(20);
    }
}
