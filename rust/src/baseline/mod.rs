//! The comparison system: a centralized, Flink-style exactly-once
//! stream processor (paper §5.1 baseline).
//!
//! This is a *behavioural model with real mechanics*, not a re-skin of
//! the Holon engine. It reproduces the architecture the paper compares
//! against, with the paper's configuration constants:
//!
//! * **centralized coordination** — a JobManager thread owns checkpoint
//!   rounds, failure detection (heartbeat interval 4 s / timeout 6 s)
//!   and restart orchestration; if a single task manager fails, the
//!   whole job is cancelled and redeployed (§2.3);
//! * **pipelined dataflow with channels** — sources chain into per-TM
//!   pre-aggregators (operator chaining); partials flow to a *root*
//!   global aggregator over simulated network channels with a
//!   buffer-flush timeout per hop (`execution.buffer-timeout`, 100 ms)
//!   — the static aggregation tree of §2.2 (leaves = TM pre-aggs,
//!   root = global combine); Q4 adds a keyed shuffle hop by category;
//! * **aligned checkpoint barriers** — the root aligns barriers from
//!   all input channels before snapshotting; sources snapshot offsets
//!   (checkpoint interval 5 s);
//! * **restart-from-checkpoint recovery** — detection wait + slot wait
//!   (10 s container restart unless spare slots are configured) +
//!   restore cost + replay from the last completed checkpoint. Without
//!   spare slots a crash (no restart) stalls the job permanently —
//!   Table 2's "–" entries.
//!
//! The compared quantities (recovery time, latency spikes, sensitivity)
//! are governed by exactly these mechanisms, which is what makes the
//! model a fair stand-in for the real system on those metrics (see
//! DESIGN.md §2 and the calibration test below validating the 35–70 s
//! recovery band the paper and Vogel et al. report).

pub mod channel;
pub mod jobmanager;
pub mod taskmanager;

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex, RwLock};
use std::thread::JoinHandle;

use crate::clock::SimClock;
use crate::config::HolonConfig;
use crate::engine::ClusterMetrics;
use crate::log::{LogBroker, Topic};
use crate::util::{NodeId, PartitionId, SimTime};

use channel::Channel;

/// Which query the baseline job runs.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FlinkJob {
    /// Q0: passthrough, no aggregation tree.
    PassThrough,
    /// Q7: per-window global max (2-level aggregation tree).
    MaxBid,
    /// Q4: per-window per-category average (keyed shuffle + tree).
    AvgByCategory,
}

/// One window partial from a pre-aggregator: (window, payload).
#[derive(Debug, Clone, PartialEq)]
pub enum Partial {
    /// Q7: (window, max price, auction)
    Max(u64, f64, u64),
    /// Q4: (window, category, count, sum_cents, max_cents)
    Cat(u64, u64, u64, f64, f64),
    /// Q0: a passthrough record (ref_ts of the input record)
    Record(SimTime),
}

/// A flush unit on a channel: partials + the sender's watermark and an
/// optional checkpoint barrier id.
#[derive(Debug, Clone, Default)]
pub struct Flush {
    /// sending task-manager id (multi-sender channels track per-sender
    /// watermarks with this).
    pub from: u32,
    pub partials: Vec<Partial>,
    pub watermark: SimTime,
    pub barrier: Option<u64>,
    /// events consumed upstream represented by this flush (throughput)
    pub consumed: u64,
}

/// Shared state of the deployment: what the JM restores on recovery.
#[derive(Debug, Default, Clone)]
pub struct BaselineCheckpoint {
    pub id: u64,
    /// per source partition: next input offset
    pub offsets: BTreeMap<PartitionId, u64>,
    /// root: next window to emit
    pub next_window: u64,
}

/// Job lifecycle as orchestrated by the JobManager.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum JobState {
    Running,
    /// cancelled, waiting for a free slot (container restart)
    WaitingForSlots,
    /// restoring state / redeploying tasks
    Restoring,
    /// permanently stalled (crash without spare slots)
    Stalled,
}

/// The Flink-model cluster.
pub struct FlinkCluster {
    pub cfg: HolonConfig,
    pub clock: SimClock,
    pub broker: LogBroker,
    pub input: Arc<Topic>,
    pub output: Arc<Topic>,
    pub metrics: ClusterMetrics,
    pub job: FlinkJob,

    /// task-manager liveness flags (failure injection).
    tm_alive: Vec<Arc<AtomicBool>>,
    /// heartbeat timestamps per TM (written by TM threads, read by JM).
    heartbeats: Arc<Vec<AtomicU64>>,
    /// current job incarnation; TM work loops check it to cancel.
    epoch: Arc<AtomicU64>,
    /// state of the job (driven by the JM).
    state: Arc<RwLock<JobState>>,
    /// last *completed* checkpoint.
    checkpoint: Arc<Mutex<BaselineCheckpoint>>,
    /// live run state shared by TMs of the current epoch.
    run: Arc<Mutex<Option<Arc<RunState>>>>,
    /// barrier currently being injected (JM -> sources).
    barrier: Arc<AtomicU64>,

    /// highest window for which latency was already recorded (metric
    /// dedup across restarts — replayed windows are duplicates).
    pub(crate) metric_window: Arc<AtomicU64>,

    shutdown: Arc<AtomicBool>,
    threads: Mutex<Vec<JoinHandle<()>>>,
}

/// Mutable state of one job incarnation.
pub struct RunState {
    pub epoch: u64,
    /// task managers participating in this incarnation (alive at deploy).
    pub active_tms: Vec<NodeId>,
    /// channels from each active TM's pre-aggregator to the root
    /// (index = position in `active_tms`).
    pub to_root: Vec<Channel>,
    /// Q4 keyed-shuffle channels: `keyed[receiver][sender]` (indices are
    /// positions in `active_tms`).
    pub keyed: Vec<Vec<Channel>>,
    /// source read offsets for this incarnation.
    pub offsets: Mutex<BTreeMap<PartitionId, u64>>,
    /// checkpoint being assembled: (barrier id, snapshot under way).
    pub pending_ckpt: Mutex<Option<(u64, BaselineCheckpoint)>>,
    /// next window the root emits.
    pub next_window: AtomicU64,
}

impl RunState {
    /// Position of `tm` in the active set, if it participates.
    pub fn slot_of(&self, tm: NodeId) -> Option<usize> {
        self.active_tms.iter().position(|&t| t == tm)
    }

    /// Source partitions owned by active-slot `slot`.
    pub fn partitions_of_slot(&self, slot: usize, partitions: u32) -> Vec<PartitionId> {
        (0..partitions)
            .filter(|p| (*p as usize) % self.active_tms.len() == slot)
            .collect()
    }
}

impl FlinkCluster {
    pub fn start_with_clock(cfg: HolonConfig, job: FlinkJob, clock: SimClock) -> Arc<Self> {
        let broker = LogBroker::new(clock.clone());
        let input = broker.topic("input", cfg.partitions);
        let output = broker.topic("flink-output", 1);
        let metrics = ClusterMetrics::new(500);
        let tms = cfg.nodes as usize;
        let cluster = Arc::new(Self {
            clock: clock.clone(),
            broker,
            input,
            output,
            metrics,
            job,
            tm_alive: (0..tms).map(|_| Arc::new(AtomicBool::new(true))).collect(),
            heartbeats: Arc::new((0..tms).map(|_| AtomicU64::new(0)).collect()),
            epoch: Arc::new(AtomicU64::new(0)),
            state: Arc::new(RwLock::new(JobState::Running)),
            checkpoint: Arc::new(Mutex::new(BaselineCheckpoint::default())),
            run: Arc::new(Mutex::new(None)),
            barrier: Arc::new(AtomicU64::new(0)),
            metric_window: Arc::new(AtomicU64::new(0)),
            shutdown: Arc::new(AtomicBool::new(false)),
            threads: Mutex::new(Vec::new()),
            cfg,
        });
        // initial deployment
        cluster.deploy(0);
        // job manager
        let jm = jobmanager::spawn(&cluster);
        cluster.threads.lock().unwrap().push(jm);
        cluster
    }

    pub fn start(cfg: HolonConfig, job: FlinkJob) -> Arc<Self> {
        let clock = SimClock::scaled(cfg.wall_ms_per_sim_sec);
        Self::start_with_clock(cfg, job, clock)
    }

    /// Deploy a new incarnation of the job from the last completed
    /// checkpoint, on the currently alive task managers (with spare
    /// slots, a dead TM's slot is conceptually filled by a spare; we
    /// model that as the alive set absorbing its work).
    pub(crate) fn deploy(self: &Arc<Self>, epoch: u64) {
        let active_tms: Vec<NodeId> = (0..self.cfg.nodes)
            .filter(|&tm| self.tm_alive[tm as usize].load(Ordering::Acquire))
            .collect();
        assert!(!active_tms.is_empty(), "no slots to deploy on");
        let n = active_tms.len();
        let cp = self.checkpoint.lock().unwrap().clone();
        let mk = |from: u32| {
            Channel::with_tail(
                self.clock.clone(),
                self.cfg.flink_buffer_timeout_ms,
                self.cfg.net_delay_ms,
                from,
                self.cfg.net_tail_prob,
                self.cfg.net_tail_ms,
            )
        };
        let run = Arc::new(RunState {
            epoch,
            active_tms: active_tms.clone(),
            to_root: (0..n).map(|s| mk(s as u32)).collect(),
            keyed: (0..n)
                .map(|_recv| (0..n).map(|s| mk(s as u32)).collect())
                .collect(),
            offsets: Mutex::new({
                let mut m = BTreeMap::new();
                for p in 0..self.cfg.partitions {
                    m.insert(p, cp.offsets.get(&p).copied().unwrap_or(0));
                }
                m
            }),
            pending_ckpt: Mutex::new(None),
            next_window: AtomicU64::new(cp.next_window),
        });
        *self.run.lock().unwrap() = Some(run.clone());
        let mut threads = self.threads.lock().unwrap();
        for &tm in &active_tms {
            let h = taskmanager::spawn(self, tm, run.clone());
            threads.push(h);
        }
    }

    /// Kill a task manager (paper failure injection): its thread exits;
    /// heartbeats stop; the JM notices after the timeout.
    pub fn fail_node(&self, tm: NodeId) {
        if let Some(flag) = self.tm_alive.get(tm as usize) {
            flag.store(false, Ordering::Release);
        }
    }

    /// Bring a task manager's container back (slot becomes available
    /// again after the configured restart delay, modeled by the JM).
    pub fn restart_node(&self, tm: NodeId) {
        if let Some(flag) = self.tm_alive.get(tm as usize) {
            flag.store(true, Ordering::Release);
        }
    }

    pub fn job_state(&self) -> JobState {
        *self.state.read().unwrap()
    }

    pub fn stop(&self) {
        self.shutdown.store(true, Ordering::Release);
        let threads: Vec<_> = self.threads.lock().unwrap().drain(..).collect();
        for t in threads {
            let _ = t.join();
        }
    }

    // -- accessors used by the jm/tm modules ------------------------------

    pub(crate) fn alive_flag(&self, tm: NodeId) -> Arc<AtomicBool> {
        self.tm_alive[tm as usize].clone()
    }

    pub(crate) fn all_alive(&self) -> bool {
        self.tm_alive.iter().all(|f| f.load(Ordering::Acquire))
    }

    pub(crate) fn heartbeats(&self) -> &Arc<Vec<AtomicU64>> {
        &self.heartbeats
    }

    pub(crate) fn epoch(&self) -> &Arc<AtomicU64> {
        &self.epoch
    }

    pub(crate) fn state_handle(&self) -> &Arc<RwLock<JobState>> {
        &self.state
    }

    pub(crate) fn checkpoint_handle(&self) -> &Arc<Mutex<BaselineCheckpoint>> {
        &self.checkpoint
    }

    pub(crate) fn run_handle(&self) -> &Arc<Mutex<Option<Arc<RunState>>>> {
        &self.run
    }

    pub(crate) fn barrier_handle(&self) -> &Arc<AtomicU64> {
        &self.barrier
    }

    pub(crate) fn shutdown_requested(&self) -> bool {
        self.shutdown.load(Ordering::Acquire)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nexmark::producer;

    fn cfg() -> HolonConfig {
        let mut cfg = HolonConfig::default();
        cfg.nodes = 3;
        cfg.partitions = 6;
        cfg.wall_ms_per_sim_sec = 20.0;
        cfg.window_ms = 1000;
        cfg
    }

    #[test]
    fn baseline_q7_produces_ordered_windows() {
        let cfg = cfg();
        let clock = SimClock::scaled(cfg.wall_ms_per_sim_sec);
        let cluster = FlinkCluster::start_with_clock(cfg.clone(), FlinkJob::MaxBid, clock.clone());
        let prod = producer::spawn(cluster.input.clone(), clock.clone(), 1, 1000, 8000);
        std::thread::sleep(clock.wall_for(12_000));
        prod.stop();
        cluster.stop();
        let (recs, _) = cluster.output.read(0, 0, usize::MAX >> 1);
        assert!(recs.len() >= 4, "windows: {}", recs.len());
        // gap-free, ordered window emission (seq == window id)
        for (i, rec) in recs.iter().enumerate() {
            let (seq, _, _) = crate::engine::node::decode_output(&rec.payload).unwrap();
            assert_eq!(seq, i as u64);
        }
        assert!(cluster.metrics.latency.count() > 0);
    }

    #[test]
    fn baseline_latency_exceeds_buffer_timeouts() {
        // The pipelined tree costs at least one buffer flush per hop.
        let cfg = cfg();
        let clock = SimClock::scaled(cfg.wall_ms_per_sim_sec);
        let cluster = FlinkCluster::start_with_clock(cfg.clone(), FlinkJob::MaxBid, clock.clone());
        let prod = producer::spawn(cluster.input.clone(), clock.clone(), 1, 1000, 6000);
        std::thread::sleep(clock.wall_for(10_000));
        prod.stop();
        cluster.stop();
        let mean = cluster.metrics.latency.mean();
        // watermark cadence (mean ~interval/2) + buffer phase + delay
        assert!(
            mean >= cfg.flink_watermark_interval_ms as f64 * 0.4,
            "mean latency {mean} implausibly low for the pipelined tree"
        );
    }

    #[test]
    fn failure_triggers_restart_and_recovery() {
        let mut cfg = cfg();
        // shrink paper constants so the test stays fast, ratios intact
        cfg.flink_checkpoint_interval_ms = 1000;
        cfg.flink_heartbeat_timeout_ms = 1500;
        cfg.flink_restart_delay_ms = 2000;
        cfg.flink_restore_cost_ms = 300;
        let clock = SimClock::scaled(cfg.wall_ms_per_sim_sec);
        let cluster = FlinkCluster::start_with_clock(cfg.clone(), FlinkJob::MaxBid, clock.clone());
        let prod = producer::spawn(cluster.input.clone(), clock.clone(), 1, 1000, 20_000);
        std::thread::sleep(clock.wall_for(5000));
        cluster.fail_node(1);
        std::thread::sleep(clock.wall_for(1000));
        cluster.restart_node(1); // container comes back
        // within detection+slot+restore+replay the job must resume
        std::thread::sleep(clock.wall_for(17_000));
        prod.stop();
        cluster.stop();
        assert_eq!(cluster.job_state(), JobState::Running);
        let (recs, _) = cluster.output.read(0, 0, usize::MAX >> 1);
        // gap-free windows even across the restart (exactly-once)
        let mut seen = 0u64;
        let mut count = 0;
        for rec in recs {
            let (seq, ..) = crate::engine::node::decode_output(&rec.payload).unwrap();
            if seq < seen {
                continue; // replayed duplicate
            }
            assert_eq!(seq, seen);
            seen += 1;
            count += 1;
        }
        assert!(count >= 10, "only {count} windows after recovery");
    }

    #[test]
    fn crash_without_spare_slots_stalls() {
        let mut cfg = cfg();
        cfg.flink_heartbeat_timeout_ms = 1000;
        cfg.flink_restart_delay_ms = 2000;
        cfg.flink_spare_slots = false;
        let clock = SimClock::scaled(cfg.wall_ms_per_sim_sec);
        let cluster = FlinkCluster::start_with_clock(cfg.clone(), FlinkJob::MaxBid, clock.clone());
        let prod = producer::spawn(cluster.input.clone(), clock.clone(), 1, 500, 10_000);
        std::thread::sleep(clock.wall_for(3000));
        cluster.fail_node(0); // never restarted
        std::thread::sleep(clock.wall_for(6000));
        assert_eq!(cluster.job_state(), JobState::Stalled);
        let stalled_at = cluster.output.end_offset(0);
        std::thread::sleep(clock.wall_for(3000));
        assert_eq!(cluster.output.end_offset(0), stalled_at, "stall must halt output");
        prod.stop();
        cluster.stop();
    }

    #[test]
    fn crash_with_spare_slots_recovers() {
        let mut cfg = cfg();
        cfg.flink_checkpoint_interval_ms = 1000;
        cfg.flink_heartbeat_timeout_ms = 1000;
        cfg.flink_spare_slots = true;
        cfg.flink_restore_cost_ms = 300;
        let clock = SimClock::scaled(cfg.wall_ms_per_sim_sec);
        let cluster = FlinkCluster::start_with_clock(cfg.clone(), FlinkJob::MaxBid, clock.clone());
        let prod = producer::spawn(cluster.input.clone(), clock.clone(), 1, 500, 15_000);
        std::thread::sleep(clock.wall_for(3000));
        cluster.fail_node(0); // never restarted, but spares exist
        std::thread::sleep(clock.wall_for(10_000));
        assert_eq!(cluster.job_state(), JobState::Running);
        prod.stop();
        cluster.stop();
    }
}
