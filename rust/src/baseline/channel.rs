//! Simulated pipeline channels with buffer-flush timeouts.
//!
//! Flink's operators exchange records over network channels whose
//! buffers flush when full or after `execution.buffer-timeout` (100 ms
//! default) — the dominant term in the baseline's end-to-end latency.
//! A [`Channel`] models that: the sender accumulates partials and
//! flushes on timeout/size; the flush is delivered after the network
//! delay. Barriers are enqueued in-band like Flink's checkpoint
//! barriers.

use std::collections::VecDeque;
use std::sync::Mutex;

use crate::clock::SimClock;
use crate::util::SimTime;

use super::{Flush, Partial};

/// Flush when this many partials accumulate, even before the timeout.
const BUFFER_CAPACITY: usize = 512;

#[derive(Debug)]
struct Pending {
    buf: Flush,
    buf_since: Option<SimTime>,
    inflight: VecDeque<(SimTime, Flush)>,
}

/// One sender → one receiver channel with buffering and delay.
#[derive(Debug)]
pub struct Channel {
    clock: SimClock,
    buffer_timeout_ms: SimTime,
    delay_ms: SimTime,
    /// heavy-tail spikes: (probability, magnitude sim-ms)
    tail: (f64, SimTime),
    rng: Mutex<crate::util::XorShift64>,
    /// sender task-manager id, stamped on every flush.
    from: u32,
    inner: Mutex<Pending>,
}

impl Channel {
    pub fn new(clock: SimClock, buffer_timeout_ms: SimTime, delay_ms: SimTime, from: u32) -> Self {
        Self::with_tail(clock, buffer_timeout_ms, delay_ms, from, 0.0, 0)
    }

    pub fn with_tail(
        clock: SimClock,
        buffer_timeout_ms: SimTime,
        delay_ms: SimTime,
        from: u32,
        tail_prob: f64,
        tail_ms: SimTime,
    ) -> Self {
        Self {
            clock,
            buffer_timeout_ms,
            delay_ms,
            tail: (tail_prob, tail_ms),
            rng: Mutex::new(crate::util::XorShift64::new(
                0x7A11 ^ ((from as u64) << 8) ^ buffer_timeout_ms,
            )),
            from,
            inner: Mutex::new(Pending {
                buf: Flush {
                    from,
                    ..Default::default()
                },
                buf_since: None,
                inflight: VecDeque::new(),
            }),
        }
    }

    /// Effective delay of one flush: base + occasional tail spike. A
    /// spike on a channel stalls the receiver's min-watermark — the
    /// single-path fragility the paper's gossip redundancy avoids.
    fn delay(&self) -> SimTime {
        let (p, tail) = self.tail;
        if p > 0.0 && tail > 1 {
            let mut rng = self.rng.lock().unwrap();
            if rng.chance(p) {
                return self.delay_ms + tail / 2 + rng.next_below(tail / 2);
            }
        }
        self.delay_ms
    }

    /// Append partials + watermark to the send buffer.
    pub fn push(&self, partials: &[Partial], watermark: SimTime, consumed: u64) {
        let now = self.clock.now();
        let delay = self.delay();
        let mut p = self.inner.lock().unwrap();
        if p.buf_since.is_none() {
            p.buf_since = Some(now);
        }
        p.buf.partials.extend_from_slice(partials);
        p.buf.watermark = p.buf.watermark.max(watermark);
        p.buf.consumed += consumed;
        if p.buf.partials.len() >= BUFFER_CAPACITY {
            Self::flush_locked(&mut p, now, delay, self.from);
        } else {
            self.maybe_flush_locked(&mut p, now, delay);
        }
    }

    /// Enqueue a checkpoint barrier (flushes the buffer first, like
    /// Flink: barriers never overtake records).
    pub fn push_barrier(&self, barrier: u64) {
        let now = self.clock.now();
        let delay = self.delay();
        let mut p = self.inner.lock().unwrap();
        Self::flush_locked(&mut p, now, delay, self.from);
        let flush = Flush {
            from: self.from,
            barrier: Some(barrier),
            ..Default::default()
        };
        p.inflight.push_back((now + delay, flush));
    }

    fn maybe_flush_locked(&self, p: &mut Pending, now: SimTime, delay: SimTime) {
        if let Some(since) = p.buf_since {
            if now.saturating_sub(since) >= self.buffer_timeout_ms {
                Self::flush_locked(p, now, delay, self.from);
            }
        }
    }

    fn flush_locked(p: &mut Pending, now: SimTime, delay: SimTime, from: u32) {
        if p.buf.partials.is_empty() && p.buf.watermark == 0 && p.buf.consumed == 0 {
            p.buf_since = None;
            return;
        }
        let flush = std::mem::replace(
            &mut p.buf,
            Flush {
                from,
                ..Default::default()
            },
        );
        p.buf_since = None;
        p.inflight.push_back((now + delay, flush));
    }

    /// Called by the *sender's* loop to honor the flush timeout even
    /// when no new records arrive.
    pub fn tick(&self) {
        let now = self.clock.now();
        let delay = self.delay();
        let mut p = self.inner.lock().unwrap();
        self.maybe_flush_locked(&mut p, now, delay);
    }

    /// Receiver side: drain flushes that have arrived by now.
    pub fn recv(&self) -> Vec<Flush> {
        let now = self.clock.now();
        let mut p = self.inner.lock().unwrap();
        let mut out = Vec::new();
        while let Some((at, _)) = p.inflight.front() {
            if *at <= now {
                out.push(p.inflight.pop_front().unwrap().1);
            } else {
                break;
            }
        }
        out
    }

    /// Drop all in-flight and buffered data (job cancellation).
    pub fn clear(&self) {
        let mut p = self.inner.lock().unwrap();
        p.buf = Flush {
            from: self.from,
            ..Default::default()
        };
        p.buf_since = None;
        p.inflight.clear();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn mk(clock: &SimClock) -> Channel {
        Channel::new(clock.clone(), 100, 10, 3)
    }

    #[test]
    fn buffers_until_timeout() {
        let clock = SimClock::manual();
        let ch = mk(&clock);
        ch.push(&[Partial::Record(1)], 5, 1);
        clock.advance(50);
        ch.tick();
        assert!(ch.recv().is_empty(), "flushed too early");
        clock.advance(60); // past the 100ms timeout
        ch.tick();
        assert!(ch.recv().is_empty(), "network delay not applied");
        clock.advance(10);
        let flushes = ch.recv();
        assert_eq!(flushes.len(), 1);
        assert_eq!(flushes[0].partials.len(), 1);
        assert_eq!(flushes[0].watermark, 5);
        assert_eq!(flushes[0].from, 3);
    }

    #[test]
    fn capacity_flushes_immediately() {
        let clock = SimClock::manual();
        let ch = mk(&clock);
        let batch: Vec<Partial> = (0..600).map(|i| Partial::Record(i)).collect();
        ch.push(&batch, 1, 600);
        clock.advance(10); // just the network delay
        let flushes = ch.recv();
        assert_eq!(flushes.len(), 1);
        assert_eq!(flushes[0].partials.len(), 600);
    }

    #[test]
    fn barrier_flushes_and_orders() {
        let clock = SimClock::manual();
        let ch = mk(&clock);
        ch.push(&[Partial::Record(1)], 1, 1);
        ch.push_barrier(7);
        clock.advance(10);
        let flushes = ch.recv();
        assert_eq!(flushes.len(), 2);
        assert!(flushes[0].barrier.is_none()); // records first
        assert_eq!(flushes[1].barrier, Some(7));
    }

    #[test]
    fn clear_drops_everything() {
        let clock = SimClock::manual();
        let ch = mk(&clock);
        ch.push(&[Partial::Record(1)], 1, 1);
        ch.push_barrier(1);
        ch.clear();
        clock.advance(1000);
        assert!(ch.recv().is_empty());
    }
}
