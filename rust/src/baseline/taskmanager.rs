//! Task-manager threads: sources + chained pre-aggregators, the Q4
//! keyed-shuffle aggregators, and the root global aggregator (scheduled
//! on the first active TM's slot).

use std::collections::BTreeMap;
use std::sync::atomic::Ordering;
use std::sync::Arc;
use std::thread::JoinHandle;

use crate::arena::OutputArena;
use crate::codec::Decode;
use crate::nexmark::Event;
use crate::util::{NodeId, SimTime};

use super::{FlinkCluster, FlinkJob, Flush, Partial, RunState};

/// Per-window pre-aggregation state for Q7.
#[derive(Default)]
struct MaxAgg {
    windows: BTreeMap<u64, (f64, u64)>, // window -> (max, auction)
}

/// Per-window keyed aggregation state for Q4 (after the shuffle).
#[derive(Default)]
struct CatAgg {
    windows: BTreeMap<u64, BTreeMap<u64, (u64, f64, f64)>>, // w -> cat -> (count, sum, max)
}

/// Root combine state.
#[derive(Default)]
struct RootState {
    /// per input slot: latest watermark seen
    watermarks: Vec<SimTime>,
    /// barrier alignment: flushes deferred on already-barriered inputs
    aligned: Vec<bool>,
    deferred: Vec<Vec<Flush>>,
    current_barrier: Option<u64>,
    /// combine buffers
    maxes: BTreeMap<u64, (f64, u64)>,
    cats: BTreeMap<u64, BTreeMap<u64, (u64, f64, f64)>>,
    /// Output arena: the baseline ships batches through the same
    /// zero-alloc frame path as the Holon engine, so the systems
    /// comparison doesn't charge only one side for output allocation.
    arena: OutputArena,
}

/// One TM work thread for one job incarnation.
pub fn spawn(cluster: &Arc<FlinkCluster>, tm: NodeId, run: Arc<RunState>) -> JoinHandle<()> {
    let c = cluster.clone();
    std::thread::Builder::new()
        .name(format!("flink-tm-{tm}-e{}", run.epoch))
        .spawn(move || tm_main(c, tm, run))
        .expect("spawn tm")
}

fn tm_main(c: Arc<FlinkCluster>, tm: NodeId, run: Arc<RunState>) {
    let Some(slot) = run.slot_of(tm) else { return };
    let n = run.active_tms.len();
    let my_parts = run.partitions_of_slot(slot, c.cfg.partitions);
    let is_root = slot == 0;
    let mut pre_max = MaxAgg::default();
    let mut pre_max_fwd = 0u64; // next window to forward
    let mut cat_agg = CatAgg::default();
    let mut cat_wms: Vec<SimTime> = vec![0; n]; // keyed-agg input watermarks
    let mut cat_fwd = 0u64;
    let mut src_wm: SimTime = 0;
    // persistent last event-ts per owned partition (watermark basis)
    let mut part_last_ts: BTreeMap<u32, SimTime> = BTreeMap::new();
    // Flink emits source watermarks on the auto-watermark cadence, not
    // per record — a real latency contributor in the baseline.
    let mut last_wm_emit: SimTime = 0;
    let mut pending_wm: SimTime = 0;
    let mut preagg_wm: SimTime = 0;
    let mut last_preagg_wm_emit: SimTime = 0;
    let mut last_barrier_seen = 0u64;
    let mut root = RootState {
        watermarks: vec![0; n],
        aligned: vec![false; n],
        deferred: (0..n).map(|_| Vec::new()).collect(),
        ..Default::default()
    };
    // service-cost model (see HolonConfig::flink_event_cost_us)
    let mut budget_events: f64 = 0.0;
    let mut last_budget_at: SimTime = c.clock.now();

    loop {
        if c.shutdown_requested()
            || !c.alive_flag(tm).load(Ordering::Acquire)
            || c.epoch().load(Ordering::Acquire) != run.epoch
        {
            return;
        }
        let now = c.clock.now();
        let mut did_work = false;

        // Heartbeats come from the worker itself (as in Flink, where the
        // TM process running the tasks is what heartbeats): a killed
        // work thread stops heartbeating immediately, so the JM always
        // detects the death even if the container restarts quickly.
        c.heartbeats()[tm as usize].store(now, Ordering::Release);

        // --- sources + chained pre-aggregator --------------------------
        let barrier = c.barrier_handle().load(Ordering::Acquire);
        let new_barrier = barrier > last_barrier_seen;

        if c.cfg.flink_event_cost_us > 0.0 {
            let dt = now.saturating_sub(last_budget_at);
            let cap = 4.0 * c.cfg.batch_size as f64 * my_parts.len().max(1) as f64;
            budget_events =
                (budget_events + dt as f64 * 1000.0 / c.cfg.flink_event_cost_us).min(cap);
        } else {
            budget_events = f64::MAX;
        }
        last_budget_at = now;
        let mut batch_partials: Vec<Partial> = Vec::new();
        let mut consumed = 0u64;
        for &p in &my_parts {
            let allowed = c.cfg.batch_size.min(budget_events as usize);
            if allowed == 0 {
                break;
            }
            let from = {
                let offs = run.offsets.lock().unwrap();
                offs[&p]
            };
            // zero-copy source read (same data-plane path as the Holon
            // engine's RUN_BATCH, so the systems comparison stays fair)
            let ((nread, last_ts), next) = c.input.read_slice(p, from, allowed, |recs| {
                for rec in recs {
                    match c.job {
                        FlinkJob::PassThrough => {
                            batch_partials.push(Partial::Record(rec.insert_ts));
                        }
                        FlinkJob::MaxBid => {
                            if let Ok(Event::Bid { auction, price, .. }) =
                                Event::from_bytes(&rec.payload)
                            {
                                let w = rec.event_ts / c.cfg.window_ms;
                                let e = pre_max.windows.entry(w).or_insert((f64::MIN, 0));
                                if price > e.0 {
                                    *e = (price, auction);
                                }
                            }
                        }
                        FlinkJob::AvgByCategory => {
                            if let Ok(Event::Bid {
                                price, category, ..
                            }) = Event::from_bytes(&rec.payload)
                            {
                                let w = rec.event_ts / c.cfg.window_ms;
                                let cents = (price * 100.0).round();
                                batch_partials.push(Partial::Cat(w, category, 1, cents, cents));
                            }
                        }
                    }
                }
                (recs.len(), recs.last().map(|r| r.event_ts))
            });
            budget_events -= nread as f64;
            if nread > 0 {
                did_work = true;
                consumed += nread as u64;
                let mut offs = run.offsets.lock().unwrap();
                offs.insert(p, next);
                part_last_ts.insert(p, last_ts.unwrap());
            }
        }
        if consumed > 0 {
            c.metrics.processed.bump(now, consumed);
        }
        // source watermark: min over owned partitions' last event times,
        // emitted on the auto-watermark cadence (Flink behaviour).
        if part_last_ts.len() == my_parts.len() {
            pending_wm = part_last_ts.values().copied().min().unwrap_or(0);
        }
        if now.saturating_sub(last_wm_emit) >= c.cfg.flink_watermark_interval_ms {
            src_wm = src_wm.max(pending_wm);
            last_wm_emit = now;
        }

        // The pre-aggregator re-emits its watermark downstream on its own
        // auto-watermark cadence as well (watermarks are generated per
        // operator in Flink, not per record) — the second cadence hop in
        // the end-to-end latency.
        if now.saturating_sub(last_preagg_wm_emit) >= c.cfg.flink_watermark_interval_ms {
            preagg_wm = src_wm;
            last_preagg_wm_emit = now;
        }
        match c.job {
            FlinkJob::PassThrough => {
                run.to_root[slot].push(&batch_partials, preagg_wm, consumed);
            }
            FlinkJob::MaxBid => {
                // forward finalized pre-agg windows (end <= watermark)
                let mut fwd: Vec<Partial> = Vec::new();
                while (pre_max_fwd + 1) * c.cfg.window_ms <= preagg_wm {
                    let w = pre_max_fwd;
                    if let Some((mx, auc)) = pre_max.windows.remove(&w) {
                        fwd.push(Partial::Max(w, mx, auc));
                    }
                    pre_max_fwd += 1;
                }
                run.to_root[slot].push(&fwd, preagg_wm, consumed);
            }
            FlinkJob::AvgByCategory => {
                // keyed shuffle: route each bid partial to its category
                // owner TM (one extra network hop vs MaxBid).
                let mut routed: Vec<Vec<Partial>> = vec![Vec::new(); n];
                for p in batch_partials {
                    if let Partial::Cat(_, cat, ..) = p {
                        routed[(cat % n as u64) as usize].push(p);
                    }
                }
                for (recv, ps) in routed.into_iter().enumerate() {
                    run.keyed[recv][slot].push(&ps, src_wm, if recv == 0 { consumed } else { 0 });
                }
            }
        }

        // --- Q4 keyed aggregator (runs on every TM) ---------------------
        if c.job == FlinkJob::AvgByCategory {
            for sender in 0..n {
                for flush in run.keyed[slot][sender].recv() {
                    did_work = did_work || !flush.partials.is_empty();
                    // shuffled records pay the per-event service cost
                    // again at the keyed operator (deserialize + state
                    // access) — the hop that caps Q4's throughput.
                    budget_events -= flush.partials.len() as f64;
                    for p in flush.partials {
                        if let Partial::Cat(w, cat, cnt, sum, mx) = p {
                            let e = cat_agg
                                .windows
                                .entry(w)
                                .or_default()
                                .entry(cat)
                                .or_insert((0, 0.0, f64::MIN));
                            e.0 += cnt;
                            e.1 += sum;
                            if mx > e.2 {
                                e.2 = mx;
                            }
                        }
                    }
                    cat_wms[sender] = cat_wms[sender].max(flush.watermark);
                    // barriers pass through the keyed agg to the root
                    // once per round (alignment simplified to min-wm).
                }
            }
            let keyed_wm = cat_wms.iter().copied().min().unwrap_or(0);
            let mut fwd: Vec<Partial> = Vec::new();
            while (cat_fwd + 1) * c.cfg.window_ms <= keyed_wm {
                let w = cat_fwd;
                if let Some(cats) = cat_agg.windows.remove(&w) {
                    for (cat, (cnt, sum, mx)) in cats {
                        fwd.push(Partial::Cat(w, cat, cnt, sum, mx));
                    }
                }
                cat_fwd += 1;
            }
            run.to_root[slot].push(&fwd, keyed_wm, 0);
        }

        // --- barrier injection at the source ---------------------------
        if new_barrier {
            last_barrier_seen = barrier;
            // snapshot source offsets into the pending checkpoint
            let mut pending = run.pending_ckpt.lock().unwrap();
            if let Some((id, cp)) = pending.as_mut() {
                if *id == barrier {
                    let offs = run.offsets.lock().unwrap();
                    for &p in &my_parts {
                        cp.offsets.insert(p, offs[&p]);
                    }
                }
            }
            drop(pending);
            run.to_root[slot].push_barrier(barrier);
        }
        run.to_root[slot].tick();
        if c.job == FlinkJob::AvgByCategory {
            for recv in 0..n {
                run.keyed[recv][slot].tick();
            }
        }

        // --- root global aggregator (slot 0 only) -----------------------
        if is_root {
            did_work |= run_root(&c, &run, &mut root);
        }

        if !did_work {
            c.clock.sleep(c.cfg.poll_interval_ms.max(1));
        }
    }
}

/// Drain root inputs with barrier alignment, combine, emit completed
/// windows. Returns whether any work was done.
fn run_root(c: &Arc<FlinkCluster>, run: &Arc<RunState>, root: &mut RootState) -> bool {
    let n = run.active_tms.len();
    let mut did_work = false;
    for i in 0..n {
        // Aligned checkpointing: once input i delivered barrier B, its
        // further flushes are deferred until all inputs reach B.
        let flushes = run.to_root[i].recv();
        for flush in flushes {
            if root.aligned[i] {
                root.deferred[i].push(flush);
                continue;
            }
            did_work |= apply_root_flush(c, root, i, flush);
        }
    }
    // complete alignment?
    if root.current_barrier.is_some() && root.aligned.iter().all(|&a| a) {
        let barrier = root.current_barrier.take().unwrap();
        // finalize the checkpoint: root state + source offsets
        let mut pending = run.pending_ckpt.lock().unwrap();
        if let Some((id, mut cp)) = pending.take() {
            if id == barrier {
                cp.id = barrier;
                cp.next_window = run.next_window.load(Ordering::Acquire);
                *c.checkpoint_handle().lock().unwrap() = cp;
            } else {
                *pending = Some((id, cp));
            }
        }
        drop(pending);
        for i in 0..n {
            root.aligned[i] = false;
            let deferred = std::mem::take(&mut root.deferred[i]);
            for flush in deferred {
                apply_root_flush(c, root, i, flush);
            }
        }
        did_work = true;
    }

    // emit completed windows (watermark = min over inputs) as arena
    // frames — sequence numbers are the window ids, which the loop
    // produces consecutively, exactly matching `finish(first_w)`.
    let wm = root.watermarks.iter().copied().min().unwrap_or(0);
    let now = c.clock.now();
    let first_w = run.next_window.load(Ordering::Acquire);
    root.arena.begin_batch();
    loop {
        let w = run.next_window.load(Ordering::Acquire);
        let end = (w + 1) * c.cfg.window_ms;
        if end > wm {
            break;
        }
        match c.job {
            FlinkJob::PassThrough => {} // records emitted eagerly
            FlinkJob::MaxBid => {
                let (mx, auc) = root.maxes.remove(&w).unwrap_or((0.0, 0));
                root.arena.frame(end, |wr| {
                    wr.put_u64(w);
                    wr.put_f64(mx.max(0.0));
                    wr.put_u64(auc);
                    true
                });
            }
            FlinkJob::AvgByCategory => {
                let cats = root.cats.remove(&w).unwrap_or_default();
                root.arena.frame(end, |wr| {
                    wr.put_u64(w);
                    wr.put_u32(cats.len() as u32);
                    for (cat, (cnt, sum, _mx)) in cats {
                        wr.put_u64(cat);
                        wr.put_f64(sum / 100.0 / cnt.max(1) as f64);
                        wr.put_u64(cnt);
                    }
                    true
                });
            }
        }
        if c.job != FlinkJob::PassThrough {
            // metric dedup across restarts: only first emission counts
            let recorded = c.metric_window.load(Ordering::Acquire);
            if w >= recorded {
                c.metric_window.store(w + 1, Ordering::Release);
                let latency = now.saturating_sub(end);
                c.metrics.latency.record(latency);
                c.metrics.latency_series.record(now, latency as f64);
                c.metrics.outputs.fetch_add(1, Ordering::Relaxed);
            } else {
                c.metrics.duplicates.fetch_add(1, Ordering::Relaxed);
            }
        }
        run.next_window.store(w + 1, Ordering::Release);
        did_work = true;
    }
    if let Some(batch) = root.arena.finish(first_w) {
        c.output.append_frames(0, &batch);
        root.arena.recycle(batch);
    }
    did_work
}

/// Fold one flush into the root state. Returns whether records arrived.
fn apply_root_flush(c: &Arc<FlinkCluster>, root: &mut RootState, i: usize, flush: Flush) -> bool {
    if let Some(b) = flush.barrier {
        match root.current_barrier {
            None => {
                root.current_barrier = Some(b);
                root.aligned[i] = true;
            }
            Some(cur) if b == cur => {
                root.aligned[i] = true;
            }
            Some(_) => { /* stale barrier from before a restart: ignore */ }
        }
        return true;
    }
    let had = !flush.partials.is_empty();
    root.arena.begin_batch();
    for p in flush.partials {
        match p {
            Partial::Max(w, mx, auc) => {
                let e = root.maxes.entry(w).or_insert((f64::MIN, 0));
                if mx > e.0 {
                    *e = (mx, auc);
                }
            }
            Partial::Cat(w, cat, cnt, sum, mx) => {
                let e = root
                    .cats
                    .entry(w)
                    .or_default()
                    .entry(cat)
                    .or_insert((0, 0.0, f64::MIN));
                e.0 += cnt;
                e.1 += sum;
                if mx > e.2 {
                    e.2 = mx;
                }
            }
            Partial::Record(ref_ts) => {
                // Q0: emit sequenced by arrival, as an (empty-payload)
                // arena frame; the whole flush ships as one batch below.
                let now = c.clock.now();
                root.arena.frame(ref_ts, |_| true);
                let latency = now.saturating_sub(ref_ts);
                c.metrics.latency.record(latency);
                c.metrics.latency_series.record(now, latency as f64);
                c.metrics.outputs.fetch_add(1, Ordering::Relaxed);
            }
        }
    }
    if !root.arena.is_empty() {
        // claim the flush's whole seq range at once — only this (root)
        // thread emits Q0 records, so the range is exactly contiguous
        // with the per-record fetch_add it replaces
        let seq0 = c
            .metric_window
            .fetch_add(root.arena.len() as u64, Ordering::AcqRel);
        if let Some(batch) = root.arena.finish(seq0) {
            c.output.append_frames(0, &batch);
            root.arena.recycle(batch);
        }
    }
    root.watermarks[i] = root.watermarks[i].max(flush.watermark);
    had
}
