//! Scaled simulation clock.
//!
//! All paper constants (5 s checkpoint interval, 4 s heartbeats, 10 s
//! restart delay, ...) are expressed in *sim-time* milliseconds. The
//! clock maps sim-time onto wall time with a configurable `scale`:
//! `scale = 0.02` means one paper-second takes 20 ms of wall time, so a
//! 200-sim-second failure experiment runs in 4 wall-seconds. Ratios
//! between the compared systems are preserved because both run against
//! the same clock.

// This module is the sanctioned wall-time boundary: everything above it
// sees only sim-time. Mirrors the holon-lint D2 (wall-clock) exemption.
#![allow(clippy::disallowed_methods)]

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

use crate::util::SimTime;

/// Shared, monotonically increasing simulation clock.
#[derive(Debug, Clone)]
pub struct SimClock {
    inner: Arc<ClockInner>,
}

#[derive(Debug)]
struct ClockInner {
    start: Instant,
    /// sim-milliseconds per wall-millisecond (e.g. 50.0 when one
    /// paper-second runs in 20 ms of wall time).
    sim_per_wall: f64,
    /// Frozen time for manual mode (tests): if `u64::MAX`, clock is live.
    manual: AtomicU64,
}

impl SimClock {
    /// A live clock where one sim-second takes `wall_ms_per_sim_sec`
    /// milliseconds of wall time.
    pub fn scaled(wall_ms_per_sim_sec: f64) -> Self {
        assert!(wall_ms_per_sim_sec > 0.0);
        SimClock {
            inner: Arc::new(ClockInner {
                start: Instant::now(),
                sim_per_wall: 1000.0 / wall_ms_per_sim_sec,
                manual: AtomicU64::new(u64::MAX),
            }),
        }
    }

    /// Real time: 1 sim-ms == 1 wall-ms.
    pub fn realtime() -> Self {
        Self::scaled(1000.0)
    }

    /// A manually advanced clock for deterministic unit tests.
    pub fn manual() -> Self {
        SimClock {
            inner: Arc::new(ClockInner {
                start: Instant::now(),
                sim_per_wall: 0.0,
                manual: AtomicU64::new(0),
            }),
        }
    }

    /// Current sim-time in milliseconds.
    pub fn now(&self) -> SimTime {
        let manual = self.inner.manual.load(Ordering::Acquire);
        if manual != u64::MAX {
            return manual;
        }
        let wall_ms = self.inner.start.elapsed().as_secs_f64() * 1000.0;
        (wall_ms * self.inner.sim_per_wall) as SimTime
    }

    /// Advance a manual clock (no-op safeguard: panics on live clocks).
    pub fn advance(&self, sim_ms: SimTime) {
        let m = self.inner.manual.load(Ordering::Acquire);
        assert_ne!(m, u64::MAX, "advance() on a live clock");
        self.inner.manual.store(m + sim_ms, Ordering::Release);
    }

    /// Sleep for `sim_ms` of simulation time (wall sleep on live clocks;
    /// on manual clocks this advances the clock instead).
    pub fn sleep(&self, sim_ms: SimTime) {
        if self.inner.manual.load(Ordering::Acquire) != u64::MAX {
            self.advance(sim_ms);
            return;
        }
        // wall-ms = sim-ms / (sim-ms per wall-ms)
        let wall_ms = sim_ms as f64 / self.inner.sim_per_wall;
        std::thread::sleep(Duration::from_secs_f64(wall_ms / 1000.0));
    }

    /// Sleep until the clock reads at least `t` sim-ms (no-op if `t` is
    /// already past). On manual clocks this jumps straight to `t`. The
    /// simulation harness uses this to execute fault schedules at their
    /// planned sim-times without accumulating per-step sleep drift.
    pub fn sleep_until(&self, t: SimTime) {
        if self.inner.manual.load(Ordering::Acquire) != u64::MAX {
            let now = self.now();
            if t > now {
                self.advance(t - now);
            }
            return;
        }
        loop {
            let now = self.now();
            if now >= t {
                return;
            }
            self.sleep((t - now).max(1));
        }
    }

    /// Wall-clock duration corresponding to `sim_ms` (for bench harnesses).
    pub fn wall_for(&self, sim_ms: SimTime) -> Duration {
        if self.inner.sim_per_wall == 0.0 {
            return Duration::ZERO;
        }
        Duration::from_secs_f64(sim_ms as f64 / self.inner.sim_per_wall / 1000.0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn manual_clock_advances() {
        let c = SimClock::manual();
        assert_eq!(c.now(), 0);
        c.advance(500);
        assert_eq!(c.now(), 500);
        c.sleep(100); // sleep == advance on manual clocks
        assert_eq!(c.now(), 600);
    }

    #[test]
    fn scaled_clock_runs_fast() {
        // 1 sim-second per 10 wall-ms => 100x speedup.
        let c = SimClock::scaled(10.0);
        let t0 = c.now();
        std::thread::sleep(Duration::from_millis(30));
        let dt = c.now() - t0;
        // ~3 sim-seconds elapsed; allow slack for scheduler noise.
        assert!(dt > 1500, "dt={dt}");
    }

    #[test]
    fn clones_share_time() {
        let a = SimClock::manual();
        let b = a.clone();
        a.advance(42);
        assert_eq!(b.now(), 42);
    }

    #[test]
    fn live_sleep_is_scaled() {
        // 1 sim-s per 5 wall-ms: sleeping 1000 sim-ms must take ~5 wall
        // ms, not 5 seconds (regression test for a unit bug).
        let c = SimClock::scaled(5.0);
        let t0 = Instant::now();
        c.sleep(1000);
        let wall = t0.elapsed();
        assert!(wall < Duration::from_millis(200), "slept {wall:?}");
    }

    #[test]
    fn sleep_until_advances_manual_clock() {
        let c = SimClock::manual();
        c.advance(100);
        c.sleep_until(250);
        assert_eq!(c.now(), 250);
        c.sleep_until(200); // already past: no-op, never regresses
        assert_eq!(c.now(), 250);
    }

    #[test]
    fn sleep_until_reaches_target_on_live_clock() {
        let c = SimClock::scaled(5.0); // 1 sim-s = 5 wall-ms
        c.sleep_until(400);
        assert!(c.now() >= 400);
    }

    #[test]
    fn wall_for_converts() {
        let c = SimClock::scaled(20.0); // 1 sim-s = 20 wall-ms
        let d = c.wall_for(2000);
        assert!((d.as_millis() as i64 - 40).abs() <= 1, "{d:?}");
    }
}
