//! Logged streams — the Kafka substitute (DESIGN.md §2).
//!
//! The paper's deployment uses Kafka topics for input, output, broadcast
//! and control streams. The algorithms only require *logged, replayable,
//! offset-addressed* partitioned streams; this module provides exactly
//! that, in-process and thread-safe. Records carry their append
//! timestamp (sim-time), which is how end-to-end latency is measured —
//! "measured by Kafka insertion timestamps" (§5.1).

use std::collections::BTreeMap;
use std::ops::Deref;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex, RwLock};

use crate::arena::FinishedBatch;
use crate::clock::SimClock;
use crate::util::{LockExt, PartitionId, SimTime};

/// A byte payload that is a *view* into a shared backing buffer.
///
/// The arena output path ships a whole batch of records as one
/// `Arc<Vec<u8>>`; each record's payload is an `(offset, len)` window
/// into it. Standalone payloads (`From<Vec<u8>>`) simply own their
/// backing with a full-range view, so every pre-arena call site keeps
/// working. `Deref<Target = [u8]>` means readers see plain byte slices
/// either way; equality is by visible bytes, not backing identity.
#[derive(Debug, Clone)]
pub struct SharedBytes {
    backing: Arc<Vec<u8>>,
    start: u32,
    len: u32,
}

impl SharedBytes {
    /// View `[start, start + len)` of a shared backing buffer.
    pub fn view(backing: Arc<Vec<u8>>, start: u32, len: u32) -> Self {
        debug_assert!((start as usize + len as usize) <= backing.len());
        Self { backing, start, len }
    }

    pub fn as_slice(&self) -> &[u8] {
        &self.backing[self.start as usize..(self.start + self.len) as usize]
    }

    /// The shared backing buffer (observability/tests: frames of one
    /// batch report `Arc::ptr_eq` backings).
    pub fn backing(&self) -> &Arc<Vec<u8>> {
        &self.backing
    }
}

impl Deref for SharedBytes {
    type Target = [u8];
    fn deref(&self) -> &[u8] {
        self.as_slice()
    }
}

impl From<Vec<u8>> for SharedBytes {
    fn from(v: Vec<u8>) -> Self {
        let len = v.len() as u32;
        Self::view(Arc::new(v), 0, len)
    }
}

impl From<Arc<Vec<u8>>> for SharedBytes {
    fn from(backing: Arc<Vec<u8>>) -> Self {
        let len = backing.len() as u32;
        Self::view(backing, 0, len)
    }
}

impl PartialEq for SharedBytes {
    fn eq(&self, other: &Self) -> bool {
        self.as_slice() == other.as_slice()
    }
}

impl Eq for SharedBytes {}

/// One record on a logged stream.
#[derive(Debug, Clone, PartialEq)]
pub struct Record {
    /// Offset within the partition (assigned at append).
    pub offset: u64,
    /// Event timestamp (sim-time) assigned by the producer.
    pub event_ts: SimTime,
    /// Append timestamp (sim-time) assigned by the broker.
    pub insert_ts: SimTime,
    /// Opaque payload bytes (possibly a view into a shared batch
    /// backing — see [`SharedBytes`]).
    pub payload: SharedBytes,
}

/// A single append-only partition.
#[derive(Debug, Default)]
struct PartitionLog {
    records: Vec<Record>,
}

/// A named, partitioned, append-only topic.
#[derive(Debug)]
pub struct Topic {
    name: String,
    clock: SimClock,
    partitions: Vec<RwLock<PartitionLog>>,
    /// Records materialized (payload `Arc` + metadata cloned into a
    /// fresh `Vec<Record>`) by the copying [`read`](Self::read) path —
    /// the allocations-per-event proxy reported by `holon bench`. The
    /// zero-copy [`read_slice`](Self::read_slice)/[`read_with`](Self::read_with)
    /// paths never bump it.
    payload_clones: AtomicU64,
    /// Records visited by *any* read path — the denominator: on the
    /// pre-overhaul code every visited record was also a clone.
    records_read: AtomicU64,
}

impl Topic {
    fn new(name: &str, partitions: u32, clock: SimClock) -> Self {
        Self {
            name: name.to_string(),
            clock,
            partitions: (0..partitions).map(|_| RwLock::new(PartitionLog::default())).collect(),
            payload_clones: AtomicU64::new(0),
            records_read: AtomicU64::new(0),
        }
    }

    pub fn name(&self) -> &str {
        &self.name
    }

    pub fn partitions(&self) -> u32 {
        self.partitions.len() as u32
    }

    fn log(&self, p: PartitionId) -> &RwLock<PartitionLog> {
        &self.partitions[p as usize]
    }

    /// Append one record; returns its offset.
    pub fn append(&self, p: PartitionId, event_ts: SimTime, payload: Vec<u8>) -> u64 {
        self.append_shared(p, event_ts, Arc::new(payload))
    }

    /// Append with a shared payload (zero-copy fan-out path).
    pub fn append_shared(&self, p: PartitionId, event_ts: SimTime, payload: Arc<Vec<u8>>) -> u64 {
        let now = self.clock.now();
        let mut log = self.log(p).write().unwrap();
        let offset = log.records.len() as u64;
        log.records.push(Record {
            offset,
            event_ts,
            insert_ts: now,
            payload: payload.into(),
        });
        offset
    }

    /// Append a batch; returns the offset of the first record.
    pub fn append_batch(&self, p: PartitionId, batch: Vec<(SimTime, Vec<u8>)>) -> u64 {
        let now = self.clock.now();
        let mut log = self.log(p).write().unwrap();
        let first = log.records.len() as u64;
        log.records.reserve(batch.len());
        for (i, (event_ts, payload)) in batch.into_iter().enumerate() {
            log.records.push(Record {
                offset: first + i as u64,
                event_ts,
                insert_ts: now,
                payload: payload.into(),
            });
        }
        first
    }

    /// Append a finished arena batch: every frame becomes one record
    /// whose payload is a [`SharedBytes`] view into the batch's single
    /// shared backing — N records, one buffer, one lock acquisition,
    /// zero payload copies. Returns the offset of the first record.
    pub fn append_frames(&self, p: PartitionId, batch: &FinishedBatch) -> u64 {
        let now = self.clock.now();
        let mut log = self.log(p).write().unwrap();
        let first = log.records.len() as u64;
        log.records.reserve(batch.frames.len());
        for (i, fr) in batch.frames.iter().enumerate() {
            log.records.push(Record {
                offset: first + i as u64,
                event_ts: fr.ref_ts,
                insert_ts: now,
                payload: SharedBytes::view(batch.backing.clone(), fr.start, fr.len),
            });
        }
        first
    }

    /// Read up to `max` records from `offset` (Algorithm 2 line 9's
    /// `inStream.READ(id, idx)`). Returns the records and the next
    /// offset to read from.
    ///
    /// This is the *copying* path: it materializes an owned
    /// `Vec<Record>` per poll (counted in [`read_stats`](Self::read_stats)).
    /// Since payloads became [`SharedBytes`], each clone is an `Arc`
    /// refcount bump rather than a byte copy, but the per-poll record
    /// materialization still makes this unfit for steady-state polling.
    /// Hot paths use [`read_slice`](Self::read_slice) /
    /// [`read_with`](Self::read_with) instead; `read` remains for tests
    /// and oracles that want owned records after the run.
    pub fn read(&self, p: PartitionId, offset: u64, max: usize) -> (Vec<Record>, u64) {
        let log = self.log(p).read().unwrap();
        let start = (offset as usize).min(log.records.len());
        let end = (start + max).min(log.records.len());
        let recs = log.records[start..end].to_vec();
        self.payload_clones.fetch_add(recs.len() as u64, Ordering::Relaxed);
        self.records_read.fetch_add(recs.len() as u64, Ordering::Relaxed);
        let next = end as u64;
        (recs, next)
    }

    /// Zero-copy batch read: run `f` on the record slice *in place*
    /// (under the partition's read lock — appends to this partition wait
    /// until `f` returns) and return `f`'s result plus the next offset.
    /// This is RUN_BATCH's path: no `Vec<Record>` per poll, no payload
    /// `Arc` bumps.
    pub fn read_slice<R>(
        &self,
        p: PartitionId,
        offset: u64,
        max: usize,
        f: impl FnOnce(&[Record]) -> R,
    ) -> (R, u64) {
        let log = self.log(p).read().unwrap();
        let start = (offset as usize).min(log.records.len());
        let end = (start + max).min(log.records.len());
        self.records_read.fetch_add((end - start) as u64, Ordering::Relaxed);
        (f(&log.records[start..end]), end as u64)
    }

    /// Zero-copy per-record visitor: call `f` on each record from
    /// `offset` (up to `max`) and return the next offset — the sink's
    /// drain path.
    pub fn read_with(
        &self,
        p: PartitionId,
        offset: u64,
        max: usize,
        mut f: impl FnMut(&Record),
    ) -> u64 {
        self.read_slice(p, offset, max, |recs| {
            for rec in recs {
                f(rec);
            }
        })
        .1
    }

    /// (records cloned by the copying `read` path, records visited by
    /// any read path) since the topic was created. The clone count is
    /// the `holon bench` allocations-per-event proxy; before the
    /// zero-copy overhaul the two were equal by construction.
    pub fn read_stats(&self) -> (u64, u64) {
        (
            self.payload_clones.load(Ordering::Relaxed),
            self.records_read.load(Ordering::Relaxed),
        )
    }

    /// Current end offset (== number of records) of a partition.
    pub fn end_offset(&self, p: PartitionId) -> u64 {
        self.log(p).read().unwrap().records.len() as u64
    }

    /// Total records across partitions.
    pub fn total_records(&self) -> u64 {
        (0..self.partitions()).map(|p| self.end_offset(p)).sum()
    }
}

/// The broker: a registry of topics, shared by all nodes of a cluster.
#[derive(Debug, Clone)]
pub struct LogBroker {
    inner: Arc<BrokerInner>,
}

#[derive(Debug)]
struct BrokerInner {
    clock: SimClock,
    topics: Mutex<BTreeMap<String, Arc<Topic>>>,
}

impl LogBroker {
    pub fn new(clock: SimClock) -> Self {
        Self {
            inner: Arc::new(BrokerInner {
                clock,
                topics: Mutex::new(BTreeMap::new()),
            }),
        }
    }

    /// Create (or fetch) a topic with the given partition count.
    /// Partition counts are immutable once created, like Kafka's.
    pub fn topic(&self, name: &str, partitions: u32) -> Arc<Topic> {
        let mut topics = self.inner.topics.plane_lock();
        if let Some(t) = topics.get(name) {
            assert_eq!(
                t.partitions(),
                partitions,
                "topic {name} exists with different partition count"
            );
            return t.clone();
        }
        let t = Arc::new(Topic::new(name, partitions, self.inner.clock.clone()));
        topics.insert(name.to_string(), t.clone());
        t
    }

    /// Fetch an existing topic.
    pub fn get(&self, name: &str) -> Option<Arc<Topic>> {
        self.inner.topics.plane_lock().get(name).cloned()
    }

    pub fn clock(&self) -> &SimClock {
        &self.inner.clock
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn broker() -> LogBroker {
        LogBroker::new(SimClock::manual())
    }

    #[test]
    fn append_assigns_sequential_offsets() {
        let b = broker();
        let t = b.topic("in", 2);
        assert_eq!(t.append(0, 1, vec![1]), 0);
        assert_eq!(t.append(0, 2, vec![2]), 1);
        assert_eq!(t.append(1, 3, vec![3]), 0); // independent per partition
    }

    #[test]
    fn read_returns_slice_and_next_offset() {
        let b = broker();
        let t = b.topic("in", 1);
        for i in 0..5u8 {
            t.append(0, i as u64, vec![i]);
        }
        let (recs, next) = t.read(0, 1, 2);
        assert_eq!(recs.len(), 2);
        assert_eq!(recs[0].offset, 1);
        assert_eq!(next, 3);
        let (recs, next) = t.read(0, 4, 10);
        assert_eq!(recs.len(), 1);
        assert_eq!(next, 5);
        // reading past the end is empty, not an error
        let (recs, next) = t.read(0, 99, 10);
        assert!(recs.is_empty());
        assert_eq!(next, 5);
    }

    #[test]
    fn replay_is_deterministic() {
        // The exactly-once story depends on re-reading a prefix yielding
        // the identical records.
        let b = broker();
        let t = b.topic("in", 1);
        for i in 0..10u8 {
            t.append(0, i as u64, vec![i]);
        }
        let (a, _) = t.read(0, 0, 10);
        let (b2, _) = t.read(0, 0, 10);
        assert_eq!(a, b2);
    }

    #[test]
    fn insert_ts_comes_from_clock() {
        let clock = SimClock::manual();
        let b = LogBroker::new(clock.clone());
        let t = b.topic("in", 1);
        clock.advance(500);
        t.append(0, 1, vec![]);
        let (recs, _) = t.read(0, 0, 1);
        assert_eq!(recs[0].insert_ts, 500);
    }

    #[test]
    fn topics_are_shared_by_name() {
        let b = broker();
        let t1 = b.topic("x", 3);
        let t2 = b.topic("x", 3);
        t1.append(0, 0, vec![9]);
        assert_eq!(t2.end_offset(0), 1);
        assert!(b.get("x").is_some());
        assert!(b.get("y").is_none());
    }

    #[test]
    fn append_batch_is_contiguous() {
        let b = broker();
        let t = b.topic("in", 1);
        t.append(0, 0, vec![0]);
        let first = t.append_batch(0, vec![(1, vec![1]), (2, vec![2])]);
        assert_eq!(first, 1);
        assert_eq!(t.end_offset(0), 3);
        assert_eq!(t.total_records(), 3);
    }

    #[test]
    fn read_slice_is_zero_copy_and_tracks_offsets() {
        let b = broker();
        let t = b.topic("in", 1);
        for i in 0..5u8 {
            t.append(0, i as u64, vec![i]);
        }
        let (sum, next) = t.read_slice(0, 1, 3, |recs| {
            recs.iter().map(|r| r.payload[0] as u64).sum::<u64>()
        });
        assert_eq!(sum, 1 + 2 + 3);
        assert_eq!(next, 4);
        // past the end: empty slice, offset clamped
        let (n, next) = t.read_slice(0, 99, 10, |recs| recs.len());
        assert_eq!((n, next), (0, 5));
        // the zero-copy path visits records without cloning payloads
        let (clones, read) = t.read_stats();
        assert_eq!(clones, 0);
        assert_eq!(read, 3);
    }

    #[test]
    fn read_with_visits_each_record_once() {
        let b = broker();
        let t = b.topic("in", 1);
        for i in 0..4u8 {
            t.append(0, i as u64, vec![i]);
        }
        let mut seen = Vec::new();
        let next = t.read_with(0, 1, 2, |r| seen.push(r.offset));
        assert_eq!(seen, vec![1, 2]);
        assert_eq!(next, 3);
    }

    #[test]
    fn copying_read_bumps_clone_counter() {
        let b = broker();
        let t = b.topic("in", 1);
        for i in 0..3u8 {
            t.append(0, i as u64, vec![i]);
        }
        let _ = t.read(0, 0, 10);
        let (clones, read) = t.read_stats();
        assert_eq!(clones, 3);
        assert_eq!(read, 3);
    }

    #[test]
    #[should_panic]
    fn partition_count_mismatch_panics() {
        let b = broker();
        b.topic("x", 2);
        b.topic("x", 3);
    }

    #[test]
    fn append_frames_shares_one_backing_across_records() {
        use crate::arena::OutputArena;
        let b = broker();
        let t = b.topic("out", 1);
        let mut a = OutputArena::new();
        a.begin_batch();
        for ts in [10u64, 20, 30] {
            a.frame(ts, |w| {
                w.put_u64(ts * 7);
                true
            });
        }
        let batch = a.finish(100).unwrap();
        let expected: Vec<Vec<u8>> = batch
            .frames
            .iter()
            .map(|f| batch.backing[f.start as usize..(f.start + f.len) as usize].to_vec())
            .collect();
        let first = t.append_frames(0, &batch);
        assert_eq!(first, 0);
        assert_eq!(t.end_offset(0), 3);
        let (recs, _) = t.read(0, 0, 10);
        for (rec, want) in recs.iter().zip(&expected) {
            assert_eq!(&rec.payload[..], &want[..]);
        }
        // all three payloads are views into the same allocation
        assert!(Arc::ptr_eq(recs[0].payload.backing(), recs[2].payload.backing()));
        assert_eq!(recs[1].event_ts, 20);
    }

    #[test]
    fn shared_bytes_equality_is_by_visible_bytes() {
        let a: SharedBytes = vec![1u8, 2, 3].into();
        let backing = Arc::new(vec![9u8, 1, 2, 3, 9]);
        let b = SharedBytes::view(backing, 1, 3);
        assert_eq!(a, b);
        assert_eq!(&b[..], &[1, 2, 3]);
    }
}
