//! Logged streams — the Kafka substitute (DESIGN.md §2).
//!
//! The paper's deployment uses Kafka topics for input, output, broadcast
//! and control streams. The algorithms only require *logged, replayable,
//! offset-addressed* partitioned streams; this module provides exactly
//! that, in-process and thread-safe. Records carry their append
//! timestamp (sim-time), which is how end-to-end latency is measured —
//! "measured by Kafka insertion timestamps" (§5.1).

use std::collections::BTreeMap;
use std::sync::{Arc, Mutex, RwLock};

use crate::clock::SimClock;
use crate::util::{PartitionId, SimTime};

/// One record on a logged stream.
#[derive(Debug, Clone, PartialEq)]
pub struct Record {
    /// Offset within the partition (assigned at append).
    pub offset: u64,
    /// Event timestamp (sim-time) assigned by the producer.
    pub event_ts: SimTime,
    /// Append timestamp (sim-time) assigned by the broker.
    pub insert_ts: SimTime,
    /// Opaque payload bytes.
    pub payload: Arc<Vec<u8>>,
}

/// A single append-only partition.
#[derive(Debug, Default)]
struct PartitionLog {
    records: Vec<Record>,
}

/// A named, partitioned, append-only topic.
#[derive(Debug)]
pub struct Topic {
    name: String,
    clock: SimClock,
    partitions: Vec<RwLock<PartitionLog>>,
}

impl Topic {
    fn new(name: &str, partitions: u32, clock: SimClock) -> Self {
        Self {
            name: name.to_string(),
            clock,
            partitions: (0..partitions).map(|_| RwLock::new(PartitionLog::default())).collect(),
        }
    }

    pub fn name(&self) -> &str {
        &self.name
    }

    pub fn partitions(&self) -> u32 {
        self.partitions.len() as u32
    }

    fn log(&self, p: PartitionId) -> &RwLock<PartitionLog> {
        &self.partitions[p as usize]
    }

    /// Append one record; returns its offset.
    pub fn append(&self, p: PartitionId, event_ts: SimTime, payload: Vec<u8>) -> u64 {
        self.append_shared(p, event_ts, Arc::new(payload))
    }

    /// Append with a shared payload (zero-copy fan-out path).
    pub fn append_shared(&self, p: PartitionId, event_ts: SimTime, payload: Arc<Vec<u8>>) -> u64 {
        let now = self.clock.now();
        let mut log = self.log(p).write().unwrap();
        let offset = log.records.len() as u64;
        log.records.push(Record {
            offset,
            event_ts,
            insert_ts: now,
            payload,
        });
        offset
    }

    /// Append a batch; returns the offset of the first record.
    pub fn append_batch(&self, p: PartitionId, batch: Vec<(SimTime, Vec<u8>)>) -> u64 {
        let now = self.clock.now();
        let mut log = self.log(p).write().unwrap();
        let first = log.records.len() as u64;
        log.records.reserve(batch.len());
        for (i, (event_ts, payload)) in batch.into_iter().enumerate() {
            log.records.push(Record {
                offset: first + i as u64,
                event_ts,
                insert_ts: now,
                payload: Arc::new(payload),
            });
        }
        first
    }

    /// Read up to `max` records from `offset` (Algorithm 2 line 9's
    /// `inStream.READ(id, idx)`). Returns the records and the next
    /// offset to read from.
    pub fn read(&self, p: PartitionId, offset: u64, max: usize) -> (Vec<Record>, u64) {
        let log = self.log(p).read().unwrap();
        let start = (offset as usize).min(log.records.len());
        let end = (start + max).min(log.records.len());
        let recs = log.records[start..end].to_vec();
        let next = end as u64;
        (recs, next)
    }

    /// Current end offset (== number of records) of a partition.
    pub fn end_offset(&self, p: PartitionId) -> u64 {
        self.log(p).read().unwrap().records.len() as u64
    }

    /// Total records across partitions.
    pub fn total_records(&self) -> u64 {
        (0..self.partitions()).map(|p| self.end_offset(p)).sum()
    }
}

/// The broker: a registry of topics, shared by all nodes of a cluster.
#[derive(Debug, Clone)]
pub struct LogBroker {
    inner: Arc<BrokerInner>,
}

#[derive(Debug)]
struct BrokerInner {
    clock: SimClock,
    topics: Mutex<BTreeMap<String, Arc<Topic>>>,
}

impl LogBroker {
    pub fn new(clock: SimClock) -> Self {
        Self {
            inner: Arc::new(BrokerInner {
                clock,
                topics: Mutex::new(BTreeMap::new()),
            }),
        }
    }

    /// Create (or fetch) a topic with the given partition count.
    /// Partition counts are immutable once created, like Kafka's.
    pub fn topic(&self, name: &str, partitions: u32) -> Arc<Topic> {
        let mut topics = self.inner.topics.lock().unwrap();
        if let Some(t) = topics.get(name) {
            assert_eq!(
                t.partitions(),
                partitions,
                "topic {name} exists with different partition count"
            );
            return t.clone();
        }
        let t = Arc::new(Topic::new(name, partitions, self.inner.clock.clone()));
        topics.insert(name.to_string(), t.clone());
        t
    }

    /// Fetch an existing topic.
    pub fn get(&self, name: &str) -> Option<Arc<Topic>> {
        self.inner.topics.lock().unwrap().get(name).cloned()
    }

    pub fn clock(&self) -> &SimClock {
        &self.inner.clock
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn broker() -> LogBroker {
        LogBroker::new(SimClock::manual())
    }

    #[test]
    fn append_assigns_sequential_offsets() {
        let b = broker();
        let t = b.topic("in", 2);
        assert_eq!(t.append(0, 1, vec![1]), 0);
        assert_eq!(t.append(0, 2, vec![2]), 1);
        assert_eq!(t.append(1, 3, vec![3]), 0); // independent per partition
    }

    #[test]
    fn read_returns_slice_and_next_offset() {
        let b = broker();
        let t = b.topic("in", 1);
        for i in 0..5u8 {
            t.append(0, i as u64, vec![i]);
        }
        let (recs, next) = t.read(0, 1, 2);
        assert_eq!(recs.len(), 2);
        assert_eq!(recs[0].offset, 1);
        assert_eq!(next, 3);
        let (recs, next) = t.read(0, 4, 10);
        assert_eq!(recs.len(), 1);
        assert_eq!(next, 5);
        // reading past the end is empty, not an error
        let (recs, next) = t.read(0, 99, 10);
        assert!(recs.is_empty());
        assert_eq!(next, 5);
    }

    #[test]
    fn replay_is_deterministic() {
        // The exactly-once story depends on re-reading a prefix yielding
        // the identical records.
        let b = broker();
        let t = b.topic("in", 1);
        for i in 0..10u8 {
            t.append(0, i as u64, vec![i]);
        }
        let (a, _) = t.read(0, 0, 10);
        let (b2, _) = t.read(0, 0, 10);
        assert_eq!(a, b2);
    }

    #[test]
    fn insert_ts_comes_from_clock() {
        let clock = SimClock::manual();
        let b = LogBroker::new(clock.clone());
        let t = b.topic("in", 1);
        clock.advance(500);
        t.append(0, 1, vec![]);
        let (recs, _) = t.read(0, 0, 1);
        assert_eq!(recs[0].insert_ts, 500);
    }

    #[test]
    fn topics_are_shared_by_name() {
        let b = broker();
        let t1 = b.topic("x", 3);
        let t2 = b.topic("x", 3);
        t1.append(0, 0, vec![9]);
        assert_eq!(t2.end_offset(0), 1);
        assert!(b.get("x").is_some());
        assert!(b.get("y").is_none());
    }

    #[test]
    fn append_batch_is_contiguous() {
        let b = broker();
        let t = b.topic("in", 1);
        t.append(0, 0, vec![0]);
        let first = t.append_batch(0, vec![(1, vec![1]), (2, vec![2])]);
        assert_eq!(first, 1);
        assert_eq!(t.end_offset(0), 3);
        assert_eq!(t.total_records(), 3);
    }

    #[test]
    #[should_panic]
    fn partition_count_mismatch_panics() {
        let b = broker();
        b.topic("x", 2);
        b.topic("x", 3);
    }
}
