//! The dataflow API (paper §3.1): a Flink-like declarative veneer over
//! the procedural API. "Programs in the dataflow API are always
//! deterministic" (§3.3) because they compile to the safe emission
//! pattern: windows are drained in sequence behind a cursor, so the
//! nondeterministic completion *timing* never reaches the user code.
//!
//! A [`WindowQuery`] is the paper's Figure-2 pipeline: source →
//! windowed CRDT insert → (completed) window value → map → emit. The
//! user supplies two closures — how an event folds into the CRDT and
//! how a completed window value maps to an output — and gets a full
//! [`Processor`] with exactly-once, work stealing and determinism for
//! free.

use std::marker::PhantomData;

use crate::crdt::Crdt;
use crate::log::Record;
use crate::nexmark::Event;
use crate::util::{PartitionId, SimTime};
use crate::wcrdt::{WatermarkGen, WindowAssigner, WindowId, WindowedCrdt};

use super::{Ctx, Processor};

/// Emission cursor local state (same layout as queries::Cursor, kept
/// here so the dataflow API has no dependency on the query module).
#[derive(Debug, Clone, Default, PartialEq)]
pub struct DfCursor {
    pub next: WindowId,
}

impl crate::codec::Encode for DfCursor {
    fn encode(&self, w: &mut crate::codec::Writer) {
        w.put_u64(self.next);
    }
}

impl crate::codec::Decode for DfCursor {
    fn decode(r: &mut crate::codec::Reader) -> crate::codec::DecodeResult<Self> {
        Ok(DfCursor { next: r.get_u64()? })
    }
}

/// A declarative windowed global aggregation.
///
/// ```ignore
/// // Q7 in the dataflow API: five lines.
/// let q7 = WindowQueryBuilder::<BoundedTopK>::tumbling(1000)
///     .insert(|p, ev, tk| {
///         if let Event::Bid { auction, price, .. } = ev {
///             tk.offer(*price, *auction, p as u64);
///         }
///     })
///     .emit(|w, tk| Some(encode(w, tk.max_score())));
/// ```
#[derive(Clone)]
pub struct WindowQuery<C, FIns, FEmit>
where
    C: Crdt,
    FIns: Fn(PartitionId, &Event, &mut C) + Clone + Send + Sync + 'static,
    FEmit: Fn(WindowId, &C) -> Option<Vec<u8>> + Clone + Send + Sync + 'static,
{
    assigner: WindowAssigner,
    watermark_gen: WatermarkGen,
    insert: FIns,
    emit: FEmit,
    _marker: PhantomData<fn() -> C>,
}

/// Builder entry point: a tumbling-window query over a CRDT type.
pub struct WindowQueryBuilder<C: Crdt> {
    assigner: WindowAssigner,
    watermark_gen: WatermarkGen,
    _marker: PhantomData<fn() -> C>,
}

impl<C: Crdt> WindowQueryBuilder<C> {
    /// Start building a tumbling-window query.
    pub fn tumbling(window_ms: SimTime) -> Self {
        Self {
            assigner: WindowAssigner::tumbling(window_ms),
            watermark_gen: WatermarkGen::Ascending,
            _marker: PhantomData,
        }
    }

    /// Start building a sliding-window query (§7 window generalization;
    /// events fold into every covering window).
    pub fn sliding(size_ms: SimTime, slide_ms: SimTime) -> Self {
        Self {
            assigner: WindowAssigner::sliding(size_ms, slide_ms),
            watermark_gen: WatermarkGen::Ascending,
            _marker: PhantomData,
        }
    }

    /// Tolerate events arriving up to `max_delay_ms` late (paper §3.2's
    /// out-of-order handling): the partition watermark trails the max
    /// observed event time by the bound; later events are dropped.
    pub fn allowed_lateness(mut self, max_delay_ms: SimTime) -> Self {
        self.watermark_gen = WatermarkGen::BoundedOutOfOrder { max_delay_ms };
        self
    }

    /// Provide the event-fold: how one event updates this partition's
    /// contribution to its window.
    pub fn insert<FIns>(self, insert: FIns) -> WindowQueryEmit<C, FIns>
    where
        FIns: Fn(PartitionId, &Event, &mut C) + Clone + Send + Sync + 'static,
    {
        WindowQueryEmit {
            assigner: self.assigner,
            watermark_gen: self.watermark_gen,
            insert,
            _marker: PhantomData,
        }
    }
}

/// Intermediate builder holding the insert fold.
pub struct WindowQueryEmit<C: Crdt, FIns> {
    assigner: WindowAssigner,
    watermark_gen: WatermarkGen,
    insert: FIns,
    _marker: PhantomData<fn() -> C>,
}

impl<C, FIns> WindowQueryEmit<C, FIns>
where
    C: Crdt,
    FIns: Fn(PartitionId, &Event, &mut C) + Clone + Send + Sync + 'static,
{
    /// Provide the output map over completed (deterministic) window
    /// values; `None` suppresses the window's output.
    pub fn emit<FEmit>(self, emit: FEmit) -> WindowQuery<C, FIns, FEmit>
    where
        FEmit: Fn(WindowId, &C) -> Option<Vec<u8>> + Clone + Send + Sync + 'static,
    {
        WindowQuery {
            assigner: self.assigner,
            watermark_gen: self.watermark_gen,
            insert: self.insert,
            emit,
            _marker: PhantomData,
        }
    }
}

impl<C, FIns, FEmit> Processor for WindowQuery<C, FIns, FEmit>
where
    C: Crdt,
    FIns: Fn(PartitionId, &Event, &mut C) + Clone + Send + Sync + 'static,
    FEmit: Fn(WindowId, &C) -> Option<Vec<u8>> + Clone + Send + Sync + 'static,
{
    type Shared = WindowedCrdt<C>;
    type Local = DfCursor;

    fn init_shared(&self, partitions: &[PartitionId]) -> Self::Shared {
        WindowedCrdt::new(self.assigner, partitions.iter().copied())
    }

    fn process(
        &self,
        ctx: &mut Ctx,
        shared: &Self::Shared,
        own: &mut Self::Shared,
        local: &mut DfCursor,
        events: &[Record],
    ) {
        let p = ctx.partition;
        let mut max_ts = own.progress_of(p)
            + match self.watermark_gen {
                WatermarkGen::Ascending => 0,
                WatermarkGen::BoundedOutOfOrder { max_delay_ms } => max_delay_ms,
            };
        let mut saw_event = false;
        for rec in events {
            if let Ok(ev) = crate::codec::Decode::from_bytes(&rec.payload) {
                let ev: Event = ev;
                max_ts = max_ts.max(rec.event_ts);
                saw_event = true;
                if self.watermark_gen.is_late(rec.event_ts, max_ts) {
                    continue; // beyond the allowed lateness: drop
                }
                // fold into every covering window (1 for tumbling)
                for w in self.assigner.windows_of(rec.event_ts) {
                    own.insert_window_with(p, w, |c| (self.insert)(p, &ev, c));
                }
            }
        }
        if saw_event {
            own.increment_watermark(p, self.watermark_gen.watermark(max_ts));
        }

        // The safe emission pattern (cursor-sequenced deterministic reads).
        if local.next < shared.first_available() {
            local.next = shared.first_available();
        }
        while let Some(value) = shared.window_value(local.next) {
            let w = local.next;
            if let Some(payload) = (self.emit)(w, &value) {
                ctx.emit(self.assigner.window_end(w), payload);
            }
            local.next += 1;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::api::{ScalarAggregator, SharedState};
    use crate::codec::{Decode, Encode};
    use crate::crdt::{BoundedTopK, GCounter};
    use crate::nexmark::queries::{Q7Out, Q7};
    use std::sync::Arc;

    fn bid(offset: u64, ts: u64, auction: u64, price: f64) -> Record {
        Record {
            offset,
            event_ts: ts,
            insert_ts: ts,
            payload: Arc::new(
                Event::Bid {
                    auction,
                    bidder: 0,
                    price,
                    category: auction % 10,
                }
                .to_bytes(),
            ),
        }
    }

    fn run<P: Processor>(
        q: &P,
        shared: &mut P::Shared,
        own: &mut P::Shared,
        local: &mut P::Local,
        events: &[Record],
    ) -> Vec<crate::api::Output> {
        let mut agg = ScalarAggregator;
        let mut ctx = Ctx::new(0, 0, &mut agg);
        q.process(&mut ctx, shared, own, local, events);
        shared.join(own);
        ctx.into_outputs()
    }

    /// Q7 expressed in the dataflow API.
    fn dataflow_q7() -> impl Processor<Shared = WindowedCrdt<BoundedTopK>, Local = DfCursor> {
        WindowQueryBuilder::<BoundedTopK>::tumbling(1000)
            .insert(|p, ev, tk: &mut BoundedTopK| {
                if let Event::Bid { auction, price, .. } = ev {
                    tk.set_k(1);
                    tk.offer(*price, *auction, p as u64);
                }
            })
            .emit(|w, tk| {
                let (price, auction) = tk
                    .top()
                    .first()
                    .map(|&(s, a, _)| (s.0, a))
                    .unwrap_or((0.0, 0));
                Some(
                    Q7Out {
                        window: w,
                        price,
                        auction,
                    }
                    .to_bytes(),
                )
            })
    }

    #[test]
    fn dataflow_q7_matches_procedural_q7() {
        let df = dataflow_q7();
        let proc_q7 = Q7::new(1000);

        let events = vec![
            bid(0, 100, 1, 50.0),
            bid(1, 600, 2, 90.0),
            bid(2, 1200, 3, 10.0),
            bid(3, 2300, 4, 70.0),
        ];

        // run the dataflow version
        let mut s1 = df.init_shared(&[0]);
        let mut o1 = df.init_shared(&[0]);
        let mut l1 = DfCursor::default();
        run(&df, &mut s1, &mut o1, &mut l1, &events);
        let out_df = run(&df, &mut s1, &mut o1, &mut l1, &[]);

        // run the hand-written version
        let mut s2 = proc_q7.init_shared(&[0]);
        let mut o2 = proc_q7.init_shared(&[0]);
        let mut l2 = crate::nexmark::queries::Cursor::default();
        let mut agg = ScalarAggregator;
        let mut ctx = Ctx::new(0, 0, &mut agg);
        proc_q7.process(&mut ctx, &s2, &mut o2, &mut l2, &events);
        s2.join(&o2);
        let mut ctx = Ctx::new(0, 0, &mut agg);
        proc_q7.process(&mut ctx, &s2, &mut o2, &mut l2, &[]);
        let out_proc = ctx.into_outputs();

        assert_eq!(out_df.len(), out_proc.len());
        for (a, b) in out_df.iter().zip(out_proc.iter()) {
            assert_eq!(
                Q7Out::from_bytes(&a.payload).unwrap(),
                Q7Out::from_bytes(&b.payload).unwrap()
            );
        }
    }

    #[test]
    fn dataflow_counts_bids_per_window() {
        let q = WindowQueryBuilder::<GCounter>::tumbling(1000)
            .insert(|p, ev, c: &mut GCounter| {
                if ev.is_bid() {
                    c.add(p as u64, 1);
                }
            })
            .emit(|w, c| {
                let mut wr = crate::codec::Writer::new();
                wr.put_u64(w);
                wr.put_u64(c.value());
                Some(wr.into_bytes())
            });
        let mut s = q.init_shared(&[0]);
        let mut o = q.init_shared(&[0]);
        let mut l = DfCursor::default();
        run(
            &q,
            &mut s,
            &mut o,
            &mut l,
            &[bid(0, 100, 1, 1.0), bid(1, 200, 2, 1.0), bid(2, 1500, 3, 1.0)],
        );
        let outs = run(&q, &mut s, &mut o, &mut l, &[]);
        assert_eq!(outs.len(), 1);
        let mut r = crate::codec::Reader::new(&outs[0].payload);
        assert_eq!(r.get_u64().unwrap(), 0); // window
        assert_eq!(r.get_u64().unwrap(), 2); // bids in window 0
    }

    #[test]
    fn allowed_lateness_accepts_bounded_disorder() {
        let count_query = |lateness: Option<u64>| {
            let b = WindowQueryBuilder::<GCounter>::tumbling(1000);
            let b = match lateness {
                Some(ms) => b.allowed_lateness(ms),
                None => b,
            };
            b.insert(|p, ev, c: &mut GCounter| {
                if ev.is_bid() {
                    c.add(p as u64, 1);
                }
            })
            .emit(|w, c| {
                let mut wr = crate::codec::Writer::new();
                wr.put_u64(w);
                wr.put_u64(c.value());
                Some(wr.into_bytes())
            })
        };
        // out-of-order stream: 100, 700, 400 (300 late), 2600
        let events = vec![
            bid(0, 100, 1, 1.0),
            bid(1, 700, 2, 1.0),
            bid(2, 400, 3, 1.0),
            bid(3, 2600, 4, 1.0),
        ];
        // with 500 ms allowed lateness, the 400-ts event counts
        let q = count_query(Some(500));
        let mut s = q.init_shared(&[0]);
        let mut o = q.init_shared(&[0]);
        let mut l = DfCursor::default();
        run(&q, &mut s, &mut o, &mut l, &events);
        let outs = run(&q, &mut s, &mut o, &mut l, &[]);
        // watermark = 2600 - 500 = 2100 => window 0 and 1 complete
        assert_eq!(outs.len(), 2);
        let mut r = crate::codec::Reader::new(&outs[0].payload);
        r.get_u64().unwrap();
        assert_eq!(r.get_u64().unwrap(), 3, "late-but-bounded event counted");
    }

    #[test]
    fn sliding_window_folds_into_covering_windows() {
        let q = WindowQueryBuilder::<GCounter>::sliding(2000, 1000)
            .insert(|p, ev, c: &mut GCounter| {
                if ev.is_bid() {
                    c.add(p as u64, 1);
                }
            })
            .emit(|w, c| {
                let mut wr = crate::codec::Writer::new();
                wr.put_u64(w);
                wr.put_u64(c.value());
                Some(wr.into_bytes())
            });
        let mut s = q.init_shared(&[0]);
        let mut o = q.init_shared(&[0]);
        let mut l = DfCursor::default();
        // ts=1500 is covered by windows 0 ([0,2000)) and 1 ([1000,3000))
        run(&q, &mut s, &mut o, &mut l, &[bid(0, 1500, 1, 1.0), bid(1, 3500, 2, 1.0)]);
        let outs = run(&q, &mut s, &mut o, &mut l, &[]);
        // watermark 3500 completes windows 0 ([0,2000)) and 1 ([1000,3000))
        assert_eq!(outs.len(), 2);
        let mut r = crate::codec::Reader::new(&outs[0].payload);
        r.get_u64().unwrap();
        assert_eq!(r.get_u64().unwrap(), 1); // window 0 sees the ts=1500 bid
        let mut r = crate::codec::Reader::new(&outs[1].payload);
        r.get_u64().unwrap();
        assert_eq!(r.get_u64().unwrap(), 1); // window 1 sees it too
    }
}
