//! Dataflow API v2 (paper §3.1): declarative, composable window
//! pipelines compiled onto the procedural [`Processor`] model.
//!
//! "Programs in the dataflow API are always deterministic" (§3.3)
//! because every pipeline compiles to the safe emission pattern:
//! completed windows are drained in sequence behind an [`EmitCursor`],
//! so the nondeterministic completion *timing* never reaches user code.
//!
//! A pipeline is the paper's Figure-2 shape, generalized:
//!
//! ```text
//! source::<E>() → filter/map/flat_map → window → (key_by →) aggregate → emit
//! ```
//!
//! * the **decode stage** turns log [`Record`]s into any event type `E`
//!   ([`Dataflow::source`] for `E: Decode`, [`Dataflow::from_fn`] for
//!   custom decoders) — nothing is hardcoded to Nexmark;
//! * **pre-window combinators** [`filter`](Dataflow::filter),
//!   [`map`](Dataflow::map), [`filter_map`](Dataflow::filter_map) and
//!   [`flat_map`](Dataflow::flat_map) reshape the event stream;
//! * [`tumbling`](Dataflow::tumbling) / [`sliding`](Dataflow::sliding)
//!   open a windowed scope; [`allowed_lateness`](Windowed::allowed_lateness)
//!   tolerates bounded disorder (§3.2);
//! * [`key_by`](Windowed::key_by) routes events into per-key CRDT
//!   aggregation backed by [`MapCrdt`];
//!   [`key_by_sharded`](Windowed::key_by_sharded) is the same stage over
//!   shard-partitioned keyed state ([`ShardedMapCrdt`]: per-shard delta
//!   gossip, parallel shard merge) for large key spaces;
//! * [`aggregate`](Windowed::aggregate) folds events into any [`Crdt`],
//!   and [`emit_typed`](WindowAgg::emit_typed) maps each completed
//!   (globally deterministic) window value to a typed, `Encode`d output;
//! * stateless pipelines end with [`emit_each`](Dataflow::emit_each)
//!   (Nexmark Q0/Q2 are two lines);
//! * [`MultiQuery`] fans one event stream into several pipelines that
//!   share a single engine job (multiway composition in the sense of
//!   Gulisano et al.), tagging each output with its branch.
//!
//! Q7 ("highest bid per window") in the v2 API:
//!
//! ```ignore
//! let q7 = Dataflow::<Event>::source()
//!     .tumbling(1000)
//!     .aggregate(|p, ev, tk: &mut BoundedTopK| {
//!         if let Event::Bid { auction, price, .. } = ev {
//!             tk.offer(*price, *auction, p as u64);
//!         }
//!     })
//!     .emit_typed(|w, tk| Some(Q7Out { window: w, price: tk.max_score().unwrap_or(0.0), auction: 0 }));
//! ```
//!
//! Exactly-once, work stealing and whole-system determinism are
//! inherited from the engine for free — a pipeline *is* a [`Processor`].

use std::sync::Arc;

use crate::codec::{Decode, Encode, Writer};
use crate::crdt::{Crdt, MapCrdt};
use crate::log::Record;
use crate::shard::ShardedMapCrdt;
use crate::util::{PartitionId, SimTime};
use crate::wcrdt::{WatermarkGen, WindowAssigner, WindowId, WindowedCrdt};

use super::{Ctx, EmitCursor, Processor};

/// The canonical emission cursor under its historical dataflow name.
pub use super::EmitCursor as DfCursor;

/// Fused decode + pre-window transform chain in sink style: one record
/// in, zero or more events pushed into the sink (zero: undecodable or
/// filtered; >1: `flat_map`). Sink style keeps the per-event hot path
/// allocation-free — combinators nest closures instead of collecting
/// intermediate `Vec`s per stage.
type XForm<E> = Arc<dyn Fn(&Record, &mut dyn FnMut(E)) + Send + Sync>;
/// Event fold into a per-window CRDT contribution.
type InsertFn<E, C> = Arc<dyn Fn(PartitionId, &E, &mut C) + Send + Sync>;
/// Completed-window emission: encode the output *in place* into the
/// batch's arena frame (`false` withdraws the frame — the zero-alloc
/// analogue of returning `None`).
type EmitFn<C> = Arc<dyn Fn(WindowId, &C, &mut Writer) -> bool + Send + Sync>;

// ======================================================================
// Stage 1 — event stream: decode + filter/map/flat_map
// ======================================================================

/// A typed event stream: the decode stage plus any chain of pre-window
/// combinators. Entry point of every v2 pipeline.
pub struct Dataflow<E> {
    xform: XForm<E>,
}

impl<E> Clone for Dataflow<E> {
    fn clone(&self) -> Self {
        Self {
            xform: Arc::clone(&self.xform),
        }
    }
}

impl<E: Decode + 'static> Dataflow<E> {
    /// Source stage: decode each record payload as an `E`. Records that
    /// fail to decode are skipped (they still advance event time).
    pub fn source() -> Self {
        Self {
            xform: Arc::new(|rec: &Record, sink: &mut dyn FnMut(E)| {
                if let Ok(e) = E::from_bytes(&rec.payload) {
                    sink(e);
                }
            }),
        }
    }
}

impl<E: 'static> Dataflow<E> {
    /// Source stage with a custom decoder — for event types that do not
    /// implement [`Decode`] or live in foreign formats. `None` skips the
    /// record.
    pub fn from_fn(f: impl Fn(&Record) -> Option<E> + Send + Sync + 'static) -> Self {
        Self {
            xform: Arc::new(move |rec, sink| {
                if let Some(e) = f(rec) {
                    sink(e);
                }
            }),
        }
    }

    /// Keep only events matching `pred`. Dropped events still advance
    /// the partition watermark (they were observed, just not folded).
    pub fn filter(self, pred: impl Fn(&E) -> bool + Send + Sync + 'static) -> Self {
        let prev = self.xform;
        Self {
            xform: Arc::new(move |rec, sink| {
                prev(rec, &mut |e| {
                    if pred(&e) {
                        sink(e);
                    }
                })
            }),
        }
    }

    /// Transform each event.
    pub fn map<F: 'static>(self, f: impl Fn(E) -> F + Send + Sync + 'static) -> Dataflow<F> {
        let prev = self.xform;
        Dataflow {
            xform: Arc::new(move |rec, sink| prev(rec, &mut |e| sink(f(e)))),
        }
    }

    /// Filter and transform in one stage.
    pub fn filter_map<F: 'static>(
        self,
        f: impl Fn(E) -> Option<F> + Send + Sync + 'static,
    ) -> Dataflow<F> {
        let prev = self.xform;
        Dataflow {
            xform: Arc::new(move |rec, sink| {
                prev(rec, &mut |e| {
                    if let Some(x) = f(e) {
                        sink(x);
                    }
                })
            }),
        }
    }

    /// Expand each event into zero or more events.
    pub fn flat_map<F: 'static, I: IntoIterator<Item = F>>(
        self,
        f: impl Fn(E) -> I + Send + Sync + 'static,
    ) -> Dataflow<F> {
        let prev = self.xform;
        Dataflow {
            xform: Arc::new(move |rec, sink| {
                prev(rec, &mut |e| {
                    for x in f(e) {
                        sink(x);
                    }
                })
            }),
        }
    }

    /// Open a tumbling-window scope of `window_ms` sim-ms.
    pub fn tumbling(self, window_ms: SimTime) -> Windowed<E> {
        Windowed {
            xform: self.xform,
            assigner: WindowAssigner::tumbling(window_ms),
            watermark_gen: WatermarkGen::Ascending,
        }
    }

    /// Open a sliding-window scope (§7 window generalization; events
    /// fold into every covering window).
    pub fn sliding(self, size_ms: SimTime, slide_ms: SimTime) -> Windowed<E> {
        Windowed {
            xform: self.xform,
            assigner: WindowAssigner::sliding(size_ms, slide_ms),
            watermark_gen: WatermarkGen::Ascending,
        }
    }

    /// Stateless terminal stage: emit one typed output per surviving
    /// event, re-using the event's broker insertion time as the latency
    /// reference (Nexmark Q0/Q2 shape). `None` suppresses the event.
    pub fn emit_each<O: Encode + 'static>(
        self,
        f: impl Fn(&E) -> Option<O> + Send + Sync + 'static,
    ) -> Passthrough {
        let xform = self.xform;
        Passthrough {
            apply: Arc::new(move |rec, ctx| {
                xform(rec, &mut |e| {
                    if let Some(o) = f(&e) {
                        // Latency reference = input insertion time; the
                        // output encodes straight into the arena frame.
                        ctx.emit_with(rec.insert_ts, |w| o.encode(w));
                    }
                })
            }),
        }
    }
}

// ======================================================================
// Stage 2 — windowed scope
// ======================================================================

/// A windowed event stream awaiting its aggregation fold.
pub struct Windowed<E> {
    xform: XForm<E>,
    assigner: WindowAssigner,
    watermark_gen: WatermarkGen,
}

impl<E: 'static> Windowed<E> {
    /// Tolerate events arriving up to `max_delay_ms` late (§3.2): the
    /// partition watermark trails the maximum observed event time by the
    /// bound; events later than the bound are dropped.
    pub fn allowed_lateness(mut self, max_delay_ms: SimTime) -> Self {
        self.watermark_gen = WatermarkGen::BoundedOutOfOrder { max_delay_ms };
        self
    }

    /// Fold every event of a window into one CRDT contribution — the
    /// *global* (unkeyed) aggregation of the paper's Figure 2.
    pub fn aggregate<C: Crdt>(
        self,
        insert: impl Fn(PartitionId, &E, &mut C) + Send + Sync + 'static,
    ) -> WindowAgg<E, C> {
        WindowAgg {
            xform: self.xform,
            assigner: self.assigner,
            watermark_gen: self.watermark_gen,
            insert: Arc::new(insert),
        }
    }

    /// Route events into per-key CRDT aggregation (backed by
    /// [`MapCrdt`]) — keyed global aggregations like Nexmark Q4/Q5,
    /// still shuffle-free.
    pub fn key_by<K>(self, key: impl Fn(&E) -> K + Send + Sync + 'static) -> Keyed<E, K>
    where
        K: Ord + Clone + Send + Encode + Decode + 'static,
    {
        Keyed {
            inner: self,
            key: Arc::new(key),
        }
    }

    /// As [`key_by`](Self::key_by), but the per-key state is partitioned
    /// across `shards` (rounded up to a power of two) independent inner
    /// maps by seeded key-hash — [`ShardedMapCrdt`]. Same outputs, byte
    /// for byte; what changes is the replication machinery: gossip ships
    /// per-shard deltas, replica joins merge shards in parallel, and
    /// checkpoints slice per shard. Use for keyed pipelines whose key
    /// space (and therefore map state) is large enough that whole-map
    /// gossip or single-core merges are the bottleneck.
    pub fn key_by_sharded<K>(
        self,
        shards: u32,
        key: impl Fn(&E) -> K + Send + Sync + 'static,
    ) -> KeyedSharded<E, K>
    where
        K: Ord + Clone + Send + Sync + Encode + Decode + 'static,
    {
        KeyedSharded {
            inner: self,
            key: Arc::new(key),
            shards,
        }
    }
}

/// A windowed, keyed event stream awaiting its per-key fold.
pub struct Keyed<E, K> {
    inner: Windowed<E>,
    key: Arc<dyn Fn(&E) -> K + Send + Sync>,
}

impl<E: 'static, K> Keyed<E, K>
where
    K: Ord + Clone + Send + Encode + Decode + 'static,
{
    /// Fold each event into the CRDT of its key (created at lattice
    /// bottom on first touch). The pipeline's window value is a
    /// [`MapCrdt`] from key to the inner CRDT.
    pub fn aggregate<C: Crdt>(
        self,
        insert: impl Fn(PartitionId, &E, &mut C) + Send + Sync + 'static,
    ) -> WindowAgg<E, MapCrdt<K, C>> {
        let key = self.key;
        WindowAgg {
            xform: self.inner.xform,
            assigner: self.inner.assigner,
            watermark_gen: self.inner.watermark_gen,
            insert: Arc::new(move |p, e, m: &mut MapCrdt<K, C>| insert(p, e, m.entry(key(e)))),
        }
    }
}

/// A windowed, keyed event stream whose per-key state is shard-
/// partitioned. Created by [`Windowed::key_by_sharded`].
pub struct KeyedSharded<E, K> {
    inner: Windowed<E>,
    key: Arc<dyn Fn(&E) -> K + Send + Sync>,
    shards: u32,
}

impl<E: 'static, K> KeyedSharded<E, K>
where
    K: Ord + Clone + Send + Sync + Encode + Decode + 'static,
{
    /// Fold each event into the CRDT of its key. The pipeline's window
    /// value is a [`ShardedMapCrdt`]; window values created at lattice
    /// bottom adopt the configured shard count on first insert (a
    /// decoded or gossip-merged window keeps the layout it arrived
    /// with — layouts are fixed per deployment).
    pub fn aggregate<C: Crdt + Sync>(
        self,
        insert: impl Fn(PartitionId, &E, &mut C) + Send + Sync + 'static,
    ) -> WindowAgg<E, ShardedMapCrdt<K, C>> {
        let key = self.key;
        let shards = self.shards;
        WindowAgg {
            xform: self.inner.xform,
            assigner: self.inner.assigner,
            watermark_gen: self.inner.watermark_gen,
            insert: Arc::new(move |p, e, m: &mut ShardedMapCrdt<K, C>| {
                m.ensure_shards(shards);
                insert(p, e, m.entry(key(e)))
            }),
        }
    }
}

// ======================================================================
// Stage 3 — aggregated scope awaiting emission
// ======================================================================

/// A fully-folded window pipeline awaiting its emission stage.
pub struct WindowAgg<E, C: Crdt> {
    xform: XForm<E>,
    assigner: WindowAssigner,
    watermark_gen: WatermarkGen,
    insert: InsertFn<E, C>,
}

impl<E: 'static, C: Crdt> WindowAgg<E, C> {
    /// Typed emission: map each completed (deterministic) window value
    /// to an `Encode`d output; `None` suppresses the window. The output
    /// encodes straight into the batch's arena frame — no intermediate
    /// `Vec<u8>` per record.
    pub fn emit_typed<O: Encode + 'static>(
        self,
        emit: impl Fn(WindowId, &C) -> Option<O> + Send + Sync + 'static,
    ) -> WindowPipeline<E, C> {
        self.emit_raw(move |w, c, wr| match emit(w, c) {
            Some(o) => {
                o.encode(wr);
                true
            }
            None => false,
        })
    }

    /// Raw emission: write the output payload directly through the
    /// [`Writer`] positioned inside the arena frame; return `false` to
    /// suppress the window (the frame is rolled back).
    pub fn emit_raw(
        self,
        emit: impl Fn(WindowId, &C, &mut Writer) -> bool + Send + Sync + 'static,
    ) -> WindowPipeline<E, C> {
        WindowPipeline {
            xform: self.xform,
            assigner: self.assigner,
            watermark_gen: self.watermark_gen,
            insert: self.insert,
            emit: Arc::new(emit),
        }
    }
}

// ======================================================================
// Compiled pipelines (Processor impls)
// ======================================================================

/// A compiled windowed pipeline: decode → transforms → WCRDT fold →
/// cursor-drained typed emission. Created by [`WindowAgg::emit_typed`].
pub struct WindowPipeline<E, C: Crdt> {
    xform: XForm<E>,
    assigner: WindowAssigner,
    watermark_gen: WatermarkGen,
    insert: InsertFn<E, C>,
    emit: EmitFn<C>,
}

impl<E, C: Crdt> Clone for WindowPipeline<E, C> {
    fn clone(&self) -> Self {
        Self {
            xform: Arc::clone(&self.xform),
            assigner: self.assigner,
            watermark_gen: self.watermark_gen,
            insert: Arc::clone(&self.insert),
            emit: Arc::clone(&self.emit),
        }
    }
}

impl<E: 'static, C: Crdt> Processor for WindowPipeline<E, C> {
    type Shared = WindowedCrdt<C>;
    type Local = EmitCursor;

    fn init_shared(&self, partitions: &[PartitionId]) -> Self::Shared {
        WindowedCrdt::new(self.assigner, partitions.iter().copied())
    }

    fn process(
        &self,
        ctx: &mut Ctx,
        shared: &Self::Shared,
        own: &mut Self::Shared,
        local: &mut EmitCursor,
        events: &[Record],
    ) {
        let p = ctx.partition;
        let mut max_ts = own.progress_of(p)
            + match self.watermark_gen {
                WatermarkGen::Ascending => 0,
                WatermarkGen::BoundedOutOfOrder { max_delay_ms } => max_delay_ms,
            };
        // One reusable buffer for the whole batch: the transform chain
        // sinks into it, so the common 0/1-event record allocates nothing
        // after warm-up, and sliding windows fold the decoded events into
        // every covering window without re-running the chain.
        let mut evs: Vec<E> = Vec::new();
        for rec in events {
            // Every record advances event time — including ones the
            // transform chain drops — matching the procedural queries'
            // watermark behavior (a filtered-out event was still
            // observed by this partition).
            max_ts = max_ts.max(rec.event_ts);
            if self.watermark_gen.is_late(rec.event_ts, max_ts) {
                // Beyond the allowed lateness: drop. Under `Ascending`
                // this fires on any timestamp regression, which keeps
                // the pipeline deterministic under re-batching; ordered
                // input per partition (the paper's implementation
                // assumption) never triggers it.
                continue;
            }
            evs.clear();
            (self.xform)(rec, &mut |e| evs.push(e));
            if evs.is_empty() {
                continue;
            }
            // fold into every covering window (1 for tumbling)
            for w in self.assigner.windows_of(rec.event_ts) {
                own.insert_window_with(p, w, |c| {
                    for e in &evs {
                        (self.insert)(p, e, c);
                    }
                });
            }
        }
        if !events.is_empty() {
            own.increment_watermark(p, self.watermark_gen.watermark(max_ts));
        }

        // The safe emission pattern (cursor-sequenced deterministic
        // reads), encoding each window's output in place in the arena.
        if local.next < shared.first_available() {
            local.next = shared.first_available();
        }
        while let Some(value) = shared.window_value(local.next) {
            let w = local.next;
            ctx.try_emit_with(self.assigner.window_end(w), |wr| (self.emit)(w, &value, wr));
            local.next += 1;
        }
    }
}

/// A compiled stateless pipeline: decode → transforms → per-event typed
/// emission (no windows, no shared state). Created by
/// [`Dataflow::emit_each`].
pub struct Passthrough {
    apply: Arc<dyn Fn(&Record, &mut Ctx) + Send + Sync>,
}

impl Clone for Passthrough {
    fn clone(&self) -> Self {
        Self {
            apply: Arc::clone(&self.apply),
        }
    }
}

impl Processor for Passthrough {
    type Shared = ();
    type Local = ();

    fn init_shared(&self, _partitions: &[PartitionId]) {}

    fn process(
        &self,
        ctx: &mut Ctx,
        _shared: &(),
        _own: &mut (),
        _local: &mut (),
        events: &[Record],
    ) {
        for rec in events {
            (self.apply)(rec, ctx);
        }
    }
}

// ======================================================================
// MultiQuery — fan one stream into several pipelines, one engine job
// ======================================================================

/// Runs two processors over the same event stream inside one engine job,
/// sharing gossip, checkpoints and work stealing. Outputs are prefixed
/// with a branch tag byte (`0` = left, `1` = right); [`MultiQuery::demux`]
/// splits it back off.
///
/// Chain [`and`](MultiQuery::and) for wider fan-outs; each nesting level
/// prepends its own tag byte, so with `MultiQuery::new(a, b).and(c)` an
/// output of `a` starts with `[0, 0]`, `b` with `[0, 1]`, `c` with `[1]`.
#[derive(Clone)]
pub struct MultiQuery<A, B> {
    left: A,
    right: B,
}

impl<A: Processor, B: Processor> MultiQuery<A, B> {
    pub fn new(left: A, right: B) -> Self {
        Self { left, right }
    }

    /// Widen the fan-out with another pipeline.
    pub fn and<C2: Processor>(self, next: C2) -> MultiQuery<Self, C2> {
        MultiQuery::new(self, next)
    }
}

/// Split a [`MultiQuery`] output payload into `(branch tag, inner
/// payload)`. A free function so callers need not name the (usually
/// opaque `impl Processor`) branch types.
pub fn demux(payload: &[u8]) -> (u8, &[u8]) {
    let (tag, rest) = payload
        .split_first()
        .expect("MultiQuery output payload carries a tag byte");
    (*tag, rest)
}

impl<A: Processor, B: Processor> Processor for MultiQuery<A, B> {
    type Shared = (A::Shared, B::Shared);
    type Local = (A::Local, B::Local);

    fn init_shared(&self, partitions: &[PartitionId]) -> Self::Shared {
        (
            self.left.init_shared(partitions),
            self.right.init_shared(partitions),
        )
    }

    fn process(
        &self,
        ctx: &mut Ctx,
        shared: &Self::Shared,
        own: &mut Self::Shared,
        local: &mut Self::Local,
        events: &[Record],
    ) {
        // Branch outputs stream straight into the shared arena through
        // tagged sub-contexts — the tag byte is written in place at the
        // head of each frame, so fan-out costs zero extra copies.
        self.left
            .process(&mut ctx.tagged(0), &shared.0, &mut own.0, &mut local.0, events);
        self.right
            .process(&mut ctx.tagged(1), &shared.1, &mut own.1, &mut local.1, events);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::api::{ScalarAggregator, SharedState};
    use crate::arena::OutputArena;
    use crate::codec::{Decode, DecodeResult, Encode, Reader, Writer};
    use crate::crdt::GCounter;
    use crate::nexmark::Event;

    fn bid(offset: u64, ts: u64, auction: u64, price: f64) -> Record {
        Record {
            offset,
            event_ts: ts,
            insert_ts: ts,
            payload: Event::Bid {
                auction,
                bidder: 0,
                price,
                category: auction % 10,
            }
            .to_bytes()
            .into(),
        }
    }

    fn run<P: Processor>(
        q: &P,
        shared: &mut P::Shared,
        own: &mut P::Shared,
        local: &mut P::Local,
        events: &[Record],
    ) -> Vec<crate::api::Output> {
        let mut agg = ScalarAggregator;
        let mut arena = OutputArena::new();
        arena.begin_batch();
        let mut ctx = Ctx::new(0, 0, &mut agg, &mut arena);
        q.process(&mut ctx, shared, own, local, events);
        // lint:allow(discarded-merge): test-harness mirror of the engine drain — assertions run on the emitted outputs, not the join outcome
        let _ = shared.join(own);
        arena.take_outputs()
    }

    /// Run a processor twice (batch, then idle drain) and return the
    /// drain outputs — mirrors the engine's poll loop.
    fn run_and_drain<P: Processor>(q: &P, events: &[Record]) -> Vec<crate::api::Output> {
        let mut s = q.init_shared(&[0]);
        let mut o = q.init_shared(&[0]);
        let mut l = P::Local::default();
        let mut first = run(q, &mut s, &mut o, &mut l, events);
        let mut rest = run(q, &mut s, &mut o, &mut l, &[]);
        first.append(&mut rest);
        first
    }

    fn count_pipeline() -> WindowPipeline<Event, GCounter> {
        Dataflow::<Event>::source()
            .filter(|e| e.is_bid())
            .tumbling(1000)
            .aggregate(|p, _e, c: &mut GCounter| c.add(p as u64, 1))
            .emit_typed(|w, c| Some((w, c.value())))
    }

    #[test]
    fn dataflow_counts_bids_per_window() {
        let outs = run_and_drain(
            &count_pipeline(),
            &[bid(0, 100, 1, 1.0), bid(1, 200, 2, 1.0), bid(2, 1500, 3, 1.0)],
        );
        assert_eq!(outs.len(), 1);
        let (w, n) = <(u64, u64)>::from_bytes(&outs[0].payload).unwrap();
        assert_eq!((w, n), (0, 2));
    }

    /// A non-Nexmark event type: the decode stage is generic.
    #[derive(Debug, Clone, PartialEq)]
    struct Reading {
        sensor: u64,
        celsius: f64,
    }

    impl Encode for Reading {
        fn encode(&self, w: &mut Writer) {
            w.put_u64(self.sensor);
            w.put_f64(self.celsius);
        }
    }

    impl Decode for Reading {
        fn decode(r: &mut Reader) -> DecodeResult<Self> {
            Ok(Reading {
                sensor: r.get_u64()?,
                celsius: r.get_f64()?,
            })
        }
    }

    #[test]
    fn generic_event_type_plugs_in() {
        let q = Dataflow::<Reading>::source()
            .filter(|r| r.celsius > 30.0)
            .tumbling(1000)
            .key_by(|r| r.sensor)
            .aggregate(|p, _r, c: &mut GCounter| c.add(p as u64, 1))
            .emit_typed(|w, m| {
                let rows: Vec<(u64, u64)> = m.iter().map(|(&s, c)| (s, c.value())).collect();
                Some((w, rows))
            });
        let rec = |offset, ts, sensor, celsius| Record {
            offset,
            event_ts: ts,
            insert_ts: ts,
            payload: Reading { sensor, celsius }.to_bytes().into(),
        };
        let outs = run_and_drain(
            &q,
            &[
                rec(0, 100, 7, 35.0),
                rec(1, 200, 7, 10.0), // filtered: too cold
                rec(2, 300, 8, 31.0),
                rec(3, 1200, 9, 40.0), // closes window 0
            ],
        );
        assert_eq!(outs.len(), 1);
        let (w, rows) = <(u64, Vec<(u64, u64)>)>::from_bytes(&outs[0].payload).unwrap();
        assert_eq!(w, 0);
        assert_eq!(rows, vec![(7, 1), (8, 1)]);
    }

    #[test]
    fn flat_map_expands_events() {
        // each bid counts twice via flat_map
        let q = Dataflow::<Event>::source()
            .filter(|e| e.is_bid())
            .flat_map(|e| [e.clone(), e])
            .tumbling(1000)
            .aggregate(|p, _e, c: &mut GCounter| c.add(p as u64, 1))
            .emit_typed(|w, c| Some((w, c.value())));
        let outs = run_and_drain(&q, &[bid(0, 100, 1, 1.0), bid(1, 1500, 2, 1.0)]);
        assert_eq!(outs.len(), 1);
        let (_, n) = <(u64, u64)>::from_bytes(&outs[0].payload).unwrap();
        assert_eq!(n, 2, "one bid in window 0, doubled by flat_map");
    }

    #[test]
    fn map_reshapes_events() {
        let q = Dataflow::<Event>::source()
            .filter_map(|e| match e {
                Event::Bid { price, .. } => Some(price),
                _ => None,
            })
            .map(|price| (price * 100.0).round() as u64 * 2) // doubled cents
            .tumbling(1000)
            .aggregate(|_p, cents, c: &mut crate::crdt::MaxRegister<u64>| c.put(*cents))
            .emit_raw(|w, c, wr| {
                wr.put_u64(w);
                wr.put_u64(c.get().copied().unwrap_or(0));
                true
            });
        let outs = run_and_drain(&q, &[bid(0, 100, 1, 21.0), bid(1, 1500, 2, 1.0)]);
        assert_eq!(outs.len(), 1);
        let mut r = Reader::new(&outs[0].payload);
        r.get_u64().unwrap();
        assert_eq!(r.get_u64().unwrap(), 4200);
    }

    #[test]
    fn emit_each_is_stateless_passthrough() {
        let q = Dataflow::<Event>::source()
            .filter(|e| e.is_bid())
            .emit_each(|e| Some(e.clone()));
        let mut s = q.init_shared(&[0]);
        let mut o = q.init_shared(&[0]);
        let mut l = ();
        let events = vec![bid(0, 10, 1, 5.0), bid(1, 20, 2, 6.0)];
        let outs = run(&q, &mut s, &mut o, &mut l, &events);
        assert_eq!(outs.len(), 2);
        assert_eq!(outs[0].ref_ts, 10, "latency reference is insert time");
        assert_eq!(
            Event::from_bytes(&outs[0].payload).unwrap(),
            Event::from_bytes(&events[0].payload).unwrap()
        );
    }

    #[test]
    fn allowed_lateness_accepts_bounded_disorder() {
        let count_query = |lateness: Option<u64>| {
            let b = Dataflow::<Event>::source()
                .filter(|e| e.is_bid())
                .tumbling(1000);
            let b = match lateness {
                Some(ms) => b.allowed_lateness(ms),
                None => b,
            };
            b.aggregate(|p, _e, c: &mut GCounter| c.add(p as u64, 1))
                .emit_typed(|w, c| Some((w, c.value())))
        };
        // out-of-order stream: 100, 700, 400 (300 late), 2600
        let events = vec![
            bid(0, 100, 1, 1.0),
            bid(1, 700, 2, 1.0),
            bid(2, 400, 3, 1.0),
            bid(3, 2600, 4, 1.0),
        ];
        // with 500 ms allowed lateness, the 400-ts event counts
        let outs = run_and_drain(&count_query(Some(500)), &events);
        // watermark = 2600 - 500 = 2100 => windows 0 and 1 complete
        assert_eq!(outs.len(), 2);
        let (_, n) = <(u64, u64)>::from_bytes(&outs[0].payload).unwrap();
        assert_eq!(n, 3, "late-but-bounded event counted");

        // without lateness (ascending watermark), the 400-ts event is
        // dropped: the watermark already passed 700
        let outs = run_and_drain(&count_query(None), &events);
        assert_eq!(outs.len(), 2);
        let (_, n) = <(u64, u64)>::from_bytes(&outs[0].payload).unwrap();
        assert_eq!(n, 2, "event beyond the bound dropped");
    }

    #[test]
    fn lateness_drop_boundary_is_exact() {
        // With 500 ms allowed lateness and max event time 1600, the
        // watermark sits at 1100: an event at exactly 1100 is the last
        // one accepted, 1099 is dropped. The window-0 count distinguishes
        // every off-by-one.
        let q = |events: &[Record]| {
            let p = Dataflow::<Event>::source()
                .filter(|e| e.is_bid())
                .tumbling(1000)
                .allowed_lateness(500)
                .aggregate(|p, _e, c: &mut GCounter| c.add(p as u64, 1))
                .emit_typed(|w, c| Some((w, c.value())));
            run_and_drain(&p, events)
        };
        let on_boundary = q(&[
            bid(0, 600, 1, 1.0),
            bid(1, 1600, 2, 1.0), // watermark -> 1100
            bid(2, 1100, 3, 1.0), // exactly at the watermark: accepted
            bid(3, 2600, 4, 1.0), // watermark -> 2100, closes 0 and 1
        ]);
        let w1: Vec<(u64, u64)> = on_boundary
            .iter()
            .map(|o| <(u64, u64)>::from_bytes(&o.payload).unwrap())
            .collect();
        assert_eq!(w1, vec![(0, 1), (1, 2)], "boundary event must count");

        let past_boundary = q(&[
            bid(0, 600, 1, 1.0),
            bid(1, 1600, 2, 1.0),
            bid(2, 1099, 3, 1.0), // one ms past the bound: dropped
            bid(3, 2600, 4, 1.0),
        ]);
        let w2: Vec<(u64, u64)> = past_boundary
            .iter()
            .map(|o| <(u64, u64)>::from_bytes(&o.payload).unwrap())
            .collect();
        assert_eq!(w2, vec![(0, 1), (1, 1)], "past-bound event must drop");
    }

    #[test]
    fn sliding_window_folds_into_covering_windows() {
        let q = Dataflow::<Event>::source()
            .filter(|e| e.is_bid())
            .sliding(2000, 1000)
            .aggregate(|p, _e, c: &mut GCounter| c.add(p as u64, 1))
            .emit_typed(|w, c| Some((w, c.value())));
        // ts=1500 is covered by windows 0 ([0,2000)) and 1 ([1000,3000))
        let outs = run_and_drain(&q, &[bid(0, 1500, 1, 1.0), bid(1, 3500, 2, 1.0)]);
        assert_eq!(outs.len(), 2);
        let (w0, n0) = <(u64, u64)>::from_bytes(&outs[0].payload).unwrap();
        let (w1, n1) = <(u64, u64)>::from_bytes(&outs[1].payload).unwrap();
        assert_eq!((w0, n0), (0, 1), "window 0 sees the ts=1500 bid");
        assert_eq!((w1, n1), (1, 1), "window 1 sees it too");
    }

    #[test]
    fn keyed_sliding_counts_per_key() {
        let q = Dataflow::<Event>::source()
            .filter(|e| e.is_bid())
            .sliding(2000, 1000)
            .key_by(|e| match e {
                Event::Bid { auction, .. } => *auction,
                _ => 0,
            })
            .aggregate(|p, _e, c: &mut GCounter| c.add(p as u64, 1))
            .emit_typed(|w, m| {
                let rows: Vec<(u64, u64)> = m.iter().map(|(&a, c)| (a, c.value())).collect();
                Some((w, rows))
            });
        let outs = run_and_drain(
            &q,
            &[
                bid(0, 500, 7, 1.0),
                bid(1, 1500, 7, 1.0),  // windows 0 and 1
                bid(2, 1600, 9, 1.0),  // windows 0 and 1
                bid(3, 3500, 11, 1.0), // closes windows 0 and 1
            ],
        );
        assert_eq!(outs.len(), 2);
        let (w, rows) = <(u64, Vec<(u64, u64)>)>::from_bytes(&outs[0].payload).unwrap();
        assert_eq!(w, 0);
        assert_eq!(rows, vec![(7, 2), (9, 1)]);
        let (w, rows) = <(u64, Vec<(u64, u64)>)>::from_bytes(&outs[1].payload).unwrap();
        assert_eq!(w, 1);
        assert_eq!(rows, vec![(7, 1), (9, 1)]);
    }

    #[test]
    fn keyed_sharded_emits_byte_identical_to_keyed() {
        // the sharded keyed stage must not change one output byte — for
        // any shard count, including the degenerate single shard
        let keyed = Dataflow::<Event>::source()
            .filter(|e| e.is_bid())
            .tumbling(1000)
            .key_by(|e| match e {
                Event::Bid { auction, .. } => *auction,
                _ => 0,
            })
            .aggregate(|p, _e, c: &mut GCounter| c.add(p as u64, 1))
            .emit_typed(|w, m| {
                let rows: Vec<(u64, u64)> = m.iter().map(|(&a, c)| (a, c.value())).collect();
                Some((w, rows))
            });
        let events: Vec<Record> = (0..64u64)
            .map(|i| bid(i, i * 40, i % 7, 1.0))
            .collect();
        let expect = run_and_drain(&keyed, &events);
        assert!(!expect.is_empty());
        for shards in [1u32, 4, 16] {
            let sharded = Dataflow::<Event>::source()
                .filter(|e| e.is_bid())
                .tumbling(1000)
                .key_by_sharded(shards, |e| match e {
                    Event::Bid { auction, .. } => *auction,
                    _ => 0,
                })
                .aggregate(|p, _e, c: &mut GCounter| c.add(p as u64, 1))
                .emit_typed(|w, m| {
                    let rows: Vec<(u64, u64)> = m.iter().map(|(&a, c)| (a, c.value())).collect();
                    Some((w, rows))
                });
            let got = run_and_drain(&sharded, &events);
            assert_eq!(got.len(), expect.len(), "{shards} shards: output count");
            for (i, (a, b)) in expect.iter().zip(&got).enumerate() {
                assert_eq!(a.payload, b.payload, "{shards} shards: output {i}");
                assert_eq!(a.ref_ts, b.ref_ts);
            }
        }
    }

    #[test]
    fn multiquery_fans_one_stream_into_two_pipelines() {
        let counts = count_pipeline();
        let passthrough = Dataflow::<Event>::source()
            .filter(|e| e.is_bid())
            .emit_each(|e| Some(e.clone()));
        let q = MultiQuery::new(counts, passthrough);

        let mut s = q.init_shared(&[0]);
        let mut o = q.init_shared(&[0]);
        let mut l = <MultiQuery<WindowPipeline<Event, GCounter>, Passthrough> as Processor>::Local::default();
        let events = vec![bid(0, 100, 1, 1.0), bid(1, 1500, 2, 1.0)];
        let mut outs = run(&q, &mut s, &mut o, &mut l, &events);
        outs.extend(run(&q, &mut s, &mut o, &mut l, &[]));

        let mut window_outs = 0;
        let mut event_outs = 0;
        for out in &outs {
            match demux(&out.payload) {
                (0, inner) => {
                    let (w, n) = <(u64, u64)>::from_bytes(inner).unwrap();
                    assert_eq!((w, n), (0, 1));
                    window_outs += 1;
                }
                (1, inner) => {
                    assert!(Event::from_bytes(inner).unwrap().is_bid());
                    event_outs += 1;
                }
                (tag, _) => panic!("unexpected branch tag {tag}"),
            }
        }
        assert_eq!(window_outs, 1, "one completed window from the left branch");
        assert_eq!(event_outs, 2, "both bids passed through the right branch");
    }

    #[test]
    fn multiquery_local_state_roundtrips_through_codec() {
        // MultiQuery locals are tuples; the checkpoint path encodes them.
        let l: (EmitCursor, ()) = (EmitCursor { next: 5 }, ());
        let b = l.to_bytes();
        let back = <(EmitCursor, ())>::from_bytes(&b).unwrap();
        assert_eq!(back.0.next, 5);
    }
}
