//! The Holon Streaming programming model (paper §3, Table 1).
//!
//! Two API levels share one execution model:
//!
//! * the **procedural API** — a query is a [`Processor`]: one
//!   *processing function* over a partition's events, combining three
//!   kinds of state:
//!   * `Shared` — replicated [`WindowedCrdt`]s (or tuples of them),
//!     synchronized in the background by gossip; reads of completed
//!     windows are globally deterministic;
//!   * `Local` — partition-local state ([`Local`]/[`WLocal`],
//!     [`EmitCursor`] and friends), checkpointed and recovered with the
//!     partition;
//!   * the event batch itself.
//! * the **dataflow API v2** ([`dataflow`], paper §3.1) — a declarative
//!   [`Dataflow`] pipeline over any decodable event type: decode →
//!   `filter`/`map`/`flat_map` → window → (`key_by` →) CRDT aggregate →
//!   typed emit, plus a [`MultiQuery`] composer fanning one stream into
//!   several pipelines inside a single engine job. Every pipeline
//!   compiles down to a [`Processor`] using the safe cursor-drain
//!   emission, so dataflow programs are always deterministic (§3.3).
//!
//! The engine guarantees exactly-once effects per partition: events are
//! consumed in deterministic order, state reflects each event once, and
//! outputs (which may be physically duplicated) carry `(partition, seq)`
//! tags for consumer-side deduplication (§3.3).

use crate::arena::OutputArena;
use crate::codec::{Decode, DecodeResult, Encode, Reader, Writer};
use crate::crdt::Crdt;
use crate::log::Record;
use crate::util::{PartitionId, SimTime};
use crate::wcrdt::{WindowId, WindowedCrdt};

pub mod dataflow;
pub mod shared;
pub use dataflow::{
    demux, Dataflow, DfCursor, Keyed, KeyedSharded, MultiQuery, Passthrough, WindowAgg,
    WindowPipeline, Windowed,
};
pub use shared::SharedState;

/// Emission cursor: the next window a partition has yet to emit — the
/// partition-local half of the Listing-2 safe emission idiom. One
/// canonical definition shared by the dataflow pipelines (as
/// [`dataflow::DfCursor`]) and the hand-written Nexmark processors (as
/// [`crate::nexmark::queries::Cursor`]).
#[derive(Debug, Clone, Default, PartialEq)]
pub struct EmitCursor {
    pub next: WindowId,
}

impl Encode for EmitCursor {
    fn encode(&self, w: &mut Writer) {
        w.put_u64(self.next);
    }
}

impl Decode for EmitCursor {
    fn decode(r: &mut Reader) -> DecodeResult<Self> {
        Ok(EmitCursor { next: r.get_u64()? })
    }
}

/// One output produced by a processing function — the *owned*,
/// test/oracle-facing view. The engine never materializes these on the
/// hot path: outputs live as frames inside the batch's [`OutputArena`]
/// and ship to the log as `(offset, len)` views over one shared
/// backing. Tests get owned `Output`s via
/// [`OutputArena::take_outputs`].
#[derive(Debug, Clone, PartialEq)]
pub struct Output {
    /// Latency reference: the sim-time this output *became due* (the
    /// window end for windowed outputs, the input insertion time for
    /// passthrough outputs). End-to-end latency = emit time − ref_ts.
    pub ref_ts: SimTime,
    /// Encoded output payload.
    pub payload: Vec<u8>,
}

impl Output {
    pub fn new(ref_ts: SimTime, payload: Vec<u8>) -> Self {
        Self { ref_ts, payload }
    }
}

/// Branch-tag prefix of a [`Ctx`]: one byte per [`MultiQuery`] nesting
/// level, outermost first — written in place at the head of every
/// emitted frame, replacing the old per-record tag-copy allocation.
/// Inline and `Copy`; 8 levels is far beyond any real fan-out.
#[derive(Debug, Clone, Copy, Default)]
struct TagStack {
    buf: [u8; 8],
    len: u8,
}

impl TagStack {
    fn push(mut self, tag: u8) -> Self {
        self.buf[self.len as usize] = tag;
        self.len += 1;
        self
    }

    fn as_slice(&self) -> &[u8] {
        &self.buf[..self.len as usize]
    }
}

/// Per-batch execution context handed to the processing function.
///
/// Emission writes *directly into the batch's output arena* through the
/// ordinary [`Writer`] surface — no per-record `Vec<u8>`.
pub struct Ctx<'a> {
    /// The partition this invocation processes (the contributor id for
    /// all CRDT inserts).
    pub partition: PartitionId,
    /// Current sim-time.
    pub now: SimTime,
    /// Batch aggregation service (XLA-backed when artifacts are loaded,
    /// pure Rust otherwise). See [`crate::runtime`].
    pub aggregator: &'a mut dyn BatchAggregator,
    arena: &'a mut OutputArena,
    tags: TagStack,
}

impl<'a> Ctx<'a> {
    pub fn new(
        partition: PartitionId,
        now: SimTime,
        aggregator: &'a mut dyn BatchAggregator,
        arena: &'a mut OutputArena,
    ) -> Self {
        Self {
            partition,
            now,
            aggregator,
            arena,
            tags: TagStack::default(),
        }
    }

    /// Emit one output record, writing its payload in place via `f` —
    /// the zero-alloc path.
    pub fn emit_with(&mut self, ref_ts: SimTime, f: impl FnOnce(&mut Writer)) {
        self.try_emit_with(ref_ts, |w| {
            f(w);
            true
        });
    }

    /// As [`emit_with`](Self::emit_with), but the closure may withdraw
    /// the record by returning `false` — the frame (tag prefix included)
    /// is rolled back without a trace. Returns whether it was emitted.
    // lint: zero-alloc
    pub fn try_emit_with(&mut self, ref_ts: SimTime, f: impl FnOnce(&mut Writer) -> bool) -> bool {
        let tags = self.tags;
        self.arena.frame(ref_ts, |w| {
            w.put_raw(tags.as_slice());
            f(w)
        })
    }

    /// Emit an already-encoded payload (one copy into the arena, no
    /// allocation).
    pub fn emit_bytes(&mut self, ref_ts: SimTime, payload: &[u8]) {
        self.emit_with(ref_ts, |w| w.put_raw(payload));
    }

    /// Emit an owned payload — compatibility shim over
    /// [`emit_bytes`](Self::emit_bytes); prefer the in-place variants
    /// on hot paths.
    pub fn emit(&mut self, ref_ts: SimTime, payload: Vec<u8>) {
        self.emit_bytes(ref_ts, &payload);
    }

    /// A sub-context whose emissions are prefixed with `tag` (appended
    /// to any tags this context already carries) — how [`MultiQuery`]
    /// demultiplexes several pipelines onto one output stream without a
    /// per-record re-copy.
    pub fn tagged(&mut self, tag: u8) -> Ctx<'_> {
        Ctx {
            partition: self.partition,
            now: self.now,
            aggregator: &mut *self.aggregator,
            arena: &mut *self.arena,
            tags: self.tags.push(tag),
        }
    }

    /// Number of frames emitted into the batch so far (cross-pipeline
    /// total, including any tagged sub-contexts).
    pub fn emitted(&self) -> usize {
        self.arena.len()
    }
}

/// Per-window partial aggregates of one event batch — what the L1/L2
/// kernel computes in one fused invocation.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct WindowAggregates {
    /// (window id, sum, count, max) for every window with ≥1 event.
    pub windows: Vec<(WindowId, f64, u64, f64)>,
}

/// Batched windowed aggregation: fold `(value, window)` pairs into
/// per-window (sum, count, max). Implemented by the pure-Rust fallback
/// and by the AOT XLA executable ([`crate::runtime`]).
pub trait BatchAggregator {
    fn aggregate(&mut self, items: &[(f64, WindowId)]) -> WindowAggregates;
}

/// Reference scalar implementation (also the test oracle for the XLA
/// path — mirrored by python/compile/kernels/ref.py on the L1 side).
#[derive(Debug, Default, Clone)]
pub struct ScalarAggregator;

impl BatchAggregator for ScalarAggregator {
    fn aggregate(&mut self, items: &[(f64, WindowId)]) -> WindowAggregates {
        // Hash-map group-by: one O(1) probe per item instead of a linear
        // scan over the windows seen so far (keyed queries like Q4 put
        // hundreds of (window × key) segments in one batch). Values fold
        // in item order per window, so float sums match the old scan.
        // Classified non-wire (audited for holon-lint D1): the map is
        // consumed only by the `collect` + `sort_unstable_by_key` below,
        // so its iteration order never escapes this function — the
        // emitted `windows` vec is strictly window-ordered.
        #[allow(clippy::disallowed_types)]
        let mut acc =
            // lint:allow(hash-on-wire): iteration order is quotiented out by the sort below — nothing order-dependent leaves this function
            std::collections::HashMap::<WindowId, (f64, u64, f64)>::with_capacity(
                items.len().min(1024),
            );
        for &(v, w) in items {
            let e = acc.entry(w).or_insert((0.0, 0, f64::NEG_INFINITY));
            e.0 += v;
            e.1 += 1;
            if v > e.2 {
                e.2 = v;
            }
        }
        let mut out: Vec<(WindowId, f64, u64, f64)> = acc
            .into_iter()
            .map(|(w, (sum, count, max))| (w, sum, count, max))
            .collect();
        out.sort_unstable_by_key(|&(w, ..)| w);
        WindowAggregates { windows: out }
    }
}

/// A Holon query: the single processing function plus its state types.
///
/// `Clone` because every node materializes the processor; processors
/// must be cheap, immutable descriptors (all mutable state lives in
/// `Shared`/`Local`).
pub trait Processor: Clone + Send + Sync + 'static {
    /// Replicated shared state (WCRDTs).
    type Shared: SharedState;
    /// Partition-local state.
    type Local: Clone + Default + Send + Encode + Decode + 'static;

    /// Build the initial shared-state replica for a node. `partitions`
    /// is the full partition set (WCRDT watermark participants).
    fn init_shared(&self, partitions: &[PartitionId]) -> Self::Shared;

    /// Process a batch of events for one partition.
    ///
    /// * `shared` — the node's gossip-merged replica: **read-only** for
    ///   window values / global watermarks (deterministic reads).
    /// * `own` — the partition's *own contribution* accumulator (same
    ///   type, restored verbatim from the checkpoint): **all inserts and
    ///   watermark increments go here.** The engine joins `own` into
    ///   `shared` after every batch. This split is what makes replays
    ///   after work stealing idempotent: a replay recomputes the same
    ///   deterministic contribution values in `own` and joining them
    ///   again is a no-op — contributions are never added on top of a
    ///   gossip-merged state.
    /// * `local` — plain partition-local state (cursors, WLocals).
    ///
    /// Called with an empty batch at idle so window emission keeps
    /// progressing as gossip completes windows.
    ///
    /// Contract: an empty batch must leave `own` untouched (reads and
    /// emission only). The engine drains `own` into the node replica
    /// only after batches that consumed events; state written to `own`
    /// during an empty invocation would sit undrained — and therefore
    /// invisible to gossip — until the partition next consumes input
    /// (debug builds assert this). Every in-repo processor guards its
    /// inserts and watermark bumps on a non-empty batch.
    fn process(
        &self,
        ctx: &mut Ctx,
        shared: &Self::Shared,
        own: &mut Self::Shared,
        local: &mut Self::Local,
        events: &[Record],
    );
}

/// Convenience: iterate the completed-but-unemitted windows of a WCRDT,
/// in order, bumping the cursor — the Listing-2 emission idiom (safe use
/// of the unsafe-mode read: acyclic data dependencies, windows processed
/// in sequence, so the nondeterministic completion *timing* never
/// affects the emitted values).
pub fn drain_completed<C: Crdt>(
    wcrdt: &WindowedCrdt<C>,
    cursor: &mut WindowId,
    mut f: impl FnMut(WindowId, C),
) {
    while let Some(v) = wcrdt.window_value(*cursor) {
        f(*cursor, v);
        *cursor += 1;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::crdt::GCounter;
    use crate::wcrdt::WindowAssigner;

    #[test]
    fn scalar_aggregator_groups_by_window() {
        let mut agg = ScalarAggregator;
        let out = agg.aggregate(&[(1.0, 0), (2.0, 1), (3.0, 0), (5.0, 1)]);
        assert_eq!(
            out.windows,
            vec![(0, 4.0, 2, 3.0), (1, 7.0, 2, 5.0)]
        );
    }

    #[test]
    fn scalar_aggregator_empty() {
        let mut agg = ScalarAggregator;
        assert!(agg.aggregate(&[]).windows.is_empty());
    }

    #[test]
    fn ctx_emits_into_arena() {
        let mut agg = ScalarAggregator;
        let mut arena = OutputArena::new();
        arena.begin_batch();
        let mut ctx = Ctx::new(3, 100, &mut agg, &mut arena);
        ctx.emit(50, vec![1]);
        ctx.emit_with(60, |w| w.put_u8(2));
        ctx.emit_bytes(70, &[3, 4]);
        assert_eq!(ctx.emitted(), 3);
        let outs = arena.take_outputs();
        assert_eq!(outs.len(), 3);
        assert_eq!(outs[0], Output::new(50, vec![1]));
        assert_eq!(outs[1], Output::new(60, vec![2]));
        assert_eq!(outs[2], Output::new(70, vec![3, 4]));
    }

    #[test]
    fn tagged_sub_ctx_prefixes_payloads() {
        let mut agg = ScalarAggregator;
        let mut arena = OutputArena::new();
        arena.begin_batch();
        let mut ctx = Ctx::new(0, 0, &mut agg, &mut arena);
        ctx.tagged(7).emit_with(10, |w| w.put_u8(42));
        ctx.emit_with(20, |w| w.put_u8(43));
        // nested MultiQuery shape: one tag byte per level, outermost first
        ctx.tagged(0).tagged(1).emit_with(30, |w| w.put_u8(44));
        // a withdrawn frame rolls back its tag prefix too
        assert!(!ctx.tagged(9).try_emit_with(40, |_| false));
        let outs = arena.take_outputs();
        assert_eq!(outs.len(), 3);
        assert_eq!(outs[0].payload, vec![7, 42]);
        assert_eq!(outs[1].payload, vec![43]);
        assert_eq!(outs[2].payload, vec![0, 1, 44]);
    }

    #[test]
    fn drain_completed_walks_in_order() {
        let mut w: WindowedCrdt<GCounter> =
            WindowedCrdt::new(WindowAssigner::tumbling(100), [0, 1]);
        w.insert_with(0, 10, |c| c.add(0, 1)).unwrap();
        w.insert_with(0, 110, |c| c.add(0, 2)).unwrap();
        w.increment_watermark(0, 250);
        w.increment_watermark(1, 250);
        let mut cursor = 0;
        let mut seen = vec![];
        drain_completed(&w, &mut cursor, |wid, c| seen.push((wid, c.value())));
        assert_eq!(seen, vec![(0, 1), (1, 2)]);
        assert_eq!(cursor, 2);
        // nothing more until the watermark advances
        drain_completed(&w, &mut cursor, |_, _| panic!("no new windows"));
    }
}
