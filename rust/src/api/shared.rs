//! `SharedState` — the node-level replica of a query's replicated state.
//!
//! A query's shared state is one or more [`WindowedCrdt`]s. The engine
//! only needs four operations on it: join with a gossiped replica,
//! project a partition's checkpoint slice, compact emitted windows, and
//! serialize. Implementations are provided for a single `WindowedCrdt`,
//! for tuples (multi-WCRDT queries like the paper's Query 1 use a pair),
//! and for `()` (stateless queries like Nexmark Q0).

use crate::codec::{Decode, Encode};
use crate::crdt::{Crdt, MergeOutcome};
use crate::util::PartitionId;
use crate::wcrdt::{WindowId, WindowedCrdt};

/// Node-level replicated state: a join-semilattice that also supports
/// per-partition projection and window compaction.
pub trait SharedState: Clone + Send + Encode + Decode + 'static {
    /// Join with another replica (gossip receive / recovery),
    /// reporting whether this replica inflated. The engine's receive
    /// path counts no-op joins (`ClusterMetrics::merge_noop`) and their
    /// payload bytes (`redundant_gossip_bytes`), and relies on the
    /// drilled-down dirty-marking: a full-sync payload the replica
    /// already subsumes marks nothing dirty, so the delta round after
    /// an anti-entropy round ships only genuine divergence.
    fn join(&mut self, other: &Self) -> MergeOutcome;

    /// The slice of this state contributed by `partition` (plus its
    /// progress entries) — what goes into the partition checkpoint.
    fn project(&self, partition: PartitionId) -> Self;

    /// Drop state for windows strictly below `wid` on every WCRDT.
    fn compact_below(&mut self, wid: WindowId);

    /// Approximate number of live windows (observability / memory tests).
    fn live_windows(&self) -> usize;

    /// Minimum global watermark across the contained WCRDTs (`u64::MAX`
    /// when there are none) — drives compaction.
    fn watermark_floor(&self) -> crate::util::SimTime;

    /// Delta-based sync (paper §7): a partial state carrying everything
    /// changed since the previous call. Default = full state (always
    /// correct, more traffic).
    fn take_delta(&mut self) -> Self {
        self.clone()
    }

    /// Windows touched since the last [`take_delta`](Self::take_delta) /
    /// [`mark_clean`](Self::mark_clean) across the contained WCRDTs —
    /// the engine skips re-encoding a partition checkpoint when its
    /// contribution accumulator reports 0 here.
    fn dirty_windows(&self) -> usize;

    /// Drop the dirty markers without building a delta (the observer has
    /// seen the full state — a full-sync gossip round or a checkpoint
    /// encode). Bounds dirty-set growth on replicas that never call
    /// `take_delta`.
    fn mark_clean(&mut self);

    /// Whether a delta round would ship anything: some window is dirty
    /// or some watermark moved since the last drain. When false (and
    /// the round is not a full sync) the engine skips the gossip
    /// encode and broadcast entirely. Defaults to the dirty-window
    /// count, which is correct for states without progress tracking.
    fn has_delta(&self) -> bool {
        self.dirty_windows() > 0
    }

    /// Drain this state's delta into `dst` by reference — semantically
    /// `dst.join(&self.take_delta())` without materializing the delta —
    /// reporting whether `dst` inflated. The engine's per-batch
    /// own→replica join runs through this (the hot path must not clone
    /// per batch); the default is only for exotic implementations.
    fn join_delta_into(&mut self, dst: &mut Self) -> MergeOutcome {
        dst.join(&self.take_delta())
    }
}

impl SharedState for () {
    fn join(&mut self, _other: &Self) -> MergeOutcome {
        MergeOutcome::Unchanged
    }

    fn project(&self, _partition: PartitionId) -> Self {}

    fn compact_below(&mut self, _wid: WindowId) {}

    fn live_windows(&self) -> usize {
        0
    }

    fn watermark_floor(&self) -> crate::util::SimTime {
        crate::util::SimTime::MAX
    }

    fn dirty_windows(&self) -> usize {
        0
    }

    fn mark_clean(&mut self) {}

    fn has_delta(&self) -> bool {
        false
    }

    fn join_delta_into(&mut self, _dst: &mut Self) -> MergeOutcome {
        MergeOutcome::Unchanged
    }
}

impl<C: Crdt> SharedState for WindowedCrdt<C> {
    fn join(&mut self, other: &Self) -> MergeOutcome {
        self.merge(other).outcome()
    }

    fn project(&self, partition: PartitionId) -> Self {
        self.project_with(partition, |c| c.project(partition as u64))
    }

    fn compact_below(&mut self, wid: WindowId) {
        WindowedCrdt::compact_below(self, wid);
    }

    fn live_windows(&self) -> usize {
        WindowedCrdt::live_windows(self)
    }

    fn watermark_floor(&self) -> crate::util::SimTime {
        self.global_watermark()
    }

    fn take_delta(&mut self) -> Self {
        WindowedCrdt::take_delta(self)
    }

    fn dirty_windows(&self) -> usize {
        WindowedCrdt::dirty_windows(self)
    }

    fn mark_clean(&mut self) {
        WindowedCrdt::mark_clean(self);
    }

    fn has_delta(&self) -> bool {
        WindowedCrdt::has_delta(self)
    }

    fn join_delta_into(&mut self, dst: &mut Self) -> MergeOutcome {
        WindowedCrdt::join_delta_into(self, dst)
    }
}

impl<A: SharedState, B: SharedState> SharedState for (A, B) {
    fn join(&mut self, other: &Self) -> MergeOutcome {
        self.0.join(&other.0) | self.1.join(&other.1)
    }

    fn project(&self, partition: PartitionId) -> Self {
        (self.0.project(partition), self.1.project(partition))
    }

    fn compact_below(&mut self, wid: WindowId) {
        self.0.compact_below(wid);
        self.1.compact_below(wid);
    }

    fn live_windows(&self) -> usize {
        self.0.live_windows() + self.1.live_windows()
    }

    fn watermark_floor(&self) -> crate::util::SimTime {
        self.0.watermark_floor().min(self.1.watermark_floor())
    }

    fn take_delta(&mut self) -> Self {
        (self.0.take_delta(), self.1.take_delta())
    }

    fn dirty_windows(&self) -> usize {
        self.0.dirty_windows() + self.1.dirty_windows()
    }

    fn mark_clean(&mut self) {
        self.0.mark_clean();
        self.1.mark_clean();
    }

    fn has_delta(&self) -> bool {
        self.0.has_delta() || self.1.has_delta()
    }

    fn join_delta_into(&mut self, dst: &mut Self) -> MergeOutcome {
        self.0.join_delta_into(&mut dst.0) | self.1.join_delta_into(&mut dst.1)
    }
}

impl<A: SharedState, B: SharedState, C: SharedState> SharedState for (A, B, C) {
    fn join(&mut self, other: &Self) -> MergeOutcome {
        self.0.join(&other.0) | self.1.join(&other.1) | self.2.join(&other.2)
    }

    fn project(&self, partition: PartitionId) -> Self {
        (
            self.0.project(partition),
            self.1.project(partition),
            self.2.project(partition),
        )
    }

    fn compact_below(&mut self, wid: WindowId) {
        self.0.compact_below(wid);
        self.1.compact_below(wid);
        self.2.compact_below(wid);
    }

    fn live_windows(&self) -> usize {
        self.0.live_windows() + self.1.live_windows() + self.2.live_windows()
    }

    fn watermark_floor(&self) -> crate::util::SimTime {
        self.0
            .watermark_floor()
            .min(self.1.watermark_floor())
            .min(self.2.watermark_floor())
    }

    fn take_delta(&mut self) -> Self {
        (
            self.0.take_delta(),
            self.1.take_delta(),
            self.2.take_delta(),
        )
    }

    fn dirty_windows(&self) -> usize {
        self.0.dirty_windows() + self.1.dirty_windows() + self.2.dirty_windows()
    }

    fn mark_clean(&mut self) {
        self.0.mark_clean();
        self.1.mark_clean();
        self.2.mark_clean();
    }

    fn has_delta(&self) -> bool {
        self.0.has_delta() || self.1.has_delta() || self.2.has_delta()
    }

    fn join_delta_into(&mut self, dst: &mut Self) -> MergeOutcome {
        self.0.join_delta_into(&mut dst.0)
            | self.1.join_delta_into(&mut dst.1)
            | self.2.join_delta_into(&mut dst.2)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::crdt::{GCounter, PrefixAgg};
    use crate::wcrdt::WindowAssigner;

    fn counter(parts: &[PartitionId]) -> WindowedCrdt<GCounter> {
        WindowedCrdt::new(WindowAssigner::tumbling(100), parts.iter().copied())
    }

    #[test]
    fn unit_shared_state_is_inert() {
        let mut s = ();
        assert_eq!(s.join(&()), MergeOutcome::Unchanged);
        assert_eq!(s.project(0), ());
        assert_eq!(s.live_windows(), 0);
        assert!(!s.has_delta());
    }

    #[test]
    fn wcrdt_projection_keeps_own_contribution() {
        let mut s = counter(&[0, 1]);
        s.insert_with(0, 10, |c| c.add(0, 3)).unwrap();
        s.insert_with(1, 10, |c| c.add(1, 5)).unwrap();
        s.increment_watermark(0, 50);
        s.increment_watermark(1, 70);
        let p = SharedState::project(&s, 0);
        assert_eq!(p.raw_window(0).unwrap().value(), 3);
        assert_eq!(p.progress_of(0), 50);
        assert_eq!(p.progress_of(1), 0);
    }

    #[test]
    fn projection_then_join_restores_contribution() {
        // The checkpoint/recovery identity: joining a projection back
        // into an empty replica reproduces the partition's contribution.
        let mut s = counter(&[0, 1]);
        s.insert_with(0, 10, |c| c.add(0, 3)).unwrap();
        s.increment_watermark(0, 50);
        let slice = SharedState::project(&s, 0);
        let mut fresh = counter(&[0, 1]);
        assert_eq!(fresh.join(&slice), MergeOutcome::Changed);
        assert_eq!(fresh.raw_window(0).unwrap().value(), 3);
        assert_eq!(fresh.progress_of(0), 50);
        // joining it again is a no-op (recovery after gossip caught up)
        assert_eq!(fresh.join(&slice), MergeOutcome::Unchanged);
    }

    #[test]
    fn dirty_tracking_composes_through_tuples() {
        let mut s = (counter(&[0]), counter(&[0]));
        assert_eq!(s.dirty_windows(), 0);
        s.0.insert_with(0, 10, |c| c.add(0, 1)).unwrap();
        s.1.insert_with(0, 10, |c| c.add(0, 2)).unwrap();
        s.1.insert_with(0, 1010, |c| c.add(0, 3)).unwrap();
        assert_eq!(SharedState::dirty_windows(&s), 3);
        SharedState::mark_clean(&mut s);
        assert_eq!(SharedState::dirty_windows(&s), 0);
    }

    #[test]
    fn tuple_shared_state_composes() {
        let mut a = (counter(&[0]), {
            let mut w: WindowedCrdt<PrefixAgg> =
                WindowedCrdt::new(WindowAssigner::tumbling(100), [0]);
            w.insert_with(0, 5, |c| c.observe(0, 2.0)).unwrap();
            w
        });
        let b = a.clone();
        assert_eq!(a.join(&b), MergeOutcome::Unchanged); // idempotent
        assert_eq!(a, b);
        assert_eq!(a.live_windows(), 1);
        a.compact_below(10);
        assert_eq!(a.live_windows(), 0);
    }

    #[test]
    fn has_delta_composes_through_tuples() {
        let mut s = (counter(&[0]), counter(&[0]));
        SharedState::mark_clean(&mut s);
        assert!(!SharedState::has_delta(&s));
        // watermark movement alone arms the delta (no dirty window)
        s.1.increment_watermark(0, 700);
        assert_eq!(SharedState::dirty_windows(&s), 0);
        assert!(SharedState::has_delta(&s));
        // lint:allow(discarded-merge): draining purely to disarm the delta flag — the payload is asserted elsewhere, this test watches `has_delta`
        let _ = SharedState::take_delta(&mut s);
        assert!(!SharedState::has_delta(&s));
        // a dirty window arms it too
        s.0.insert_with(0, 750, |c| c.add(0, 1)).unwrap();
        assert!(SharedState::has_delta(&s));
        SharedState::mark_clean(&mut s);
        assert!(!SharedState::has_delta(&s));
    }
}
