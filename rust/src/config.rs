//! System configuration — cluster shape, intervals, time scale.
//!
//! All intervals are in *sim-milliseconds* (paper-time); defaults follow
//! the paper's §5.1 experimental setup. Parsed from a simple
//! `key = value` file (`# comments` allowed) plus `--key=value` CLI
//! overrides — there is no serde/clap in the vendored crate set.

use std::collections::BTreeMap;
use std::path::Path;

/// Sentinel value of [`HolonConfig::gossip_fanout`]: resolve the
/// fan-out from the cluster size as ⌈log₂ nodes⌉ (parsed and dumped as
/// `auto` in config files).
pub const AUTO_GOSSIP_FANOUT: u32 = u32::MAX;

/// Full configuration for a Holon (and baseline) deployment.
#[derive(Debug, Clone, PartialEq)]
pub struct HolonConfig {
    // -- cluster shape ---------------------------------------------------
    /// Number of Holon execution nodes.
    pub nodes: u32,
    /// Number of logical stream partitions.
    pub partitions: u32,
    /// Events per second per partition produced by the workload.
    pub events_per_sec_per_partition: u64,
    /// RNG seed for workload + jitter.
    pub seed: u64,

    // -- time ------------------------------------------------------------
    /// Wall-milliseconds per sim-second (scale knob; 1000 = real time).
    pub wall_ms_per_sim_sec: f64,
    /// Total experiment duration in sim-ms.
    pub duration_ms: u64,

    // -- Holon engine ----------------------------------------------------
    /// Tumbling window size (sim-ms). Paper uses 1 s windows for Q7.
    pub window_ms: u64,
    /// Max events pulled per run-loop batch (Algorithm 2 RUN_BATCH).
    pub batch_size: usize,
    /// Gossip (WCRDT sync) interval per node, sim-ms.
    pub gossip_interval_ms: u64,
    /// Gossip fan-out: peers sampled per gossip round. `0` = broadcast
    /// to all (O(n²) traffic per round); the default is the `auto`
    /// sentinel ([`AUTO_GOSSIP_FANOUT`]), resolved per deployment to
    /// ⌈log₂ nodes⌉ by [`HolonConfig::effective_gossip_fanout`].
    ///
    /// Tradeoff (measured by the `bench-smoke` gossip-byte counters;
    /// see EXPERIMENTS.md §Gossip fan-out): full broadcast converges in
    /// one round but its per-round wire volume grows quadratically with
    /// the cluster, which is what capped fig9 scalability runs; a
    /// ⌈log₂ n⌉ sample keeps per-round traffic at O(n·log n) while
    /// transitive state-based gossip still converges in O(log n)
    /// rounds — a few gossip intervals of extra propagation latency
    /// (bounded staleness, never divergence) for an order-of-magnitude
    /// wire-volume cut at 100 nodes. Delta-mode full-sync rounds ignore
    /// the fan-out and always broadcast to all (anti-entropy must reach
    /// every peer before dirty markers drop).
    pub gossip_fanout: u32,
    /// Delta-based WCRDT synchronization (paper §7): gossip only the
    /// windows touched since the last round, with a periodic full-state
    /// anti-entropy round. Cuts steady-state gossip volume sharply.
    pub gossip_delta: bool,
    /// Checkpoint interval per partition, sim-ms.
    pub checkpoint_interval_ms: u64,
    /// Shard count for keyed aggregation state (rounded up to a power
    /// of two). `0` = unsharded flat maps. With `N > 0`, keyed CLI
    /// workloads (`holon run q4`) run over
    /// [`ShardedMapCrdt`](crate::shard::ShardedMapCrdt): per-shard
    /// delta gossip, parallel shard merges, per-shard checkpoint
    /// slices. Outputs are byte-identical either way.
    pub shard_count: u32,
    /// Worker cap for the parallel shard-merge pool (`0` = the host's
    /// available parallelism). Applied process-wide at cluster start.
    pub shard_merge_threads: u32,
    /// Heartbeat broadcast interval, sim-ms.
    pub heartbeat_interval_ms: u64,
    /// Declare a node dead after this long without a heartbeat, sim-ms.
    pub failure_timeout_ms: u64,
    /// Executor idle poll interval when no work is due, sim-ms.
    pub poll_interval_ms: u64,

    // -- network ---------------------------------------------------------
    /// Base one-way network delay, sim-ms.
    pub net_delay_ms: u64,
    /// Uniform network jitter, sim-ms.
    pub net_jitter_ms: u64,
    /// Message drop probability.
    pub net_drop_prob: f64,
    /// Probability of a heavy-tail delay spike per message/flush (cloud
    /// networks have tails; redundant gossip absorbs them, single-path
    /// channel watermarks do not).
    pub net_tail_prob: f64,
    /// Extra delay of a tail spike, sim-ms (uniform in [tail/2, tail]).
    pub net_tail_ms: u64,
    /// Max undelivered messages per node inbox (`0` = unbounded). The
    /// backpressure knob: with a cap set, flush parks overflow on the
    /// sender's outbound queues, receivers advertise their free space
    /// as credits on heartbeats, and senders shrink their event budget
    /// when credits run dry — overload degrades to bounded lag instead
    /// of unbounded inbox memory.
    pub inbox_capacity: usize,
    /// Changefeed retention ring depth per node (`0` = derive from the
    /// gossip config; see `engine::effective_changefeed_retention`). A
    /// batched flush burst can deliver many gossip rounds at once, so
    /// retention must cover at least a full anti-entropy period or one
    /// slow subscriber turns every burst into a FeedGap re-bootstrap.
    pub changefeed_retention: usize,
    /// Modeled per-event service cost of a Holon node, microseconds of
    /// sim-time (calibrated from the paper's measured 2.05M ev/s on 10
    /// nodes ≈ 4.9 µs/event; §5.3).
    pub holon_event_cost_us: f64,
    /// Modeled per-event service cost of a baseline task slot (paper:
    /// 1.09M ev/s on 10 nodes ≈ 9 µs/event for Q7; shuffled events pay
    /// it at each hop).
    pub flink_event_cost_us: f64,

    // -- baseline (Flink model; paper §5.1 configuration) -----------------
    /// Checkpoint interval (paper: 5 s).
    pub flink_checkpoint_interval_ms: u64,
    /// Heartbeat interval (paper: 4 s).
    pub flink_heartbeat_interval_ms: u64,
    /// Heartbeat timeout (paper: 6 s).
    pub flink_heartbeat_timeout_ms: u64,
    /// Time for a failed task-manager container to come back (the 10 s
    /// "restarted 10 seconds later" of §5.2 scenarios).
    pub flink_restart_delay_ms: u64,
    /// Job restore cost: state re-load + task redeploy, sim-ms.
    pub flink_restore_cost_ms: u64,
    /// Network buffer flush timeout per pipeline hop (execution.buffer-timeout).
    pub flink_buffer_timeout_ms: u64,
    /// Source auto-watermark emission interval
    /// (pipeline.auto-watermark-interval, Flink default 200 ms).
    pub flink_watermark_interval_ms: u64,
    /// Whether spare task slots are available (Table 2's third row).
    pub flink_spare_slots: bool,

    // -- runtime ---------------------------------------------------------
    /// Use the AOT XLA kernels on the hot path when artifacts exist.
    pub use_xla: bool,
    /// Directory with *.hlo.txt artifacts.
    pub artifacts_dir: String,

    // -- bench harness ---------------------------------------------------
    /// Where `holon bench` writes its machine-readable report (the
    /// perf-trajectory data point; schema in EXPERIMENTS.md).
    pub bench_out: String,

    // -- observability ---------------------------------------------------
    /// Enable the flight recorder (per-node bounded event rings; see
    /// `crate::trace`). Off by default: the instrumentation compiles in
    /// permanently but records nothing — disabled handles cost one
    /// predicted branch per call site and zero allocations.
    pub trace: bool,
    /// Where to write the Chrome `trace_event` JSON dump at the end of
    /// a traced run (empty = don't write). The CLI front end turns
    /// `--trace-out=path` into `trace = true` as well; as a plain
    /// config key the two are independent.
    pub trace_out: String,
}

impl Default for HolonConfig {
    fn default() -> Self {
        Self {
            nodes: 5,
            partitions: 10,
            events_per_sec_per_partition: 1000,
            seed: 42,
            wall_ms_per_sim_sec: 20.0,
            duration_ms: 60_000,
            window_ms: 1000,
            batch_size: 256,
            gossip_interval_ms: 50,
            gossip_fanout: AUTO_GOSSIP_FANOUT,
            gossip_delta: false,
            checkpoint_interval_ms: 1000,
            shard_count: 0,
            shard_merge_threads: 0,
            heartbeat_interval_ms: 150,
            failure_timeout_ms: 600,
            poll_interval_ms: 5,
            net_delay_ms: 5,
            net_jitter_ms: 5,
            net_drop_prob: 0.0,
            net_tail_prob: 0.02,
            net_tail_ms: 200,
            inbox_capacity: 0,
            changefeed_retention: 0,
            holon_event_cost_us: 4.9,
            flink_event_cost_us: 9.0,
            flink_checkpoint_interval_ms: 5000,
            flink_heartbeat_interval_ms: 4000,
            flink_heartbeat_timeout_ms: 6000,
            flink_restart_delay_ms: 10_000,
            flink_restore_cost_ms: 1500,
            flink_buffer_timeout_ms: 100,
            flink_watermark_interval_ms: 200,
            flink_spare_slots: false,
            use_xla: false,
            artifacts_dir: "artifacts".to_string(),
            bench_out: "BENCH_PR9.json".to_string(),
            trace: false,
            trace_out: String::new(),
        }
    }
}

/// Configuration errors.
#[derive(Debug, thiserror::Error)]
pub enum ConfigError {
    #[error("unknown config key: {0}")]
    UnknownKey(String),
    #[error("invalid value for {key}: {value}")]
    InvalidValue { key: String, value: String },
    #[error("malformed line {0}: expected `key = value`")]
    Malformed(usize),
    #[error("io: {0}")]
    Io(#[from] std::io::Error),
}

impl HolonConfig {
    /// Apply one `key = value` override.
    pub fn set(&mut self, key: &str, value: &str) -> Result<(), ConfigError> {
        macro_rules! parse {
            () => {
                value.parse().map_err(|_| ConfigError::InvalidValue {
                    key: key.to_string(),
                    value: value.to_string(),
                })?
            };
        }
        match key {
            "nodes" => self.nodes = parse!(),
            "partitions" => self.partitions = parse!(),
            "events_per_sec_per_partition" => self.events_per_sec_per_partition = parse!(),
            "seed" => self.seed = parse!(),
            "wall_ms_per_sim_sec" => self.wall_ms_per_sim_sec = parse!(),
            "duration_ms" => self.duration_ms = parse!(),
            "window_ms" => self.window_ms = parse!(),
            "batch_size" => self.batch_size = parse!(),
            "gossip_interval_ms" => self.gossip_interval_ms = parse!(),
            "gossip_fanout" => {
                self.gossip_fanout = if value == "auto" {
                    AUTO_GOSSIP_FANOUT
                } else {
                    parse!()
                }
            }
            "gossip_delta" => self.gossip_delta = parse!(),
            "checkpoint_interval_ms" => self.checkpoint_interval_ms = parse!(),
            "shard_count" => self.shard_count = parse!(),
            "shard_merge_threads" => self.shard_merge_threads = parse!(),
            "heartbeat_interval_ms" => self.heartbeat_interval_ms = parse!(),
            "failure_timeout_ms" => self.failure_timeout_ms = parse!(),
            "poll_interval_ms" => self.poll_interval_ms = parse!(),
            "net_delay_ms" => self.net_delay_ms = parse!(),
            "net_jitter_ms" => self.net_jitter_ms = parse!(),
            "net_drop_prob" => self.net_drop_prob = parse!(),
            "net_tail_prob" => self.net_tail_prob = parse!(),
            "net_tail_ms" => self.net_tail_ms = parse!(),
            "inbox_capacity" => self.inbox_capacity = parse!(),
            "changefeed_retention" => self.changefeed_retention = parse!(),
            "holon_event_cost_us" => self.holon_event_cost_us = parse!(),
            "flink_event_cost_us" => self.flink_event_cost_us = parse!(),
            "flink_checkpoint_interval_ms" => self.flink_checkpoint_interval_ms = parse!(),
            "flink_heartbeat_interval_ms" => self.flink_heartbeat_interval_ms = parse!(),
            "flink_heartbeat_timeout_ms" => self.flink_heartbeat_timeout_ms = parse!(),
            "flink_restart_delay_ms" => self.flink_restart_delay_ms = parse!(),
            "flink_restore_cost_ms" => self.flink_restore_cost_ms = parse!(),
            "flink_buffer_timeout_ms" => self.flink_buffer_timeout_ms = parse!(),
            "flink_watermark_interval_ms" => self.flink_watermark_interval_ms = parse!(),
            "flink_spare_slots" => self.flink_spare_slots = parse!(),
            "use_xla" => self.use_xla = parse!(),
            "artifacts_dir" => self.artifacts_dir = value.to_string(),
            "bench_out" => self.bench_out = value.to_string(),
            "trace" => self.trace = parse!(),
            "trace_out" => self.trace_out = value.to_string(),
            _ => return Err(ConfigError::UnknownKey(key.to_string())),
        }
        Ok(())
    }

    /// The gossip fan-out the engine actually uses: the configured
    /// value, with the `auto` sentinel resolved to ⌈log₂ nodes⌉ (`0` =
    /// broadcast to all; see the [`gossip_fanout`](Self::gossip_fanout)
    /// doc for the measured tradeoff).
    pub fn effective_gossip_fanout(&self) -> usize {
        if self.gossip_fanout == AUTO_GOSSIP_FANOUT {
            ceil_log2(self.nodes)
        } else {
            self.gossip_fanout as usize
        }
    }

    /// Parse a config file of `key = value` lines.
    pub fn from_file(path: &Path) -> Result<Self, ConfigError> {
        let text = std::fs::read_to_string(path)?;
        let mut cfg = Self::default();
        cfg.apply_text(&text)?;
        Ok(cfg)
    }

    /// Apply `key = value` lines from a string.
    pub fn apply_text(&mut self, text: &str) -> Result<(), ConfigError> {
        for (i, line) in text.lines().enumerate() {
            let line = line.trim();
            if line.is_empty() || line.starts_with('#') {
                continue;
            }
            let Some((k, v)) = line.split_once('=') else {
                return Err(ConfigError::Malformed(i + 1));
            };
            self.set(k.trim(), v.trim())?;
        }
        Ok(())
    }

    /// Apply `--key=value` CLI arguments; returns non-option args.
    ///
    /// Options whose key is not a config key pass through to the caller
    /// (subcommands own flags like `--system=` or `--seeds=`); only a
    /// *known* key with an unparsable value is an error. Config files
    /// stay strict — see [`apply_text`](Self::apply_text).
    pub fn apply_args<'a>(
        &mut self,
        args: impl Iterator<Item = &'a str>,
    ) -> Result<Vec<&'a str>, ConfigError> {
        let mut rest = Vec::new();
        for a in args {
            if let Some(kv) = a.strip_prefix("--") {
                if let Some((k, v)) = kv.split_once('=') {
                    match self.set(&k.replace('-', "_"), v) {
                        Ok(()) => continue,
                        Err(ConfigError::UnknownKey(_)) => {} // subcommand flag
                        Err(e) => return Err(e),
                    }
                }
            }
            rest.push(a);
        }
        Ok(rest)
    }

    /// Dump as `key = value` lines (introspection / `holon inspect`).
    pub fn dump(&self) -> String {
        let mut m = BTreeMap::new();
        m.insert("nodes", self.nodes.to_string());
        m.insert("partitions", self.partitions.to_string());
        m.insert(
            "events_per_sec_per_partition",
            self.events_per_sec_per_partition.to_string(),
        );
        m.insert("seed", self.seed.to_string());
        m.insert("wall_ms_per_sim_sec", self.wall_ms_per_sim_sec.to_string());
        m.insert("duration_ms", self.duration_ms.to_string());
        m.insert("window_ms", self.window_ms.to_string());
        m.insert("batch_size", self.batch_size.to_string());
        m.insert("gossip_interval_ms", self.gossip_interval_ms.to_string());
        m.insert(
            "gossip_fanout",
            if self.gossip_fanout == AUTO_GOSSIP_FANOUT {
                "auto".to_string()
            } else {
                self.gossip_fanout.to_string()
            },
        );
        m.insert("gossip_delta", self.gossip_delta.to_string());
        m.insert("shard_count", self.shard_count.to_string());
        m.insert(
            "shard_merge_threads",
            self.shard_merge_threads.to_string(),
        );
        m.insert(
            "checkpoint_interval_ms",
            self.checkpoint_interval_ms.to_string(),
        );
        m.insert(
            "heartbeat_interval_ms",
            self.heartbeat_interval_ms.to_string(),
        );
        m.insert("failure_timeout_ms", self.failure_timeout_ms.to_string());
        m.insert("poll_interval_ms", self.poll_interval_ms.to_string());
        m.insert("net_delay_ms", self.net_delay_ms.to_string());
        m.insert("net_jitter_ms", self.net_jitter_ms.to_string());
        m.insert("net_drop_prob", self.net_drop_prob.to_string());
        m.insert("net_tail_prob", self.net_tail_prob.to_string());
        m.insert("net_tail_ms", self.net_tail_ms.to_string());
        m.insert("inbox_capacity", self.inbox_capacity.to_string());
        m.insert(
            "changefeed_retention",
            self.changefeed_retention.to_string(),
        );
        m.insert("holon_event_cost_us", self.holon_event_cost_us.to_string());
        m.insert("flink_event_cost_us", self.flink_event_cost_us.to_string());
        m.insert(
            "flink_checkpoint_interval_ms",
            self.flink_checkpoint_interval_ms.to_string(),
        );
        m.insert(
            "flink_heartbeat_interval_ms",
            self.flink_heartbeat_interval_ms.to_string(),
        );
        m.insert(
            "flink_heartbeat_timeout_ms",
            self.flink_heartbeat_timeout_ms.to_string(),
        );
        m.insert(
            "flink_restart_delay_ms",
            self.flink_restart_delay_ms.to_string(),
        );
        m.insert(
            "flink_restore_cost_ms",
            self.flink_restore_cost_ms.to_string(),
        );
        m.insert(
            "flink_buffer_timeout_ms",
            self.flink_buffer_timeout_ms.to_string(),
        );
        m.insert(
            "flink_watermark_interval_ms",
            self.flink_watermark_interval_ms.to_string(),
        );
        m.insert("flink_spare_slots", self.flink_spare_slots.to_string());
        m.insert("use_xla", self.use_xla.to_string());
        m.insert("artifacts_dir", self.artifacts_dir.clone());
        m.insert("bench_out", self.bench_out.clone());
        m.insert("trace", self.trace.to_string());
        m.insert("trace_out", self.trace_out.clone());
        m.iter()
            .map(|(k, v)| format!("{k} = {v}"))
            .collect::<Vec<_>>()
            .join("\n")
    }
}

/// ⌈log₂ n⌉, with n ≤ 1 → 0 (no peers to sample).
fn ceil_log2(n: u32) -> usize {
    if n <= 1 {
        0
    } else {
        (32 - (n - 1).leading_zeros()) as usize
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_follow_paper_section_5_1() {
        let c = HolonConfig::default();
        assert_eq!(c.flink_checkpoint_interval_ms, 5000);
        assert_eq!(c.flink_heartbeat_interval_ms, 4000);
        assert_eq!(c.flink_heartbeat_timeout_ms, 6000);
    }

    #[test]
    fn set_and_apply_text() {
        let mut c = HolonConfig::default();
        c.apply_text("# comment\n\nnodes = 10\nwindow_ms=500\nflink_spare_slots = true\n")
            .unwrap();
        assert_eq!(c.nodes, 10);
        assert_eq!(c.window_ms, 500);
        assert!(c.flink_spare_slots);
    }

    #[test]
    fn unknown_key_rejected() {
        let mut c = HolonConfig::default();
        assert!(matches!(
            c.set("bogus", "1"),
            Err(ConfigError::UnknownKey(_))
        ));
    }

    #[test]
    fn invalid_value_rejected() {
        let mut c = HolonConfig::default();
        assert!(matches!(
            c.set("nodes", "abc"),
            Err(ConfigError::InvalidValue { .. })
        ));
    }

    #[test]
    fn malformed_line_reports_number() {
        let mut c = HolonConfig::default();
        let err = c.apply_text("nodes = 3\nnot a kv line\n").unwrap_err();
        assert!(matches!(err, ConfigError::Malformed(2)));
    }

    #[test]
    fn cli_args_override_and_pass_through() {
        let mut c = HolonConfig::default();
        let rest = c
            .apply_args(["--nodes=7", "run", "--net-delay-ms=9"].into_iter())
            .unwrap();
        assert_eq!(c.nodes, 7);
        assert_eq!(c.net_delay_ms, 9);
        assert_eq!(rest, vec!["run"]);
    }

    #[test]
    fn cli_args_pass_subcommand_flags_through() {
        // `--system=` / `--seeds=` are subcommand flags, not config keys;
        // they must reach the subcommand instead of erroring.
        let mut c = HolonConfig::default();
        let rest = c
            .apply_args(["run", "--system=flink", "--nodes=3", "--seeds=20"].into_iter())
            .unwrap();
        assert_eq!(c.nodes, 3);
        assert_eq!(rest, vec!["run", "--system=flink", "--seeds=20"]);
    }

    #[test]
    fn cli_args_bad_value_for_known_key_still_errors() {
        let mut c = HolonConfig::default();
        assert!(matches!(
            c.apply_args(["--nodes=lots"].into_iter()),
            Err(ConfigError::InvalidValue { .. })
        ));
    }

    #[test]
    fn dump_roundtrips() {
        let mut c = HolonConfig::default();
        c.nodes = 17;
        c.net_drop_prob = 0.25;
        let mut c2 = HolonConfig::default();
        c2.apply_text(&c.dump()).unwrap();
        assert_eq!(c, c2);
    }

    #[test]
    fn dump_roundtrips_explicit_fanout_and_shards() {
        let mut c = HolonConfig::default();
        c.gossip_fanout = 3;
        c.shard_count = 16;
        c.shard_merge_threads = 2;
        let mut c2 = HolonConfig::default();
        c2.apply_text(&c.dump()).unwrap();
        assert_eq!(c, c2);
    }

    #[test]
    fn gossip_fanout_auto_parses_dumps_and_resolves() {
        let mut c = HolonConfig::default();
        assert_eq!(c.gossip_fanout, AUTO_GOSSIP_FANOUT, "auto is the default");
        assert!(c.dump().contains("gossip_fanout = auto"));
        // auto resolves to ⌈log₂ nodes⌉
        for (nodes, want) in [(1u32, 0usize), (2, 1), (4, 2), (5, 3), (8, 3), (9, 4), (100, 7)] {
            c.nodes = nodes;
            assert_eq!(c.effective_gossip_fanout(), want, "nodes = {nodes}");
        }
        // explicit values pass through untouched, including broadcast-all
        c.set("gossip_fanout", "0").unwrap();
        assert_eq!(c.effective_gossip_fanout(), 0);
        c.set("gossip_fanout", "4").unwrap();
        assert_eq!(c.effective_gossip_fanout(), 4);
        c.set("gossip_fanout", "auto").unwrap();
        assert_eq!(c.gossip_fanout, AUTO_GOSSIP_FANOUT);
        // bad values still error
        assert!(matches!(
            c.set("gossip_fanout", "lots"),
            Err(ConfigError::InvalidValue { .. })
        ));
    }

    #[test]
    fn backpressure_knobs_parse_and_roundtrip() {
        let mut c = HolonConfig::default();
        assert_eq!(c.inbox_capacity, 0, "backpressure is opt-in");
        assert_eq!(c.changefeed_retention, 0, "retention derives by default");
        c.apply_text("inbox_capacity = 64\nchangefeed_retention = 512\n")
            .unwrap();
        assert_eq!(c.inbox_capacity, 64);
        assert_eq!(c.changefeed_retention, 512);
        let mut c2 = HolonConfig::default();
        c2.apply_text(&c.dump()).unwrap();
        assert_eq!(c, c2);
    }

    #[test]
    fn trace_knobs_parse_and_roundtrip() {
        let mut c = HolonConfig::default();
        assert!(!c.trace, "flight recorder is opt-in");
        assert!(c.trace_out.is_empty());
        c.apply_text("trace = true\ntrace_out = out/trace.json\n").unwrap();
        assert!(c.trace);
        assert_eq!(c.trace_out, "out/trace.json");
        let mut c2 = HolonConfig::default();
        c2.apply_text(&c.dump()).unwrap();
        assert_eq!(c, c2);
    }

    #[test]
    fn shard_knobs_parse() {
        let mut c = HolonConfig::default();
        assert_eq!(c.shard_count, 0, "sharding is opt-in");
        c.apply_text("shard_count = 8\nshard_merge_threads = 4\n").unwrap();
        assert_eq!(c.shard_count, 8);
        assert_eq!(c.shard_merge_threads, 4);
    }
}
