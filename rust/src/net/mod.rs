//! Simulated network: broadcast + control buses between nodes.
//!
//! Stands in for the paper's Kafka broadcast/control topics and the GCP
//! network. Point-to-point and broadcast messages are delivered into
//! per-node inboxes after a configurable delay, with optional message
//! loss and *network partitions* (groups that cannot reach each other)
//! for the CAP-behaviour experiments. Because gossip is periodic
//! full-state CRDT exchange, dropped messages only delay convergence —
//! they never break it (that is the point of the paper's design).

use std::collections::{BTreeMap, VecDeque};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex, RwLock};

use crate::clock::SimClock;
use crate::util::{NodeId, SimTime, XorShift64};

/// Message kinds on the buses.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum MsgKind {
    /// CRDT state gossip (the background "async shuffle" of state).
    Gossip,
    /// Node heartbeat (failure detection).
    Heartbeat,
    /// Partition-ownership claim (work stealing coordination).
    Claim,
}

/// An in-flight or delivered message.
#[derive(Debug, Clone)]
pub struct Msg {
    pub from: NodeId,
    pub kind: MsgKind,
    pub sent_at: SimTime,
    pub payload: Arc<Vec<u8>>,
}

#[derive(Debug, Clone)]
pub struct NetConfig {
    /// Base one-way delay in sim-ms.
    pub base_delay_ms: u64,
    /// Extra uniform jitter in sim-ms.
    pub jitter_ms: u64,
    /// Probability a message is silently dropped.
    pub drop_prob: f64,
    /// Probability of a heavy-tail delay spike.
    pub tail_prob: f64,
    /// Spike magnitude, sim-ms (uniform in [tail/2, tail]).
    pub tail_ms: u64,
}

impl Default for NetConfig {
    fn default() -> Self {
        Self {
            base_delay_ms: 5,
            jitter_ms: 5,
            drop_prob: 0.0,
            tail_prob: 0.0,
            tail_ms: 0,
        }
    }
}

#[derive(Debug, Default)]
struct Inbox {
    /// (deliver_at, msg), kept sorted by arrival of push (delays are
    /// bounded so near-sorted; we scan for due messages).
    queue: VecDeque<(SimTime, Msg)>,
}

/// A transient fault condition layered on top of the steady-state
/// [`NetConfig`] — the knob the simulation harness turns for delay and
/// loss *bursts* (cloud incidents are episodic, not stationary). Unlike
/// `NetConfig`, the overlay can change while the bus is live.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct FaultOverlay {
    /// Extra one-way delay added to every message, sim-ms.
    pub extra_delay_ms: u64,
    /// Extra independent drop probability applied to every message.
    pub extra_drop_prob: f64,
}

/// Registry + partition state; per-inbox queues are individually locked
/// so a 100-node cluster doesn't serialize on one mutex (see §Perf).
#[derive(Debug)]
struct BusInner {
    cfg: NetConfig,
    rng: Mutex<XorShift64>,
    inboxes: RwLock<BTreeMap<NodeId, Arc<Mutex<Inbox>>>>,
    /// group id per node; nodes in different groups are partitioned.
    /// Empty map = fully connected.
    groups: RwLock<BTreeMap<NodeId, u32>>,
    /// Transient delay/loss burst injected by the fault harness.
    faults: RwLock<FaultOverlay>,
    delivered: AtomicU64,
    dropped: AtomicU64,
    /// Payload bytes enqueued toward recipients (post-drop) — the bench
    /// harness's gossip-bytes/sec source. Payloads are `Arc`-shared, so
    /// this counts logical wire bytes, not allocations.
    bytes_sent: AtomicU64,
}

/// Shared broadcast/control bus.
#[derive(Debug, Clone)]
pub struct Bus {
    clock: SimClock,
    inner: Arc<BusInner>,
}

impl Bus {
    pub fn new(clock: SimClock, cfg: NetConfig, seed: u64) -> Self {
        Self {
            clock,
            inner: Arc::new(BusInner {
                cfg,
                rng: Mutex::new(XorShift64::new(seed)),
                inboxes: RwLock::new(BTreeMap::new()),
                groups: RwLock::new(BTreeMap::new()),
                faults: RwLock::new(FaultOverlay::default()),
                delivered: AtomicU64::new(0),
                dropped: AtomicU64::new(0),
                bytes_sent: AtomicU64::new(0),
            }),
        }
    }

    /// Register a node's inbox (idempotent).
    pub fn register(&self, node: NodeId) {
        let mut inboxes = self.inner.inboxes.write().unwrap();
        inboxes.entry(node).or_default();
    }

    /// Remove a node's inbox (simulated crash drops queued messages).
    pub fn unregister(&self, node: NodeId) {
        self.inner.inboxes.write().unwrap().remove(&node);
    }

    fn reachable(&self, from: NodeId, to: NodeId) -> bool {
        let groups = self.inner.groups.read().unwrap();
        if groups.is_empty() {
            return true;
        }
        let gf = groups.get(&from).copied().unwrap_or(0);
        let gt = groups.get(&to).copied().unwrap_or(0);
        gf == gt
    }

    /// Broadcast to all registered nodes except the sender.
    pub fn broadcast(&self, from: NodeId, kind: MsgKind, payload: Vec<u8>) {
        self.broadcast_shared(from, kind, Arc::new(payload));
    }

    /// As [`broadcast`](Self::broadcast), but the payload is already an
    /// `Arc` — the caller encoded once for the whole round and every
    /// recipient shares the same bytes (no per-recipient clone, no
    /// re-wrap). The gossip hot path — including sharded keyed-state
    /// deltas, whose shard-tagged segments ride inside the one encoded
    /// payload (`crate::shard`), so per-shard granularity costs no
    /// extra messages or allocations on the bus.
    pub fn broadcast_shared(&self, from: NodeId, kind: MsgKind, payload: Arc<Vec<u8>>) {
        let now = self.clock.now();
        let inboxes = self.inner.inboxes.read().unwrap();
        for (&to, inbox) in inboxes.iter() {
            if to != from {
                self.push(inbox, now, from, to, kind, payload.clone());
            }
        }
    }

    /// Gossip-style fan-out: send to up to `fanout` random peers (the
    /// Pekko-distributed-data pattern). State-based CRDT gossip spreads
    /// transitively, so O(n·fanout) traffic converges in O(log n)
    /// rounds instead of O(n²) per round — the difference between 10
    /// and 100 nodes staying responsive (§Perf, Fig 9).
    pub fn broadcast_sample(&self, from: NodeId, kind: MsgKind, payload: Vec<u8>, fanout: usize) {
        self.broadcast_sample_shared(from, kind, Arc::new(payload), fanout);
    }

    /// `Arc`-payload variant of [`broadcast_sample`](Self::broadcast_sample):
    /// one encode per gossip round, shared across all sampled peers.
    pub fn broadcast_sample_shared(
        &self,
        from: NodeId,
        kind: MsgKind,
        payload: Arc<Vec<u8>>,
        fanout: usize,
    ) {
        let now = self.clock.now();
        let inboxes = self.inner.inboxes.read().unwrap();
        let peers: Vec<NodeId> = inboxes.keys().copied().filter(|&n| n != from).collect();
        if peers.is_empty() {
            return;
        }
        if fanout == 0 || fanout >= peers.len() {
            for &to in &peers {
                self.push(&inboxes[&to], now, from, to, kind, payload.clone());
            }
            return;
        }
        let mut rng = self.inner.rng.lock().unwrap();
        let mut chosen = std::collections::BTreeSet::new();
        while chosen.len() < fanout {
            chosen.insert(*rng.pick(&peers));
        }
        drop(rng);
        for &to in &chosen {
            self.push(&inboxes[&to], now, from, to, kind, payload.clone());
        }
    }

    /// Point-to-point send.
    pub fn send(&self, from: NodeId, to: NodeId, kind: MsgKind, payload: Vec<u8>) {
        let now = self.clock.now();
        let inboxes = self.inner.inboxes.read().unwrap();
        match inboxes.get(&to) {
            Some(inbox) => self.push(inbox, now, from, to, kind, Arc::new(payload)),
            None => {
                self.inner.dropped.fetch_add(1, Ordering::Relaxed);
            }
        }
    }

    fn push(
        &self,
        inbox: &Arc<Mutex<Inbox>>,
        now: SimTime,
        from: NodeId,
        to: NodeId,
        kind: MsgKind,
        payload: Arc<Vec<u8>>,
    ) {
        if !self.reachable(from, to) {
            self.inner.dropped.fetch_add(1, Ordering::Relaxed);
            return;
        }
        let cfg = &self.inner.cfg;
        let overlay = *self.inner.faults.read().unwrap();
        let jitter;
        {
            let mut rng = self.inner.rng.lock().unwrap();
            if cfg.drop_prob > 0.0 && rng.chance(cfg.drop_prob) {
                self.inner.dropped.fetch_add(1, Ordering::Relaxed);
                return;
            }
            if overlay.extra_drop_prob > 0.0 && rng.chance(overlay.extra_drop_prob) {
                self.inner.dropped.fetch_add(1, Ordering::Relaxed);
                return;
            }
            jitter = if cfg.jitter_ms > 0 {
                rng.next_below(cfg.jitter_ms + 1)
            } else {
                0
            } + if cfg.tail_prob > 0.0 && cfg.tail_ms > 1 && rng.chance(cfg.tail_prob) {
                cfg.tail_ms / 2 + rng.next_below(cfg.tail_ms / 2)
            } else {
                0
            };
        }
        let deliver_at = now + cfg.base_delay_ms + overlay.extra_delay_ms + jitter;
        self.inner.bytes_sent.fetch_add(payload.len() as u64, Ordering::Relaxed);
        inbox.lock().unwrap().queue.push_back((
            deliver_at,
            Msg {
                from,
                kind,
                sent_at: now,
                payload,
            },
        ));
    }

    /// Drain all messages due for `node` at the current sim-time.
    pub fn recv(&self, node: NodeId) -> Vec<Msg> {
        let now = self.clock.now();
        let inbox = {
            let inboxes = self.inner.inboxes.read().unwrap();
            match inboxes.get(&node) {
                Some(i) => i.clone(),
                None => return Vec::new(),
            }
        };
        let mut inbox = inbox.lock().unwrap();
        let mut due: Vec<(SimTime, Msg)> = Vec::new();
        let mut rest = VecDeque::with_capacity(inbox.queue.len());
        while let Some((at, msg)) = inbox.queue.pop_front() {
            if at <= now {
                due.push((at, msg));
            } else {
                rest.push_back((at, msg));
            }
        }
        inbox.queue = rest;
        drop(inbox);
        // Canonical delivery order: the order messages landed in the
        // inbox depends on sender thread interleaving; sorting the due
        // set by (deliver_at, sender, send time) removes that source of
        // schedule nondeterminism (the stable sort keeps a sender's own
        // messages in send order). The simulation oracles compare runs
        // across wildly different interleavings, so delivery order must
        // be a function of message metadata, not of thread scheduling.
        due.sort_by_key(|(at, m)| (*at, m.from, m.sent_at));
        self.inner.delivered.fetch_add(due.len() as u64, Ordering::Relaxed);
        due.into_iter().map(|(_, m)| m).collect()
    }

    /// Install a transient delay/loss burst on every subsequent message.
    pub fn set_fault_overlay(&self, overlay: FaultOverlay) {
        *self.inner.faults.write().unwrap() = overlay;
    }

    /// End any delay/loss burst (back to the steady-state `NetConfig`).
    pub fn clear_fault_overlay(&self) {
        *self.inner.faults.write().unwrap() = FaultOverlay::default();
    }

    /// Impose a network partition: nodes listed in different groups
    /// cannot exchange messages. Nodes not listed join group 0.
    pub fn set_partition(&self, groups: &[&[NodeId]]) {
        let mut g = self.inner.groups.write().unwrap();
        g.clear();
        for (gid, members) in groups.iter().enumerate() {
            for &n in *members {
                g.insert(n, gid as u32 + 1);
            }
        }
    }

    /// Heal all network partitions.
    pub fn heal_partition(&self) {
        self.inner.groups.write().unwrap().clear();
    }

    /// (delivered, dropped) counters — for tests and the bench reports.
    pub fn stats(&self) -> (u64, u64) {
        (
            self.inner.delivered.load(Ordering::Acquire),
            self.inner.dropped.load(Ordering::Acquire),
        )
    }

    /// Payload bytes enqueued toward recipients so far (logical wire
    /// volume; dropped messages are excluded).
    pub fn bytes_sent(&self) -> u64 {
        self.inner.bytes_sent.load(Ordering::Acquire)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn bus(clock: &SimClock) -> Bus {
        Bus::new(
            clock.clone(),
            NetConfig {
                base_delay_ms: 10,
                jitter_ms: 0,
                drop_prob: 0.0,
                tail_prob: 0.0,
                tail_ms: 0,
            },
            7,
        )
    }

    #[test]
    fn delivery_respects_delay() {
        let clock = SimClock::manual();
        let b = bus(&clock);
        b.register(1);
        b.register(2);
        b.send(1, 2, MsgKind::Gossip, vec![42]);
        assert!(b.recv(2).is_empty()); // not due yet
        clock.advance(10);
        let msgs = b.recv(2);
        assert_eq!(msgs.len(), 1);
        assert_eq!(*msgs[0].payload, vec![42]);
        assert_eq!(msgs[0].from, 1);
    }

    #[test]
    fn broadcast_reaches_everyone_but_sender() {
        let clock = SimClock::manual();
        let b = bus(&clock);
        for n in 1..=3 {
            b.register(n);
        }
        b.broadcast(1, MsgKind::Heartbeat, vec![]);
        clock.advance(10);
        assert!(b.recv(1).is_empty());
        assert_eq!(b.recv(2).len(), 1);
        assert_eq!(b.recv(3).len(), 1);
    }

    #[test]
    fn partition_blocks_cross_group_traffic() {
        let clock = SimClock::manual();
        let b = bus(&clock);
        for n in 1..=4 {
            b.register(n);
        }
        b.set_partition(&[&[1, 2], &[3, 4]]);
        b.broadcast(1, MsgKind::Gossip, vec![]);
        clock.advance(10);
        assert_eq!(b.recv(2).len(), 1);
        assert!(b.recv(3).is_empty());
        assert!(b.recv(4).is_empty());
        b.heal_partition();
        b.broadcast(1, MsgKind::Gossip, vec![]);
        clock.advance(10);
        assert_eq!(b.recv(3).len(), 1);
    }

    #[test]
    fn drop_prob_loses_messages() {
        let clock = SimClock::manual();
        let b = Bus::new(
            clock.clone(),
            NetConfig {
                base_delay_ms: 0,
                jitter_ms: 0,
                drop_prob: 1.0,
                tail_prob: 0.0,
                tail_ms: 0,
            },
            9,
        );
        b.register(1);
        b.register(2);
        b.send(1, 2, MsgKind::Gossip, vec![]);
        clock.advance(1);
        assert!(b.recv(2).is_empty());
        assert_eq!(b.stats().1, 1);
    }

    #[test]
    fn unregistered_target_counts_as_drop() {
        let clock = SimClock::manual();
        let b = bus(&clock);
        b.register(1);
        b.send(1, 99, MsgKind::Claim, vec![]);
        assert_eq!(b.stats().1, 1);
    }

    #[test]
    fn fault_overlay_adds_delay_and_loss() {
        let clock = SimClock::manual();
        let b = bus(&clock); // base delay 10
        b.register(1);
        b.register(2);
        b.set_fault_overlay(FaultOverlay {
            extra_delay_ms: 40,
            extra_drop_prob: 0.0,
        });
        b.send(1, 2, MsgKind::Gossip, vec![7]);
        clock.advance(10);
        assert!(b.recv(2).is_empty()); // base delay alone is not enough
        clock.advance(40);
        assert_eq!(b.recv(2).len(), 1);

        b.set_fault_overlay(FaultOverlay {
            extra_delay_ms: 0,
            extra_drop_prob: 1.0,
        });
        b.send(1, 2, MsgKind::Gossip, vec![8]);
        clock.advance(100);
        assert!(b.recv(2).is_empty());
        assert_eq!(b.stats().1, 1);

        // messages queued during a burst keep their (delayed) schedule,
        // but new messages after clear() are back to normal
        b.clear_fault_overlay();
        b.send(1, 2, MsgKind::Gossip, vec![9]);
        clock.advance(10);
        assert_eq!(b.recv(2).len(), 1);
    }

    #[test]
    fn shared_broadcast_shares_one_payload_and_counts_bytes() {
        let clock = SimClock::manual();
        let b = bus(&clock);
        for n in 1..=4 {
            b.register(n);
        }
        let payload = Arc::new(vec![1u8, 2, 3]);
        b.broadcast_shared(1, MsgKind::Gossip, payload.clone());
        // 3 recipients × 3 bytes of logical wire volume, one allocation
        assert_eq!(b.bytes_sent(), 9);
        clock.advance(10);
        for n in 2..=4 {
            let msgs = b.recv(n);
            assert_eq!(msgs.len(), 1);
            // recipients alias the sender's buffer (no copy)
            assert!(Arc::ptr_eq(&msgs[0].payload, &payload));
        }
    }

    #[test]
    fn sampled_shared_broadcast_respects_fanout() {
        let clock = SimClock::manual();
        let b = bus(&clock);
        for n in 1..=5 {
            b.register(n);
        }
        b.broadcast_sample_shared(1, MsgKind::Gossip, Arc::new(vec![7, 7]), 2);
        assert_eq!(b.bytes_sent(), 4); // 2 peers × 2 bytes
        clock.advance(10);
        let got: usize = (2..=5).map(|n| b.recv(n).len()).sum();
        assert_eq!(got, 2);
    }

    #[test]
    fn recv_orders_due_messages_canonically() {
        let clock = SimClock::manual();
        let b = bus(&clock);
        for n in 1..=3 {
            b.register(n);
        }
        // same deliver_at from two senders; recv must order by sender id
        // regardless of push order
        b.send(3, 1, MsgKind::Gossip, vec![3]);
        b.send(2, 1, MsgKind::Gossip, vec![2]);
        clock.advance(10);
        let msgs = b.recv(1);
        assert_eq!(msgs.len(), 2);
        assert_eq!(msgs[0].from, 2);
        assert_eq!(msgs[1].from, 3);
    }

    #[test]
    fn messages_stay_queued_until_due() {
        let clock = SimClock::manual();
        let b = bus(&clock);
        b.register(1);
        b.register(2);
        b.send(1, 2, MsgKind::Gossip, vec![1]);
        clock.advance(5);
        b.send(1, 2, MsgKind::Gossip, vec![2]);
        clock.advance(5);
        // first due (t=10), second not (t=15)
        let msgs = b.recv(2);
        assert_eq!(msgs.len(), 1);
        clock.advance(5);
        assert_eq!(b.recv(2).len(), 1);
    }
}
