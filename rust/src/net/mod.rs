//! Simulated network: broadcast + control buses between nodes.
//!
//! Stands in for the paper's Kafka broadcast/control topics and the GCP
//! network. Point-to-point and broadcast messages are delivered into
//! per-node inboxes after a configurable delay, with optional message
//! loss and *network partitions* (groups that cannot reach each other)
//! for the CAP-behaviour experiments. Because gossip is periodic
//! full-state CRDT exchange, dropped messages only delay convergence —
//! they never break it (that is the point of the paper's design).
//!
//! ## Async data plane
//!
//! Send-side calls ([`Bus::send`], [`Bus::broadcast_shared`],
//! [`Bus::broadcast_sample_shared`]) only *enqueue* `(to, kind, Arc
//! payload)` onto the sender's per-peer outbound queues and return
//! immediately — no RNG lock, no recipient inbox lock, no fault
//! pipeline on the sender's hot path, so send cost is O(fan-out) queue
//! pushes regardless of how congested any receiver is. [`Bus::flush`]
//! (driven once per node-loop iteration) moves the whole batch: it
//! applies partition checks, loss, delay and jitter in ONE RNG critical
//! section for the entire batch and bulk-appends to recipient inboxes.
//! Delivery ordering stays canonical — [`Bus::recv`] sorts due messages
//! by `(deliver_at, from, sent_at)`, and `sent_at` is stamped at
//! enqueue time — so seeded fault schedules remain byte-reproducible.
//!
//! Backpressure: when [`NetConfig::inbox_capacity`] is non-zero, a
//! recipient inbox never holds more than that many undelivered
//! messages. Flush delivers into the free space and *parks* the
//! remainder on the sender's outbound queue (state-based CRDT gossip
//! converges from any prefix of deliveries, so parking is bounded
//! staleness, never divergence). Parked queues are themselves bounded
//! (4× the inbox capacity); beyond that the *oldest* parked message is
//! dropped — old gossip is subsumed by newer state, so oldest-first is
//! the CRDT-safe shedding order. Receivers advertise their free inbox
//! space as *credits* on the heartbeat path (see `engine::node`), which
//! lets senders shrink their event budget before shedding starts.
//! Credits gate *sources*, never acknowledgements — exactly-once
//! delivery is cursor/dedup-based and unaffected.

use std::collections::{BTreeMap, VecDeque};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex, RwLock};

use crate::clock::SimClock;
use crate::util::{LockExt, NodeId, SimTime, XorShift64};

/// Message kinds on the buses.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum MsgKind {
    /// CRDT state gossip (the background "async shuffle" of state).
    Gossip,
    /// Node heartbeat (failure detection + credit advertisement).
    Heartbeat,
    /// Partition-ownership claim (work stealing coordination).
    Claim,
}

/// An in-flight or delivered message.
#[derive(Debug, Clone)]
pub struct Msg {
    pub from: NodeId,
    pub kind: MsgKind,
    pub sent_at: SimTime,
    pub payload: Arc<Vec<u8>>,
}

#[derive(Debug, Clone)]
pub struct NetConfig {
    /// Base one-way delay in sim-ms.
    pub base_delay_ms: u64,
    /// Extra uniform jitter in sim-ms.
    pub jitter_ms: u64,
    /// Probability a message is silently dropped.
    pub drop_prob: f64,
    /// Probability of a heavy-tail delay spike.
    pub tail_prob: f64,
    /// Spike magnitude, sim-ms (uniform in [tail/2, tail]).
    pub tail_ms: u64,
    /// Max undelivered messages per recipient inbox (0 = unbounded).
    /// The backpressure knob: flush parks what does not fit instead of
    /// growing inbox memory without bound.
    pub inbox_capacity: usize,
}

impl Default for NetConfig {
    fn default() -> Self {
        Self {
            base_delay_ms: 5,
            jitter_ms: 5,
            drop_prob: 0.0,
            tail_prob: 0.0,
            tail_ms: 0,
            inbox_capacity: 0,
        }
    }
}

#[derive(Debug, Default)]
struct Inbox {
    /// (deliver_at, msg), kept sorted by arrival of push (delays are
    /// bounded so near-sorted; we scan for due messages).
    queue: VecDeque<(SimTime, Msg)>,
}

/// A transient fault condition layered on top of the steady-state
/// [`NetConfig`] — the knob the simulation harness turns for delay and
/// loss *bursts* (cloud incidents are episodic, not stationary). Unlike
/// `NetConfig`, the overlay can change while the bus is live. The
/// overlay rides the flush step: messages enqueued before a burst but
/// flushed during it see the burst's loss/delay.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct FaultOverlay {
    /// Extra one-way delay added to every message, sim-ms.
    pub extra_delay_ms: u64,
    /// Extra independent drop probability applied to every message.
    pub extra_drop_prob: f64,
}

/// A message sitting on a sender's outbound queue, waiting for flush.
/// `sent_at` is the enqueue time — it keys canonical delivery ordering,
/// so the async hop is invisible to the determinism oracles.
#[derive(Debug, Clone)]
struct OutMsg {
    kind: MsgKind,
    sent_at: SimTime,
    payload: Arc<Vec<u8>>,
}

/// One sender's pending traffic: a queue per destination. Only the
/// owning node thread enqueues and flushes, so the single mutex is
/// uncontended in steady state.
#[derive(Debug, Default)]
struct Outbound {
    queues: BTreeMap<NodeId, VecDeque<OutMsg>>,
}

/// What one [`Bus::flush`] accomplished.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct FlushStats {
    /// Messages moved into recipient inboxes this flush.
    pub delivered: u64,
    /// Messages left parked on outbound queues because their
    /// destination inbox was at capacity — the backpressure signal.
    pub parked: u64,
}

/// What one [`Bus::flush_with`] batch did toward a single peer —
/// reported through the per-peer callback so the flight recorder can
/// attribute gossip-round outcomes (delivered / parked / dropped)
/// without the bus knowing about tracing.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct PeerFlush {
    /// Messages moved into this peer's inbox.
    pub delivered: u64,
    /// Messages left parked for this peer (inbox at capacity).
    pub parked: u64,
    /// Messages dropped toward this peer this batch (loss, partition,
    /// or unregistered target).
    pub dropped: u64,
}

/// Dropped-message accounting, split by cause. Restart churn
/// (`no_inbox`), partitions, lossy links and backpressure shedding are
/// different operational problems; folding them into one counter made
/// sim triage blame "network loss" for all of them.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct DropStats {
    /// Sender and destination were in different partition groups.
    pub partition: u64,
    /// Lost to `drop_prob` or a fault-overlay loss burst.
    pub loss: u64,
    /// Destination had no registered inbox (crashed/unregistered node).
    pub no_inbox: u64,
    /// Oldest parked message shed because a stalled peer's outbound
    /// queue hit its cap (only possible with `inbox_capacity > 0`).
    pub backpressure: u64,
}

impl DropStats {
    /// Sum over all causes — the pre-split `dropped` counter.
    pub fn total(&self) -> u64 {
        self.partition + self.loss + self.no_inbox + self.backpressure
    }
}

/// Registry + partition state; per-inbox queues are individually locked
/// so a 100-node cluster doesn't serialize on one mutex (see §Perf).
#[derive(Debug)]
struct BusInner {
    cfg: NetConfig,
    rng: Mutex<XorShift64>,
    inboxes: RwLock<BTreeMap<NodeId, Arc<Mutex<Inbox>>>>,
    /// Per-sender outbound queues, flushed by the sender's own thread.
    outbound: RwLock<BTreeMap<NodeId, Arc<Mutex<Outbound>>>>,
    /// group id per node; nodes in different groups are partitioned.
    /// Empty map = fully connected.
    groups: RwLock<BTreeMap<NodeId, u32>>,
    /// Transient delay/loss burst injected by the fault harness.
    faults: RwLock<FaultOverlay>,
    delivered: AtomicU64,
    dropped_partition: AtomicU64,
    dropped_loss: AtomicU64,
    dropped_no_inbox: AtomicU64,
    dropped_backpressure: AtomicU64,
    /// High-water mark of any recipient inbox depth (undelivered
    /// messages) — with `inbox_capacity > 0` this never exceeds it.
    inbox_depth_max: AtomicU64,
    /// High-water mark of any sender's per-peer outbound queue depth.
    outbound_depth_max: AtomicU64,
    /// Payload bytes enqueued toward recipients (post-drop) — the bench
    /// harness's gossip-bytes/sec source. Payloads are `Arc`-shared, so
    /// this counts logical wire bytes, not allocations.
    bytes_sent: AtomicU64,
}

/// Shared broadcast/control bus.
#[derive(Debug, Clone)]
pub struct Bus {
    clock: SimClock,
    inner: Arc<BusInner>,
}

/// Partition reachability against a groups snapshot (empty = connected).
fn reachable_in(groups: &BTreeMap<NodeId, u32>, from: NodeId, to: NodeId) -> bool {
    if groups.is_empty() {
        return true;
    }
    let gf = groups.get(&from).copied().unwrap_or(0);
    let gt = groups.get(&to).copied().unwrap_or(0);
    gf == gt
}

impl Bus {
    pub fn new(clock: SimClock, cfg: NetConfig, seed: u64) -> Self {
        Self {
            clock,
            inner: Arc::new(BusInner {
                cfg,
                rng: Mutex::new(XorShift64::new(seed)),
                inboxes: RwLock::new(BTreeMap::new()),
                outbound: RwLock::new(BTreeMap::new()),
                groups: RwLock::new(BTreeMap::new()),
                faults: RwLock::new(FaultOverlay::default()),
                delivered: AtomicU64::new(0),
                dropped_partition: AtomicU64::new(0),
                dropped_loss: AtomicU64::new(0),
                dropped_no_inbox: AtomicU64::new(0),
                dropped_backpressure: AtomicU64::new(0),
                inbox_depth_max: AtomicU64::new(0),
                outbound_depth_max: AtomicU64::new(0),
                bytes_sent: AtomicU64::new(0),
            }),
        }
    }

    /// Register a node's inbox (idempotent).
    pub fn register(&self, node: NodeId) {
        let mut inboxes = self.inner.inboxes.write().unwrap();
        inboxes.entry(node).or_default();
    }

    /// Remove a node's inbox (simulated crash drops queued messages —
    /// both its inbox and anything it had enqueued but not flushed).
    pub fn unregister(&self, node: NodeId) {
        self.inner.inboxes.write().unwrap().remove(&node);
        self.inner.outbound.write().unwrap().remove(&node);
    }

    /// Per-peer parked-queue cap: beyond this, the oldest parked message
    /// is shed (`DropStats::backpressure`). Unbounded inboxes never
    /// park, so no cap is needed there.
    fn outbound_cap(&self) -> usize {
        match self.inner.cfg.inbox_capacity {
            0 => usize::MAX,
            cap => cap.saturating_mul(4),
        }
    }

    /// This sender's outbound state, created lazily (senders need no
    /// inbox of their own — the overload bench's phantom receiver has
    /// the converse: an inbox but no outbound traffic).
    fn sender_outbound(&self, from: NodeId) -> Arc<Mutex<Outbound>> {
        if let Some(ob) = self.inner.outbound.read().unwrap().get(&from) {
            return ob.clone();
        }
        self.inner
            .outbound
            .write()
            .unwrap()
            .entry(from)
            .or_default()
            .clone()
    }

    /// Enqueue one message onto `from`'s queue toward `to`. O(1), no
    /// RNG, no recipient locks — the sender-side cost is independent of
    /// the destination's congestion.
    fn enqueue(&self, from: NodeId, to: NodeId, kind: MsgKind, payload: Arc<Vec<u8>>) {
        let sent_at = self.clock.now();
        let cap = self.outbound_cap();
        let ob = self.sender_outbound(from);
        let mut ob = ob.plane_lock();
        let q = ob.queues.entry(to).or_default();
        q.push_back(OutMsg {
            kind,
            sent_at,
            payload,
        });
        if q.len() > cap {
            // shed oldest-first: newer CRDT state subsumes older
            q.pop_front();
            self.inner.dropped_backpressure.fetch_add(1, Ordering::Relaxed);
        }
        self.inner
            .outbound_depth_max
            .fetch_max(q.len() as u64, Ordering::Relaxed);
    }

    /// Registered peers other than `from` (broadcast targets).
    fn peers_of(&self, from: NodeId) -> Vec<NodeId> {
        self.inner
            .inboxes
            .read()
            .unwrap()
            .keys()
            .copied()
            .filter(|&n| n != from)
            .collect()
    }

    /// Broadcast to all registered nodes except the sender.
    pub fn broadcast(&self, from: NodeId, kind: MsgKind, payload: Vec<u8>) {
        self.broadcast_shared(from, kind, Arc::new(payload));
    }

    /// As [`broadcast`](Self::broadcast), but the payload is already an
    /// `Arc` — the caller encoded once for the whole round and every
    /// recipient shares the same bytes (no per-recipient clone, no
    /// re-wrap). The gossip hot path — including sharded keyed-state
    /// deltas, whose shard-tagged segments ride inside the one encoded
    /// payload (`crate::shard`), so per-shard granularity costs no
    /// extra messages or allocations on the bus. Enqueue-only: the
    /// fault/delay pipeline runs at the next [`flush`](Self::flush).
    pub fn broadcast_shared(&self, from: NodeId, kind: MsgKind, payload: Arc<Vec<u8>>) {
        for to in self.peers_of(from) {
            self.enqueue(from, to, kind, payload.clone());
        }
    }

    /// Gossip-style fan-out: send to up to `fanout` random peers (the
    /// Pekko-distributed-data pattern). State-based CRDT gossip spreads
    /// transitively, so O(n·fanout) traffic converges in O(log n)
    /// rounds instead of O(n²) per round — the difference between 10
    /// and 100 nodes staying responsive (§Perf, Fig 9).
    pub fn broadcast_sample(&self, from: NodeId, kind: MsgKind, payload: Vec<u8>, fanout: usize) {
        self.broadcast_sample_shared(from, kind, Arc::new(payload), fanout);
    }

    /// `Arc`-payload variant of [`broadcast_sample`](Self::broadcast_sample):
    /// one encode per gossip round, shared across all sampled peers.
    ///
    /// Sampling is a bounded partial Fisher–Yates shuffle: exactly
    /// `fanout` RNG draws regardless of how close `fanout` is to the
    /// peer count. The previous rejection sampler ("draw until the set
    /// has `fanout` members") was a coupon-collector: with fanout near
    /// `peers.len()` its expected draw count blew up and the number of
    /// draws varied per round. Differential suites pin *outputs*, not
    /// RNG draw sequences, so the stream change is free.
    pub fn broadcast_sample_shared(
        &self,
        from: NodeId,
        kind: MsgKind,
        payload: Arc<Vec<u8>>,
        fanout: usize,
    ) {
        let mut peers = self.peers_of(from);
        if peers.is_empty() {
            return;
        }
        if fanout > 0 && fanout < peers.len() {
            let mut rng = self.inner.rng.plane_lock();
            for i in 0..fanout {
                let j = i + rng.next_below((peers.len() - i) as u64) as usize;
                peers.swap(i, j);
            }
            drop(rng);
            peers.truncate(fanout);
        }
        for &to in &peers {
            self.enqueue(from, to, kind, payload.clone());
        }
    }

    /// Point-to-point send (enqueue-only; an unregistered target counts
    /// as `DropStats::no_inbox` at flush time).
    pub fn send(&self, from: NodeId, to: NodeId, kind: MsgKind, payload: Vec<u8>) {
        self.enqueue(from, to, kind, Arc::new(payload));
    }

    /// Move `from`'s enqueued batch toward recipient inboxes: partition
    /// check per destination, loss/delay/jitter per message — all RNG
    /// work in one critical section for the whole batch — and bulk
    /// append into each inbox up to its free capacity. Messages that
    /// don't fit stay parked (in order) for the next flush; their
    /// count is returned so the caller can feed the backpressure loop.
    pub fn flush(&self, from: NodeId) -> FlushStats {
        self.flush_with(from, |_, _| {})
    }

    /// [`flush`](Self::flush) with a per-peer outcome callback: after
    /// each non-empty peer queue is processed, `on_peer(to, outcome)`
    /// reports what this batch did toward that peer. The flight
    /// recorder rides this hook to attribute gossip-round causality
    /// (who got the payload, who parked, who dropped and why) without
    /// the bus knowing anything about tracing. Called with internal
    /// locks held — keep the callback allocation-free and cheap.
    pub fn flush_with(
        &self,
        from: NodeId,
        mut on_peer: impl FnMut(NodeId, PeerFlush),
    ) -> FlushStats {
        let mut stats = FlushStats::default();
        let ob = match self.inner.outbound.read().unwrap().get(&from) {
            Some(ob) => ob.clone(),
            None => return stats,
        };
        let mut ob = ob.plane_lock();
        if ob.queues.values().all(|q| q.is_empty()) {
            return stats;
        }
        let now = self.clock.now();
        let cfg = &self.inner.cfg;
        let overlay = *self.inner.faults.read().unwrap();
        let inboxes = self.inner.inboxes.read().unwrap();
        let groups = self.inner.groups.read().unwrap().clone();
        let mut bytes = 0u64;
        // ONE RNG critical section for the whole batch (the synchronous
        // bus locked it once per message, on the sender's hot path).
        let mut rng = self.inner.rng.plane_lock();
        for (&to, q) in ob.queues.iter_mut() {
            if q.is_empty() {
                continue;
            }
            let Some(inbox) = inboxes.get(&to) else {
                self.inner
                    .dropped_no_inbox
                    .fetch_add(q.len() as u64, Ordering::Relaxed);
                on_peer(
                    to,
                    PeerFlush {
                        dropped: q.len() as u64,
                        ..PeerFlush::default()
                    },
                );
                q.clear();
                continue;
            };
            if !reachable_in(&groups, from, to) {
                self.inner
                    .dropped_partition
                    .fetch_add(q.len() as u64, Ordering::Relaxed);
                on_peer(
                    to,
                    PeerFlush {
                        dropped: q.len() as u64,
                        ..PeerFlush::default()
                    },
                );
                q.clear();
                continue;
            }
            let mut peer = PeerFlush::default();
            let mut inq = inbox.plane_lock();
            let mut free = match cfg.inbox_capacity {
                0 => usize::MAX,
                cap => cap.saturating_sub(inq.queue.len()),
            };
            while let Some(m) = q.pop_front() {
                if free == 0 {
                    q.push_front(m);
                    break;
                }
                if cfg.drop_prob > 0.0 && rng.chance(cfg.drop_prob) {
                    self.inner.dropped_loss.fetch_add(1, Ordering::Relaxed);
                    peer.dropped += 1;
                    continue;
                }
                if overlay.extra_drop_prob > 0.0 && rng.chance(overlay.extra_drop_prob) {
                    self.inner.dropped_loss.fetch_add(1, Ordering::Relaxed);
                    peer.dropped += 1;
                    continue;
                }
                let jitter = if cfg.jitter_ms > 0 {
                    rng.next_below(cfg.jitter_ms + 1)
                } else {
                    0
                } + if cfg.tail_prob > 0.0 && cfg.tail_ms > 1 && rng.chance(cfg.tail_prob) {
                    cfg.tail_ms / 2 + rng.next_below(cfg.tail_ms / 2)
                } else {
                    0
                };
                let deliver_at = now + cfg.base_delay_ms + overlay.extra_delay_ms + jitter;
                bytes += m.payload.len() as u64;
                inq.queue.push_back((
                    deliver_at,
                    Msg {
                        from,
                        kind: m.kind,
                        sent_at: m.sent_at,
                        payload: m.payload,
                    },
                ));
                free -= 1;
                stats.delivered += 1;
                peer.delivered += 1;
            }
            self.inner
                .inbox_depth_max
                .fetch_max(inq.queue.len() as u64, Ordering::Relaxed);
            stats.parked += q.len() as u64;
            peer.parked = q.len() as u64;
            on_peer(to, peer);
        }
        drop(rng);
        if bytes > 0 {
            self.inner.bytes_sent.fetch_add(bytes, Ordering::Relaxed);
        }
        stats
    }

    /// Drain all messages due for `node` at the current sim-time.
    pub fn recv(&self, node: NodeId) -> Vec<Msg> {
        let now = self.clock.now();
        let inbox = {
            let inboxes = self.inner.inboxes.read().unwrap();
            match inboxes.get(&node) {
                Some(i) => i.clone(),
                None => return Vec::new(),
            }
        };
        let mut inbox = inbox.plane_lock();
        let mut due: Vec<(SimTime, Msg)> = Vec::new();
        let mut rest = VecDeque::with_capacity(inbox.queue.len());
        while let Some((at, msg)) = inbox.queue.pop_front() {
            if at <= now {
                due.push((at, msg));
            } else {
                rest.push_back((at, msg));
            }
        }
        inbox.queue = rest;
        drop(inbox);
        // Canonical delivery order: the order messages landed in the
        // inbox depends on sender thread interleaving; sorting the due
        // set by (deliver_at, sender, send time) removes that source of
        // schedule nondeterminism (the stable sort keeps a sender's own
        // messages in send order). The simulation oracles compare runs
        // across wildly different interleavings, so delivery order must
        // be a function of message metadata, not of thread scheduling.
        due.sort_by_key(|(at, m)| (*at, m.from, m.sent_at));
        self.inner.delivered.fetch_add(due.len() as u64, Ordering::Relaxed);
        due.into_iter().map(|(_, m)| m).collect()
    }

    /// Free inbox space `node` can advertise as credits on its
    /// heartbeat (`u64::MAX` = unbounded inbox, never throttles).
    pub fn advertised_credits(&self, node: NodeId) -> u64 {
        if self.inner.cfg.inbox_capacity == 0 {
            return u64::MAX;
        }
        let inboxes = self.inner.inboxes.read().unwrap();
        match inboxes.get(&node) {
            Some(inbox) => {
                let depth = inbox.plane_lock().queue.len();
                (self.inner.cfg.inbox_capacity.saturating_sub(depth)) as u64
            }
            None => 0,
        }
    }

    /// Install a transient delay/loss burst on every subsequent flush.
    pub fn set_fault_overlay(&self, overlay: FaultOverlay) {
        *self.inner.faults.write().unwrap() = overlay;
    }

    /// End any delay/loss burst (back to the steady-state `NetConfig`).
    pub fn clear_fault_overlay(&self) {
        *self.inner.faults.write().unwrap() = FaultOverlay::default();
    }

    /// Impose a network partition: nodes listed in different groups
    /// cannot exchange messages. Nodes not listed join group 0.
    pub fn set_partition(&self, groups: &[&[NodeId]]) {
        let mut g = self.inner.groups.write().unwrap();
        g.clear();
        for (gid, members) in groups.iter().enumerate() {
            for &n in *members {
                g.insert(n, gid as u32 + 1);
            }
        }
    }

    /// Heal all network partitions.
    pub fn heal_partition(&self) {
        self.inner.groups.write().unwrap().clear();
    }

    /// (delivered, dropped) counters — for tests and the bench reports.
    /// `dropped` is the sum over all causes (see [`drop_stats`](Self::drop_stats)
    /// for the split), preserving the pre-split counter's meaning.
    pub fn stats(&self) -> (u64, u64) {
        (
            self.inner.delivered.load(Ordering::Acquire),
            self.drop_stats().total(),
        )
    }

    /// Dropped messages split by cause.
    pub fn drop_stats(&self) -> DropStats {
        DropStats {
            partition: self.inner.dropped_partition.load(Ordering::Acquire),
            loss: self.inner.dropped_loss.load(Ordering::Acquire),
            no_inbox: self.inner.dropped_no_inbox.load(Ordering::Acquire),
            backpressure: self.inner.dropped_backpressure.load(Ordering::Acquire),
        }
    }

    /// High-water mark of any recipient inbox depth (undelivered
    /// messages). With `inbox_capacity > 0` this is ≤ the capacity — the
    /// bounded-memory guarantee the backpressure tests pin.
    pub fn inbox_depth_max(&self) -> u64 {
        self.inner.inbox_depth_max.load(Ordering::Acquire)
    }

    /// High-water mark of any sender's per-peer outbound queue depth.
    pub fn outbound_depth_max(&self) -> u64 {
        self.inner.outbound_depth_max.load(Ordering::Acquire)
    }

    /// Payload bytes enqueued toward recipients so far (logical wire
    /// volume; dropped messages are excluded).
    pub fn bytes_sent(&self) -> u64 {
        self.inner.bytes_sent.load(Ordering::Acquire)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn bus(clock: &SimClock) -> Bus {
        bus_with_capacity(clock, 0)
    }

    fn bus_with_capacity(clock: &SimClock, inbox_capacity: usize) -> Bus {
        Bus::new(
            clock.clone(),
            NetConfig {
                base_delay_ms: 10,
                jitter_ms: 0,
                drop_prob: 0.0,
                tail_prob: 0.0,
                tail_ms: 0,
                inbox_capacity,
            },
            7,
        )
    }

    #[test]
    fn delivery_respects_delay() {
        let clock = SimClock::manual();
        let b = bus(&clock);
        b.register(1);
        b.register(2);
        b.send(1, 2, MsgKind::Gossip, vec![42]);
        assert!(b.recv(2).is_empty()); // not flushed yet
        b.flush(1);
        assert!(b.recv(2).is_empty()); // not due yet
        clock.advance(10);
        let msgs = b.recv(2);
        assert_eq!(msgs.len(), 1);
        assert_eq!(*msgs[0].payload, vec![42]);
        assert_eq!(msgs[0].from, 1);
    }

    #[test]
    fn send_is_enqueue_only_until_flush() {
        let clock = SimClock::manual();
        let b = bus(&clock);
        b.register(1);
        b.register(2);
        b.send(1, 2, MsgKind::Gossip, vec![1]);
        clock.advance(100);
        // never flushed: nothing ever arrives, no drop recorded either
        assert!(b.recv(2).is_empty());
        assert_eq!(b.stats(), (0, 0));
        // flush moves it; delay counts from flush time
        let fl = b.flush(1);
        assert_eq!(fl, FlushStats { delivered: 1, parked: 0 });
        clock.advance(10);
        assert_eq!(b.recv(2).len(), 1);
    }

    #[test]
    fn broadcast_reaches_everyone_but_sender() {
        let clock = SimClock::manual();
        let b = bus(&clock);
        for n in 1..=3 {
            b.register(n);
        }
        b.broadcast(1, MsgKind::Heartbeat, vec![]);
        b.flush(1);
        clock.advance(10);
        assert!(b.recv(1).is_empty());
        assert_eq!(b.recv(2).len(), 1);
        assert_eq!(b.recv(3).len(), 1);
    }

    #[test]
    fn partition_blocks_cross_group_traffic() {
        let clock = SimClock::manual();
        let b = bus(&clock);
        for n in 1..=4 {
            b.register(n);
        }
        b.set_partition(&[&[1, 2], &[3, 4]]);
        b.broadcast(1, MsgKind::Gossip, vec![]);
        b.flush(1);
        clock.advance(10);
        assert_eq!(b.recv(2).len(), 1);
        assert!(b.recv(3).is_empty());
        assert!(b.recv(4).is_empty());
        assert_eq!(b.drop_stats().partition, 2);
        b.heal_partition();
        b.broadcast(1, MsgKind::Gossip, vec![]);
        b.flush(1);
        clock.advance(10);
        assert_eq!(b.recv(3).len(), 1);
    }

    #[test]
    fn drop_prob_loses_messages() {
        let clock = SimClock::manual();
        let b = Bus::new(
            clock.clone(),
            NetConfig {
                base_delay_ms: 0,
                jitter_ms: 0,
                drop_prob: 1.0,
                tail_prob: 0.0,
                tail_ms: 0,
                inbox_capacity: 0,
            },
            9,
        );
        b.register(1);
        b.register(2);
        b.send(1, 2, MsgKind::Gossip, vec![]);
        b.flush(1);
        clock.advance(1);
        assert!(b.recv(2).is_empty());
        assert_eq!(b.stats().1, 1);
        // the split attributes it to loss, not partition/churn
        assert_eq!(b.drop_stats().loss, 1);
        assert_eq!(b.drop_stats().partition, 0);
        assert_eq!(b.drop_stats().no_inbox, 0);
    }

    #[test]
    fn unregistered_target_counts_as_drop() {
        let clock = SimClock::manual();
        let b = bus(&clock);
        b.register(1);
        b.send(1, 99, MsgKind::Claim, vec![]);
        b.flush(1);
        assert_eq!(b.stats().1, 1);
        assert_eq!(b.drop_stats().no_inbox, 1);
        assert_eq!(b.drop_stats().loss, 0);
    }

    /// Regression (drop accounting): the three non-backpressure causes
    /// were a single `dropped` counter, so restart churn and partitions
    /// masqueraded as network loss in metrics and sim triage. Each
    /// cause must land in its own counter while the sum keeps the old
    /// counter's meaning.
    #[test]
    fn drop_causes_are_split_and_sum_preserved() {
        let clock = SimClock::manual();
        let b = bus(&clock);
        for n in 1..=3 {
            b.register(n);
        }
        // cause 1: partition
        b.set_partition(&[&[1], &[2, 3]]);
        b.send(1, 2, MsgKind::Gossip, vec![]);
        b.flush(1);
        // cause 2: no inbox (node 3 crashed between enqueue and flush)
        b.heal_partition();
        b.send(1, 3, MsgKind::Gossip, vec![]);
        b.unregister(3);
        b.flush(1);
        let d = b.drop_stats();
        assert_eq!(d.partition, 1);
        assert_eq!(d.no_inbox, 1);
        assert_eq!(d.loss, 0);
        assert_eq!(d.backpressure, 0);
        assert_eq!(b.stats().1, d.total());
        assert_eq!(d.total(), 2);
    }

    /// `flush_with` reports one outcome per non-empty peer queue and
    /// agrees with both the returned `FlushStats` and the drop split.
    #[test]
    fn flush_with_reports_per_peer_outcomes() {
        let clock = SimClock::manual();
        let b = bus_with_capacity(&clock, 1);
        for n in 1..=4 {
            b.register(n);
        }
        // peer 2: healthy but capacity 1 → 1 delivered, 1 parked.
        b.send(1, 2, MsgKind::Gossip, vec![1]);
        b.send(1, 2, MsgKind::Gossip, vec![2]);
        // peer 3: partitioned away → dropped.
        b.set_partition(&[&[1, 2, 4], &[3]]);
        b.send(1, 3, MsgKind::Gossip, vec![3]);
        // peer 4: nothing enqueued → no callback at all.
        let mut seen: Vec<(NodeId, PeerFlush)> = Vec::new();
        let stats = b.flush_with(1, |to, pf| seen.push((to, pf)));
        seen.sort_by_key(|(to, _)| *to);
        assert_eq!(
            seen,
            vec![
                (2, PeerFlush { delivered: 1, parked: 1, dropped: 0 }),
                (3, PeerFlush { delivered: 0, parked: 0, dropped: 1 }),
            ]
        );
        assert_eq!(stats, FlushStats { delivered: 1, parked: 1 });
        assert_eq!(b.drop_stats().partition, 1);
    }

    #[test]
    fn fault_overlay_adds_delay_and_loss() {
        let clock = SimClock::manual();
        let b = bus(&clock); // base delay 10
        b.register(1);
        b.register(2);
        b.set_fault_overlay(FaultOverlay {
            extra_delay_ms: 40,
            extra_drop_prob: 0.0,
        });
        b.send(1, 2, MsgKind::Gossip, vec![7]);
        b.flush(1);
        clock.advance(10);
        assert!(b.recv(2).is_empty()); // base delay alone is not enough
        clock.advance(40);
        assert_eq!(b.recv(2).len(), 1);

        b.set_fault_overlay(FaultOverlay {
            extra_delay_ms: 0,
            extra_drop_prob: 1.0,
        });
        b.send(1, 2, MsgKind::Gossip, vec![8]);
        b.flush(1);
        clock.advance(100);
        assert!(b.recv(2).is_empty());
        assert_eq!(b.stats().1, 1);
        assert_eq!(b.drop_stats().loss, 1);

        // messages flushed during a burst keep their (delayed) schedule,
        // but new messages after clear() are back to normal
        b.clear_fault_overlay();
        b.send(1, 2, MsgKind::Gossip, vec![9]);
        b.flush(1);
        clock.advance(10);
        assert_eq!(b.recv(2).len(), 1);
    }

    /// The overlay rides the *flush* step: a message enqueued before a
    /// burst but flushed during it sees the burst.
    #[test]
    fn fault_overlay_applies_at_flush_time() {
        let clock = SimClock::manual();
        let b = bus(&clock);
        b.register(1);
        b.register(2);
        b.send(1, 2, MsgKind::Gossip, vec![1]); // enqueued pre-burst
        b.set_fault_overlay(FaultOverlay {
            extra_delay_ms: 0,
            extra_drop_prob: 1.0,
        });
        b.flush(1); // flushed mid-burst → lost
        clock.advance(50);
        assert!(b.recv(2).is_empty());
        assert_eq!(b.drop_stats().loss, 1);
    }

    #[test]
    fn shared_broadcast_shares_one_payload_and_counts_bytes() {
        let clock = SimClock::manual();
        let b = bus(&clock);
        for n in 1..=4 {
            b.register(n);
        }
        let payload = Arc::new(vec![1u8, 2, 3]);
        b.broadcast_shared(1, MsgKind::Gossip, payload.clone());
        assert_eq!(b.bytes_sent(), 0); // enqueue-only: no wire volume yet
        b.flush(1);
        // 3 recipients × 3 bytes of logical wire volume, one allocation
        assert_eq!(b.bytes_sent(), 9);
        clock.advance(10);
        for n in 2..=4 {
            let msgs = b.recv(n);
            assert_eq!(msgs.len(), 1);
            // recipients alias the sender's buffer (no copy)
            assert!(Arc::ptr_eq(&msgs[0].payload, &payload));
        }
    }

    #[test]
    fn sampled_shared_broadcast_respects_fanout() {
        let clock = SimClock::manual();
        let b = bus(&clock);
        for n in 1..=5 {
            b.register(n);
        }
        b.broadcast_sample_shared(1, MsgKind::Gossip, Arc::new(vec![7, 7]), 2);
        b.flush(1);
        assert_eq!(b.bytes_sent(), 4); // 2 peers × 2 bytes
        clock.advance(10);
        let got: usize = (2..=5).map(|n| b.recv(n).len()).sum();
        assert_eq!(got, 2);
    }

    /// Regression (coupon-collector sampling): the old sampler drew
    /// from the RNG *until* the chosen set reached `fanout`, so with
    /// fanout = peers - 1 the expected draw count blew up and varied
    /// per round. The partial Fisher–Yates replacement makes exactly
    /// `fanout` draws; this pins the bounded draw count by checking the
    /// RNG stream position after sampling (two buses with the same
    /// seed must consume the same number of draws regardless of how
    /// many collisions a rejection sampler would have hit).
    #[test]
    fn fanout_sampling_is_bounded_and_exact() {
        let clock = SimClock::manual();
        // fanout = peers - 1: worst case for the rejection sampler
        for fanout in 1..=4usize {
            let b = bus(&clock);
            for n in 1..=6 {
                b.register(n);
            }
            b.broadcast_sample_shared(1, MsgKind::Gossip, Arc::new(vec![1]), fanout);
            b.flush(1);
            clock.advance(10);
            let got: usize = (2..=6).map(|n| b.recv(n).len()).sum();
            assert_eq!(got, fanout, "exactly {fanout} distinct peers sampled");
        }
        // fanout 0 and >= peers: broadcast to all, no RNG at all
        let b = bus(&clock);
        for n in 1..=4 {
            b.register(n);
        }
        b.broadcast_sample_shared(1, MsgKind::Gossip, Arc::new(vec![1]), 0);
        b.broadcast_sample_shared(1, MsgKind::Gossip, Arc::new(vec![2]), 9);
        b.flush(1);
        clock.advance(10);
        for n in 2..=4 {
            assert_eq!(b.recv(n).len(), 2);
        }
    }

    #[test]
    fn recv_orders_due_messages_canonically() {
        let clock = SimClock::manual();
        let b = bus(&clock);
        for n in 1..=3 {
            b.register(n);
        }
        // same deliver_at from two senders; recv must order by sender id
        // regardless of push order
        b.send(3, 1, MsgKind::Gossip, vec![3]);
        b.send(2, 1, MsgKind::Gossip, vec![2]);
        b.flush(3);
        b.flush(2);
        clock.advance(10);
        let msgs = b.recv(1);
        assert_eq!(msgs.len(), 2);
        assert_eq!(msgs[0].from, 2);
        assert_eq!(msgs[1].from, 3);
    }

    #[test]
    fn messages_stay_queued_until_due() {
        let clock = SimClock::manual();
        let b = bus(&clock);
        b.register(1);
        b.register(2);
        b.send(1, 2, MsgKind::Gossip, vec![1]);
        b.flush(1);
        clock.advance(5);
        b.send(1, 2, MsgKind::Gossip, vec![2]);
        b.flush(1);
        clock.advance(5);
        // first due (t=10), second not (t=15)
        let msgs = b.recv(2);
        assert_eq!(msgs.len(), 1);
        clock.advance(5);
        assert_eq!(b.recv(2).len(), 1);
    }

    /// Backpressure: a full inbox parks the overflow on the sender's
    /// outbound queue instead of growing without bound, and the parked
    /// messages deliver (in order) once the receiver drains.
    #[test]
    fn full_inbox_parks_overflow_until_receiver_drains() {
        let clock = SimClock::manual();
        let b = bus_with_capacity(&clock, 2);
        b.register(1);
        b.register(2);
        for i in 0..5u8 {
            b.send(1, 2, MsgKind::Gossip, vec![i]);
        }
        let fl = b.flush(1);
        assert_eq!(fl, FlushStats { delivered: 2, parked: 3 });
        assert_eq!(b.inbox_depth_max(), 2);
        clock.advance(10);
        let first: Vec<u8> = b.recv(2).iter().map(|m| m.payload[0]).collect();
        assert_eq!(first, [0, 1]);
        // drained: the next flush moves the parked remainder, in order
        let fl = b.flush(1);
        assert_eq!(fl, FlushStats { delivered: 2, parked: 1 });
        clock.advance(10);
        let second: Vec<u8> = b.recv(2).iter().map(|m| m.payload[0]).collect();
        assert_eq!(second, [2, 3]);
        let fl = b.flush(1);
        assert_eq!(fl, FlushStats { delivered: 1, parked: 0 });
        // nothing was dropped: parking is bounded lag, not loss
        assert_eq!(b.stats().1, 0);
        // and the cap held the whole time
        assert!(b.inbox_depth_max() <= 2);
    }

    /// A stalled peer never blocks or steals delivery from healthy
    /// peers in the same flush — the sender-side cost of a slow
    /// receiver is parking, not stalling.
    #[test]
    fn stalled_peer_does_not_block_healthy_peers() {
        let clock = SimClock::manual();
        let b = bus_with_capacity(&clock, 1);
        for n in 1..=3 {
            b.register(n);
        }
        // saturate peer 2's inbox
        b.send(1, 2, MsgKind::Gossip, vec![0]);
        b.flush(1);
        // broadcast: peer 2 is full, peer 3 is healthy
        b.broadcast(1, MsgKind::Gossip, vec![1]);
        let fl = b.flush(1);
        assert_eq!(fl.parked, 1); // peer 2's copy parked
        assert_eq!(fl.delivered, 1); // peer 3's copy delivered
        clock.advance(10);
        assert_eq!(b.recv(3).len(), 1);
    }

    /// The parked-queue cap sheds oldest-first and counts it as a
    /// backpressure drop, bounding sender-side memory too.
    #[test]
    fn outbound_cap_sheds_oldest_as_backpressure_drop() {
        let clock = SimClock::manual();
        let b = bus_with_capacity(&clock, 1); // outbound cap = 4
        b.register(1);
        b.register(2);
        for i in 0..6u8 {
            b.send(1, 2, MsgKind::Gossip, vec![i]);
        }
        // queue held at 4: messages 0 and 1 were shed
        assert_eq!(b.drop_stats().backpressure, 2);
        assert!(b.outbound_depth_max() >= 4);
        b.flush(1);
        clock.advance(10);
        let got: Vec<u8> = b.recv(2).iter().map(|m| m.payload[0]).collect();
        assert_eq!(got, [2]); // oldest survivor delivered first
    }

    #[test]
    fn advertised_credits_track_free_inbox_space() {
        let clock = SimClock::manual();
        let b = bus_with_capacity(&clock, 3);
        b.register(1);
        b.register(2);
        assert_eq!(b.advertised_credits(2), 3);
        b.send(1, 2, MsgKind::Gossip, vec![0]);
        b.send(1, 2, MsgKind::Gossip, vec![1]);
        b.flush(1);
        assert_eq!(b.advertised_credits(2), 1);
        clock.advance(10);
        b.recv(2);
        assert_eq!(b.advertised_credits(2), 3);
        // unbounded inboxes never throttle
        let ub = bus(&clock);
        ub.register(1);
        assert_eq!(ub.advertised_credits(1), u64::MAX);
        // no inbox → no credits
        assert_eq!(b.advertised_credits(99), 0);
    }
}
