//! State-based CRDTs (join-semilattices).
//!
//! The paper wraps Akka/Pekko Distributed Data CRDTs; we implement our
//! own. Every type here is a *state-based* CRDT: replicas synchronize by
//! exchanging full state and joining with [`Crdt::merge`], which must be
//! commutative, associative and idempotent (verified by the property
//! tests in `rust/tests/properties.rs` and unit tests per module).
//!
//! Contributor tagging: the Holon execution model keys contributions by
//! *partition*. Counters and registers therefore take a `contributor`
//! argument on update; a partition's contribution is deterministic given
//! its input prefix, which is what makes double-processing after work
//! stealing idempotent (paper §4.3).

mod agg;
mod counter;
mod map;
mod register;
mod set;
mod topk;

pub use agg::PrefixAgg;
pub use counter::{GCounter, PNCounter};
pub use map::MapCrdt;
pub use register::{LwwRegister, MaxRegister, MinRegister};
pub use set::{GSet, ORSet, TwoPSet};
pub use topk::BoundedTopK;

use crate::codec::{Decode, Encode};

/// What a join did to its target — the central currency of delta
/// synchronization (Crdt trait v3).
///
/// Delta-state CRDT theory (Almeida et al.) observes that a join can
/// report *inflation* for free: it already compares every piece of
/// incoming state against the local lattice position. Reporting it is
/// what confines dirty-marking to genuine changes — a replica that
/// receives a full-sync payload it already subsumes must not re-mark
/// (and re-ship) its whole state on the next delta round.
///
/// Contract (checked by the `merge_outcome_*` property suites): a merge
/// returns [`Changed`](MergeOutcome::Changed) **iff** the target state
/// actually differs afterwards (per `PartialEq`). In particular,
/// re-merging the same state is always `Unchanged` (idempotence).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq, PartialOrd, Ord, Hash)]
#[must_use = "the merge outcome drives dirty-marking; discard it explicitly with `let _ =` if unneeded"]
pub enum MergeOutcome {
    /// The join was a no-op: the target already subsumed `other`.
    #[default]
    Unchanged,
    /// The target inflated (gained information it did not have).
    Changed,
}

impl MergeOutcome {
    /// `Changed` iff the flag is set.
    pub fn changed_if(changed: bool) -> Self {
        if changed {
            MergeOutcome::Changed
        } else {
            MergeOutcome::Unchanged
        }
    }

    pub fn is_changed(self) -> bool {
        self == MergeOutcome::Changed
    }
}

/// Outcomes combine like the joins they describe: any changed part
/// changes the whole.
impl std::ops::BitOr for MergeOutcome {
    type Output = MergeOutcome;

    fn bitor(self, rhs: Self) -> Self {
        MergeOutcome::changed_if(self.is_changed() || rhs.is_changed())
    }
}

impl std::ops::BitOrAssign for MergeOutcome {
    fn bitor_assign(&mut self, rhs: Self) {
        *self = *self | rhs;
    }
}

/// A state-based CRDT: a join-semilattice with a bottom element
/// (`Default::default()`) and a join ([`merge`](Crdt::merge)).
///
/// Laws (checked by tests):
/// * commutativity: `a ⊔ b == b ⊔ a`
/// * associativity: `(a ⊔ b) ⊔ c == a ⊔ (b ⊔ c)`
/// * idempotence:   `a ⊔ a == a`
/// * identity:      `a ⊔ ⊥ == a`
/// * change reporting: `merge` returns [`MergeOutcome::Changed`] iff the
///   target actually differs afterwards
pub trait Crdt: Clone + Default + Send + Encode + Decode + 'static {
    /// Join this replica with another (least upper bound), reporting
    /// whether the join inflated `self`. Keyed compositions additionally
    /// expose per-unit changed-sets via their `merge_report` hooks
    /// ([`MapCrdt::merge_report`], [`crate::shard::ShardedMapCrdt::merge_report`]).
    fn merge(&mut self, other: &Self) -> MergeOutcome;

    /// Project the sub-state contributed by `contributor` (a partition
    /// id) — used to build minimal checkpoint slices. The default
    /// (a full clone) is always *correct* (merge is idempotent), just
    /// larger; contributor-tagged types override it.
    fn project(&self, _contributor: u64) -> Self {
        self.clone()
    }

    /// `self ⊑ other` — lattice order; default derives it from merge on
    /// `Eq` types via `other == self ⊔ other` where possible. Types
    /// override this with a cheaper direct check.
    fn merged(mut self, other: &Self) -> Self
    where
        Self: Sized,
    {
        // lint:allow(discarded-merge): by-value lattice-join helper — the merged state itself is the result; the outcome is recoverable by comparing with the input
        let _ = self.merge(other);
        self
    }

    /// Delta hook for composed sync: a partial state carrying everything
    /// changed since the previous call, clearing any internal dirty
    /// markers. The default — a full clone, clearing nothing — is always
    /// correct (any CRDT state is its own valid delta); types with
    /// internal dirty tracking ([`crate::shard::ShardedMapCrdt`])
    /// override it so a containing
    /// [`WindowedCrdt`](crate::wcrdt::WindowedCrdt) delta ships only the
    /// changed sub-state.
    fn take_delta(&mut self) -> Self {
        self.clone()
    }

    /// Drop internal dirty markers without building a delta (a full-state
    /// observer has seen everything). No-op by default.
    fn mark_clean(&mut self) {}

    /// Drain this value's delta into `dst` by reference — semantically
    /// `dst.merge(&self.take_delta())` without materializing the delta —
    /// reporting whether `dst` inflated. The default merges the full
    /// state (for types without dirty tracking the delta *is* the full
    /// state, and merging by reference costs no clone);
    /// [`crate::shard::ShardedMapCrdt`] overrides it to merge only its
    /// dirty shards. The engine's per-batch own-contribution→replica
    /// join runs through this, so it must stay allocation-free on the
    /// default path.
    fn join_delta_into(&mut self, dst: &mut Self) -> MergeOutcome {
        let outcome = dst.merge(self);
        self.mark_clean();
        outcome
    }
}

/// Join an iterator of CRDT states into one (fold over ⊔ from ⊥).
pub fn join_all<C: Crdt, I: IntoIterator<Item = C>>(iter: I) -> C {
    let mut acc = C::default();
    for x in iter {
        // lint:allow(discarded-merge): folding from ⊥ — the accumulator is under construction and every input is expected to inflate or no-op freely
        let _ = acc.merge(&x);
    }
    acc
}

#[cfg(test)]
pub(crate) mod lawcheck {
    //! Reusable lattice-law checker used by each CRDT's unit tests.
    use super::{Crdt, MergeOutcome};

    /// The trait-v3 contract: `merge -> Changed` iff the target actually
    /// differs afterwards, and an immediate re-merge is always a no-op.
    pub fn check_merge_outcome<C: Crdt + PartialEq + std::fmt::Debug>(samples: &[C]) {
        for a in samples {
            for b in samples {
                let mut t = a.clone();
                let outcome = t.merge(b);
                assert_eq!(
                    outcome.is_changed(),
                    &t != a,
                    "merge must report Changed iff the target differs \
                     (target {a:?}, source {b:?}, result {t:?})"
                );
                let settled = t.clone();
                assert_eq!(
                    t.merge(b),
                    MergeOutcome::Unchanged,
                    "re-merging the same state must be a no-op"
                );
                assert_eq!(t, settled);
            }
        }
    }

    pub fn check_laws<C: Crdt + PartialEq + std::fmt::Debug>(samples: &[C]) {
        for a in samples {
            // idempotence
            assert_eq!(a.clone().merged(a), a.clone(), "idempotence");
            // identity
            assert_eq!(C::default().merged(a), a.clone(), "left identity");
            assert_eq!(a.clone().merged(&C::default()), a.clone(), "right identity");
            for b in samples {
                // commutativity
                assert_eq!(
                    a.clone().merged(b),
                    b.clone().merged(a),
                    "commutativity"
                );
                for c in samples {
                    // associativity
                    assert_eq!(
                        a.clone().merged(b).merged(c),
                        a.clone().merged(&b.clone().merged(c)),
                        "associativity"
                    );
                }
            }
        }
    }

    pub fn check_codec_roundtrip<C>(samples: &[C])
    where
        C: Crdt + PartialEq + std::fmt::Debug,
    {
        for s in samples {
            let b = s.to_bytes();
            assert_eq!(&C::from_bytes(&b).unwrap(), s);
        }
    }
}
