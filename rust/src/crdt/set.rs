//! Set CRDTs: GSet, TwoPSet, ORSet.

use std::collections::BTreeMap;
use std::collections::BTreeSet;

use super::{Crdt, MergeOutcome};
use crate::codec::{Decode, DecodeResult, Encode, Reader, Writer};

/// Grow-only set; join = union.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct GSet<T: Ord + Clone> {
    items: BTreeSet<T>,
}

impl<T: Ord + Clone> Default for GSet<T> {
    fn default() -> Self {
        Self {
            items: BTreeSet::new(),
        }
    }
}

impl<T: Ord + Clone> GSet<T> {
    pub fn new() -> Self {
        Self {
            items: BTreeSet::new(),
        }
    }

    pub fn insert(&mut self, item: T) {
        self.items.insert(item);
    }

    pub fn contains(&self, item: &T) -> bool {
        self.items.contains(item)
    }

    pub fn len(&self) -> usize {
        self.items.len()
    }

    pub fn is_empty(&self) -> bool {
        self.items.is_empty()
    }

    pub fn iter(&self) -> impl Iterator<Item = &T> {
        self.items.iter()
    }
}

impl<T: Ord + Clone + Send + Encode + Decode + 'static> Crdt for GSet<T> {
    fn merge(&mut self, other: &Self) -> MergeOutcome {
        let mut changed = false;
        for x in &other.items {
            // probe before cloning: the steady-state merge (warmed-up
            // replicas) carries mostly-present items
            if !self.items.contains(x) {
                self.items.insert(x.clone());
                changed = true;
            }
        }
        MergeOutcome::changed_if(changed)
    }
}

impl<T: Ord + Clone + Encode> Encode for GSet<T> {
    fn encode(&self, w: &mut Writer) {
        w.put_u32(self.items.len() as u32);
        for x in &self.items {
            x.encode(w);
        }
    }
}

impl<T: Ord + Clone + Decode> Decode for GSet<T> {
    fn decode(r: &mut Reader) -> DecodeResult<Self> {
        let n = r.get_u32()? as usize;
        let mut items = BTreeSet::new();
        for _ in 0..n {
            items.insert(T::decode(r)?);
        }
        Ok(GSet { items })
    }
}

/// Two-phase set: add once, remove once, never re-add.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct TwoPSet<T: Ord + Clone> {
    added: GSet<T>,
    removed: GSet<T>,
}

impl<T: Ord + Clone> Default for TwoPSet<T> {
    fn default() -> Self {
        Self {
            added: GSet::default(),
            removed: GSet::default(),
        }
    }
}

impl<T: Ord + Clone + Send + Encode + Decode + 'static> TwoPSet<T> {
    pub fn new() -> Self {
        Self {
            added: GSet::new(),
            removed: GSet::new(),
        }
    }

    pub fn insert(&mut self, item: T) {
        self.added.insert(item);
    }

    /// Remove wins over add, permanently (2P-set semantics).
    pub fn remove(&mut self, item: T) {
        self.removed.insert(item);
    }

    pub fn contains(&self, item: &T) -> bool {
        self.added.contains(item) && !self.removed.contains(item)
    }

    pub fn live_len(&self) -> usize {
        self.added.iter().filter(|x| !self.removed.contains(x)).count()
    }
}

impl<T: Ord + Clone + Send + Encode + Decode + 'static> Crdt for TwoPSet<T> {
    fn merge(&mut self, other: &Self) -> MergeOutcome {
        self.added.merge(&other.added) | self.removed.merge(&other.removed)
    }
}

impl<T: Ord + Clone + Encode> Encode for TwoPSet<T> {
    fn encode(&self, w: &mut Writer) {
        self.added.encode(w);
        self.removed.encode(w);
    }
}

impl<T: Ord + Clone + Decode> Decode for TwoPSet<T> {
    fn decode(r: &mut Reader) -> DecodeResult<Self> {
        Ok(TwoPSet {
            added: GSet::decode(r)?,
            removed: GSet::decode(r)?,
        })
    }
}

/// Observed-remove set with (contributor, seq) unique tags. Re-adding
/// after removal works (unlike [`TwoPSet`]); removal only affects tags
/// observed at the removing replica.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ORSet<T: Ord + Clone> {
    /// live tags per element
    entries: BTreeMap<T, BTreeSet<(u64, u64)>>,
    /// tombstoned tags per element
    tombs: BTreeMap<T, BTreeSet<(u64, u64)>>,
    /// next sequence number per contributor (local metadata, merged by max)
    seqs: BTreeMap<u64, u64>,
}

impl<T: Ord + Clone> Default for ORSet<T> {
    fn default() -> Self {
        Self {
            entries: BTreeMap::new(),
            tombs: BTreeMap::new(),
            seqs: BTreeMap::new(),
        }
    }
}

impl<T: Ord + Clone + Send + Encode + Decode + 'static> ORSet<T> {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn insert(&mut self, contributor: u64, item: T) {
        let seq = self.seqs.entry(contributor).or_insert(0);
        *seq += 1;
        let tag = (contributor, *seq);
        self.entries.entry(item).or_default().insert(tag);
    }

    /// Remove all currently observed tags of `item`.
    pub fn remove(&mut self, item: &T) {
        if let Some(tags) = self.entries.get(item) {
            let observed: BTreeSet<_> = tags.clone();
            self.tombs.entry(item.clone()).or_default().extend(observed);
        }
    }

    pub fn contains(&self, item: &T) -> bool {
        match self.entries.get(item) {
            None => false,
            Some(tags) => {
                let empty = BTreeSet::new();
                let dead = self.tombs.get(item).unwrap_or(&empty);
                tags.iter().any(|t| !dead.contains(t))
            }
        }
    }

    pub fn live_elements(&self) -> Vec<&T> {
        self.entries
            .keys()
            .filter(|k| self.contains(k))
            .collect()
    }
}

impl<T: Ord + Clone + Send + Encode + Decode + 'static> Crdt for ORSet<T> {
    fn merge(&mut self, other: &Self) -> MergeOutcome {
        fn union_tags<T: Ord + Clone>(
            dst: &mut BTreeMap<T, BTreeSet<(u64, u64)>>,
            src: &BTreeMap<T, BTreeSet<(u64, u64)>>,
        ) -> bool {
            let mut changed = false;
            for (k, tags) in src {
                match dst.get_mut(k) {
                    Some(mine) => {
                        for &t in tags {
                            changed |= mine.insert(t);
                        }
                    }
                    None => {
                        dst.insert(k.clone(), tags.clone());
                        changed = true;
                    }
                }
            }
            changed
        }
        let mut changed = union_tags(&mut self.entries, &other.entries);
        changed |= union_tags(&mut self.tombs, &other.tombs);
        for (&c, &s) in &other.seqs {
            match self.seqs.get_mut(&c) {
                Some(e) => {
                    if s > *e {
                        *e = s;
                        changed = true;
                    }
                }
                None => {
                    self.seqs.insert(c, s);
                    changed = true;
                }
            }
        }
        MergeOutcome::changed_if(changed)
    }
}

impl<T: Ord + Clone + Encode> Encode for ORSet<T> {
    fn encode(&self, w: &mut Writer) {
        w.put_u32(self.entries.len() as u32);
        for (k, tags) in &self.entries {
            k.encode(w);
            let v: Vec<(u64, u64)> = tags.iter().copied().collect();
            v.encode(w);
        }
        w.put_u32(self.tombs.len() as u32);
        for (k, tags) in &self.tombs {
            k.encode(w);
            let v: Vec<(u64, u64)> = tags.iter().copied().collect();
            v.encode(w);
        }
        self.seqs.encode(w);
    }
}

impl<T: Ord + Clone + Decode> Decode for ORSet<T> {
    fn decode(r: &mut Reader) -> DecodeResult<Self> {
        let mut entries = BTreeMap::new();
        let n = r.get_u32()? as usize;
        for _ in 0..n {
            let k = T::decode(r)?;
            let tags: Vec<(u64, u64)> = Vec::decode(r)?;
            entries.insert(k, tags.into_iter().collect());
        }
        let mut tombs = BTreeMap::new();
        let n = r.get_u32()? as usize;
        for _ in 0..n {
            let k = T::decode(r)?;
            let tags: Vec<(u64, u64)> = Vec::decode(r)?;
            tombs.insert(k, tags.into_iter().collect());
        }
        Ok(ORSet {
            entries,
            tombs,
            seqs: BTreeMap::decode(r)?,
        })
    }
}

// lint:allow-tests(discarded-merge): law-check tests merge for effect; outcomes are asserted by check_merge_outcome
#[cfg(test)]
mod tests {
    use super::*;
    use crate::crdt::lawcheck::{check_codec_roundtrip, check_laws, check_merge_outcome};

    fn gsamples() -> Vec<GSet<u64>> {
        let mut a = GSet::new();
        a.insert(1);
        a.insert(2);
        let mut b = GSet::new();
        b.insert(2);
        b.insert(3);
        vec![GSet::new(), a, b]
    }

    #[test]
    fn gset_laws() {
        check_laws(&gsamples());
    }

    #[test]
    fn gset_codec() {
        check_codec_roundtrip(&gsamples());
    }

    #[test]
    fn gset_merge_is_union() {
        let mut s = gsamples().remove(1);
        assert_eq!(s.merge(&gsamples()[2]), MergeOutcome::Changed);
        assert_eq!(s.len(), 3);
        // the union already holds both partners: further merges are no-ops
        assert_eq!(s.merge(&gsamples()[1]), MergeOutcome::Unchanged);
        check_merge_outcome(&gsamples());
    }

    #[test]
    fn twopset_remove_wins() {
        let mut a = TwoPSet::new();
        a.insert(1u64);
        let mut b = a.clone();
        b.remove(1);
        assert_eq!(a.merge(&b), MergeOutcome::Changed); // tombstone arrived
        assert!(!a.contains(&1));
        // re-add cannot resurrect
        a.insert(1);
        assert!(!a.contains(&1));
        assert_eq!(a.live_len(), 0);
    }

    #[test]
    fn twopset_laws() {
        let mut a = TwoPSet::new();
        a.insert(1u64);
        let mut b = TwoPSet::new();
        b.insert(1);
        b.remove(1);
        let mut c = TwoPSet::new();
        c.insert(2);
        check_laws(&[TwoPSet::new(), a.clone(), b.clone(), c.clone()]);
        check_merge_outcome(&[TwoPSet::new(), a, b, c]);
    }

    #[test]
    fn orset_readd_after_remove() {
        let mut a = ORSet::new();
        a.insert(1, "x".to_string());
        a.remove(&"x".to_string());
        assert!(!a.contains(&"x".to_string()));
        a.insert(1, "x".to_string());
        assert!(a.contains(&"x".to_string()));
    }

    #[test]
    fn orset_concurrent_add_survives_remove() {
        // replica A removes its observed tag; replica B concurrently adds.
        let mut base = ORSet::new();
        base.insert(1, 7u64);
        let mut a = base.clone();
        let mut b = base.clone();
        a.remove(&7);
        b.insert(2, 7);
        let _ = a.merge(&b);
        assert!(a.contains(&7)); // B's unobserved tag survives
    }

    #[test]
    fn orset_laws() {
        let mut a = ORSet::new();
        a.insert(1, 1u64);
        let mut b = a.clone();
        b.remove(&1);
        let mut c = ORSet::new();
        c.insert(2, 2);
        check_laws(&[ORSet::new(), a.clone(), b.clone(), c.clone()]);
        check_merge_outcome(&[ORSet::new(), a, b, c]);
    }

    #[test]
    fn orset_codec() {
        let mut a = ORSet::new();
        a.insert(1, 5u64);
        a.insert(2, 6);
        a.remove(&5);
        check_codec_roundtrip(&[a]);
    }
}
