//! Counter CRDTs: GCounter (grow-only) and PNCounter.

use std::collections::BTreeMap;

use super::{Crdt, MergeOutcome};
use crate::codec::{Decode, DecodeResult, Encode, Reader, Writer};

/// Grow-only counter (the paper's Listing 1/2 `GCounter`).
///
/// Per-contributor partial counts; the value is their sum, the join is
/// the pointwise max. In Holon, contributors are partition ids: a
/// partition's count is a deterministic function of its input prefix, so
/// replicas of the same contribution are totally ordered and max-join is
/// exact (no double counting on replay/steal).
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct GCounter {
    counts: BTreeMap<u64, u64>,
}

impl GCounter {
    pub fn new() -> Self {
        Self::default()
    }

    /// Add `n` on behalf of `contributor`.
    pub fn add(&mut self, contributor: u64, n: u64) {
        *self.counts.entry(contributor).or_insert(0) += n;
    }

    /// Overwrite a contributor's partial count to `n` if larger
    /// (checkpoint-restore path).
    pub fn raise_to(&mut self, contributor: u64, n: u64) {
        let e = self.counts.entry(contributor).or_insert(0);
        *e = (*e).max(n);
    }

    /// Total across all contributors.
    pub fn value(&self) -> u64 {
        self.counts.values().sum()
    }

    /// This contributor's partial count.
    pub fn contribution(&self, contributor: u64) -> u64 {
        self.counts.get(&contributor).copied().unwrap_or(0)
    }

    /// Project the sub-state contributed by `contributor` (checkpointing).
    pub fn project(&self, contributor: u64) -> Self {
        let mut g = GCounter::new();
        if let Some(&n) = self.counts.get(&contributor) {
            g.counts.insert(contributor, n);
        }
        g
    }
}

impl Crdt for GCounter {
    fn project(&self, contributor: u64) -> Self {
        GCounter::project(self, contributor)
    }

    fn merge(&mut self, other: &Self) -> MergeOutcome {
        let mut changed = false;
        for (&k, &v) in &other.counts {
            match self.counts.get_mut(&k) {
                Some(e) => {
                    if v > *e {
                        *e = v;
                        changed = true;
                    }
                }
                None => {
                    self.counts.insert(k, v);
                    changed = true;
                }
            }
        }
        MergeOutcome::changed_if(changed)
    }
}

impl Encode for GCounter {
    fn encode(&self, w: &mut Writer) {
        self.counts.encode(w);
    }
}

impl Decode for GCounter {
    fn decode(r: &mut Reader) -> DecodeResult<Self> {
        Ok(GCounter {
            counts: BTreeMap::decode(r)?,
        })
    }
}

/// Positive-negative counter: two GCounters (increments, decrements).
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct PNCounter {
    pos: GCounter,
    neg: GCounter,
}

impl PNCounter {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn add(&mut self, contributor: u64, n: u64) {
        self.pos.add(contributor, n);
    }

    pub fn sub(&mut self, contributor: u64, n: u64) {
        self.neg.add(contributor, n);
    }

    pub fn value(&self) -> i64 {
        self.pos.value() as i64 - self.neg.value() as i64
    }

    pub fn project(&self, contributor: u64) -> Self {
        PNCounter {
            pos: self.pos.project(contributor),
            neg: self.neg.project(contributor),
        }
    }
}

impl Crdt for PNCounter {
    fn project(&self, contributor: u64) -> Self {
        PNCounter::project(self, contributor)
    }

    fn merge(&mut self, other: &Self) -> MergeOutcome {
        self.pos.merge(&other.pos) | self.neg.merge(&other.neg)
    }
}

impl Encode for PNCounter {
    fn encode(&self, w: &mut Writer) {
        self.pos.encode(w);
        self.neg.encode(w);
    }
}

impl Decode for PNCounter {
    fn decode(r: &mut Reader) -> DecodeResult<Self> {
        Ok(PNCounter {
            pos: GCounter::decode(r)?,
            neg: GCounter::decode(r)?,
        })
    }
}

// lint:allow-tests(discarded-merge): law-check tests merge for effect; outcomes are asserted by check_merge_outcome
#[cfg(test)]
mod tests {
    use super::*;
    use crate::crdt::lawcheck::{check_codec_roundtrip, check_laws, check_merge_outcome};

    fn samples() -> Vec<GCounter> {
        let mut a = GCounter::new();
        a.add(1, 5);
        a.add(2, 3);
        let mut b = GCounter::new();
        b.add(1, 7);
        let mut c = GCounter::new();
        c.add(3, 1);
        c.add(2, 10);
        vec![GCounter::new(), a, b, c]
    }

    #[test]
    fn gcounter_laws() {
        check_laws(&samples());
    }

    #[test]
    fn gcounter_codec() {
        check_codec_roundtrip(&samples());
    }

    #[test]
    fn gcounter_merge_reports_change() {
        check_merge_outcome(&samples());
        // raising one contributor's count is Changed; re-merging is not
        let mut a = GCounter::new();
        a.add(1, 5);
        let mut b = GCounter::new();
        b.add(1, 7);
        assert_eq!(a.merge(&b), MergeOutcome::Changed);
        assert_eq!(a.merge(&b), MergeOutcome::Unchanged);
        // a dominated partner changes nothing
        let mut low = GCounter::new();
        low.add(1, 2);
        assert_eq!(a.merge(&low), MergeOutcome::Unchanged);
    }

    #[test]
    fn gcounter_value_sums_contributors() {
        let mut g = GCounter::new();
        g.add(1, 2);
        g.add(2, 3);
        g.add(1, 1);
        assert_eq!(g.value(), 6);
        assert_eq!(g.contribution(1), 3);
    }

    #[test]
    fn gcounter_merge_takes_max_per_contributor() {
        let mut a = GCounter::new();
        a.add(1, 5);
        let mut b = GCounter::new();
        b.add(1, 3);
        b.add(2, 4);
        let _ = a.merge(&b);
        assert_eq!(a.value(), 9); // max(5,3) + 4
    }

    #[test]
    fn gcounter_replay_is_idempotent() {
        // A replica that re-processed the same prefix merges to no-op.
        let mut a = GCounter::new();
        a.add(1, 10);
        let replay = a.project(1);
        let before = a.clone();
        assert_eq!(a.merge(&replay), MergeOutcome::Unchanged);
        assert_eq!(a, before);
    }

    #[test]
    fn pncounter_laws() {
        let mut a = PNCounter::new();
        a.add(1, 5);
        a.sub(1, 2);
        let mut b = PNCounter::new();
        b.sub(2, 1);
        check_laws(&[PNCounter::new(), a.clone(), b.clone()]);
        check_merge_outcome(&[PNCounter::new(), a.clone(), b]);
        assert_eq!(a.value(), 3);
    }

    #[test]
    fn pncounter_codec() {
        let mut a = PNCounter::new();
        a.add(1, 5);
        a.sub(2, 9);
        check_codec_roundtrip(&[a]);
    }

    #[test]
    fn project_isolates_contributor() {
        let mut g = GCounter::new();
        g.add(1, 5);
        g.add(2, 7);
        let p = g.project(2);
        assert_eq!(p.value(), 7);
        assert_eq!(p.contribution(1), 0);
    }
}
