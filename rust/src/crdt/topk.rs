//! Bounded top-k CRDT — the Q7 ("highest bids") aggregate.
//!
//! A bounded join-semilattice: the state is the set of the k largest
//! entries seen; join = union followed by truncation to the top k.
//! Truncation commutes with union (it is a lattice homomorphism image of
//! GSet-union onto the "top-k" quotient), so the laws hold — verified by
//! the property tests.

use std::collections::BTreeSet;

use super::{Crdt, MergeOutcome};
use crate::codec::{Decode, DecodeResult, Encode, Reader, Writer};
use crate::util::OrdF64;

/// One scored entry: `(price, auction_id, contributor)`. The full tuple
/// participates in ordering so entries are never ambiguous and the join
/// is deterministic.
pub type TopKEntry = (OrdF64, u64, u64);

/// Keep the `k` largest `(score, id, contributor)` entries.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct BoundedTopK {
    k: usize,
    entries: BTreeSet<TopKEntry>,
}

impl Default for BoundedTopK {
    fn default() -> Self {
        Self::new(1)
    }
}

impl BoundedTopK {
    pub fn new(k: usize) -> Self {
        assert!(k >= 1);
        Self {
            k,
            entries: BTreeSet::new(),
        }
    }

    pub fn k(&self) -> usize {
        self.k
    }

    /// Raise the bound to `k` (monotone; queries call this on lattice-
    /// bottom states created by `Default` before offering entries —
    /// every replica applies the same deterministic bound).
    pub fn set_k(&mut self, k: usize) {
        self.k = self.k.max(k);
    }

    /// Offer an entry; keeps it only if it ranks in the top k.
    pub fn offer(&mut self, score: f64, id: u64, contributor: u64) {
        self.entries.insert((OrdF64(score), id, contributor));
        self.truncate();
    }

    fn truncate(&mut self) {
        while self.entries.len() > self.k {
            // BTreeSet iterates ascending; pop the smallest.
            let min = *self.entries.iter().next().unwrap();
            self.entries.remove(&min);
        }
    }

    /// Entries in descending score order.
    pub fn top(&self) -> Vec<TopKEntry> {
        self.entries.iter().rev().copied().collect()
    }

    /// The single highest score, if any (Q7's output).
    pub fn max_score(&self) -> Option<f64> {
        self.entries.iter().next_back().map(|(s, _, _)| s.0)
    }

    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    /// Project entries contributed by `contributor` (checkpoint slice).
    pub fn project(&self, contributor: u64) -> Self {
        Self {
            k: self.k,
            entries: self
                .entries
                .iter()
                .filter(|(_, _, c)| *c == contributor)
                .copied()
                .collect(),
        }
    }
}

impl Crdt for BoundedTopK {
    fn project(&self, contributor: u64) -> Self {
        BoundedTopK::project(self, contributor)
    }

    fn merge(&mut self, other: &Self) -> MergeOutcome {
        // Replicas of the same logical aggregate always share k; the
        // defensive max keeps merge total anyway.
        let mut changed = other.k > self.k;
        self.k = self.k.max(other.k);
        // Inserted entries may be evicted right back by the truncation
        // (they ranked below the incumbent top k), in which case they
        // did not change the state — count them apart from evicted
        // incumbents, which always do.
        let mut fresh: Vec<TopKEntry> = Vec::new();
        for e in &other.entries {
            if self.entries.insert(*e) {
                fresh.push(*e);
            }
        }
        let mut evicted_fresh = 0usize;
        while self.entries.len() > self.k {
            let min = *self.entries.iter().next().unwrap();
            self.entries.remove(&min);
            if fresh.contains(&min) {
                evicted_fresh += 1;
            } else {
                changed = true; // an incumbent fell out of the top k
            }
        }
        changed |= fresh.len() > evicted_fresh; // some fresh entry survived
        MergeOutcome::changed_if(changed)
    }
}

impl Encode for BoundedTopK {
    fn encode(&self, w: &mut Writer) {
        w.put_u64(self.k as u64);
        w.put_u32(self.entries.len() as u32);
        for (s, id, c) in &self.entries {
            w.put_f64(s.0);
            w.put_u64(*id);
            w.put_u64(*c);
        }
    }
}

impl Decode for BoundedTopK {
    fn decode(r: &mut Reader) -> DecodeResult<Self> {
        let k = r.get_u64()? as usize;
        let n = r.get_u32()? as usize;
        let mut entries = BTreeSet::new();
        for _ in 0..n {
            let s = r.get_f64()?;
            let id = r.get_u64()?;
            let c = r.get_u64()?;
            entries.insert((OrdF64(s), id, c));
        }
        Ok(Self { k: k.max(1), entries })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::crdt::lawcheck::{check_codec_roundtrip, check_laws, check_merge_outcome};

    fn topk(k: usize, xs: &[(f64, u64)]) -> BoundedTopK {
        let mut t = BoundedTopK::new(k);
        for (i, &(s, id)) in xs.iter().enumerate() {
            t.offer(s, id, i as u64 % 3);
        }
        t
    }

    #[test]
    fn laws_hold_for_same_k() {
        let samples = vec![
            BoundedTopK::new(3),
            topk(3, &[(1.0, 1), (5.0, 2)]),
            topk(3, &[(2.0, 3), (4.0, 4), (9.0, 5), (0.5, 6)]),
            topk(3, &[(9.0, 5), (8.0, 7)]),
        ];
        check_laws(&samples);
        check_codec_roundtrip(&samples);
        check_merge_outcome(&samples);
    }

    #[test]
    fn merge_of_evicted_entries_is_a_noop() {
        // other's entries all rank below the incumbent top k: the join
        // inserts and immediately evicts them — no state change.
        let mut top = topk(2, &[(8.0, 1), (9.0, 2)]);
        let low = topk(2, &[(1.0, 3), (2.0, 4)]);
        let before = top.clone();
        assert_eq!(top.merge(&low), MergeOutcome::Unchanged);
        assert_eq!(top, before);
        // the reverse direction evicts incumbents: Changed
        let mut low = low;
        assert_eq!(low.merge(&before), MergeOutcome::Changed);
        assert_eq!(low, before);
    }

    #[test]
    fn keeps_only_top_k() {
        let t = topk(2, &[(1.0, 1), (5.0, 2), (3.0, 3)]);
        assert_eq!(t.len(), 2);
        let tops = t.top();
        assert_eq!(tops[0].0 .0, 5.0);
        assert_eq!(tops[1].0 .0, 3.0);
    }

    #[test]
    fn merge_equals_offer_order_independent() {
        let a = topk(3, &[(1.0, 1), (9.0, 2)]);
        let b = topk(3, &[(5.0, 3), (7.0, 4)]);
        let m = a.clone().merged(&b);
        assert_eq!(m.max_score(), Some(9.0));
        assert_eq!(m.len(), 3);
        assert_eq!(m, b.clone().merged(&a));
    }

    #[test]
    fn max_score_on_empty_is_none() {
        assert_eq!(BoundedTopK::new(4).max_score(), None);
    }

    #[test]
    fn duplicate_offers_are_idempotent() {
        let mut t = BoundedTopK::new(2);
        t.offer(5.0, 1, 0);
        t.offer(5.0, 1, 0);
        assert_eq!(t.len(), 1);
    }

    #[test]
    fn project_filters_contributor() {
        let mut t = BoundedTopK::new(4);
        t.offer(1.0, 1, 0);
        t.offer(2.0, 2, 1);
        t.offer(3.0, 3, 0);
        let p = t.project(0);
        assert_eq!(p.len(), 2);
        assert_eq!(p.max_score(), Some(3.0));
    }
}
