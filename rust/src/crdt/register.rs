//! Register CRDTs: last-writer-wins, max and min registers.

use super::{Crdt, MergeOutcome};
use crate::codec::{Decode, DecodeResult, Encode, Reader, Writer};

/// Last-writer-wins register. Ties on timestamp break by contributor id
/// (higher wins) so the join stays commutative and deterministic.
#[derive(Debug, Clone, PartialEq)]
pub struct LwwRegister<T: Clone> {
    entry: Option<(u64, u64, T)>, // (timestamp, contributor, value)
}

impl<T: Clone> Default for LwwRegister<T> {
    fn default() -> Self {
        Self { entry: None }
    }
}

impl<T: Clone> LwwRegister<T> {
    pub fn new() -> Self {
        Self { entry: None }
    }

    pub fn set(&mut self, ts: u64, contributor: u64, value: T) {
        let newer = match &self.entry {
            None => true,
            Some((t, c, _)) => (ts, contributor) > (*t, *c),
        };
        if newer {
            self.entry = Some((ts, contributor, value));
        }
    }

    pub fn get(&self) -> Option<&T> {
        self.entry.as_ref().map(|(_, _, v)| v)
    }

    pub fn timestamp(&self) -> Option<u64> {
        self.entry.as_ref().map(|(t, _, _)| *t)
    }
}

impl<T: Clone + Send + Encode + Decode + 'static> Crdt for LwwRegister<T> {
    fn merge(&mut self, other: &Self) -> MergeOutcome {
        if let Some((ts, c, v)) = &other.entry {
            let newer = match &self.entry {
                None => true,
                Some((t, mc, _)) => (*ts, *c) > (*t, *mc),
            };
            if newer {
                self.entry = Some((*ts, *c, v.clone()));
                return MergeOutcome::Changed;
            }
        }
        MergeOutcome::Unchanged
    }
}

impl<T: Clone + Encode> Encode for LwwRegister<T> {
    fn encode(&self, w: &mut Writer) {
        match &self.entry {
            None => w.put_u8(0),
            Some((t, c, v)) => {
                w.put_u8(1);
                w.put_u64(*t);
                w.put_u64(*c);
                v.encode(w);
            }
        }
    }
}

impl<T: Clone + Decode> Decode for LwwRegister<T> {
    fn decode(r: &mut Reader) -> DecodeResult<Self> {
        match r.get_u8()? {
            0 => Ok(Self { entry: None }),
            _ => {
                let t = r.get_u64()?;
                let c = r.get_u64()?;
                let v = T::decode(r)?;
                Ok(Self {
                    entry: Some((t, c, v)),
                })
            }
        }
    }
}

/// Max register: keeps the largest value ever written; join = max.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct MaxRegister<T: Ord + Clone> {
    value: Option<T>,
}

impl<T: Ord + Clone> Default for MaxRegister<T> {
    fn default() -> Self {
        Self { value: None }
    }
}

impl<T: Ord + Clone> MaxRegister<T> {
    pub fn new() -> Self {
        Self { value: None }
    }

    pub fn put(&mut self, v: T) {
        match &self.value {
            Some(cur) if *cur >= v => {}
            _ => self.value = Some(v),
        }
    }

    pub fn get(&self) -> Option<&T> {
        self.value.as_ref()
    }
}

impl<T: Ord + Clone + Send + Encode + Decode + 'static> Crdt for MaxRegister<T> {
    fn merge(&mut self, other: &Self) -> MergeOutcome {
        if let Some(v) = &other.value {
            let raises = match &self.value {
                Some(cur) => v > cur,
                None => true,
            };
            if raises {
                self.value = Some(v.clone());
                return MergeOutcome::Changed;
            }
        }
        MergeOutcome::Unchanged
    }
}

impl<T: Ord + Clone + Encode> Encode for MaxRegister<T> {
    fn encode(&self, w: &mut Writer) {
        self.value.encode(w);
    }
}

impl<T: Ord + Clone + Decode> Decode for MaxRegister<T> {
    fn decode(r: &mut Reader) -> DecodeResult<Self> {
        Ok(Self {
            value: Option::decode(r)?,
        })
    }
}

/// Min register: dual of [`MaxRegister`].
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct MinRegister<T: Ord + Clone> {
    value: Option<T>,
}

impl<T: Ord + Clone> Default for MinRegister<T> {
    fn default() -> Self {
        Self { value: None }
    }
}

impl<T: Ord + Clone> MinRegister<T> {
    pub fn new() -> Self {
        Self { value: None }
    }

    pub fn put(&mut self, v: T) {
        match &self.value {
            Some(cur) if *cur <= v => {}
            _ => self.value = Some(v),
        }
    }

    pub fn get(&self) -> Option<&T> {
        self.value.as_ref()
    }
}

impl<T: Ord + Clone + Send + Encode + Decode + 'static> Crdt for MinRegister<T> {
    fn merge(&mut self, other: &Self) -> MergeOutcome {
        if let Some(v) = &other.value {
            let lowers = match &self.value {
                Some(cur) => v < cur,
                None => true,
            };
            if lowers {
                self.value = Some(v.clone());
                return MergeOutcome::Changed;
            }
        }
        MergeOutcome::Unchanged
    }
}

impl<T: Ord + Clone + Encode> Encode for MinRegister<T> {
    fn encode(&self, w: &mut Writer) {
        self.value.encode(w);
    }
}

impl<T: Ord + Clone + Decode> Decode for MinRegister<T> {
    fn decode(r: &mut Reader) -> DecodeResult<Self> {
        Ok(Self {
            value: Option::decode(r)?,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::crdt::lawcheck::{check_codec_roundtrip, check_laws, check_merge_outcome};

    #[test]
    fn lww_laws() {
        let mut a = LwwRegister::new();
        a.set(1, 1, 10u64);
        let mut b = LwwRegister::new();
        b.set(2, 1, 20);
        let mut c = LwwRegister::new();
        c.set(2, 2, 30); // same ts as b, higher contributor
        check_laws(&[LwwRegister::new(), a.clone(), b.clone(), c.clone()]);
        check_merge_outcome(&[LwwRegister::new(), a, b, c]);
    }

    #[test]
    fn lww_ties_break_by_contributor() {
        let mut a = LwwRegister::new();
        a.set(5, 1, "a".to_string());
        let mut b = LwwRegister::new();
        b.set(5, 2, "b".to_string());
        let m1 = a.clone().merged(&b);
        let m2 = b.clone().merged(&a);
        assert_eq!(m1.get(), Some(&"b".to_string()));
        assert_eq!(m1, m2);
    }

    #[test]
    fn lww_old_write_ignored() {
        let mut a = LwwRegister::new();
        a.set(10, 1, 1u64);
        a.set(5, 1, 2);
        assert_eq!(a.get(), Some(&1));
    }

    #[test]
    fn max_register_laws_and_codec() {
        let mut a = MaxRegister::new();
        a.put(3u64);
        let mut b = MaxRegister::new();
        b.put(9);
        let samples = vec![MaxRegister::new(), a, b];
        check_laws(&samples);
        check_codec_roundtrip(&samples);
        check_merge_outcome(&samples);
    }

    #[test]
    fn register_merge_reports_change() {
        let mut lo = MaxRegister::new();
        lo.put(3u64);
        let mut hi = MaxRegister::new();
        hi.put(9);
        assert_eq!(lo.merge(&hi), MergeOutcome::Changed);
        assert_eq!(lo.merge(&hi), MergeOutcome::Unchanged);
        assert_eq!(hi.merge(&lo), MergeOutcome::Unchanged); // already dominated
        let mut min_a = MinRegister::new();
        min_a.put(5u64);
        let mut min_b = MinRegister::new();
        min_b.put(2);
        assert_eq!(min_a.merge(&min_b), MergeOutcome::Changed);
        assert_eq!(min_b.merge(&min_a), MergeOutcome::Unchanged);
        check_merge_outcome(&[MinRegister::new(), min_a, min_b]);
    }

    #[test]
    fn max_register_keeps_max() {
        let mut r = MaxRegister::new();
        r.put(5u64);
        r.put(3);
        assert_eq!(r.get(), Some(&5));
        r.put(8);
        assert_eq!(r.get(), Some(&8));
    }

    #[test]
    fn min_register_keeps_min() {
        let mut r = MinRegister::new();
        r.put(5u64);
        r.put(9);
        assert_eq!(r.get(), Some(&5));
        r.put(2);
        assert_eq!(r.get(), Some(&2));
    }

    #[test]
    fn lww_codec() {
        let mut a = LwwRegister::new();
        a.set(7, 3, 42u64);
        check_codec_roundtrip(&[LwwRegister::<u64>::new(), a]);
    }
}
