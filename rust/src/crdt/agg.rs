//! PrefixAgg — per-contributor monotone aggregates (sum/count/min/max).
//!
//! The workhorse behind keyed global aggregations like Nexmark Q4
//! (average price per category). Each contributor (partition) publishes
//! a *deterministic* aggregate of its input prefix: `(count, sum, min,
//! max)`. Because a partition's aggregate only ever extends its prefix,
//! two replicas of the same contributor are totally ordered by `count`,
//! and the join keeps the one with the larger count — the same rule the
//! paper uses for whole partition states ("largest nxtIdx wins", §4.3).

use std::collections::BTreeMap;

use super::{Crdt, MergeOutcome};
use crate::codec::{Decode, DecodeResult, Encode, Reader, Writer};

/// One contributor's running aggregate over its input prefix.
///
/// The float fields are sound under the prefix discipline (waived from
/// holon-lint D4): a join never adds two cells' floats — it keeps the
/// larger-`count` cell *wholesale* (two replicas of one contributor are
/// totally ordered by `count`), so merge order cannot reach the values.
/// Within a single contributor, values fold in deterministic input
/// order, making every replica's cell bit-identical.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct AggCell {
    pub count: u64,
    pub sum: f64, // lint:allow(float-crdt-field): prefix discipline — join keeps the larger-count cell wholesale, floats are never added across replicas
    pub min: f64, // lint:allow(float-crdt-field): prefix discipline — see `sum`
    pub max: f64, // lint:allow(float-crdt-field): prefix discipline — see `sum`
}

impl Default for AggCell {
    fn default() -> Self {
        Self {
            count: 0,
            sum: 0.0,
            min: f64::INFINITY,
            max: f64::NEG_INFINITY,
        }
    }
}

impl AggCell {
    pub fn observe(&mut self, v: f64) {
        self.count += 1;
        self.sum += v;
        self.min = self.min.min(v);
        self.max = self.max.max(v);
    }

    /// Fold a pre-aggregated batch in (count, sum, max) — the fast path
    /// fed by the XLA window-aggregation kernel.
    pub fn observe_batch(&mut self, count: u64, sum: f64, max: f64) {
        if count == 0 {
            return;
        }
        self.count += count;
        self.sum += sum;
        self.max = self.max.max(max);
        // min unavailable from the 3-output kernel; keep it untouched
        // (the min is not used by any paper query).
    }
}

/// Per-contributor prefix aggregates; join keeps the longer prefix.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct PrefixAgg {
    cells: BTreeMap<u64, AggCell>,
}

impl PrefixAgg {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn observe(&mut self, contributor: u64, v: f64) {
        self.cells.entry(contributor).or_default().observe(v);
    }

    pub fn observe_batch(&mut self, contributor: u64, count: u64, sum: f64, max: f64) {
        self.cells
            .entry(contributor)
            .or_default()
            .observe_batch(count, sum, max);
    }

    /// Global count across contributors.
    pub fn count(&self) -> u64 {
        self.cells.values().map(|c| c.count).sum()
    }

    /// Global sum across contributors.
    pub fn sum(&self) -> f64 {
        self.cells.values().map(|c| c.sum).sum()
    }

    /// Global average; `None` when empty.
    pub fn avg(&self) -> Option<f64> {
        let n = self.count();
        if n == 0 {
            None
        } else {
            Some(self.sum() / n as f64)
        }
    }

    /// Global max; `None` when empty.
    pub fn max(&self) -> Option<f64> {
        let m = self
            .cells
            .values()
            .map(|c| c.max)
            .fold(f64::NEG_INFINITY, f64::max);
        if m == f64::NEG_INFINITY {
            None
        } else {
            Some(m)
        }
    }

    /// Global min; `None` when empty.
    pub fn min(&self) -> Option<f64> {
        let m = self
            .cells
            .values()
            .map(|c| c.min)
            .fold(f64::INFINITY, f64::min);
        if m == f64::INFINITY {
            None
        } else {
            Some(m)
        }
    }

    pub fn project(&self, contributor: u64) -> Self {
        let mut p = Self::new();
        if let Some(c) = self.cells.get(&contributor) {
            p.cells.insert(contributor, *c);
        }
        p
    }
}

impl Crdt for PrefixAgg {
    fn project(&self, contributor: u64) -> Self {
        PrefixAgg::project(self, contributor)
    }

    fn merge(&mut self, other: &Self) -> MergeOutcome {
        let mut changed = false;
        for (&k, cell) in &other.cells {
            match self.cells.get_mut(&k) {
                None => {
                    self.cells.insert(k, *cell);
                    changed = true;
                }
                Some(mine) => {
                    // Longer prefix wins; ties are identical by determinism.
                    if cell.count > mine.count {
                        *mine = *cell;
                        changed = true;
                    }
                }
            }
        }
        MergeOutcome::changed_if(changed)
    }
}

impl Encode for PrefixAgg {
    fn encode(&self, w: &mut Writer) {
        w.put_u32(self.cells.len() as u32);
        for (&k, c) in &self.cells {
            w.put_u64(k);
            w.put_u64(c.count);
            w.put_f64(c.sum);
            w.put_f64(c.min);
            w.put_f64(c.max);
        }
    }
}

impl Decode for PrefixAgg {
    fn decode(r: &mut Reader) -> DecodeResult<Self> {
        let n = r.get_u32()? as usize;
        let mut cells = BTreeMap::new();
        for _ in 0..n {
            let k = r.get_u64()?;
            let cell = AggCell {
                count: r.get_u64()?,
                sum: r.get_f64()?,
                min: r.get_f64()?,
                max: r.get_f64()?,
            };
            cells.insert(k, cell);
        }
        Ok(Self { cells })
    }
}

// lint:allow-tests(discarded-merge): law-check tests merge for effect; outcomes are asserted by check_merge_outcome
#[cfg(test)]
mod tests {
    use super::*;
    use crate::crdt::lawcheck::{check_codec_roundtrip, check_laws, check_merge_outcome};

    fn agg(contributor: u64, vals: &[f64]) -> PrefixAgg {
        let mut a = PrefixAgg::new();
        for &v in vals {
            a.observe(contributor, v);
        }
        a
    }

    #[test]
    fn laws_hold_for_prefix_replicas() {
        // Samples must respect the prefix discipline: replicas of the
        // same contributor are prefixes of one another.
        let p1_short = agg(1, &[1.0, 2.0]);
        let p1_long = agg(1, &[1.0, 2.0, 3.0]);
        let p2 = agg(2, &[10.0]);
        check_laws(&[PrefixAgg::new(), p1_short.clone(), p1_long.clone(), p2.clone()]);
        check_codec_roundtrip(&[p1_short.clone(), p1_long.clone(), p2.clone()]);
        check_merge_outcome(&[PrefixAgg::new(), p1_short, p1_long, p2]);
    }

    #[test]
    fn merge_reports_change_only_on_prefix_extension() {
        let short = agg(1, &[1.0, 2.0]);
        let long = agg(1, &[1.0, 2.0, 3.0]);
        let mut m = short.clone();
        assert_eq!(m.merge(&long), MergeOutcome::Changed);
        assert_eq!(m.merge(&short), MergeOutcome::Unchanged); // shorter prefix
        assert_eq!(m.merge(&long), MergeOutcome::Unchanged); // same prefix
    }

    #[test]
    fn longer_prefix_wins() {
        let short = agg(1, &[1.0, 2.0]);
        let long = agg(1, &[1.0, 2.0, 3.0]);
        let m = short.clone().merged(&long);
        assert_eq!(m.count(), 3);
        assert_eq!(m.sum(), 6.0);
        assert_eq!(m, long.clone().merged(&short));
    }

    #[test]
    fn aggregates_across_contributors() {
        let mut a = agg(1, &[2.0, 4.0]);
        let _ = a.merge(&agg(2, &[6.0]));
        assert_eq!(a.count(), 3);
        assert_eq!(a.avg(), Some(4.0));
        assert_eq!(a.max(), Some(6.0));
        assert_eq!(a.min(), Some(2.0));
    }

    #[test]
    fn empty_aggregate_is_none() {
        let a = PrefixAgg::new();
        assert_eq!(a.avg(), None);
        assert_eq!(a.max(), None);
        assert_eq!(a.min(), None);
    }

    #[test]
    fn observe_batch_matches_individual() {
        let mut a = PrefixAgg::new();
        a.observe(1, 2.0);
        a.observe(1, 8.0);
        let mut b = PrefixAgg::new();
        b.observe_batch(1, 2, 10.0, 8.0);
        assert_eq!(a.count(), b.count());
        assert_eq!(a.sum(), b.sum());
        assert_eq!(a.max(), b.max());
    }

    #[test]
    fn observe_batch_empty_is_noop() {
        let mut a = PrefixAgg::new();
        a.observe_batch(1, 0, 0.0, f64::NEG_INFINITY);
        assert_eq!(a.count(), 0);
        assert_eq!(a.max(), None);
    }

    #[test]
    fn project_isolates() {
        let mut a = agg(1, &[1.0]);
        let _ = a.merge(&agg(2, &[5.0]));
        let p = a.project(2);
        assert_eq!(p.count(), 1);
        assert_eq!(p.sum(), 5.0);
    }
}
