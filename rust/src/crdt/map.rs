//! MapCrdt — a keyed composition of CRDTs (pointwise join).
//!
//! Keyed global aggregations (Nexmark Q4: average price *per category*)
//! are maps from key to an inner CRDT; the join is pointwise. Absent
//! keys join as the inner bottom element.

use std::collections::BTreeMap;

use super::{Crdt, MergeOutcome};
use crate::codec::{Decode, DecodeResult, Encode, Reader, Writer};

/// Map from key to inner CRDT; join is pointwise.
#[derive(Debug, Clone, PartialEq)]
pub struct MapCrdt<K: Ord + Clone, C: Crdt> {
    entries: BTreeMap<K, C>,
}

impl<K: Ord + Clone, C: Crdt> Default for MapCrdt<K, C> {
    fn default() -> Self {
        Self {
            entries: BTreeMap::new(),
        }
    }
}

impl<K: Ord + Clone, C: Crdt> MapCrdt<K, C> {
    pub fn new() -> Self {
        Self::default()
    }

    /// Mutable access to the inner CRDT at `key` (created at bottom).
    pub fn entry(&mut self, key: K) -> &mut C {
        self.entries.entry(key).or_default()
    }

    pub fn get(&self, key: &K) -> Option<&C> {
        self.entries.get(key)
    }

    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    pub fn iter(&self) -> impl Iterator<Item = (&K, &C)> {
        self.entries.iter()
    }

    /// Apply `project` pointwise (checkpoint slices for map CRDTs).
    pub fn project_with(&self, f: impl Fn(&C) -> C) -> Self {
        Self {
            entries: self.entries.iter().map(|(k, v)| (k.clone(), f(v))).collect(),
        }
    }

    /// Join one `(key, value)` pair in, reporting whether this map
    /// changed. A fresh key is always a change (the map gains an entry);
    /// an existing key reports its inner join's outcome.
    pub fn merge_entry(&mut self, key: &K, value: &C) -> MergeOutcome {
        match self.entries.get_mut(key) {
            Some(mine) => mine.merge(value),
            None => {
                let mut fresh = C::default();
                // lint:allow(discarded-merge): joining into a fresh ⊥ entry — the map-level outcome is `Changed` regardless (the map gains a key) and is returned below
                let _ = fresh.merge(value);
                self.entries.insert(key.clone(), fresh);
                MergeOutcome::Changed
            }
        }
    }

    /// Pointwise join with a per-key changed-set: `on_changed` fires
    /// once for every key whose entry actually inflated (the trait-v3
    /// `merge_report` hook — [`crate::shard::ShardedMapCrdt`] rides it
    /// to confine shard dirty-marking to genuine changes).
    pub fn merge_report(&mut self, other: &Self, mut on_changed: impl FnMut(&K)) -> MergeOutcome {
        let mut outcome = MergeOutcome::Unchanged;
        for (k, v) in &other.entries {
            // Probe with the borrowed key first: the steady-state merge
            // (gossip between warmed-up replicas) touches only existing
            // keys, and the old `entry(k.clone())` paid a key clone per
            // key per merge just to discover that.
            if self.merge_entry(k, v).is_changed() {
                on_changed(k);
                outcome = MergeOutcome::Changed;
            }
        }
        outcome
    }
}

impl<K, C> Crdt for MapCrdt<K, C>
where
    K: Ord + Clone + Send + Encode + Decode + 'static,
    C: Crdt,
{
    fn project(&self, contributor: u64) -> Self {
        self.project_with(|c| c.project(contributor))
    }

    fn merge(&mut self, other: &Self) -> MergeOutcome {
        self.merge_report(other, |_| {})
    }
}

impl<K: Ord + Clone + Encode, C: Crdt> Encode for MapCrdt<K, C> {
    fn encode(&self, w: &mut Writer) {
        w.put_u32(self.entries.len() as u32);
        for (k, v) in &self.entries {
            k.encode(w);
            v.encode(w);
        }
    }
}

impl<K: Ord + Clone + Decode, C: Crdt> Decode for MapCrdt<K, C> {
    fn decode(r: &mut Reader) -> DecodeResult<Self> {
        let n = r.get_u32()? as usize;
        let mut entries = BTreeMap::new();
        for _ in 0..n {
            let k = K::decode(r)?;
            let v = C::decode(r)?;
            entries.insert(k, v);
        }
        Ok(Self { entries })
    }
}

// lint:allow-tests(discarded-merge): clone-accounting tests merge for the side effect on the clone counter, not the outcome
#[cfg(test)]
mod tests {
    use super::*;
    use crate::crdt::lawcheck::{check_codec_roundtrip, check_laws, check_merge_outcome};
    use crate::crdt::GCounter;

    fn sample(pairs: &[(u64, u64, u64)]) -> MapCrdt<u64, GCounter> {
        let mut m: MapCrdt<u64, GCounter> = MapCrdt::new();
        for &(k, c, n) in pairs {
            m.entry(k).add(c, n);
        }
        m
    }

    #[test]
    fn laws_hold_pointwise() {
        let samples = vec![
            MapCrdt::new(),
            sample(&[(1, 0, 5)]),
            sample(&[(1, 1, 3), (2, 0, 7)]),
            sample(&[(2, 0, 2), (3, 2, 9)]),
        ];
        check_laws(&samples);
        check_codec_roundtrip(&samples);
        check_merge_outcome(&samples);
    }

    #[test]
    fn merge_joins_per_key() {
        let mut a = sample(&[(1, 0, 5)]);
        let b = sample(&[(1, 1, 3), (2, 0, 7)]);
        assert_eq!(a.merge(&b), MergeOutcome::Changed);
        assert_eq!(a.get(&1).unwrap().value(), 8);
        assert_eq!(a.get(&2).unwrap().value(), 7);
        assert_eq!(a.merge(&b), MergeOutcome::Unchanged);
    }

    #[test]
    fn merge_report_names_exactly_the_changed_keys() {
        let mut a = sample(&[(1, 0, 5), (2, 0, 7), (3, 0, 1)]);
        // key 1: dominated (no-op); key 2: inflates; key 4: fresh
        let b = sample(&[(1, 0, 3), (2, 0, 9), (4, 2, 2)]);
        let mut changed = Vec::new();
        let outcome = a.merge_report(&b, |k| changed.push(*k));
        assert_eq!(outcome, MergeOutcome::Changed);
        assert_eq!(changed, vec![2, 4]);
        // a now subsumes b: the report is empty and the outcome a no-op
        let mut changed = Vec::new();
        assert_eq!(
            a.merge_report(&b, |k| changed.push(*k)),
            MergeOutcome::Unchanged
        );
        assert!(changed.is_empty());
    }

    #[test]
    fn absent_key_is_bottom() {
        let m: MapCrdt<u64, GCounter> = MapCrdt::new();
        assert!(m.get(&99).is_none());
    }

    #[test]
    fn project_with_slices_pointwise() {
        let m = sample(&[(1, 0, 5), (1, 1, 2), (2, 1, 3)]);
        let p = m.project_with(|c| c.project(1));
        assert_eq!(p.get(&1).unwrap().value(), 2);
        assert_eq!(p.get(&2).unwrap().value(), 3);
    }

    /// A key whose `Clone` is observable — the merge hot-path regression
    /// guard (merge used to clone every key of `other` even when the key
    /// already existed; see `benches/micro_hotpath.rs` for the timing
    /// side of the same fix).
    mod key_clone_accounting {
        use super::*;
        use crate::codec::{Decode, DecodeResult, Encode, Reader, Writer};
        use std::sync::atomic::{AtomicU64, Ordering};

        static KEY_CLONES: AtomicU64 = AtomicU64::new(0);

        #[derive(Debug, PartialEq, Eq, PartialOrd, Ord)]
        struct CountingKey(u64);

        impl Clone for CountingKey {
            fn clone(&self) -> Self {
                KEY_CLONES.fetch_add(1, Ordering::Relaxed);
                CountingKey(self.0)
            }
        }

        impl Encode for CountingKey {
            fn encode(&self, w: &mut Writer) {
                w.put_u64(self.0);
            }
        }

        impl Decode for CountingKey {
            fn decode(r: &mut Reader) -> DecodeResult<Self> {
                Ok(CountingKey(r.get_u64()?))
            }
        }

        #[test]
        fn merge_clones_only_absent_keys() {
            let build = |keys: &[u64]| {
                let mut m: MapCrdt<CountingKey, GCounter> = MapCrdt::new();
                for &k in keys {
                    m.entry(CountingKey(k)).add(0, k + 1);
                }
                m
            };
            let mut a = build(&[1, 2, 3, 4]);
            let b = build(&[1, 2, 3, 4]);
            let before = KEY_CLONES.load(Ordering::Relaxed);
            let _ = a.merge(&b); // all keys present: zero clones
            assert_eq!(KEY_CLONES.load(Ordering::Relaxed) - before, 0);

            let c = build(&[3, 4, 5, 6]);
            let before = KEY_CLONES.load(Ordering::Relaxed);
            let _ = a.merge(&c); // exactly the two absent keys clone
            assert_eq!(KEY_CLONES.load(Ordering::Relaxed) - before, 2);
            assert_eq!(a.len(), 6);
            // same contributor, same count: the join is the max, not a sum
            assert_eq!(a.get(&CountingKey(3)).unwrap().value(), 4);
        }
    }
}
