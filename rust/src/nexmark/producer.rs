//! Rate-controlled Nexmark producers: append events to the input topic
//! at `events_per_sec_per_partition`, in timestamp order per partition
//! (the ordering assumption of the paper's implementation; §4.4).

use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::Arc;
use std::thread::JoinHandle;

use crate::clock::SimClock;
use crate::codec::Encode;
use crate::log::Topic;
use crate::util::{PartitionId, SimTime};

use super::NexmarkGen;

/// Producer tick granularity (sim-ms). Events within a tick share the
/// tick's timestamp spread evenly.
const TICK_MS: SimTime = 10;

/// Handle over the producer threads.
pub struct Producers {
    stop: Arc<AtomicBool>,
    produced: Arc<AtomicU64>,
    handles: Vec<JoinHandle<()>>,
}

impl Producers {
    /// Total events appended so far.
    pub fn produced(&self) -> u64 {
        self.produced.load(Ordering::Acquire)
    }

    /// Stop producing and wait for the threads.
    pub fn stop(mut self) -> u64 {
        self.stop.store(true, Ordering::Release);
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
        self.produced()
    }
}

/// Static-rate production for `duration_ms` of sim-time (or until
/// stopped). One thread drives all partitions — the broker, not the
/// producer, is the contended path under test.
pub fn spawn(
    input: Arc<Topic>,
    clock: SimClock,
    seed: u64,
    events_per_sec_per_partition: u64,
    duration_ms: SimTime,
) -> Producers {
    spawn_ramped(input, clock, seed, move |_t| events_per_sec_per_partition, duration_ms)
}

/// As [`spawn_ramped`] but appending pre-encoded events from a cycled
/// pool. Generation/encoding happens once up front, so the producer can
/// sustain millions of events per second — required by the §5.3
/// saturation experiment, where the producer must outrun both systems
/// (a fresh-encoding producer caps out far below their capacity on this
/// host and would measure itself, not them).
pub fn spawn_ramped_pooled(
    input: Arc<Topic>,
    clock: SimClock,
    seed: u64,
    rate_at: impl Fn(SimTime) -> u64 + Send + 'static,
    duration_ms: SimTime,
    pool_size: usize,
) -> Producers {
    let stop = Arc::new(AtomicBool::new(false));
    let produced = Arc::new(AtomicU64::new(0));
    let stop2 = stop.clone();
    let produced2 = produced.clone();
    let handle = std::thread::Builder::new()
        .name("nexmark-producer-pooled".to_string())
        .spawn(move || {
            let partitions = input.partitions();
            // one pool shared by all partitions (payload bytes are Arc'd)
            let mut gen = NexmarkGen::new(seed, 0);
            let pool: Vec<Arc<Vec<u8>>> = (0..pool_size)
                .map(|_| Arc::new(gen.next_event().to_bytes()))
                .collect();
            let mut pos = 0usize;
            let start = clock.now();
            let mut debt = vec![0f64; partitions as usize];
            let mut last = start;
            loop {
                if stop2.load(Ordering::Acquire) {
                    return;
                }
                let now = clock.now();
                if now.saturating_sub(start) >= duration_ms {
                    return;
                }
                let dt = now.saturating_sub(last);
                if dt < TICK_MS {
                    clock.sleep(TICK_MS - dt);
                    continue;
                }
                last = now;
                let rate = rate_at(now.saturating_sub(start));
                for p in 0..partitions {
                    debt[p as usize] += rate as f64 * dt as f64 / 1000.0;
                    let n = debt[p as usize] as u64;
                    if n == 0 {
                        continue;
                    }
                    debt[p as usize] -= n as f64;
                    for i in 0..n {
                        let ts = now.saturating_sub(dt) + (i * dt / n.max(1));
                        input.append_shared(p as PartitionId, ts, pool[pos].clone());
                        pos = (pos + 1) % pool.len();
                    }
                    produced2.fetch_add(n, Ordering::Relaxed);
                }
            }
        })
        .expect("spawn pooled producer");
    Producers {
        stop,
        produced,
        handles: vec![handle],
    }
}

/// Production with a time-varying per-partition rate (the §5.3
/// max-throughput experiment ramps the ingestion rate exponentially).
pub fn spawn_ramped(
    input: Arc<Topic>,
    clock: SimClock,
    seed: u64,
    rate_at: impl Fn(SimTime) -> u64 + Send + 'static,
    duration_ms: SimTime,
) -> Producers {
    let stop = Arc::new(AtomicBool::new(false));
    let produced = Arc::new(AtomicU64::new(0));
    let stop2 = stop.clone();
    let produced2 = produced.clone();
    let handle = std::thread::Builder::new()
        .name("nexmark-producer".to_string())
        .spawn(move || {
            let partitions = input.partitions();
            let mut gens: Vec<NexmarkGen> = (0..partitions)
                .map(|p| NexmarkGen::new(seed, p as PartitionId))
                .collect();
            let start = clock.now();
            // Fractional event debt per partition (rate * tick may not
            // be integral).
            let mut debt = vec![0f64; partitions as usize];
            let mut last = start;
            loop {
                if stop2.load(Ordering::Acquire) {
                    return;
                }
                let now = clock.now();
                if now.saturating_sub(start) >= duration_ms {
                    return;
                }
                let dt = now.saturating_sub(last);
                if dt < TICK_MS {
                    clock.sleep(TICK_MS - dt);
                    continue;
                }
                last = now;
                let rate = rate_at(now.saturating_sub(start));
                for p in 0..partitions {
                    debt[p as usize] += rate as f64 * dt as f64 / 1000.0;
                    let n = debt[p as usize] as u64;
                    if n == 0 {
                        continue;
                    }
                    debt[p as usize] -= n as f64;
                    let gen = &mut gens[p as usize];
                    let batch: Vec<(SimTime, Vec<u8>)> = (0..n)
                        .map(|i| {
                            // spread event timestamps across the tick
                            let ts = now.saturating_sub(dt) + (i * dt / n.max(1));
                            (ts, gen.next_event().to_bytes())
                        })
                        .collect();
                    produced2.fetch_add(batch.len() as u64, Ordering::Relaxed);
                    input.append_batch(p as PartitionId, batch);
                }
            }
        })
        .expect("spawn producer");
    Producers {
        stop,
        produced,
        handles: vec![handle],
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::log::LogBroker;

    fn await_events(p: &Producers, min: u64) {
        // Parallel test scheduling can delay the producer thread; wait
        // for it to actually run before asserting.
        for _ in 0..2000 {
            if p.produced() >= min {
                return;
            }
            std::thread::sleep(std::time::Duration::from_millis(1));
        }
    }

    #[test]
    fn produces_at_roughly_the_requested_rate() {
        let clock = SimClock::scaled(20.0); // 1 sim-s per 20 wall-ms
        let broker = LogBroker::new(clock.clone());
        let input = broker.topic("in", 4);
        let p = spawn(input.clone(), clock.clone(), 1, 1000, 2000);
        await_events(&p, 5000);
        let total = p.stop();
        // 4 partitions * 1000 ev/s * 2 s = 8000 expected; producer stops
        // itself at the 2-sim-second mark.
        assert!((5000..=9000).contains(&total), "total={total}");
        assert_eq!(input.total_records(), total);
    }

    #[test]
    fn event_timestamps_are_ordered_per_partition() {
        let clock = SimClock::scaled(20.0);
        let broker = LogBroker::new(clock.clone());
        let input = broker.topic("in", 2);
        let p = spawn(input.clone(), clock.clone(), 2, 500, 1000);
        await_events(&p, 500);
        p.stop();
        for part in 0..2 {
            let (recs, _) = input.read(part, 0, usize::MAX >> 1);
            for w in recs.windows(2) {
                assert!(w[0].event_ts <= w[1].event_ts);
            }
        }
    }

    #[test]
    fn pooled_producer_is_fast_and_ordered() {
        let clock = SimClock::scaled(20.0);
        let broker = LogBroker::new(clock.clone());
        let input = broker.topic("in", 2);
        let p = spawn_ramped_pooled(input.clone(), clock.clone(), 7, |_| 5_000, 1000, 256);
        await_events(&p, 5000);
        let total = p.stop();
        assert!(total >= 5000, "total={total}");
        for part in 0..2 {
            let (recs, _) = input.read(part, 0, usize::MAX >> 1);
            for w in recs.windows(2) {
                assert!(w[0].event_ts <= w[1].event_ts);
            }
        }
    }

    #[test]
    fn ramped_rate_increases_volume() {
        let clock = SimClock::scaled(20.0);
        let broker = LogBroker::new(clock.clone());
        let input = broker.topic("in", 1);
        let p = spawn_ramped(
            input.clone(),
            clock.clone(),
            3,
            |t| if t < 1000 { 100 } else { 2000 },
            2000,
        );
        await_events(&p, 1001);
        let total = p.stop();
        // second half dominates: well above the 100-ev/s floor alone
        assert!(total > 1000, "total={total}");
    }
}
