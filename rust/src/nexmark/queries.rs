//! The paper's queries as Holon processors — each in two forms:
//!
//! * the **procedural API** (§3): one processing function combining
//!   Windowed-CRDT shared state with partition-local state, following
//!   the structure of the paper's Listing 2 (insert → advance watermark
//!   → drain completed windows → emit);
//! * the **dataflow API v2** ([`crate::api::Dataflow`], §3.1):
//!   [`dataflow_q0`], [`dataflow_q2`], [`dataflow_q4`], [`dataflow_q5`]
//!   and [`dataflow_q7`] declare the same queries in a handful of
//!   lines, and [`dataflow_q4_sharded`]/[`dataflow_q5_sharded`] run the
//!   keyed queries over shard-partitioned state
//!   ([`crate::shard::ShardedMapCrdt`]). The procedural versions serve
//!   as differential-test oracles: all forms emit byte-identical
//!   outputs over the same input.
//!
//! All emission uses the *safe pattern* of the unsafe-mode read: windows
//! are drained in sequence behind a cursor, so completion timing never
//! affects emitted values.

use crate::api::{Ctx, Dataflow, Processor};
use crate::codec::{Decode, DecodeResult, Encode, Reader, Writer};
use crate::crdt::{BoundedTopK, GCounter, MapCrdt, PrefixAgg};
use crate::log::Record;
use crate::shard::ShardedMapCrdt;
use crate::util::PartitionId;
use crate::wcrdt::{WindowAssigner, WindowId, WindowRing, WindowedCrdt};

use super::{Event, CATEGORIES};

/// Emission cursor: the next window a partition has yet to emit — the
/// canonical [`crate::api::EmitCursor`] under its historical name.
pub use crate::api::EmitCursor as Cursor;

// ======================================================================
// Q0 — passthrough
// ======================================================================

/// Nexmark Q0: stateless passthrough; measures pipeline overhead.
#[derive(Debug, Clone, Default)]
pub struct Q0;

impl Processor for Q0 {
    type Shared = ();
    type Local = ();

    fn init_shared(&self, _partitions: &[PartitionId]) {}

    fn process(
        &self,
        ctx: &mut Ctx,
        _shared: &(),
        _own: &mut (),
        _local: &mut (),
        events: &[Record],
    ) {
        for rec in events {
            // Latency reference = input insertion time (broker-to-broker).
            // emit_bytes copies straight into the arena frame — no
            // intermediate Vec per record.
            ctx.emit_bytes(rec.insert_ts, &rec.payload);
        }
    }
}

// ======================================================================
// Q2 — selection (stateless filter)
// ======================================================================

/// Output of Q2: one selected bid.
#[derive(Debug, Clone, PartialEq)]
pub struct Q2Out {
    pub auction: u64,
    pub price: f64,
}

impl Encode for Q2Out {
    fn encode(&self, w: &mut Writer) {
        w.put_u64(self.auction);
        w.put_f64(self.price);
    }
}

impl Decode for Q2Out {
    fn decode(r: &mut Reader) -> DecodeResult<Self> {
        Ok(Q2Out {
            auction: r.get_u64()?,
            price: r.get_f64()?,
        })
    }
}

/// Nexmark Q2: select `(auction, price)` for bids on a sampled set of
/// auctions (`auction % every == 0`) — a stateless filter; measures
/// per-event selection overhead.
#[derive(Debug, Clone)]
pub struct Q2 {
    pub every: u64,
}

impl Q2 {
    pub fn new(every: u64) -> Self {
        assert!(every > 0);
        Self { every }
    }
}

impl Processor for Q2 {
    type Shared = ();
    type Local = ();

    fn init_shared(&self, _partitions: &[PartitionId]) {}

    fn process(
        &self,
        ctx: &mut Ctx,
        _shared: &(),
        _own: &mut (),
        _local: &mut (),
        events: &[Record],
    ) {
        for rec in events {
            if let Ok(Event::Bid { auction, price, .. }) = Event::from_bytes(&rec.payload) {
                if auction % self.every == 0 {
                    ctx.emit_with(rec.insert_ts, |w| Q2Out { auction, price }.encode(w));
                }
            }
        }
    }
}

// ======================================================================
// Q7 — highest bid(s) per window (global aggregation)
// ======================================================================

/// Output of Q7: the winning bid of one window.
#[derive(Debug, Clone, PartialEq)]
pub struct Q7Out {
    pub window: WindowId,
    pub price: f64,
    pub auction: u64,
}

impl Encode for Q7Out {
    fn encode(&self, w: &mut Writer) {
        w.put_u64(self.window);
        w.put_f64(self.price);
        w.put_u64(self.auction);
    }
}

impl Decode for Q7Out {
    fn decode(r: &mut Reader) -> DecodeResult<Self> {
        Ok(Q7Out {
            window: r.get_u64()?,
            price: r.get_f64()?,
            auction: r.get_u64()?,
        })
    }
}

/// Nexmark Q7: the highest bid per tumbling window, computed as a
/// Windowed CRDT of a bounded top-k (k = 1 for the paper's query).
///
/// With `k == 1` the per-batch aggregation runs through the
/// [`BatchAggregator`](crate::api::BatchAggregator) — the XLA/Pallas
/// AOT kernel when loaded — and only each window's batch-max is offered
/// to the CRDT. With `k > 1` every bid is offered individually (the
/// batch max would under-approximate ranks 2..k and break determinism
/// across batch boundaries).
#[derive(Debug, Clone)]
pub struct Q7 {
    pub window_ms: u64,
    pub k: usize,
}

impl Q7 {
    pub fn new(window_ms: u64) -> Self {
        Self { window_ms, k: 1 }
    }

    fn assigner(&self) -> WindowAssigner {
        WindowAssigner::tumbling(self.window_ms)
    }
}

impl Processor for Q7 {
    type Shared = WindowedCrdt<BoundedTopK>;
    type Local = Cursor;

    fn init_shared(&self, partitions: &[PartitionId]) -> Self::Shared {
        WindowedCrdt::new(self.assigner(), partitions.iter().copied())
    }

    fn process(
        &self,
        ctx: &mut Ctx,
        shared: &Self::Shared,
        own: &mut Self::Shared,
        local: &mut Cursor,
        events: &[Record],
    ) {
        let wa = self.assigner();
        let p = ctx.partition;
        let k = self.k;
        let mut last_ts = 0;
        if k == 1 {
            // Fast path: fold the batch through the (XLA) aggregator,
            // then offer one per-window max to the CRDT.
            let mut items: Vec<(f64, WindowId)> = Vec::with_capacity(events.len());
            let mut bids: Vec<(f64, u64, WindowId)> = Vec::with_capacity(events.len());
            for rec in events {
                if let Ok(Event::Bid { auction, price, .. }) = Event::from_bytes(&rec.payload) {
                    let w = wa.window_of(rec.event_ts);
                    items.push((price, w));
                    bids.push((price, auction, w));
                }
                last_ts = rec.event_ts;
            }
            if !items.is_empty() {
                let aggs = ctx.aggregator.aggregate(&items);
                for (w, _sum, _count, max) in aggs.windows {
                    // Recover the winning auction id for the window max.
                    // On a price tie the *largest* auction id wins — the
                    // same tie-break as BoundedTopK's lattice order, and
                    // (unlike first-in-batch) independent of where the
                    // engine happens to cut batch boundaries, which is
                    // not replay-stable.
                    let auction = bids
                        .iter()
                        .filter(|&&(pr, _, bw)| bw == w && pr == max)
                        .map(|&(_, a, _)| a)
                        .max()
                        .unwrap_or(0);
                    own.insert_window_with(p, w, |tk| {
                        tk.set_k(k);
                        tk.offer(max, auction, p as u64);
                    });
                }
            }
        } else {
            for rec in events {
                if let Ok(Event::Bid { auction, price, .. }) = Event::from_bytes(&rec.payload) {
                    let _ = own.insert_with(p, rec.event_ts, |tk| {
                        tk.set_k(k);
                        tk.offer(price, auction, p as u64);
                    });
                }
                last_ts = rec.event_ts;
            }
        }
        if last_ts > 0 {
            own.increment_watermark(p, last_ts);
        }

        // Emission: drain completed windows behind the cursor (from the
        // gossip-merged replica — deterministic reads only).
        if local.next < shared.first_available() {
            local.next = shared.first_available();
        }
        while let Some(tk) = shared.window_value(local.next) {
            let w = local.next;
            ctx.emit_with(wa.window_end(w), |wr| q7_winner(w, &tk).encode(wr));
            local.next += 1;
        }
    }
}

/// The winning bid of a completed Q7 window — shared by the procedural
/// processor and [`dataflow_q7`] so both emit byte-identical outputs.
fn q7_winner(w: WindowId, tk: &BoundedTopK) -> Q7Out {
    let (price, auction) = tk
        .top()
        .first()
        .map(|&(s, a, _)| (s.0, a))
        .unwrap_or((0.0, 0));
    Q7Out {
        window: w,
        price,
        auction,
    }
}

// ======================================================================
// Q4 — average price per category (keyed global aggregation)
// ======================================================================

/// Output of Q4: per-category averages of one window.
#[derive(Debug, Clone, PartialEq)]
pub struct Q4Out {
    pub window: WindowId,
    /// (category, average price, count)
    pub rows: Vec<(u64, f64, u64)>,
}

impl Encode for Q4Out {
    fn encode(&self, w: &mut Writer) {
        w.put_u64(self.window);
        w.put_u32(self.rows.len() as u32);
        for &(c, avg, n) in &self.rows {
            w.put_u64(c);
            w.put_f64(avg);
            w.put_u64(n);
        }
    }
}

impl Decode for Q4Out {
    fn decode(r: &mut Reader) -> DecodeResult<Self> {
        let window = r.get_u64()?;
        let n = r.get_u32()? as usize;
        let mut rows = Vec::with_capacity(n);
        for _ in 0..n {
            rows.push((r.get_u64()?, r.get_f64()?, r.get_u64()?));
        }
        Ok(Q4Out { window, rows })
    }
}

/// Nexmark Q4 (adapted, see DESIGN.md): average bid price per category
/// per tumbling window — a *keyed* global aggregation, computed without
/// any shuffle as a Windowed CRDT of per-category prefix aggregates.
///
/// Batch fast path: the aggregator segment-reduces on the synthetic
/// segment id `window * CATEGORIES + category`, so one kernel invocation
/// covers every (window, category) pair in the batch.
///
/// Determinism note: sums are accumulated in integer **cents** (exact
/// and associative in f64/f32 within range), so a partition's
/// contribution is independent of batch boundaries — float-dollar sums
/// would drift by ULPs when a replay re-batches the same prefix.
#[derive(Debug, Clone)]
pub struct Q4 {
    pub window_ms: u64,
}

impl Q4 {
    pub fn new(window_ms: u64) -> Self {
        Self { window_ms }
    }

    fn assigner(&self) -> WindowAssigner {
        WindowAssigner::tumbling(self.window_ms)
    }
}

impl Processor for Q4 {
    type Shared = WindowedCrdt<MapCrdt<u64, PrefixAgg>>;
    type Local = Cursor;

    fn init_shared(&self, partitions: &[PartitionId]) -> Self::Shared {
        WindowedCrdt::new(self.assigner(), partitions.iter().copied())
    }

    fn process(
        &self,
        ctx: &mut Ctx,
        shared: &Self::Shared,
        own: &mut Self::Shared,
        local: &mut Cursor,
        events: &[Record],
    ) {
        let wa = self.assigner();
        let p = ctx.partition;
        let mut last_ts = 0;
        let mut items: Vec<(f64, u64)> = Vec::with_capacity(events.len());
        for rec in events {
            if let Ok(Event::Bid {
                price, category, ..
            }) = Event::from_bytes(&rec.payload)
            {
                let w = wa.window_of(rec.event_ts);
                let cents = (price * 100.0).round();
                items.push((cents, w * CATEGORIES + category));
            }
            last_ts = rec.event_ts;
        }
        if !items.is_empty() {
            let aggs = ctx.aggregator.aggregate(&items);
            for (seg, sum, count, max) in aggs.windows {
                let (w, cat) = (seg / CATEGORIES, seg % CATEGORIES);
                own.insert_window_with(p, w, |m| {
                    m.entry(cat).observe_batch(p as u64, count, sum, max);
                });
            }
        }
        if last_ts > 0 {
            own.increment_watermark(p, last_ts);
        }

        if local.next < shared.first_available() {
            local.next = shared.first_available();
        }
        while let Some(m) = shared.window_value(local.next) {
            let w = local.next;
            ctx.emit_with(wa.window_end(w), |wr| q4_out(w, m.iter()).encode(wr));
            local.next += 1;
        }
    }
}

/// The per-category average rows of a completed Q4 window — shared by
/// the procedural processor and the [`dataflow_q4`]/
/// [`dataflow_q4_sharded`] pipelines so all forms emit byte-identical
/// outputs. Entries arrive in ascending category order from both flat
/// and sharded keyed state.
fn q4_out<'a>(
    w: WindowId,
    entries: impl Iterator<Item = (&'a u64, &'a PrefixAgg)>,
) -> Q4Out {
    let rows: Vec<(u64, f64, u64)> = entries
        .filter_map(|(&cat, agg)| {
            // sums are in cents; convert the average to dollars
            agg.avg().map(|a| (cat, a / 100.0, agg.count()))
        })
        .collect();
    Q4Out { window: w, rows }
}

// ======================================================================
// Q5 — hot items (keyed aggregation over sliding windows)
// ======================================================================

/// Output of Q5: the hottest auction of one sliding window.
#[derive(Debug, Clone, PartialEq)]
pub struct Q5Out {
    pub window: WindowId,
    pub auction: u64,
    pub bids: u64,
}

impl Encode for Q5Out {
    fn encode(&self, w: &mut Writer) {
        w.put_u64(self.window);
        w.put_u64(self.auction);
        w.put_u64(self.bids);
    }
}

impl Decode for Q5Out {
    fn decode(r: &mut Reader) -> DecodeResult<Self> {
        Ok(Q5Out {
            window: r.get_u64()?,
            auction: r.get_u64()?,
            bids: r.get_u64()?,
        })
    }
}

/// The hot item of a completed Q5 window: most bids, ties broken by the
/// larger auction id — shared by the procedural processor and the
/// [`dataflow_q5`]/[`dataflow_q5_sharded`] pipelines so all forms emit
/// byte-identical outputs (the entries iterator abstracts over flat
/// [`MapCrdt`] and [`ShardedMapCrdt`] keyed state).
fn q5_hot_item<'a>(
    w: WindowId,
    entries: impl Iterator<Item = (&'a u64, &'a GCounter)>,
) -> Q5Out {
    let (bids, auction) = entries.map(|(&a, c)| (c.value(), a)).max().unwrap_or((0, 0));
    Q5Out {
        window: w,
        auction,
        bids,
    }
}

/// Nexmark Q5 ("hot items"): the auction with the most bids per sliding
/// window — a *keyed* global aggregation over overlapping windows,
/// computed shuffle-free as a Windowed CRDT of per-auction GCounters
/// (each bid folds into every covering window).
#[derive(Debug, Clone)]
pub struct Q5 {
    pub size_ms: u64,
    pub slide_ms: u64,
}

impl Q5 {
    pub fn new(size_ms: u64, slide_ms: u64) -> Self {
        Self { size_ms, slide_ms }
    }

    fn assigner(&self) -> WindowAssigner {
        WindowAssigner::sliding(self.size_ms, self.slide_ms)
    }
}

impl Processor for Q5 {
    type Shared = WindowedCrdt<MapCrdt<u64, GCounter>>;
    type Local = Cursor;

    fn init_shared(&self, partitions: &[PartitionId]) -> Self::Shared {
        WindowedCrdt::new(self.assigner(), partitions.iter().copied())
    }

    fn process(
        &self,
        ctx: &mut Ctx,
        shared: &Self::Shared,
        own: &mut Self::Shared,
        local: &mut Cursor,
        events: &[Record],
    ) {
        let wa = self.assigner();
        let p = ctx.partition;
        let mut last_ts = 0;
        for rec in events {
            if let Ok(Event::Bid { auction, .. }) = Event::from_bytes(&rec.payload) {
                for w in wa.windows_of(rec.event_ts) {
                    own.insert_window_with(p, w, |m| m.entry(auction).add(p as u64, 1));
                }
            }
            last_ts = rec.event_ts;
        }
        if last_ts > 0 {
            own.increment_watermark(p, last_ts);
        }

        if local.next < shared.first_available() {
            local.next = shared.first_available();
        }
        while let Some(m) = shared.window_value(local.next) {
            let w = local.next;
            ctx.emit_with(wa.window_end(w), |wr| q5_hot_item(w, m.iter()).encode(wr));
            local.next += 1;
        }
    }
}

// ======================================================================
// The same queries in the dataflow API v2 (§3.1) — each a handful of
// declarative lines; the procedural processors above are their
// differential-test oracles.
// ======================================================================

/// Q0 (passthrough) in the dataflow API.
pub fn dataflow_q0() -> impl Processor<Shared = (), Local = ()> {
    Dataflow::<Event>::source().emit_each(|ev| Some(ev.clone()))
}

/// Q2 (selection) in the dataflow API.
pub fn dataflow_q2(every: u64) -> impl Processor<Shared = (), Local = ()> {
    assert!(every > 0, "Q2 sampling modulus must be positive");
    Dataflow::<Event>::source()
        .filter_map(move |ev| match ev {
            Event::Bid { auction, price, .. } if auction % every == 0 => {
                Some(Q2Out { auction, price })
            }
            _ => None,
        })
        .emit_each(|out| Some(out.clone()))
}

/// Q5 (hot items) in the dataflow API: keyed sliding-window counts.
pub fn dataflow_q5(
    size_ms: u64,
    slide_ms: u64,
) -> impl Processor<Shared = WindowedCrdt<MapCrdt<u64, GCounter>>, Local = Cursor> {
    Dataflow::<Event>::source()
        .filter(|ev| ev.is_bid())
        .sliding(size_ms, slide_ms)
        .key_by(|ev| match ev {
            Event::Bid { auction, .. } => *auction,
            _ => 0,
        })
        .aggregate(|p, _ev, c: &mut GCounter| c.add(p as u64, 1))
        .emit_typed(|w, m| Some(q5_hot_item(w, m.iter())))
}

/// Q5 over sharded keyed state: identical outputs to [`dataflow_q5`]
/// and the procedural [`Q5`], with per-auction counters partitioned
/// across `shards` — per-shard delta gossip and parallel replica joins.
pub fn dataflow_q5_sharded(
    size_ms: u64,
    slide_ms: u64,
    shards: u32,
) -> impl Processor<Shared = WindowedCrdt<ShardedMapCrdt<u64, GCounter>>, Local = Cursor> {
    Dataflow::<Event>::source()
        .filter(|ev| ev.is_bid())
        .sliding(size_ms, slide_ms)
        .key_by_sharded(shards, |ev| match ev {
            Event::Bid { auction, .. } => *auction,
            _ => 0,
        })
        .aggregate(|p, _ev, c: &mut GCounter| c.add(p as u64, 1))
        // `entries()` (unsorted, allocation-free): the hot-item max is
        // order-independent, so the sorted `iter()` would be pure cost
        .emit_typed(|w, m| Some(q5_hot_item(w, m.entries())))
}

/// Q4 (average price per category) in the dataflow API: keyed
/// tumbling-window prefix aggregates in integer cents, emitted through
/// the same [`q4_out`] rows as the procedural [`Q4`] — byte-identical
/// outputs (the per-event `observe` folds the same exact-integer cent
/// sums the procedural batch path accumulates).
pub fn dataflow_q4(
    window_ms: u64,
) -> impl Processor<Shared = WindowedCrdt<MapCrdt<u64, PrefixAgg>>, Local = Cursor> {
    Dataflow::<Event>::source()
        .filter(|ev| ev.is_bid())
        .tumbling(window_ms)
        .key_by(|ev| match ev {
            Event::Bid { category, .. } => *category,
            _ => 0,
        })
        .aggregate(|p, ev, agg: &mut PrefixAgg| {
            if let Event::Bid { price, .. } = ev {
                agg.observe(p as u64, (price * 100.0).round());
            }
        })
        .emit_typed(|w, m| Some(q4_out(w, m.iter())))
}

/// Q4 over sharded keyed state — the `q4_keyed_sharded` bench pipeline
/// and the sharded side of the determinism differential tests.
pub fn dataflow_q4_sharded(
    window_ms: u64,
    shards: u32,
) -> impl Processor<Shared = WindowedCrdt<ShardedMapCrdt<u64, PrefixAgg>>, Local = Cursor> {
    Dataflow::<Event>::source()
        .filter(|ev| ev.is_bid())
        .tumbling(window_ms)
        .key_by_sharded(shards, |ev| match ev {
            Event::Bid { category, .. } => *category,
            _ => 0,
        })
        .aggregate(|p, ev, agg: &mut PrefixAgg| {
            if let Event::Bid { price, .. } = ev {
                agg.observe(p as u64, (price * 100.0).round());
            }
        })
        .emit_typed(|w, m| Some(q4_out(w, m.iter())))
}

/// Q7 (highest bid per window) in the dataflow API.
pub fn dataflow_q7(
    window_ms: u64,
) -> impl Processor<Shared = WindowedCrdt<BoundedTopK>, Local = Cursor> {
    Dataflow::<Event>::source()
        .tumbling(window_ms)
        .aggregate(|p, ev, tk: &mut BoundedTopK| {
            if let Event::Bid { auction, price, .. } = ev {
                tk.set_k(1);
                tk.offer(*price, *auction, p as u64);
            }
        })
        .emit_typed(|w, tk| Some(q7_winner(w, tk)))
}

// ======================================================================
// Query 1 (paper §2.2) — local/global bid-count ratio
// ======================================================================

/// Output of the paper's Query 1: one partition's share of the global
/// bid count for one window.
#[derive(Debug, Clone, PartialEq)]
pub struct RatioOut {
    pub window: WindowId,
    pub local: u64,
    pub total: u64,
}

impl RatioOut {
    pub fn ratio(&self) -> f64 {
        if self.total == 0 {
            0.0
        } else {
            self.local as f64 / self.total as f64
        }
    }
}

impl Encode for RatioOut {
    fn encode(&self, w: &mut Writer) {
        w.put_u64(self.window);
        w.put_u64(self.local);
        w.put_u64(self.total);
    }
}

impl Decode for RatioOut {
    fn decode(r: &mut Reader) -> DecodeResult<Self> {
        Ok(RatioOut {
            window: r.get_u64()?,
            local: r.get_u64()?,
            total: r.get_u64()?,
        })
    }
}

/// Partition-local state of Query 1: windowed local bid counts plus the
/// emission cursor (the paper's `localCount` WLocal + `prevWatermark`).
#[derive(Debug, Clone, Default, PartialEq)]
pub struct Q1Local {
    /// window -> local bid count (manual WLocal: Default-constructible;
    /// ring-backed like every other window store, same byte layout).
    pub counts: WindowRing<u64>,
    pub cursor: WindowId,
}

impl Encode for Q1Local {
    fn encode(&self, w: &mut Writer) {
        self.counts.encode(w);
        w.put_u64(self.cursor);
    }
}

impl Decode for Q1Local {
    fn decode(r: &mut Reader) -> DecodeResult<Self> {
        Ok(Q1Local {
            counts: WindowRing::decode(r)?,
            cursor: r.get_u64()?,
        })
    }
}

/// The paper's Query 1 (Listing 2): ratio of bids processed by this
/// partition relative to the global bid count, per window. Shared state
/// is a windowed GCounter; local state a windowed local counter.
#[derive(Debug, Clone)]
pub struct Query1 {
    pub window_ms: u64,
}

impl Query1 {
    pub fn new(window_ms: u64) -> Self {
        Self { window_ms }
    }

    fn assigner(&self) -> WindowAssigner {
        WindowAssigner::tumbling(self.window_ms)
    }
}

impl Processor for Query1 {
    type Shared = WindowedCrdt<GCounter>;
    type Local = Q1Local;

    fn init_shared(&self, partitions: &[PartitionId]) -> Self::Shared {
        WindowedCrdt::new(self.assigner(), partitions.iter().copied())
    }

    fn process(
        &self,
        ctx: &mut Ctx,
        shared: &Self::Shared,
        own: &mut Self::Shared,
        local: &mut Q1Local,
        events: &[Record],
    ) {
        let wa = self.assigner();
        let p = ctx.partition;
        let mut last_ts = 0;
        for rec in events {
            if let Ok(ev) = Event::from_bytes(&rec.payload) {
                if ev.is_bid() {
                    // totalCount.insert(1, e.ts)
                    let _ = own.insert_with(p, rec.event_ts, |c| c.add(p as u64, 1));
                    // localCount.insert(1, e.ts)
                    *local
                        .counts
                        .entry_or_insert_with(wa.window_of(rec.event_ts), || 0) += 1;
                }
            }
            last_ts = rec.event_ts;
        }
        if last_ts > 0 {
            own.increment_watermark(p, last_ts);
        }

        // for w in prevWatermark..watermark: emit local/total.
        //
        // Emission is gated on *this replica's own* progress as well as
        // the global watermark: with overlapping owners (work stealing /
        // startup churn), gossip can complete a window in `shared`
        // before this replica has processed its own partition through
        // it — the WLocal `counts` would still be partial. The shared
        // window value is final either way; the local counter is only
        // final once our own watermark passes the window end (the
        // paper's per-node progress entry gives exactly this guarantee).
        let own_wm = own.progress_of(p);
        if local.cursor < shared.first_available() {
            local.cursor = shared.first_available();
        }
        while wa.window_end(local.cursor) <= own_wm {
            let Some(total) = shared.window_value(local.cursor) else {
                break;
            };
            let w = local.cursor;
            let out = RatioOut {
                window: w,
                local: local.counts.get(&w).copied().unwrap_or(0),
                total: total.value(),
            };
            ctx.emit_with(wa.window_end(w), |wr| out.encode(wr));
            local.counts.remove(&w); // compact the emitted window
            local.cursor += 1;
        }
    }
}

// lint:allow-tests(discarded-merge): end-to-end query tests drain state for effect and assert on emitted outputs
#[cfg(test)]
mod tests {
    use super::*;
    use crate::api::ScalarAggregator;
    use crate::log::Record;

    fn bid_record(offset: u64, ts: u64, auction: u64, price: f64) -> Record {
        let ev = Event::Bid {
            auction,
            bidder: 0,
            price,
            category: auction % CATEGORIES,
        };
        Record {
            offset,
            event_ts: ts,
            insert_ts: ts,
            payload: ev.to_bytes().into(),
        }
    }

    fn run<P: Processor>(
        q: &P,
        shared: &mut P::Shared,
        own: &mut P::Shared,
        local: &mut P::Local,
        partition: PartitionId,
        now: u64,
        events: &[Record],
    ) -> Vec<crate::api::Output> {
        use crate::api::SharedState;
        let mut agg = ScalarAggregator;
        let mut arena = crate::arena::OutputArena::new();
        arena.begin_batch();
        let mut ctx = Ctx::new(partition, now, &mut agg, &mut arena);
        q.process(&mut ctx, shared, own, local, events);
        let _ = shared.join(own);
        arena.take_outputs()
    }

    #[test]
    fn q0_passthrough_emits_everything() {
        let q = Q0;
        let recs = vec![bid_record(0, 10, 1, 5.0), bid_record(1, 20, 2, 6.0)];
        let outs = run(&q, &mut (), &mut (), &mut (), 0, 100, &recs);
        assert_eq!(outs.len(), 2);
        assert_eq!(outs[0].ref_ts, 10);
    }

    #[test]
    fn q7_single_partition_window_flow() {
        let q = Q7::new(1000);
        let mut shared = q.init_shared(&[0]);
        let mut own = q.init_shared(&[0]);
        let mut local = Cursor::default();

        // window 0 bids, then a window-1 bid that closes window 0
        let recs = vec![
            bid_record(0, 100, 1, 50.0),
            bid_record(1, 500, 2, 80.0),
            bid_record(2, 900, 3, 20.0),
        ];
        let outs = run(&q, &mut shared, &mut own, &mut local, 0, 1000, &recs);
        assert!(outs.is_empty()); // window 0 not complete yet

        let recs2 = vec![bid_record(3, 1200, 4, 10.0)];
        run(&q, &mut shared, &mut own, &mut local, 0, 1300, &recs2);
        // watermark=1200 joined into the replica after that batch; the
        // next (idle) invocation sees window 0 complete — mirroring the
        // engine's poll loop.
        let outs = run(&q, &mut shared, &mut own, &mut local, 0, 1305, &[]);
        assert_eq!(outs.len(), 1);
        let o = Q7Out::from_bytes(&outs[0].payload).unwrap();
        assert_eq!(o.window, 0);
        assert_eq!(o.price, 80.0);
        assert_eq!(o.auction, 2);
        assert_eq!(outs[0].ref_ts, 1000); // window end
    }

    #[test]
    fn q7_global_max_across_partitions() {
        let q = Q7::new(1000);
        let parts = [0u32, 1u32];
        let mut shared0 = q.init_shared(&parts);
        let mut own0 = q.init_shared(&parts);
        let mut local0 = Cursor::default();
        let mut shared1 = q.init_shared(&parts);
        let mut own1 = q.init_shared(&parts);
        let mut local1 = Cursor::default();

        run(
            &q,
            &mut shared0,
            &mut own0,
            &mut local0,
            0,
            2000,
            &[bid_record(0, 100, 1, 70.0), bid_record(1, 1100, 2, 5.0)],
        );
        run(
            &q,
            &mut shared1,
            &mut own1,
            &mut local1,
            1,
            2000,
            &[bid_record(0, 200, 3, 99.0), bid_record(1, 1100, 4, 5.0)],
        );
        // gossip both ways
        use crate::api::SharedState;
        let _ = shared0.join(&shared1);
        let _ = shared1.join(&shared0);

        let outs0 = run(&q, &mut shared0, &mut own0, &mut local0, 0, 2100, &[]);
        let outs1 = run(&q, &mut shared1, &mut own1, &mut local1, 1, 2100, &[]);
        let o0 = Q7Out::from_bytes(&outs0[0].payload).unwrap();
        let o1 = Q7Out::from_bytes(&outs1[0].payload).unwrap();
        // deterministic reads: both partitions see the same global max
        assert_eq!(o0, o1);
        assert_eq!(o0.price, 99.0);
        assert_eq!(o0.auction, 3);
    }

    #[test]
    fn q4_averages_per_category() {
        let q = Q4::new(1000);
        let mut shared = q.init_shared(&[0]);
        let mut own = q.init_shared(&[0]);
        let mut local = Cursor::default();
        // categories: auction % 10
        let recs = vec![
            bid_record(0, 100, 10, 4.0), // cat 0
            bid_record(1, 200, 20, 8.0), // cat 0
            bid_record(2, 300, 11, 10.0), // cat 1
            bid_record(3, 1100, 12, 1.0), // closes window 0
        ];
        run(&q, &mut shared, &mut own, &mut local, 0, 1200, &recs);
        let outs = run(&q, &mut shared, &mut own, &mut local, 0, 1205, &[]);
        assert_eq!(outs.len(), 1);
        let o = Q4Out::from_bytes(&outs[0].payload).unwrap();
        assert_eq!(o.window, 0);
        assert_eq!(o.rows, vec![(0, 6.0, 2), (1, 10.0, 1)]);
    }

    #[test]
    fn query1_ratio_flow() {
        let q = Query1::new(1000);
        let parts = [0u32, 1u32];
        let mut shared0 = q.init_shared(&parts);
        let mut own0 = q.init_shared(&parts);
        let mut local0 = Q1Local::default();
        let mut shared1 = q.init_shared(&parts);
        let mut own1 = q.init_shared(&parts);
        let mut local1 = Q1Local::default();

        // partition 0: 3 bids in window 0; partition 1: 1 bid
        run(
            &q,
            &mut shared0,
            &mut own0,
            &mut local0,
            0,
            2000,
            &[
                bid_record(0, 100, 1, 1.0),
                bid_record(1, 200, 1, 1.0),
                bid_record(2, 300, 1, 1.0),
                bid_record(3, 1100, 1, 1.0),
            ],
        );
        run(
            &q,
            &mut shared1,
            &mut own1,
            &mut local1,
            1,
            2000,
            &[bid_record(0, 150, 1, 1.0), bid_record(1, 1100, 1, 1.0)],
        );
        use crate::api::SharedState;
        let _ = shared0.join(&shared1);
        let outs = run(&q, &mut shared0, &mut own0, &mut local0, 0, 2100, &[]);
        assert_eq!(outs.len(), 1);
        let o = RatioOut::from_bytes(&outs[0].payload).unwrap();
        assert_eq!(o.window, 0);
        assert_eq!(o.local, 3);
        assert_eq!(o.total, 4);
        assert!((o.ratio() - 0.75).abs() < 1e-9);
    }

    #[test]
    fn q7_empty_window_emits_zero() {
        let q = Q7::new(1000);
        let mut shared = q.init_shared(&[0]);
        let mut own = q.init_shared(&[0]);
        let mut local = Cursor::default();
        // only a window-2 bid: windows 0 and 1 close empty... window 0
        // has no bids at all.
        let recs = vec![bid_record(0, 2500, 1, 9.0)];
        run(&q, &mut shared, &mut own, &mut local, 0, 2600, &recs);
        let outs = run(&q, &mut shared, &mut own, &mut local, 0, 2605, &[]);
        assert_eq!(outs.len(), 2); // windows 0 and 1
        let o0 = Q7Out::from_bytes(&outs[0].payload).unwrap();
        assert_eq!((o0.window, o0.price), (0, 0.0));
    }

    #[test]
    fn outputs_codec_roundtrip() {
        let o = Q7Out {
            window: 3,
            price: 12.5,
            auction: 9,
        };
        assert_eq!(Q7Out::from_bytes(&o.to_bytes()).unwrap(), o);
        let o = Q4Out {
            window: 1,
            rows: vec![(0, 2.0, 3), (4, 5.5, 1)],
        };
        assert_eq!(Q4Out::from_bytes(&o.to_bytes()).unwrap(), o);
        let o = RatioOut {
            window: 2,
            local: 1,
            total: 4,
        };
        assert_eq!(RatioOut::from_bytes(&o.to_bytes()).unwrap(), o);
        let o = Q2Out {
            auction: 8,
            price: 3.25,
        };
        assert_eq!(Q2Out::from_bytes(&o.to_bytes()).unwrap(), o);
        let o = Q5Out {
            window: 4,
            auction: 2048,
            bids: 17,
        };
        assert_eq!(Q5Out::from_bytes(&o.to_bytes()).unwrap(), o);
    }

    #[test]
    fn q2_selects_sampled_auctions() {
        let q = Q2::new(2);
        // auction ids 2 and 3 -> only the even one selected
        let recs = vec![bid_record(0, 10, 2, 5.0), bid_record(1, 20, 3, 6.0)];
        let outs = run(&q, &mut (), &mut (), &mut (), 0, 100, &recs);
        assert_eq!(outs.len(), 1);
        let o = Q2Out::from_bytes(&outs[0].payload).unwrap();
        assert_eq!((o.auction, o.price), (2, 5.0));
        assert_eq!(outs[0].ref_ts, 10, "selection keeps the insert time");
    }

    #[test]
    fn q5_hot_item_over_sliding_windows() {
        let q = Q5::new(2000, 1000);
        let mut shared = q.init_shared(&[0]);
        let mut own = q.init_shared(&[0]);
        let mut local = Cursor::default();
        // auction 7 gets 2 bids in [0,2000), auction 9 gets 1; the
        // ts=1500 bids also land in window 1 ([1000,3000)).
        let recs = vec![
            bid_record(0, 500, 7, 1.0),
            bid_record(1, 1500, 7, 1.0),
            bid_record(2, 1600, 9, 1.0),
            bid_record(3, 3500, 11, 1.0), // closes windows 0 and 1
        ];
        run(&q, &mut shared, &mut own, &mut local, 0, 3600, &recs);
        let outs = run(&q, &mut shared, &mut own, &mut local, 0, 3700, &[]);
        assert_eq!(outs.len(), 2);
        let o0 = Q5Out::from_bytes(&outs[0].payload).unwrap();
        assert_eq!((o0.window, o0.auction, o0.bids), (0, 7, 2));
        let o1 = Q5Out::from_bytes(&outs[1].payload).unwrap();
        // window 1 sees one bid each on 7 and 9: tie breaks to larger id
        assert_eq!((o1.window, o1.auction, o1.bids), (1, 9, 1));
    }

    // -- differential tests: dataflow v2 vs procedural oracles ----------

    /// Deterministic Nexmark records with ascending event times.
    fn gen_records(seed: u64, partition: u32, n: u64) -> Vec<Record> {
        let mut g = crate::nexmark::NexmarkGen::new(seed, partition);
        (0..n)
            .map(|i| {
                let ev = g.next_event();
                Record {
                    offset: i,
                    event_ts: i * 7,
                    insert_ts: i * 7 + 1,
                    payload: ev.to_bytes().into(),
                }
            })
            .collect()
    }

    /// Feed `events` through a processor in batches of `batch`, then an
    /// idle drain — a single-partition mirror of the engine's poll loop.
    fn run_batched<P: Processor>(q: &P, events: &[Record], batch: usize) -> Vec<crate::api::Output> {
        let mut shared = q.init_shared(&[0]);
        let mut own = q.init_shared(&[0]);
        let mut local = P::Local::default();
        let mut outs = Vec::new();
        for chunk in events.chunks(batch) {
            outs.extend(run(q, &mut shared, &mut own, &mut local, 0, 0, chunk));
        }
        outs.extend(run(q, &mut shared, &mut own, &mut local, 0, 0, &[]));
        outs
    }

    /// Both forms must emit byte-identical outputs over the same input —
    /// even when fed with different batch boundaries.
    ///
    /// The equality contract assumes per-partition in-order event times
    /// (the paper's implementation assumption). On disordered input the
    /// procedural oracles' window guard is batch-boundary-dependent,
    /// while the dataflow pipeline drops timestamp regressions
    /// deterministically — deliberately stricter, not equal.
    fn assert_differential<A: Processor, B: Processor>(
        oracle: &A,
        dataflow: &B,
        events: &[Record],
    ) {
        let a = run_batched(oracle, events, 61);
        let b = run_batched(dataflow, events, 37);
        assert!(!a.is_empty(), "oracle produced no outputs");
        assert_eq!(a.len(), b.len(), "output counts differ");
        for (i, (x, y)) in a.iter().zip(b.iter()).enumerate() {
            assert_eq!(x.ref_ts, y.ref_ts, "output {i}: ref_ts differs");
            assert_eq!(x.payload, y.payload, "output {i}: payload differs");
        }
    }

    #[test]
    fn dataflow_q0_matches_procedural_q0() {
        assert_differential(&Q0, &dataflow_q0(), &gen_records(11, 0, 300));
    }

    #[test]
    fn dataflow_q2_matches_procedural_q2() {
        assert_differential(&Q2::new(3), &dataflow_q2(3), &gen_records(13, 0, 300));
    }

    #[test]
    fn dataflow_q5_matches_procedural_q5() {
        assert_differential(
            &Q5::new(2000, 1000),
            &dataflow_q5(2000, 1000),
            &gen_records(17, 0, 500),
        );
    }

    #[test]
    fn dataflow_q4_matches_procedural_q4() {
        // per-event cent observes vs the procedural batch-aggregated
        // path: exact integer sums make them byte-identical
        assert_differential(&Q4::new(1000), &dataflow_q4(1000), &gen_records(23, 0, 500));
    }

    #[test]
    fn sharded_q4_matches_procedural_q4() {
        for shards in [1, 4, 16] {
            assert_differential(
                &Q4::new(1000),
                &dataflow_q4_sharded(1000, shards),
                &gen_records(29, 0, 500),
            );
        }
    }

    #[test]
    fn sharded_q5_matches_procedural_q5() {
        for shards in [1, 4, 16] {
            assert_differential(
                &Q5::new(2000, 1000),
                &dataflow_q5_sharded(2000, 1000, shards),
                &gen_records(31, 0, 500),
            );
        }
    }

    #[test]
    fn dataflow_q7_matches_procedural_q7() {
        assert_differential(&Q7::new(1000), &dataflow_q7(1000), &gen_records(19, 0, 500));
    }
}
