//! Nexmark workload (Tucker et al.) — the paper's benchmark (§5.1).
//!
//! Event model: an online auction site emits Person, Auction and Bid
//! events. Proportions follow the Nexmark generator (≈ 1 person : 3
//! auctions : 46 bids per 50 events). Auction popularity and bid prices
//! are skewed (hot auctions, long price tail). Categories follow the
//! Nexmark default of 10.
//!
//! The queries used by the paper, plus two Nexmark extensions:
//! * **Q0** — passthrough (stateless; measures pipeline overhead);
//! * **Q2** — selection of sampled auctions (stateless filter);
//! * **Q4** — average price per category (keyed *global* aggregation);
//! * **Q5** — hot items per sliding window (keyed, overlapping windows);
//! * **Q7** — highest bid per window (global aggregation);
//! * **Query 1** (§2.2) — per-partition ratio of local to global bid
//!   counts (the paper's running example).
//!
//! Q0/Q2/Q5/Q7 also exist as dataflow-API-v2 pipelines
//! ([`queries::dataflow_q0`] and friends) with the procedural forms as
//! their differential-test oracles.

pub mod queries;
pub mod producer;

use crate::codec::{Decode, DecodeError, DecodeResult, Encode, Reader, Writer};
use crate::util::XorShift64;

/// Number of auction categories (Nexmark default).
pub const CATEGORIES: u64 = 10;

/// Hot-auction pool size per partition.
const LIVE_AUCTIONS: u64 = 100;

/// One Nexmark event.
#[derive(Debug, Clone, PartialEq)]
pub enum Event {
    /// A new bidder/seller registers.
    Person { id: u64, state: u8 },
    /// A new auction opens.
    Auction { id: u64, seller: u64, category: u64 },
    /// A bid on an open auction.
    Bid {
        auction: u64,
        bidder: u64,
        price: f64,
        category: u64,
    },
}

impl Event {
    pub fn is_bid(&self) -> bool {
        matches!(self, Event::Bid { .. })
    }
}

impl Encode for Event {
    fn encode(&self, w: &mut Writer) {
        match self {
            Event::Person { id, state } => {
                w.put_u8(0);
                w.put_u64(*id);
                w.put_u8(*state);
            }
            Event::Auction {
                id,
                seller,
                category,
            } => {
                w.put_u8(1);
                w.put_u64(*id);
                w.put_u64(*seller);
                w.put_u64(*category);
            }
            Event::Bid {
                auction,
                bidder,
                price,
                category,
            } => {
                w.put_u8(2);
                w.put_u64(*auction);
                w.put_u64(*bidder);
                w.put_f64(*price);
                w.put_u64(*category);
            }
        }
    }
}

impl Decode for Event {
    fn decode(r: &mut Reader) -> DecodeResult<Self> {
        match r.get_u8()? {
            0 => Ok(Event::Person {
                id: r.get_u64()?,
                state: r.get_u8()?,
            }),
            1 => Ok(Event::Auction {
                id: r.get_u64()?,
                seller: r.get_u64()?,
                category: r.get_u64()?,
            }),
            2 => Ok(Event::Bid {
                auction: r.get_u64()?,
                bidder: r.get_u64()?,
                price: r.get_f64()?,
                category: r.get_u64()?,
            }),
            _ => Err(DecodeError("invalid event tag")),
        }
    }
}

/// Deterministic Nexmark event generator for one partition.
#[derive(Debug, Clone)]
pub struct NexmarkGen {
    rng: XorShift64,
    partition: u64,
    next_person: u64,
    next_auction: u64,
    emitted: u64,
}

impl NexmarkGen {
    pub fn new(seed: u64, partition: u32) -> Self {
        Self {
            rng: XorShift64::new(seed ^ (0x4E58 + partition as u64)),
            partition: partition as u64,
            next_person: 0,
            next_auction: 0,
            emitted: 0,
        }
    }

    /// Number of events generated so far.
    pub fn emitted(&self) -> u64 {
        self.emitted
    }

    fn auction_id(&self, local: u64) -> u64 {
        // Partition-scoped id space, interleaved so global aggregations
        // see ids from all partitions.
        local * 1024 + self.partition
    }

    /// Generate the next event (Nexmark proportions: 2% persons, 6%
    /// auctions, 92% bids).
    pub fn next_event(&mut self) -> Event {
        self.emitted += 1;
        let roll = self.rng.next_below(50);
        // Bids need an open auction: force the first events to seed one.
        let roll = if self.next_auction == 0 && roll > 3 { 1 } else { roll };
        if roll == 0 {
            let id = self.next_person;
            self.next_person += 1;
            Event::Person {
                id: id * 1024 + self.partition,
                state: (self.rng.next_below(50)) as u8,
            }
        } else if roll <= 3 {
            let id = self.next_auction;
            self.next_auction += 1;
            let auction = self.auction_id(id);
            Event::Auction {
                id: auction,
                seller: self.rng.next_below(self.next_person.max(1)),
                category: auction % CATEGORIES,
            }
        } else {
            // Bid on a recent auction (hot head via skewed draw).
            let live = self.next_auction.max(1);
            let back = self.rng.skewed_below(LIVE_AUCTIONS.min(live));
            let local = live - 1 - back.min(live - 1);
            let auction = self.auction_id(local);
            // Skewed price: long tail, occasional very high bids.
            let u = self.rng.next_f64();
            let price = 10.0 + 990.0 * u * u * u;
            Event::Bid {
                auction,
                bidder: self.rng.next_below(self.next_person.max(1)),
                price: (price * 100.0).round() / 100.0,
                category: auction % CATEGORIES,
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn event_codec_roundtrip() {
        let events = vec![
            Event::Person { id: 7, state: 3 },
            Event::Auction {
                id: 9,
                seller: 1,
                category: 4,
            },
            Event::Bid {
                auction: 9,
                bidder: 2,
                price: 123.45,
                category: 4,
            },
        ];
        for e in events {
            let b = e.to_bytes();
            assert_eq!(Event::from_bytes(&b).unwrap(), e);
        }
    }

    #[test]
    fn decode_rejects_bad_tag() {
        assert!(Event::from_bytes(&[9]).is_err());
    }

    #[test]
    fn generator_is_deterministic() {
        let mut a = NexmarkGen::new(1, 0);
        let mut b = NexmarkGen::new(1, 0);
        for _ in 0..1000 {
            assert_eq!(a.next_event(), b.next_event());
        }
    }

    #[test]
    fn partitions_generate_distinct_streams() {
        let mut a = NexmarkGen::new(1, 0);
        let mut b = NexmarkGen::new(1, 1);
        let ea: Vec<Event> = (0..100).map(|_| a.next_event()).collect();
        let eb: Vec<Event> = (0..100).map(|_| b.next_event()).collect();
        assert_ne!(ea, eb);
    }

    #[test]
    fn proportions_are_nexmark_like() {
        let mut g = NexmarkGen::new(3, 0);
        let mut bids = 0;
        let mut auctions = 0;
        let mut persons = 0;
        for _ in 0..10_000 {
            match g.next_event() {
                Event::Bid { .. } => bids += 1,
                Event::Auction { .. } => auctions += 1,
                Event::Person { .. } => persons += 1,
            }
        }
        assert!(bids > 8800, "bids={bids}");
        assert!((300..900).contains(&auctions), "auctions={auctions}");
        assert!((100..400).contains(&persons), "persons={persons}");
    }

    #[test]
    fn bid_prices_in_range_and_categories_valid() {
        let mut g = NexmarkGen::new(5, 2);
        for _ in 0..5000 {
            if let Event::Bid {
                price, category, ..
            } = g.next_event()
            {
                assert!((10.0..=1000.0).contains(&price), "price={price}");
                assert!(category < CATEGORIES);
            }
        }
    }

    #[test]
    fn bids_reference_existing_auctions() {
        let mut g = NexmarkGen::new(7, 1);
        let mut auctions = std::collections::BTreeSet::new();
        for _ in 0..5000 {
            match g.next_event() {
                Event::Auction { id, .. } => {
                    auctions.insert(id);
                }
                Event::Bid { auction, .. } => {
                    assert!(auctions.contains(&auction), "bid on unknown auction");
                }
                _ => {}
            }
        }
    }
}
