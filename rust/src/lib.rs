//! # Holon Streaming
//!
//! A from-scratch reproduction of *Holon Streaming: Global Aggregations
//! with Windowed CRDTs* (Spenger et al., 2025) as a three-layer
//! Rust + JAX + Pallas stack. See `DESIGN.md` for the system inventory
//! and `EXPERIMENTS.md` for the paper-vs-measured results.
//!
//! The crate is organized bottom-up:
//!
//! * substrates: [`codec`], [`clock`], [`log`] (the Kafka substitute),
//!   [`net`] (simulated network), [`storage`] (checkpoint store),
//!   [`metrics`], [`config`];
//! * the paper's abstractions: [`crdt`] (state-based CRDTs), [`wcrdt`]
//!   (Windowed CRDTs, Algorithm 1), [`api`] (the procedural programming
//!   model of Table 1);
//! * the engines: [`engine`] (Holon: decentralized nodes, work stealing,
//!   Algorithm 2) and [`baseline`] (the centralized Flink-model used as
//!   the paper's comparison system);
//! * workloads: [`nexmark`] (generator + queries Q0/Q4/Q7/Query1);
//! * the AOT hot path: [`runtime`] (PJRT-loaded XLA kernels);
//! * harness support: [`benchkit`], [`proptest_lite`].

pub mod api;
pub mod baseline;
pub mod benchkit;
pub mod clock;
pub mod codec;
pub mod config;
pub mod crdt;
pub mod engine;
pub mod experiments;
pub mod log;
pub mod metrics;
pub mod net;
pub mod nexmark;
pub mod proptest_lite;
pub mod runtime;
pub mod storage;
pub mod util;
pub mod wcrdt;
