//! # Holon Streaming
//!
//! A from-scratch reproduction of *Holon Streaming: Global Aggregations
//! with Windowed CRDTs* (Spenger et al., 2025) as a three-layer
//! Rust + JAX + Pallas stack. See `DESIGN.md` for the system inventory
//! and `EXPERIMENTS.md` for the paper-vs-measured results.
//!
//! The crate is organized bottom-up:
//!
//! * substrates: [`codec`], [`clock`], [`log`] (the Kafka substitute),
//!   [`arena`] (per-batch framed output buffer — the zero-alloc write
//!   side of the data plane), [`net`] (simulated network), [`storage`]
//!   (checkpoint store), [`metrics`], [`config`];
//! * the paper's abstractions: [`crdt`] (state-based CRDTs; since trait
//!   v3 every join reports its effect — `merge ->`
//!   [`crdt::MergeOutcome`], with per-key/per-shard changed-sets via
//!   the `merge_report` hooks — which is what confines delta
//!   dirty-marking to genuine changes), [`wcrdt`] (Windowed CRDTs,
//!   Algorithm 1; `merge` returns the exact set of inflated windows),
//!   [`shard`] (sharded keyed state: a key-partitioned `MapCrdt` with
//!   per-shard delta gossip and a parallel merge pool — the layer that
//!   lets keyed aggregations like Q4/Q5 scale past one core and one
//!   whole-map gossip payload per replica), [`api`] (the procedural
//!   programming model of Table 1);
//! * the engines: [`engine`] (Holon: decentralized nodes, work stealing,
//!   Algorithm 2) and [`baseline`] (the centralized Flink-model used as
//!   the paper's comparison system);
//! * the read path: [`query`] (any-replica point/range/top-k queries
//!   with per-query staleness bounds, a signature-index pre-filter, and
//!   changefeed subscriptions over the gossip delta stream);
//! * workloads: [`nexmark`] (generator + queries Q0/Q4/Q7/Query1);
//! * the AOT hot path: [`runtime`] (PJRT-loaded XLA kernels);
//! * harness support: [`benchkit`], [`proptest_lite`], [`sim`].
//!
//! ## Testing strategy
//!
//! Three layers of tests back the paper's guarantees, in increasing
//! order of adversarialness:
//!
//! * **Scenario tests** (`rust/tests/failure_recovery.rs`,
//!   `exactly_once.rs`, `determinism.rs`, `integration.rs`) replay the
//!   paper's §5.2 failure scenarios — concurrent/subsequent failures,
//!   crashes without restart, network partitions — against a live
//!   cluster and assert progress, consistency and ground-truth counts
//!   at hand-picked injection points.
//! * **Property tests** (`rust/tests/properties.rs`, via
//!   [`proptest_lite`]) check the algebra the system is built on over
//!   randomized states: CRDT lattice laws (commutativity,
//!   associativity, idempotence, identity), merge-vs-sequential-apply
//!   equivalence, codec round-trips, WCRDT convergence under shuffled
//!   merge orders, and assignment stability.
//! * **The simulation harness** ([`sim`], `rust/tests/simulation.rs`)
//!   generates whole fault *schedules* from a seed — kills, restarts,
//!   partitions, delay/loss bursts, reconfigurations — executes them
//!   against the sim clock, and checks the global oracles after every
//!   run: duplicate-free gap-free delivery, byte-equality with a
//!   fault-free golden run, and replica convergence. Failures shrink
//!   to a minimal plan and print a one-line repro.
//!
//! To reproduce a failing simulation seed, run the printed line, e.g.
//!
//! ```text
//! HOLON_SIM_SEED=17 HOLON_SIM_PLAN='700:k1;1400:r1' \
//!     cargo test --release --test simulation replay_from_env -- --nocapture
//! ```
//!
//! and for long soaks: `holon sim --seeds=500 --start-seed=1000`.
//!
//! ## Benchmarks & the perf trajectory
//!
//! The paper's headline claims are throughput/latency numbers, so every
//! PR records a comparable, machine-readable data point:
//!
//! ```text
//! holon bench [--quick] [--bench-out=FILE]
//! ```
//!
//! runs the §5.3 max-throughput ramp (Holon + the Flink-model baseline)
//! and the Table 2 latency rows headlessly, prints human-readable rows,
//! and writes a `holon-bench/v1` JSON report (default `BENCH_PR9.json`;
//! see EXPERIMENTS.md for the schema and the trajectory log). Each
//! scenario entry carries events/sec (peak + mean), p50/p99/mean
//! latency, gossip volume (`gossip_bytes_wire`, per-recipient), and the
//! allocations-per-event proxy: `payload_clones` (records materialized
//! by the copying `log::Topic::read` path) against `records_read`
//! (records visited by any path). The zero-copy hot path — `read_slice`
//! under RUN_BATCH, `read_with` in the sink — keeps `payload_clones` at
//! 0; before the overhaul the two counters were equal by construction,
//! so every report contains its own before/after comparison. The report
//! is validated in CI (`bench-smoke` job) by
//! `python/tools/validate_bench.py` and uploaded as an artifact.
//!
//! Micro benches for the individual hot-path pieces (zero-copy read vs
//! copying read, nested vs two-pass checkpoint encode, CRDT merge and
//! gossip codec costs) live in `cargo bench --bench micro_hotpath`;
//! `holon bench --targets` lists the per-figure targets.
//!
//! ## Sharded keyed state
//!
//! Keyed aggregation state ([`crate::crdt::MapCrdt`] per window per
//! replica) is the scaling bottleneck the [`shard`] subsystem removes:
//! [`shard::ShardedMapCrdt`] partitions keys across a power-of-two
//! shard count by seeded key-hash, gossips per-shard deltas
//! (shard-tagged payloads; clean shards never ship), merges shards in
//! parallel on receive, and checkpoints per-shard slices. Pipelines opt
//! in via [`api::dataflow::Windowed::key_by_sharded`] (or
//! `--shard-count=N` on `holon run q4`); `holon bench` measures the
//! effect in the `q4_keyed_sharded` scenario, whose report rows carry
//! per-shard gossip-byte counters and the parallel-merge counts.
//! Sharding never changes a single output byte — `tests/determinism.rs`
//! pins sharded vs unsharded Q4/Q5 byte-equality across shard counts
//! {1, 4, 16} under seeded fault schedules.
//!
//! ## Change-reporting merges (Crdt trait v3)
//!
//! Delta gossip is only as good as its dirty markers. Pre-v3, merging a
//! *received* full-sync payload had to conservatively re-mark every
//! window/shard dirty (a `()`-returning merge cannot tell a no-op join
//! from new information), so the delta round after each anti-entropy
//! round re-shipped ~full state. Trait v3 makes every join report its
//! effect: [`crdt::Crdt::merge`] returns [`crdt::MergeOutcome`]
//! (`Changed` **iff** the target actually differs — a contract pinned by
//! the `merge_outcome_*` property suites), `MapCrdt`/`ShardedMapCrdt`
//! expose per-key/per-shard changed-sets via `merge_report`, and
//! [`wcrdt::WindowedCrdt::merge`] returns a [`wcrdt::MergeReport`] with
//! the exact set of inflated windows. The engine drills these through
//! [`api::SharedState`]: the gossip receive path dirty-marks only what
//! genuinely inflated (counted by `ClusterMetrics::{merge_changed,
//! merge_noop}` and `redundant_gossip_bytes`), and a replica with
//! nothing dirty and no watermark movement skips the delta-round
//! encode/broadcast entirely (`gossip_skipped`).
//! `tests/amplification.rs` holds the headline regression: the
//! post-full-sync delta round ships <5% of full-state bytes when
//! replicas have not diverged.
//!
//! ## Queryable state (the read path)
//!
//! Production means clients *querying* live windowed state, not just
//! sinks draining outputs. The [`query`] subsystem serves reads off any
//! replica without coordination — safe because windowed-CRDT
//! convergence makes completed windows identical everywhere, and
//! bounded-stale for incomplete ones. [`query::QueryEngine`] wraps a
//! [`wcrdt::WindowedCrdt`] replica and answers point lookups, inclusive
//! range scans and top-k scans over keyed windows (flat
//! [`crdt::MapCrdt`] or [`shard::ShardedMapCrdt`]) under a per-query
//! **staleness bound** against the replica's watermark: `staleness == 0`
//! demands the final value (exactly `is_complete`, with the same
//! exact-boundary semantics as allowed lateness), larger bounds admit
//! fresher-but-provisional reads stamped with their `lag_ms`. Reads are
//! pre-filtered through a per-window signature index
//! ([`query::SignatureIndex`]: key-fingerprint Bloom + shard-occupancy
//! bitset) maintained incrementally from the
//! [`wcrdt::MergeReport`] changed-window sets — it prunes lookups and
//! whole shards but never drops a matching key (property-tested in
//! `tests/query_read_path.rs`). Replica state reaches readers over a
//! changefeed ([`query::ReadHandle`]): each node publishes the very
//! payload Arcs it gossips (full state on full-sync rounds, deltas
//! otherwise) into a bounded retention ring; subscribers poll with
//! exactly-once-per-cursor delivery, resume from a saved cursor, and
//! re-bootstrap from the latest full snapshot after falling behind
//! retention. `holon query` demos the path end-to-end, and the
//! `mixed_rw_q4_*` bench scenarios measure it (`queries_served`,
//! `query_index_hits/misses`, `query_scan_rows_avoided`,
//! `changefeed_lag`).
//!
//! ## Async data plane (per-peer outbound queues + credit backpressure)
//!
//! Gossip sends are *enqueue-only*: [`net::Bus::send`],
//! [`net::Bus::broadcast_shared`] and [`net::Bus::broadcast_sample_shared`]
//! append `(kind, sent_at, Arc<payload>)` to a per-peer outbound queue
//! and return immediately — a sender's loop iteration costs O(1) pushes
//! no matter how congested any receiver is. Each node drains its own
//! queues once per loop iteration via [`net::Bus::flush`], which applies
//! loss, partitions, delay/jitter and any live [`net::FaultOverlay`] in
//! **one** RNG critical section per batch and bulk-appends to receiver
//! inboxes. Because `sent_at` is stamped at enqueue time and
//! [`net::Bus::recv`] orders by `(deliver_at, from, sent_at)`, the async
//! hop is invisible to the determinism oracles: seeded fault schedules
//! stay byte-reproducible.
//!
//! Backpressure is credit-based and **gates sources, never acks**: with
//! [`config::HolonConfig::inbox_capacity`] set, a full inbox parks
//! overflow on the sender's queue (in order; the bounded outbound queue
//! sheds its *oldest* entries as `dropped_backpressure` — newer CRDT
//! state subsumes older), receivers advertise free inbox space as
//! credits piggybacked on heartbeats, and a sender seeing parked traffic
//! or a zero-credit live peer shrinks its per-iteration event budget —
//! bounded lag instead of unbounded memory, while delivery/ack paths
//! run untouched so exactly-once progress cannot deadlock on a slow
//! reader. Drops are accounted by cause
//! (`dropped_{partition,loss,no_inbox,backpressure}`, sum-preserving vs
//! the old single counter), and the `overload_q7_*` bench rows pin the
//! acceptance claim: a 10×-slowed receiver leaves writer throughput
//! within 20% of the uniform run with `inbox_depth_max ≤
//! inbox_capacity` (`tests/backpressure.rs` also pins byte-identical
//! outputs under pressure).
//!
//! ## Memory layout (arena output path + ring window store)
//!
//! The two allocation hot spots the zero-copy read path left behind are
//! gone:
//!
//! * **Outputs** are written *in place* into a per-batch [`arena::OutputArena`]
//!   — emit stages ([`api::Ctx::emit_with`] and friends) receive the
//!   backing [`codec::Writer`] positioned inside a cancellable frame, so
//!   no per-record `Vec<u8>` is ever built. The batch drain backpatches
//!   sequence numbers and ships the whole buffer as **one**
//!   `Arc<Vec<u8>>` via [`log::Topic::append_frames`]; every record of
//!   the batch references that single shared backing ([`log::SharedBytes`])
//!   with zero payload copies. Steady-state cost: ≤1 allocation per
//!   batch (the pre-reserve to the high-water mark, asserted by a
//!   counting global allocator in `benches/micro_hotpath.rs`) plus the
//!   `Arc` cell. The frame wire format is byte-identical to the old
//!   per-record encoding, and the [`baseline`] taskmanager emits through
//!   the same arena so the systems comparison stays fair.
//! * **Window state** lives in a [`wcrdt::WindowRing`]: a dense
//!   ring buffer indexed by `window_id − base` — O(1) lookup/insert on
//!   the live horizon, zero allocations per in-horizon touch, compaction
//!   advances the base without moving survivors. Out-of-span windows
//!   overflow into a spill map (counted by
//!   `ClusterMetrics::window_ring_spills`, expected 0 in-order);
//!   ascending iteration keeps `Encode` byte-identical to the
//!   `BTreeMap` it replaced, so no wire/checkpoint/gossip format
//!   changed — `tests/properties.rs` pins the ring ≡ BTreeMap
//!   equivalence by differential property tests and a seeded
//!   fault-schedule replay.
//!
//! ## Observability & tracing (the flight recorder)
//!
//! Aggregate counters say *what* happened; the [`trace`] flight
//! recorder says *when and in what order*. Every node — and the sink,
//! under [`trace::SINK_NODE`] — owns a bounded pre-allocated ring of
//! [`trace::TraceEvent`]s covering the whole window lifecycle
//! (`window_opened → delta_merged → watermark_advanced → window_fired
//! → window_converged → window_emitted → sink_deduped`), gossip-round
//! causality (`gossip_round`/`gossip_skipped` at the sender,
//! per-peer `peer_flush` outcomes from [`net::Bus::flush_with`]),
//! recovery timelines (`steal_start → checkpoint_restore →
//! first_output`), and `checkpoint`/`backpressure` events.
//!
//! **Span pairing** is by plain integers, never pointers: window
//! events share the window-end timestamp as `span_id`, gossip events
//! the sender's round id, recovery events the partition id — so one
//! window's lifecycle lines up across every node and the sink in a
//! single Perfetto view.
//!
//! **Overhead contract:** instrumentation stays in the hot paths
//! permanently. Disabled (default), [`trace::TraceHandle::record`] is
//! one branch — the `micro_hotpath` counting-allocator harness
//! asserts the steady-state emit loop still makes **zero** global
//! allocations with a disabled handle threaded through it. Enabled,
//! recording is one uncontended lock plus a `Copy` store into the
//! pre-allocated ring; when the ring wraps, the oldest events are
//! overwritten and counted (`trace_dropped_events` in the bench
//! JSON), so the newest diagnostics always survive.
//!
//! The recorder feeds two export surfaces. (1) `holon trace` (and
//! `--trace-out=FILE` on `run`/`sim`/`bench`) writes Chrome
//! `trace_event` JSON — open it at <https://ui.perfetto.dev> or
//! `about:tracing`; `tid` is the node id. (2) When a sim oracle
//! falsifies, the harness re-runs the *shrunk* plan with tracing on
//! and writes `holon-trace-dump-seed<seed>.json` next to the repro
//! line, turning every failure into a browsable timeline. Because an
//! event is six integers, a trace of a deterministic execution is
//! itself deterministic — the seeded-script test in [`trace`] pins
//! byte-identical dumps for identical event streams (live full-run
//! dumps are additionally subject to wall-clock thread interleaving
//! through the scaled [`clock::SimClock`]).
//!
//! From the span pairs the engine derives the **stage-latency
//! breakdown** that decomposes the end-to-end latency histogram:
//! `stage_latency_{ingest,fire,converge,emit}_{p50,p99}` in the bench
//! JSON, measuring source→node pickup, window-end→watermark-fire,
//! fire→sink-convergence, and convergence→sink-drain respectively.
//!
//! ## Determinism hazards & static analysis (holon-lint)
//!
//! The runtime suites above *check* determinism and exactly-once; the
//! disciplines that make those checks pass are **source-level** and are
//! enforced by `python/tools/holon_lint.py`, a stdlib-only analyzer
//! over `rust/{src,tests,benches}` (CI `lint-smoke` job — it runs even
//! where no cargo toolchain exists). The hazards, each mapped to the
//! paper guarantee it would silently break:
//!
//! * **hash-on-wire (D1)** — `HashMap`/`HashSet` in a module whose
//!   iteration order can reach the wire (crdt, wcrdt, shard, net, api,
//!   engine, storage, codec, arena, query::index). Unordered iteration
//!   makes two replicas encode the same lattice state as different
//!   bytes, falsifying byte-identical gossip/checkpoint/emit. Use
//!   `BTreeMap`, [`wcrdt::WindowRing`], or sort before emitting.
//! * **wall-clock (D2)** — `SystemTime`/`Instant`/ambient RNG outside
//!   [`clock`], [`benchkit`], [`trace`]. All data-plane time flows
//!   through [`clock::SimClock`]; all randomness through seeded
//!   `util::XorShift64` — otherwise seeded fault schedules stop
//!   replaying.
//! * **discarded-merge (D3)** — `let _ = …merge/join/take_delta…`.
//!   The trait-v3 contract is that every join reports its effect
//!   ([`crdt::MergeOutcome`]); discarding it hides divergence and
//!   breaks the dirty-marking discipline delta gossip rests on. Feed
//!   outcomes to `ClusterMetrics::note_join` or waive with the reason
//!   the outcome is irrelevant at that site.
//! * **float-crdt-field (D4)** — raw `f32`/`f64` fields in CRDT state.
//!   Float addition is not associative, so merge order would leak into
//!   converged values. Use `util::OrdF64` (join = max under a total
//!   order) or a documented prefix discipline
//!   ([`crdt::PrefixAgg`]'s waiver: joins move whole cells, floats are
//!   never added across replicas).
//! * **zero-alloc (A1)** — functions annotated `// lint: zero-alloc`
//!   (the arena emit path, `WindowRing` in-horizon touch,
//!   `TraceHandle::record`, the gossip encode round) must contain no
//!   allocating construct; the counting `#[global_allocator]` in
//!   `benches/micro_hotpath.rs` is the runtime ground truth for
//!   transitive callees, this is its always-on static twin.
//! * **lock-unwrap (S1)** — bare `.lock().unwrap()` in data-plane
//!   modules. A poisoned mutex cascades one partition's panic across
//!   every in-process node — a cluster-wide abort the exactly-once
//!   recovery machinery never gets to handle. `util::LockExt::plane_lock`
//!   recovers the guard instead; sound because CRDT state is monotone,
//!   so a torn update is re-converged by the next merge.
//!
//! Waivers are inline comments with a mandatory reason
//! (`// lint:allow(rule): why`, plus `allow-file`/`allow-tests`
//! granularity); a waiver that stops suppressing anything fails CI
//! (`--strict`), so the waiver set only shrinks. `clippy.toml` at the
//! repo root mirrors D1/D2 as `disallowed_types`/`disallowed_methods`
//! once a cargo toolchain is present.

pub mod api;
pub mod arena;
pub mod baseline;
pub mod benchkit;
pub mod clock;
pub mod codec;
pub mod config;
pub mod crdt;
pub mod engine;
pub mod experiments;
pub mod log;
pub mod metrics;
pub mod net;
pub mod nexmark;
pub mod proptest_lite;
pub mod query;
pub mod runtime;
pub mod shard;
pub mod sim;
pub mod storage;
pub mod trace;
pub mod util;
pub mod wcrdt;
