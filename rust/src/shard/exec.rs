//! Scoped worker pool for parallel shard merges.
//!
//! Shards hold disjoint key sets, so joining two same-layout
//! [`ShardedMapCrdt`](super::ShardedMapCrdt)s is a pointwise join of
//! independent shard pairs — embarrassingly parallel. Large joins fan
//! the shard pairs out over scoped threads ([`std::thread::scope`]:
//! no `'static` bounds, no channels, workers die with the call); small
//! joins stay inline because a thread spawn costs more than the merge.
//!
//! Parallelism is capped process-wide by [`set_max_threads`] (config
//! key `shard_merge_threads`, `0` = the machine's available
//! parallelism) so a many-node simulated cluster does not oversubscribe
//! the host with `nodes × shards` merge threads.
//!
//! Per-thread merge counters ([`take_merge_stats`]) let the engine
//! attribute parallel-vs-inline merges to its
//! [`ClusterMetrics`](crate::engine::ClusterMetrics) without plumbing a
//! metrics handle into the `Crdt::merge` signature.

use std::cell::Cell;
use std::sync::atomic::{AtomicUsize, Ordering};

use crate::codec::{Decode, Encode};
use crate::crdt::{Crdt, MapCrdt};

/// Process-wide thread cap; 0 = resolve from available parallelism.
static MAX_THREADS: AtomicUsize = AtomicUsize::new(0);

thread_local! {
    /// (parallel, inline) sharded merges executed on this thread since
    /// the last [`take_merge_stats`] drain.
    static MERGES: Cell<(u64, u64)> = const { Cell::new((0, 0)) };
}

/// Cap the shard-merge pool (0 restores the auto default). Called by
/// the engine from `shard_merge_threads`; last caller wins, which is
/// fine for the one-deployment-per-process shapes the knob exists for.
pub fn set_max_threads(n: usize) {
    MAX_THREADS.store(n, Ordering::Relaxed);
}

/// Effective worker cap for the next parallel merge.
pub fn max_threads() -> usize {
    match MAX_THREADS.load(Ordering::Relaxed) {
        0 => std::thread::available_parallelism().map_or(1, |n| n.get()),
        n => n,
    }
}

pub(crate) fn note_merge(parallel: bool) {
    MERGES.with(|m| {
        let (p, s) = m.get();
        m.set(if parallel { (p + 1, s) } else { (p, s + 1) });
    });
}

/// Drain this thread's `(parallel, inline)` sharded-merge counters.
pub fn take_merge_stats() -> (u64, u64) {
    MERGES.with(|m| m.replace((0, 0)))
}

/// Join `src` into `dst` shard-by-shard across up to `threads` scoped
/// workers, OR-ing each pair's change flag into `changed` (index =
/// shard id) so the caller can dirty-mark only the shards that actually
/// inflated. Caller guarantees `dst.len() == src.len() == changed.len()`
/// (same layout).
pub(crate) fn merge_pairwise<K, C>(
    dst: &mut [MapCrdt<K, C>],
    src: &[MapCrdt<K, C>],
    changed: &mut [bool],
    threads: usize,
) where
    K: Ord + Clone + Send + Sync + Encode + Decode + 'static,
    C: Crdt + Sync,
{
    debug_assert_eq!(dst.len(), src.len());
    debug_assert_eq!(dst.len(), changed.len());
    if dst.is_empty() {
        return;
    }
    let threads = threads.clamp(1, dst.len());
    if threads <= 1 {
        for ((d, s), c) in dst.iter_mut().zip(src).zip(changed.iter_mut()) {
            *c |= d.merge(s).is_changed();
        }
        return;
    }
    let chunk = dst.len().div_ceil(threads);
    std::thread::scope(|scope| {
        for ((dc, sc), cc) in dst
            .chunks_mut(chunk)
            .zip(src.chunks(chunk))
            .zip(changed.chunks_mut(chunk))
        {
            scope.spawn(move || {
                for ((d, s), c) in dc.iter_mut().zip(sc).zip(cc.iter_mut()) {
                    *c |= d.merge(s).is_changed();
                }
            });
        }
    });
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::crdt::GCounter;

    fn shard_vec(n: usize, salt: u64) -> Vec<MapCrdt<u64, GCounter>> {
        (0..n)
            .map(|i| {
                let mut m: MapCrdt<u64, GCounter> = MapCrdt::new();
                for k in 0..20u64 {
                    m.entry(k * n as u64 + i as u64).add(salt, k + 1 + salt);
                }
                m
            })
            .collect()
    }

    #[test]
    fn pairwise_parallel_equals_pairwise_serial() {
        let src = shard_vec(8, 7);
        let mut serial = shard_vec(8, 1);
        let mut parallel = serial.clone();
        let mut changed_serial = vec![false; 8];
        let mut changed_parallel = vec![false; 8];
        merge_pairwise(&mut serial, &src, &mut changed_serial, 1);
        merge_pairwise(&mut parallel, &src, &mut changed_parallel, 4);
        assert_eq!(serial, parallel);
        // every pair inflated (disjoint contributor salts), and both
        // execution shapes report identical per-shard change flags
        assert_eq!(changed_serial, changed_parallel);
        assert!(changed_serial.iter().all(|&c| c));
        // re-merging the same source is a cross-shard no-op
        let mut changed_again = vec![false; 8];
        merge_pairwise(&mut parallel, &src, &mut changed_again, 4);
        assert!(changed_again.iter().all(|&c| !c));
    }

    #[test]
    fn auto_thread_cap_is_at_least_one() {
        // (the explicit-cap path is covered by the parallel-merge test
        // in `shard::tests`; only one test mutates the global cap so
        // parallel test threads cannot race on it)
        assert!(max_threads() >= 1);
    }

    #[test]
    fn merge_stats_drain_per_thread() {
        let _ = take_merge_stats();
        note_merge(true);
        note_merge(false);
        note_merge(false);
        assert_eq!(take_merge_stats(), (1, 2));
        assert_eq!(take_merge_stats(), (0, 0));
    }
}
