//! Sharded keyed state — key-partitioned [`MapCrdt`] composition.
//!
//! Keyed global aggregations (Nexmark Q4/Q5) hold a map from key to an
//! inner CRDT per window per replica. With a single `BTreeMap` that map
//! is one lock-step structure: every gossip round re-ships the whole
//! map and every merge walks it on one core. [`ShardedMapCrdt`] splits
//! the key space across a configurable power-of-two number of shards by
//! a seeded key-hash; each shard is an independent inner [`MapCrdt`]
//! with its own dirty marker, so
//!
//! * **delta gossip is per-shard**: [`take_delta`](ShardedMapCrdt::take_delta)
//!   carries only the shards touched since the previous round, encoded
//!   as shard-tagged payloads ([`crate::codec::Writer::put_nested`]
//!   segments), and merge on the receiving side touches only those
//!   shards;
//! * **merge is embarrassingly parallel**: shards hold disjoint key
//!   sets, so a replica join is a pointwise join of shard pairs —
//!   [`exec`] fans large joins out over scoped worker threads;
//! * **checkpoint slices stay per-shard**: projection composes
//!   pointwise, and the encoded layout keeps one length-prefixed
//!   segment per shard, so a reader can skip shards it does not need.
//!
//! Sharding preserves the lattice: the shard assignment is a pure
//! function of `(key, seed, shard count)`, identical on every replica,
//! and per-shard joins compose to the same pointwise join a flat
//! [`MapCrdt`] computes (delta-state CRDT composition; Almeida et al.).
//! The whole type implements [`Crdt`] + [`Encode`] + [`Decode`], so it
//! drops into [`WindowedCrdt`](crate::wcrdt::WindowedCrdt) unchanged —
//! `tests/determinism.rs` asserts byte-identical Q4/Q5 outputs for
//! sharded vs unsharded pipelines across shard counts under seeded
//! fault schedules.
//!
//! Equality is *logical* (the sorted key→value entries), independent of
//! shard layout: a 4-shard and a 16-shard replica holding the same
//! entries are equal, and the lattice bottom (no layout yet) equals any
//! empty layout. This is what lets differently-configured replicas —
//! and deltas, whose absent shards decode as empty — converge under the
//! usual CRDT laws.

pub mod exec;

use std::cell::RefCell;

use crate::codec::{Decode, DecodeError, DecodeResult, Encode, Reader, Writer};
use crate::crdt::{Crdt, MapCrdt, MergeOutcome};

/// Default seed folded into every key hash (any fixed value works; it
/// only has to be identical on all replicas of a deployment).
pub const DEFAULT_HASH_SEED: u64 = 0x5EED_5AAD_0BAD_F00D;

/// Hard ceiling on the shard count — bounds the `Vec` a decode
/// preallocates from the wire-read count field (a corrupted payload
/// must fail with a `DecodeError`, not abort in the allocator) and is
/// far above any sane configuration.
pub const MAX_SHARDS: usize = 1 << 16;

/// Below this many shards a parallel merge never pays for itself.
const PAR_MIN_SHARDS: usize = 4;

/// Minimum combined entry count before a merge fans out to the pool
/// (scoped-thread spawn costs dominate below it).
const PAR_MIN_ENTRIES: usize = 1024;

thread_local! {
    /// Reusable hash buffer: keys are hashed over their encoded bytes,
    /// and re-encoding into a fresh `Vec` per lookup would put an
    /// allocation on the per-event insert path.
    static HASH_BUF: RefCell<Writer> = RefCell::new(Writer::new());
    /// Per-shard encoded byte counts since the last drain — the engine
    /// samples this right after a gossip encode to attribute payload
    /// bytes to shards (see `ClusterMetrics::shard_gossip_bytes`).
    static ENCODED_BYTES: RefCell<Vec<u64>> = RefCell::new(Vec::new());
}

fn note_shard_bytes(idx: usize, n: u64) {
    ENCODED_BYTES.with(|b| {
        let mut b = b.borrow_mut();
        if b.len() <= idx {
            b.resize(idx + 1, 0);
        }
        b[idx] += n;
    });
}

/// Size the per-thread counters to the full layout so the drained
/// vector's length is the configured shard count (stable across runs),
/// not the highest shard that happened to encode bytes.
fn note_shard_layout(count: usize) {
    ENCODED_BYTES.with(|b| {
        let mut b = b.borrow_mut();
        if b.len() < count {
            b.resize(count, 0);
        }
    });
}

/// Drain this thread's per-shard encoded byte counters (index = shard
/// id). The engine calls this around the gossip encode so checkpoint
/// encodes on the same thread are not misattributed to gossip.
pub fn take_shard_encoded_bytes() -> Vec<u64> {
    ENCODED_BYTES.with(|b| std::mem::take(&mut *b.borrow_mut()))
}

/// Seeded FNV-1a over a key's encoded bytes — deterministic across
/// replicas, processes and runs (no `RandomState`).
fn hash_key<K: Encode>(seed: u64, key: &K) -> u64 {
    HASH_BUF.with(|buf| {
        let mut buf = buf.borrow_mut();
        buf.clear();
        key.encode(&mut buf);
        let mut h = seed ^ 0xcbf2_9ce4_8422_2325;
        for &b in buf.as_slice() {
            h ^= b as u64;
            h = h.wrapping_mul(0x0000_0100_0000_01b3);
        }
        h
    })
}

/// A keyed CRDT map partitioned across power-of-two shards by seeded
/// key-hash. See the module docs for the design.
#[derive(Debug, Clone)]
pub struct ShardedMapCrdt<K: Ord + Clone, C: Crdt> {
    seed: u64,
    /// Empty = lattice bottom (layout adopted from the first non-bottom
    /// merge partner or fixed by [`ensure_shards`](Self::ensure_shards)).
    shards: Vec<MapCrdt<K, C>>,
    /// Shards touched since the last [`take_delta`](Self::take_delta) /
    /// [`mark_clean`](Self::mark_clean) — sync metadata, not state (not
    /// serialized, excluded from equality).
    dirty: Vec<bool>,
}

impl<K: Ord + Clone, C: Crdt> Default for ShardedMapCrdt<K, C> {
    fn default() -> Self {
        Self {
            seed: DEFAULT_HASH_SEED,
            shards: Vec::new(),
            dirty: Vec::new(),
        }
    }
}

fn normalize_shards(n: u32) -> usize {
    (n.max(1) as usize).next_power_of_two().min(MAX_SHARDS)
}

impl<K: Ord + Clone, C: Crdt> ShardedMapCrdt<K, C> {
    /// The lattice bottom: no entries, layout not yet fixed.
    pub fn new() -> Self {
        Self::default()
    }

    /// Bottom with the layout fixed to `shards` (rounded up to a power
    /// of two) under the default hash seed.
    pub fn with_shards(shards: u32) -> Self {
        Self::with_shards_seeded(shards, DEFAULT_HASH_SEED)
    }

    /// Bottom with an explicit hash seed (must match across replicas).
    pub fn with_shards_seeded(shards: u32, seed: u64) -> Self {
        let n = normalize_shards(shards);
        Self {
            seed,
            shards: (0..n).map(|_| MapCrdt::new()).collect(),
            dirty: vec![false; n],
        }
    }

    /// Fix the layout if it is still unset (no-op otherwise — decoded or
    /// merged state keeps its layout). Called by insert paths that know
    /// the configured shard count; a bare [`entry`](Self::entry) on a
    /// bottom value defaults to a single shard.
    pub fn ensure_shards(&mut self, shards: u32) {
        if self.shards.is_empty() {
            let n = normalize_shards(shards);
            self.shards = (0..n).map(|_| MapCrdt::new()).collect();
            self.dirty = vec![false; n];
        }
    }

    /// Number of shards (0 while still at bottom).
    pub fn shard_count(&self) -> usize {
        self.shards.len()
    }

    /// The shard slices themselves (observability and tests).
    pub fn shards(&self) -> &[MapCrdt<K, C>] {
        &self.shards
    }

    /// Shards currently marked dirty.
    pub fn dirty_shards(&self) -> usize {
        self.dirty.iter().filter(|&&d| d).count()
    }

    /// Total entries across shards.
    pub fn len(&self) -> usize {
        self.shards.iter().map(|s| s.len()).sum()
    }

    pub fn is_empty(&self) -> bool {
        self.shards.iter().all(|s| s.is_empty())
    }

    fn sorted_entries(&self) -> Vec<(&K, &C)> {
        let mut v: Vec<(&K, &C)> = Vec::with_capacity(self.len());
        for s in &self.shards {
            v.extend(s.iter());
        }
        v.sort_by(|a, b| a.0.cmp(b.0));
        v
    }

    /// Iterate `(key, value)` in ascending key order across all shards —
    /// the same order a flat [`MapCrdt`] iterates, which is what keeps
    /// sharded and unsharded emission byte-identical. Allocates and
    /// sorts; order-independent consumers (max/sum folds like Q5's hot
    /// item) should use [`entries`](Self::entries) instead.
    pub fn iter(&self) -> impl Iterator<Item = (&K, &C)> {
        self.sorted_entries().into_iter()
    }

    /// Iterate `(key, value)` in unspecified (shard-major) order —
    /// allocation- and sort-free. Only for order-independent folds.
    pub fn entries(&self) -> impl Iterator<Item = (&K, &C)> {
        self.shards.iter().flat_map(|s| s.iter())
    }

    /// Apply `f` pointwise, preserving the shard layout (checkpoint
    /// slices for sharded maps).
    pub fn project_with(&self, f: impl Fn(&C) -> C) -> Self {
        Self {
            seed: self.seed,
            shards: self.shards.iter().map(|s| s.project_with(&f)).collect(),
            dirty: vec![false; self.shards.len()],
        }
    }

    /// A partial replica carrying only the shards touched since the
    /// previous call (clean shards ship as empty maps, which the encoder
    /// skips entirely). Clears the dirty markers.
    pub fn take_delta(&mut self) -> Self {
        let shards: Vec<MapCrdt<K, C>> = self
            .shards
            .iter()
            .zip(&self.dirty)
            .map(|(s, &d)| if d { s.clone() } else { MapCrdt::new() })
            .collect();
        self.dirty.fill(false);
        Self {
            seed: self.seed,
            dirty: vec![false; shards.len()],
            shards,
        }
    }

    /// Drop the dirty markers without building a delta (a full-state
    /// observer has seen everything).
    pub fn mark_clean(&mut self) {
        self.dirty.fill(false);
    }
}

impl<K: Ord + Clone + Encode, C: Crdt> ShardedMapCrdt<K, C> {
    fn shard_of(&self, key: &K) -> usize {
        // power-of-two shard count: mask instead of modulo
        (hash_key(self.seed, key) & (self.shards.len() as u64 - 1)) as usize
    }

    /// Mutable access to the inner CRDT at `key` (created at bottom),
    /// marking the key's shard dirty.
    pub fn entry(&mut self, key: K) -> &mut C {
        self.ensure_shards(1);
        let idx = self.shard_of(&key);
        self.dirty[idx] = true;
        self.shards[idx].entry(key)
    }

    pub fn get(&self, key: &K) -> Option<&C> {
        if self.shards.is_empty() {
            return None;
        }
        self.shards[self.shard_of(key)].get(key)
    }

    /// The shard a key routes to, or `None` while still at bottom (no
    /// shards materialized). The read path's signature index uses this
    /// to prune per-shard lookups without touching shard contents.
    pub fn shard_index(&self, key: &K) -> Option<usize> {
        if self.shards.is_empty() {
            None
        } else {
            Some(self.shard_of(key))
        }
    }
}

impl<K, C> ShardedMapCrdt<K, C>
where
    K: Ord + Clone + Send + Sync + Encode + Decode + 'static,
    C: Crdt + Sync,
{
    /// Pointwise join with a per-shard changed-set: `on_changed` fires
    /// once for every shard index whose state actually inflated (the
    /// trait-v3 `merge_report` hook). Dirty markers are set on exactly
    /// those shards — a no-op join (e.g. a received full-sync payload
    /// the replica already subsumes) marks nothing, so the next delta
    /// round ships nothing.
    pub fn merge_report(
        &mut self,
        other: &Self,
        mut on_changed: impl FnMut(usize),
    ) -> MergeOutcome {
        if other.shards.is_empty() {
            return MergeOutcome::Unchanged;
        }
        if self.shards.is_empty() {
            // bottom adopts the partner's layout; everything merged in
            // is new information, so every non-empty shard is dirty
            // (transitive delta propagation).
            self.seed = other.seed;
            self.shards = other.shards.clone();
            self.dirty = other.shards.iter().map(|s| !s.is_empty()).collect();
            let mut changed = false;
            for (i, d) in self.dirty.iter().enumerate() {
                if *d {
                    changed = true;
                    on_changed(i);
                }
            }
            return MergeOutcome::changed_if(changed);
        }
        if self.shards.len() == other.shards.len() && self.seed == other.seed {
            // The fast path: identical layouts join shard-by-shard —
            // disjoint key sets, so pairs are independent and large
            // joins fan out across the scoped worker pool. Each pair
            // reports its own outcome; only inflated shards dirty.
            let mut round = vec![false; self.shards.len()];
            let parallel = self.shards.len() >= PAR_MIN_SHARDS
                && self.len() + other.len() >= PAR_MIN_ENTRIES
                && exec::max_threads() > 1;
            if parallel {
                exec::merge_pairwise(
                    &mut self.shards,
                    &other.shards,
                    &mut round,
                    exec::max_threads(),
                );
            } else {
                exec::merge_pairwise(&mut self.shards, &other.shards, &mut round, 1);
            }
            exec::note_merge(parallel);
            let mut changed = false;
            for (i, &c) in round.iter().enumerate() {
                if c {
                    self.dirty[i] = true;
                    changed = true;
                    on_changed(i);
                }
            }
            return MergeOutcome::changed_if(changed);
        }
        // Layout mismatch (misconfigured replicas or a reshard in
        // flight): rehash into our layout. Slow but correct — shard
        // assignment is deterministic per layout, so this is still the
        // pointwise map join.
        let mut reported = vec![false; self.shards.len()];
        let mut changed = false;
        for shard in &other.shards {
            for (k, v) in shard.iter() {
                let idx = self.shard_of(k);
                if self.shards[idx].merge_entry(k, v).is_changed() {
                    self.dirty[idx] = true;
                    changed = true;
                    if !reported[idx] {
                        reported[idx] = true;
                        on_changed(idx);
                    }
                }
            }
        }
        exec::note_merge(false);
        MergeOutcome::changed_if(changed)
    }
}

impl<K, C> Crdt for ShardedMapCrdt<K, C>
where
    K: Ord + Clone + Send + Sync + Encode + Decode + 'static,
    C: Crdt + Sync,
{
    fn project(&self, contributor: u64) -> Self {
        self.project_with(|c| c.project(contributor))
    }

    fn merge(&mut self, other: &Self) -> MergeOutcome {
        self.merge_report(other, |_| {})
    }

    fn take_delta(&mut self) -> Self {
        ShardedMapCrdt::take_delta(self)
    }

    fn mark_clean(&mut self) {
        ShardedMapCrdt::mark_clean(self);
    }

    fn join_delta_into(&mut self, dst: &mut Self) -> MergeOutcome {
        if self.shards.is_empty() {
            return MergeOutcome::Unchanged;
        }
        if dst.shards.len() != self.shards.len() || dst.seed != self.seed {
            // bottom dst (adopts the layout) or a mismatched layout:
            // the full-state path is correct and these cases are rare
            let outcome = dst.merge(self);
            self.dirty.fill(false);
            return outcome;
        }
        // same layout: drain only the dirty shards, by reference; dst
        // dirty-marks exactly the shards its state inflated on
        let mut changed = false;
        for (i, (mine, theirs)) in dst.shards.iter_mut().zip(&self.shards).enumerate() {
            if self.dirty[i] && !theirs.is_empty() && mine.merge(theirs).is_changed() {
                dst.dirty[i] = true;
                changed = true;
            }
        }
        self.dirty.fill(false);
        exec::note_merge(false);
        MergeOutcome::changed_if(changed)
    }
}

/// Logical equality: the sorted entry set, independent of shard layout
/// and dirty markers (see module docs).
impl<K: Ord + Clone, C: Crdt + PartialEq> PartialEq for ShardedMapCrdt<K, C> {
    fn eq(&self, other: &Self) -> bool {
        self.len() == other.len() && self.sorted_entries() == other.sorted_entries()
    }
}

impl<K: Ord + Clone + Encode, C: Crdt> Encode for ShardedMapCrdt<K, C> {
    fn encode(&self, w: &mut Writer) {
        if !self.shards.is_empty() {
            note_shard_layout(self.shards.len());
        }
        w.put_u64(self.seed);
        w.put_u32(self.shards.len() as u32);
        let present = self.shards.iter().filter(|s| !s.is_empty()).count();
        w.put_u32(present as u32);
        for (i, s) in self.shards.iter().enumerate() {
            if s.is_empty() {
                continue; // absent shards decode as empty (delta payloads)
            }
            w.put_u32(i as u32);
            let before = w.len();
            w.put_nested(|w| s.encode(w));
            note_shard_bytes(i, (w.len() - before) as u64);
        }
    }
}

impl<K: Ord + Clone + Encode + Decode, C: Crdt> Decode for ShardedMapCrdt<K, C> {
    fn decode(r: &mut Reader) -> DecodeResult<Self> {
        let seed = r.get_u64()?;
        let count = r.get_u32()? as usize;
        if count > MAX_SHARDS {
            // validate before the preallocation below: a corrupted count
            // field must not turn into a multi-gigabyte Vec
            return Err(DecodeError("shard count exceeds MAX_SHARDS"));
        }
        if count > 0 && !count.is_power_of_two() {
            // shard routing masks with `count - 1`; a non-power-of-two
            // layout would silently make some shards unreachable and
            // duplicate keys across shards — fail loudly instead
            return Err(DecodeError("shard count is not a power of two"));
        }
        let present = r.get_u32()? as usize;
        if present > count {
            return Err(DecodeError("more present shards than shards"));
        }
        let mut shards: Vec<MapCrdt<K, C>> = (0..count).map(|_| MapCrdt::new()).collect();
        for _ in 0..present {
            let idx = r.get_u32()? as usize;
            if idx >= count {
                return Err(DecodeError("shard index out of range"));
            }
            if !shards[idx].is_empty() {
                return Err(DecodeError("duplicate shard index"));
            }
            let m: MapCrdt<K, C> = MapCrdt::from_bytes(r.get_bytes()?)?;
            // Routing integrity (debug builds only — this is a per-key
            // re-hash on the gossip-receive hot path): a key in the
            // wrong shard would make `get` miss it while `iter`/`len`
            // still see it, and a later `entry` would duplicate it in
            // the right shard. The structural checks above stay on in
            // release; tier-1 `cargo test` runs debug, so the sim and
            // differential suites exercise this guard.
            #[cfg(debug_assertions)]
            {
                let mask = count as u64 - 1;
                for (k, _) in m.iter() {
                    if (hash_key(seed, k) & mask) as usize != idx {
                        return Err(DecodeError("key routed to the wrong shard"));
                    }
                }
            }
            shards[idx] = m;
        }
        Ok(Self {
            seed,
            dirty: vec![false; count],
            shards,
        })
    }
}

// lint:allow-tests(discarded-merge): tests join shards for effect and assert on values and dirty-sets directly
#[cfg(test)]
mod tests {
    use super::*;
    use crate::crdt::lawcheck::{check_codec_roundtrip, check_laws};
    use crate::crdt::GCounter;
    use crate::wcrdt::{WindowAssigner, WindowedCrdt};

    fn sharded(n: u32, pairs: &[(u64, u64, u64)]) -> ShardedMapCrdt<u64, GCounter> {
        let mut m = ShardedMapCrdt::with_shards(n);
        for &(k, c, amount) in pairs {
            m.entry(k).add(c, amount);
        }
        m
    }

    fn flat(pairs: &[(u64, u64, u64)]) -> MapCrdt<u64, GCounter> {
        let mut m: MapCrdt<u64, GCounter> = MapCrdt::new();
        for &(k, c, amount) in pairs {
            m.entry(k).add(c, amount);
        }
        m
    }

    const PAIRS: &[(u64, u64, u64)] = &[(1, 0, 5), (9, 1, 3), (2, 0, 7), (17, 2, 1), (9, 0, 4)];

    #[test]
    fn laws_hold_per_shard_layout() {
        for n in [1, 4, 16] {
            let samples = vec![
                ShardedMapCrdt::with_shards(n),
                sharded(n, &PAIRS[..2]),
                sharded(n, &PAIRS[..4]),
                sharded(n, PAIRS),
            ];
            check_laws(&samples);
            check_codec_roundtrip(&samples);
        }
    }

    #[test]
    fn shard_count_normalizes_to_power_of_two() {
        assert_eq!(ShardedMapCrdt::<u64, GCounter>::with_shards(0).shard_count(), 1);
        assert_eq!(ShardedMapCrdt::<u64, GCounter>::with_shards(3).shard_count(), 4);
        assert_eq!(ShardedMapCrdt::<u64, GCounter>::with_shards(16).shard_count(), 16);
    }

    #[test]
    fn sharded_equals_flat_logically() {
        for n in [1, 2, 4, 16] {
            let s = sharded(n, PAIRS);
            let f = flat(PAIRS);
            let sv: Vec<(u64, u64)> = s.iter().map(|(&k, c)| (k, c.value())).collect();
            let fv: Vec<(u64, u64)> = f.iter().map(|(&k, c)| (k, c.value())).collect();
            assert_eq!(sv, fv, "{n} shards must iterate like the flat map");
            assert_eq!(s.get(&9).unwrap().value(), f.get(&9).unwrap().value());
            assert_eq!(s.len(), f.len());
        }
    }

    #[test]
    fn cross_layout_merge_converges_logically() {
        let mut a = sharded(4, &PAIRS[..3]);
        let b = sharded(16, &PAIRS[3..]);
        assert_eq!(a.merge(&b), MergeOutcome::Changed);
        assert_eq!(a, sharded(4, PAIRS), "rehash merge must reach the same join");
        // re-merging the cross-layout partner is now a no-op
        assert_eq!(a.merge(&b), MergeOutcome::Unchanged);
        // and equality itself is layout-independent
        assert_eq!(sharded(4, PAIRS), sharded(16, PAIRS));
    }

    #[test]
    fn bottom_adopts_layout_on_merge() {
        let mut bottom: ShardedMapCrdt<u64, GCounter> = ShardedMapCrdt::new();
        assert_eq!(bottom.shard_count(), 0);
        assert_eq!(bottom.merge(&sharded(8, PAIRS)), MergeOutcome::Changed);
        assert_eq!(bottom.shard_count(), 8);
        assert_eq!(bottom, sharded(8, PAIRS));
        assert!(bottom.dirty_shards() > 0, "merged-in shards propagate as dirty");
    }

    #[test]
    fn take_delta_carries_only_dirty_shards() {
        let mut m = sharded(8, PAIRS);
        let _ = ShardedMapCrdt::take_delta(&mut m); // drain
        assert_eq!(m.dirty_shards(), 0);
        m.entry(9).add(1, 1); // dirties exactly key 9's shard
        let d = ShardedMapCrdt::take_delta(&mut m);
        assert_eq!(m.dirty_shards(), 0);
        let populated = d.shards().iter().filter(|s| !s.is_empty()).count();
        assert_eq!(populated, 1, "delta must carry one shard");
        assert!(d.get(&9).is_some());
        // the delta round-trips through the shard-tagged codec
        let back = ShardedMapCrdt::<u64, GCounter>::from_bytes(&d.to_bytes()).unwrap();
        assert_eq!(back, d);
        // and joining the delta onto a stale replica converges it
        let mut stale = sharded(8, PAIRS);
        let _ = stale.merge(&back);
        assert_eq!(stale, m);
    }

    #[test]
    fn delta_encoding_skips_clean_shards() {
        let mut m = sharded(16, PAIRS);
        let full_bytes = m.to_bytes().len();
        let _ = ShardedMapCrdt::take_delta(&mut m);
        m.entry(2).add(0, 1);
        let delta_bytes = ShardedMapCrdt::take_delta(&mut m).to_bytes().len();
        assert!(
            delta_bytes < full_bytes,
            "delta ({delta_bytes} B) must be smaller than full state ({full_bytes} B)"
        );
    }

    #[test]
    fn merge_report_names_exactly_the_inflated_shards() {
        let mut m = sharded(8, PAIRS);
        ShardedMapCrdt::mark_clean(&mut m);
        // a partner that only extends key 9's counter
        let mut partner = sharded(8, PAIRS);
        partner.entry(9).add(1, 100);
        let nine = partner.shard_of(&9);
        let mut changed = Vec::new();
        let outcome = m.merge_report(&partner, |i| changed.push(i));
        assert_eq!(outcome, MergeOutcome::Changed);
        assert_eq!(changed, vec![nine], "only key 9's shard inflated");
        assert_eq!(m.dirty_shards(), 1, "dirty-marking follows the report");
        // re-merging the same partner: nothing inflates, nothing dirties
        ShardedMapCrdt::mark_clean(&mut m);
        let mut changed = Vec::new();
        assert_eq!(
            m.merge_report(&partner, |i| changed.push(i)),
            MergeOutcome::Unchanged
        );
        assert!(changed.is_empty());
        assert_eq!(m.dirty_shards(), 0);
    }

    #[test]
    fn noop_full_sync_merge_leaves_the_delta_empty() {
        // The amplification fix at the shard level: a received full-sync
        // payload the replica already subsumes must not re-mark shards
        // dirty — the next delta round ships nothing instead of ~full
        // state (failing before trait v3: merge marked every non-empty
        // received shard).
        let mut replica = sharded(8, PAIRS);
        let _ = ShardedMapCrdt::take_delta(&mut replica); // markers clean
        let full_sync = sharded(8, PAIRS); // identical remote full state
        assert_eq!(replica.merge(&full_sync), MergeOutcome::Unchanged);
        assert_eq!(replica.dirty_shards(), 0, "no-op join must not dirty");
        assert!(ShardedMapCrdt::take_delta(&mut replica).is_empty());
    }

    #[test]
    fn mark_clean_is_metadata_only() {
        let mut m = sharded(4, PAIRS);
        let before = m.clone();
        assert!(m.dirty_shards() > 0);
        ShardedMapCrdt::mark_clean(&mut m);
        assert_eq!(m.dirty_shards(), 0);
        assert_eq!(m, before);
        // next delta is empty-shard-only
        assert!(ShardedMapCrdt::take_delta(&mut m).is_empty());
    }

    #[test]
    fn project_slices_pointwise_per_shard() {
        let m = sharded(4, &[(1, 0, 5), (1, 1, 2), (9, 1, 3)]);
        let p = Crdt::project(&m, 1);
        assert_eq!(p.shard_count(), 4);
        assert_eq!(p.get(&1).unwrap().value(), 2);
        assert_eq!(p.get(&9).unwrap().value(), 3);
        // projection then join restores the contribution (a no-op join)
        let mut joined = m.clone();
        assert_eq!(joined.merge(&p), MergeOutcome::Unchanged);
        assert_eq!(joined, m);
    }

    #[test]
    fn parallel_merge_matches_serial_merge() {
        // enough entries to clear PAR_MIN_ENTRIES with 8 shards
        let mut big_a = ShardedMapCrdt::with_shards(8);
        let mut big_b = ShardedMapCrdt::with_shards(8);
        for k in 0..1200u64 {
            big_a.entry(k).add(k % 4, k + 1);
            big_b.entry(k * 3).add(k % 4, k + 2);
        }
        // pin the cap > 1 so the test is not flaky on single-core hosts
        exec::set_max_threads(4);
        let _ = exec::take_merge_stats(); // reset this thread's counters
        let mut par = big_a.clone();
        let _ = par.merge(&big_b);
        exec::set_max_threads(0);
        let (parallel, _serial) = exec::take_merge_stats();
        assert_eq!(parallel, 1, "large same-layout merge must use the pool");
        // serial oracle: pairwise merge without the pool
        let mut serial = big_a.clone();
        for (mine, theirs) in serial.shards.iter_mut().zip(&big_b.shards) {
            let _ = mine.merge(theirs);
        }
        serial.dirty = par.dirty.clone();
        assert_eq!(par, serial);
    }

    #[test]
    fn small_merges_stay_inline() {
        let _ = exec::take_merge_stats();
        let mut a = sharded(8, PAIRS);
        let _ = a.merge(&sharded(8, PAIRS));
        let (parallel, serial) = exec::take_merge_stats();
        assert_eq!((parallel, serial), (0, 1), "tiny merges must not spawn threads");
    }

    #[test]
    fn decode_rejects_absurd_shard_counts() {
        // a corrupted count field must fail as a DecodeError, not as a
        // multi-gigabyte preallocation
        let mut w = crate::codec::Writer::new();
        w.put_u64(DEFAULT_HASH_SEED);
        w.put_u32(u32::MAX); // shard count from a corrupted payload
        w.put_u32(0);
        assert!(ShardedMapCrdt::<u64, GCounter>::from_bytes(&w.into_bytes()).is_err());
    }

    #[test]
    fn shard_assignment_is_stable_across_replicas() {
        // the same key must land on the same shard on every replica —
        // the determinism the whole design rests on
        let a = sharded(16, PAIRS);
        let b = sharded(16, PAIRS);
        for (sa, sb) in a.shards().iter().zip(b.shards()) {
            let ka: Vec<&u64> = sa.iter().map(|(k, _)| k).collect();
            let kb: Vec<&u64> = sb.iter().map(|(k, _)| k).collect();
            assert_eq!(ka, kb);
        }
    }

    #[test]
    fn encoded_bytes_are_attributed_per_shard() {
        let _ = take_shard_encoded_bytes(); // reset
        let m = sharded(8, PAIRS);
        let _ = m.to_bytes();
        let per = take_shard_encoded_bytes();
        // the full layout is always represented (stable shard_count in
        // the bench report), with zero slots for shards that shipped
        // nothing
        assert_eq!(per.len(), 8);
        let populated = m.shards().iter().filter(|s| !s.is_empty()).count();
        assert_eq!(per.iter().filter(|&&b| b > 0).count(), populated);
        // drained: a second take reads empty
        assert!(take_shard_encoded_bytes().is_empty());
    }

    #[cfg(debug_assertions)] // the routing guard is compiled out in release
    #[test]
    fn decode_rejects_misrouted_keys() {
        // craft a payload whose only shard segment carries a key that
        // hashes to the other shard
        let mut m: ShardedMapCrdt<u64, GCounter> = ShardedMapCrdt::with_shards(2);
        m.entry(7).add(0, 1);
        let right = m.shards().iter().position(|s| !s.is_empty()).unwrap();
        let wrong = 1 - right;
        let mut w = crate::codec::Writer::new();
        w.put_u64(DEFAULT_HASH_SEED);
        w.put_u32(2);
        w.put_u32(1);
        w.put_u32(wrong as u32);
        w.put_nested(|w| m.shards()[right].encode(w));
        assert!(ShardedMapCrdt::<u64, GCounter>::from_bytes(&w.into_bytes()).is_err());
        // the healthy encoding still round-trips
        assert_eq!(ShardedMapCrdt::<u64, GCounter>::from_bytes(&m.to_bytes()).unwrap(), m);
    }

    #[test]
    fn decode_rejects_non_power_of_two_counts() {
        // routing masks with len-1: a non-pow2 layout would silently
        // strand shards, so the codec must refuse it
        let mut w = crate::codec::Writer::new();
        w.put_u64(DEFAULT_HASH_SEED);
        w.put_u32(6);
        w.put_u32(0);
        assert!(ShardedMapCrdt::<u64, GCounter>::from_bytes(&w.into_bytes()).is_err());
    }

    #[test]
    fn entries_covers_the_same_pairs_as_iter() {
        let m = sharded(8, PAIRS);
        let mut unsorted: Vec<(u64, u64)> = m.entries().map(|(&k, c)| (k, c.value())).collect();
        unsorted.sort_unstable();
        let sorted: Vec<(u64, u64)> = m.iter().map(|(&k, c)| (k, c.value())).collect();
        assert_eq!(unsorted, sorted);
    }

    #[test]
    fn join_delta_into_equals_merge_of_take_delta() {
        // the engine's reference-drain must be indistinguishable from
        // materializing the delta and merging it
        let mut src_a = sharded(8, PAIRS);
        let _ = ShardedMapCrdt::take_delta(&mut src_a); // drain construction dirt
        src_a.entry(9).add(1, 2);
        src_a.entry(2).add(0, 1);
        let mut src_b = src_a.clone();

        let mut dst_a = sharded(8, &PAIRS[..3]);
        let mut dst_b = dst_a.clone();
        let oc_a = Crdt::join_delta_into(&mut src_a, &mut dst_a);
        let oc_b = dst_b.merge(&Crdt::take_delta(&mut src_b));
        assert_eq!(dst_a, dst_b);
        assert_eq!(oc_a, oc_b, "both drain shapes report the same outcome");
        assert_eq!(src_a.dirty_shards(), 0, "drain clears the markers");
        // dst marks exactly the drained shards dirty (transitive gossip)
        assert_eq!(dst_a.dirty_shards(), dst_b.dirty_shards());
        // bottom dst adopts the layout through the fallback path
        let mut src_c = sharded(4, PAIRS);
        let mut bottom: ShardedMapCrdt<u64, GCounter> = ShardedMapCrdt::new();
        let _ = Crdt::join_delta_into(&mut src_c, &mut bottom);
        assert_eq!(bottom, sharded(4, PAIRS));
    }

    #[test]
    fn drops_into_windowed_crdt_with_per_shard_deltas() {
        // the integration the subsystem exists for: a WCRDT over sharded
        // keyed state, where window deltas carry only dirty shards
        let mut w: WindowedCrdt<ShardedMapCrdt<u64, GCounter>> =
            WindowedCrdt::new(WindowAssigner::tumbling(1000), [0, 1]);
        w.insert_with(0, 100, |m| {
            m.ensure_shards(8);
            m.entry(1).add(0, 5);
            m.entry(9).add(0, 3);
        })
        .unwrap();
        let _ = w.take_delta(); // drain both window- and shard-dirty
        w.insert_with(0, 200, |m| {
            m.entry(9).add(0, 2);
        })
        .unwrap();
        w.increment_watermark(0, 1200);
        let d = w.take_delta();
        let win = d.raw_window(0).expect("touched window in delta");
        let populated = win.shards().iter().filter(|s| !s.is_empty()).count();
        assert_eq!(populated, 1, "window delta must carry only key 9's shard");
        // replica exchange via deltas converges
        let mut replica: WindowedCrdt<ShardedMapCrdt<u64, GCounter>> =
            WindowedCrdt::new(WindowAssigner::tumbling(1000), [0, 1]);
        replica.insert_with(1, 150, |m| {
            m.ensure_shards(8);
            m.entry(1).add(1, 7);
        })
        .unwrap();
        replica.increment_watermark(1, 1200);
        let dr = replica.take_delta();
        let _ = replica.merge(&w); // full state one way
        let _ = w.merge(&dr); // delta the other
        assert_eq!(replica, w);
        let v = w.window_value(0).unwrap();
        assert_eq!(v.get(&1).unwrap().value(), 12);
        assert_eq!(v.get(&9).unwrap().value(), 5);
    }
}
