//! The node main loop — Algorithm 2 (execution, checkpointing, work
//! stealing) plus the control-plane duties of Figure 5's node (heartbeat
//! broadcasting, failure detection, gossip).
//!
//! One OS thread per node. Every iteration:
//!
//! 1. drain the control/broadcast bus (heartbeats → membership, gossip →
//!    CRDT join, claims → ownership view);
//! 2. broadcast a heartbeat when due;
//! 3. reconcile ownership against the rendezvous target assignment —
//!    steal (RECOVER) partitions that now target this node, release
//!    partitions whose rightful owner has claimed them;
//! 4. for each owned partition (in rotated order, so service-budget
//!    exhaustion never starves the same partitions): run the processing
//!    function over the input log's record slice in place (zero-copy),
//!    append outputs (tagged `(partition, seq)`), advance offsets — the
//!    paper's `RUN_BATCH`;
//! 5. gossip the shared-state replica when due ("state is asynchronously
//!    shuffled in the background", §2.5);
//! 6. checkpoint owned partitions when due (`storage.PUT`);
//! 7. compact windows far below the global watermark.
//!
//! A killed node (failure injection) exits before step 4 without a
//! final checkpoint; its partitions are stolen by survivors after the
//! heartbeat timeout.

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;

use crate::api::{Ctx, Processor, SharedState};
use crate::arena::OutputArena;
use crate::clock::SimClock;
use crate::codec::{Decode, Encode, Reader, Writer};
use crate::config::HolonConfig;
use crate::log::Topic;
use crate::net::{Bus, MsgKind};
use crate::storage::{CheckpointStore, PartitionCheckpoint};
use crate::trace::{TraceHandle, TraceKind};
use crate::util::{LockExt, NodeId, PartitionId, SimTime, XorShift64};

use super::membership::{target_owner, Membership};
use super::ClusterMetrics;

/// Every Nth gossip round sends full state instead of a delta
/// (anti-entropy against dropped messages and fan-out gaps). Crate-
/// visible because the changefeed retention default derives from it
/// (see `engine::effective_changefeed_retention`).
pub(crate) const FULL_SYNC_EVERY: u64 = 10;

/// What one gossip round does: payload shape and effective fan-out.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
struct GossipPlan {
    /// Encode full state (and drop the dirty markers) vs a delta.
    full: bool,
    /// Peers to sample (0 = broadcast to all).
    fanout: usize,
}

/// Decide the round's shape. The delta-mode full-sync interaction is
/// load-bearing: a full-sync round *must* reach every peer before the
/// dirty markers drop, because the markers are the only record of what
/// un-sampled peers have not seen. The pre-fix code kept the configured
/// fan-out on full-sync rounds and compensated by never calling
/// `mark_clean()` when `gossip_fanout > 0` — which left full-state
/// rounds unable to bound the dirty set (it regrew between delta
/// drains forever) and, worse, left sampled-out peers reliant on
/// transitive deltas alone with no true anti-entropy round at all.
/// Forcing fanout = all on delta-mode full-sync rounds makes
/// `mark_clean()` unconditionally sound there.
fn gossip_plan(delta_enabled: bool, fanout: usize, round: u64) -> GossipPlan {
    if !delta_enabled {
        // full state every round; sampling is fine (transitive
        // convergence), and the markers have no reader — mark_clean
        // merely bounds their growth.
        return GossipPlan { full: true, fanout };
    }
    if round % FULL_SYNC_EVERY == 0 {
        GossipPlan { full: true, fanout: 0 } // anti-entropy: everyone
    } else {
        GossipPlan { full: false, fanout }
    }
}

/// How many windows behind the watermark floor we keep before compacting
/// (the recovery horizon: a restarted/stealing node must still find the
/// windows its checkpoint cursor points at).
const COMPACTION_HORIZON_WINDOWS: u64 = 16;

/// Everything a node thread needs.
pub struct NodeCtx<P: Processor> {
    pub id: NodeId,
    pub cfg: HolonConfig,
    pub clock: SimClock,
    pub input: Arc<Topic>,
    pub output: Arc<Topic>,
    pub bus: Bus,
    pub store: CheckpointStore,
    pub processor: P,
    pub shutdown: Arc<AtomicBool>,
    pub failed: Arc<AtomicBool>,
    pub metrics: ClusterMetrics,
    /// Where to publish the encoded shared replica on graceful shutdown
    /// (the convergence oracle's view; killed nodes never publish).
    pub state_out: Arc<std::sync::Mutex<BTreeMap<NodeId, Vec<u8>>>>,
    /// Changefeed publication point: every gossip payload this node
    /// encodes (full state or delta) is also published here for read-path
    /// subscribers, at zero extra encode cost (shared `Arc`).
    pub reads: crate::query::ReadHandle,
    /// Flight-recorder endpoint (a single branch per record call when
    /// tracing is disabled — the instrumentation stays in permanently).
    pub trace: TraceHandle,
}

/// Execution state of one owned partition.
struct PartState<S, L> {
    nxt_idx: u64,
    nxt_odx: u64,
    /// The partition's own contribution accumulator (checkpointed
    /// verbatim; joined into the node replica after every batch).
    own: S,
    local: L,
    /// Per-batch output arena (reused across batches; its high-water
    /// pre-reserve keeps the steady-state emit path allocation-free).
    arena: OutputArena,
    last_ckpt: SimTime,
    /// `(nxt_idx, nxt_odx)` at the last checkpoint put — together with
    /// `own.dirty_windows() == 0` this gates the skip-re-encode fast
    /// path: the store rejects same-`nxt_idx` puts anyway (deterministic
    /// execution makes them byte-identical), so when nothing moved we
    /// skip the encode too instead of serializing state just to have the
    /// put refused.
    last_put: Option<(u64, u64)>,
    /// When this partition was stolen/recovered — consumed by the first
    /// finished output batch to close the recovery timeline in the
    /// flight recorder (`TraceKind::FirstOutput`).
    recovered_at: Option<SimTime>,
}

/// Encode an output record payload: (seq, ref_ts, inner). The arena
/// path ([`OutputArena::frame`]) produces these same bytes in place;
/// this free function remains for the baseline and for tests/oracles.
pub fn encode_output(seq: u64, ref_ts: SimTime, inner: &[u8]) -> Vec<u8> {
    let mut w = Writer::with_capacity(inner.len() + 20);
    w.put_u64(seq);
    w.put_u64(ref_ts);
    w.put_bytes(inner);
    w.into_bytes()
}

/// Decode an output record payload; returns (seq, ref_ts, inner). The
/// inner payload is *borrowed* from the record bytes — consumers (sink
/// dedup, oracles) read it in place, no per-record copy.
pub fn decode_output(bytes: &[u8]) -> Option<(u64, SimTime, &[u8])> {
    let mut r = Reader::new(bytes);
    let seq = r.get_u64().ok()?;
    let ref_ts = r.get_u64().ok()?;
    let inner = r.get_bytes().ok()?;
    Some((seq, ref_ts, inner))
}

/// Heartbeat payload: the sender's advertised inbox credits (free inbox
/// slots; `u64::MAX` = unbounded). Riding the existing heartbeat path
/// means backpressure needs no extra message kind or cadence.
fn encode_heartbeat(credits: u64) -> Vec<u8> {
    let mut w = Writer::with_capacity(8);
    w.put_u64(credits);
    w.into_bytes()
}

/// Empty/short payloads (older nodes, the startup announce) decode as
/// `None` = no credit information = treat the peer as unbounded.
fn decode_heartbeat(bytes: &[u8]) -> Option<u64> {
    let mut r = Reader::new(bytes);
    r.get_u64().ok()
}

fn encode_claim(p: PartitionId, ts: SimTime) -> Vec<u8> {
    let mut w = Writer::new();
    w.put_u32(p);
    w.put_u64(ts);
    w.into_bytes()
}

fn decode_claim(bytes: &[u8]) -> Option<(PartitionId, SimTime)> {
    let mut r = Reader::new(bytes);
    Some((r.get_u32().ok()?, r.get_u64().ok()?))
}

/// Encode one gossip round's payload — the full replica or the pending
/// delta — into a fresh pre-sized buffer. Full rounds drop the dirty
/// markers afterwards: every peer is about to see the full state
/// (delta-mode full-sync forces fanout = all; non-delta mode has no
/// delta reader at all), so no peer's missing windows are lost.
// lint: zero-alloc
fn encode_gossip_round<S: SharedState>(shared: &mut S, full: bool, size_hint: usize) -> Writer {
    let mut w = Writer::with_capacity(size_hint);
    if full {
        shared.encode(&mut w);
        shared.mark_clean();
    } else {
        shared.take_delta().encode(&mut w);
    }
    w
}

fn encode_checkpoint_state<S: Encode, L: Encode>(local: &L, own: &S) -> Vec<u8> {
    // Single-pass nested encode: byte-identical to the old
    // put_bytes(&x.to_bytes()) layout without materializing the two
    // intermediate vectors per checkpoint.
    let mut w = Writer::new();
    w.put_nested(|w| local.encode(w));
    w.put_nested(|w| own.encode(w));
    w.into_bytes()
}

fn decode_checkpoint_state<S: Decode, L: Decode>(bytes: &[u8]) -> Option<(L, S)> {
    let mut r = Reader::new(bytes);
    let local = L::from_bytes(r.get_bytes().ok()?).ok()?;
    let own = S::from_bytes(r.get_bytes().ok()?).ok()?;
    Some((local, own))
}

/// Node thread entrypoint.
pub fn node_main<P: Processor>(ctx: NodeCtx<P>) {
    let NodeCtx {
        id,
        cfg,
        clock,
        input,
        output,
        bus,
        store,
        processor,
        shutdown,
        failed,
        metrics,
        state_out,
        reads,
        trace,
    } = ctx;

    let all_parts: Vec<PartitionId> = (0..cfg.partitions).collect();
    let mut shared = processor.init_shared(&all_parts);
    let mut membership = Membership::new(id, cfg.failure_timeout_ms, clock.now());
    let mut claims: BTreeMap<PartitionId, (NodeId, SimTime)> = BTreeMap::new();
    let mut parts: BTreeMap<PartitionId, PartState<P::Shared, P::Local>> = BTreeMap::new();
    let mut aggregator = crate::runtime::make_aggregator(&cfg);
    let mut rng = XorShift64::new(cfg.seed ^ (0xA11CE + id as u64));

    // Stagger periodic work so nodes don't phase-lock.
    let mut last_hb: SimTime = 0;
    let mut last_gossip: SimTime = rng.next_below(cfg.gossip_interval_ms.max(1));
    let mut gossip_round: u64 = rng.next_below(FULL_SYNC_EVERY);
    // cached rendezvous assignment (invalidated on membership change)
    let mut last_alive: Vec<NodeId> = Vec::new();
    let mut targets: BTreeMap<PartitionId, NodeId> = BTreeMap::new();
    // service-cost model: a node processes at most 1e6/cost events per
    // sim-second (calibrated from the paper's measured throughput);
    // the budget accrues with sim-time and is spent per event.
    let mut budget_events: f64 = 0.0;
    let mut last_budget_at: SimTime = clock.now();
    // RUN_BATCH fairness: the partition the budgeted pass starts from
    // rotates each round so budget exhaustion doesn't starve the same
    // (high-numbered) partitions every iteration.
    let mut batch_rotation: usize = 0;
    let mut batch_order: Vec<PartitionId> = Vec::new();
    // reusable gossip encode target: size hint from the previous round
    // so each round is one exact allocation into the shared Arc.
    let mut gossip_size_hint: usize = 0;
    // Backpressure state: last advertised credits per peer (absent =
    // unknown = unbounded) and how much the last flush left parked.
    let mut peer_credits: BTreeMap<NodeId, u64> = BTreeMap::new();
    let mut parked_last_flush: u64 = 0;
    // Stage-latency fire tracking: the watermark floor as of the last
    // iteration — every window end it passes this iteration *fired*.
    let mut last_floor: SimTime = 0;

    // Announce ourselves, then wait one heartbeat round before claiming
    // anything: peers' announcements arrive during the grace period, so
    // the first ownership reconciliation sees the real membership
    // instead of every node transiently claiming every partition.
    // Each announce is flushed immediately — the bus is enqueue-only
    // until flush, and the grace-period sleep must cover in-flight
    // delivery, not shift it.
    bus.broadcast(id, MsgKind::Heartbeat, encode_heartbeat(bus.advertised_credits(id)));
    bus.flush(id);
    membership.refresh_self(clock.now());
    clock.sleep(cfg.heartbeat_interval_ms.max(2 * (cfg.net_delay_ms + cfg.net_jitter_ms)));
    {
        let now = clock.now();
        for msg in bus.recv(id) {
            membership.heard_from(msg.from, now);
        }
        bus.broadcast(id, MsgKind::Heartbeat, encode_heartbeat(bus.advertised_credits(id)));
        bus.flush(id);
        membership.refresh_self(now);
    }

    loop {
        if failed.load(Ordering::Acquire) {
            // Simulated crash: drop everything on the floor.
            return;
        }
        let now = clock.now();
        if shutdown.load(Ordering::Acquire) {
            // Graceful stop: final checkpoints + publish the replica for
            // post-run convergence checks. The changefeed gets the same
            // bytes as a final full snapshot so late subscribers can
            // still bootstrap to the node's last state.
            for (&p, st) in parts.iter_mut() {
                checkpoint_partition(&store, p, st, &trace, now);
            }
            let bytes = shared.to_bytes();
            let floor = shared.watermark_floor();
            let wm = if floor == SimTime::MAX { 0 } else { floor };
            reads.publish_full(Arc::new(bytes.clone()), wm);
            state_out.plane_lock().insert(id, bytes);
            return;
        }

        // 1. Drain control/broadcast messages.
        for msg in bus.recv(id) {
            match msg.kind {
                MsgKind::Heartbeat => {
                    if let Some(credits) = decode_heartbeat(&msg.payload) {
                        peer_credits.insert(msg.from, credits);
                    }
                    membership.heard_from(msg.from, now);
                }
                MsgKind::Gossip => {
                    if let Ok(other) = P::Shared::from_bytes(&msg.payload) {
                        // Change-reporting join (trait v3): only units
                        // that actually inflated were marked dirty, so a
                        // received full-sync payload we already subsume
                        // costs nothing on the next delta round — and
                        // the outcome feeds the redundancy counters.
                        if shared.join(&other).is_changed() {
                            metrics.merge_changed.fetch_add(1, Ordering::Relaxed);
                            trace.record(
                                now,
                                TraceKind::DeltaMerged,
                                msg.from as u64,
                                msg.payload.len() as u64,
                                0,
                            );
                        } else {
                            metrics.merge_noop.fetch_add(1, Ordering::Relaxed);
                            metrics
                                .redundant_gossip_bytes
                                .fetch_add(msg.payload.len() as u64, Ordering::Relaxed);
                            trace.record(
                                now,
                                TraceKind::MergeNoop,
                                msg.from as u64,
                                msg.payload.len() as u64,
                                0,
                            );
                        }
                    }
                    membership.heard_from(msg.from, now);
                }
                MsgKind::Claim => {
                    if let Some((p, ts)) = decode_claim(&msg.payload) {
                        let e = claims.entry(p).or_insert((msg.from, ts));
                        if ts >= e.1 {
                            *e = (msg.from, ts);
                        }
                    }
                    membership.heard_from(msg.from, now);
                }
            }
        }

        // 2. Heartbeat, carrying this node's advertised inbox credits
        // (free inbox space) so senders can throttle before shedding.
        if now.saturating_sub(last_hb) >= cfg.heartbeat_interval_ms {
            bus.broadcast(id, MsgKind::Heartbeat, encode_heartbeat(bus.advertised_credits(id)));
            membership.refresh_self(now);
            last_hb = now;
        }

        // 3. Reconcile ownership with the rendezvous assignment. The
        // target map is a pure function of the alive set — recompute it
        // only when membership changes (O(P·N) hashing per loop was the
        // top CPU consumer at 100 nodes; see §Perf).
        let alive = membership.alive(now);
        if alive != last_alive {
            targets.clear();
            for &p in &all_parts {
                targets.insert(p, target_owner(p, &alive));
            }
            last_alive = alive;
        }
        for &p in &all_parts {
            let target = targets[&p];
            let owned = parts.contains_key(&p);
            if target == id && !owned {
                trace.record(now, TraceKind::StealStart, p as u64, 0, 0);
                let st = recover_partition::<P>(
                    &store, &processor, &all_parts, &mut shared, p, now, &metrics, &trace,
                );
                parts.insert(p, st);
                bus.broadcast(id, MsgKind::Claim, encode_claim(p, now));
                metrics.steals.fetch_add(1, Ordering::Relaxed);
            } else if target != id && owned {
                // Release only after the rightful owner has claimed it —
                // overlap is safe, a gap is merely slow.
                let claimed = claims
                    .get(&p)
                    .map_or(false, |&(n, ts)| n == target && now.saturating_sub(ts) <= 2 * cfg.failure_timeout_ms);
                if claimed {
                    let mut st = parts.remove(&p).unwrap();
                    checkpoint_partition(&store, p, &mut st, &trace, now);
                }
            }
        }

        // 4. RUN_BATCH per owned partition (bounded by the service-cost
        // budget; excess input queues in the log = backpressure).
        if cfg.holon_event_cost_us > 0.0 {
            let dt = now.saturating_sub(last_budget_at);
            let cap = 4.0 * cfg.batch_size as f64 * parts.len().max(1) as f64;
            budget_events =
                (budget_events + dt as f64 * 1000.0 / cfg.holon_event_cost_us).min(cap);
        } else {
            budget_events = f64::MAX;
        }
        last_budget_at = now;
        // Credit-based backpressure: when a peer advertised zero credits
        // or our last flush had to park traffic, shrink the accrued
        // burst headroom to one batch per partition. This throttles the
        // *source* of new events (excess input stays queued in the log),
        // never the gossip/ack machinery — exactly-once is cursor-based
        // and unaffected. The shrink is gentle by design: steady-state
        // throughput (one batch per partition per iteration) is
        // preserved, only the 4x catch-up burst is surrendered, so a
        // slowed receiver degrades writers to bounded lag, not a stall.
        if cfg.inbox_capacity > 0
            && (parked_last_flush > 0
                || last_alive
                    .iter()
                    .any(|&n| n != id && peer_credits.get(&n) == Some(&0)))
        {
            let tight = (cfg.batch_size * parts.len().max(1)) as f64;
            if budget_events > tight {
                budget_events = tight;
            }
            metrics.credits_stalled_rounds.fetch_add(1, Ordering::Relaxed);
            trace.record(now, TraceKind::Backpressure, parked_last_flush, tight as u64, 0);
        }
        let mut did_work = false;
        // Budgeted pass in rotated partition order: under sustained
        // budget pressure a fixed (BTreeMap) order spends the whole
        // budget on the lowest-numbered partitions every round; their
        // starved peers stall the global watermark min. Rotating the
        // starting partition keeps per-partition progress within one
        // batch of each other.
        batch_order.clear();
        batch_order.extend(parts.keys().copied());
        let nparts = batch_order.len();
        for i in 0..nparts {
            let p = batch_order[(batch_rotation + i) % nparts];
            let st = parts.get_mut(&p).unwrap();
            let allowed = cfg.batch_size.min(budget_events as usize);
            if allowed == 0 {
                break;
            }
            // Zero-copy RUN_BATCH: the processor runs over the log's
            // record slice in place — no per-poll Vec<Record>, no
            // payload Arc bumps — and emits into the partition's
            // reusable output arena (≤1 allocation per batch: the
            // high-water pre-reserve). (Always invoke the processor: an
            // empty batch still lets it emit windows completed by
            // freshly merged gossip.)
            st.arena.begin_batch();
            let arena = &mut st.arena;
            let own = &mut st.own;
            let local = &mut st.local;
            let (consumed, nxt_idx) = input.read_slice(p, st.nxt_idx, allowed, |recs| {
                // Stage-ingest latency: how long the batch's oldest
                // record sat queued in the input log before pickup (one
                // sample per batch — the oldest bounds the rest).
                if let Some(first) = recs.first() {
                    metrics.stage_ingest.record(now.saturating_sub(first.insert_ts));
                }
                let mut pctx = Ctx::new(p, now, aggregator.as_mut(), arena);
                processor.process(&mut pctx, &shared, own, local, recs);
                recs.len()
            });
            budget_events -= consumed as f64;
            // Drain only what this batch touched (own's dirty windows,
            // and within them only the changed sub-state) into the
            // replica, by reference — no delta materialization on the
            // hot path. Joining the whole accumulator re-marked every
            // live window and shard dirty in `shared` each iteration,
            // which made delta gossip re-ship the entire keyed state
            // every round — defeating per-shard deltas on the engine
            // path. An empty batch cannot mutate `own` (no inserts, no
            // watermark bump), so skip the drain entirely; recovery
            // joins the full accumulator already.
            if consumed > 0 {
                // The outcome feeds the merge-effectiveness counters: a
                // `Changed` drain is a batch that contributed fresh
                // state, a no-op drain a batch whose contribution the
                // replica already subsumed (steal replay).
                metrics.note_join(st.own.join_delta_into(&mut shared));
            } else {
                // contract (documented on Processor::process): an empty
                // batch must not mutate `own` — anything it wrote here
                // (a window insert OR a watermark bump) would sit
                // undrained until the next consuming batch, and a
                // drained partition might never have one
                debug_assert!(
                    !st.own.has_delta(),
                    "processor mutated `own` on an empty batch"
                );
            }
            // Ship the batch's outputs: seq numbers are backpatched into
            // the frames, then the whole batch appends as views over one
            // shared backing — zero payload copies end to end.
            if let Some(batch) = st.arena.finish(st.nxt_odx) {
                st.nxt_odx += batch.frames.len() as u64;
                if trace.enabled() {
                    let span = batch.frames.first().map_or(0, |f| f.ref_ts);
                    trace.record(
                        now,
                        TraceKind::WindowEmitted,
                        span,
                        batch.frames.len() as u64,
                        batch.backing.len() as u64,
                    );
                    // Recovery timeline close: first batch of outputs
                    // after a steal/restore marks the partition live.
                    if let Some(t0) = st.recovered_at.take() {
                        trace.record(
                            now,
                            TraceKind::FirstOutput,
                            p as u64,
                            now.saturating_sub(t0),
                            0,
                        );
                    }
                }
                output.append_frames(p, &batch);
                st.arena.recycle(batch);
            }
            let (arena_bytes, arena_frames) = st.arena.take_totals();
            if arena_frames > 0 {
                metrics.output_arena_bytes.fetch_add(arena_bytes, Ordering::Relaxed);
                metrics.output_frames.fetch_add(arena_frames, Ordering::Relaxed);
            }
            if consumed > 0 {
                st.nxt_idx = nxt_idx;
                metrics.processed.bump(now, consumed as u64);
                did_work = true;
            }
        }
        batch_rotation = batch_rotation.wrapping_add(1);

        // Stage-fire latency: every window boundary the global watermark
        // floor passed since the last iteration just became fireable.
        // `now - window_end` is how long the window waited between
        // closing (event-time end) and the cluster agreeing it is
        // complete — the coordination-lag component of end-to-end
        // latency. Capped at 32 boundaries per iteration so a huge floor
        // jump (recovery catch-up) cannot turn this into an O(windows)
        // scan; the skipped boundaries fired in the same instant anyway.
        let floor = shared.watermark_floor();
        if floor != SimTime::MAX && cfg.window_ms > 0 && floor > last_floor {
            trace.record(now, TraceKind::WatermarkAdvanced, floor, last_floor, 0);
            let mut wend = (last_floor / cfg.window_ms + 1) * cfg.window_ms;
            let mut steps = 0;
            while wend <= floor && steps < 32 {
                metrics.stage_fire.record(now.saturating_sub(wend));
                trace.record(now, TraceKind::WindowFired, wend, now.saturating_sub(wend), 0);
                wend += cfg.window_ms;
                steps += 1;
            }
            last_floor = floor;
        }

        // 5. Gossip the shared replica (sampled fan-out when configured;
        // delta payloads with periodic full anti-entropy when enabled).
        if now.saturating_sub(last_gossip) >= cfg.gossip_interval_ms {
            gossip_round += 1;
            let plan = gossip_plan(
                cfg.gossip_delta,
                cfg.effective_gossip_fanout(),
                gossip_round,
            );
            if !plan.full && !shared.has_delta() {
                // Empty-delta fast path: nothing dirty and no watermark
                // movement since the last drain — the delta would carry
                // no information, so skip the encode AND the broadcast
                // (the round still counts toward the full-sync cadence,
                // which keeps anti-entropy flowing on idle replicas).
                metrics.gossip_skipped.fetch_add(1, Ordering::Relaxed);
                trace.record(now, TraceKind::GossipSkipped, gossip_round, 0, 0);
            } else {
                // Discard per-shard byte samples accumulated by
                // checkpoint encodes on this thread, so the drain below
                // attributes gossip bytes only.
                let _ = crate::shard::take_shard_encoded_bytes();
                // Encode once per round into an Arc shared by every
                // recipient; the previous round's size pre-sizes the
                // buffer so a round is a single exact allocation (the
                // payload used to be re-wrapped per broadcast call and,
                // before that, cloned per recipient).
                let w = encode_gossip_round(&mut shared, plan.full, gossip_size_hint);
                gossip_size_hint = w.len();
                metrics.add_shard_gossip_bytes(&crate::shard::take_shard_encoded_bytes());
                let payload = Arc::new(w.into_bytes());
                metrics
                    .gossip_payload_bytes
                    .fetch_add(payload.len() as u64, Ordering::Relaxed);
                trace.record(
                    now,
                    TraceKind::GossipRound,
                    gossip_round,
                    payload.len() as u64,
                    plan.full as u64,
                );
                // Changefeed: subscribers ride the gossip encode — same
                // Arc, no extra serialization. Full rounds double as
                // bootstrap snapshots for late/lagging subscribers.
                let floor = shared.watermark_floor();
                let wm = if floor == SimTime::MAX { 0 } else { floor };
                if plan.full {
                    reads.publish_full(Arc::clone(&payload), wm);
                } else {
                    reads.publish_delta(Arc::clone(&payload), wm);
                }
                metrics
                    .changefeed_lag
                    .fetch_max(reads.max_lag(), Ordering::Relaxed);
                bus.broadcast_sample_shared(id, MsgKind::Gossip, payload, plan.fanout);
                metrics.gossip_sent.fetch_add(1, Ordering::Relaxed);
            }
            last_gossip = now;

            // 7. Compaction, piggybacked on the gossip cadence: drop
            // windows far below the watermark floor.
            let floor = shared.watermark_floor();
            if floor != SimTime::MAX && cfg.window_ms > 0 {
                let horizon = (floor / cfg.window_ms).saturating_sub(COMPACTION_HORIZON_WINDOWS);
                if horizon > 0 {
                    shared.compact_below(horizon);
                    for (_, st) in parts.iter_mut() {
                        st.own.compact_below(horizon);
                    }
                }
            }
        }

        // 6. Periodic checkpoints (staggered per partition via last_ckpt).
        for (&p, st) in parts.iter_mut() {
            if now.saturating_sub(st.last_ckpt) >= cfg.checkpoint_interval_ms {
                checkpoint_partition(&store, p, st, &trace, now);
                st.last_ckpt = now;
            }
        }

        // Attribute this thread's sharded-state merges (gossip joins,
        // post-batch own-joins) to the cluster counters. Thread-local
        // drain: `Crdt::merge` has no metrics handle.
        let (par, ser) = crate::shard::exec::take_merge_stats();
        if par + ser > 0 {
            metrics.shard_parallel_merges.fetch_add(par, Ordering::Relaxed);
            metrics.shard_serial_merges.fetch_add(ser, Ordering::Relaxed);
        }
        // Same drain pattern for out-of-horizon window-ring spills
        // (inserts the O(1) dense ring couldn't take): ~0 in a healthy
        // deployment, so a nonzero rate flags lateness/compaction skew.
        let spills = crate::wcrdt::ring::take_ring_spills();
        if spills > 0 {
            metrics.window_ring_spills.fetch_add(spills, Ordering::Relaxed);
        }
        // And for window opens (first local contribution to a window):
        // one summary event per iteration — span = newest opened
        // window's end, detail = count, aux = oldest opened window's
        // end — instead of one event per open, keeping the hot insert
        // path at a thread-local Cell update.
        let (opened, oldest, newest) = crate::wcrdt::take_window_opens();
        if opened > 0 && cfg.window_ms > 0 {
            trace.record(
                now,
                TraceKind::WindowOpened,
                (newest + 1).saturating_mul(cfg.window_ms),
                opened,
                (oldest + 1).saturating_mul(cfg.window_ms),
            );
        }
        // Fold this node's ring overwrites into the cluster counter so
        // the bench/validator surface sees trace loss explicitly.
        let tdrops = trace.take_dropped();
        if tdrops > 0 {
            metrics.trace_dropped_events.fetch_add(tdrops, Ordering::Relaxed);
        }

        // Flush the whole iteration's sends (heartbeat, claims, gossip)
        // as one batch: a single RNG critical section for all of it, and
        // the parked count feeds the next iteration's budget shrink.
        parked_last_flush = if trace.enabled() {
            // Traced flush: per-peer outcome events (span = peer id,
            // detail = delivered, aux = parked<<32 | dropped) ride the
            // same single flush pass via the callback.
            bus.flush_with(id, |to, pf| {
                trace.record(
                    now,
                    TraceKind::PeerFlush,
                    to as u64,
                    pf.delivered,
                    (pf.parked.min(u32::MAX as u64) << 32) | pf.dropped.min(u32::MAX as u64),
                );
            })
            .parked
        } else {
            bus.flush(id).parked
        };
        // Mirror bus-level backpressure observability into the cluster
        // counters (bus totals, so `store`/`fetch_max` are idempotent
        // across nodes).
        let drops = bus.drop_stats();
        metrics.dropped_partition.store(drops.partition, Ordering::Relaxed);
        metrics.dropped_loss.store(drops.loss, Ordering::Relaxed);
        metrics.dropped_no_inbox.store(drops.no_inbox, Ordering::Relaxed);
        metrics.dropped_backpressure.store(drops.backpressure, Ordering::Relaxed);
        metrics
            .outbound_queue_depth_max
            .fetch_max(bus.outbound_depth_max(), Ordering::Relaxed);
        metrics
            .inbox_depth_max
            .fetch_max(bus.inbox_depth_max(), Ordering::Relaxed);

        if !did_work {
            clock.sleep(cfg.poll_interval_ms);
        }
    }
}

fn checkpoint_partition<S: SharedState, L: Encode>(
    store: &CheckpointStore,
    p: PartitionId,
    st: &mut PartState<S, L>,
    trace: &TraceHandle,
    now: SimTime,
) {
    // Skip the re-encode when nothing moved since the last put: offsets
    // unchanged and no window of the contribution accumulator touched.
    // This is behavior-preserving, not just cheap — the store already
    // rejects a put whose `nxt_idx` matches the stored checkpoint
    // (deterministic execution makes such checkpoints byte-identical),
    // so all the skip removes is serializing state for a refused put.
    if st.last_put == Some((st.nxt_idx, st.nxt_odx)) && st.own.dirty_windows() == 0 {
        return;
    }
    let state = encode_checkpoint_state(&st.local, &st.own);
    trace.record(now, TraceKind::Checkpoint, p as u64, state.len() as u64, st.nxt_idx);
    st.own.mark_clean();
    st.last_put = Some((st.nxt_idx, st.nxt_odx));
    store.put(
        p,
        PartitionCheckpoint {
            nxt_idx: st.nxt_idx,
            nxt_odx: st.nxt_odx,
            state,
        },
    );
}

fn recover_partition<P: Processor>(
    store: &CheckpointStore,
    processor: &P,
    all_parts: &[PartitionId],
    shared: &mut P::Shared,
    p: PartitionId,
    now: SimTime,
    metrics: &ClusterMetrics,
    trace: &TraceHandle,
) -> PartState<P::Shared, P::Local> {
    if let Some(cp) = store.get(p) {
        if let Some((local, own)) = decode_checkpoint_state::<P::Shared, P::Local>(&cp.state) {
            // The recovered contribution re-joins the replica; if newer
            // state already arrived via gossip the join is a no-op —
            // the counters record which case this recovery hit.
            metrics.note_join(shared.join(&own));
            metrics.recoveries.fetch_add(1, Ordering::Relaxed);
            trace.record(now, TraceKind::CheckpointRestore, p as u64, cp.nxt_idx, cp.nxt_odx);
            return PartState {
                nxt_idx: cp.nxt_idx,
                nxt_odx: cp.nxt_odx,
                own,
                local,
                arena: OutputArena::new(),
                last_ckpt: now,
                // the store holds exactly this state; skip re-encoding
                // until the partition actually moves
                last_put: Some((cp.nxt_idx, cp.nxt_odx)),
                recovered_at: Some(now),
            };
        }
    }
    // Fresh partition (initial assignment before any checkpoint).
    PartState {
        nxt_idx: 0,
        nxt_odx: 0,
        own: processor.init_shared(all_parts),
        local: P::Local::default(),
        arena: OutputArena::new(),
        last_ckpt: now,
        last_put: None,
        recovered_at: Some(now),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn output_codec_roundtrip() {
        let b = encode_output(7, 123, &[1, 2, 3]);
        let (seq, ts, inner) = decode_output(&b).unwrap();
        assert_eq!((seq, ts, inner), (7, 123, &[1u8, 2, 3][..]));
    }

    #[test]
    fn claim_codec_roundtrip() {
        let b = encode_claim(9, 555);
        assert_eq!(decode_claim(&b), Some((9, 555)));
    }

    #[test]
    fn heartbeat_codec_roundtrip_and_legacy_empty() {
        assert_eq!(decode_heartbeat(&encode_heartbeat(42)), Some(42));
        assert_eq!(decode_heartbeat(&encode_heartbeat(u64::MAX)), Some(u64::MAX));
        // the startup announce / older nodes send no payload: no credit
        // info, peer treated as unbounded
        assert_eq!(decode_heartbeat(&[]), None);
    }

    #[test]
    fn output_decode_rejects_garbage() {
        assert!(decode_output(&[1, 2]).is_none());
    }

    /// Regression for the delta-mode full-sync/fanout interaction
    /// (ROADMAP item): before the fix, a delta-mode full-sync round
    /// kept the configured fan-out, so the full state reached only a
    /// sample of peers and `mark_clean()` had to be skipped — failing
    /// this assertion — leaving the dirty set to regrow between delta
    /// drains forever and the un-sampled peers without any true
    /// anti-entropy round.
    #[test]
    fn delta_full_sync_rounds_broadcast_to_all() {
        for round in [0, FULL_SYNC_EVERY, 7 * FULL_SYNC_EVERY] {
            let plan = gossip_plan(true, 3, round);
            assert!(plan.full, "round {round} is a full-sync round");
            assert_eq!(plan.fanout, 0, "full sync must reach every peer");
        }
    }

    #[test]
    fn delta_rounds_keep_the_sampled_fanout() {
        for round in [1, FULL_SYNC_EVERY + 1, FULL_SYNC_EVERY - 1] {
            let plan = gossip_plan(true, 3, round);
            assert_eq!(plan, GossipPlan { full: false, fanout: 3 });
        }
    }

    #[test]
    fn non_delta_rounds_are_full_and_sampled() {
        // full state every round; sampling is safe (transitive
        // convergence) and cheap
        for round in 0..3 {
            assert_eq!(gossip_plan(false, 4, round), GossipPlan { full: true, fanout: 4 });
            assert_eq!(gossip_plan(false, 0, round), GossipPlan { full: true, fanout: 0 });
        }
    }
}
