//! The deduplicating output consumer (§3.3): outputs may be physically
//! duplicated (replay after steal/restart); a consumer maintaining a map
//! from partitions to sequence numbers deduplicates them. This sink is
//! that consumer — it also records the end-to-end latency metrics
//! (output insertion timestamp − reference timestamp, i.e. the window
//! end for windowed outputs, exactly the paper's measurement) and audits
//! delivery: a *skipped* sequence number is an output that was lost on
//! the way to the consumer, counted in [`ClusterMetrics::gaps`] and
//! asserted zero by the cluster tests.

use std::sync::atomic::Ordering;
use std::sync::Arc;
use std::thread::JoinHandle;

use crate::api::Processor;
use crate::clock::SimClock;
use crate::log::Topic;
use crate::trace::{self, TraceHandle, TraceKind};
use crate::util::PartitionId;

use super::node::decode_output;
use super::{ClusterMetrics, HolonCluster};

/// Records examined per partition per pass (bounds the time any one
/// partition can monopolize a pass, not total drain volume — the loop
/// keeps passing until idle).
const SINK_BATCH: usize = 1024;

/// Spawn the sink thread for a cluster.
pub fn spawn_sink<P: Processor>(cluster: &Arc<HolonCluster<P>>) -> JoinHandle<()> {
    let c = cluster.clone();
    let trace = c.tracer.handle(trace::SINK_NODE);
    std::thread::Builder::new()
        .name("holon-sink".to_string())
        .spawn(move || {
            sink_loop(&c.output, &c.metrics, &c.clock, c.cfg.poll_interval_ms, trace, || {
                c.shutdown_requested()
            })
        })
        .expect("spawn sink")
}

/// The sink main loop, factored out of the thread spawn so the
/// shutdown-drain and dedup/gap accounting are unit-testable.
///
/// Termination: only when a pass that *started after* `shutdown()` was
/// observed true finds nothing new in any partition. Sampling shutdown
/// before the pass matters: everything appended before the shutdown
/// request is sequenced before that pass's reads (topic appends are
/// lock-ordered), so an idle stopping-pass proves the log is fully
/// drained. The old sink exited on the first pass after shutdown, so
/// anything appended to an already-visited partition during that pass —
/// or anything beyond the per-pass batch bound — was dropped from the
/// metrics forever (the tail-drain race).
pub(crate) fn sink_loop(
    output: &Topic,
    metrics: &ClusterMetrics,
    clock: &SimClock,
    poll_interval_ms: u64,
    trace: TraceHandle,
    shutdown: impl Fn() -> bool,
) {
    let parts = output.partitions() as usize;
    // Per output partition: read offset + next expected output seq.
    let mut offsets = vec![0u64; parts];
    let mut next_seq = vec![0u64; parts];
    loop {
        // sampled BEFORE the pass: an idle pass only justifies exiting
        // if the whole pass ran with the shutdown request already visible
        let stopping = shutdown();
        let mut idle = true;
        for p in 0..parts {
            let expected = &mut next_seq[p];
            let before = offsets[p];
            // Zero-copy drain: visit records in place, no Vec<Record>
            // materialization per poll.
            let nxt = output.read_with(p as PartitionId, before, SINK_BATCH, |rec| {
                // decode_output borrows the inner payload from the
                // record bytes — the dedup path never copies it (the old
                // signature materialized a Vec per record just to drop
                // it here).
                let Some((seq, ref_ts, _inner)) = decode_output(&rec.payload) else {
                    return;
                };
                if seq < *expected {
                    // Replay duplicate — deterministic outputs make it
                    // byte-identical; drop it.
                    metrics.duplicates.fetch_add(1, Ordering::Relaxed);
                    trace.record(rec.insert_ts, TraceKind::SinkDeduped, ref_ts, 0, seq);
                    return;
                }
                if seq > *expected {
                    // Sequence gap: outputs [expected, seq) never made
                    // it to the log — a delivery failure. Count every
                    // lost seq instead of silently resynchronizing.
                    metrics.gaps.fetch_add(seq - *expected, Ordering::Relaxed);
                }
                *expected = seq + 1;
                let latency = rec.insert_ts.saturating_sub(ref_ts);
                metrics.latency.record(latency);
                metrics.latency_series.record(rec.insert_ts, latency as f64);
                metrics.outputs.fetch_add(1, Ordering::Relaxed);
                // Stage breakdown: *converge* is window-end → output-log
                // append (the distributed agreement + emit path, the
                // paper's latency measurement); *emit* is output-log
                // append → sink pickup (pure consumer-side queueing).
                metrics.stage_converge.record(latency);
                metrics
                    .stage_emit
                    .record(clock.now().saturating_sub(rec.insert_ts));
                trace.record(rec.insert_ts, TraceKind::WindowConverged, ref_ts, latency, seq);
            });
            if nxt != before {
                idle = false;
                offsets[p] = nxt;
            }
        }
        let tdrops = trace.take_dropped();
        if tdrops > 0 {
            metrics.trace_dropped_events.fetch_add(tdrops, Ordering::Relaxed);
        }
        if idle {
            if stopping {
                // Fully-idle pass, begun after the shutdown request:
                // every partition is drained to its end offset; nothing
                // can arrive anymore (node threads exit before the
                // cluster joins the sink).
                return;
            }
            clock.sleep(poll_interval_ms.max(1));
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::node::encode_output;
    use crate::log::LogBroker;
    use std::sync::atomic::AtomicBool;

    fn topic_with(parts: u32) -> (SimClock, Arc<Topic>) {
        let clock = SimClock::manual();
        let broker = LogBroker::new(clock.clone());
        (clock.clone(), broker.topic("out", parts))
    }

    fn append_seqs(t: &Topic, p: PartitionId, seqs: impl IntoIterator<Item = u64>) {
        for seq in seqs {
            t.append(p, 0, encode_output(seq, 0, &[1, 2]));
        }
    }

    #[test]
    fn drains_backlog_beyond_one_pass_after_shutdown() {
        // Regression (tail-drain race): shutdown is already requested
        // and one partition holds more records than a single pass
        // examines. The old sink did one pass (1024 records) and
        // exited, silently dropping the rest from the metrics.
        let (clock, t) = topic_with(2);
        append_seqs(&t, 0, 0..(SINK_BATCH as u64 + 500));
        append_seqs(&t, 1, 0..10);
        let m = ClusterMetrics::new(500);
        sink_loop(&t, &m, &clock, 1, TraceHandle::disabled(trace::SINK_NODE), || true);
        assert_eq!(
            m.outputs.load(Ordering::Acquire),
            SINK_BATCH as u64 + 500 + 10
        );
        assert_eq!(m.gaps.load(Ordering::Acquire), 0);
        assert_eq!(m.duplicates.load(Ordering::Acquire), 0);
    }

    #[test]
    fn exits_only_after_a_fully_idle_pass() {
        // Outputs appended while the sink is mid-drain (here: between
        // passes, simulated by a shutdown flag that flips after the
        // backlog exists) must still be counted. Deterministic because
        // the appends are sequenced before the shutdown store, and the
        // sink may only exit from a pass that began with the shutdown
        // flag already visible — such a pass observes the appends.
        let (clock, t) = topic_with(1);
        append_seqs(&t, 0, 0..5);
        let stop = Arc::new(AtomicBool::new(false));
        let t2 = t.clone();
        let stop2 = stop.clone();
        let m = ClusterMetrics::new(500);
        let m2 = m.clone();
        let clock2 = clock.clone();
        let h = std::thread::spawn(move || {
            sink_loop(&t2, &m2, &clock2, 1, TraceHandle::disabled(trace::SINK_NODE), || {
                stop2.load(Ordering::Acquire)
            })
        });
        // let the sink drain the first batch, then append more and only
        // then request shutdown
        while m.outputs.load(Ordering::Acquire) < 5 {
            std::thread::yield_now();
        }
        append_seqs(&t, 0, 5..12);
        stop.store(true, Ordering::Release);
        h.join().unwrap();
        assert_eq!(m.outputs.load(Ordering::Acquire), 12);
    }

    #[test]
    fn sequence_gaps_are_counted_not_swallowed() {
        // Regression: seq jumps used to be silently accepted, making
        // lost outputs invisible outside the sim oracle. A jump from
        // expected=2 to seq=5 is 3 lost outputs.
        let (clock, t) = topic_with(1);
        append_seqs(&t, 0, [0, 1, 5, 6]);
        let m = ClusterMetrics::new(500);
        sink_loop(&t, &m, &clock, 1, TraceHandle::disabled(trace::SINK_NODE), || true);
        assert_eq!(m.outputs.load(Ordering::Acquire), 4);
        assert_eq!(m.gaps.load(Ordering::Acquire), 3);
    }

    #[test]
    fn duplicates_still_dropped_and_not_gap_counted() {
        let (clock, t) = topic_with(1);
        append_seqs(&t, 0, [0, 1, 2, 1, 2, 3]);
        let m = ClusterMetrics::new(500);
        sink_loop(&t, &m, &clock, 1, TraceHandle::disabled(trace::SINK_NODE), || true);
        assert_eq!(m.outputs.load(Ordering::Acquire), 4);
        assert_eq!(m.duplicates.load(Ordering::Acquire), 2);
        assert_eq!(m.gaps.load(Ordering::Acquire), 0);
    }
}
