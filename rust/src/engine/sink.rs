//! The deduplicating output consumer (§3.3): outputs may be physically
//! duplicated (replay after steal/restart); a consumer maintaining a map
//! from partitions to sequence numbers deduplicates them. This sink is
//! that consumer — it also records the end-to-end latency metrics
//! (output insertion timestamp − reference timestamp, i.e. the window
//! end for windowed outputs), exactly the paper's measurement.

use std::sync::atomic::Ordering;
use std::sync::Arc;
use std::thread::JoinHandle;

use crate::api::Processor;
use crate::util::PartitionId;

use super::node::decode_output;
use super::HolonCluster;

/// Spawn the sink thread for a cluster.
pub fn spawn_sink<P: Processor>(cluster: &Arc<HolonCluster<P>>) -> JoinHandle<()> {
    let c = cluster.clone();
    std::thread::Builder::new()
        .name("holon-sink".to_string())
        .spawn(move || sink_main(c))
        .expect("spawn sink")
}

fn sink_main<P: Processor>(c: Arc<HolonCluster<P>>) {
    let parts = c.cfg.partitions;
    // Per output partition: read offset + next expected output seq.
    let mut offsets = vec![0u64; parts as usize];
    let mut next_seq = vec![0u64; parts as usize];
    loop {
        let mut idle = true;
        for p in 0..parts {
            let (recs, nxt) = c.output.read(p as PartitionId, offsets[p as usize], 1024);
            if recs.is_empty() {
                continue;
            }
            idle = false;
            offsets[p as usize] = nxt;
            for rec in recs {
                let Some((seq, ref_ts, _inner)) = decode_output(&rec.payload) else {
                    continue;
                };
                let expected = &mut next_seq[p as usize];
                if seq < *expected {
                    // Replay duplicate — deterministic outputs make it
                    // byte-identical; drop it.
                    c.metrics.duplicates.fetch_add(1, Ordering::Relaxed);
                    continue;
                }
                //

                *expected = seq + 1;
                let latency = rec.insert_ts.saturating_sub(ref_ts);
                c.metrics.latency.record(latency);
                c.metrics.latency_series.record(rec.insert_ts, latency as f64);
                c.metrics.outputs.fetch_add(1, Ordering::Relaxed);
            }
        }
        if c.shutdown_requested() {
            // One final drain already happened above; exit.
            return;
        }
        if idle {
            c.clock.sleep(c.cfg.poll_interval_ms.max(1));
        }
    }
}
