//! The Holon Streaming engine (paper §4): decentralized nodes, logged
//! streams, gossip-synchronized Windowed CRDTs, work-stealing failure
//! recovery and reconfiguration.
//!
//! A [`HolonCluster`] wires the substrates together: an input topic and
//! an output topic on the [`LogBroker`] (the Kafka substitute), a
//! broadcast/control [`Bus`], a shared [`CheckpointStore`], and N node
//! threads each running [`node::node_main`] (Algorithm 2). Failure
//! injection flips a per-node flag: the thread exits without a final
//! checkpoint, exactly like a killed container. Restart spawns a fresh
//! thread with the same id and empty state.

pub mod membership;
pub mod node;
pub mod sink;

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;

use crate::api::Processor;
use crate::clock::SimClock;
use crate::config::HolonConfig;
use crate::log::{LogBroker, Topic};
use crate::metrics::{LatencyHistogram, TimeSeries};
use crate::net::{Bus, NetConfig};
use crate::storage::CheckpointStore;
use crate::util::{LockExt, NodeId, PartitionId};

/// Cluster-wide observability counters shared by nodes and the sink.
#[derive(Debug, Clone)]
pub struct ClusterMetrics {
    /// Events consumed per time bucket (the paper's throughput metric).
    pub processed: TimeSeries,
    /// End-to-end latency histogram over deduplicated outputs.
    pub latency: LatencyHistogram,
    /// Mean end-to-end latency per time bucket (Fig. 6/7 series).
    pub latency_series: TimeSeries,
    /// Deduplicated outputs delivered.
    pub outputs: Arc<AtomicU64>,
    /// Physical duplicates dropped by the sink (§3.3: outputs may be
    /// duplicated; consumers dedup by (partition, seq)).
    pub duplicates: Arc<AtomicU64>,
    /// Output sequence numbers skipped over by the sink — every skipped
    /// seq is an output that was lost on the way to the consumer. Must
    /// be zero in a correct run (the log is durable and replays are
    /// deterministic); cluster tests assert it.
    pub gaps: Arc<AtomicU64>,
    /// Partitions stolen from other nodes (recovery/reconfiguration).
    pub steals: Arc<AtomicU64>,
    /// Partition recoveries from the checkpoint store.
    pub recoveries: Arc<AtomicU64>,
    /// Gossip messages sent.
    pub gossip_sent: Arc<AtomicU64>,
    /// Total encoded gossip payload bytes (one encode per round; the
    /// per-recipient wire volume is tracked by [`crate::net::Bus::bytes_sent`]).
    pub gossip_payload_bytes: Arc<AtomicU64>,
    /// Encoded gossip payload bytes attributed per shard (index = shard
    /// id) for queries over sharded keyed state — empty for unsharded
    /// queries. The per-shard view is what shows delta gossip shipping
    /// only the dirty shards.
    pub shard_gossip_bytes: Arc<Mutex<Vec<u64>>>,
    /// Sharded-state merges executed on the parallel shard pool.
    pub shard_parallel_merges: Arc<AtomicU64>,
    /// Sharded-state merges executed inline (below the parallel
    /// threshold, or layout-mismatch rehashes).
    pub shard_serial_merges: Arc<AtomicU64>,
    /// Gossip payloads whose join inflated the receiving replica
    /// (trait-v3 change-reporting merges).
    pub merge_changed: Arc<AtomicU64>,
    /// Gossip payloads whose join was a complete no-op — the receiver
    /// already subsumed everything in them.
    pub merge_noop: Arc<AtomicU64>,
    /// Bytes of received payloads whose join was a *complete* no-op
    /// (whole-payload granularity: a payload with even one inflating
    /// unit counts zero here). The traffic a smarter sync protocol
    /// would not have shipped; full-sync anti-entropy keeps a baseline
    /// of these by design.
    pub redundant_gossip_bytes: Arc<AtomicU64>,
    /// Delta gossip rounds skipped entirely because the replica had
    /// nothing dirty and no watermark movement (no encode, no
    /// broadcast — the empty-delta fast path).
    pub gossip_skipped: Arc<AtomicU64>,
    /// Read-path: queries answered (point + range + top-k) across all
    /// query engines attached to this cluster's read handles.
    pub queries_served: Arc<AtomicU64>,
    /// Read-path: queries where the signature pre-filter pruned work.
    pub query_index_hits: Arc<AtomicU64>,
    /// Read-path: queries the pre-filter could not narrow.
    pub query_index_misses: Arc<AtomicU64>,
    /// Read-path: state rows the pre-filter excluded from scans.
    pub query_scan_rows_avoided: Arc<AtomicU64>,
    /// Read-path high-water mark: the most items any live changefeed
    /// subscriber was observed behind its feed head (fetch_max over all
    /// nodes' publish points, not a sum).
    pub changefeed_lag: Arc<AtomicU64>,
    /// Messages dropped because sender and receiver were partitioned
    /// (mirror of [`crate::net::DropStats::partition`]).
    pub dropped_partition: Arc<AtomicU64>,
    /// Messages lost to `drop_prob`/fault-overlay loss
    /// (mirror of [`crate::net::DropStats::loss`]).
    pub dropped_loss: Arc<AtomicU64>,
    /// Messages to nodes with no registered inbox — restart churn
    /// (mirror of [`crate::net::DropStats::no_inbox`]).
    pub dropped_no_inbox: Arc<AtomicU64>,
    /// Parked messages shed at the outbound-queue cap under sustained
    /// backpressure (mirror of [`crate::net::DropStats::backpressure`]).
    pub dropped_backpressure: Arc<AtomicU64>,
    /// Node-loop iterations that shrank the event budget because a peer
    /// advertised zero credits or the last flush had to park traffic —
    /// how often backpressure actually throttled sources.
    pub credits_stalled_rounds: Arc<AtomicU64>,
    /// High-water mark of any sender's per-peer outbound queue depth.
    pub outbound_queue_depth_max: Arc<AtomicU64>,
    /// High-water mark of any receiver's inbox depth; stays ≤
    /// `inbox_capacity` when the cap is set (the bounded-memory
    /// guarantee backpressure exists to provide).
    pub inbox_depth_max: Arc<AtomicU64>,
    /// Bytes shipped through per-batch output arenas (backing buffers
    /// handed to the log as shared `Arc`s — the zero-copy output path).
    pub output_arena_bytes: Arc<AtomicU64>,
    /// Output frames written into arenas (one per output record).
    pub output_frames: Arc<AtomicU64>,
    /// Window-store inserts that fell outside the dense ring horizon
    /// and landed in the spill map. ~0 in a healthy run; a sustained
    /// rate means lateness/compaction tuning is off.
    pub window_ring_spills: Arc<AtomicU64>,
    /// Flight-recorder ring overwrites: events lost because a node's
    /// trace ring wrapped (newest events win). Zero when tracing is
    /// disabled or the rings never fill.
    pub trace_dropped_events: Arc<AtomicU64>,
    /// Stage latency: source insert → node pickup (sampled once per
    /// RUN_BATCH batch at its first record).
    pub stage_ingest: LatencyHistogram,
    /// Stage latency: window end → the cluster watermark floor passing
    /// it at a node (the window *fires*).
    pub stage_fire: LatencyHistogram,
    /// Stage latency: window end (`ref_ts`) → the converged output
    /// being accepted by the sink — the paper's end-to-end latency.
    pub stage_converge: LatencyHistogram,
    /// Stage latency: output insert into the log → the sink draining
    /// it (the tail the sink's poll cadence adds on top of converge).
    pub stage_emit: LatencyHistogram,
    /// Registry of every named `u64` counter above, keyed by its bench
    /// JSON field name (fields without a JSON column keep their struct
    /// name). One place to enumerate counters — `DataPlaneStats`, the
    /// JSON writer and the flight-recorder dump all read through it.
    counters: Arc<Vec<(&'static str, Arc<AtomicU64>)>>,
}

/// Builds [`ClusterMetrics`] counters while registering each one under
/// its bench JSON field name (one source of truth for enumeration).
struct CounterReg(Vec<(&'static str, Arc<AtomicU64>)>);

impl CounterReg {
    fn mk(&mut self, name: &'static str) -> Arc<AtomicU64> {
        let c = Arc::new(AtomicU64::new(0));
        self.0.push((name, c.clone()));
        c
    }
}

impl ClusterMetrics {
    pub fn new(bucket_ms: u64) -> Self {
        let mut reg = CounterReg(Vec::with_capacity(32));
        Self {
            processed: TimeSeries::new(bucket_ms),
            latency: LatencyHistogram::new(),
            latency_series: TimeSeries::new(bucket_ms),
            outputs: reg.mk("outputs"),
            duplicates: reg.mk("dedup_duplicates"),
            gaps: reg.mk("seq_gaps"),
            steals: reg.mk("steals"),
            recoveries: reg.mk("recoveries"),
            gossip_sent: reg.mk("gossip_msgs"),
            gossip_payload_bytes: reg.mk("gossip_bytes_encoded"),
            shard_gossip_bytes: Arc::new(Mutex::new(Vec::new())),
            shard_parallel_merges: reg.mk("shard_parallel_merges"),
            shard_serial_merges: reg.mk("shard_serial_merges"),
            merge_changed: reg.mk("merge_changed"),
            merge_noop: reg.mk("merge_noop"),
            redundant_gossip_bytes: reg.mk("redundant_gossip_bytes"),
            gossip_skipped: reg.mk("gossip_skipped"),
            queries_served: reg.mk("queries_served"),
            query_index_hits: reg.mk("query_index_hits"),
            query_index_misses: reg.mk("query_index_misses"),
            query_scan_rows_avoided: reg.mk("query_scan_rows_avoided"),
            changefeed_lag: reg.mk("changefeed_lag"),
            dropped_partition: reg.mk("dropped_partition"),
            dropped_loss: reg.mk("dropped_loss"),
            dropped_no_inbox: reg.mk("dropped_no_inbox"),
            dropped_backpressure: reg.mk("dropped_backpressure"),
            credits_stalled_rounds: reg.mk("credits_stalled_rounds"),
            outbound_queue_depth_max: reg.mk("outbound_queue_depth_max"),
            inbox_depth_max: reg.mk("inbox_depth_max"),
            output_arena_bytes: reg.mk("output_arena_bytes"),
            output_frames: reg.mk("output_frames"),
            window_ring_spills: reg.mk("window_ring_spills"),
            trace_dropped_events: reg.mk("trace_dropped_events"),
            stage_ingest: LatencyHistogram::new(),
            stage_fire: LatencyHistogram::new(),
            stage_converge: LatencyHistogram::new(),
            stage_emit: LatencyHistogram::new(),
            counters: Arc::new(reg.0),
        }
    }

    /// Look up a counter by its registered (bench JSON) name. The
    /// returned `Arc` aliases the corresponding named field.
    pub fn counter(&self, name: &str) -> Option<&Arc<AtomicU64>> {
        self.counters
            .iter()
            .find(|(n, _)| *n == name)
            .map(|(_, c)| c)
    }

    /// `(name, current value)` snapshot of every registered counter, in
    /// registration order — what the flight-recorder dump embeds.
    pub fn counter_snapshot(&self) -> Vec<(&'static str, u64)> {
        self.counters
            .iter()
            .map(|(n, c)| (*n, c.load(Ordering::Acquire)))
            .collect()
    }

    /// Fold a drained [`crate::query::QueryStats`] into the read-path
    /// counters.
    pub fn add_query_stats(&self, s: &crate::query::QueryStats) {
        self.queries_served.fetch_add(s.served, Ordering::Relaxed);
        self.query_index_hits.fetch_add(s.index_hits, Ordering::Relaxed);
        self.query_index_misses
            .fetch_add(s.index_misses, Ordering::Relaxed);
        self.query_scan_rows_avoided
            .fetch_add(s.scan_rows_avoided, Ordering::Relaxed);
    }

    /// Fold a join's reported outcome into the merge-effectiveness
    /// counters. The trait-v3 contract is that every join reports its
    /// effect — call sites must consume the
    /// [`MergeOutcome`](crate::crdt::MergeOutcome) rather than
    /// discard it (holon-lint rule `discarded-merge`); this is
    /// the standard sink for outcomes with no better use in scope.
    pub fn note_join(&self, outcome: crate::crdt::MergeOutcome) {
        if outcome.is_changed() {
            self.merge_changed.fetch_add(1, Ordering::Relaxed);
        } else {
            self.merge_noop.fetch_add(1, Ordering::Relaxed);
        }
    }

    /// Fold a node's per-shard encoded gossip byte counts (index =
    /// shard id) into the cluster-wide counters.
    pub fn add_shard_gossip_bytes(&self, per_shard: &[u64]) {
        if per_shard.is_empty() {
            return;
        }
        let mut v = self.shard_gossip_bytes.plane_lock();
        if v.len() < per_shard.len() {
            v.resize(per_shard.len(), 0);
        }
        for (slot, b) in v.iter_mut().zip(per_shard) {
            *slot += b;
        }
    }
}

/// Changefeed retention ring depth for a deployment: the configured
/// [`HolonConfig::changefeed_retention`] override, or a default derived
/// from the gossip config. The derivation covers the worst publish
/// burst a batched flush can deliver at once — a full anti-entropy
/// period ([`node::FULL_SYNC_EVERY`] rounds) scaled by the effective
/// fan-out (each round of transitive gossip can trigger up to fan-out
/// re-publishes downstream), with headroom — and never goes below the
/// previous hard-coded default, so existing deployments keep their
/// retention byte-for-byte.
pub fn effective_changefeed_retention(cfg: &HolonConfig) -> usize {
    if cfg.changefeed_retention > 0 {
        return cfg.changefeed_retention;
    }
    (node::FULL_SYNC_EVERY as usize * cfg.effective_gossip_fanout().max(1) * 8)
        .max(crate::query::feed::DEFAULT_RETENTION)
}

/// Handle to a running node thread.
struct NodeHandle {
    failed: Arc<AtomicBool>,
    join: Option<JoinHandle<()>>,
}

/// A running Holon deployment.
pub struct HolonCluster<P: Processor> {
    pub cfg: HolonConfig,
    pub clock: SimClock,
    pub broker: LogBroker,
    pub input: Arc<Topic>,
    pub output: Arc<Topic>,
    pub bus: Bus,
    pub store: CheckpointStore,
    pub metrics: ClusterMetrics,
    /// Flight recorder shared by all node threads and the sink
    /// (disabled unless `cfg.trace` — a disabled recorder's handles
    /// are a single branch on the hot paths).
    pub tracer: Arc<crate::trace::Tracer>,
    processor: P,
    shutdown: Arc<AtomicBool>,
    nodes: Mutex<BTreeMap<NodeId, NodeHandle>>,
    sink: Mutex<Option<JoinHandle<()>>>,
    /// Encoded shared-state replicas published by nodes on graceful
    /// shutdown (crashed nodes never publish). The simulation oracles
    /// decode these to check replica convergence after a run.
    final_states: Arc<Mutex<BTreeMap<NodeId, Vec<u8>>>>,
    /// Per-node changefeed publication points. Keyed by node id and kept
    /// across restarts, so a subscriber's cursor survives its node's
    /// crash (the restarted node publishes into the same handle).
    read_handles: Mutex<BTreeMap<NodeId, crate::query::ReadHandle>>,
}

impl<P: Processor> HolonCluster<P> {
    /// Build the substrate and spawn `cfg.nodes` node threads plus the
    /// deduplicating sink.
    pub fn start(cfg: HolonConfig, processor: P) -> Arc<Self> {
        let clock = SimClock::scaled(cfg.wall_ms_per_sim_sec);
        Self::start_with_clock(cfg, processor, clock)
    }

    /// As [`start`](Self::start) but with a caller-provided clock
    /// (benches share one clock across compared systems).
    pub fn start_with_clock(cfg: HolonConfig, processor: P, clock: SimClock) -> Arc<Self> {
        if cfg.shard_merge_threads > 0 {
            // explicit cap only — the process-wide default (auto) is
            // left alone so concurrent test clusters don't fight over it
            crate::shard::exec::set_max_threads(cfg.shard_merge_threads as usize);
        }
        let broker = LogBroker::new(clock.clone());
        let input = broker.topic("input", cfg.partitions);
        let output = broker.topic("output", cfg.partitions);
        let bus = Bus::new(
            clock.clone(),
            NetConfig {
                base_delay_ms: cfg.net_delay_ms,
                jitter_ms: cfg.net_jitter_ms,
                drop_prob: cfg.net_drop_prob,
                tail_prob: cfg.net_tail_prob,
                tail_ms: cfg.net_tail_ms,
                inbox_capacity: cfg.inbox_capacity,
            },
            cfg.seed ^ 0xB05,
        );
        let metrics = ClusterMetrics::new(500);
        let tracer = Arc::new(if cfg.trace {
            crate::trace::Tracer::new(crate::trace::DEFAULT_RING_CAP)
        } else {
            crate::trace::Tracer::disabled()
        });
        let cluster = Arc::new(Self {
            clock,
            broker,
            input,
            output,
            bus,
            store: CheckpointStore::new(),
            metrics,
            tracer,
            processor,
            shutdown: Arc::new(AtomicBool::new(false)),
            nodes: Mutex::new(BTreeMap::new()),
            sink: Mutex::new(None),
            final_states: Arc::new(Mutex::new(BTreeMap::new())),
            read_handles: Mutex::new(BTreeMap::new()),
            cfg,
        });
        for id in 0..cluster.cfg.nodes {
            cluster.spawn_node(id);
        }
        let sink = sink::spawn_sink(&cluster);
        *cluster.sink.plane_lock() = Some(sink);
        cluster
    }

    fn spawn_node(self: &Arc<Self>, id: NodeId) {
        let failed = Arc::new(AtomicBool::new(false));
        self.bus.register(id);
        let reads = self
            .read_handles
            .plane_lock()
            .entry(id)
            .or_insert_with(|| {
                crate::query::ReadHandle::with_retention(effective_changefeed_retention(&self.cfg))
            })
            .clone();
        let ctx = node::NodeCtx {
            id,
            cfg: self.cfg.clone(),
            clock: self.clock.clone(),
            input: self.input.clone(),
            output: self.output.clone(),
            bus: self.bus.clone(),
            store: self.store.clone(),
            processor: self.processor.clone(),
            shutdown: self.shutdown.clone(),
            failed: failed.clone(),
            metrics: self.metrics.clone(),
            state_out: self.final_states.clone(),
            reads,
            trace: self.tracer.handle(id),
        };
        let join = std::thread::Builder::new()
            .name(format!("holon-node-{id}"))
            .spawn(move || node::node_main(ctx))
            .expect("spawn node");
        self.nodes.plane_lock().insert(
            id,
            NodeHandle {
                failed,
                join: Some(join),
            },
        );
    }

    /// Kill a node abruptly (no final checkpoint, queued messages lost) —
    /// the §5.2 failure injection.
    pub fn fail_node(&self, id: NodeId) {
        let mut nodes = self.nodes.plane_lock();
        if let Some(h) = nodes.get_mut(&id) {
            h.failed.store(true, Ordering::Release);
            if let Some(j) = h.join.take() {
                drop(nodes); // don't hold the lock while joining
                let _ = j.join();
                self.bus.unregister(id);
                self.nodes.plane_lock().remove(&id);
                return;
            }
        }
    }

    /// Restart a previously failed node with the same id (fresh state;
    /// it re-learns membership and steals back its share of partitions).
    pub fn restart_node(self: &Arc<Self>, id: NodeId) {
        assert!(
            !self.nodes.plane_lock().contains_key(&id),
            "node {id} is still running"
        );
        self.spawn_node(id);
    }

    /// Reconfiguration: add a node with a fresh id to a running cluster.
    /// It announces itself via heartbeats and the rendezvous assignment
    /// rebalances partitions onto it — same path as a restart, but the
    /// id has never held state.
    pub fn add_node(self: &Arc<Self>, id: NodeId) {
        assert!(
            !self.nodes.plane_lock().contains_key(&id),
            "node {id} is already running"
        );
        self.spawn_node(id);
    }

    /// Encoded final shared-state replicas published by nodes that shut
    /// down gracefully (call after [`stop`](Self::stop); killed nodes do
    /// not publish). Keyed by node id.
    pub fn final_replicas(&self) -> BTreeMap<NodeId, Vec<u8>> {
        self.final_states.plane_lock().clone()
    }

    /// The changefeed read handle of node `id` — present for any node
    /// that was ever spawned, even while it is down (the handle and its
    /// subscribers' cursors outlive node restarts).
    pub fn read_handle(&self, id: NodeId) -> Option<crate::query::ReadHandle> {
        self.read_handles.plane_lock().get(&id).cloned()
    }

    /// Ids of currently running nodes.
    pub fn running_nodes(&self) -> Vec<NodeId> {
        self.nodes.plane_lock().keys().copied().collect()
    }

    /// All partition ids of this deployment.
    pub fn partitions(&self) -> Vec<PartitionId> {
        (0..self.cfg.partitions).collect()
    }

    /// Stop all node threads and the sink, letting them checkpoint.
    pub fn stop(&self) {
        self.shutdown.store(true, Ordering::Release);
        let handles: Vec<_> = {
            let mut nodes = self.nodes.plane_lock();
            nodes
                .iter_mut()
                .filter_map(|(_, h)| h.join.take())
                .collect()
        };
        for h in handles {
            let _ = h.join();
        }
        if let Some(s) = self.sink.plane_lock().take() {
            let _ = s.join();
        }
    }

    /// Whether `stop()` has been requested (used by the sink thread).
    pub(crate) fn shutdown_requested(&self) -> bool {
        self.shutdown.load(Ordering::Acquire)
    }

    /// Block until the sink has delivered `n` deduplicated outputs or
    /// `timeout_sim_ms` elapsed. Returns whether the target was reached.
    pub fn await_outputs(&self, n: u64, timeout_sim_ms: u64) -> bool {
        let deadline = self.clock.now() + timeout_sim_ms;
        while self.clock.now() < deadline {
            if self.metrics.outputs.load(Ordering::Acquire) >= n {
                return true;
            }
            std::thread::sleep(std::time::Duration::from_millis(1));
        }
        self.metrics.outputs.load(Ordering::Acquire) >= n
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// The counter registry must alias the named fields (same atomics,
    /// not copies) under the bench JSON field names, with no duplicate
    /// registrations — it is the single enumeration point for
    /// `DataPlaneStats`, the JSON writer and trace-dump snapshots.
    #[test]
    fn counter_registry_aliases_the_named_fields() {
        let m = ClusterMetrics::new(500);
        m.outputs.fetch_add(3, Ordering::Relaxed);
        assert!(Arc::ptr_eq(m.counter("outputs").unwrap(), &m.outputs));
        assert!(Arc::ptr_eq(
            m.counter("dedup_duplicates").unwrap(),
            &m.duplicates
        ));
        assert!(Arc::ptr_eq(m.counter("seq_gaps").unwrap(), &m.gaps));
        assert!(Arc::ptr_eq(m.counter("gossip_msgs").unwrap(), &m.gossip_sent));
        assert!(Arc::ptr_eq(
            m.counter("gossip_bytes_encoded").unwrap(),
            &m.gossip_payload_bytes
        ));
        assert!(Arc::ptr_eq(
            m.counter("trace_dropped_events").unwrap(),
            &m.trace_dropped_events
        ));
        assert!(m.counter("no_such_counter").is_none());
        let snap = m.counter_snapshot();
        assert_eq!(snap.iter().find(|(n, _)| *n == "outputs").unwrap().1, 3);
        let names: std::collections::BTreeSet<_> = snap.iter().map(|(n, _)| *n).collect();
        assert_eq!(names.len(), snap.len(), "duplicate registry names");
    }

    /// Regression (changefeed gap storms): retention was hard-coded at
    /// 256 while the comment tied it to the gossip cadence. The derived
    /// default must (a) keep the old value under the default config so
    /// nothing shifts silently, (b) scale up with fan-out so a batched
    /// flush burst covering an anti-entropy period cannot out-run
    /// retention, (c) yield to an explicit override.
    #[test]
    fn changefeed_retention_derives_from_gossip_config() {
        let cfg = HolonConfig::default(); // 5 nodes → auto fanout 3
        assert_eq!(
            effective_changefeed_retention(&cfg),
            crate::query::feed::DEFAULT_RETENTION,
            "default config keeps the pre-derivation retention"
        );
        // larger fan-out pushes past the floor: 10 rounds × 7 × 8 = 560
        let mut big = HolonConfig::default();
        big.nodes = 100; // auto fanout ⌈log₂ 100⌉ = 7
        assert_eq!(effective_changefeed_retention(&big), 560);
        // broadcast-to-all (fanout 0) clamps at the floor, not at 0
        let mut bc = HolonConfig::default();
        bc.gossip_fanout = 0;
        assert_eq!(
            effective_changefeed_retention(&bc),
            crate::query::feed::DEFAULT_RETENTION
        );
        // explicit override wins
        let mut ov = HolonConfig::default();
        ov.changefeed_retention = 32;
        assert_eq!(effective_changefeed_retention(&ov), 32);
    }
}
