//! Decentralized membership: heartbeats, failure detection, and the
//! deterministic partition-ownership rule behind work stealing.
//!
//! There is no coordinator. Every node independently maintains an
//! `alive` view from heartbeats on the control bus and computes, for
//! every partition, a *target owner* with rendezvous hashing over the
//! alive set. When the views agree the assignment is balanced and
//! stable; while they disagree (around failures/restarts) two nodes may
//! process the same partition — which is exactly what the paper's
//! deterministic programming model makes safe (§4.3: "the execution
//! allows multiple nodes to process the same partitions").
//! Rendezvous hashing minimizes partition movement on membership change,
//! which keeps reconfiguration cheap.

use std::collections::BTreeMap;

use crate::util::{NodeId, PartitionId, SimTime};

/// A node's local view of cluster membership.
#[derive(Debug)]
pub struct Membership {
    myself: NodeId,
    /// last heartbeat receive-time per node (self refreshed locally).
    last_seen: BTreeMap<NodeId, SimTime>,
    /// failure timeout (sim-ms).
    timeout: SimTime,
}

impl Membership {
    pub fn new(myself: NodeId, timeout: SimTime, now: SimTime) -> Self {
        let mut last_seen = BTreeMap::new();
        last_seen.insert(myself, now);
        Self {
            myself,
            last_seen,
            timeout,
        }
    }

    /// Record a heartbeat from `node` at local time `now`.
    pub fn heard_from(&mut self, node: NodeId, now: SimTime) {
        let e = self.last_seen.entry(node).or_insert(now);
        *e = (*e).max(now);
    }

    /// Refresh own liveness (called when broadcasting a heartbeat).
    pub fn refresh_self(&mut self, now: SimTime) {
        self.last_seen.insert(self.myself, now);
    }

    /// Nodes currently considered alive at `now` (always includes self).
    pub fn alive(&self, now: SimTime) -> Vec<NodeId> {
        self.last_seen
            .iter()
            .filter(|&(&n, &ts)| n == self.myself || now.saturating_sub(ts) <= self.timeout)
            .map(|(&n, _)| n)
            .collect()
    }

    /// Nodes that have timed out (for observability).
    pub fn dead(&self, now: SimTime) -> Vec<NodeId> {
        self.last_seen
            .iter()
            .filter(|&(&n, &ts)| n != self.myself && now.saturating_sub(ts) > self.timeout)
            .map(|(&n, _)| n)
            .collect()
    }

    pub fn myself(&self) -> NodeId {
        self.myself
    }
}

/// Deterministic weight of (node, partition) for rendezvous hashing —
/// a strong 64-bit mix so ownership is uniform and stable.
fn weight(node: NodeId, partition: PartitionId) -> u64 {
    let mut x = ((node as u64) << 32) ^ (partition as u64) ^ 0x9E37_79B9_7F4A_7C15;
    x ^= x >> 33;
    x = x.wrapping_mul(0xFF51_AFD7_ED55_8CCD);
    x ^= x >> 33;
    x = x.wrapping_mul(0xC4CE_B9FE_1A85_EC53);
    x ^= x >> 33;
    x
}

/// The target owner of `partition` among `alive` nodes (rendezvous
/// hashing: highest weight wins). `alive` must be non-empty.
pub fn target_owner(partition: PartitionId, alive: &[NodeId]) -> NodeId {
    debug_assert!(!alive.is_empty());
    *alive
        .iter()
        .max_by_key(|&&n| weight(n, partition))
        .expect("non-empty alive set")
}

/// Full target assignment for `partitions` over `alive` nodes.
pub fn assignment(partitions: u32, alive: &[NodeId]) -> BTreeMap<PartitionId, NodeId> {
    (0..partitions)
        .map(|p| (p, target_owner(p, alive)))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn heartbeat_tracking() {
        let mut m = Membership::new(0, 100, 0);
        m.heard_from(1, 10);
        m.heard_from(2, 20);
        assert_eq!(m.alive(50), vec![0, 1, 2]);
        // node 1 times out at t > 110
        assert_eq!(m.alive(120), vec![0, 2]);
        assert_eq!(m.dead(120), vec![1]);
        // self never times out
        assert_eq!(m.alive(10_000), vec![0]);
    }

    #[test]
    fn stale_heartbeat_does_not_regress() {
        let mut m = Membership::new(0, 100, 0);
        m.heard_from(1, 50);
        m.heard_from(1, 30); // reordered delivery
        assert!(m.alive(140).contains(&1));
    }

    #[test]
    fn rendezvous_is_deterministic_and_total() {
        let alive = vec![0, 1, 2, 3, 4];
        for p in 0..100 {
            let a = target_owner(p, &alive);
            let b = target_owner(p, &alive);
            assert_eq!(a, b);
            assert!(alive.contains(&a));
        }
    }

    #[test]
    fn rendezvous_balances_reasonably() {
        let alive = vec![0, 1, 2, 3, 4];
        let asg = assignment(1000, &alive);
        let mut counts = BTreeMap::new();
        for (_, n) in asg {
            *counts.entry(n).or_insert(0u32) += 1;
        }
        for (_, c) in counts {
            assert!((100..350).contains(&c), "imbalanced: {c}");
        }
    }

    #[test]
    fn failure_moves_only_failed_nodes_partitions() {
        // The reconfiguration-cost property: removing one node must not
        // reshuffle partitions owned by surviving nodes.
        let before = assignment(200, &[0, 1, 2, 3, 4]);
        let after = assignment(200, &[0, 1, 3, 4]); // node 2 died
        for (p, owner) in &before {
            if *owner != 2 {
                assert_eq!(after[p], *owner, "partition {p} moved needlessly");
            } else {
                assert_ne!(after[p], 2);
            }
        }
    }

    #[test]
    fn restart_restores_original_assignment() {
        let with5 = assignment(100, &[0, 1, 2, 3, 4]);
        let with4 = assignment(100, &[0, 1, 3, 4]);
        let healed = assignment(100, &[0, 1, 2, 3, 4]);
        assert_eq!(with5, healed);
        assert_ne!(with5, with4);
    }
}
