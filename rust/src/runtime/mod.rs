//! PJRT runtime — loads the AOT-compiled XLA artifacts and runs them on
//! the Rust hot path. Python is never involved at runtime: `make
//! artifacts` lowered the L2 JAX graphs (which call the L1 Pallas
//! kernels) to HLO *text*; here we parse, compile once per node thread,
//! and execute per batch.
//!
//! Interchange is HLO text, not serialized protos: jax ≥ 0.5 emits
//! 64-bit instruction ids that xla_extension 0.5.1 rejects; the text
//! parser reassigns ids (see /opt/xla-example/README.md).

use std::path::{Path, PathBuf};

use crate::api::{BatchAggregator, ScalarAggregator, WindowAggregates};
use crate::config::HolonConfig;
use crate::wcrdt::WindowId;

/// AOT shapes — must match python/compile/kernels/window_agg.py.
pub const BATCH: usize = 1024;
pub const WINDOWS: usize = 32;

/// Historical note (perf iteration 3, EXPERIMENTS.md §Perf): chunks
/// were originally capped at 128 events so f32 kernel sums of
/// cent-valued inputs stayed below 2^24 (exact). Sums are now
/// accumulated in Rust in f64 (exact for integers < 2^53, independent
/// of batch boundaries), so the kernel runs full [`BATCH`]-size chunks
/// — 8× fewer PJRT dispatches — and contributes counts and maxes,
/// which are exact in f32 at any chunk size.
pub const EXACT_CHUNK: usize = BATCH;

/// Errors from the XLA runtime.
#[derive(Debug, thiserror::Error)]
pub enum RuntimeError {
    #[error("artifact not found: {0}")]
    MissingArtifact(PathBuf),
    #[error("xla: {0}")]
    Xla(String),
}

impl From<xla::Error> for RuntimeError {
    fn from(e: xla::Error) -> Self {
        RuntimeError::Xla(e.to_string())
    }
}

/// A compiled `window_agg` executable bound to a PJRT CPU client.
///
/// One instance per node thread (PJRT executables are not shared across
/// threads here); compilation happens once, execution per batch.
pub struct XlaWindowAggregator {
    exe: xla::PjRtLoadedExecutable,
    /// scratch input buffers, reused across batches (no per-batch alloc)
    values: Vec<f32>,
    window_ids: Vec<i32>,
    /// reusable input literals (filled with copy_raw_from per call)
    lit_values: xla::Literal,
    lit_wids: xla::Literal,
    calls: u64,
}

impl XlaWindowAggregator {
    /// Load `window_agg.hlo.txt` from `dir` and compile it.
    pub fn load(dir: &Path) -> Result<Self, RuntimeError> {
        let path = dir.join("window_agg.hlo.txt");
        if !path.exists() {
            return Err(RuntimeError::MissingArtifact(path));
        }
        let client = xla::PjRtClient::cpu()?;
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().expect("utf8 path"),
        )?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = client.compile(&comp)?;
        Ok(Self {
            exe,
            values: vec![0.0; BATCH],
            window_ids: vec![-1; BATCH],
            lit_values: xla::Literal::vec1(&vec![0f32; BATCH]),
            lit_wids: xla::Literal::vec1(&vec![-1i32; BATCH]),
            calls: 0,
        })
    }

    /// Number of kernel invocations so far (observability).
    pub fn calls(&self) -> u64 {
        self.calls
    }

    /// Run one padded batch through the AOT executable. `items` must
    /// have length ≤ BATCH with window indices in [0, WINDOWS).
    fn run_chunk(
        &mut self,
        items: &[(f64, u64)],
        out: &mut Vec<(u64, f64, u64, f64)>,
        base: u64,
    ) -> Result<(), RuntimeError> {
        debug_assert!(items.len() <= BATCH);
        // fill the reused scratch buffers directly (no temp allocation)
        for (i, &(v, w)) in items.iter().enumerate() {
            self.values[i] = v as f32;
            self.window_ids[i] = w as i32;
        }
        // pad the tail
        for i in items.len()..BATCH {
            self.window_ids[i] = -1;
        }
        self.lit_values.copy_raw_from(&self.values)?;
        self.lit_wids.copy_raw_from(&self.window_ids)?;
        // exact sums in f64 on the CPU side (see EXACT_CHUNK note): one
        // cheap pass, deterministic for integer-valued inputs at any
        // batch split — the kernel contributes counts and maxes.
        let mut exact_sums = [0f64; WINDOWS];
        for &(v, w) in items {
            exact_sums[w as usize] += v;
        }
        let result = self
            .exe
            .execute::<&xla::Literal>(&[&self.lit_values, &self.lit_wids])?[0][0]
            .to_literal_sync()?;
        self.calls += 1;
        let (_sums, counts, maxes, _avgs) = result.to_tuple4()?;
        let counts = counts.to_vec::<f32>()?;
        let maxes = maxes.to_vec::<f32>()?;
        for w in 0..WINDOWS {
            let count = counts[w] as u64;
            if count > 0 {
                out.push((base + w as u64, exact_sums[w], count, maxes[w] as f64));
            }
        }
        Ok(())
    }
}

impl BatchAggregator for XlaWindowAggregator {
    fn aggregate(&mut self, items: &[(f64, WindowId)]) -> WindowAggregates {
        if items.is_empty() {
            return WindowAggregates::default();
        }
        // Rebase window ids so the batch fits the fixed [0, WINDOWS)
        // kernel range; chunk on both batch length and window span.
        let mut out: Vec<(u64, f64, u64, f64)> = Vec::new();
        let mut start = 0usize;
        while start < items.len() {
            let base = items[start].1;
            let mut end = start;
            while end < items.len()
                && end - start < EXACT_CHUNK
                && items[end].1 >= base
                && items[end].1 - base < WINDOWS as u64
            {
                end += 1;
            }
            if end == start {
                // Out-of-order window id below base: restart chunk there.
                start = end + 1;
                continue;
            }
            let rel: Vec<(f64, u64)> = items[start..end]
                .iter()
                .map(|&(v, w)| (v, w - base))
                .collect();
            if self.run_chunk(&rel, &mut out, base).is_err() {
                // Fall back to the scalar oracle on any runtime error.
                return ScalarAggregator.aggregate(items);
            }
            start = end;
        }
        // Merge duplicate windows across chunks (events of one window
        // split by chunking).
        out.sort_by_key(|&(w, ..)| w);
        let mut merged: Vec<(u64, f64, u64, f64)> = Vec::with_capacity(out.len());
        for (w, s, c, m) in out {
            match merged.last_mut() {
                Some((lw, ls, lc, lm)) if *lw == w => {
                    *ls += s;
                    *lc += c;
                    if m > *lm {
                        *lm = m;
                    }
                }
                _ => merged.push((w, s, c, m)),
            }
        }
        WindowAggregates {
            windows: merged.into_iter().map(|(w, s, c, m)| (w, s, c, m)).collect(),
        }
    }
}

/// Build the batch aggregator for a node: XLA-backed when configured and
/// the artifact exists, scalar otherwise.
pub fn make_aggregator(cfg: &HolonConfig) -> Box<dyn BatchAggregator> {
    if cfg.use_xla {
        match XlaWindowAggregator::load(Path::new(&cfg.artifacts_dir)) {
            Ok(agg) => return Box::new(agg),
            Err(e) => {
                log::warn!("xla aggregator unavailable ({e}); using scalar path");
            }
        }
    }
    Box::new(ScalarAggregator)
}

/// A compiled `crdt_merge` executable: element-wise lattice join of two
/// stacked f32 state matrices (ROWS×COLS = 64×128). Exercised by tests
/// and the merge micro-bench; the engine's BTreeMap-backed CRDTs use
/// their own merge, but this is the vectorized path a dense-state
/// deployment would use (DESIGN.md §Hardware-Adaptation).
pub struct XlaMergeKernel {
    exe: xla::PjRtLoadedExecutable,
}

pub const MERGE_ROWS: usize = 64;
pub const MERGE_COLS: usize = 128;

impl XlaMergeKernel {
    pub fn load(dir: &Path) -> Result<Self, RuntimeError> {
        let path = dir.join("crdt_merge.hlo.txt");
        if !path.exists() {
            return Err(RuntimeError::MissingArtifact(path));
        }
        let client = xla::PjRtClient::cpu()?;
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().expect("utf8 path"),
        )?;
        let comp = xla::XlaComputation::from_proto(&proto);
        Ok(Self {
            exe: client.compile(&comp)?,
        })
    }

    /// Join two ROWS×COLS matrices element-wise (max).
    pub fn merge(&self, a: &[f32], b: &[f32]) -> Result<Vec<f32>, RuntimeError> {
        assert_eq!(a.len(), MERGE_ROWS * MERGE_COLS);
        assert_eq!(b.len(), MERGE_ROWS * MERGE_COLS);
        let la = xla::Literal::vec1(a).reshape(&[MERGE_ROWS as i64, MERGE_COLS as i64])?;
        let lb = xla::Literal::vec1(b).reshape(&[MERGE_ROWS as i64, MERGE_COLS as i64])?;
        let result = self.exe.execute::<xla::Literal>(&[la, lb])?[0][0].to_literal_sync()?;
        let out = result.to_tuple1()?;
        Ok(out.to_vec::<f32>()?)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::api::BatchAggregator;

    fn artifacts_dir() -> PathBuf {
        // tests run from the crate root
        PathBuf::from("artifacts")
    }

    fn have_artifacts() -> bool {
        artifacts_dir().join("window_agg.hlo.txt").exists()
    }

    #[test]
    fn xla_matches_scalar_oracle() {
        if !have_artifacts() {
            eprintln!("skipping: run `make artifacts` first");
            return;
        }
        let mut xla_agg = XlaWindowAggregator::load(&artifacts_dir()).unwrap();
        let mut scalar = ScalarAggregator;
        let items: Vec<(f64, u64)> = (0..500)
            .map(|i| ((i % 97) as f64 * 1.5, (i % 7) as u64))
            .collect();
        let a = xla_agg.aggregate(&items);
        let b = scalar.aggregate(&items);
        assert_eq!(a.windows.len(), b.windows.len());
        for (x, y) in a.windows.iter().zip(b.windows.iter()) {
            assert_eq!(x.0, y.0);
            assert!((x.1 - y.1).abs() < 1e-3, "sum {x:?} vs {y:?}");
            assert_eq!(x.2, y.2);
            assert!((x.3 - y.3).abs() < 1e-6, "max {x:?} vs {y:?}");
        }
    }

    #[test]
    fn xla_handles_large_window_span() {
        if !have_artifacts() {
            return;
        }
        let mut xla_agg = XlaWindowAggregator::load(&artifacts_dir()).unwrap();
        // window ids spanning more than WINDOWS forces chunking
        let items: Vec<(f64, u64)> = (0..200).map(|i| (1.0, i as u64)).collect();
        let a = xla_agg.aggregate(&items);
        assert_eq!(a.windows.len(), 200);
        assert!(a.windows.iter().all(|&(_, s, c, m)| s == 1.0 && c == 1 && m == 1.0));
        assert!(xla_agg.calls() >= (200 / WINDOWS) as u64);
    }

    #[test]
    fn xla_handles_oversize_batch() {
        if !have_artifacts() {
            return;
        }
        let mut xla_agg = XlaWindowAggregator::load(&artifacts_dir()).unwrap();
        let items: Vec<(f64, u64)> = (0..3000).map(|i| (2.0, (i % 4) as u64)).collect();
        let a = xla_agg.aggregate(&items);
        let total: u64 = a.windows.iter().map(|&(_, _, c, _)| c).sum();
        assert_eq!(total, 3000);
    }

    #[test]
    fn merge_kernel_is_elementwise_max() {
        if !artifacts_dir().join("crdt_merge.hlo.txt").exists() {
            return;
        }
        let k = XlaMergeKernel::load(&artifacts_dir()).unwrap();
        let a: Vec<f32> = (0..MERGE_ROWS * MERGE_COLS).map(|i| i as f32).collect();
        let b: Vec<f32> = (0..MERGE_ROWS * MERGE_COLS)
            .map(|i| (MERGE_ROWS * MERGE_COLS - i) as f32)
            .collect();
        let m = k.merge(&a, &b).unwrap();
        for i in 0..a.len() {
            assert_eq!(m[i], a[i].max(b[i]));
        }
    }

    #[test]
    fn missing_artifacts_fall_back() {
        let mut cfg = HolonConfig::default();
        cfg.use_xla = true;
        cfg.artifacts_dir = "/nonexistent".to_string();
        let mut agg = make_aggregator(&cfg);
        let out = agg.aggregate(&[(1.0, 0)]);
        assert_eq!(out.windows, vec![(0, 1.0, 1, 1.0)]);
    }
}
