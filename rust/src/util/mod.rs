//! Small shared utilities: deterministic PRNG, id types, ordered floats.
//!
//! The vendor set has no `rand` crate, so we carry a tiny xorshift64*
//! generator — deterministic, seedable, and good enough for workload
//! generation and the randomized property tests.

pub mod rng;

pub use rng::XorShift64;

/// Identifier of a logical stream partition. Partitions are the unit of
/// ownership, checkpointing and work stealing (paper §4.3).
pub type PartitionId = u32;

/// Identifier of a processing node (a simulated container in the paper's
/// GCP deployment).
pub type NodeId = u32;

/// Simulation timestamps, in *sim-milliseconds* (paper-time). The clock
/// module maps these onto wall time via the configured time scale.
pub type SimTime = u64;

/// An `f64` with a total order, usable as a BTree key (bid prices in the
/// Q7 top-k CRDT). NaNs are ordered greatest; we never produce them.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct OrdF64(pub f64);

impl Eq for OrdF64 {}

impl PartialOrd for OrdF64 {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}

impl Ord for OrdF64 {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        self.0.total_cmp(&other.0)
    }
}

impl From<f64> for OrdF64 {
    fn from(v: f64) -> Self {
        OrdF64(v)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ordf64_total_order() {
        let mut v = vec![OrdF64(3.0), OrdF64(-1.0), OrdF64(2.5)];
        v.sort();
        assert_eq!(v, vec![OrdF64(-1.0), OrdF64(2.5), OrdF64(3.0)]);
    }

    #[test]
    fn ordf64_handles_negative_zero() {
        assert!(OrdF64(-0.0) < OrdF64(0.0));
    }
}
