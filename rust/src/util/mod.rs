//! Small shared utilities: deterministic PRNG, id types, ordered floats.
//!
//! The vendor set has no `rand` crate, so we carry a tiny xorshift64*
//! generator — deterministic, seedable, and good enough for workload
//! generation and the randomized property tests.

pub mod rng;

pub use rng::XorShift64;

/// Identifier of a logical stream partition. Partitions are the unit of
/// ownership, checkpointing and work stealing (paper §4.3).
pub type PartitionId = u32;

/// Identifier of a processing node (a simulated container in the paper's
/// GCP deployment).
pub type NodeId = u32;

/// Simulation timestamps, in *sim-milliseconds* (paper-time). The clock
/// module maps these onto wall time via the configured time scale.
pub type SimTime = u64;

/// An `f64` with a total order, usable as a BTree key (bid prices in the
/// Q7 top-k CRDT). NaNs are ordered greatest; we never produce them.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct OrdF64(pub f64);

impl Eq for OrdF64 {}

impl PartialOrd for OrdF64 {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}

impl Ord for OrdF64 {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        self.0.total_cmp(&other.0)
    }
}

impl From<f64> for OrdF64 {
    fn from(v: f64) -> Self {
        OrdF64(v)
    }
}

/// Poison-recovering mutex access for data-plane modules.
///
/// A bare `.lock().unwrap()` turns one panicking thread into a cascade:
/// every sibling node in the in-process cluster that touches the same
/// mutex re-panics on the poison flag, so a single partition's bug
/// aborts the whole cluster before the exactly-once recovery machinery
/// (heartbeat timeout → steal → checkpoint restore) ever observes the
/// failure. Recovering the guard is sound here: the protected state is
/// either CRDT state — monotone, so a torn update is subsumed by the
/// next merge/anti-entropy round — or an append-only collection whose
/// operations leave it valid on unwind. Enforced by holon-lint rule
/// `lock-unwrap` (S1); see python/tools/holon_lint.py.
pub trait LockExt<T> {
    /// Lock, recovering the guard from a poisoned mutex instead of
    /// propagating the panic.
    fn plane_lock(&self) -> std::sync::MutexGuard<'_, T>;
}

impl<T> LockExt<T> for std::sync::Mutex<T> {
    fn plane_lock(&self) -> std::sync::MutexGuard<'_, T> {
        self.lock().unwrap_or_else(std::sync::PoisonError::into_inner)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ordf64_total_order() {
        let mut v = vec![OrdF64(3.0), OrdF64(-1.0), OrdF64(2.5)];
        v.sort();
        assert_eq!(v, vec![OrdF64(-1.0), OrdF64(2.5), OrdF64(3.0)]);
    }

    #[test]
    fn ordf64_handles_negative_zero() {
        assert!(OrdF64(-0.0) < OrdF64(0.0));
    }

    #[test]
    fn plane_lock_recovers_a_poisoned_mutex() {
        let m = std::sync::Mutex::new(1u32);
        let _ = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            let _g = m.lock().unwrap();
            panic!("poison the lock");
        }));
        assert!(m.lock().is_err(), "mutex should be poisoned");
        *m.plane_lock() += 1;
        assert_eq!(*m.plane_lock(), 2);
    }
}
