//! xorshift64* PRNG — deterministic, seedable, dependency-free.

/// A small, fast, deterministic PRNG (xorshift64*). Not cryptographic;
/// used for workload generation, jittered scheduling, and property tests.
#[derive(Debug, Clone)]
pub struct XorShift64 {
    state: u64,
}

impl XorShift64 {
    /// Create a generator from a seed. A zero seed is remapped (xorshift
    /// has a zero fixed point).
    pub fn new(seed: u64) -> Self {
        Self {
            state: if seed == 0 { 0x9E3779B97F4A7C15 } else { seed },
        }
    }

    /// Next raw 64-bit value.
    pub fn next_u64(&mut self) -> u64 {
        let mut x = self.state;
        x ^= x >> 12;
        x ^= x << 25;
        x ^= x >> 27;
        self.state = x;
        x.wrapping_mul(0x2545F4914F6CDD1D)
    }

    /// Uniform in `[0, bound)`. `bound` must be non-zero.
    pub fn next_below(&mut self, bound: u64) -> u64 {
        debug_assert!(bound > 0);
        // Modulo bias is negligible for our bounds (<< 2^32).
        self.next_u64() % bound
    }

    /// Uniform in `[lo, hi)`.
    pub fn range(&mut self, lo: u64, hi: u64) -> u64 {
        debug_assert!(hi > lo);
        lo + self.next_below(hi - lo)
    }

    /// Uniform float in `[0, 1)`.
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 / (1u64 << 53) as f64
    }

    /// Bernoulli trial with probability `p`.
    pub fn chance(&mut self, p: f64) -> bool {
        self.next_f64() < p
    }

    /// Zipf-like skewed index in `[0, n)` — hot head, long tail. Used by
    /// the Nexmark generator for auction/category popularity.
    pub fn skewed_below(&mut self, n: u64) -> u64 {
        if n <= 1 {
            return 0;
        }
        // Square the uniform draw: density concentrated near zero.
        let u = self.next_f64();
        ((u * u) * n as f64) as u64
    }

    /// Pick a uniformly random element of a slice. Panics on empty input.
    pub fn pick<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.next_below(xs.len() as u64) as usize]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_seed() {
        let mut a = XorShift64::new(42);
        let mut b = XorShift64::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_diverge() {
        let mut a = XorShift64::new(1);
        let mut b = XorShift64::new(2);
        assert_ne!(a.next_u64(), b.next_u64());
    }

    #[test]
    fn zero_seed_is_remapped() {
        let mut r = XorShift64::new(0);
        assert_ne!(r.next_u64(), 0);
    }

    #[test]
    fn next_below_in_bounds() {
        let mut r = XorShift64::new(7);
        for _ in 0..1000 {
            assert!(r.next_below(10) < 10);
        }
    }

    #[test]
    fn next_f64_unit_interval() {
        let mut r = XorShift64::new(9);
        for _ in 0..1000 {
            let f = r.next_f64();
            assert!((0.0..1.0).contains(&f));
        }
    }

    #[test]
    fn skewed_is_head_heavy() {
        let mut r = XorShift64::new(11);
        let n = 100u64;
        let head = (0..10_000).filter(|_| r.skewed_below(n) < n / 4).count();
        // With the squared draw, half the mass lands in the first quarter.
        assert!(head > 4000, "head={head}");
    }

    #[test]
    fn range_bounds() {
        let mut r = XorShift64::new(13);
        for _ in 0..100 {
            let v = r.range(5, 8);
            assert!((5..8).contains(&v));
        }
    }
}
