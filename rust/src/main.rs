//! `holon` — the CLI launcher.
//!
//! Subcommands (config keys are `--key=value` overrides of
//! [`HolonConfig`](holon::config::HolonConfig); see `holon inspect`):
//!
//! ```text
//! holon run      [q0|q4|q7|query1] [--system=holon|flink|flink-spare] [--scenario=...] [--config=FILE] [--key=value ...]
//! holon sim      [--seeds=N] [--start-seed=S] [--plan=PLAN] — deterministic fault-schedule soak
//! holon bench    [--quick] [--bench-out=FILE] — headless perf-trajectory run, writes BENCH_*.json
//! holon bench --targets — list the cargo bench targets for each figure/table
//! holon generate [--count=N] [--partition=P] — dump Nexmark events as text
//! holon inspect  [--config=FILE] [--key=value ...] — print the resolved config
//! holon query    [--staleness=MS] [--key=value ...] — run Q4 briefly, then answer
//!                point/range/top-k queries from every replica's read path
//! holon trace    [q0|q4|q7|query1] [--key=value ...] — traced live run; writes a
//!                Chrome trace_event dump (default holon-trace.json; Perfetto-ready)
//! ```
//!
//! `--trace-out=FILE` on any subcommand enables the flight recorder for
//! the run and writes the dump there (`holon run q7 --trace-out=t.json`);
//! `holon sim` additionally dumps a trace of the shrunk failing schedule
//! automatically when an oracle falsifies.
//!
//! Keyed workloads run over sharded keyed state when `--shard-count=N`
//! is set (`holon run q4 --shard-count=16`): same outputs byte for
//! byte, with per-shard delta gossip and parallel replica joins (see
//! `holon::shard`).
//!
//! `holon bench` runs the throughput_max and table2_latency scenarios
//! headlessly and writes a machine-readable report (schema
//! `holon-bench/v1`, see EXPERIMENTS.md) to `bench_out` so every PR
//! appends a comparable perf data point.
//!
//! `holon sim` explores one fault schedule per seed and checks the
//! determinism / exactly-once / convergence oracles after each run; on
//! falsification it shrinks the schedule and prints a replayable
//! `HOLON_SIM_SEED=… HOLON_SIM_PLAN=…` line, then exits non-zero. The
//! same env vars, when set, replay that exact schedule instead.

use holon::benchkit::{row, secs, section, sparkline};
use holon::config::HolonConfig;
use holon::experiments::{
    bench_report_json, bench_scenarios, run_flink, run_holon, Scenario, SystemKind, Workload,
};
use holon::nexmark::NexmarkGen;
use holon::sim::{run_seed_with, FaultPlan, SimSpec};

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let arg_refs: Vec<&str> = args.iter().map(|s| s.as_str()).collect();

    // --config=FILE first, then --key=value overrides
    let mut cfg = HolonConfig::default();
    let mut rest: Vec<&str> = Vec::new();
    for a in &arg_refs {
        if let Some(path) = a.strip_prefix("--config=") {
            match HolonConfig::from_file(std::path::Path::new(path)) {
                Ok(c) => cfg = c,
                Err(e) => {
                    eprintln!("error reading {path}: {e}");
                    std::process::exit(2);
                }
            }
        } else {
            rest.push(a);
        }
    }
    let rest = match cfg.apply_args(rest.into_iter()) {
        Ok(r) => r,
        Err(e) => {
            eprintln!("error: {e}");
            std::process::exit(2);
        }
    };
    // A dump destination implies recording (the keys stay independent
    // at the config layer so `dump()` roundtrips).
    if !cfg.trace_out.is_empty() {
        cfg.trace = true;
    }

    match rest.first().copied() {
        Some("run") => cmd_run(&cfg, &rest[1..]),
        Some("sim") => cmd_sim(&cfg, &rest[1..]),
        Some("generate") => cmd_generate(&cfg, &rest[1..]),
        Some("inspect") => {
            if let Some(stray) = rest.get(1) {
                eprintln!("unknown inspect option: {stray}");
                std::process::exit(2);
            }
            println!("{}", cfg.dump());
        }
        Some("bench") => cmd_bench(&cfg, &rest[1..]),
        Some("query") => cmd_query(&cfg, &rest[1..]),
        Some("trace") => cmd_trace(&cfg, &rest[1..]),
        _ => {
            eprintln!("usage: holon <run|sim|generate|inspect|bench|query|trace> [options]");
            eprintln!("       holon run q7 --system=holon --scenario=concurrent --nodes=5");
            eprintln!("       holon sim --seeds=100 --start-seed=0");
            eprintln!("       holon query --staleness=0 --shard-count=8");
            eprintln!("       holon trace q7 --trace-out=holon-trace.json");
            std::process::exit(2);
        }
    }
}

/// Seeded fault-schedule soak: `holon sim --seeds=N [--start-seed=S]`.
/// `HOLON_SIM_SEED`/`HOLON_SIM_PLAN` (or `--plan=`) replay one exact
/// schedule instead of generating per-seed ones.
fn cmd_sim(cfg: &HolonConfig, args: &[&str]) {
    let mut seeds = 20u64;
    let mut start_seed = cfg.seed;
    let mut explicit_plan: Option<String> = None;
    let parse_or_die = |flag: &str, v: &str| -> u64 {
        v.parse().unwrap_or_else(|_| {
            eprintln!("bad value for {flag}: {v}");
            std::process::exit(2);
        })
    };
    for a in args {
        if let Some(v) = a.strip_prefix("--seeds=") {
            seeds = parse_or_die("--seeds", v);
        } else if let Some(v) = a.strip_prefix("--start-seed=") {
            start_seed = parse_or_die("--start-seed", v);
        } else if let Some(v) = a.strip_prefix("--plan=") {
            explicit_plan = Some(v.to_string());
        } else {
            eprintln!("unknown sim option: {a}");
            std::process::exit(2);
        }
    }
    if let Ok(s) = std::env::var("HOLON_SIM_SEED") {
        start_seed = s.parse().unwrap_or_else(|_| {
            eprintln!("bad HOLON_SIM_SEED: {s}");
            std::process::exit(2);
        });
        seeds = 1;
    }
    if explicit_plan.is_none() {
        if let Ok(p) = std::env::var("HOLON_SIM_PLAN") {
            explicit_plan = Some(p);
        }
    }

    section(&format!(
        "deterministic simulation | seeds {start_seed}..{} | {}",
        start_seed + seeds,
        explicit_plan
            .as_deref()
            .map(|p| format!("explicit plan `{p}`"))
            .unwrap_or_else(|| "generated plans".to_string()),
    ));
    let mut failures = 0u64;
    for seed in start_seed..start_seed + seeds {
        let spec = SimSpec {
            seed,
            ..SimSpec::default()
        };
        let plan = match &explicit_plan {
            Some(p) => match FaultPlan::parse(p) {
                Ok(plan) => plan,
                Err(e) => {
                    eprintln!("bad plan: {e}");
                    std::process::exit(2);
                }
            },
            None => FaultPlan::generate(seed, spec.nodes, spec.fault_window()),
        };
        match run_seed_with(&spec, &plan, None) {
            Ok(()) => println!("seed {seed:>6}  PASS  {plan}"),
            Err(f) => {
                failures += 1;
                println!("seed {seed:>6}  FAIL  {plan}");
                println!("{f}");
            }
        }
    }
    if failures > 0 {
        eprintln!("{failures} seed(s) falsified an oracle");
        std::process::exit(1);
    }
    println!("all {seeds} seed(s) passed the oracle suite");
}

fn cmd_run(cfg: &HolonConfig, args: &[&str]) {
    let mut workload = Workload::Q7;
    let mut system = SystemKind::Holon;
    let mut scenario = Scenario::Baseline;
    for a in args {
        match *a {
            "q0" => workload = Workload::Q0,
            "q4" => workload = Workload::Q4,
            "q7" => workload = Workload::Q7,
            "query1" => workload = Workload::Query1,
            "--system=holon" => system = SystemKind::Holon,
            "--system=flink" => system = SystemKind::Flink,
            "--system=flink-spare" => system = SystemKind::FlinkSpareSlots,
            "--scenario=baseline" => scenario = Scenario::Baseline,
            "--scenario=concurrent" => scenario = Scenario::ConcurrentFailures,
            "--scenario=subsequent" => scenario = Scenario::SubsequentFailures,
            "--scenario=crash" => scenario = Scenario::CrashFailures,
            other => {
                eprintln!("unknown run option: {other}");
                std::process::exit(2);
            }
        }
    }

    let t0 = cfg.duration_ms / 3;
    let schedule = scenario.schedule(t0);
    section(&format!(
        "{:?} on {:?} | {} nodes, {} partitions, {} ev/s/part, {} s | scenario {:?}",
        workload,
        system,
        cfg.nodes,
        cfg.partitions,
        cfg.events_per_sec_per_partition,
        cfg.duration_ms / 1000,
        scenario,
    ));
    let result = match system {
        SystemKind::Holon => run_holon(cfg, workload, schedule),
        SystemKind::Flink => run_flink(cfg, workload, false, schedule),
        SystemKind::FlinkSpareSlots => run_flink(cfg, workload, true, schedule),
    };
    row(
        "result",
        &[
            ("avg_latency_s", secs(result.latency_mean_ms)),
            ("p99_s", secs(result.latency_p99_ms as f64)),
            ("outputs", result.outputs.to_string()),
            ("consumed", result.consumed.to_string()),
            ("produced", result.produced.to_string()),
            ("peak_throughput", format!("{:.0}/s", result.peak_throughput)),
            ("steals", result.steals.to_string()),
        ],
    );
    let lat: Vec<f64> = result
        .latency_series
        .iter()
        .map(|v| v.unwrap_or(0.0))
        .collect();
    println!("latency    {}", sparkline(&lat));
    println!("throughput {}", sparkline(&result.throughput_series));
}

fn cmd_generate(cfg: &HolonConfig, args: &[&str]) {
    let mut count = 20u64;
    let mut partition = 0u32;
    for a in args {
        if let Some(v) = a.strip_prefix("--count=") {
            count = v.parse().unwrap_or(count);
        } else if let Some(v) = a.strip_prefix("--partition=") {
            partition = v.parse().unwrap_or(partition);
        } else {
            // config typos land here now that apply_args passes unknown
            // flags through — reject rather than silently use defaults
            eprintln!("unknown generate option: {a}");
            std::process::exit(2);
        }
    }
    let mut gen = NexmarkGen::new(cfg.seed, partition);
    for i in 0..count {
        println!("{i:>6}: {:?}", gen.next_event());
    }
}

/// Headless perf-trajectory run: throughput_max + table2_latency
/// scenarios, human-readable rows on stdout, machine-readable
/// `holon-bench/v1` JSON written to `cfg.bench_out` (override with
/// `--bench-out=FILE`; `--quick` is the CI smoke shape).
fn cmd_bench(cfg: &HolonConfig, args: &[&str]) {
    let mut quick = false;
    for a in args {
        match *a {
            "--quick" => quick = true,
            "--targets" => {
                println!("Each paper table/figure has a dedicated bench target:");
                println!("  cargo bench --bench fig6_failure_timeseries   # Fig 6");
                println!("  cargo bench --bench fig7_sensitivity_curves   # Fig 7");
                println!("  cargo bench --bench fig8_sensitivity_bars     # Fig 8");
                println!("  cargo bench --bench table2_latency            # Table 2");
                println!("  cargo bench --bench fig9_scalability          # Fig 9");
                println!("  cargo bench --bench throughput_max            # §5.3 max throughput");
                println!("  cargo bench --bench micro_hotpath             # hot-path micro benches");
                println!("or everything: cargo bench");
                return;
            }
            other => {
                eprintln!("unknown bench option: {other}");
                std::process::exit(2);
            }
        }
    }

    section(&format!(
        "holon bench — perf trajectory{}",
        if quick { " (--quick)" } else { "" }
    ));
    let scenarios = bench_scenarios(cfg, quick);
    for s in &scenarios {
        let r = &s.result;
        row(
            &s.name,
            &[
                ("peak_ev_s", format!("{:.0}", r.peak_throughput)),
                ("p50_ms", r.latency_p50_ms.to_string()),
                ("p99_ms", r.latency_p99_ms.to_string()),
                ("outputs", r.outputs.to_string()),
                ("gossip_B", r.data_plane.gossip_bytes_wire.to_string()),
                (
                    "clones/read",
                    format!(
                        "{}/{}",
                        r.data_plane.payload_clones, r.data_plane.records_read
                    ),
                ),
                ("gaps", r.data_plane.gaps.to_string()),
            ],
        );
    }
    let json = bench_report_json("PR9", quick, &scenarios);
    if let Err(e) = std::fs::write(&cfg.bench_out, json.as_bytes()) {
        eprintln!("error writing {}: {e}", cfg.bench_out);
        std::process::exit(1);
    }
    println!("wrote {} ({} scenarios)", cfg.bench_out, scenarios.len());
}

/// Read-path demo: run the keyed Q4 workload briefly, then answer
/// point/range/top-k queries from *every* node's final replica through
/// `holon::query::QueryEngine` — the same rows from each, because
/// completed windows are identical on every converged replica.
fn cmd_query(cfg: &HolonConfig, args: &[&str]) {
    use holon::clock::SimClock;
    use holon::crdt::PrefixAgg;
    use holon::engine::HolonCluster;
    use holon::nexmark::{producer, CATEGORIES};
    use holon::query::QueryEngine;
    use holon::shard::ShardedMapCrdt;
    use holon::wcrdt::WindowedCrdt;

    let mut staleness = 0u64;
    for a in args {
        if let Some(v) = a.strip_prefix("--staleness=") {
            staleness = v.parse().unwrap_or_else(|_| {
                eprintln!("bad value for --staleness: {v}");
                std::process::exit(2);
            });
        } else {
            eprintln!("unknown query option: {a}");
            std::process::exit(2);
        }
    }

    let mut cfg = cfg.clone();
    cfg.gossip_delta = true;
    let shards = if cfg.shard_count > 0 { cfg.shard_count } else { 8 };
    section(&format!(
        "holon query — Q4 over {} nodes, {} shards, staleness bound {staleness} ms",
        cfg.nodes, shards
    ));

    let processor = holon::nexmark::queries::dataflow_q4_sharded(cfg.window_ms, shards);
    let clock = SimClock::scaled(cfg.wall_ms_per_sim_sec);
    let cluster = HolonCluster::start_with_clock(cfg.clone(), processor, clock.clone());
    let prod = producer::spawn(
        cluster.input.clone(),
        clock.clone(),
        cfg.seed,
        cfg.events_per_sec_per_partition,
        cfg.duration_ms,
    );
    std::thread::sleep(clock.wall_for(cfg.duration_ms + (cfg.window_ms * 4).max(4000)));
    let produced = prod.stop();
    cluster.stop();
    println!("ingested {produced} events; querying each replica:");

    for (node, bytes) in cluster.final_replicas() {
        let state = match WindowedCrdt::<ShardedMapCrdt<u64, PrefixAgg>>::from_bytes(&bytes) {
            Ok(s) => s,
            Err(e) => {
                eprintln!("node {node}: undecodable replica: {e}");
                continue;
            }
        };
        let mut q = QueryEngine::new(state);
        let Some(wid) = q.state().completed_up_to() else {
            println!("node {node}: no completed window yet");
            continue;
        };
        let wid = wid.max(q.state().first_available());
        match q.top_k(wid, 3, staleness) {
            Ok(r) => {
                let rows: Vec<String> = r
                    .value
                    .iter()
                    .map(|(cat, agg)| {
                        format!("cat {cat}: avg {:.0}¢ × {}", agg.avg().unwrap_or(0.0), agg.count())
                    })
                    .collect();
                println!(
                    "node {node} | window {wid} (lag {} ms{}) | top-3 {}",
                    r.lag_ms,
                    if r.is_final { ", final" } else { "" },
                    rows.join(" | "),
                );
            }
            Err(e) => println!("node {node} | window {wid} | {e}"),
        }
        // a point probe per category plus one verifiably-absent key to
        // show the index pre-filter pruning
        for cat in [0, CATEGORIES / 2, 999_999] {
            match q.point(wid, &cat, staleness) {
                Ok(r) => match r.value {
                    Some(agg) => println!("  point cat {cat}: count {}", agg.count()),
                    None => println!("  point cat {cat}: absent"),
                },
                Err(e) => println!("  point cat {cat}: {e}"),
            }
        }
        let s = q.stats();
        println!(
            "  stats: served {} | index hits {} misses {} | rows avoided {}",
            s.served, s.index_hits, s.index_misses, s.scan_rows_avoided
        );
    }
}

/// Traced live run: the chosen workload with the flight recorder on,
/// dumping a Chrome `trace_event` JSON at the end (open the file in
/// Perfetto or chrome://tracing to see the window lifecycle, gossip
/// rounds, and recovery timelines per node). All config keys apply —
/// `holon trace q7 --nodes=3 --duration-ms=10000 --scenario=crash`.
fn cmd_trace(cfg: &HolonConfig, args: &[&str]) {
    let mut workload = Workload::Q7;
    let mut scenario = Scenario::Baseline;
    for a in args {
        match *a {
            "q0" => workload = Workload::Q0,
            "q4" => workload = Workload::Q4,
            "q7" => workload = Workload::Q7,
            "query1" => workload = Workload::Query1,
            "--scenario=baseline" => scenario = Scenario::Baseline,
            "--scenario=concurrent" => scenario = Scenario::ConcurrentFailures,
            "--scenario=subsequent" => scenario = Scenario::SubsequentFailures,
            "--scenario=crash" => scenario = Scenario::CrashFailures,
            other => {
                eprintln!("unknown trace option: {other}");
                std::process::exit(2);
            }
        }
    }
    let mut cfg = cfg.clone();
    cfg.trace = true;
    if cfg.trace_out.is_empty() {
        cfg.trace_out = "holon-trace.json".to_string();
    }
    let schedule = scenario.schedule(cfg.duration_ms / 3);
    section(&format!(
        "holon trace — {:?} on {} nodes, {} s, scenario {:?} → {}",
        workload,
        cfg.nodes,
        cfg.duration_ms / 1000,
        scenario,
        cfg.trace_out,
    ));
    let result = run_holon(&cfg, workload, schedule);
    row(
        "result",
        &[
            ("outputs", result.outputs.to_string()),
            ("p99_ms", result.latency_p99_ms.to_string()),
            ("steals", result.steals.to_string()),
            (
                "trace_dropped",
                result.data_plane.trace_dropped_events.to_string(),
            ),
        ],
    );
}
