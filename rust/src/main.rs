//! `holon` — the CLI launcher.
//!
//! Subcommands (config keys are `--key=value` overrides of
//! [`HolonConfig`](holon::config::HolonConfig); see `holon inspect`):
//!
//! ```text
//! holon run      [q0|q4|q7|query1] [--system=holon|flink|flink-spare] [--scenario=...] [--config=FILE] [--key=value ...]
//! holon bench    — points at the cargo bench targets for each figure/table
//! holon generate [--count=N] [--partition=P] — dump Nexmark events as text
//! holon inspect  [--config=FILE] [--key=value ...] — print the resolved config
//! ```

use holon::benchkit::{row, secs, section, sparkline};
use holon::config::HolonConfig;
use holon::experiments::{run_flink, run_holon, Scenario, SystemKind, Workload};
use holon::nexmark::NexmarkGen;

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let arg_refs: Vec<&str> = args.iter().map(|s| s.as_str()).collect();

    // --config=FILE first, then --key=value overrides
    let mut cfg = HolonConfig::default();
    let mut rest: Vec<&str> = Vec::new();
    for a in &arg_refs {
        if let Some(path) = a.strip_prefix("--config=") {
            match HolonConfig::from_file(std::path::Path::new(path)) {
                Ok(c) => cfg = c,
                Err(e) => {
                    eprintln!("error reading {path}: {e}");
                    std::process::exit(2);
                }
            }
        } else {
            rest.push(a);
        }
    }
    let rest = match cfg.apply_args(rest.into_iter()) {
        Ok(r) => r,
        Err(e) => {
            eprintln!("error: {e}");
            std::process::exit(2);
        }
    };

    match rest.first().copied() {
        Some("run") => cmd_run(&cfg, &rest[1..]),
        Some("generate") => cmd_generate(&cfg, &rest[1..]),
        Some("inspect") => println!("{}", cfg.dump()),
        Some("bench") => cmd_bench(),
        _ => {
            eprintln!("usage: holon <run|generate|inspect|bench> [options]");
            eprintln!("       holon run q7 --system=holon --scenario=concurrent --nodes=5");
            std::process::exit(2);
        }
    }
}

fn cmd_run(cfg: &HolonConfig, args: &[&str]) {
    let mut workload = Workload::Q7;
    let mut system = SystemKind::Holon;
    let mut scenario = Scenario::Baseline;
    for a in args {
        match *a {
            "q0" => workload = Workload::Q0,
            "q4" => workload = Workload::Q4,
            "q7" => workload = Workload::Q7,
            "query1" => workload = Workload::Query1,
            "--system=holon" => system = SystemKind::Holon,
            "--system=flink" => system = SystemKind::Flink,
            "--system=flink-spare" => system = SystemKind::FlinkSpareSlots,
            "--scenario=baseline" => scenario = Scenario::Baseline,
            "--scenario=concurrent" => scenario = Scenario::ConcurrentFailures,
            "--scenario=subsequent" => scenario = Scenario::SubsequentFailures,
            "--scenario=crash" => scenario = Scenario::CrashFailures,
            other => {
                eprintln!("unknown run option: {other}");
                std::process::exit(2);
            }
        }
    }

    let t0 = cfg.duration_ms / 3;
    let schedule = scenario.schedule(t0);
    section(&format!(
        "{:?} on {:?} | {} nodes, {} partitions, {} ev/s/part, {} s | scenario {:?}",
        workload,
        system,
        cfg.nodes,
        cfg.partitions,
        cfg.events_per_sec_per_partition,
        cfg.duration_ms / 1000,
        scenario,
    ));
    let result = match system {
        SystemKind::Holon => run_holon(cfg, workload, schedule),
        SystemKind::Flink => run_flink(cfg, workload, false, schedule),
        SystemKind::FlinkSpareSlots => run_flink(cfg, workload, true, schedule),
    };
    row(
        "result",
        &[
            ("avg_latency_s", secs(result.latency_mean_ms)),
            ("p99_s", secs(result.latency_p99_ms as f64)),
            ("outputs", result.outputs.to_string()),
            ("consumed", result.consumed.to_string()),
            ("produced", result.produced.to_string()),
            ("peak_throughput", format!("{:.0}/s", result.peak_throughput)),
            ("steals", result.steals.to_string()),
        ],
    );
    let lat: Vec<f64> = result
        .latency_series
        .iter()
        .map(|v| v.unwrap_or(0.0))
        .collect();
    println!("latency    {}", sparkline(&lat));
    println!("throughput {}", sparkline(&result.throughput_series));
}

fn cmd_generate(cfg: &HolonConfig, args: &[&str]) {
    let mut count = 20u64;
    let mut partition = 0u32;
    for a in args {
        if let Some(v) = a.strip_prefix("--count=") {
            count = v.parse().unwrap_or(count);
        } else if let Some(v) = a.strip_prefix("--partition=") {
            partition = v.parse().unwrap_or(partition);
        }
    }
    let mut gen = NexmarkGen::new(cfg.seed, partition);
    for i in 0..count {
        println!("{i:>6}: {:?}", gen.next_event());
    }
}

fn cmd_bench() {
    println!("Each paper table/figure has a dedicated bench target:");
    println!("  cargo bench --bench fig6_failure_timeseries   # Fig 6");
    println!("  cargo bench --bench fig7_sensitivity_curves   # Fig 7");
    println!("  cargo bench --bench fig8_sensitivity_bars     # Fig 8");
    println!("  cargo bench --bench table2_latency            # Table 2");
    println!("  cargo bench --bench fig9_scalability          # Fig 9");
    println!("  cargo bench --bench throughput_max            # §5.3 max throughput");
    println!("  cargo bench --bench micro_hotpath             # hot-path micro benches");
    println!("or everything: cargo bench");
}
