//! Metrics: latency histograms, throughput time series, and the paper's
//! derived *sensitivity* metric (§5.1, after Gramoli et al.): the area
//! between the latency curve under failures and the failure-free
//! baseline — it captures both amplitude and duration of a disturbance.
//!
//! Cluster-wide counters (gossip volume, per-cause drop counters
//! `dropped_{partition,loss,no_inbox,backpressure}`, the async
//! data-plane high-water marks `outbound_queue_depth_max` /
//! `inbox_depth_max`, and `credits_stalled_rounds`) live on
//! [`crate::engine::ClusterMetrics`]; this module holds the reusable
//! measurement primitives they feed into.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex, OnceLock};

use crate::util::{LockExt, SimTime};

/// Log-bucketed latency histogram (HDR-style, base-1.07 buckets over
/// sim-ms). Recording is lock-free — one relaxed `fetch_add` per bucket
/// plus a `fetch_max` for the tail — so the sink's per-output `record()`
/// never contends with concurrent recorders or end-of-run readers (the
/// old `Mutex<HistInner>` serialized every output through one lock).
/// Percentile queries walk a snapshot of the bucket array at the end;
/// concurrent recording during a query can only under-count in-flight
/// samples, never corrupt the histogram.
#[derive(Debug, Clone)]
pub struct LatencyHistogram {
    inner: Arc<HistInner>,
}

#[derive(Debug)]
struct HistInner {
    buckets: [AtomicU64; NBUCKETS],
    count: AtomicU64,
    /// Integer sim-ms sum: exact for the u64 latencies we record, and
    /// atomically updatable (the old f64 sum was neither).
    sum_ms: AtomicU64,
    max: AtomicU64,
}

const GROWTH: f64 = 1.07;
const NBUCKETS: usize = 256;

fn bucket_of(ms: u64) -> usize {
    if ms <= 1 {
        return 0;
    }
    let b = ((ms as f64).ln() / GROWTH.ln()) as usize;
    b.min(NBUCKETS - 1)
}

/// Bucket upper-bound table, computed once — `percentile()` used to call
/// `powi` per bucket on every query. One extra entry covers the
/// `bucket_value(b + 1)` upper-bound read off the last bucket.
fn bucket_values() -> &'static [u64; NBUCKETS + 1] {
    static TABLE: OnceLock<[u64; NBUCKETS + 1]> = OnceLock::new();
    TABLE.get_or_init(|| {
        let mut t = [0u64; NBUCKETS + 1];
        for (b, v) in t.iter_mut().enumerate() {
            *v = GROWTH.powi(b as i32) as u64;
        }
        t
    })
}

fn bucket_value(b: usize) -> u64 {
    bucket_values()[b.min(NBUCKETS)]
}

impl Default for LatencyHistogram {
    fn default() -> Self {
        Self::new()
    }
}

impl LatencyHistogram {
    pub fn new() -> Self {
        #[allow(clippy::declare_interior_mutable_const)]
        const ZERO: AtomicU64 = AtomicU64::new(0);
        Self {
            inner: Arc::new(HistInner {
                buckets: [ZERO; NBUCKETS],
                count: AtomicU64::new(0),
                sum_ms: AtomicU64::new(0),
                max: AtomicU64::new(0),
            }),
        }
    }

    /// Record one latency sample. Lock-free: four relaxed atomic RMWs,
    /// no allocation, safe from any thread.
    pub fn record(&self, latency_ms: u64) {
        let h = &*self.inner;
        h.buckets[bucket_of(latency_ms)].fetch_add(1, Ordering::Relaxed);
        h.count.fetch_add(1, Ordering::Relaxed);
        h.sum_ms.fetch_add(latency_ms, Ordering::Relaxed);
        h.max.fetch_max(latency_ms, Ordering::Relaxed);
    }

    pub fn count(&self) -> u64 {
        self.inner.count.load(Ordering::Relaxed)
    }

    pub fn mean(&self) -> f64 {
        let count = self.count();
        if count == 0 {
            0.0
        } else {
            self.inner.sum_ms.load(Ordering::Relaxed) as f64 / count as f64
        }
    }

    pub fn max(&self) -> u64 {
        self.inner.max.load(Ordering::Relaxed)
    }

    /// Approximate percentile (bucket upper bound), q in [0, 1].
    pub fn percentile(&self, q: f64) -> u64 {
        let h = &*self.inner;
        let count = h.count.load(Ordering::Relaxed);
        if count == 0 {
            return 0;
        }
        let max = h.max.load(Ordering::Relaxed);
        let target = (q * count as f64).ceil() as u64;
        let mut seen = 0;
        for (b, n) in h.buckets.iter().enumerate() {
            seen += n.load(Ordering::Relaxed);
            if seen >= target {
                return bucket_value(b + 1).min(max.max(1));
            }
        }
        max
    }

    pub fn p99(&self) -> u64 {
        self.percentile(0.99)
    }

    pub fn p50(&self) -> u64 {
        self.percentile(0.50)
    }

    pub fn reset(&self) {
        let h = &*self.inner;
        for b in h.buckets.iter() {
            b.store(0, Ordering::Relaxed);
        }
        h.count.store(0, Ordering::Relaxed);
        h.sum_ms.store(0, Ordering::Relaxed);
        h.max.store(0, Ordering::Relaxed);
    }
}

/// A time series of (sim-time bucket, value) samples — the raw material
/// of the paper's Figures 6 and 7.
#[derive(Debug, Clone)]
pub struct TimeSeries {
    bucket_ms: SimTime,
    inner: Arc<Mutex<SeriesInner>>,
}

#[derive(Debug, Default)]
struct SeriesInner {
    /// per-bucket (sum, count) — enables both mean latency series and
    /// event-count (throughput) series.
    samples: Vec<(f64, u64)>,
}

impl TimeSeries {
    pub fn new(bucket_ms: SimTime) -> Self {
        assert!(bucket_ms > 0);
        Self {
            bucket_ms,
            inner: Arc::new(Mutex::new(SeriesInner::default())),
        }
    }

    pub fn bucket_ms(&self) -> SimTime {
        self.bucket_ms
    }

    /// Record a measurement at sim-time `t`.
    pub fn record(&self, t: SimTime, value: f64) {
        let idx = (t / self.bucket_ms) as usize;
        let mut s = self.inner.plane_lock();
        if s.samples.len() <= idx {
            s.samples.resize(idx + 1, (0.0, 0));
        }
        s.samples[idx].0 += value;
        s.samples[idx].1 += 1;
    }

    /// Record `n` occurrences at time `t` (throughput counting).
    pub fn bump(&self, t: SimTime, n: u64) {
        let idx = (t / self.bucket_ms) as usize;
        let mut s = self.inner.plane_lock();
        if s.samples.len() <= idx {
            s.samples.resize(idx + 1, (0.0, 0));
        }
        s.samples[idx].1 += n;
    }

    /// Mean value per bucket (None for empty buckets).
    pub fn means(&self) -> Vec<Option<f64>> {
        self.inner
            .plane_lock()
            .samples
            .iter()
            .map(|&(sum, n)| if n == 0 { None } else { Some(sum / n as f64) })
            .collect()
    }

    /// Events per second per bucket.
    pub fn rates_per_sec(&self) -> Vec<f64> {
        let per_bucket = self.bucket_ms as f64 / 1000.0;
        self.inner
            .plane_lock()
            .samples
            .iter()
            .map(|&(_, n)| n as f64 / per_bucket)
            .collect()
    }

    pub fn counts(&self) -> Vec<u64> {
        self.inner.plane_lock().samples.iter().map(|&(_, n)| n).collect()
    }
}

/// Excess-latency curve of a failure run against its baseline (the
/// curves of the paper's Figure 7), in ms per bucket.
///
/// The baseline is step-interpolated across empty buckets. The failure
/// curve treats *prolonged* silence as an outage: after a grace period
/// of [`OUTAGE_GRACE_BUCKETS`] (covering the natural output cadence —
/// 1 s windows over 500 ms buckets leave every other bucket empty), the
/// oldest unserved window keeps aging, so the effective latency grows
/// by the bucket width per silent bucket — a stalled system accumulates
/// unbounded sensitivity instead of inheriting its pre-failure latency.
pub const OUTAGE_GRACE_BUCKETS: usize = 2;

pub fn excess_series(
    with_failures: &[Option<f64>],
    baseline: &[Option<f64>],
    bucket_ms: SimTime,
) -> Vec<f64> {
    let n = with_failures.len().max(baseline.len());
    let mut out = Vec::with_capacity(n);
    let mut last_f = 0.0;
    let mut last_b = 0.0;
    let mut silent = 0usize;
    for i in 0..n {
        match with_failures.get(i) {
            Some(Some(v)) => {
                last_f = *v;
                silent = 0;
            }
            _ => silent += 1,
        }
        if let Some(Some(v)) = baseline.get(i) {
            last_b = *v;
        }
        let aging = silent.saturating_sub(OUTAGE_GRACE_BUCKETS) as f64 * bucket_ms as f64;
        out.push((last_f + aging - last_b).max(0.0));
    }
    out
}

/// Sensitivity: area between a latency curve under failures and the
/// failure-free baseline, integrated over the experiment (sim-seconds ×
/// latency-seconds) — see [`excess_series`] for the outage treatment.
pub fn sensitivity(
    with_failures: &[Option<f64>],
    baseline: &[Option<f64>],
    bucket_ms: SimTime,
) -> f64 {
    let dt_s = bucket_ms as f64 / 1000.0;
    excess_series(with_failures, baseline, bucket_ms)
        .iter()
        .map(|ms| ms / 1000.0 * dt_s)
        .sum()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn histogram_mean_and_count() {
        let h = LatencyHistogram::new();
        for v in [10, 20, 30] {
            h.record(v);
        }
        assert_eq!(h.count(), 3);
        assert!((h.mean() - 20.0).abs() < 1e-9);
        assert_eq!(h.max(), 30);
    }

    #[test]
    fn histogram_percentiles_ordered() {
        let h = LatencyHistogram::new();
        for v in 1..=1000u64 {
            h.record(v);
        }
        let p50 = h.p50();
        let p99 = h.p99();
        assert!(p50 <= p99);
        // log buckets: accept a loose band around the true values
        assert!((400..700).contains(&p50), "p50={p50}");
        assert!(p99 >= 900, "p99={p99}");
    }

    /// Satellite pin for the atomic-bucket rewrite: many threads
    /// hammering `record()` concurrently (the sink path plus stage
    /// recorders) must lose no samples and keep the aggregates exact —
    /// the property the old mutex bought, now without the contention.
    #[test]
    fn histogram_concurrent_recording_loses_nothing() {
        let h = LatencyHistogram::new();
        let threads = 8;
        let per_thread = 10_000u64;
        std::thread::scope(|s| {
            for t in 0..threads {
                let h = h.clone();
                s.spawn(move || {
                    for i in 0..per_thread {
                        h.record((t * per_thread + i) % 1000 + 1);
                    }
                });
            }
        });
        assert_eq!(h.count(), threads * per_thread);
        // integer sum is exact: mean of the uniform 1..=1000 cycle
        let expected_mean = 500.5;
        assert!((h.mean() - expected_mean).abs() < 1.0, "mean={}", h.mean());
        assert_eq!(h.max(), 1000);
        assert!(h.p50() <= h.p99());
    }

    #[test]
    fn histogram_bucket_value_table_matches_powi() {
        for b in 0..=NBUCKETS {
            assert_eq!(bucket_value(b), GROWTH.powi(b as i32) as u64, "bucket {b}");
        }
        // out-of-range indices clamp to the table's last entry
        assert_eq!(bucket_value(NBUCKETS + 5), bucket_value(NBUCKETS));
    }

    #[test]
    fn histogram_empty_is_zero() {
        let h = LatencyHistogram::new();
        assert_eq!(h.p99(), 0);
        assert_eq!(h.mean(), 0.0);
    }

    #[test]
    fn histogram_reset() {
        let h = LatencyHistogram::new();
        h.record(5);
        h.reset();
        assert_eq!(h.count(), 0);
    }

    #[test]
    fn series_buckets_by_time() {
        let s = TimeSeries::new(100);
        s.record(50, 10.0);
        s.record(60, 20.0);
        s.record(250, 5.0);
        let means = s.means();
        assert_eq!(means[0], Some(15.0));
        assert_eq!(means[1], None);
        assert_eq!(means[2], Some(5.0));
    }

    #[test]
    fn series_rates() {
        let s = TimeSeries::new(500);
        s.bump(0, 50);
        s.bump(400, 50);
        s.bump(700, 10);
        let rates = s.rates_per_sec();
        assert_eq!(rates[0], 200.0); // 100 events / 0.5 s
        assert_eq!(rates[1], 20.0);
    }

    #[test]
    fn sensitivity_zero_when_identical() {
        let a = vec![Some(100.0), Some(100.0)];
        assert_eq!(sensitivity(&a, &a, 1000), 0.0);
    }

    #[test]
    fn sensitivity_measures_excess_area() {
        // baseline 100ms; failure curve spikes to 1100ms for 2 buckets
        // of 1s each => excess 1s * 2s = 2.0 s².
        let base = vec![Some(100.0); 4];
        let fail = vec![Some(100.0), Some(1100.0), Some(1100.0), Some(100.0)];
        let s = sensitivity(&fail, &base, 1000);
        assert!((s - 2.0).abs() < 1e-9, "s={s}");
    }

    #[test]
    fn short_gaps_carry_forward_within_grace() {
        // One silent bucket (within the grace of the output cadence)
        // carries the last latency forward: excess = 2.0 + 2.0 + 0.
        let base = vec![Some(100.0); 4];
        let fail = vec![Some(100.0), Some(2100.0), None, Some(100.0)];
        let s = sensitivity(&fail, &base, 1000);
        assert!((s - 4.0).abs() < 1e-9, "s={s}");
    }

    #[test]
    fn permanent_stall_grows_without_bound() {
        let base = vec![Some(100.0); 10];
        let mut fail = vec![Some(100.0)];
        fail.extend(std::iter::repeat(None).take(9));
        let s = sensitivity(&fail, &base, 1000);
        // after the 2-bucket grace, the outage ages linearly
        assert!(s > 20.0, "s={s}");
        // and a longer stall is strictly worse
        let mut fail2 = vec![Some(100.0)];
        fail2.extend(std::iter::repeat(None).take(19));
        let base2 = vec![Some(100.0); 20];
        assert!(sensitivity(&fail2, &base2, 1000) > 2.0 * s);
    }

    #[test]
    fn negative_excess_clamped() {
        // Faster-than-baseline does not produce negative sensitivity.
        let base = vec![Some(100.0); 2];
        let fail = vec![Some(50.0), Some(50.0)];
        assert_eq!(sensitivity(&fail, &base, 1000), 0.0);
    }
}
