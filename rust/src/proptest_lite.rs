//! A small property-testing helper (no proptest in the vendor set).
//!
//! `forall(cases, gen, prop)` runs `prop` on `cases` generated inputs;
//! on failure it re-runs with progressively simpler inputs from the
//! generator's shrink ladder (re-generation at smaller "size" budgets —
//! a cheap stand-in for structural shrinking) and reports the smallest
//! failing seed so the case is reproducible.

use crate::util::XorShift64;

/// Input generator: builds a case from a PRNG and a size budget.
pub trait Gen {
    type Value;
    fn generate(&self, rng: &mut XorShift64, size: usize) -> Self::Value;
}

impl<T, F: Fn(&mut XorShift64, usize) -> T> Gen for F {
    type Value = T;
    fn generate(&self, rng: &mut XorShift64, size: usize) -> T {
        self(rng, size)
    }
}

/// Outcome of a property run.
#[derive(Debug)]
pub struct Falsified {
    pub seed: u64,
    pub size: usize,
    pub message: String,
}

/// Run `prop` on `cases` generated values of max size `max_size`.
/// Panics with the minimal failing (seed, size) on falsification.
pub fn forall<G: Gen>(
    name: &str,
    cases: u32,
    max_size: usize,
    gen: &G,
    prop: impl Fn(&G::Value) -> Result<(), String>,
) {
    let base_seed = 0x5EED ^ (name.len() as u64) << 7;
    for case in 0..cases {
        let seed = base_seed.wrapping_add((case as u64).wrapping_mul(0x9E3779B97F4A7C15));
        // ramp sizes: early cases small, later cases large
        let size = 1 + (max_size - 1) * case as usize / cases.max(1) as usize;
        if let Some(f) = run_one(gen, &prop, seed, size) {
            // shrink: retry same seed at smaller sizes, keep smallest fail
            let mut minimal = f;
            let mut s = size;
            while s > 1 {
                s /= 2;
                if let Some(f2) = run_one(gen, &prop, seed, s) {
                    minimal = f2;
                } else {
                    break;
                }
            }
            panic!(
                "property '{name}' falsified (seed={:#x}, size={}): {}",
                minimal.seed, minimal.size, minimal.message
            );
        }
    }
}

/// Generic greedy shrinker: repeatedly ask `candidates` for smaller
/// variants of the current value and keep the first one that still
/// fails, until no candidate fails or the re-run `budget` is spent.
/// Returns the smallest failing value found (possibly the initial one).
///
/// This is the structural-shrinking counterpart to `forall`'s size-ladder
/// re-generation; the simulation harness uses it to minimize failing
/// fault plans (each probe is a full cluster run, hence the budget).
pub fn shrink_to_minimal<T: Clone>(
    initial: T,
    candidates: impl Fn(&T) -> Vec<T>,
    mut still_fails: impl FnMut(&T) -> bool,
    mut budget: usize,
) -> T {
    let mut best = initial;
    loop {
        let mut improved = false;
        for cand in candidates(&best) {
            if budget == 0 {
                return best;
            }
            budget -= 1;
            if still_fails(&cand) {
                best = cand;
                improved = true;
                break; // restart from the new, smaller value
            }
        }
        if !improved {
            return best;
        }
    }
}

fn run_one<G: Gen>(
    gen: &G,
    prop: &impl Fn(&G::Value) -> Result<(), String>,
    seed: u64,
    size: usize,
) -> Option<Falsified> {
    let mut rng = XorShift64::new(seed);
    let value = gen.generate(&mut rng, size);
    match prop(&value) {
        Ok(()) => None,
        Err(message) => Some(Falsified {
            seed,
            size,
            message,
        }),
    }
}

/// Convenience generator: a vector of `n ≤ size` values from `f`.
pub fn vec_of<T>(
    f: impl Fn(&mut XorShift64) -> T,
) -> impl Fn(&mut XorShift64, usize) -> Vec<T> {
    move |rng, size| {
        let n = rng.next_below(size as u64 + 1) as usize;
        (0..n).map(|_| f(rng)).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_completes() {
        forall("sum is commutative", 50, 64, &vec_of(|r| r.next_below(100)), |xs| {
            let fwd: u64 = xs.iter().sum();
            let rev: u64 = xs.iter().rev().sum();
            if fwd == rev {
                Ok(())
            } else {
                Err(format!("{fwd} != {rev}"))
            }
        });
    }

    #[test]
    #[should_panic(expected = "falsified")]
    fn failing_property_is_reported() {
        forall("all vectors are short", 50, 64, &vec_of(|r| r.next_below(10)), |xs| {
            if xs.len() < 5 {
                Ok(())
            } else {
                Err(format!("len={}", xs.len()))
            }
        });
    }

    #[test]
    fn shrink_to_minimal_drops_irrelevant_elements() {
        // Property fails iff the vector contains a 7; dropping one
        // element at a time must shrink to exactly [7].
        let initial = vec![1u64, 9, 7, 4, 2];
        let candidates = |v: &Vec<u64>| {
            (0..v.len())
                .map(|i| {
                    let mut c = v.clone();
                    c.remove(i);
                    c
                })
                .collect::<Vec<_>>()
        };
        let min = shrink_to_minimal(initial, candidates, |v| v.contains(&7), 1000);
        assert_eq!(min, vec![7]);
    }

    #[test]
    fn shrink_to_minimal_respects_budget() {
        let mut probes = 0;
        let min = shrink_to_minimal(
            vec![7u64; 16],
            |v: &Vec<u64>| {
                (0..v.len())
                    .map(|i| {
                        let mut c = v.clone();
                        c.remove(i);
                        c
                    })
                    .collect()
            },
            |v| {
                probes += 1;
                v.contains(&7)
            },
            3,
        );
        assert_eq!(probes, 3);
        assert_eq!(min.len(), 13); // three successful single-drops
    }

    #[test]
    fn generation_is_deterministic() {
        let gen = vec_of(|r| r.next_below(1000));
        let mut r1 = XorShift64::new(7);
        let mut r2 = XorShift64::new(7);
        assert_eq!(gen(&mut r1, 32), gen(&mut r2, 32));
    }
}
