//! Flight recorder: deterministic, low-overhead event tracing.
//!
//! Every node (and the sink, under [`SINK_NODE`]) owns a bounded ring
//! buffer of structured [`TraceEvent`]s recording the full window
//! lifecycle — opened → delta merged → watermark advanced → fired →
//! converged → emitted → delivered/deduped at the sink — plus
//! gossip-round causality (round id, encode size, per-peer flush
//! outcome), recovery timelines (steal → checkpoint restore → first
//! output), and checkpoint/backpressure events.
//!
//! # Overhead contract
//!
//! The recorder is built so instrumentation can stay in the hot paths
//! permanently:
//!
//! - **Disabled** (the default): [`TraceHandle::record`] is a single
//!   branch on an inline bool — no lock, no allocation. The
//!   `micro_hotpath` counting-allocator harness asserts the
//!   steady-state emit loop stays at zero global allocations with a
//!   disabled handle threaded through it.
//! - **Enabled**: one uncontended per-node mutex lock and a `Copy`
//!   store into a pre-allocated ring. The ring never grows; when full
//!   it overwrites the oldest event and counts the loss in
//!   `dropped_events` (exported as the `trace_dropped_events` bench
//!   counter), so the newest — most diagnostic — events always
//!   survive.
//!
//! # Span pairing
//!
//! Events pair into spans through `span_id`, never through pointers:
//!
//! - window lifecycle events use the **window end timestamp** (sim ms)
//!   as `span_id`, so a window's open/fire/converge/emit/dedup line up
//!   across nodes and the sink;
//! - gossip events use the sender's **round id** (`GossipRound` at the
//!   sender, `PeerFlush` outcomes for the same flush batch);
//! - recovery events use the **partition id**
//!   (`StealStart` → `CheckpointRestore` → `FirstOutput`).
//!
//! # Determinism
//!
//! An event is fully determined by `(t, node, kind, span_id, detail,
//! aux)` — all plain integers, no wall-clock reads, no addresses — so
//! a trace of a deterministic execution is itself deterministic: the
//! seeded-script test below pins that the same event stream produces
//! byte-identical Chrome-trace dumps. Live cluster runs read the
//! scaled [`crate::clock::SimClock`], whose millisecond quantisation
//! absorbs most scheduling jitter but is still wall-driven; the
//! byte-identity guarantee therefore attaches to the *event stream*,
//! and full-run dumps are diffable modulo thread interleaving.
//!
//! # Export
//!
//! [`Tracer::chrome_trace_json`] writes the Chrome `trace_event`
//! format (instant events, `ts` in microseconds, `tid` = node id)
//! loadable in Perfetto / `about:tracing`. The sim harness dumps the
//! recorder automatically when an oracle falsifies, attaching the
//! dump path next to the `HOLON_SIM_SEED=…` repro line.

use std::collections::BTreeMap;
use std::sync::{Arc, Mutex};

use crate::benchkit::JsonWriter;
use crate::util::{LockExt, NodeId, SimTime};

/// Pseudo node id the sink records under (`tid` in the Chrome dump).
pub const SINK_NODE: NodeId = NodeId::MAX;

/// Default per-node ring capacity (events). At the ~6 events per
/// node-loop iteration of a busy node this holds the last few hundred
/// iterations — enough to reconstruct a failure tail without growing.
pub const DEFAULT_RING_CAP: usize = 4096;

/// What happened. Names are the Chrome-trace event names; see the
/// module docs for span-pairing rules.
#[repr(u8)]
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum TraceKind {
    /// First local contribution materialised a window. `span_id` =
    /// window end ts of the *newest* window in the drained batch,
    /// `detail` = count opened since last drain, `aux` = oldest
    /// window end ts.
    WindowOpened,
    /// A gossip join changed local state. `span_id` = peer node id,
    /// `detail` = payload bytes, `aux` unused (the receiver cannot
    /// tell a full-sync payload from a delta; the sender's
    /// [`TraceKind::GossipRound`] event carries that bit).
    DeltaMerged,
    /// A gossip join was a no-op (redundant bytes). Fields as
    /// [`TraceKind::DeltaMerged`].
    MergeNoop,
    /// The cluster-wide watermark floor advanced. `span_id` = new
    /// floor (sim ms), `detail` = previous floor.
    WatermarkAdvanced,
    /// The floor passed a window end: the window fired. `span_id` =
    /// window end ts.
    WindowFired,
    /// Output record accepted by the sink: the value all replicas
    /// converged on. `span_id` = ref_ts (window end ts), `detail` =
    /// end-to-end latency ms, `aux` = sequence number.
    WindowConverged,
    /// A batch of output frames left a node. `span_id` = ref_ts of
    /// the first frame, `detail` = frame count, `aux` = batch bytes.
    WindowEmitted,
    /// Duplicate output dropped at the sink. `span_id` = ref_ts,
    /// `aux` = sequence number.
    SinkDeduped,
    /// A gossip round was encoded and broadcast. `span_id` = round
    /// id, `detail` = payload bytes, `aux` = 1 full sync / 0 delta.
    GossipRound,
    /// A delta round had nothing to ship. `span_id` = round id.
    GossipSkipped,
    /// Outcome of one `Bus::flush` toward one peer. `span_id` = peer
    /// node id, `detail` = delivered count, `aux` = parked count
    /// (high 32 bits) | dropped count (low 32 bits).
    PeerFlush,
    /// A node began stealing an unowned/failed partition. `span_id` =
    /// partition id.
    StealStart,
    /// Checkpoint restore during recovery. `span_id` = partition id,
    /// `detail` = restored input cursor, `aux` = restored output seq.
    CheckpointRestore,
    /// First output batch from a recovered partition. `span_id` =
    /// partition id, `detail` = ms since the steal began.
    FirstOutput,
    /// A partition checkpoint was encoded and stored. `span_id` =
    /// partition id, `detail` = encoded bytes, `aux` = input cursor.
    Checkpoint,
    /// Credit backpressure engaged (parked traffic or a zero-credit
    /// live peer). `span_id` = messages left parked by the last flush,
    /// `detail` = the shrunk per-iteration event budget.
    Backpressure,
}

impl TraceKind {
    pub fn name(self) -> &'static str {
        match self {
            TraceKind::WindowOpened => "window_opened",
            TraceKind::DeltaMerged => "delta_merged",
            TraceKind::MergeNoop => "merge_noop",
            TraceKind::WatermarkAdvanced => "watermark_advanced",
            TraceKind::WindowFired => "window_fired",
            TraceKind::WindowConverged => "window_converged",
            TraceKind::WindowEmitted => "window_emitted",
            TraceKind::SinkDeduped => "sink_deduped",
            TraceKind::GossipRound => "gossip_round",
            TraceKind::GossipSkipped => "gossip_skipped",
            TraceKind::PeerFlush => "peer_flush",
            TraceKind::StealStart => "steal_start",
            TraceKind::CheckpointRestore => "checkpoint_restore",
            TraceKind::FirstOutput => "first_output",
            TraceKind::Checkpoint => "checkpoint",
            TraceKind::Backpressure => "backpressure",
        }
    }
}

/// One recorded event. Plain `Copy` integers only: recording is a
/// struct store, and dumps are deterministic functions of the stream.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct TraceEvent {
    /// Sim time (ms) the event was recorded at.
    pub t: SimTime,
    /// Recording node ([`SINK_NODE`] for the sink).
    pub node: NodeId,
    pub kind: TraceKind,
    /// Span correlation key — see module docs.
    pub span_id: u64,
    pub detail: u64,
    pub aux: u64,
}

/// Bounded event ring. Pre-allocated to capacity at creation;
/// overwrites the oldest event when full and counts the loss.
#[derive(Debug)]
pub struct TraceRing {
    buf: Vec<TraceEvent>,
    cap: usize,
    /// Oldest element (== next overwrite slot) once the ring is full.
    head: usize,
    /// Lifetime events overwritten.
    dropped: u64,
    /// Overwrites since the last [`TraceRing::take_dropped`] drain.
    fresh_dropped: u64,
}

impl TraceRing {
    fn new(cap: usize) -> Self {
        let cap = cap.max(1);
        Self {
            buf: Vec::with_capacity(cap),
            cap,
            head: 0,
            dropped: 0,
            fresh_dropped: 0,
        }
    }

    fn push(&mut self, ev: TraceEvent) {
        if self.buf.len() < self.cap {
            self.buf.push(ev);
        } else {
            self.buf[self.head] = ev;
            self.head = (self.head + 1) % self.cap;
            self.dropped += 1;
            self.fresh_dropped += 1;
        }
    }

    pub fn len(&self) -> usize {
        self.buf.len()
    }

    pub fn is_empty(&self) -> bool {
        self.buf.is_empty()
    }

    /// Lifetime count of overwritten (lost) events.
    pub fn dropped(&self) -> u64 {
        self.dropped
    }

    fn take_dropped(&mut self) -> u64 {
        std::mem::take(&mut self.fresh_dropped)
    }

    /// Events oldest → newest.
    pub fn iter(&self) -> impl Iterator<Item = &TraceEvent> {
        self.buf[self.head..].iter().chain(self.buf[..self.head].iter())
    }
}

/// Per-process flight recorder: hands out per-node [`TraceHandle`]s
/// and renders the combined dump. Cheap to share (`Arc<Tracer>`).
#[derive(Debug)]
pub struct Tracer {
    enabled: bool,
    cap: usize,
    rings: Mutex<BTreeMap<NodeId, Arc<Mutex<TraceRing>>>>,
}

impl Tracer {
    /// An enabled recorder with `cap` events per node ring.
    pub fn new(cap: usize) -> Self {
        Self {
            enabled: true,
            cap: cap.max(1),
            rings: Mutex::new(BTreeMap::new()),
        }
    }

    /// A recorder whose handles record nothing (a single branch on
    /// the hot path, zero allocations).
    pub fn disabled() -> Self {
        Self {
            enabled: false,
            cap: 1,
            rings: Mutex::new(BTreeMap::new()),
        }
    }

    pub fn is_enabled(&self) -> bool {
        self.enabled
    }

    /// Handle for `node`. Re-requesting a node's handle (e.g. after a
    /// crash-restart in the sim) reattaches to the same ring so the
    /// pre-crash tail survives in the dump.
    pub fn handle(&self, node: NodeId) -> TraceHandle {
        if !self.enabled {
            return TraceHandle::disabled(node);
        }
        let ring = self
            .rings
            .plane_lock()
            .entry(node)
            .or_insert_with(|| Arc::new(Mutex::new(TraceRing::new(self.cap))))
            .clone();
        TraceHandle {
            enabled: true,
            node,
            ring: Some(ring),
        }
    }

    /// Total events currently held across all rings.
    pub fn event_count(&self) -> usize {
        self.rings
            .plane_lock()
            .values()
            .map(|r| r.plane_lock().len())
            .sum()
    }

    /// Lifetime overwritten events across all rings.
    pub fn dropped_total(&self) -> u64 {
        self.rings
            .plane_lock()
            .values()
            .map(|r| r.plane_lock().dropped())
            .sum()
    }

    /// Render the Chrome `trace_event` JSON dump: one instant event
    /// per recorded [`TraceEvent`], `ts` in microseconds, `tid` = node
    /// id, rings in ascending node order, each oldest → newest.
    /// `counters` lands in `otherData` as an end-of-run snapshot.
    pub fn chrome_trace_json(&self, counters: &[(&str, u64)]) -> String {
        let mut w = JsonWriter::new();
        w.obj();
        w.arr_field("traceEvents");
        let rings = self.rings.plane_lock();
        for (node, ring) in rings.iter() {
            let ring = ring.plane_lock();
            for ev in ring.iter() {
                w.obj()
                    .str_field("name", ev.kind.name())
                    .str_field("ph", "i")
                    .str_field("s", "t")
                    .u64_field("ts", ev.t.saturating_mul(1000))
                    .u64_field("pid", 0)
                    .u64_field("tid", *node as u64)
                    .obj_field("args")
                    .u64_field("span", ev.span_id)
                    .u64_field("detail", ev.detail)
                    .u64_field("aux", ev.aux)
                    .end_obj()
                    .end_obj();
            }
        }
        drop(rings);
        w.end_arr();
        w.str_field("displayTimeUnit", "ms");
        w.obj_field("otherData");
        w.str_field("schema", "holon-trace/v1");
        w.u64_field("dropped_events", self.dropped_total());
        for (k, v) in counters {
            w.u64_field(k, *v);
        }
        w.end_obj();
        w.end_obj();
        w.finish()
    }
}

/// A node's recording endpoint. Clone-cheap; safe to thread through
/// hot paths — `record` is a branch when disabled.
#[derive(Debug, Clone)]
pub struct TraceHandle {
    enabled: bool,
    node: NodeId,
    ring: Option<Arc<Mutex<TraceRing>>>,
}

impl TraceHandle {
    /// A handle that records nothing — the default for code paths
    /// (unit tests, benches) that don't wire a recorder.
    pub fn disabled(node: NodeId) -> Self {
        Self {
            enabled: false,
            node,
            ring: None,
        }
    }

    #[inline]
    pub fn enabled(&self) -> bool {
        self.enabled
    }

    /// Record one event. Disabled: a single branch. Enabled: one
    /// uncontended lock + `Copy` store into the pre-allocated ring —
    /// never allocates.
    // lint: zero-alloc
    #[inline]
    pub fn record(&self, t: SimTime, kind: TraceKind, span_id: u64, detail: u64, aux: u64) {
        if !self.enabled {
            return;
        }
        if let Some(ring) = &self.ring {
            ring.plane_lock().push(TraceEvent {
                t,
                node: self.node,
                kind,
                span_id,
                detail,
                aux,
            });
        }
    }

    /// Drain the ring's overwrite count since the last call — the
    /// node loop mirrors this into the `trace_dropped_events` metric.
    pub fn take_dropped(&self) -> u64 {
        match &self.ring {
            Some(ring) => ring.plane_lock().take_dropped(),
            None => 0,
        }
    }
}

/// Feed a seeded, scripted event stream into `tracer` — the
/// deterministic stand-in for a cluster run used by the byte-identity
/// test (the layer the same-seed ⇒ same-dump guarantee is pinned at).
/// Returns the event count.
pub fn scripted_events(tracer: &Tracer, seed: u64, events: usize, nodes: u32) -> usize {
    use crate::util::XorShift64;
    const KINDS: [TraceKind; 8] = [
        TraceKind::WindowOpened,
        TraceKind::DeltaMerged,
        TraceKind::WatermarkAdvanced,
        TraceKind::WindowFired,
        TraceKind::WindowConverged,
        TraceKind::WindowEmitted,
        TraceKind::GossipRound,
        TraceKind::PeerFlush,
    ];
    let mut rng = XorShift64::new(seed);
    let nodes = nodes.max(1);
    let handles: Vec<TraceHandle> = (0..nodes).map(|n| tracer.handle(n)).collect();
    let mut t: SimTime = 0;
    for _ in 0..events {
        t += rng.next_u64() % 7;
        let h = &handles[(rng.next_u64() % nodes as u64) as usize];
        let kind = KINDS[(rng.next_u64() % KINDS.len() as u64) as usize];
        let span = rng.next_u64() % 1000;
        let detail = rng.next_u64() % 4096;
        h.record(t, kind, span, detail, 0);
    }
    events
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ring_wraparound_keeps_newest_and_counts_drops() {
        let tracer = Tracer::new(4);
        let h = tracer.handle(0);
        for i in 0..10u64 {
            h.record(i, TraceKind::WindowFired, i, 0, 0);
        }
        let rings = tracer.rings.lock().unwrap();
        let ring = rings[&0].lock().unwrap();
        assert_eq!(ring.len(), 4);
        assert_eq!(ring.dropped(), 6);
        let kept: Vec<u64> = ring.iter().map(|e| e.t).collect();
        assert_eq!(kept, vec![6, 7, 8, 9], "oldest overwritten, newest kept, order preserved");
        drop(ring);
        drop(rings);
        assert_eq!(tracer.dropped_total(), 6);
        // the drain-for-metrics counter resets, the lifetime one doesn't
        assert_eq!(h.take_dropped(), 6);
        assert_eq!(h.take_dropped(), 0);
        assert_eq!(tracer.dropped_total(), 6);
    }

    #[test]
    fn same_seed_twice_yields_byte_identical_dumps() {
        let mk = |seed: u64| {
            let tracer = Tracer::new(256);
            scripted_events(&tracer, seed, 1000, 3);
            tracer.chrome_trace_json(&[("processed", 42)])
        };
        let a = mk(0xD00D);
        let b = mk(0xD00D);
        assert_eq!(a, b, "same seed must give byte-identical dumps");
        assert!(a.contains("\"traceEvents\""));
        assert!(a.contains("\"schema\":\"holon-trace/v1\""));
        assert!(a.contains("\"dropped_events\":"));
        let c = mk(0xBEEF);
        assert_ne!(a, c, "different seeds should differ");
    }

    #[test]
    fn disabled_tracer_records_nothing() {
        let tracer = Tracer::disabled();
        let h = tracer.handle(7);
        assert!(!h.enabled());
        h.record(1, TraceKind::GossipRound, 1, 1, 1);
        assert_eq!(tracer.event_count(), 0);
        assert_eq!(tracer.dropped_total(), 0);
        assert_eq!(h.take_dropped(), 0);
        // and its dump is still a valid empty document
        let dump = tracer.chrome_trace_json(&[]);
        assert!(dump.contains("\"traceEvents\":[]"));
    }

    #[test]
    fn handle_reattaches_to_existing_ring() {
        let tracer = Tracer::new(16);
        let h1 = tracer.handle(3);
        h1.record(1, TraceKind::StealStart, 0, 0, 0);
        // crash-restart: a fresh handle for the same node sees the ring
        let h2 = tracer.handle(3);
        h2.record(2, TraceKind::CheckpointRestore, 0, 0, 0);
        assert_eq!(tracer.event_count(), 2);
    }

    #[test]
    fn kind_names_are_unique() {
        let all = [
            TraceKind::WindowOpened,
            TraceKind::DeltaMerged,
            TraceKind::MergeNoop,
            TraceKind::WatermarkAdvanced,
            TraceKind::WindowFired,
            TraceKind::WindowConverged,
            TraceKind::WindowEmitted,
            TraceKind::SinkDeduped,
            TraceKind::GossipRound,
            TraceKind::GossipSkipped,
            TraceKind::PeerFlush,
            TraceKind::StealStart,
            TraceKind::CheckpointRestore,
            TraceKind::FirstOutput,
            TraceKind::Checkpoint,
            TraceKind::Backpressure,
        ];
        let names: std::collections::BTreeSet<&str> = all.iter().map(|k| k.name()).collect();
        assert_eq!(names.len(), all.len());
    }
}
