//! Minimal benchmark harness (no criterion in the vendored crate set).
//!
//! `cargo bench` drives `rust/benches/*.rs` with `harness = false`; each
//! bench builds its scenario, runs it, and prints the table/figure rows
//! through these helpers so all outputs share one format that
//! EXPERIMENTS.md quotes directly.

use std::time::Instant;

/// Wall-clock timing statistics over repeated runs of a closure.
#[derive(Debug, Clone)]
pub struct Stats {
    pub name: String,
    pub iters: u32,
    pub mean_ns: f64,
    pub min_ns: f64,
    pub max_ns: f64,
}

impl Stats {
    pub fn mean_us(&self) -> f64 {
        self.mean_ns / 1e3
    }

    pub fn mean_ms(&self) -> f64 {
        self.mean_ns / 1e6
    }
}

/// Time `f` for `iters` iterations after `warmup` unmeasured ones.
pub fn bench(name: &str, warmup: u32, iters: u32, mut f: impl FnMut()) -> Stats {
    for _ in 0..warmup {
        f();
    }
    let mut total = 0f64;
    let mut min = f64::MAX;
    let mut max = 0f64;
    for _ in 0..iters {
        let t0 = Instant::now();
        f();
        let dt = t0.elapsed().as_nanos() as f64;
        total += dt;
        min = min.min(dt);
        max = max.max(dt);
    }
    let s = Stats {
        name: name.to_string(),
        iters,
        mean_ns: total / iters as f64,
        min_ns: min,
        max_ns: max,
    };
    println!(
        "bench {:<40} mean {:>12.2} us   min {:>12.2} us   max {:>12.2} us   ({} iters)",
        s.name,
        s.mean_ns / 1e3,
        s.min_ns / 1e3,
        s.max_ns / 1e3,
        iters
    );
    s
}

/// Print a section header for one paper table/figure.
pub fn section(title: &str) {
    println!();
    println!("=== {title} ===");
}

/// Print a table row of (label, columns).
pub fn row(label: &str, cols: &[(&str, String)]) {
    let cells: Vec<String> = cols.iter().map(|(k, v)| format!("{k}={v}")).collect();
    println!("{:<28} {}", label, cells.join("  "));
}

/// Format seconds with 2 decimals from sim-ms.
pub fn secs(sim_ms: f64) -> String {
    format!("{:.2}", sim_ms / 1000.0)
}

/// Format a ratio like "5.2x".
pub fn ratio(a: f64, b: f64) -> String {
    if b == 0.0 {
        "inf".to_string()
    } else {
        format!("{:.1}x", a / b)
    }
}

/// Render an ASCII sparkline of a series (for time-series figures in
/// the bench output).
pub fn sparkline(values: &[f64]) -> String {
    const TICKS: [char; 8] = ['▁', '▂', '▃', '▄', '▅', '▆', '▇', '█'];
    let max = values.iter().copied().fold(f64::MIN, f64::max);
    let min = values.iter().copied().fold(f64::MAX, f64::min);
    let span = (max - min).max(1e-12);
    values
        .iter()
        .map(|&v| {
            let idx = (((v - min) / span) * 7.0).round() as usize;
            TICKS[idx.min(7)]
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_measures_and_counts() {
        let mut n = 0u64;
        let s = bench("noop", 2, 5, || n += 1);
        assert_eq!(n, 7); // warmup + iters
        assert_eq!(s.iters, 5);
        assert!(s.min_ns <= s.mean_ns && s.mean_ns <= s.max_ns);
    }

    #[test]
    fn sparkline_renders_all_buckets() {
        let s = sparkline(&[0.0, 1.0, 2.0, 3.0, 4.0, 5.0, 6.0, 7.0]);
        assert_eq!(s.chars().count(), 8);
        assert!(s.starts_with('▁') && s.ends_with('█'));
    }

    #[test]
    fn ratio_formats() {
        assert_eq!(ratio(10.0, 2.0), "5.0x");
        assert_eq!(ratio(1.0, 0.0), "inf");
    }

    #[test]
    fn secs_formats() {
        assert_eq!(secs(1234.0), "1.23");
    }
}
