//! Minimal benchmark harness (no criterion in the vendored crate set).
//!
//! `cargo bench` drives `rust/benches/*.rs` with `harness = false`; each
//! bench builds its scenario, runs it, and prints the table/figure rows
//! through these helpers so all outputs share one format that
//! EXPERIMENTS.md quotes directly. [`JsonWriter`] additionally backs the
//! machine-readable `BENCH_*.json` perf-trajectory reports written by
//! `holon bench` (schema documented in EXPERIMENTS.md).

// Benchmarks measure wall time by definition; this module is the
// sanctioned boundary. Mirrors the holon-lint D2 (wall-clock) exemption.
#![allow(clippy::disallowed_methods)]

use std::time::Instant;

/// Minimal streaming JSON emitter (no serde in the vendored crate set):
/// just enough structure for the `holon bench` reports. Scope nesting is
/// tracked so commas are inserted correctly; strings are escaped;
/// non-finite floats are emitted as `null` (JSON has no NaN).
#[derive(Debug, Default)]
pub struct JsonWriter {
    buf: String,
    /// per open scope: whether it already has an element
    stack: Vec<bool>,
}

impl JsonWriter {
    pub fn new() -> Self {
        Self::default()
    }

    fn elem(&mut self) {
        if let Some(top) = self.stack.last_mut() {
            if *top {
                self.buf.push(',');
            }
            *top = true;
        }
    }

    fn push_escaped(&mut self, s: &str) {
        self.buf.push('"');
        for c in s.chars() {
            match c {
                '"' => self.buf.push_str("\\\""),
                '\\' => self.buf.push_str("\\\\"),
                '\n' => self.buf.push_str("\\n"),
                '\r' => self.buf.push_str("\\r"),
                '\t' => self.buf.push_str("\\t"),
                c if (c as u32) < 0x20 => {
                    self.buf.push_str(&format!("\\u{:04x}", c as u32));
                }
                c => self.buf.push(c),
            }
        }
        self.buf.push('"');
    }

    fn key(&mut self, k: &str) {
        self.elem();
        self.push_escaped(k);
        self.buf.push(':');
    }

    /// Open the root object or an object array element.
    pub fn obj(&mut self) -> &mut Self {
        self.elem();
        self.buf.push('{');
        self.stack.push(false);
        self
    }

    /// Open an object-valued field.
    pub fn obj_field(&mut self, k: &str) -> &mut Self {
        self.key(k);
        self.buf.push('{');
        self.stack.push(false);
        self
    }

    pub fn end_obj(&mut self) -> &mut Self {
        self.stack.pop();
        self.buf.push('}');
        self
    }

    /// Open an array-valued field.
    pub fn arr_field(&mut self, k: &str) -> &mut Self {
        self.key(k);
        self.buf.push('[');
        self.stack.push(false);
        self
    }

    pub fn end_arr(&mut self) -> &mut Self {
        self.stack.pop();
        self.buf.push(']');
        self
    }

    pub fn str_field(&mut self, k: &str, v: &str) -> &mut Self {
        self.key(k);
        self.push_escaped(v);
        self
    }

    pub fn u64_field(&mut self, k: &str, v: u64) -> &mut Self {
        self.key(k);
        self.buf.push_str(&v.to_string());
        self
    }

    /// Append a bare u64 element inside an open array scope (numeric
    /// arrays like the per-shard gossip-byte counters).
    pub fn u64_elem(&mut self, v: u64) -> &mut Self {
        self.elem();
        self.buf.push_str(&v.to_string());
        self
    }

    pub fn f64_field(&mut self, k: &str, v: f64) -> &mut Self {
        self.key(k);
        if v.is_finite() {
            self.buf.push_str(&format!("{v:.3}"));
        } else {
            self.buf.push_str("null");
        }
        self
    }

    pub fn bool_field(&mut self, k: &str, v: bool) -> &mut Self {
        self.key(k);
        self.buf.push_str(if v { "true" } else { "false" });
        self
    }

    /// Finish and return the document.
    pub fn finish(self) -> String {
        debug_assert!(self.stack.is_empty(), "unbalanced JSON scopes");
        self.buf
    }
}

/// Wall-clock timing statistics over repeated runs of a closure.
#[derive(Debug, Clone)]
pub struct Stats {
    pub name: String,
    pub iters: u32,
    pub mean_ns: f64,
    pub min_ns: f64,
    pub max_ns: f64,
}

impl Stats {
    pub fn mean_us(&self) -> f64 {
        self.mean_ns / 1e3
    }

    pub fn mean_ms(&self) -> f64 {
        self.mean_ns / 1e6
    }
}

/// Time `f` for `iters` iterations after `warmup` unmeasured ones.
pub fn bench(name: &str, warmup: u32, iters: u32, mut f: impl FnMut()) -> Stats {
    for _ in 0..warmup {
        f();
    }
    let mut total = 0f64;
    let mut min = f64::MAX;
    let mut max = 0f64;
    for _ in 0..iters {
        let t0 = Instant::now();
        f();
        let dt = t0.elapsed().as_nanos() as f64;
        total += dt;
        min = min.min(dt);
        max = max.max(dt);
    }
    let s = Stats {
        name: name.to_string(),
        iters,
        mean_ns: total / iters as f64,
        min_ns: min,
        max_ns: max,
    };
    println!(
        "bench {:<40} mean {:>12.2} us   min {:>12.2} us   max {:>12.2} us   ({} iters)",
        s.name,
        s.mean_ns / 1e3,
        s.min_ns / 1e3,
        s.max_ns / 1e3,
        iters
    );
    s
}

/// Print a section header for one paper table/figure.
pub fn section(title: &str) {
    println!();
    println!("=== {title} ===");
}

/// Print a table row of (label, columns).
pub fn row(label: &str, cols: &[(&str, String)]) {
    let cells: Vec<String> = cols.iter().map(|(k, v)| format!("{k}={v}")).collect();
    println!("{:<28} {}", label, cells.join("  "));
}

/// Format seconds with 2 decimals from sim-ms.
pub fn secs(sim_ms: f64) -> String {
    format!("{:.2}", sim_ms / 1000.0)
}

/// Format a ratio like "5.2x".
pub fn ratio(a: f64, b: f64) -> String {
    if b == 0.0 {
        "inf".to_string()
    } else {
        format!("{:.1}x", a / b)
    }
}

/// Render an ASCII sparkline of a series (for time-series figures in
/// the bench output).
pub fn sparkline(values: &[f64]) -> String {
    const TICKS: [char; 8] = ['▁', '▂', '▃', '▄', '▅', '▆', '▇', '█'];
    let max = values.iter().copied().fold(f64::MIN, f64::max);
    let min = values.iter().copied().fold(f64::MAX, f64::min);
    let span = (max - min).max(1e-12);
    values
        .iter()
        .map(|&v| {
            let idx = (((v - min) / span) * 7.0).round() as usize;
            TICKS[idx.min(7)]
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_measures_and_counts() {
        let mut n = 0u64;
        let s = bench("noop", 2, 5, || n += 1);
        assert_eq!(n, 7); // warmup + iters
        assert_eq!(s.iters, 5);
        assert!(s.min_ns <= s.mean_ns && s.mean_ns <= s.max_ns);
    }

    #[test]
    fn sparkline_renders_all_buckets() {
        let s = sparkline(&[0.0, 1.0, 2.0, 3.0, 4.0, 5.0, 6.0, 7.0]);
        assert_eq!(s.chars().count(), 8);
        assert!(s.starts_with('▁') && s.ends_with('█'));
    }

    #[test]
    fn ratio_formats() {
        assert_eq!(ratio(10.0, 2.0), "5.0x");
        assert_eq!(ratio(1.0, 0.0), "inf");
    }

    #[test]
    fn secs_formats() {
        assert_eq!(secs(1234.0), "1.23");
    }

    #[test]
    fn json_writer_nests_and_escapes() {
        let mut j = JsonWriter::new();
        j.obj()
            .str_field("schema", "holon-bench/v1")
            .bool_field("quick", true)
            .arr_field("scenarios");
        j.obj()
            .str_field("name", "a\"b\\c\nd")
            .u64_field("outputs", 7)
            .f64_field("p99", 1.5)
            .end_obj();
        j.obj().str_field("name", "second").f64_field("p99", f64::NAN).end_obj();
        j.end_arr().end_obj();
        let s = j.finish();
        assert_eq!(
            s,
            "{\"schema\":\"holon-bench/v1\",\"quick\":true,\"scenarios\":[\
             {\"name\":\"a\\\"b\\\\c\\nd\",\"outputs\":7,\"p99\":1.500},\
             {\"name\":\"second\",\"p99\":null}]}"
        );
    }

    #[test]
    fn json_writer_empty_containers() {
        let mut j = JsonWriter::new();
        j.obj().arr_field("xs").end_arr().obj_field("o").end_obj().end_obj();
        assert_eq!(j.finish(), "{\"xs\":[],\"o\":{}}");
    }

    #[test]
    fn json_writer_numeric_arrays() {
        let mut j = JsonWriter::new();
        j.obj().arr_field("bytes");
        for v in [0u64, 17, 4096] {
            j.u64_elem(v);
        }
        j.end_arr().u64_field("n", 3).end_obj();
        assert_eq!(j.finish(), "{\"bytes\":[0,17,4096],\"n\":3}");
    }
}
