//! Exactly-once semantics (§3.3): each event's effects appear exactly
//! once in state; outputs may be physically duplicated but dedup by
//! (partition, seq) makes them exactly-once for a consumer. These tests
//! inject aggressive failures and verify counts.

use holon::clock::SimClock;
use holon::codec::Decode;
use holon::config::HolonConfig;
use holon::engine::node::decode_output;
use holon::engine::HolonCluster;
use holon::nexmark::producer;
use holon::nexmark::queries::{Query1, RatioOut};
use holon::nexmark::Event;
use holon::sim::{check_exactly_once, collect_outputs, RunArtifacts};

fn cfg() -> HolonConfig {
    let mut cfg = HolonConfig::default();
    cfg.nodes = 4;
    cfg.partitions = 8;
    cfg.events_per_sec_per_partition = 1500;
    cfg.wall_ms_per_sim_sec = 50.0;
    cfg.duration_ms = 8000;
    cfg.window_ms = 1000;
    cfg.gossip_interval_ms = 50;
    cfg.checkpoint_interval_ms = 300;
    cfg.heartbeat_interval_ms = 200;
    cfg.failure_timeout_ms = 800;
    cfg
}

/// Assert every emitted window matches bid counts recomputed straight
/// off the input log (ground truth), requiring at least `min_windows`
/// comparisons so the check cannot pass vacuously.
fn assert_ratio_outputs_match_ground_truth(
    cluster: &HolonCluster<Query1>,
    cfg: &HolonConfig,
    min_windows: u64,
) {
    // ground truth: bids per (partition, window) from the input log
    let mut truth: Vec<std::collections::BTreeMap<u64, u64>> =
        vec![Default::default(); cfg.partitions as usize];
    let mut total_truth: std::collections::BTreeMap<u64, u64> = Default::default();
    for p in 0..cfg.partitions {
        let (recs, _) = cluster.input.read(p, 0, usize::MAX >> 1);
        for rec in recs {
            if let Ok(ev) = Event::from_bytes(&rec.payload) {
                if ev.is_bid() {
                    let w = rec.event_ts / cfg.window_ms;
                    *truth[p as usize].entry(w).or_insert(0) += 1;
                    *total_truth.entry(w).or_insert(0) += 1;
                }
            }
        }
    }

    // compare every emitted window against the ground truth
    let mut compared = 0;
    for p in 0..cfg.partitions {
        let (recs, _) = cluster.output.read(p, 0, usize::MAX >> 1);
        let mut seen = 0u64;
        for rec in recs {
            let (seq, _ts, inner) = decode_output(&rec.payload).unwrap();
            if seq < seen {
                continue;
            }
            seen = seq + 1;
            let out = RatioOut::from_bytes(&inner).unwrap();
            let want_local = truth[p as usize].get(&out.window).copied().unwrap_or(0);
            let want_total = total_truth.get(&out.window).copied().unwrap_or(0);
            assert_eq!(
                out.local, want_local,
                "partition {p} window {} local count",
                out.window
            );
            assert_eq!(
                out.total, want_total,
                "partition {p} window {} global count",
                out.window
            );
            compared += 1;
        }
    }
    assert!(compared >= min_windows, "only {compared} windows compared");
}

/// Assert the sink dedup invariant directly on the output log: after
/// first-delivery-per-seq dedup the sequence numbers are contiguous
/// from 0, and every physical replay is byte-identical to the first
/// delivery of its sequence number — the same oracle the simulation
/// harness applies after every fault schedule.
fn assert_dedup_invariant(cluster: &HolonCluster<Query1>, cfg: &HolonConfig) {
    let (raw, deduped) = collect_outputs(&cluster.output, cfg.partitions);
    let artifacts = RunArtifacts {
        partitions: cfg.partitions,
        raw,
        deduped,
        replicas: Default::default(),
        steals: 0,
        trace_json: None,
    };
    if let Err(f) = check_exactly_once(&artifacts) {
        panic!("dedup invariant violated: {f}");
    }
}

/// Count the bids per window per partition straight off the input log
/// (ground truth), then compare with Query1 outputs after a failure.
#[test]
fn state_counts_every_event_exactly_once_despite_failures() {
    let cfg = cfg();
    let clock = SimClock::scaled(cfg.wall_ms_per_sim_sec);
    let cluster =
        HolonCluster::start_with_clock(cfg.clone(), Query1::new(cfg.window_ms), clock.clone());
    let prod = producer::spawn(
        cluster.input.clone(),
        clock.clone(),
        cfg.seed,
        cfg.events_per_sec_per_partition,
        cfg.duration_ms,
    );
    // two failures while data is flowing
    std::thread::sleep(clock.wall_for(2500));
    cluster.fail_node(0);
    std::thread::sleep(clock.wall_for(1200));
    cluster.restart_node(0);
    std::thread::sleep(clock.wall_for(800));
    cluster.fail_node(2);
    std::thread::sleep(clock.wall_for(1200));
    cluster.restart_node(2);
    std::thread::sleep(clock.wall_for(cfg.duration_ms - 5700 + 4000));
    prod.stop();
    cluster.stop();

    // delivery audit: replays may duplicate but must never skip a seq
    assert_eq!(
        cluster.metrics.gaps.load(std::sync::atomic::Ordering::Acquire),
        0,
        "sink observed output sequence gaps"
    );
    assert_ratio_outputs_match_ground_truth(&cluster, &cfg, 20);
}

/// Double restart: the node is killed *again* mid-recovery — after it
/// has stolen its partitions back but before its first post-restart
/// checkpoint — so the second recovery replays from the stale
/// pre-restart checkpoints. The sink dedup invariant (contiguous seqs,
/// byte-identical replays) and the ground-truth counts must survive.
#[test]
fn double_restart_mid_recovery_keeps_dedup_invariant() {
    let cfg = cfg();
    let clock = SimClock::scaled(cfg.wall_ms_per_sim_sec);
    let cluster =
        HolonCluster::start_with_clock(cfg.clone(), Query1::new(cfg.window_ms), clock.clone());
    let prod = producer::spawn(
        cluster.input.clone(),
        clock.clone(),
        cfg.seed,
        cfg.events_per_sec_per_partition,
        cfg.duration_ms,
    );
    std::thread::sleep(clock.wall_for(2500));
    cluster.fail_node(1);
    std::thread::sleep(clock.wall_for(900));
    cluster.restart_node(1);
    // the restarted node claims its partitions after one heartbeat round
    // (200 sim-ms) and would first checkpoint 300 sim-ms after recovery;
    // killing at +350 lands between the two
    std::thread::sleep(clock.wall_for(350));
    cluster.fail_node(1);
    std::thread::sleep(clock.wall_for(1000));
    cluster.restart_node(1);
    std::thread::sleep(clock.wall_for(cfg.duration_ms - 4750 + 4000));
    prod.stop();
    cluster.stop();

    assert_eq!(
        cluster.metrics.gaps.load(std::sync::atomic::Ordering::Acquire),
        0,
        "sink observed output sequence gaps"
    );
    assert_dedup_invariant(&cluster, &cfg);
    assert_ratio_outputs_match_ground_truth(&cluster, &cfg, 20);
}

/// Duplicated physical outputs must be byte-identical to the originals
/// (idempotent emission — the paper's justification for calling
/// duplicated outputs exactly-once).
#[test]
fn physical_duplicates_are_byte_identical() {
    // Whether a given fail/restart produces physical duplicates depends
    // on checkpoint timing; try a few injection offsets until it does.
    let mut total_duplicates = 0;
    for attempt in 0..4 {
        let mut cfg = cfg();
        cfg.seed += attempt;
        // stale checkpoints make replays (and thus duplicates) likely
        cfg.checkpoint_interval_ms = 1500;
        let clock = SimClock::scaled(cfg.wall_ms_per_sim_sec);
        let cluster =
            HolonCluster::start_with_clock(cfg.clone(), Query1::new(cfg.window_ms), clock.clone());
        let prod = producer::spawn(
            cluster.input.clone(),
            clock.clone(),
            cfg.seed,
            cfg.events_per_sec_per_partition,
            cfg.duration_ms,
        );
        std::thread::sleep(clock.wall_for(3000 + attempt * 300));
        cluster.fail_node(1);
        std::thread::sleep(clock.wall_for(1500));
        cluster.restart_node(1);
        std::thread::sleep(clock.wall_for(800));
        cluster.fail_node(2);
        std::thread::sleep(clock.wall_for(1500));
        cluster.restart_node(2);
        std::thread::sleep(clock.wall_for(cfg.duration_ms + 4000));
        prod.stop();
        cluster.stop();

        for p in 0..cfg.partitions {
            let (recs, _) = cluster.output.read(p, 0, usize::MAX >> 1);
            let mut first: std::collections::BTreeMap<u64, Vec<u8>> = Default::default();
            for rec in recs {
                let (seq, _ts, inner) = decode_output(&rec.payload).unwrap();
                match first.get(&seq) {
                    None => {
                        first.insert(seq, inner.to_vec());
                    }
                    Some(orig) => {
                        assert_eq!(orig, &inner, "partition {p} seq {seq} duplicate differs");
                        total_duplicates += 1;
                    }
                }
            }
        }
        if total_duplicates > 0 {
            return; // property exercised and verified
        }
    }
    panic!("no duplicates produced across attempts; failure injection ineffective");
}

/// The checkpoint store's monotone rule: concurrent checkpointing from
/// overlapping owners never regresses offsets.
#[test]
fn checkpoints_never_regress_under_overlap() {
    let cfg = cfg();
    let clock = SimClock::scaled(cfg.wall_ms_per_sim_sec);
    let cluster =
        HolonCluster::start_with_clock(cfg.clone(), Query1::new(cfg.window_ms), clock.clone());
    let prod = producer::spawn(
        cluster.input.clone(),
        clock.clone(),
        cfg.seed,
        cfg.events_per_sec_per_partition,
        cfg.duration_ms,
    );
    // watch checkpoint offsets while failing/restarting nodes
    let mut high: std::collections::BTreeMap<u32, u64> = Default::default();
    let steps = 40;
    for step in 0..steps {
        std::thread::sleep(clock.wall_for(cfg.duration_ms / steps));
        if step == 10 {
            cluster.fail_node(3);
        }
        if step == 16 {
            cluster.restart_node(3);
        }
        for p in cluster.store.partitions() {
            let cp = cluster.store.get(p).unwrap();
            let e = high.entry(p).or_insert(0);
            assert!(
                cp.nxt_idx >= *e,
                "partition {p} checkpoint regressed: {} < {}",
                cp.nxt_idx,
                *e
            );
            *e = cp.nxt_idx;
        }
    }
    prod.stop();
    cluster.stop();
    assert!(!high.is_empty());
}
