//! Regression tests for the full-sync delta-amplification fix (Crdt
//! trait v3, change-reporting merges).
//!
//! Pre-v3, `Crdt::merge` returned nothing, so merging a *received*
//! full-sync payload had to conservatively re-mark every window/shard
//! dirty — and the one delta round after each anti-entropy round
//! re-shipped ~full state (the 1-in-`FULL_SYNC_EVERY` amplification
//! documented in EXPERIMENTS.md). With merges reporting inflation,
//! receive-path dirty-marking is confined to genuine changes, and a
//! replica with nothing dirty and no watermark movement skips the
//! gossip encode/broadcast entirely.

// lint:allow-file(discarded-merge): amplification harness merges to advance replica state; the assertions are on bytes shipped, not outcomes
use std::sync::atomic::Ordering;

use holon::api::SharedState;
use holon::clock::SimClock;
use holon::codec::{Decode, Encode};
use holon::config::HolonConfig;
use holon::crdt::{GCounter, MergeOutcome};
use holon::engine::HolonCluster;
use holon::nexmark::queries::Q7;
use holon::shard::ShardedMapCrdt;
use holon::wcrdt::{WindowAssigner, WindowedCrdt};

type Keyed = WindowedCrdt<ShardedMapCrdt<u64, GCounter>>;

/// `n` already-converged replicas of a realistically-sized keyed
/// windowed state, with their dirty markers drained (the deltas were
/// shipped in earlier rounds).
fn converged_replicas(n: usize) -> Vec<Keyed> {
    let mut base: Keyed = WindowedCrdt::new(WindowAssigner::tumbling(1000), [0, 1, 2]);
    for k in 0..400u64 {
        let p = (k % 3) as u32;
        let ts = 100 + (k % 3) * 1000;
        base.insert_with(p, ts, |m| {
            m.ensure_shards(8);
            m.entry(k).add(p as u64, k + 1);
        })
        .unwrap();
    }
    for p in 0..3u32 {
        base.increment_watermark(p, 3500);
    }
    let mut reps: Vec<Keyed> = (0..n).map(|_| base.clone()).collect();
    for r in &mut reps {
        let _ = SharedState::take_delta(r); // markers drained
        assert!(!SharedState::has_delta(r));
    }
    reps
}

/// The acceptance-criterion regression (failing before trait v3): after
/// a received full-sync round, the next delta round ships <5% of the
/// full-state bytes when the replicas have not diverged. This mirrors
/// the engine's gossip protocol exactly — a full-sync payload is decoded
/// and joined via `SharedState::join`, then the receiver's next delta is
/// what `take_delta` encodes.
#[test]
fn post_full_sync_delta_round_ships_under_5_percent() {
    let mut reps = converged_replicas(3);
    let full_bytes = reps[0].to_bytes();
    assert!(full_bytes.len() > 2000, "full state must be non-trivial");

    // anti-entropy round: replica 0 broadcasts its full state
    let payload = Keyed::from_bytes(&full_bytes).unwrap();
    for r in &mut reps[1..] {
        // nothing diverged: the join reports a complete no-op ...
        assert_eq!(SharedState::join(r, &payload), MergeOutcome::Unchanged);
    }
    for r in &mut reps[1..] {
        // ... so the receiver has nothing to gossip (the engine skips
        // the encode/broadcast of this round entirely) ...
        assert!(
            !SharedState::has_delta(r),
            "a subsumed full-sync must not re-arm the delta"
        );
        // ... and even encoding the delta anyway ships near-zero bytes
        // (the empty window set plus the small progress map).
        let delta_bytes = SharedState::take_delta(r).to_bytes();
        assert!(
            delta_bytes.len() * 20 < full_bytes.len(),
            "post-full-sync delta round ships {} B — more than 5% of the \
             {} B full state (the pre-v3 amplification)",
            delta_bytes.len(),
            full_bytes.len()
        );
    }
}

/// Genuine divergence still propagates — and the delta after a full sync
/// carries exactly the divergent shard, not the whole state.
#[test]
fn post_full_sync_delta_carries_only_genuine_divergence() {
    let mut reps = converged_replicas(2);
    let full_size = reps[0].to_bytes().len();

    // the sender diverged on one key before its full-sync broadcast
    let mut sender = reps.remove(0);
    sender
        .insert_with(0, 3500, |m| {
            m.entry(9).add(0, 1000);
        })
        .unwrap();
    let payload = Keyed::from_bytes(&sender.to_bytes()).unwrap();

    let receiver = &mut reps[0];
    assert_eq!(SharedState::join(receiver, &payload), MergeOutcome::Changed);
    assert!(
        SharedState::has_delta(receiver),
        "new information must re-arm the delta for transitive gossip"
    );
    let delta = SharedState::take_delta(receiver);
    let delta_bytes = delta.to_bytes().len();
    assert!(
        delta_bytes * 5 < full_size,
        "delta after divergent full-sync must stay shard-sized: {delta_bytes} B vs {full_size} B"
    );
    // the delta converges a stale replica on exactly the divergent value
    let mut stale = converged_replicas(1).remove(0);
    assert_eq!(SharedState::join(&mut stale, &delta), MergeOutcome::Changed);
    let w3 = stale.raw_window(3).expect("divergent window present");
    assert_eq!(w3.get(&9).unwrap().value(), 1000);
}

/// The engine-level empty-delta fast path (satellite of the trait-v3
/// redesign): a delta-mode replica with nothing dirty and no watermark
/// movement skips the gossip encode and broadcast entirely — asserted
/// via `Bus::bytes_sent` against an otherwise-identical full-state-mode
/// cluster, which encodes and ships every round.
#[test]
fn idle_delta_cluster_skips_empty_gossip_rounds() {
    fn idle_run(delta: bool) -> (u64, u64, u64) {
        let mut cfg = HolonConfig::default();
        cfg.nodes = 3;
        cfg.partitions = 6;
        cfg.gossip_delta = delta;
        cfg.gossip_interval_ms = 50;
        cfg.wall_ms_per_sim_sec = 50.0;
        cfg.seed = 7;
        let clock = SimClock::scaled(cfg.wall_ms_per_sim_sec);
        let cluster = HolonCluster::start_with_clock(cfg, Q7::new(1000), clock.clone());
        // no producer: the cluster is idle, watermarks never move
        std::thread::sleep(clock.wall_for(6000));
        cluster.stop();
        (
            cluster.bus.bytes_sent(),
            cluster.metrics.gossip_sent.load(Ordering::Acquire),
            cluster.metrics.gossip_skipped.load(Ordering::Acquire),
        )
    }

    let (full_bytes, full_sent, full_skipped) = idle_run(false);
    let (delta_bytes, delta_sent, delta_skipped) = idle_run(true);

    // full-state mode never skips (every round carries the anti-entropy)
    assert_eq!(full_skipped, 0);
    assert!(full_sent > 0);
    // delta mode skips the empty rounds and ships only the periodic
    // full syncs — an order of magnitude fewer sends; allow wide margin
    // for scheduling jitter
    assert!(
        delta_skipped > delta_sent,
        "idle delta rounds must be skipped ({delta_skipped} skipped vs {delta_sent} sent)"
    );
    assert!(
        delta_sent * 3 < full_sent,
        "delta mode must ship far fewer rounds ({delta_sent} vs {full_sent})"
    );
    assert!(delta_sent > 0, "full-sync anti-entropy must still flow");
    assert!(
        delta_bytes * 2 < full_bytes,
        "skipped rounds must show up as wire bytes saved ({delta_bytes} B vs {full_bytes} B)"
    );
}
