//! Whole-system determinism: two cluster runs over the same input must
//! produce byte-identical deduplicated outputs — including runs where
//! one of them suffers failures and work stealing. This is the paper's
//! central claim (§2.4/§3.3): output is a function of the input alone,
//! regardless of execution and network order.

use holon::api::Processor;
use holon::clock::SimClock;
use holon::codec::Encode;
use holon::config::HolonConfig;
use holon::engine::node::decode_output;
use holon::engine::HolonCluster;
use holon::log::Topic;
use holon::nexmark::queries::{
    dataflow_q4_sharded, dataflow_q5, dataflow_q5_sharded, dataflow_q7, Query1, Q4, Q5, Q7,
};
use holon::nexmark::NexmarkGen;
use holon::sim::{check_exactly_once, run_plan_with, FaultPlan, RunArtifacts, SimSpec};

fn cfg(seed: u64) -> HolonConfig {
    let mut cfg = HolonConfig::default();
    cfg.nodes = 4;
    cfg.partitions = 8;
    cfg.events_per_sec_per_partition = 1500;
    cfg.wall_ms_per_sim_sec = 50.0;
    cfg.duration_ms = 6000;
    cfg.window_ms = 1000;
    cfg.gossip_interval_ms = 50;
    cfg.checkpoint_interval_ms = 400;
    cfg.heartbeat_interval_ms = 200;
    cfg.failure_timeout_ms = 800;
    cfg.seed = seed;
    cfg
}

/// Deduplicated inner payloads per partition, decoded as raw bytes.
fn dedup_payloads(output: &Topic, partitions: u32) -> Vec<Vec<Vec<u8>>> {
    (0..partitions)
        .map(|p| {
            let (recs, _) = output.read(p, 0, usize::MAX >> 1);
            let mut seen = 0u64;
            let mut outs = Vec::new();
            for rec in recs {
                let (seq, _ts, inner) = decode_output(&rec.payload).unwrap();
                if seq < seen {
                    continue;
                }
                seen = seq + 1;
                outs.push(inner.to_vec());
            }
            outs
        })
        .collect()
}

/// Pre-seed a byte-identical input log: the *input* must be the same
/// across compared runs (a live rate-based producer would jitter event
/// timestamps and change window contents — that would compare different
/// inputs, not different executions).
fn seed_input(input: &Topic, cfg: &HolonConfig) {
    for p in 0..cfg.partitions {
        let mut gen = NexmarkGen::new(cfg.seed, p);
        let n = cfg.events_per_sec_per_partition * cfg.duration_ms / 1000;
        let batch: Vec<(u64, Vec<u8>)> = (0..n)
            .map(|i| {
                let ts = i * 1000 / cfg.events_per_sec_per_partition;
                (ts, gen.next_event().to_bytes())
            })
            .collect();
        input.append_batch(p, batch);
    }
}

/// Run a cluster (optionally with failure injection) over a pre-seeded
/// deterministic input and return its deduplicated output payloads.
fn run_once<P: Processor>(processor: P, seed: u64, with_failures: bool) -> Vec<Vec<Vec<u8>>> {
    let cfg = cfg(seed);
    let clock = SimClock::scaled(cfg.wall_ms_per_sim_sec);
    let cluster = HolonCluster::start_with_clock(cfg.clone(), processor, clock.clone());
    seed_input(&cluster.input, &cfg);
    if with_failures {
        std::thread::sleep(clock.wall_for(2000));
        cluster.fail_node(1);
        std::thread::sleep(clock.wall_for(1500));
        cluster.restart_node(1);
        std::thread::sleep(clock.wall_for(cfg.duration_ms - 3500 + 3500));
    } else {
        std::thread::sleep(clock.wall_for(cfg.duration_ms + 3500));
    }
    cluster.stop();
    dedup_payloads(&cluster.output, cfg.partitions)
}

/// Compare the common prefix of two runs' outputs (runs may complete a
/// different number of windows; the completed prefix must be identical).
fn assert_prefix_equal(a: &[Vec<Vec<u8>>], b: &[Vec<Vec<u8>>], min_windows: usize) {
    assert_eq!(a.len(), b.len());
    for (p, (pa, pb)) in a.iter().zip(b.iter()).enumerate() {
        let common = pa.len().min(pb.len());
        assert!(
            common >= min_windows,
            "partition {p}: only {common} common outputs"
        );
        for i in 0..common {
            assert_eq!(pa[i], pb[i], "partition {p}, output {i} differs");
        }
    }
}

#[test]
fn q7_output_is_a_function_of_input() {
    let a = run_once(Q7::new(1000), 11, false);
    let b = run_once(Q7::new(1000), 11, false);
    assert_prefix_equal(&a, &b, 3);
}

#[test]
fn q7_failures_do_not_change_output() {
    // The strongest determinism claim: a run with two node failures and
    // work stealing emits the same windows as an undisturbed run.
    let clean = run_once(Q7::new(1000), 17, false);
    let faulty = run_once(Q7::new(1000), 17, true);
    assert_prefix_equal(&clean, &faulty, 3);
}

#[test]
fn q4_failures_do_not_change_output() {
    let clean = run_once(Q4::new(1000), 23, false);
    let faulty = run_once(Q4::new(1000), 23, true);
    assert_prefix_equal(&clean, &faulty, 3);
}

#[test]
fn query1_failures_do_not_change_output() {
    let clean = run_once(Query1::new(1000), 29, false);
    let faulty = run_once(Query1::new(1000), 29, true);
    assert_prefix_equal(&clean, &faulty, 3);
}

#[test]
fn dataflow_q7_matches_procedural_q7_on_cluster() {
    // The ISSUE-1 differential claim at full scale: the dataflow-API Q7
    // emits byte-identical deduplicated outputs to the hand-written
    // procedural Q7 over the same seeded input, on a real multi-node
    // cluster (different code paths, same deterministic function).
    let procedural = run_once(Q7::new(1000), 47, false);
    let dataflow = run_once(dataflow_q7(1000), 47, false);
    assert_prefix_equal(&procedural, &dataflow, 3);
}

#[test]
fn dataflow_q7_survives_failures_like_procedural() {
    // Work stealing + replay under the v2 pipeline must not change a
    // single output byte relative to the undisturbed procedural oracle.
    let procedural = run_once(Q7::new(1000), 53, false);
    let dataflow_faulty = run_once(dataflow_q7(1000), 53, true);
    assert_prefix_equal(&procedural, &dataflow_faulty, 3);
}

#[test]
fn dataflow_q5_matches_procedural_q5_on_cluster() {
    // Sliding windows + keyed aggregation through the v2 builder.
    let procedural = run_once(Q5::new(2000, 1000), 59, false);
    let dataflow = run_once(dataflow_q5(2000, 1000), 59, false);
    assert_prefix_equal(&procedural, &dataflow, 2);
}

#[test]
fn delta_gossip_is_equivalent_to_full_gossip() {
    // §7 delta synchronization must not change any output.
    let full = run_once(Q7::new(1000), 41, false);

    let mut cfg2 = cfg(41);
    cfg2.gossip_delta = true;
    let clock = SimClock::scaled(cfg2.wall_ms_per_sim_sec);
    let cluster = HolonCluster::start_with_clock(cfg2.clone(), Q7::new(1000), clock.clone());
    seed_input(&cluster.input, &cfg2);
    std::thread::sleep(clock.wall_for(cfg2.duration_ms + 3500));
    cluster.stop();
    let delta = dedup_payloads(&cluster.output, cfg2.partitions);

    assert_prefix_equal(&full, &delta, 3);
}

#[test]
fn sharded_delta_gossip_is_equivalent_to_full_gossip() {
    // Per-shard delta payloads must not change any output: the delta
    // run ships only dirty shards of dirty windows per round, with
    // periodic full-state anti-entropy.
    let full = run_once(dataflow_q4_sharded(1000, 8), 43, false);

    let mut cfg2 = cfg(43);
    cfg2.gossip_delta = true;
    let clock = SimClock::scaled(cfg2.wall_ms_per_sim_sec);
    let cluster =
        HolonCluster::start_with_clock(cfg2.clone(), dataflow_q4_sharded(1000, 8), clock.clone());
    seed_input(&cluster.input, &cfg2);
    std::thread::sleep(clock.wall_for(cfg2.duration_ms + 3500));
    cluster.stop();
    let delta = dedup_payloads(&cluster.output, cfg2.partitions);

    assert_prefix_equal(&full, &delta, 3);
}

#[test]
fn delta_gossip_with_fanout_matches_default_gossip() {
    // Regression companion to the full-sync/fanout fix: delta mode with
    // an aggressively sampled fan-out (full-sync rounds forced to all
    // peers by `gossip_plan`) must deliver the same outputs as the
    // default (full-state, auto-fanout) gossip configuration.
    let baseline = run_once(Q7::new(1000), 37, false);

    let mut cfg2 = cfg(37);
    cfg2.gossip_delta = true;
    cfg2.gossip_fanout = 1; // aggressive sampling: 1 of 3 peers per round
    let clock = SimClock::scaled(cfg2.wall_ms_per_sim_sec);
    let cluster = HolonCluster::start_with_clock(cfg2.clone(), Q7::new(1000), clock.clone());
    seed_input(&cluster.input, &cfg2);
    std::thread::sleep(clock.wall_for(cfg2.duration_ms + 3500));
    cluster.stop();
    let sampled = dedup_payloads(&cluster.output, cfg2.partitions);

    assert_prefix_equal(&baseline, &sampled, 3);
}

/// Compare the deduped per-partition output prefixes of two sim-harness
/// runs (seq-ordered `(seq, payload)` streams).
fn assert_artifact_prefix_equal(a: &RunArtifacts, b: &RunArtifacts, min_outputs: usize, tag: &str) {
    assert_eq!(a.partitions, b.partitions);
    for (p, (pa, pb)) in a.deduped.iter().zip(&b.deduped).enumerate() {
        let common = pa.len().min(pb.len());
        assert!(
            common >= min_outputs,
            "{tag}: partition {p} has only {common} common outputs"
        );
        for i in 0..common {
            assert_eq!(pa[i].0, pb[i].0, "{tag}: partition {p} seq {i}");
            assert_eq!(pa[i].1, pb[i].1, "{tag}: partition {p} output {i} differs");
        }
    }
}

#[test]
fn sharded_q4_matches_unsharded_under_seeded_faults() {
    // The subsystem's acceptance claim: sharded and unsharded keyed
    // pipelines are byte-identical under the sim harness's seeded fault
    // schedules, for shard counts {1, 4, 16}. The oracle is the
    // procedural (flat MapCrdt, batch-aggregated) Q4 on a fault-free
    // run; each sharded run executes a generated kill/restart/
    // partition/burst schedule.
    let spec = SimSpec { seed: 77, ..SimSpec::default() };
    let plan = FaultPlan::generate(77, spec.nodes, spec.fault_window());
    let oracle = run_plan_with(&spec, &FaultPlan::empty(), None, Q4::new(spec.window_ms));
    for shards in [1u32, 4, 16] {
        let sharded = run_plan_with(
            &spec,
            &plan,
            None,
            dataflow_q4_sharded(spec.window_ms, shards),
        );
        // the processor-generic half of the sim oracle suite: dup-free,
        // gap-free, byte-identical replays (convergence is Query1-only;
        // see run_plan_with)
        if let Err(f) = check_exactly_once(&sharded) {
            panic!("q4 {shards} shards: {f}");
        }
        assert_artifact_prefix_equal(&oracle, &sharded, 2, &format!("q4 {shards} shards"));
    }
}

#[test]
fn sharded_q5_matches_unsharded_under_seeded_faults() {
    let spec = SimSpec { seed: 83, ..SimSpec::default() };
    let plan = FaultPlan::generate(83, spec.nodes, spec.fault_window());
    let oracle = run_plan_with(&spec, &FaultPlan::empty(), None, Q5::new(2000, 1000));
    for shards in [1u32, 4, 16] {
        let sharded = run_plan_with(
            &spec,
            &plan,
            None,
            dataflow_q5_sharded(2000, 1000, shards),
        );
        if let Err(f) = check_exactly_once(&sharded) {
            panic!("q5 {shards} shards: {f}");
        }
        assert_artifact_prefix_equal(&oracle, &sharded, 2, &format!("q5 {shards} shards"));
    }
}

#[test]
fn different_seeds_differ() {
    // sanity: the comparison above is not vacuous
    let a = run_once(Q7::new(1000), 31, false);
    let b = run_once(Q7::new(1000), 32, false);
    let same = a
        .iter()
        .zip(b.iter())
        .all(|(pa, pb)| pa.iter().zip(pb.iter()).all(|(x, y)| x == y));
    assert!(!same, "different inputs produced identical outputs");
}
