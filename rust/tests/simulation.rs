//! Deterministic simulation smoke tests (CI tier): a fixed set of
//! seeds, each expanding into a generated fault schedule — kills,
//! restarts, crashes, partitions, delay/loss bursts, reconfiguration —
//! executed against a live cluster and checked by the oracle suite
//! (exactly-once delivery, determinism vs. a fault-free golden run,
//! replica convergence).
//!
//! On falsification the harness shrinks the schedule and the panic
//! message carries a one-line repro:
//!
//! ```text
//! HOLON_SIM_SEED=… HOLON_SIM_PLAN='…' \
//!     cargo test --release --test simulation replay_from_env -- --nocapture
//! ```
//!
//! Long soaks over many seeds run via `holon sim --seeds=N`.

use holon::sim::{
    check_seed, run_seed_with, FaultAction, FaultPlan, Mutation, SimSpec,
};

/// Run a batch of seeds, panicking with the shrunk repro on failure.
fn run_seed_batch(seeds: std::ops::Range<u64>) {
    for seed in seeds {
        if let Err(f) = check_seed(seed) {
            panic!("{f}");
        }
    }
}

// The fixed CI seed set: 24 distinct seeds across four parallel test
// threads (the acceptance bar is ≥ 20).

#[test]
fn sim_seeds_batch_a() {
    run_seed_batch(0..6);
}

#[test]
fn sim_seeds_batch_b() {
    run_seed_batch(6..12);
}

#[test]
fn sim_seeds_batch_c() {
    run_seed_batch(12..18);
}

#[test]
fn sim_seeds_batch_d() {
    run_seed_batch(18..24);
}

/// Replay a schedule pinned by `HOLON_SIM_SEED` / `HOLON_SIM_PLAN` —
/// the target of the repro line the harness prints. A no-op pass when
/// the env vars are unset (the normal CI case).
#[test]
fn replay_from_env() {
    let Ok(seed_str) = std::env::var("HOLON_SIM_SEED") else {
        return;
    };
    let seed: u64 = seed_str.parse().expect("HOLON_SIM_SEED must be a u64");
    let spec = SimSpec {
        seed,
        ..SimSpec::default()
    };
    let plan = match std::env::var("HOLON_SIM_PLAN") {
        Ok(p) => FaultPlan::parse(&p).expect("bad HOLON_SIM_PLAN"),
        Err(_) => FaultPlan::generate(seed, spec.nodes, spec.fault_window()),
    };
    eprintln!("replaying seed {seed} plan `{plan}`");
    if let Err(f) = run_seed_with(&spec, &plan, None) {
        panic!("{f}");
    }
}

/// Mutation check of the harness itself: an intentionally injected
/// dedup bug (a replayed output leaking past dedup) must be caught by
/// the oracles, shrink to a minimal — here plan-independent, so empty —
/// schedule, and yield a replayable repro line.
#[test]
fn oracles_catch_injected_dedup_bug() {
    let spec = SimSpec {
        seed: 4242,
        ..SimSpec::default()
    };
    let plan = FaultPlan::generate(spec.seed, spec.nodes, spec.fault_window());
    let failure = run_seed_with(&spec, &plan, Some(Mutation::DuplicateDelivery))
        .expect_err("injected dedup bug went undetected");
    assert!(
        failure.failure.contains("duplicate delivery"),
        "wrong oracle fired: {}",
        failure.failure
    );
    // the bug is plan-independent, so the shrinker must strip the
    // schedule down to (at most a fragment of) nothing
    assert!(
        failure.shrunk_plan.events.len() < plan.events.len() || plan.events.is_empty(),
        "shrinker made no progress: {} -> {}",
        plan,
        failure.shrunk_plan
    );
    // and the repro line must be replayable as-is
    assert!(failure.repro.contains(&format!("HOLON_SIM_SEED={}", spec.seed)));
    assert!(failure.repro.contains("HOLON_SIM_PLAN="));
    let reparsed = FaultPlan::parse(&failure.shrunk_plan.to_plan_string()).unwrap();
    assert_eq!(reparsed, failure.shrunk_plan);
    // every falsification ships with a flight-recorder dump of the
    // shrunk schedule, and the failure report names its path
    let dump = failure
        .trace_dump
        .as_deref()
        .expect("oracle failure must write a trace dump");
    assert_eq!(dump, &format!("holon-trace-dump-seed{}.json", spec.seed));
    let json = std::fs::read_to_string(dump).expect("dump file exists");
    assert!(json.contains("\"traceEvents\""), "not a Chrome trace: {json:.40}");
    assert!(format!("{failure}").contains(dump), "report must name the dump");
    let _ = std::fs::remove_file(dump);
    eprintln!("caught: {failure}");
}

/// A second mutation: losing an output must trip the gap oracle.
#[test]
fn oracles_catch_injected_output_loss() {
    let spec = SimSpec {
        seed: 777,
        // no schedule needed: the defect is injected directly, so keep
        // the run short and the shrink cheap
        duration_ms: 4000,
        ..SimSpec::default()
    };
    let failure = run_seed_with(&spec, &FaultPlan::empty(), Some(Mutation::DropDelivery))
        .expect_err("injected output loss went undetected");
    assert!(
        failure.failure.contains("sequence gap"),
        "wrong oracle fired: {}",
        failure.failure
    );
    assert!(failure.shrunk_plan.is_empty());
}

/// Determinism mutation: a corrupted payload must trip the golden-run
/// comparison.
#[test]
fn oracles_catch_injected_corruption() {
    let spec = SimSpec {
        seed: 909,
        duration_ms: 4000,
        ..SimSpec::default()
    };
    let failure = run_seed_with(&spec, &FaultPlan::empty(), Some(Mutation::CorruptPayload))
        .expect_err("injected corruption went undetected");
    assert!(
        failure.failure.contains("differs from golden")
            || failure.failure.contains("replayed output differs"),
        "wrong oracle fired: {}",
        failure.failure
    );
}

/// Convergence mutation: a skewed replica must trip the replica checks.
#[test]
fn oracles_catch_injected_replica_skew() {
    let spec = SimSpec {
        seed: 1313,
        duration_ms: 4000,
        ..SimSpec::default()
    };
    let failure = run_seed_with(&spec, &FaultPlan::empty(), Some(Mutation::SkewReplica))
        .expect_err("injected replica skew went undetected");
    assert!(
        failure.failure.contains("replica"),
        "wrong oracle fired: {}",
        failure.failure
    );
}

/// The generated schedules must actually exercise recovery machinery:
/// across the CI seed set, a healthy majority of plans contain kills,
/// and at least one contains each fault family.
#[test]
fn generated_schedules_cover_all_fault_families() {
    let spec = SimSpec::default();
    let mut kills = 0;
    let (mut partitions, mut bursts, mut reconfigs) = (0, 0, 0);
    for seed in 0..24u64 {
        let plan = FaultPlan::generate(seed, spec.nodes, spec.fault_window());
        for e in &plan.events {
            match e.action {
                FaultAction::Kill(_) => kills += 1,
                FaultAction::Partition(_) => partitions += 1,
                FaultAction::Loss { .. } | FaultAction::Delay { .. } => bursts += 1,
                FaultAction::AddNode(_) => reconfigs += 1,
                _ => {}
            }
        }
    }
    assert!(kills >= 8, "only {kills} kills across the seed set");
    assert!(partitions >= 1, "no partitions generated");
    assert!(bursts >= 1, "no delay/loss bursts generated");
    assert!(reconfigs >= 1, "no reconfigurations generated");
}
