//! End-to-end integration: Nexmark workloads on a running HolonCluster
//! (real node threads, logged streams, gossip, checkpoints).

use holon::api::{demux, MultiQuery};
use holon::clock::SimClock;
use holon::codec::Decode;
use holon::config::HolonConfig;
use holon::engine::node::decode_output;
use holon::engine::HolonCluster;
use holon::nexmark::producer;
use holon::nexmark::queries::{
    dataflow_q2, dataflow_q5, dataflow_q7, Q2Out, Q4Out, Q5Out, Q7Out, Query1, RatioOut, Q0, Q4,
    Q7,
};

fn test_config() -> HolonConfig {
    let mut cfg = HolonConfig::default();
    cfg.nodes = 3;
    cfg.partitions = 6;
    cfg.events_per_sec_per_partition = 2000;
    cfg.wall_ms_per_sim_sec = 50.0; // 1 sim-s = 50 wall-ms
    cfg.duration_ms = 6000;
    cfg.window_ms = 1000;
    cfg.gossip_interval_ms = 50;
    cfg.checkpoint_interval_ms = 500;
    cfg.heartbeat_interval_ms = 200;
    cfg.failure_timeout_ms = 1000;
    cfg
}

/// Collect deduplicated decoded outputs per partition from the output topic.
fn decoded_outputs<T: Decode>(
    cluster: &HolonCluster<impl holon::api::Processor>,
) -> Vec<Vec<T>> {
    let mut per_part = Vec::new();
    for p in 0..cluster.cfg.partitions {
        let (recs, _) = cluster.output.read(p, 0, usize::MAX >> 1);
        let mut seen = 0u64;
        let mut outs = Vec::new();
        for rec in recs {
            let (seq, _ref_ts, inner) = decode_output(&rec.payload).unwrap();
            if seq < seen {
                continue; // duplicate from replay
            }
            seen = seq + 1;
            outs.push(T::from_bytes(&inner).unwrap());
        }
        per_part.push(outs);
    }
    per_part
}

#[test]
fn q7_cluster_end_to_end() {
    let cfg = test_config();
    let clock = SimClock::scaled(cfg.wall_ms_per_sim_sec);
    let cluster = HolonCluster::start_with_clock(cfg.clone(), Q7::new(cfg.window_ms), clock.clone());
    let prod = producer::spawn(
        cluster.input.clone(),
        clock.clone(),
        cfg.seed,
        cfg.events_per_sec_per_partition,
        cfg.duration_ms,
    );
    // run the experiment + drain tail
    std::thread::sleep(clock.wall_for(cfg.duration_ms + 3000));
    prod.stop();
    cluster.stop();

    let outs: Vec<Vec<Q7Out>> = decoded_outputs(&cluster);
    // every partition must have emitted a prefix of windows 0..n
    let min_windows = outs.iter().map(|o| o.len()).min().unwrap();
    assert!(
        min_windows >= 3,
        "too few completed windows: {:?}",
        outs.iter().map(|o| o.len()).collect::<Vec<_>>()
    );
    for part in &outs {
        for (i, o) in part.iter().enumerate() {
            assert_eq!(o.window, i as u64, "windows must be emitted in order");
        }
    }
    // global determinism: all partitions agree on every common window
    for w in 0..min_windows {
        let first = &outs[0][w];
        for part in &outs[1..] {
            assert_eq!(&part[w], first, "window {w} disagrees across partitions");
        }
        assert!(first.price > 0.0, "window {w} should have bids");
    }
    // sink metrics recorded
    assert!(cluster.metrics.outputs.load(std::sync::atomic::Ordering::Acquire) > 0);
    assert!(cluster.metrics.latency.count() > 0);
}

#[test]
fn q0_passthrough_preserves_volume() {
    let mut cfg = test_config();
    cfg.duration_ms = 2000;
    let clock = SimClock::scaled(cfg.wall_ms_per_sim_sec);
    let cluster = HolonCluster::start_with_clock(cfg.clone(), Q0, clock.clone());
    let prod = producer::spawn(
        cluster.input.clone(),
        clock.clone(),
        cfg.seed,
        1000,
        cfg.duration_ms,
    );
    std::thread::sleep(clock.wall_for(cfg.duration_ms + 2000));
    let produced = prod.stop();
    cluster.stop();

    // Each input event is passed through exactly once (after dedup).
    let mut total = 0;
    for p in 0..cfg.partitions {
        let (recs, _) = cluster.output.read(p, 0, usize::MAX >> 1);
        let mut seen = 0u64;
        for rec in recs {
            let (seq, ..) = decode_output(&rec.payload).unwrap();
            if seq >= seen {
                seen = seq + 1;
                total += 1;
            }
        }
    }
    assert_eq!(total, produced, "passthrough must preserve event count");
}

#[test]
fn q4_categories_converge_across_partitions() {
    let cfg = test_config();
    let clock = SimClock::scaled(cfg.wall_ms_per_sim_sec);
    let cluster = HolonCluster::start_with_clock(cfg.clone(), Q4::new(cfg.window_ms), clock.clone());
    let prod = producer::spawn(
        cluster.input.clone(),
        clock.clone(),
        cfg.seed,
        cfg.events_per_sec_per_partition,
        cfg.duration_ms,
    );
    std::thread::sleep(clock.wall_for(cfg.duration_ms + 3000));
    prod.stop();
    cluster.stop();

    let outs: Vec<Vec<Q4Out>> = decoded_outputs(&cluster);
    let min_windows = outs.iter().map(|o| o.len()).min().unwrap();
    assert!(min_windows >= 3);
    for w in 0..min_windows {
        for part in &outs[1..] {
            assert_eq!(part[w], outs[0][w], "Q4 window {w} must be deterministic");
        }
        // with 6 partitions * 2000 ev/s, every category gets bids
        assert!(outs[0][w].rows.len() >= 5, "rows: {:?}", outs[0][w].rows);
    }
}

#[test]
fn dataflow_q5_sliding_windows_on_cluster() {
    // The dataflow API v2 end to end: keyed aggregation over sliding
    // windows (each bid folds into two covering windows).
    let cfg = test_config();
    let clock = SimClock::scaled(cfg.wall_ms_per_sim_sec);
    let cluster =
        HolonCluster::start_with_clock(cfg.clone(), dataflow_q5(2000, 1000), clock.clone());
    let prod = producer::spawn(
        cluster.input.clone(),
        clock.clone(),
        cfg.seed,
        cfg.events_per_sec_per_partition,
        cfg.duration_ms,
    );
    std::thread::sleep(clock.wall_for(cfg.duration_ms + 3000));
    prod.stop();
    cluster.stop();

    let outs: Vec<Vec<Q5Out>> = decoded_outputs(&cluster);
    let min_windows = outs.iter().map(|o| o.len()).min().unwrap();
    assert!(min_windows >= 3, "too few completed sliding windows");
    for w in 0..min_windows {
        // global determinism across partitions, same as the procedural API
        for part in &outs[1..] {
            assert_eq!(part[w], outs[0][w], "Q5 window {w} disagrees");
        }
        assert!(outs[0][w].bids > 0, "hot item of window {w} has bids");
    }
}

#[test]
fn multiquery_shares_one_job_on_cluster() {
    // One engine job fans the stream into a windowed pipeline (Q7) and a
    // stateless selection (Q2); outputs demux by branch tag.
    let mut cfg = test_config();
    cfg.duration_ms = 4000;
    let clock = SimClock::scaled(cfg.wall_ms_per_sim_sec);
    let q = MultiQuery::new(dataflow_q7(cfg.window_ms), dataflow_q2(3));
    let cluster = HolonCluster::start_with_clock(cfg.clone(), q, clock.clone());
    let prod = producer::spawn(
        cluster.input.clone(),
        clock.clone(),
        cfg.seed,
        cfg.events_per_sec_per_partition,
        cfg.duration_ms,
    );
    std::thread::sleep(clock.wall_for(cfg.duration_ms + 3000));
    prod.stop();
    cluster.stop();

    let mut q7_per_part: Vec<Vec<Q7Out>> = Vec::new();
    let mut q2_total = 0usize;
    for p in 0..cfg.partitions {
        let (recs, _) = cluster.output.read(p, 0, usize::MAX >> 1);
        let mut seen = 0u64;
        let mut q7_outs = Vec::new();
        for rec in recs {
            let (seq, _ref_ts, inner) = decode_output(&rec.payload).unwrap();
            if seq < seen {
                continue;
            }
            seen = seq + 1;
            match demux(&inner) {
                (0, bytes) => q7_outs.push(Q7Out::from_bytes(bytes).unwrap()),
                (1, bytes) => {
                    let o = Q2Out::from_bytes(bytes).unwrap();
                    assert_eq!(o.auction % 3, 0, "Q2 branch must filter auctions");
                    q2_total += 1;
                }
                (tag, _) => panic!("unexpected branch tag {tag}"),
            }
        }
        q7_per_part.push(q7_outs);
    }
    let min_windows = q7_per_part.iter().map(|o| o.len()).min().unwrap();
    assert!(min_windows >= 2, "too few Q7 windows through MultiQuery");
    for w in 0..min_windows {
        for part in &q7_per_part[1..] {
            assert_eq!(part[w], q7_per_part[0][w], "Q7 window {w} disagrees");
        }
    }
    assert!(q2_total > 0, "Q2 branch produced no selections");
}

#[test]
fn query1_ratios_sum_to_one() {
    let cfg = test_config();
    let clock = SimClock::scaled(cfg.wall_ms_per_sim_sec);
    let cluster =
        HolonCluster::start_with_clock(cfg.clone(), Query1::new(cfg.window_ms), clock.clone());
    let prod = producer::spawn(
        cluster.input.clone(),
        clock.clone(),
        cfg.seed,
        cfg.events_per_sec_per_partition,
        cfg.duration_ms,
    );
    std::thread::sleep(clock.wall_for(cfg.duration_ms + 3000));
    prod.stop();
    cluster.stop();

    let outs: Vec<Vec<RatioOut>> = decoded_outputs(&cluster);
    let min_windows = outs.iter().map(|o| o.len()).min().unwrap();
    assert!(min_windows >= 3);
    for w in 0..min_windows {
        // all partitions agree on the global total
        let total = outs[0][w].total;
        assert!(total > 0);
        let mut local_sum = 0;
        for part in &outs {
            assert_eq!(part[w].total, total);
            local_sum += part[w].local;
        }
        // locals partition the global count exactly
        assert_eq!(local_sum, total, "window {w}");
    }
}
