//! Hot-path data-plane regression tests: batch-budget fairness under
//! pressure, zero-copy read accounting, and the sink's delivery audit
//! (gap counter) at cluster level.

use holon::clock::SimClock;
use holon::config::HolonConfig;
use holon::engine::HolonCluster;
use holon::nexmark::producer;
use holon::nexmark::queries::Q7;
use std::sync::atomic::Ordering;

/// Regression (batch-budget starvation): under sustained service-cost
/// budget pressure, the pre-fix RUN_BATCH spent the whole budget in
/// fixed BTreeMap order, so the lowest-numbered partitions consumed
/// everything and the highest-numbered ones starved — stalling the
/// global watermark min. With the rotating start, per-partition progress
/// stays within a couple of batches of each other.
#[test]
fn low_budget_keeps_partition_progress_fair() {
    let mut cfg = HolonConfig::default();
    cfg.nodes = 1;
    cfg.partitions = 4;
    cfg.batch_size = 64;
    cfg.events_per_sec_per_partition = 5_000;
    // ~10k events/sim-s of budget vs 20k/s of offered load: the node
    // runs at ~2x overload for the whole test.
    cfg.holon_event_cost_us = 100.0;
    cfg.wall_ms_per_sim_sec = 100.0;
    cfg.duration_ms = 4_000;
    cfg.checkpoint_interval_ms = 500;

    let clock = SimClock::scaled(cfg.wall_ms_per_sim_sec);
    let cluster = HolonCluster::start_with_clock(cfg.clone(), Q7::new(cfg.window_ms), clock.clone());
    let prod = producer::spawn(
        cluster.input.clone(),
        clock.clone(),
        cfg.seed,
        cfg.events_per_sec_per_partition,
        cfg.duration_ms,
    );
    std::thread::sleep(clock.wall_for(cfg.duration_ms + 1000));
    prod.stop();
    cluster.stop();

    // per-partition consumed offsets from the (graceful-shutdown) checkpoints
    let idx: Vec<u64> = (0..cfg.partitions)
        .map(|p| cluster.store.get(p).expect("checkpoint per partition").nxt_idx)
        .collect();
    let min = *idx.iter().min().unwrap();
    let max = *idx.iter().max().unwrap();
    // overload sanity: the budget really was the constraint (otherwise
    // the test passes vacuously because everything was consumed)
    for p in 0..cfg.partitions {
        assert!(
            cluster.input.end_offset(p) > idx[p as usize],
            "partition {p} fully drained — no budget pressure, test is vacuous"
        );
    }
    assert!(
        min >= cfg.batch_size as u64,
        "every partition must make progress, got {idx:?}"
    );
    // fairness: within a couple of batches (rotation grants each
    // partition the first slot every `partitions` rounds)
    assert!(
        max - min <= 2 * cfg.batch_size as u64,
        "per-partition progress spread too wide under budget pressure: {idx:?}"
    );
}

/// Cluster-level delivery audit + zero-copy accounting: a healthy run
/// has no output sequence gaps, and the hot path (RUN_BATCH + sink)
/// never materializes record clones — the copying `read` path is only
/// used by test oracles after the run.
#[test]
fn healthy_run_has_zero_gaps_and_zero_hotpath_clones() {
    let mut cfg = HolonConfig::default();
    cfg.nodes = 3;
    cfg.partitions = 6;
    cfg.events_per_sec_per_partition = 2_000;
    cfg.wall_ms_per_sim_sec = 50.0;
    cfg.duration_ms = 5_000;

    let clock = SimClock::scaled(cfg.wall_ms_per_sim_sec);
    let cluster = HolonCluster::start_with_clock(cfg.clone(), Q7::new(cfg.window_ms), clock.clone());
    let prod = producer::spawn(
        cluster.input.clone(),
        clock.clone(),
        cfg.seed,
        cfg.events_per_sec_per_partition,
        cfg.duration_ms,
    );
    std::thread::sleep(clock.wall_for(cfg.duration_ms + 2000));
    let produced = prod.stop();
    cluster.stop();
    assert!(produced > 0);
    assert!(cluster.metrics.outputs.load(Ordering::Acquire) > 0);

    // delivery audit: no sequence numbers skipped by the sink
    assert_eq!(cluster.metrics.gaps.load(Ordering::Acquire), 0);

    // zero-copy accounting, sampled BEFORE any test-side read() call
    let (in_clones, in_read) = cluster.input.read_stats();
    let (out_clones, out_read) = cluster.output.read_stats();
    assert_eq!(in_clones + out_clones, 0, "hot path must not clone records");
    assert!(in_read > 0 && out_read > 0, "hot path must visit records");

    // ...and the copying oracle path is still available and counted
    let (recs, _) = cluster.output.read(0, 0, 16);
    let (out_clones_after, _) = cluster.output.read_stats();
    assert_eq!(out_clones_after, recs.len() as u64);
}
